//! Genz test-function suite — the standard benchmark battery for
//! multidimensional integration — run as one multifunction batch and
//! gated against closed forms.
//!
//! Families (Genz 1984): oscillatory, product peak, Gaussian, plus the
//! monomial/abs families used elsewhere in the repo. Each family is
//! instantiated at several difficulty levels (c-norms).
//!
//! ```text
//! cargo run --release --example genz_suite
//! ```

use zmc::analytic;
use zmc::integrator::spec::IntegralJob;
use zmc::session::Session;

struct Case {
    name: String,
    job: IntegralJob,
    truth: f64,
}

fn main() -> anyhow::Result<()> {
    let samples = std::env::var("ZMC_SAMPLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1 << 18);
    let session = Session::builder()
        .artifacts_or_emulator("artifacts")
        .workers(1)
        .build()?;
    let unit2 = [(0.0, 1.0), (0.0, 1.0)];
    let unit3 = [(0.0, 1.0), (0.0, 1.0), (0.0, 1.0)];

    let mut cases: Vec<Case> = Vec::new();
    // oscillatory at increasing frequency: cos(2πu + c1 x1 + c2 x2)
    for (i, scale) in [1.0, 4.0, 9.0].iter().enumerate() {
        let (c, u) = (vec![scale * 1.3, scale * 0.7], 0.25);
        cases.push(Case {
            name: format!("oscillatory[{i}]"),
            job: IntegralJob::with_params(
                "cos(2*pi*p0 + p1*x1 + p2*x2)",
                &unit2,
                &[u, c[0], c[1]],
            )?,
            truth: analytic::genz_oscillatory(u, &c),
        });
    }
    // product peak at w=(0.35, 0.65)
    for (i, scale) in [2.0, 6.0].iter().enumerate() {
        let c = vec![*scale, scale * 1.5];
        let w = vec![0.35, 0.65];
        cases.push(Case {
            name: format!("product_peak[{i}]"),
            job: IntegralJob::with_params(
                "(1/((1/(p0*p0)) + (x1-p2)^2)) \
                 * (1/((1/(p1*p1)) + (x2-p3)^2))",
                &unit2,
                &[c[0], c[1], w[0], w[1]],
            )?,
            truth: analytic::genz_product_peak(&c, &w),
        });
    }
    // gaussian bumps in 3-D
    for (i, scale) in [1.5, 4.0].iter().enumerate() {
        let c = vec![*scale, scale * 0.8, scale * 1.2];
        let w = vec![0.2, 0.5, 0.8];
        cases.push(Case {
            name: format!("gaussian[{i}]"),
            job: IntegralJob::with_params(
                "exp(-( (p0*(x1-p3))^2 + (p1*(x2-p4))^2 + (p2*(x3-p5))^2 ))",
                &unit3,
                &[c[0], c[1], c[2], w[0], w[1], w[2]],
            )?,
            truth: analytic::genz_gaussian(&c, &w),
        });
    }
    // monomials
    for p in [2.0, 6.0] {
        cases.push(Case {
            name: format!("monomial[x^{p}]"),
            job: IntegralJob::with_params("x1^p0", &unit2, &[p])?,
            truth: analytic::monomial(p),
        });
    }

    let jobs: Vec<IntegralJob> =
        cases.iter().map(|c| c.job.clone()).collect();
    let t0 = std::time::Instant::now();
    let ests = session
        .multifunctions(&jobs)
        .samples(samples)
        .seed(31415)
        .run()?;
    let wall = t0.elapsed().as_secs_f64();

    println!("# case  estimate  sigma  truth  |z|");
    let mut worst: f64 = 0.0;
    for (c, e) in cases.iter().zip(&ests) {
        let z = (e.value - c.truth).abs() / e.std_err.max(1e-12);
        worst = worst.max(z);
        println!(
            "{:<18}  {:>10.6}  {:>9.3e}  {:>10.6}  {:>6.2}",
            c.name, e.value, e.std_err, c.truth, z
        );
    }
    println!(
        "# {} Genz cases x {samples} samples: {wall:.2}s (worst |z| = \
         {worst:.2})",
        cases.len()
    );
    assert!(worst < 6.0, "Genz suite inconsistent with closed forms");

    // The same suite driven to a per-function relative-error target:
    // the classic adaptive showcase — smooth families converge on the
    // pilot, the oscillatory/peaked ones soak up the budget.
    let target = std::env::var("ZMC_TARGET_REL")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5e-3);
    let acfg = zmc::integrator::multifunctions::MultiConfig {
        samples_per_fn: samples.max(1 << 16),
        seed: 31415,
        target_rel_err: Some(target),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    // the report-returning adaptive entry point takes the session's
    // LaunchExec directly (the builder's .target_rel_err() path wraps
    // the same loop without the diagnostics)
    let (aests, report) =
        zmc::adaptive::integrate_with_report(session.exec(), &jobs, &acfg)?;
    let awall = t0.elapsed().as_secs_f64();

    println!("# adaptive to {target:.0e} rel err:");
    println!("# case  estimate  sigma  rounds  samples  |z|");
    let mut aworst: f64 = 0.0;
    for (c, e) in cases.iter().zip(&aests) {
        let z = (e.value - c.truth).abs() / e.std_err.max(1e-12);
        aworst = aworst.max(z);
        println!(
            "{:<18}  {:>10.6}  {:>9.3e}  {:>6}  {:>8}  {:>6.2}",
            c.name, e.value, e.std_err, e.rounds, e.n_samples, z
        );
    }
    let uniform_budget = samples as u64 * cases.len() as u64;
    println!(
        "# adaptive: {} samples over {} rounds ({} splits, {}/{} \
         converged) in {awall:.2}s vs {uniform_budget} uniform-budget",
        report.total_samples,
        report.rounds,
        report.splits,
        report.converged,
        cases.len()
    );
    assert!(aworst < 6.0, "adaptive Genz suite inconsistent");
    println!("OK");
    Ok(())
}
