//! The motivating physics workload from the paper's "Reasons for the new
//! version": solving a Boltzmann equation with radiation requires a
//! *different collision integral for every energy beam* — many similar
//! but distinct integrals evaluated simultaneously.
//!
//! We model a relativistic 2→2 collision-rate integrand in the
//! center-of-momentum frame, reduced to the (cosθ, φ, s-weight) angular
//! variables per beam energy E:
//!
//!   R(E) = ∫₀¹∫₀¹∫₀¹  σ(θ,φ; E) · J(u; E)  du dθ̂ dφ̂
//!
//! with a screened-Rutherford-like differential cross-section
//! σ ∝ 1/(1 + ε(E) − cosθ)² (forward-peaked — the hard part for plain
//! MC), a relativistic flux Jacobian, and a thermal weight exp(−E·u).
//! Each beam energy is its own integrand; a 64-beam sweep is one
//! multifunction batch — the exact usage pattern the paper describes.
//!
//! A high-resolution CPU quadrature provides the per-beam reference.
//!
//! ```text
//! cargo run --release --example boltzmann_collision
//! ```

use zmc::integrator::functional::linspace;
use zmc::integrator::spec::IntegralJob;
use zmc::session::Session;

/// The collision integrand at (u, th, ph) for parameters
/// p0 = E (beam energy), p1 = ε(E) (screening).
/// Variables are unit-cube mapped: cosθ = 2·x2 − 1, φ = 2π·x3.
fn integrand(x: &[f64], e: f64, eps: f64) -> f64 {
    let u = x[0];
    let cos_th = 2.0 * x[1] - 1.0;
    let phi = 2.0 * std::f64::consts::PI * x[2];
    // screened forward-peaked cross-section
    let sigma = 1.0 / (1.0 + eps - cos_th).powi(2);
    // mild anisotropy in φ (radiation polarization term)
    let pol = 1.0 + 0.1 * (2.0 * phi).cos();
    // relativistic flux ∝ s(u)·exp(−E·u), s = 1 + E·u
    let flux = (1.0 + e * u) * (-e * u).exp();
    sigma * pol * flux
}

/// Same integrand as an expression string for the device bytecode path.
fn integrand_expr() -> &'static str {
    // x1=u, x2=θ̂, x3=φ̂ ; p0=E, p1=ε
    "(1/(1 + p1 - (2*x2-1))^2) \
     * (1 + 0.1*cos(2*(2*pi*x3))) \
     * (1 + p0*x1) * exp(-p0*x1)"
}

/// Midpoint quadrature reference (converges fast: smooth in u, φ; the
/// θ peak is resolved with 1200 points).
fn reference(e: f64, eps: f64) -> f64 {
    let (nu, nt, np) = (60, 1200, 24);
    let mut total = 0.0;
    for iu in 0..nu {
        let u = (iu as f64 + 0.5) / nu as f64;
        for it in 0..nt {
            let t = (it as f64 + 0.5) / nt as f64;
            for ip in 0..np {
                let p = (ip as f64 + 0.5) / np as f64;
                total += integrand(&[u, t, p], e, eps);
            }
        }
    }
    total / (nu * nt * np) as f64
}

fn main() -> anyhow::Result<()> {
    let n_beams = std::env::var("ZMC_BEAMS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64usize);
    let samples = std::env::var("ZMC_SAMPLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1 << 17);

    let session = Session::builder()
        .artifacts_or_emulator("artifacts")
        .workers(1)
        .build()?;

    // beam energies E ∈ [0.5, 8] (units of kT), screening ε(E) = 0.02+0.01·E
    let energies = linspace(0.5, 8.0, n_beams);
    let thetas: Vec<Vec<f64>> = energies
        .iter()
        .map(|&e| vec![e, 0.02 + 0.01 * e])
        .collect();

    let job = IntegralJob::with_params(
        integrand_expr(),
        &[(0.0, 1.0), (0.0, 1.0), (0.0, 1.0)],
        &thetas[0],
    )?;
    let t0 = std::time::Instant::now();
    let rates = session
        .functional(&job, &thetas)
        .samples(samples)
        .seed(1986)
        .run()?;
    let wall = t0.elapsed().as_secs_f64();

    println!("# beam  E  rate  sigma  reference  |z|");
    let mut worst: f64 = 0.0;
    // reference quadrature is slow; check a subsample of beams
    let stride = (n_beams / 8).max(1);
    for (i, (e, est)) in energies.iter().zip(&rates).enumerate() {
        if i % stride == 0 {
            let r = reference(*e, 0.02 + 0.01 * e);
            let z = (est.value - r).abs() / est.std_err.max(1e-12);
            worst = worst.max(z);
            println!(
                "{i:>4}  {e:>6.3}  {:>10.6}  {:>9.3e}  {:>10.6}  {z:>6.2}",
                est.value, est.std_err, r
            );
        } else {
            println!(
                "{i:>4}  {e:>6.3}  {:>10.6}  {:>9.3e}          -       -",
                est.value, est.std_err
            );
        }
    }
    println!(
        "# {n_beams} collision integrals x {samples} samples: {wall:.2}s \
         (worst checked |z| = {worst:.2})"
    );
    assert!(worst < 6.0);
    // physical sanity: rate decreases with beam energy (thermal weight)
    assert!(rates.first().unwrap().value > rates.last().unwrap().value);
    println!("OK");
    Ok(())
}
