//! Fig. 1 reproduction — the paper's headline experiment.
//!
//! Integrates f_n(x) = cos(k_n·x) + sin(k_n·x), k_n = ((n+50)/2π)·𝟙₄,
//! over [0,1]⁴ for n = 1..100 with 10 independent evaluations, then
//! reports the mean ± ΔF band against the analytic curve exactly as the
//! figure does, plus the per-trial wall time the caption quotes (C3).
//!
//! ```text
//! cargo run --release --example harmonic_series            # full figure
//! ZMC_N=20 ZMC_SAMPLES=65536 cargo run --release --example harmonic_series
//! ```

use zmc::integrator::harmonic::HarmonicBatch;
use zmc::session::Session;
use zmc::stats::Welford;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let n = env_usize("ZMC_N", 100) as u32;
    let samples = env_usize("ZMC_SAMPLES", 1 << 20);
    let trials = env_usize("ZMC_TRIALS", 10) as u32;
    let workers = env_usize("ZMC_WORKERS", 1);

    let session = Session::builder()
        .artifacts_or_emulator("artifacts")
        .workers(workers)
        .build()?;
    let batch = HarmonicBatch::fig1(n);

    println!(
        "# Fig.1: {n} harmonics, {samples} samples/fn, {trials} trials, \
         {workers} worker(s)"
    );
    let t0 = std::time::Instant::now();
    let per_trial = session
        .harmonic(&batch)
        .samples(samples)
        .seed(2021)
        .run_trials(trials)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("# n  mean  dF  analytic  inside_band");
    let mut covered = 0usize;
    let mut max_z: f64 = 0.0;
    for i in 0..n as usize {
        let mut w = Welford::new();
        for t in &per_trial {
            w.push(t[i].value);
        }
        let truth = batch.truth(i);
        let df = w.std(); // the paper's ΔF: std of the 10 evaluations
        let inside = (w.mean() - truth).abs() <= df * 2.0;
        covered += inside as usize;
        if w.sem() > 0.0 {
            max_z = max_z.max((w.mean() - truth).abs() / w.sem());
        }
        println!(
            "{:>3}  {:>12.6}  {:>10.3e}  {:>12.6}  {}",
            i + 1,
            w.mean(),
            df,
            truth,
            inside
        );
    }
    println!("# coverage(±2ΔF): {covered}/{n}");
    println!("# max |z| (vs sem over trials): {max_z:.2}");
    println!(
        "# wall: {wall:.2}s total, {:.2}s per independent evaluation \
         (paper: ~60s on one V100 at 1e6 samples)",
        wall / trials as f64
    );
    assert!(covered as f64 >= 0.9 * n as f64, "band coverage too low");
    Ok(())
}
