//! Eq. (2) of the paper: one batch mixing integrands of different
//! dimensionality and different coefficients —
//!
//!   g_n(x1,x2)    = a_n·|x1 + x2|        for 0  < n < 50
//!   g_n(x1,x2,x3) = b_n·|x1 + x2 − x3|   for 50 ≤ n ≤ 100
//!
//! exactly the "different dimensions, forms and integration domains"
//! capability v5.1 adds. Every estimate is gated against the closed form.
//!
//! ```text
//! cargo run --release --example mixed_dims
//! ```

use zmc::analytic;
use zmc::integrator::spec::IntegralJob;
use zmc::session::Session;

fn main() -> anyhow::Result<()> {
    let samples = std::env::var("ZMC_SAMPLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1 << 17);
    let session = Session::builder()
        .artifacts_or_emulator("artifacts")
        .workers(1)
        .build()?;

    // a_n, b_n: arbitrary but reproducible coefficient ramps
    let mut jobs = Vec::new();
    let mut truths = Vec::new();
    for n in 1..=100u32 {
        if n < 50 {
            let a = 0.5 + n as f64 / 50.0;
            jobs.push(IntegralJob::with_params(
                "p0*abs(x1+x2)",
                &[(0.0, 1.0), (0.0, 1.0)],
                &[a],
            )?);
            truths.push(analytic::eq2_abs2(a));
        } else {
            let b = 1.0 + (n - 50) as f64 / 25.0;
            jobs.push(IntegralJob::with_params(
                "p0*abs(x1+x2-x3)",
                &[(0.0, 1.0), (0.0, 1.0), (0.0, 1.0)],
                &[b],
            )?);
            truths.push(analytic::eq2_abs3(b));
        }
    }

    let t0 = std::time::Instant::now();
    let ests =
        session.multifunctions(&jobs).samples(samples).seed(77).run()?;
    let wall = t0.elapsed().as_secs_f64();

    println!("# n  dims  estimate  sigma  analytic  |z|");
    let mut worst: f64 = 0.0;
    for (i, (e, t)) in ests.iter().zip(&truths).enumerate() {
        let z = (e.value - t).abs() / e.std_err.max(1e-12);
        worst = worst.max(z);
        println!(
            "{:>3}  {}  {:>10.6}  {:>9.3e}  {:>10.6}  {:>6.2}",
            i + 1,
            jobs[i].dims(),
            e.value,
            e.std_err,
            t,
            z
        );
    }
    println!(
        "# 100 mixed-dimension integrals, {samples} samples each: \
         {wall:.2}s  (worst |z| = {worst:.2})"
    );
    assert!(worst < 6.0, "some estimate inconsistent with closed form");
    println!("OK");
    Ok(())
}
