//! Quickstart: integrate one expression end-to-end through the AOT
//! device path.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use zmc::integrator::spec::IntegralJob;
use zmc::session::Session;

fn main() -> anyhow::Result<()> {
    // 1. one Session owns the whole stack: the AOT artifacts (built
    //    once by `make artifacts`, with emulator fallback when running
    //    without PJRT), the device pool, and the persistent engine —
    //    workers + executable caches live from here on
    let session = Session::builder()
        .artifacts_or_emulator("artifacts")
        .workers(1)
        .build()?;

    // 2. describe the integral: ∫∫ sin(x1)·x2 over [0,π]×[0,1]
    let job = IntegralJob::parse(
        "sin(x1) * x2",
        &[(0.0, std::f64::consts::PI), (0.0, 1.0)],
    )?;

    // 3. run it — the expression was compiled to device bytecode; the
    //    launch runs on the simulated device pool standing in for a GPU.
    let est = session
        .multifunctions(std::slice::from_ref(&job))
        .samples(1 << 20)
        .seed(42)
        .run()?[0];

    // truth: ∫ sin = 2, ∫ x2 = 1/2 → 1.0
    println!("{est}");
    println!("analytic = 1.000000");
    println!(
        "|z|      = {:.2}",
        (est.value - 1.0).abs() / est.std_err
    );
    assert!(est.consistent_with(1.0, 6.0));
    println!("OK");
    Ok(())
}
