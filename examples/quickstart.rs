//! Quickstart: integrate one expression end-to-end through the AOT
//! device path.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use zmc::engine::Engine;
use zmc::integrator::multifunctions::{self, MultiConfig};
use zmc::integrator::spec::IntegralJob;
use zmc::runtime::device::DevicePool;
use zmc::runtime::registry::Registry;

fn main() -> anyhow::Result<()> {
    // 1. load the AOT artifacts (built once by `make artifacts`), or the
    //    emulated registry when running without PJRT, and spawn the
    //    persistent engine: workers + executable caches live from here on
    let registry = Arc::new(
        Registry::load("artifacts").unwrap_or_else(|_| Registry::emulated()),
    );
    let pool = DevicePool::new(&registry, 1)?;
    let engine = Engine::for_pool(&pool)?;

    // 2. describe the integral: ∫∫ sin(x1)·x2 over [0,π]×[0,1]
    let job = IntegralJob::parse(
        "sin(x1) * x2",
        &[(0.0, std::f64::consts::PI), (0.0, 1.0)],
    )?;

    // 3. run it — the expression was compiled to device bytecode; the
    //    launch runs on the simulated device pool standing in for a GPU.
    let cfg = MultiConfig {
        samples_per_fn: 1 << 20,
        seed: 42,
        ..Default::default()
    };
    let est = multifunctions::integrate(&engine, &[job], &cfg)?[0];

    // truth: ∫ sin = 2, ∫ x2 = 1/2 → 1.0
    println!("I        = {:.6} ± {:.2e}", est.value, est.std_err);
    println!("analytic = 1.000000");
    println!(
        "|z|      = {:.2}",
        (est.value - 1.0).abs() / est.std_err
    );
    assert!(est.consistent_with(1.0, 6.0));
    println!("OK");
    Ok(())
}
