"""Dynamic program length (`plens`) semantics — the §Perf L1 contract:
running only the first `plen` instructions must be indistinguishable
from running the full HALT-padded program, and null slots (plen=0)
contribute exact zeros."""

import numpy as np

from compile import opcodes as oc
from compile.kernels import ref
from compile.kernels.vm_eval import make_vm_multi
from compile.vm_core import vm_eval_tile


def test_truncated_loop_equals_full_loop():
    instrs = [
        (oc.VAR, 0, 0), (oc.SIN, 0, 0), (oc.VAR, 1, 0), (oc.MUL, 0, 0),
        (oc.CONST, 0, 0.5), (oc.ADD, 0, 0),
    ]
    ops, ia, fa = oc.assemble(instrs)
    theta = np.zeros(oc.MAX_PARAM, np.float32)
    x = np.random.default_rng(0).random((2, 64)).astype(np.float32)
    full = np.asarray(vm_eval_tile(x, ops, ia, fa, theta))
    cut = np.asarray(vm_eval_tile(x, ops, ia, fa, theta,
                                  np.int32(len(instrs))))
    np.testing.assert_array_equal(full, cut)


def test_null_slots_are_exact_zero():
    n_fns, samples, dims, tile = 4, 512, 4, 256
    fn = make_vm_multi(n_fns, samples, dims, oc.MAX_PROG, tile)
    ops, ia, fa = oc.assemble([(oc.CONST, 0, 3.0)])
    opsF = np.zeros((n_fns, oc.MAX_PROG), np.int32)
    iaF = np.zeros((n_fns, oc.MAX_PROG), np.int32)
    faF = np.zeros((n_fns, oc.MAX_PROG), np.float32)
    opsF[0], iaF[0], faF[0] = ops, ia, fa
    plens = np.array([1, 0, 0, 0], np.int32)  # only slot 0 live
    out = np.asarray(fn(
        np.array([1, 2], np.uint32), np.array([0, 0], np.uint32),
        np.arange(n_fns, dtype=np.uint32), plens, opsF, iaF, faF,
        np.zeros((n_fns, oc.MAX_PARAM), np.float32),
        np.zeros((n_fns, dims), np.float32),
        np.ones((n_fns, dims), np.float32)))
    assert out[0, 0] == 3.0 * samples
    assert out[0, 1] == 9.0 * samples
    np.testing.assert_array_equal(out[1:], 0.0)


def test_plen_matches_reference_on_heterogeneous_batch():
    """Mixed program lengths in one launch agree with the oracle."""
    n_fns, samples, dims, tile = 3, 512, 4, 256
    fn = make_vm_multi(n_fns, samples, dims, oc.MAX_PROG, tile)
    progs = [
        [(oc.VAR, 0, 0)],
        [(oc.VAR, 0, 0), (oc.VAR, 1, 0), (oc.ADD, 0, 0), (oc.ABS, 0, 0)],
        [(oc.CONST, 0, 2.0), (oc.VAR, 2, 0), (oc.MUL, 0, 0),
         (oc.SIN, 0, 0), (oc.SQUARE, 0, 0)],
    ]
    opsF = np.zeros((n_fns, oc.MAX_PROG), np.int32)
    iaF = np.zeros((n_fns, oc.MAX_PROG), np.int32)
    faF = np.zeros((n_fns, oc.MAX_PROG), np.float32)
    plens = np.zeros(n_fns, np.int32)
    for i, p in enumerate(progs):
        o, ia, fa = oc.assemble(p)
        opsF[i], iaF[i], faF[i] = o, ia, fa
        plens[i] = len(p)
    seed = np.array([9, 9], np.uint32)
    ctr = np.array([100, 2], np.uint32)
    streams = np.array([5, 6, 7], np.uint32)
    theta = np.zeros((n_fns, oc.MAX_PARAM), np.float32)
    lo = np.zeros((n_fns, dims), np.float32)
    hi = np.ones((n_fns, dims), np.float32)
    got = np.asarray(
        fn(seed, ctr, streams, plens, opsF, iaF, faF, theta, lo, hi))
    want = ref.vm_multi_ref(samples, dims, seed, ctr, streams, opsF, iaF,
                            faF, theta, lo, hi)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)
