"""Bytecode VM: jnp tile evaluator vs the python-list reference machine.

Includes a hypothesis strategy that generates random *valid* programs
(stack-depth tracked), which is the same invariant the rust compiler
guarantees — so passing here means any rust-compiled program evaluates
identically on-device.
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import opcodes as oc
from compile.vm_core import vm_eval_ref, vm_eval_tile

UNARY = [oc.NEG, oc.ABS, oc.SIN, oc.COS, oc.TAN, oc.EXP, oc.LOG, oc.SQRT,
         oc.TANH, oc.ATAN, oc.FLOOR, oc.SQUARE, oc.RECIP]
BINARY = [oc.ADD, oc.SUB, oc.MUL, oc.DIV, oc.POW, oc.MIN, oc.MAX]
# Ops safe on arbitrary real inputs (no NaN/Inf surprises for comparison).
SAFE_UNARY = [oc.NEG, oc.ABS, oc.SIN, oc.COS, oc.TANH, oc.ATAN, oc.FLOOR,
              oc.SQUARE]
SAFE_BINARY = [oc.ADD, oc.SUB, oc.MUL, oc.MIN, oc.MAX]


def run_both(instrs, x, theta=None, prog_len=oc.MAX_PROG):
    theta = theta if theta is not None else np.zeros(oc.MAX_PARAM,
                                                     np.float32)
    ops, iargs, fargs = oc.assemble(instrs, prog_len)
    got = np.asarray(vm_eval_tile(
        np.ascontiguousarray(x.T), ops, iargs, fargs, theta))
    want = vm_eval_ref(x, ops, iargs, fargs, theta)
    return got, want


def test_const():
    x = np.zeros((16, 4), np.float32)
    got, want = run_both([(oc.CONST, 0, 3.25)], x)
    np.testing.assert_array_equal(got, want)
    assert (got == 3.25).all()


def test_eq1_harmonic_program():
    """The Fig-1 integrand as bytecode: cos(k.x) + sin(k.x), D=4."""
    kn = np.float32((7 + 50) / (2 * np.pi))
    instrs = []
    # k.x = kn*(x0+x1+x2+x3)
    instrs.append((oc.VAR, 0, 0))
    for d in range(1, 4):
        instrs.append((oc.VAR, d, 0))
        instrs.append((oc.ADD, 0, 0))
    instrs.append((oc.CONST, 0, kn))
    instrs.append((oc.MUL, 0, 0))
    instrs.append((oc.COS, 0, 0))       # cos(p)
    # rebuild phase for sin — exercises deeper stacks too
    instrs.append((oc.VAR, 0, 0))
    for d in range(1, 4):
        instrs.append((oc.VAR, d, 0))
        instrs.append((oc.ADD, 0, 0))
    instrs.append((oc.CONST, 0, kn))
    instrs.append((oc.MUL, 0, 0))
    instrs.append((oc.SIN, 0, 0))
    instrs.append((oc.ADD, 0, 0))
    x = np.random.default_rng(1).random((512, 4), np.float32)
    got, want = run_both(instrs, x)
    np.testing.assert_allclose(got, want, rtol=2e-6, atol=2e-6)
    direct = np.cos(kn * x.sum(1)) + np.sin(kn * x.sum(1))
    np.testing.assert_allclose(got, direct, rtol=1e-4, atol=1e-4)


def test_eq2_abs_program():
    """Eq. (2): b*|x0 + x1 - x2| with parameter from theta."""
    instrs = [
        (oc.PARAM, 3, 0),
        (oc.VAR, 0, 0), (oc.VAR, 1, 0), (oc.ADD, 0, 0),
        (oc.VAR, 2, 0), (oc.SUB, 0, 0), (oc.ABS, 0, 0),
        (oc.MUL, 0, 0),
    ]
    theta = np.zeros(oc.MAX_PARAM, np.float32)
    theta[3] = 2.5
    x = np.random.default_rng(2).random((256, 3), np.float32) * 4 - 2
    got, want = run_both(instrs, x, theta)
    np.testing.assert_allclose(got, want, rtol=1e-6)
    np.testing.assert_allclose(
        got, 2.5 * np.abs(x[:, 0] + x[:, 1] - x[:, 2]), rtol=1e-5,
        atol=1e-6)


def test_all_unary_ops():
    x = np.random.default_rng(3).random((128, 1), np.float32) + 0.5
    for op in UNARY:
        got, want = run_both([(oc.VAR, 0, 0), (op, 0, 0)], x)
        np.testing.assert_allclose(got, want, rtol=2e-6, atol=1e-6,
                                   err_msg=oc.NAMES[op])


def test_all_binary_ops():
    rng = np.random.default_rng(4)
    x = (rng.random((128, 2), np.float32) + 0.5) * 2
    for op in BINARY:
        got, want = run_both(
            [(oc.VAR, 0, 0), (oc.VAR, 1, 0), (op, 0, 0)], x)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6,
                                   err_msg=oc.NAMES[op])


def test_halt_padding_is_noop():
    x = np.random.default_rng(5).random((64, 2), np.float32)
    got_a, _ = run_both([(oc.VAR, 0, 0)], x, prog_len=4)
    got_b, _ = run_both([(oc.VAR, 0, 0)], x, prog_len=oc.MAX_PROG)
    np.testing.assert_array_equal(got_a, got_b)


def test_stack_to_limit():
    """Push STACK values then fold them down — exercises full depth."""
    instrs = [(oc.CONST, 0, float(i)) for i in range(oc.STACK)]
    instrs += [(oc.ADD, 0, 0)] * (oc.STACK - 1)
    x = np.zeros((8, 1), np.float32)
    got, want = run_both(instrs, x)
    np.testing.assert_array_equal(got, want)
    assert (got == sum(range(oc.STACK))).all()


@st.composite
def valid_programs(draw):
    """Random stack-valid programs over safe ops, depth-tracked."""
    n_instr = draw(st.integers(1, 24))
    instrs = []
    depth = 0
    for _ in range(n_instr):
        choices = []
        if depth < oc.STACK:
            choices.append("push")
        if depth >= 1:
            choices.append("unary")
        if depth >= 2:
            choices.append("binary")
        kind = draw(st.sampled_from(choices))
        if kind == "push":
            which = draw(st.sampled_from([oc.CONST, oc.VAR, oc.PARAM]))
            if which == oc.CONST:
                instrs.append((oc.CONST, 0,
                               draw(st.floats(-4, 4, width=32))))
            elif which == oc.VAR:
                instrs.append((oc.VAR, draw(st.integers(0, 3)), 0))
            else:
                instrs.append((oc.PARAM, draw(st.integers(0, 7)), 0))
            depth += 1
        elif kind == "unary":
            instrs.append((draw(st.sampled_from(SAFE_UNARY)), 0, 0))
        else:
            instrs.append((draw(st.sampled_from(SAFE_BINARY)), 0, 0))
            depth -= 1
    # fold everything to a single value
    while depth > 1:
        instrs.append((oc.ADD, 0, 0))
        depth -= 1
    return instrs


@settings(max_examples=40, deadline=None)
@given(valid_programs(), st.integers(0, 2**31 - 1))
def test_random_programs_match_reference(instrs, seed):
    x = np.random.default_rng(seed).random((64, 4), np.float32) * 2 - 1
    theta = np.linspace(-1, 1, oc.MAX_PARAM).astype(np.float32)
    got, want = run_both(instrs, x, theta)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
