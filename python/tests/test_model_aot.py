"""Variant/manifest consistency + AOT lowering smoke tests."""

import json
import os

import jax
import numpy as np
import pytest

from compile import opcodes as oc
from compile.aot import lower_variant
from compile.model import (CONSTANTS, all_variants, harmonic_variant,
                           vm_multi_variant)

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_variant_names_unique():
    names = [v.name for v in all_variants()]
    assert len(names) == len(set(names))


def test_example_args_match_manifest():
    for v in all_variants():
        args = v.example_args()
        assert len(args) == len(v.inputs)
        for arg, (_, (dtype, shape)) in zip(args, v.inputs):
            assert list(arg.shape) == shape
            assert {"f32": "float32", "i32": "int32",
                    "u32": "uint32"}[dtype] == arg.dtype.name


def test_constants_block():
    assert CONSTANTS["MAX_PROG"] == oc.MAX_PROG
    assert CONSTANTS["STACK"] == oc.STACK
    assert CONSTANTS["N_OPS"] == oc.N_OPS
    assert CONSTANTS["abi_version"] == 1


def test_variant_output_abstract_shape():
    """jax abstract evaluation of each variant matches declared outputs."""
    for v in all_variants():
        if v.meta["samples"] > 8192:
            continue  # keep the test fast; geometry identical to small
        out = jax.eval_shape(v.fn, *v.example_args())
        want_dtype, want_shape = v.outputs[0]
        assert list(out.shape) == want_shape, v.name
        assert out.dtype == np.float32


def test_lowering_produces_hlo_text():
    v = harmonic_variant(samples=1024, n_fns=4, tile=512)
    text = lower_variant(v)
    assert "HloModule" in text
    # entry computation must be a tuple per the interchange contract
    assert "ROOT" in text


def test_lowered_vm_has_single_loop_not_unrolled():
    """The VM instruction loop must lower as a while-loop, not MAX_PROG
    unrolled switch trees — this is what keeps artifact size O(1) in
    program length (§Perf L2)."""
    v = vm_multi_variant(n_fns=2, samples=512, tile=256)
    text = lower_variant(v)
    assert text.count("while(") <= 6
    # 24-branch dispatch appears once (inside the loop body), not 48x.
    assert text.count("conditional") < 40


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS,
                                                    "manifest.json")),
                    reason="artifacts not built")
class TestShippedManifest:
    def setup_method(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            self.manifest = json.load(f)

    def test_manifest_constants_match(self):
        assert self.manifest["constants"] == CONSTANTS

    def test_all_files_present_and_hashed(self):
        import hashlib

        for name, entry in self.manifest["executables"].items():
            path = os.path.join(ARTIFACTS, entry["file"])
            assert os.path.exists(path), name
            text = open(path).read()
            assert hashlib.sha256(
                text.encode()).hexdigest() == entry["sha256"], name

    def test_manifest_covers_all_variants(self):
        assert set(self.manifest["executables"]) == {
            v.name for v in all_variants()
        }
