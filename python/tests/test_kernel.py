"""Pallas kernels vs pure-numpy oracles — the CORE correctness signal.

hypothesis sweeps kernel geometry (tile sizes, function counts, dims,
domains) so the BlockSpec indexing and the grid accumulation are exercised
at many shapes, not just the shipped variants.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import opcodes as oc
from compile.kernels import ref
from compile.kernels.harmonic import make_harmonic
from compile.kernels.stratified import make_stratified
from compile.kernels.vm_eval import make_vm_multi


def rand_harmonic_args(rng, n_fns, dims):
    k = rng.normal(size=(n_fns, dims)).astype(np.float32) * 3
    a = rng.normal(size=n_fns).astype(np.float32)
    b = rng.normal(size=n_fns).astype(np.float32)
    lo = (rng.random(dims) * -2).astype(np.float32)
    hi = (rng.random(dims) * 2 + 0.1).astype(np.float32)
    return k, a, b, lo, hi


def plens_of(opsF):
    """Actual program lengths per row (programs are HALT-padded)."""
    return (opsF != 0).sum(axis=1).astype(np.int32)


def simple_program():
    """f(x) = |x0 + x1| * theta0 + cos(x2)."""
    return [
        (oc.VAR, 0, 0), (oc.VAR, 1, 0), (oc.ADD, 0, 0), (oc.ABS, 0, 0),
        (oc.PARAM, 0, 0), (oc.MUL, 0, 0),
        (oc.VAR, 2, 0), (oc.COS, 0, 0), (oc.ADD, 0, 0),
    ]


class TestHarmonic:
    def test_matches_ref_shipped_geometry(self):
        rng = np.random.default_rng(0)
        samples, n_fns, dims, tile = 4096, 128, 8, 1024
        fn = make_harmonic(samples, n_fns, dims, tile)
        seed = np.array([11, 22], np.uint32)
        ctr = np.array([1000, 5, 2], np.uint32)
        k, a, b, lo, hi = rand_harmonic_args(rng, n_fns, dims)
        got = np.asarray(fn(seed, ctr, k, a, b, lo, hi))
        want = ref.harmonic_ref(samples, n_fns, dims, seed, ctr, k, a, b,
                                lo, hi)
        scale = np.abs(want).max()
        np.testing.assert_allclose(got, want, atol=2e-5 * scale)

    @settings(max_examples=12, deadline=None)
    @given(
        tile_pow=st.integers(7, 10),
        n_tiles=st.integers(1, 4),
        n_fns=st.sampled_from([1, 3, 16, 128]),
        dims=st.integers(1, 8),
        seed=st.integers(0, 2**32 - 1),
    )
    def test_matches_ref_swept(self, tile_pow, n_tiles, n_fns, dims, seed):
        tile = 2 ** tile_pow
        samples = tile * n_tiles
        rng = np.random.default_rng(seed)
        fn = make_harmonic(samples, n_fns, dims, tile)
        sd = np.array([seed & 0xFFFFFFFF, seed >> 16], np.uint32)
        ctr = np.array([rng.integers(0, 2**20), rng.integers(0, 100),
                        rng.integers(0, 10)], np.uint32)
        k, a, b, lo, hi = rand_harmonic_args(rng, n_fns, dims)
        got = np.asarray(fn(sd, ctr, k, a, b, lo, hi))
        want = ref.harmonic_ref(samples, n_fns, dims, sd, ctr, k, a, b,
                                lo, hi)
        scale = max(np.abs(want).max(), 1.0)
        np.testing.assert_allclose(got, want, atol=3e-5 * scale)

    def test_tile_decomposition_invariance(self):
        """Same launch, different TILE -> identical samples, ~equal sums."""
        rng = np.random.default_rng(7)
        k, a, b, lo, hi = rand_harmonic_args(rng, 16, 4)
        seed = np.array([3, 4], np.uint32)
        ctr = np.array([0, 0, 0], np.uint32)
        out1 = np.asarray(
            make_harmonic(4096, 16, 4, 512)(seed, ctr, k, a, b, lo, hi))
        out2 = np.asarray(
            make_harmonic(4096, 16, 4, 2048)(seed, ctr, k, a, b, lo, hi))
        np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-3)


class TestVmMulti:
    def test_matches_ref(self):
        rng = np.random.default_rng(1)
        n_fns, samples, dims, tile = 8, 2048, 8, 512
        fn = make_vm_multi(n_fns, samples, dims, oc.MAX_PROG, tile)
        ops, iargs, fargs = oc.assemble(simple_program())
        opsF = np.tile(ops, (n_fns, 1))
        iaF = np.tile(iargs, (n_fns, 1))
        faF = np.tile(fargs, (n_fns, 1))
        theta = rng.random((n_fns, oc.MAX_PARAM)).astype(np.float32)
        lo = np.zeros((n_fns, dims), np.float32)
        hi = np.ones((n_fns, dims), np.float32) * 2
        streams = np.arange(100, 100 + n_fns, dtype=np.uint32)
        seed = np.array([5, 6], np.uint32)
        ctr = np.array([0, 3], np.uint32)
        got = np.asarray(
            fn(seed, ctr, streams, plens_of(opsF), opsF, iaF, faF, theta, lo, hi))
        want = ref.vm_multi_ref(samples, dims, seed, ctr, streams, opsF,
                                iaF, faF, theta, lo, hi)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)

    def test_heterogeneous_functions_and_domains(self):
        """Each row a different program + box — the v5.1 headline feature."""
        n_fns, samples, dims, tile = 4, 1024, 8, 256
        fn = make_vm_multi(n_fns, samples, dims, oc.MAX_PROG, tile)
        progs = [
            [(oc.VAR, 0, 0), (oc.SQUARE, 0, 0)],                  # x0^2
            [(oc.VAR, 0, 0), (oc.VAR, 1, 0), (oc.MUL, 0, 0)],     # x0*x1
            [(oc.CONST, 0, 1.0)],                                 # 1
            [(oc.VAR, 2, 0), (oc.SIN, 0, 0), (oc.ABS, 0, 0)],     # |sin x2|
        ]
        opsF = np.zeros((n_fns, oc.MAX_PROG), np.int32)
        iaF = np.zeros((n_fns, oc.MAX_PROG), np.int32)
        faF = np.zeros((n_fns, oc.MAX_PROG), np.float32)
        for i, p in enumerate(progs):
            o, ia, fa = oc.assemble(p)
            opsF[i], iaF[i], faF[i] = o, ia, fa
        theta = np.zeros((n_fns, oc.MAX_PARAM), np.float32)
        lo = np.stack([np.zeros(dims), -np.ones(dims), np.zeros(dims),
                       np.full(dims, 2.0)]).astype(np.float32)
        hi = np.stack([np.ones(dims), np.ones(dims), np.full(dims, 0.5),
                       np.full(dims, 3.0)]).astype(np.float32)
        streams = np.array([9, 8, 7, 6], np.uint32)
        seed = np.array([1, 2], np.uint32)
        ctr = np.array([512, 0], np.uint32)
        got = np.asarray(
            fn(seed, ctr, streams, plens_of(opsF), opsF, iaF, faF, theta, lo, hi))
        want = ref.vm_multi_ref(samples, dims, seed, ctr, streams, opsF,
                                iaF, faF, theta, lo, hi)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)
        # sanity: constant function integrates exactly
        assert abs(got[2, 0] / samples - 1.0) < 1e-6

    @settings(max_examples=8, deadline=None)
    @given(
        n_fns=st.integers(1, 6),
        tile_pow=st.integers(6, 9),
        n_tiles=st.integers(1, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_swept_geometry(self, n_fns, tile_pow, n_tiles, seed):
        tile = 2 ** tile_pow
        samples = tile * n_tiles
        dims = 8
        rng = np.random.default_rng(seed)
        fn = make_vm_multi(n_fns, samples, dims, oc.MAX_PROG, tile)
        ops, iargs, fargs = oc.assemble(simple_program())
        opsF = np.tile(ops, (n_fns, 1))
        iaF = np.tile(iargs, (n_fns, 1))
        faF = np.tile(fargs, (n_fns, 1))
        theta = rng.random((n_fns, oc.MAX_PARAM)).astype(np.float32)
        lo = rng.random((n_fns, dims)).astype(np.float32) * -1
        hi = rng.random((n_fns, dims)).astype(np.float32) + 0.5
        streams = rng.integers(0, 2**16, n_fns).astype(np.uint32)
        sd = np.array([seed, seed ^ 0xABCD], np.uint32)
        ctr = np.array([rng.integers(0, 2**20), rng.integers(0, 8)],
                       np.uint32)
        got = np.asarray(
            fn(sd, ctr, streams, plens_of(opsF), opsF, iaF, faF, theta, lo, hi))
        want = ref.vm_multi_ref(samples, dims, sd, ctr, streams, opsF,
                                iaF, faF, theta, lo, hi)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


class TestStratified:
    def test_matches_ref(self):
        rng = np.random.default_rng(2)
        n_cubes, spc, dims, tile = 16, 512, 8, 256
        fn = make_stratified(n_cubes, spc, dims, oc.MAX_PROG, tile)
        ops, iargs, fargs = oc.assemble(simple_program())
        theta = rng.random(oc.MAX_PARAM).astype(np.float32)
        # a 16-cube partition of [0,1]^D along dim 0
        edges = np.linspace(0, 1, n_cubes + 1).astype(np.float32)
        cube_lo = np.zeros((n_cubes, dims), np.float32)
        cube_hi = np.ones((n_cubes, dims), np.float32)
        cube_lo[:, 0] = edges[:-1]
        cube_hi[:, 0] = edges[1:]
        streams = np.arange(n_cubes, dtype=np.uint32)
        seed = np.array([42, 43], np.uint32)
        ctr = np.array([0, 1], np.uint32)
        plen = np.array([(ops != 0).sum()], np.int32)
        got = np.asarray(fn(seed, ctr, streams, plen, ops, iargs, fargs,
                            theta, cube_lo, cube_hi))
        want = ref.stratified_ref(spc, dims, seed, ctr, streams, ops,
                                  iargs, fargs, theta, cube_lo, cube_hi)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)

    def test_stratified_sum_equals_uniform_expectation(self):
        """Integral of 1 over a partition == total volume, exactly."""
        n_cubes, spc, dims = 8, 256, 8
        fn = make_stratified(n_cubes, spc, dims, oc.MAX_PROG, 256)
        ops, iargs, fargs = oc.assemble([(oc.CONST, 0, 1.0)])
        theta = np.zeros(oc.MAX_PARAM, np.float32)
        edges = np.linspace(0, 1, n_cubes + 1).astype(np.float32)
        cube_lo = np.zeros((n_cubes, dims), np.float32)
        cube_hi = np.ones((n_cubes, dims), np.float32)
        cube_lo[:, 0] = edges[:-1]
        cube_hi[:, 0] = edges[1:]
        streams = np.arange(n_cubes, dtype=np.uint32)
        plen = np.array([1], np.int32)
        got = np.asarray(fn(np.array([0, 0], np.uint32),
                            np.array([0, 0], np.uint32), streams, plen,
                            ops, iargs, fargs, theta, cube_lo, cube_hi))
        np.testing.assert_allclose(got[:, 0], spc, rtol=0)
        np.testing.assert_allclose(got[:, 1], spc, rtol=0)
