"""The python opcode table must match the golden spec/opcodes.txt exactly."""

import os

from compile import opcodes as oc

SPEC = os.path.join(os.path.dirname(__file__), "..", "..", "spec",
                    "opcodes.txt")


def load_spec():
    rows = {}
    with open(SPEC) as f:
        for line in f:
            line = line.split("#")[0].strip()
            if not line:
                continue
            code, name, kind = line.split()
            rows[int(code)] = (name, kind)
    return rows


def test_table_matches_spec():
    spec = load_spec()
    assert len(spec) == oc.N_OPS
    for code, (name, kind) in spec.items():
        assert oc.NAMES[code] == name, f"code {code}"
        assert oc.KINDS[code] == kind, f"code {code}"
        assert getattr(oc, name) == code


def test_codes_dense():
    spec = load_spec()
    assert sorted(spec) == list(range(len(spec)))


def test_assemble_pads_with_halt():
    ops, iargs, fargs = oc.assemble([(oc.CONST, 0, 2.5)])
    assert ops.shape == (oc.MAX_PROG,)
    assert ops[0] == oc.CONST and fargs[0] == 2.5
    assert (ops[1:] == oc.HALT).all()


def test_assemble_rejects_long_programs():
    import pytest

    with pytest.raises(ValueError):
        oc.assemble([(oc.CONST, 0, 1.0)] * (oc.MAX_PROG + 1))
