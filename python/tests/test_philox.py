"""Philox-4x32-10: known-answer tests, cross-impl equality, statistics."""

import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import philox
from compile.kernels import ref

SPEC = os.path.join(os.path.dirname(__file__), "..", "..", "spec",
                    "philox_kat.txt")


def load_kat():
    rows = []
    with open(SPEC) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            ins, outs = line.split("->")
            rows.append((
                [int(w, 16) for w in ins.split()],
                [int(w, 16) for w in outs.split()],
            ))
    assert rows, "empty KAT file"
    return rows


@pytest.mark.parametrize("ins,outs", load_kat())
def test_kat_jnp(ins, outs):
    got = philox.philox4x32(*ins)
    assert [int(g) for g in got] == outs


@pytest.mark.parametrize("ins,outs", load_kat())
def test_kat_numpy_ref(ins, outs):
    got = ref.philox4x32_ref(*ins)
    assert [int(g) for g in got] == outs


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 2**32 - 1), min_size=6, max_size=6))
def test_cross_impl_equality(words):
    """jnp and numpy implementations agree on random counter/key blocks."""
    a = philox.philox4x32(*words)
    b = ref.philox4x32_ref(*words)
    assert [int(x) for x in a] == [int(x) for x in b]


def test_cross_impl_vectorized():
    rng = np.random.default_rng(42)
    c = rng.integers(0, 2**32, size=(4, 1000), dtype=np.uint32)
    a = philox.philox4x32(c[0], c[1], c[2], c[3], 7, 9)
    b = ref.philox4x32_ref(c[0], c[1], c[2], c[3], 7, 9)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), y)


def test_uniform_tile_matches_ref():
    t = np.asarray(philox.uniform_tile(100, 256, 8, 3, 1, 11, 22))
    r = ref.uniforms_ref(100, 256, 8, 3, 1, 11, 22)
    np.testing.assert_allclose(t.T, r, rtol=0, atol=0)


def test_unit_range():
    u = np.asarray(philox.uniform_tile(0, 4096, 8, 0, 0, 1, 2))
    assert u.min() >= 0.0 and u.max() < 1.0


def test_stream_independence():
    """Different streams give different draws; same stream reproduces."""
    a = np.asarray(philox.uniform_tile(0, 512, 4, 1, 0, 5, 6))
    b = np.asarray(philox.uniform_tile(0, 512, 4, 2, 0, 5, 6))
    a2 = np.asarray(philox.uniform_tile(0, 512, 4, 1, 0, 5, 6))
    assert not np.array_equal(a, b)
    np.testing.assert_array_equal(a, a2)


def test_counter_chunking_is_seamless():
    """tile(base=0, n=512) == concat(tile(0,256), tile(256,256)).

    This is the property the rust coordinator relies on when splitting a
    logical launch into chunks with advancing counter_base.
    """
    whole = np.asarray(philox.uniform_tile(0, 512, 8, 9, 2, 3, 4))
    lo = np.asarray(philox.uniform_tile(0, 256, 8, 9, 2, 3, 4))
    hi = np.asarray(philox.uniform_tile(256, 256, 8, 9, 2, 3, 4))
    np.testing.assert_array_equal(whole, np.concatenate([lo, hi], axis=1))


def test_uniformity_chi2():
    """Chi-squared test on 64 bins, 2^16 draws: statistic within 5-sigma."""
    u = np.asarray(philox.uniform_tile(0, 65536, 1, 0, 0, 123, 456))[0]
    counts, _ = np.histogram(u, bins=64, range=(0, 1))
    expected = len(u) / 64
    chi2 = ((counts - expected) ** 2 / expected).sum()
    # dof=63: mean 63, std sqrt(2*63)=11.2; 5 sigma ~ 119
    assert chi2 < 63 + 5 * np.sqrt(2 * 63), f"chi2={chi2}"


def test_moments():
    u = np.asarray(philox.uniform_tile(0, 65536, 4, 7, 0, 9, 9))
    assert abs(u.mean() - 0.5) < 0.005
    assert abs(u.var() - 1 / 12) < 0.002


def test_ks_statistic():
    """Kolmogorov-Smirnov distance vs U(0,1) below 5-sigma bound."""
    n = 32768
    u = np.sort(np.asarray(philox.uniform_tile(0, n, 1, 3, 1, 77, 88))[0])
    ecdf_hi = np.arange(1, n + 1) / n
    ecdf_lo = np.arange(0, n) / n
    d = max(np.abs(ecdf_hi - u).max(), np.abs(u - ecdf_lo).max())
    assert d < 2.5 / np.sqrt(n), f"KS d={d}"
