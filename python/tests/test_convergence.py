"""Statistical end-to-end checks at the python level: MC estimates from the
kernels converge to analytic values with ~1/sqrt(S) error (paper Fig. 1
semantics, small scale)."""

import numpy as np

from compile import opcodes as oc
from compile.kernels.harmonic import make_harmonic
from compile.kernels.vm_eval import make_vm_multi


def analytic_harmonic(k, a, b, lo, hi):
    """Closed form of a*cos(k.x)+b*sin(k.x) over the box [lo,hi]^D.

    Using: Int cos(k.x) = Re[ prod_d (e^{i k_d hi_d} - e^{i k_d lo_d})
    / (i k_d) ], and similarly Im for sin. k_d == 0 contributes
    (hi_d - lo_d).
    """
    prod = complex(1.0, 0.0)
    for kd, l, h in zip(k, lo, hi):
        if abs(kd) < 1e-12:
            prod *= (h - l)
        else:
            prod *= (np.exp(1j * kd * h) - np.exp(1j * kd * l)) / (1j * kd)
    return a * prod.real + b * prod.imag


def test_harmonic_converges_to_analytic():
    """Fig-1 miniature: n in 1..16, D=4, S=65536 -> estimate within 6 sigma."""
    n_fns, dims, samples, tile = 16, 4, 65536, 2048
    fn = make_harmonic(samples, n_fns, dims, tile)
    n = np.arange(1, n_fns + 1)
    kmag = (n + 50) / (2 * np.pi)
    k = np.repeat(kmag[:, None], dims, axis=1).astype(np.float32)
    a = np.ones(n_fns, np.float32)
    b = np.ones(n_fns, np.float32)
    lo = np.zeros(dims, np.float32)
    hi = np.ones(dims, np.float32)
    out = np.asarray(fn(np.array([2024, 1], np.uint32),
                        np.array([0, 0, 0], np.uint32), k, a, b, lo, hi))
    mean = out[0] / samples
    var = np.maximum(out[1] / samples - mean**2, 0)
    sigma = np.sqrt(var / samples)
    truth = np.array([
        analytic_harmonic(k[i], 1.0, 1.0, lo, hi) for i in range(n_fns)
    ])
    err = np.abs(mean - truth)
    assert (err < 6 * sigma + 1e-7).all(), (err / sigma)


def test_vm_polynomial_exact_value():
    """Integral of x0^2 over [0,1]^8 = 1/3 within 6 sigma."""
    samples = 16384
    fn = make_vm_multi(1, samples, 8, oc.MAX_PROG, 2048)
    ops, ia, fa = oc.assemble([(oc.VAR, 0, 0), (oc.SQUARE, 0, 0)])
    out = np.asarray(fn(
        np.array([7, 8], np.uint32), np.array([0, 0], np.uint32),
        np.array([0], np.uint32), np.array([2], np.int32),
        ops[None], ia[None], fa[None],
        np.zeros((1, oc.MAX_PARAM), np.float32),
        np.zeros((1, 8), np.float32), np.ones((1, 8), np.float32)))
    mean = out[0, 0] / samples
    var = out[0, 1] / samples - mean**2
    sigma = np.sqrt(var / samples)
    assert abs(mean - 1 / 3) < 6 * sigma


def test_error_shrinks_with_samples():
    """Empirical MC std halves (x ~2) when S quadruples."""
    dims = 4
    k = np.full((1, dims), 8.0, np.float32)
    a = np.ones(1, np.float32)
    b = np.zeros(1, np.float32)
    lo = np.zeros(dims, np.float32)
    hi = np.ones(dims, np.float32)

    def run(samples, trial):
        fn = make_harmonic(samples, 1, dims, min(samples, 2048))
        out = np.asarray(fn(np.array([5, 5], np.uint32),
                            np.array([0, 0, trial], np.uint32),
                            k, a, b, lo, hi))
        return out[0, 0] / samples

    small = np.array([run(2048, t) for t in range(12)])
    large = np.array([run(8192, t) for t in range(12)])
    ratio = small.std() / large.std()
    assert 1.2 < ratio < 3.5, ratio
