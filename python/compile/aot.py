"""AOT build: lower every L2 variant to HLO text + write the manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the rust `xla` 0.1.6 crate) rejects with
``proto.id() <= INT_MAX``; the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
`artifacts` target). Python never runs again after this: the rust binary
loads the manifest + HLO files and is self-contained.
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import CONSTANTS, all_variants


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(variant):
    lowered = jax.jit(variant.fn).lower(*variant.example_args())
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated variant names (default: all)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"constants": CONSTANTS, "executables": {}}
    for v in all_variants():
        if only and v.name not in only:
            continue
        text = lower_variant(v)
        path = os.path.join(args.out_dir, f"{v.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entry = v.manifest_entry()
        entry["sha256"] = hashlib.sha256(text.encode()).hexdigest()
        manifest["executables"][v.name] = entry
        print(f"  {v.name}: {len(text) / 1024:.0f} KiB -> {path}")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath} ({len(manifest['executables'])} executables)")


if __name__ == "__main__":
    main()
