"""Bytecode opcode table — python half of the ABI.

Must match spec/opcodes.txt and rust/src/vm/opcodes.rs exactly; enforced by
python/tests/test_opcode_abi.py. The VM is a stack machine: ``ops`` selects
the operation, ``iargs`` carries VAR/PARAM indices, ``fargs`` carries CONST
immediates. Programs are padded to MAX_PROG with HALT (a no-op), so a valid
program always leaves its result in stack slot 0 after all MAX_PROG steps.
"""

HALT = 0
CONST = 1
VAR = 2
PARAM = 3
ADD = 4
SUB = 5
MUL = 6
DIV = 7
POW = 8
MIN = 9
MAX = 10
NEG = 11
ABS = 12
SIN = 13
COS = 14
TAN = 15
EXP = 16
LOG = 17
SQRT = 18
TANH = 19
ATAN = 20
FLOOR = 21
SQUARE = 22
RECIP = 23

N_OPS = 24

NAMES = {
    HALT: "HALT", CONST: "CONST", VAR: "VAR", PARAM: "PARAM",
    ADD: "ADD", SUB: "SUB", MUL: "MUL", DIV: "DIV", POW: "POW",
    MIN: "MIN", MAX: "MAX", NEG: "NEG", ABS: "ABS", SIN: "SIN",
    COS: "COS", TAN: "TAN", EXP: "EXP", LOG: "LOG", SQRT: "SQRT",
    TANH: "TANH", ATAN: "ATAN", FLOOR: "FLOOR", SQUARE: "SQUARE",
    RECIP: "RECIP",
}

KINDS = {
    HALT: "nullary", CONST: "push", VAR: "push", PARAM: "push",
    ADD: "binary", SUB: "binary", MUL: "binary", DIV: "binary",
    POW: "binary", MIN: "binary", MAX: "binary",
    NEG: "unary", ABS: "unary", SIN: "unary", COS: "unary", TAN: "unary",
    EXP: "unary", LOG: "unary", SQRT: "unary", TANH: "unary",
    ATAN: "unary", FLOOR: "unary", SQUARE: "unary", RECIP: "unary",
}

# Compile-time VM geometry (mirrored in manifest.json "constants").
MAX_PROG = 48    # instructions per program (HALT-padded)
STACK = 16       # value-stack depth
MAX_PARAM = 16   # per-function parameter slots
MAX_DIM = 8      # padded sample dimensionality


def assemble(instrs, max_prog=MAX_PROG):
    """Assemble [(op, iarg, farg), ...] into padded numpy program arrays."""
    import numpy as np

    if len(instrs) > max_prog:
        raise ValueError(f"program too long: {len(instrs)} > {max_prog}")
    ops = np.zeros(max_prog, np.int32)
    iargs = np.zeros(max_prog, np.int32)
    fargs = np.zeros(max_prog, np.float32)
    for p, (op, ia, fa) in enumerate(instrs):
        ops[p], iargs[p], fargs[p] = op, ia, fa
    return ops, iargs, fargs
