"""Stack-machine bytecode evaluator, pure jnp (traceable inside Pallas).

Evaluates one program over a tile of sample points. The stack is a dense
``(STACK, TILE)`` f32 array; the stack pointer is a traced i32. Each
instruction is dispatched with ``lax.switch`` so the lowered HLO contains
one conditional per loop step rather than an unrolled 24-way tree per
program slot — the instruction loop itself is a ``lax.fori_loop`` and is
compiled once regardless of MAX_PROG.

Out-of-range stack accesses cannot crash: ``dynamic_slice`` clamps indices,
so an invalid program yields garbage values, never UB. Program validation
(depth, arity, terminal sp==1) is the rust compiler's job.
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import opcodes as oc


def _dget(stack, row):
    """stack[row] as a (1, TILE) slice with a traced row index."""
    return jax.lax.dynamic_slice_in_dim(stack, row, 1, axis=0)


def _dput(stack, row, val):
    return jax.lax.dynamic_update_slice_in_dim(stack, val, row, axis=0)


def vm_eval_tile(xT, ops, iargs, fargs, theta, n_instr=None):
    """Run one program over a tile of samples.

    xT:    (D, TILE) f32 — samples, one dimension per row.
    ops:   (P,) i32, iargs: (P,) i32, fargs: (P,) f32 — the program.
    theta: (MAX_PARAM,) f32 — per-function parameters.
    n_instr: optional traced i32 — actual program length. The
      instruction loop runs exactly this many iterations instead of the
      padded P, which is the §Perf L1 win: typical programs are ~10
      instructions against MAX_PROG=48, and null (padding) function
      slots with n_instr=0 cost one bounds check. Defaults to P.
    Returns (TILE,) f32 — f(x) for every sample in the tile.
    """
    tile = xT.shape[1]
    xT = jnp.asarray(xT, jnp.float32)
    ops = jnp.asarray(ops, jnp.int32)
    iargs = jnp.asarray(iargs, jnp.int32)
    fargs = jnp.asarray(fargs, jnp.float32)
    theta = jnp.asarray(theta, jnp.float32)
    stack0 = jnp.zeros((oc.STACK, tile), jnp.float32)

    def step(p, carry):
        # §Perf note: the switch branches take and return single (TILE,)
        # ROWS, not the whole (STACK, TILE) buffer — an earlier version
        # closed over the stack in every branch, which made XLA carry
        # (and copy) the full stack through a 24-way conditional per
        # instruction. Row-based dispatch plus exactly one
        # dynamic_update_slice per instruction cut the per-launch cost
        # ~2x (see EXPERIMENTS.md §Perf L1).
        stack, sp = carry
        op = ops[p]
        ia = iargs[p]
        fa = fargs[p]
        a = _dget(stack, sp - 1)[0]  # top        (TILE,)
        b = _dget(stack, sp - 2)[0]  # second     (TILE,)
        var_row = jax.lax.dynamic_slice_in_dim(xT, ia, 1, axis=0)[0]
        param = jax.lax.dynamic_slice_in_dim(theta, ia, 1)[0]

        branches = [None] * oc.N_OPS
        branches[oc.HALT] = lambda: a
        branches[oc.CONST] = lambda: jnp.full((tile,), fa, jnp.float32)
        branches[oc.VAR] = lambda: var_row
        branches[oc.PARAM] = lambda: jnp.full((tile,), param, jnp.float32)
        # binary convention: b pushed first, a on top → result = b ∘ a
        branches[oc.ADD] = lambda: b + a
        branches[oc.SUB] = lambda: b - a
        branches[oc.MUL] = lambda: b * a
        branches[oc.DIV] = lambda: b / a
        branches[oc.POW] = lambda: jnp.power(b, a)
        branches[oc.MIN] = lambda: jnp.minimum(b, a)
        branches[oc.MAX] = lambda: jnp.maximum(b, a)
        branches[oc.NEG] = lambda: -a
        branches[oc.ABS] = lambda: jnp.abs(a)
        branches[oc.SIN] = lambda: jnp.sin(a)
        branches[oc.COS] = lambda: jnp.cos(a)
        branches[oc.TAN] = lambda: jnp.tan(a)
        branches[oc.EXP] = lambda: jnp.exp(a)
        branches[oc.LOG] = lambda: jnp.log(a)
        branches[oc.SQRT] = lambda: jnp.sqrt(a)
        branches[oc.TANH] = lambda: jnp.tanh(a)
        branches[oc.ATAN] = lambda: jnp.arctan(a)
        branches[oc.FLOOR] = lambda: jnp.floor(a)
        branches[oc.SQUARE] = lambda: a * a
        branches[oc.RECIP] = lambda: 1.0 / a

        result = jax.lax.switch(op, branches)
        # Stack effect from the ABI's code ranges (spec/opcodes.txt is
        # ordered: HALT=0, pushes 1..3, binaries 4..10, unaries 11..23 —
        # pinned by test_opcode_abi on both languages). Push writes at
        # sp, binary at sp-2, unary at sp-1; HALT rewrites the top row
        # onto itself. Scalar arithmetic instead of table constants
        # because pallas kernels may not capture array constants.
        is_push = (op >= oc.CONST) & (op <= oc.PARAM)
        is_bin = (op >= oc.ADD) & (op <= oc.MAX)
        delta = jnp.where(is_push, 1, jnp.where(is_bin, -1, 0))
        woff = jnp.where(is_push, 0, jnp.where(is_bin, -2, -1))
        # write position clamps at 0 (HALT at sp=0 rewrites row 0 with
        # itself — a no-op), matching dynamic_slice's clamped reads.
        wpos = jnp.maximum(sp + woff, 0)
        stack = _dput(stack, wpos, result[None, :])
        return stack, sp + delta

    bound = ops.shape[0] if n_instr is None else jnp.int32(n_instr)
    stack, _sp = jax.lax.fori_loop(0, bound, step, (stack0, jnp.int32(0)))
    # A valid program terminates with sp == 1, leaving f(x) in slot 0.
    return stack[0]


def vm_eval_ref(x, ops, iargs, fargs, theta):
    """Pure-numpy oracle: evaluate the program at sample rows ``x`` (S, D).

    Implemented with a python list as the stack — deliberately nothing in
    common with the jnp path so the two cross-check each other.
    """
    x = np.asarray(x, np.float32)
    stack = []
    un = {
        oc.NEG: np.negative, oc.ABS: np.abs, oc.SIN: np.sin, oc.COS: np.cos,
        oc.TAN: np.tan, oc.EXP: np.exp, oc.LOG: np.log, oc.SQRT: np.sqrt,
        oc.TANH: np.tanh, oc.ATAN: np.arctan, oc.FLOOR: np.floor,
        oc.SQUARE: np.square, oc.RECIP: np.reciprocal,
    }
    bin_ = {
        oc.ADD: np.add, oc.SUB: np.subtract, oc.MUL: np.multiply,
        oc.DIV: np.divide, oc.POW: np.power, oc.MIN: np.minimum,
        oc.MAX: np.maximum,
    }
    with np.errstate(all="ignore"):
        for op, ia, fa in zip(ops, iargs, fargs):
            op = int(op)
            if op == oc.HALT:
                continue
            elif op == oc.CONST:
                stack.append(np.full(x.shape[0], fa, np.float32))
            elif op == oc.VAR:
                stack.append(x[:, int(ia)].copy())
            elif op == oc.PARAM:
                stack.append(np.full(x.shape[0], theta[int(ia)], np.float32))
            elif op in un:
                stack.append(un[op](stack.pop()).astype(np.float32))
            elif op in bin_:
                b = stack.pop()
                a = stack.pop()
                stack.append(bin_[op](a, b).astype(np.float32))
            else:
                raise ValueError(f"bad opcode {op}")
    assert len(stack) == 1, f"program left {len(stack)} values on the stack"
    return stack[0]
