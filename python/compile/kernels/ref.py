"""Pure-numpy correctness oracles for every L1 kernel.

Nothing here shares code with the jnp/pallas path: the Philox reference is
an independent numpy implementation, the VM reference is a python-list
stack machine (vm_core.vm_eval_ref). pytest asserts allclose between each
pallas kernel and these oracles under hypothesis-swept shapes.
"""

import numpy as np

from ..vm_core import vm_eval_ref

M0 = np.uint32(0xD2511F53)
M1 = np.uint32(0xCD9E8D57)
W0 = np.uint32(0x9E3779B9)
W1 = np.uint32(0xBB67AE85)


def philox4x32_ref(c0, c1, c2, c3, k0, k1):
    """Independent numpy Philox-4x32-10 (vectorized over arrays)."""
    c = [np.asarray(v, np.uint32) for v in (c0, c1, c2, c3)]
    k0 = np.asarray(k0, np.uint32)
    k1 = np.asarray(k1, np.uint32)
    with np.errstate(over="ignore"):
        for r in range(10):
            if r > 0:
                k0 = (k0 + W0).astype(np.uint32)
                k1 = (k1 + W1).astype(np.uint32)
            p0 = c[0].astype(np.uint64) * np.uint64(M0)
            p1 = c[2].astype(np.uint64) * np.uint64(M1)
            hi0 = (p0 >> np.uint64(32)).astype(np.uint32)
            lo0 = p0.astype(np.uint32)
            hi1 = (p1 >> np.uint64(32)).astype(np.uint32)
            lo1 = p1.astype(np.uint32)
            c = [hi1 ^ c[1] ^ k0, lo1, hi0 ^ c[3] ^ k1, lo0]
    return c


def uniforms_ref(base, n, dims, stream, trial, seed0, seed1):
    """(n, dims) f32 uniforms matching philox.uniform_tile (transposed)."""
    idx = (np.uint32(base) + np.arange(n, dtype=np.uint32))
    cols = []
    for j in range((dims + 3) // 4):
        out = philox4x32_ref(idx, np.uint32(j), np.uint32(stream),
                             np.uint32(trial), np.uint32(seed0),
                             np.uint32(seed1))
        for o in out:
            cols.append((o >> np.uint32(8)).astype(np.float32)
                        * np.float32(1.0 / (1 << 24)))
    return np.stack(cols, axis=1)[:, :dims]


def harmonic_ref(samples, n_fns, dims, seed, ctr, k, a, b, lo, hi):
    """Oracle for kernels.harmonic.make_harmonic: returns f32[2, N]."""
    u = uniforms_ref(ctr[0], samples, dims, ctr[1], ctr[2], seed[0], seed[1])
    x = lo[None, :] + (hi - lo)[None, :] * u           # (S, D)
    phases = x.astype(np.float32) @ k.T.astype(np.float32)  # (S, N)
    f = a[None, :] * np.cos(phases) + b[None, :] * np.sin(phases)
    return np.stack([f.sum(axis=0), (f * f).sum(axis=0)]).astype(np.float32)


def vm_multi_ref(samples, dims, seed, ctr, streams, ops, iargs, fargs,
                 theta, lo, hi):
    """Oracle for kernels.vm_eval.make_vm_multi: returns f32[F, 2]."""
    n_fns = ops.shape[0]
    out = np.zeros((n_fns, 2), np.float32)
    for f in range(n_fns):
        u = uniforms_ref(ctr[0], samples, dims, streams[f], ctr[1],
                         seed[0], seed[1])
        x = lo[f][None, :] + (hi[f] - lo[f])[None, :] * u
        vals = vm_eval_ref(x, ops[f], iargs[f], fargs[f], theta[f])
        out[f, 0] = vals.sum()
        out[f, 1] = (vals * vals).sum()
    return out


def stratified_ref(samples, dims, seed, ctr, streams, ops, iargs, fargs,
                   theta, cube_lo, cube_hi):
    """Oracle for kernels.stratified.make_stratified: returns f32[C, 2]."""
    n_cubes = cube_lo.shape[0]
    out = np.zeros((n_cubes, 2), np.float32)
    for c in range(n_cubes):
        u = uniforms_ref(ctr[0], samples, dims, streams[c], ctr[1],
                         seed[0], seed[1])
        x = cube_lo[c][None, :] + (cube_hi[c] - cube_lo[c])[None, :] * u
        vals = vm_eval_ref(x, ops, iargs, fargs, theta)
        out[c, 0] = vals.sum()
        out[c, 1] = (vals * vals).sum()
    return out
