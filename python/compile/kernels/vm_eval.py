"""Pallas kernel: bytecode-VM multi-function Monte-Carlo evaluator.

The generality workhorse behind ``ZMCintegral_multifunctions``: one AOT
artifact evaluates *any* closed-form integrand. The rust coordinator
compiles user expression strings to fixed-width bytecode (ops/iargs/fargs
rows); this kernel runs F programs, each over S in-kernel Philox samples
mapped to that function's own [lo_f, hi_f] box, and emits per-function
(sum f, sum f^2).

Grid is (F, S/TILE): the f axis picks the program row (BlockSpec block
(1, P)), the t axis walks sample tiles; partials accumulate into the
function's (1, 2) output block across the sequential t steps. Streams are
caller-controlled (u32[F]) so the coordinator can assign globally unique
Philox streams per integrand across chunks and workers.

VMEM per grid step (TILE=2048, STACK=16, f32): stack 128 KiB + sample tile
64 KiB + program rows < 1 KiB — far under budget; the VM is ALU-bound.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import philox
from ..vm_core import vm_eval_tile


def _kernel(seed_ref, ctr_ref, streams_ref, plens_ref, ops_ref, iargs_ref,
            fargs_ref, theta_ref, lo_ref, hi_ref, out_ref, *, tile, dims):
    t = pl.program_id(1)
    base = ctr_ref[0] + jnp.uint32(t) * jnp.uint32(tile)
    u = philox.uniform_tile(
        base, tile, dims, streams_ref[0], ctr_ref[1],
        seed_ref[0], seed_ref[1],
    )
    lo = lo_ref[0]
    hi = hi_ref[0]
    x = lo[:, None] + (hi - lo)[:, None] * u          # (D, TILE)
    vals = vm_eval_tile(x, ops_ref[0], iargs_ref[0], fargs_ref[0],
                        theta_ref[0], plens_ref[0])   # (TILE,)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0, 0] += jnp.sum(vals)
    out_ref[0, 1] += jnp.sum(vals * vals)


def make_vm_multi(n_fns, samples, dims, prog, tile):
    """Build the multi-function VM evaluator.

    Signature of the returned function:
      (seed u32[2], ctr u32[2]=(counter_base, trial), streams u32[F],
       plens i32[F] (actual program lengths; 0 = null slot),
       ops i32[F, P], iargs i32[F, P], fargs f32[F, P],
       theta f32[F, MAX_PARAM], lo f32[F, D], hi f32[F, D])
      -> f32[F, 2]   (col 0 = sum f, col 1 = sum f^2 over `samples` draws)
    """
    assert samples % tile == 0, "samples must be a multiple of tile"
    from .. import opcodes as oc

    grid = (n_fns, samples // tile)
    kern = functools.partial(_kernel, tile=tile, dims=dims)

    def fn(seed, ctr, streams, plens, ops, iargs, fargs, theta, lo, hi):
        return pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[
                pl.BlockSpec((2,), lambda f, t: (0,)),
                pl.BlockSpec((2,), lambda f, t: (0,)),
                pl.BlockSpec((1,), lambda f, t: (f,)),
                pl.BlockSpec((1,), lambda f, t: (f,)),
                pl.BlockSpec((1, prog), lambda f, t: (f, 0)),
                pl.BlockSpec((1, prog), lambda f, t: (f, 0)),
                pl.BlockSpec((1, prog), lambda f, t: (f, 0)),
                pl.BlockSpec((1, oc.MAX_PARAM), lambda f, t: (f, 0)),
                pl.BlockSpec((1, dims), lambda f, t: (f, 0)),
                pl.BlockSpec((1, dims), lambda f, t: (f, 0)),
            ],
            out_specs=pl.BlockSpec((1, 2), lambda f, t: (f, 0)),
            out_shape=jax.ShapeDtypeStruct((n_fns, 2), jnp.float32),
            interpret=True,
        )(seed, ctr, streams, plens, ops, iargs, fargs, theta, lo, hi)

    return fn
