"""Pallas kernel: multi-harmonic Monte-Carlo evaluator (Fig. 1 hot path).

Evaluates, for a batch of N harmonic integrands

    f_n(x) = a_n * cos(k_n . x) + b_n * sin(k_n . x)

the per-function running sums (sum f, sum f^2) over S samples drawn
in-kernel from the Philox counter RNG and affinely mapped to the box
[lo, hi]^D.

TPU mapping (see DESIGN.md #Hardware-Adaptation): the CUDA original spends
one thread per sample with per-thread xoroshiro state; here each grid step
owns a (TILE, D) sample tile resident in VMEM, the phase computation
x @ k^T is a (TILE, D) x (D, N) matmul shaped for the 128x128 MXU, and
partial reductions accumulate into the (2, N) output block across the
sequential TPU grid. Lowered with interpret=True for the CPU PJRT plugin.

VMEM working set per grid step (TILE=2048, D=8, N=128, f32):
  x tile 64 KiB + phases/f 2 x 1 MiB + params ~5 KiB  <  8 MiB budget.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .. import philox


def _kernel(seed_ref, ctr_ref, k_ref, a_ref, b_ref, lo_ref, hi_ref,
            out_ref, *, tile, dims):
    t = pl.program_id(0)
    base = ctr_ref[0] + jnp.uint32(t) * jnp.uint32(tile)
    # (D, TILE) uniforms in [0,1), then affine map into the integration box.
    u = philox.uniform_tile(
        base, tile, dims, ctr_ref[1], ctr_ref[2], seed_ref[0], seed_ref[1]
    )
    lo = lo_ref[...]
    hi = hi_ref[...]
    x = lo[:, None] + (hi - lo)[:, None] * u          # (D, TILE)
    # MXU path: phases = x^T @ k^T : (TILE, D) x (D, N) -> (TILE, N).
    phases = jax.lax.dot_general(
        x.T, k_ref[...].T,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    f = a_ref[...][None, :] * jnp.cos(phases) \
        + b_ref[...][None, :] * jnp.sin(phases)       # (TILE, N)
    psum = jnp.sum(f, axis=0)
    psq = jnp.sum(f * f, axis=0)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0, :] += psum
    out_ref[1, :] += psq


def make_harmonic(samples, n_fns, dims, tile):
    """Build the (jit-able) harmonic batch evaluator.

    Signature of the returned function:
      (seed u32[2], ctr u32[3]=(counter_base, stream, trial),
       k f32[N, D], a f32[N], b f32[N], lo f32[D], hi f32[D])
      -> f32[2, N]  (row 0 = sum f, row 1 = sum f^2 over `samples` draws)
    """
    assert samples % tile == 0, "samples must be a multiple of tile"
    grid = (samples // tile,)
    kern = functools.partial(_kernel, tile=tile, dims=dims)

    def fn(seed, ctr, k, a, b, lo, hi):
        return pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[
                pl.BlockSpec((2,), lambda t: (0,)),
                pl.BlockSpec((3,), lambda t: (0,)),
                pl.BlockSpec((n_fns, dims), lambda t: (0, 0)),
                pl.BlockSpec((n_fns,), lambda t: (0,)),
                pl.BlockSpec((n_fns,), lambda t: (0,)),
                pl.BlockSpec((dims,), lambda t: (0,)),
                pl.BlockSpec((dims,), lambda t: (0,)),
            ],
            out_specs=pl.BlockSpec((2, n_fns), lambda t: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((2, n_fns), jnp.float32),
            interpret=True,
        )(seed, ctr, k, a, b, lo, hi)

    return fn
