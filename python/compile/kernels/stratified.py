"""Pallas kernel: stratified per-cube evaluator (ZMCintegral_normal).

One bytecode program, C hypercubes, S samples per cube. Each grid step
(c, t) draws a Philox tile in cube c's box and runs the shared program on
it; partials accumulate into the cube's (1, 2) output block. The rust
tree-search driver batches every cube of one refinement level into a
single launch and assigns each cube a globally unique stream id via the
``streams`` input, so refined sub-cubes never reuse parent sample streams.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import philox
from ..vm_core import vm_eval_tile


def _kernel(seed_ref, ctr_ref, streams_ref, plen_ref, ops_ref, iargs_ref,
            fargs_ref, theta_ref, cube_lo_ref, cube_hi_ref, out_ref, *,
            tile, dims):
    t = pl.program_id(1)
    base = ctr_ref[0] + jnp.uint32(t) * jnp.uint32(tile)
    u = philox.uniform_tile(
        base, tile, dims, streams_ref[0], ctr_ref[1],
        seed_ref[0], seed_ref[1],
    )
    lo = cube_lo_ref[0]
    hi = cube_hi_ref[0]
    x = lo[:, None] + (hi - lo)[:, None] * u
    vals = vm_eval_tile(x, ops_ref[...], iargs_ref[...], fargs_ref[...],
                        theta_ref[...], plen_ref[0])

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0, 0] += jnp.sum(vals)
    out_ref[0, 1] += jnp.sum(vals * vals)


def make_stratified(n_cubes, samples_per_cube, dims, prog, tile):
    """Build the stratified cube evaluator.

    Signature of the returned function:
      (seed u32[2], ctr u32[2]=(counter_base, trial), streams u32[C],
       plen i32[1] (actual program length), ops i32[P], iargs i32[P],
       fargs f32[P], theta f32[MAX_PARAM],
       cube_lo f32[C, D], cube_hi f32[C, D])
      -> f32[C, 2]  (per-cube sum f, sum f^2 over `samples_per_cube` draws)
    """
    assert samples_per_cube % tile == 0
    from .. import opcodes as oc

    grid = (n_cubes, samples_per_cube // tile)
    kern = functools.partial(_kernel, tile=tile, dims=dims)

    def fn(seed, ctr, streams, plen, ops, iargs, fargs, theta, cube_lo,
           cube_hi):
        return pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[
                pl.BlockSpec((2,), lambda c, t: (0,)),
                pl.BlockSpec((2,), lambda c, t: (0,)),
                pl.BlockSpec((1,), lambda c, t: (c,)),
                pl.BlockSpec((1,), lambda c, t: (0,)),
                pl.BlockSpec((prog,), lambda c, t: (0,)),
                pl.BlockSpec((prog,), lambda c, t: (0,)),
                pl.BlockSpec((prog,), lambda c, t: (0,)),
                pl.BlockSpec((oc.MAX_PARAM,), lambda c, t: (0,)),
                pl.BlockSpec((1, dims), lambda c, t: (c, 0)),
                pl.BlockSpec((1, dims), lambda c, t: (c, 0)),
            ],
            out_specs=pl.BlockSpec((1, 2), lambda c, t: (c, 0)),
            out_shape=jax.ShapeDtypeStruct((n_cubes, 2), jnp.float32),
            interpret=True,
        )(seed, ctr, streams, plen, ops, iargs, fargs, theta, cube_lo,
          cube_hi)

    return fn
