"""L2 — the jax compute graphs that get AOT-lowered for the rust runtime.

Each entry point composes Philox sample generation, an L1 Pallas kernel,
and the reduction layout the coordinator expects. Geometry (batch sizes,
function counts, program width) is fixed per *variant* at lowering time;
``all_variants`` below is the single source of truth consumed by aot.py
and mirrored into artifacts/manifest.json for the rust registry.

All entry points return raw (sum f, sum f^2) accumulators — the rust side
owns volume scaling, Welford merging across chunks, and error estimates,
so one artifact serves any sample budget by chunked relaunch with
advancing ``counter_base``.
"""

import jax.numpy as jnp

from . import opcodes as oc
from .kernels.harmonic import make_harmonic
from .kernels.stratified import make_stratified
from .kernels.vm_eval import make_vm_multi


def _u32(shape):
    return ("u32", list(shape))


def _i32(shape):
    return ("i32", list(shape))


def _f32(shape):
    return ("f32", list(shape))


class Variant:
    """One AOT executable: a jax callable plus its manifest description."""

    def __init__(self, name, kind, fn, inputs, outputs, meta):
        self.name = name
        self.kind = kind
        self.fn = fn
        self.inputs = inputs      # [(arg_name, (dtype, shape)), ...]
        self.outputs = outputs    # [(dtype, shape)]
        self.meta = meta          # kind-specific constants for the rust side

    def example_args(self):
        """ShapeDtypeStructs for jax.jit(...).lower()."""
        import jax

        dt = {"f32": jnp.float32, "i32": jnp.int32, "u32": jnp.uint32}
        return [
            jax.ShapeDtypeStruct(tuple(shape), dt[dtype])
            for _, (dtype, shape) in self.inputs
        ]

    def manifest_entry(self):
        return {
            "file": f"{self.name}.hlo.txt",
            "kind": self.kind,
            "inputs": [
                {"name": n, "dtype": d, "shape": s}
                for n, (d, s) in self.inputs
            ],
            "outputs": [
                {"dtype": d, "shape": s} for d, s in self.outputs
            ],
            **self.meta,
        }


def harmonic_variant(samples, n_fns, dims=oc.MAX_DIM, tile=2048):
    """Multi-harmonic evaluator (Fig. 1 hot path)."""
    fn = make_harmonic(samples, n_fns, dims, tile)
    name = f"harmonic_s{samples}_n{n_fns}"
    inputs = [
        ("seed", _u32((2,))),
        ("ctr", _u32((3,))),          # (counter_base, stream, trial)
        ("k", _f32((n_fns, dims))),
        ("a", _f32((n_fns,))),
        ("b", _f32((n_fns,))),
        ("lo", _f32((dims,))),
        ("hi", _f32((dims,))),
    ]
    outputs = [_f32((2, n_fns))]
    meta = {"samples": samples, "n_fns": n_fns, "dims": dims, "tile": tile}
    return Variant(name, "harmonic", fn, inputs, outputs, meta)


def vm_multi_variant(n_fns, samples, dims=oc.MAX_DIM, prog=oc.MAX_PROG,
                     tile=2048):
    """Bytecode-VM multi-function evaluator (ZMCintegral_multifunctions).

    ``dims`` variants exist because sample generation is one Philox
    block per 4 dimensions per sample: a d4 artifact does half the RNG
    work of the d8 one — a measured ~1.5x launch win for the (common)
    dims<=4 integrand population (§Perf L1). The rust registry picks the
    smallest variant whose dims fit the job batch.
    """
    fn = make_vm_multi(n_fns, samples, dims, prog, tile)
    name = f"vm_multi_f{n_fns}_s{samples}"
    if dims != oc.MAX_DIM:
        name += f"_d{dims}"
    inputs = [
        ("seed", _u32((2,))),
        ("ctr", _u32((2,))),          # (counter_base, trial)
        ("streams", _u32((n_fns,))),
        ("plens", _i32((n_fns,))),    # actual program lengths (0 = null)
        ("ops", _i32((n_fns, prog))),
        ("iargs", _i32((n_fns, prog))),
        ("fargs", _f32((n_fns, prog))),
        ("theta", _f32((n_fns, oc.MAX_PARAM))),
        ("lo", _f32((n_fns, dims))),
        ("hi", _f32((n_fns, dims))),
    ]
    outputs = [_f32((n_fns, 2))]
    meta = {
        "samples": samples, "n_fns": n_fns, "dims": dims, "prog": prog,
        "tile": tile,
    }
    return Variant(name, "vm_multi", fn, inputs, outputs, meta)


def stratified_variant(n_cubes, samples_per_cube, dims=oc.MAX_DIM,
                       prog=oc.MAX_PROG, tile=None):
    """Per-cube stratified evaluator (ZMCintegral_normal tree search)."""
    tile = tile or min(samples_per_cube, 1024)
    fn = make_stratified(n_cubes, samples_per_cube, dims, prog, tile)
    name = f"stratified_c{n_cubes}_s{samples_per_cube}"
    inputs = [
        ("seed", _u32((2,))),
        ("ctr", _u32((2,))),          # (counter_base, trial)
        ("streams", _u32((n_cubes,))),
        ("plen", _i32((1,))),         # actual program length
        ("ops", _i32((prog,))),
        ("iargs", _i32((prog,))),
        ("fargs", _f32((prog,))),
        ("theta", _f32((oc.MAX_PARAM,))),
        ("cube_lo", _f32((n_cubes, dims))),
        ("cube_hi", _f32((n_cubes, dims))),
    ]
    outputs = [_f32((n_cubes, 2))]
    meta = {
        "samples": samples_per_cube, "n_cubes": n_cubes, "dims": dims,
        "prog": prog, "tile": tile,
    }
    return Variant(name, "stratified", fn, inputs, outputs, meta)


def all_variants():
    """Every executable shipped in artifacts/ — the AOT build matrix.

    Production sizes are chosen so one launch amortizes PJRT dispatch
    overhead (>= 2^16 evaluations) while staying responsive for the
    chunk scheduler; *_small variants keep integration tests fast.
    """
    return [
        # Fig-1 / harmonic family hot path.
        harmonic_variant(samples=65536, n_fns=128),
        harmonic_variant(samples=8192, n_fns=128, tile=1024),
        # Generic multi-function VM path (C1 workload); the d4 variant
        # halves in-kernel RNG cost for dims<=4 integrands. TILE swept
        # 1024..16384: 2048 and 4096 tie within run-to-run noise on the
        # rust-side XLA; 2048 kept (smaller VMEM estimate, §Perf L1).
        vm_multi_variant(n_fns=32, samples=16384),
        vm_multi_variant(n_fns=32, samples=16384, dims=4),
        vm_multi_variant(n_fns=8, samples=4096, tile=1024),
        vm_multi_variant(n_fns=8, samples=4096, dims=4, tile=1024),
        # Stratified tree-search path.
        stratified_variant(n_cubes=64, samples_per_cube=1024),
        stratified_variant(n_cubes=16, samples_per_cube=256),
    ]


CONSTANTS = {
    "abi_version": 1,
    "MAX_DIM": oc.MAX_DIM,
    "MAX_PROG": oc.MAX_PROG,
    "STACK": oc.STACK,
    "MAX_PARAM": oc.MAX_PARAM,
    "N_OPS": oc.N_OPS,
}
