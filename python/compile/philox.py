"""Philox-4x32-10 counter-based RNG, pure jnp.

This is the build-time twin of ``rust/src/sampler/philox.rs``; the two are
kept bit-exact (see python/tests/test_philox.py and the golden vectors in
spec/philox_kat.txt). Counter-based RNG replaces the paper's per-thread
xoroshiro128+ state (numba.cuda.random): on TPU there is no persistent
per-lane register state across grid steps, so a stateless generator keyed
on ``(seed, stream) x counter`` is the natural mapping — and it makes every
sample reproducible and addressable from the rust coordinator.

Counter layout (ABI, shared with rust):
    c0 = counter_base + sample_index      (within-launch sample id)
    c1 = dim_block                        (which group of 4 dimensions)
    c2 = stream                           (function / cube / parameter id)
    c3 = trial                            (independent-repeat id)
    key = (seed0, seed1)

All functions are pure jnp on uint32 and can be traced inside Pallas
kernels (interpret=True) as well as plain jax.jit code.
"""

import jax
import jax.numpy as jnp
import numpy as np

# 64-bit intermediates are used for the 32x32->64 multiply; build-time only.
jax.config.update("jax_enable_x64", True)

PHILOX_M0 = np.uint32(0xD2511F53)
PHILOX_M1 = np.uint32(0xCD9E8D57)
PHILOX_W0 = np.uint32(0x9E3779B9)  # golden-ratio Weyl constant
PHILOX_W1 = np.uint32(0xBB67AE85)  # sqrt(3)-1 Weyl constant
ROUNDS = 10

# 2^-24: maps the top 24 bits of a u32 to a float32 uniform in [0, 1).
U01_SCALE = np.float32(1.0 / (1 << 24))


def _mulhilo(a, b):
    """(hi, lo) 32-bit halves of the 64-bit product a*b (u32 inputs)."""
    p = a.astype(jnp.uint64) * b.astype(jnp.uint64)
    return (p >> np.uint64(32)).astype(jnp.uint32), p.astype(jnp.uint32)


def _round(c0, c1, c2, c3, k0, k1):
    hi0, lo0 = _mulhilo(jnp.uint32(PHILOX_M0), c0)
    hi1, lo1 = _mulhilo(jnp.uint32(PHILOX_M1), c2)
    return hi1 ^ c1 ^ k0, lo1, hi0 ^ c3 ^ k1, lo0


def philox4x32(c0, c1, c2, c3, k0, k1):
    """Philox-4x32-10 block: four u32 counters + two u32 keys -> four u32.

    Inputs may be scalars or arrays (broadcast together); outputs have the
    broadcast shape. Bit-exact with the Random123 reference and with the
    rust twin.
    """
    c0, c1, c2, c3 = (jnp.asarray(c, jnp.uint32) for c in (c0, c1, c2, c3))
    k0 = jnp.asarray(k0, jnp.uint32)
    k1 = jnp.asarray(k1, jnp.uint32)
    for r in range(ROUNDS):
        if r > 0:
            k0 = k0 + PHILOX_W0
            k1 = k1 + PHILOX_W1
        c0, c1, c2, c3 = _round(c0, c1, c2, c3, k0, k1)
    return c0, c1, c2, c3


def u32_to_unit_f32(x):
    """Map u32 -> f32 uniform in [0, 1) using the top 24 bits."""
    return (x >> np.uint32(8)).astype(jnp.float32) * U01_SCALE


def uniform_block(idx, dim_block, stream, trial, seed0, seed1):
    """Four f32 uniforms in [0,1) for each element of ``idx``.

    idx: u32 array of global sample indices (counter_base already added).
    Returns an array of shape ``idx.shape + (4,)``.
    """
    o0, o1, o2, o3 = philox4x32(idx, dim_block, stream, trial, seed0, seed1)
    return jnp.stack(
        [u32_to_unit_f32(o) for o in (o0, o1, o2, o3)], axis=-1
    )


def uniform_tile(base, tile, dims, stream, trial, seed0, seed1):
    """Generate a ``(dims, tile)`` f32 tile of uniforms in [0,1).

    ``base`` is the u32 counter offset of the tile's first sample (the rust
    coordinator chunks a logical launch into counter ranges). Dimensions are
    produced in groups of 4 (one philox block per group), transposed so
    that row ``d`` holds dimension ``d`` across the tile — the layout the
    VM kernel wants for O(1) row slicing.
    """
    idx = jnp.asarray(base, jnp.uint32) + jnp.arange(tile, dtype=jnp.uint32)
    blocks = []
    for j in range((dims + 3) // 4):
        u = uniform_block(idx, jnp.uint32(j), stream, trial, seed0, seed1)
        blocks.append(u.T)  # (4, tile)
    return jnp.concatenate(blocks, axis=0)[:dims]  # (dims, tile)
