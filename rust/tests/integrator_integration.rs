//! End-to-end integrator tests against analytic ground truth, including
//! fault-injected runs and device-vs-CPU agreement, on the persistent
//! engine.
//!
//! Backend selection: with real artifacts present they are used; without
//! them the CPU emulator registry stands in (default build), so this
//! suite runs fully offline. Under `--features pjrt` without artifacts
//! every test skips gracefully, as before.

use std::path::Path;
use std::sync::Arc;

use zmc::analytic;
use zmc::config::JobConfig;
use zmc::coordinator::fault::FaultPlan;
use zmc::coordinator::progress::Metrics;
use zmc::engine::{DeviceEngine, Engine};
use zmc::integrator::harmonic::{self, HarmonicBatch};
use zmc::integrator::multifunctions::{self, MultiConfig};
use zmc::integrator::normal::{self, NormalConfig};
use zmc::integrator::{direct, functional, spec::IntegralJob};
use zmc::runtime::device::DevicePool;
use zmc::runtime::registry::Registry;

fn registry() -> Option<Arc<Registry>> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        return Some(Arc::new(Registry::load(dir).unwrap()));
    }
    if cfg!(feature = "pjrt") {
        eprintln!("skipping: run `make artifacts` first");
        None
    } else {
        Some(Arc::new(Registry::emulated()))
    }
}

fn engine(workers: usize) -> Option<DeviceEngine> {
    let reg = registry()?;
    let pool = DevicePool::new(&reg, workers).unwrap();
    Some(Engine::for_pool(&pool).unwrap())
}

fn engine_with_fault(
    workers: usize,
    fault: Arc<FaultPlan>,
    metrics: Arc<Metrics>,
) -> Option<DeviceEngine> {
    let reg = registry()?;
    let pool = DevicePool::new(&reg, workers).unwrap();
    Some(Engine::for_pool_with(&pool, 3, fault, metrics).unwrap())
}

fn small_cfg(samples: usize) -> MultiConfig {
    MultiConfig {
        samples_per_fn: samples,
        seed: 20210711,
        exe: Some("vm_multi_f8_s4096".into()),
        ..Default::default()
    }
}

#[test]
fn multifunctions_heterogeneous_vs_analytic() {
    let Some(engine) = engine(1) else { return };
    // the Eq. (2) mixed-dimension workload + extras
    let jobs = vec![
        IntegralJob::with_params(
            "p0*abs(x1+x2)",
            &[(0.0, 1.0), (0.0, 1.0)],
            &[1.5],
        )
        .unwrap(),
        IntegralJob::with_params(
            "p0*abs(x1+x2-x3)",
            &[(0.0, 1.0); 3],
            &[2.0],
        )
        .unwrap(),
        IntegralJob::parse("x1^2", &[(0.0, 1.0)]).unwrap(),
        IntegralJob::parse("1", &[(0.0, 2.0), (0.0, 3.0)]).unwrap(),
    ];
    let truths = [
        analytic::eq2_abs2(1.5),
        analytic::eq2_abs3(2.0),
        analytic::monomial(2.0),
        6.0,
    ];
    let ests =
        multifunctions::integrate(&engine, &jobs, &small_cfg(1 << 15))
            .unwrap();
    for (e, t) in ests.iter().zip(truths) {
        assert!(
            e.consistent_with(t, 6.0),
            "estimate {e:?} vs truth {t}"
        );
    }
    // constant integrand: exactly zero variance
    assert!(ests[3].std_err < 1e-9);
    assert!((ests[3].value - 6.0).abs() < 1e-4);
}

#[test]
fn device_matches_cpu_baseline_statistically() {
    let Some(engine) = engine(1) else { return };
    let job =
        IntegralJob::parse("sin(3*x1)*x2", &[(0.0, 1.0), (0.0, 2.0)])
            .unwrap();
    let dev = multifunctions::integrate(
        &engine,
        std::slice::from_ref(&job),
        &small_cfg(1 << 14),
    )
    .unwrap()[0];
    let cpu = direct::integrate_one(&job, 1 << 14, 20210711, 0, 0);
    // same streams, same bytecode → same estimate up to f32 ordering
    assert!(
        (dev.value - cpu.value).abs() < 1e-4,
        "dev={dev:?} cpu={cpu:?}"
    );
    assert!((dev.std_err - cpu.std_err).abs() < 1e-5);
}

#[test]
fn multifunction_batch_of_twenty_mixed_dims() {
    let Some(engine) = engine(1) else { return };
    // n<10: a_n|x1+x2| ; n>=10: b_n|x1+x2-x3| (Eq. 2 at scale)
    let mut jobs = Vec::new();
    let mut truths = Vec::new();
    for n in 0..20 {
        if n < 10 {
            let a = 0.5 + n as f64 * 0.1;
            jobs.push(
                IntegralJob::with_params(
                    "p0*abs(x1+x2)",
                    &[(0.0, 1.0), (0.0, 1.0)],
                    &[a],
                )
                .unwrap(),
            );
            truths.push(analytic::eq2_abs2(a));
        } else {
            let b = 1.0 + (n - 10) as f64 * 0.2;
            jobs.push(
                IntegralJob::with_params(
                    "p0*abs(x1+x2-x3)",
                    &[(0.0, 1.0); 3],
                    &[b],
                )
                .unwrap(),
            );
            truths.push(analytic::eq2_abs3(b));
        }
    }
    let ests =
        multifunctions::integrate(&engine, &jobs, &small_cfg(1 << 14))
            .unwrap();
    for (i, (e, t)) in ests.iter().zip(&truths).enumerate() {
        assert!(e.consistent_with(*t, 6.0), "fn {i}: {e:?} vs {t}");
    }
}

#[test]
fn results_identical_across_worker_counts_and_faults() {
    let Some(e1) = engine(1) else { return };
    let jobs = vec![
        IntegralJob::parse("x1*x2", &[(0.0, 1.0), (0.0, 1.0)]).unwrap(),
        IntegralJob::parse("cos(5*x1)", &[(0.0, 1.0)]).unwrap(),
    ];
    let cfg = small_cfg(1 << 14);
    let base = multifunctions::integrate(&e1, &jobs, &cfg).unwrap();

    let e2 = engine(2).unwrap();
    let two = multifunctions::integrate(&e2, &jobs, &cfg).unwrap();
    for (a, b) in base.iter().zip(&two) {
        assert_eq!(a.value, b.value, "worker-count changed the result");
    }

    let m = Arc::new(Metrics::new());
    let ef = engine_with_fault(
        2,
        Arc::new(FaultPlan::transient(3)),
        Arc::clone(&m),
    )
    .unwrap();
    let faulty = multifunctions::integrate(&ef, &jobs, &cfg).unwrap();
    for (a, b) in base.iter().zip(&faulty) {
        assert_eq!(a.value, b.value, "fault injection changed the result");
    }
    assert!(m.retried() > 0);
}

#[test]
fn repeated_integrate_reuses_compiled_executables() {
    // the warm-cache acceptance gate, end to end on the integrator API
    let Some(reg) = registry() else { return };
    let pool = DevicePool::new(&reg, 1).unwrap();
    let engine = Engine::for_pool(&pool).unwrap();
    let job = IntegralJob::parse("x1^2", &[(0.0, 1.0)]).unwrap();
    let before = reg.compile_count();
    for _ in 0..6 {
        multifunctions::integrate(
            &engine,
            std::slice::from_ref(&job),
            &small_cfg(1 << 12),
        )
        .unwrap();
    }
    let compiled = reg.compile_count() - before;
    assert_eq!(
        compiled, 1,
        "one worker, one executable, six integrate() calls: \
         must compile exactly once"
    );
}

#[test]
fn harmonic_fig1_slice_vs_analytic() {
    let Some(engine) = engine(1) else { return };
    let batch = HarmonicBatch::fig1(10);
    let cfg = MultiConfig {
        samples_per_fn: 1 << 16,
        seed: 99,
        exe: Some("harmonic_s8192_n128".into()),
        ..Default::default()
    };
    let trials =
        harmonic::integrate_trials(&engine, &batch, &cfg, 6).unwrap();
    for i in 0..batch.len() {
        let mut w = zmc::stats::Welford::new();
        for t in &trials {
            w.push(t[i].value);
        }
        let truth = batch.truth(i);
        // mean over 6 trials; gate at 6 standard errors of the mean
        assert!(
            (w.mean() - truth).abs() < 6.0 * w.sem().max(1e-6),
            "n={}: mean={} truth={truth} sem={}",
            i + 1,
            w.mean(),
            w.sem()
        );
    }
}

#[test]
fn functional_scan_tracks_parameter() {
    let Some(engine) = engine(1) else { return };
    // ∫ p0·x1² over [0,1] = p0/3, swept over p0
    let job = IntegralJob::with_params("p0*x1^2", &[(0.0, 1.0)], &[0.0])
        .unwrap();
    let thetas: Vec<Vec<f64>> = functional::linspace(0.0, 4.0, 9)
        .into_iter()
        .map(|v| vec![v])
        .collect();
    let ests =
        functional::scan(&engine, &job, &thetas, &small_cfg(1 << 14))
            .unwrap();
    for (t, e) in thetas.iter().zip(&ests) {
        assert!(
            e.consistent_with(t[0] / 3.0, 6.0),
            "p0={}: {e:?}",
            t[0]
        );
    }
}

#[test]
fn normal_tree_search_converges() {
    let Some(engine) = engine(1) else { return };
    // peaked integrand: tree search should refine around the peak
    let job = IntegralJob::parse(
        "exp(-50*((x1-0.5)^2 + (x2-0.5)^2))",
        &[(0.0, 1.0), (0.0, 1.0)],
    )
    .unwrap();
    let truth = {
        // separable gaussian: (∫ exp(-50 (u-.5)^2))^2
        let c = 50.0f64.sqrt();
        let one_d = (std::f64::consts::PI.sqrt() / (2.0 * c))
            * 2.0
            * analytic::erf(c * 0.5);
        one_d * one_d
    };
    let cfg = NormalConfig {
        initial_divisions: 4,
        n_trials: 4,
        max_depth: 2,
        seed: 7,
        exe: Some("stratified_c16_s256".into()),
        ..Default::default()
    };
    let r = normal::integrate(&engine, &job, &cfg).unwrap();
    assert!(
        r.estimate.consistent_with(truth, 8.0),
        "{:?} vs {truth}",
        r.estimate
    );
    assert_eq!(r.cubes_per_level[0], 16);
    assert!(r.launches > 0);
}

#[test]
fn normal_flags_fluctuating_regions() {
    let Some(engine) = engine(1) else { return };
    // highly oscillatory in x1<0.25 only: flagged cubes should cluster
    let job = IntegralJob::parse(
        "max(0, 0.25-x1) * sin(60*x1) * 40",
        &[(0.0, 1.0)],
    )
    .unwrap();
    let cfg = NormalConfig {
        initial_divisions: 8,
        n_trials: 4,
        sigma_mult: 0.5,
        max_depth: 1,
        seed: 3,
        exe: Some("stratified_c16_s256".into()),
        ..Default::default()
    };
    let r = normal::integrate(&engine, &job, &cfg).unwrap();
    assert!(
        r.flagged_per_level[0] >= 1 && r.flagged_per_level[0] <= 4,
        "flagged: {:?}",
        r.flagged_per_level
    );
}

#[test]
fn config_file_end_to_end() {
    let Some(engine) = engine(1) else { return };
    let cfg = JobConfig::from_json_text(
        r#"{
        "samples_per_fn": 16384, "trials": 2, "seed": 5,
        "functions": [
            {"expr": "x1+x2", "bounds": [[0,1],[0,1]]},
            {"expr": "p0*x1", "bounds": [[0,2]], "theta": [3.0]}
        ]}"#,
    )
    .unwrap();
    let mcfg = MultiConfig {
        samples_per_fn: cfg.samples_per_fn,
        seed: cfg.seed,
        exe: Some("vm_multi_f8_s4096".into()),
        ..Default::default()
    };
    let per_trial = multifunctions::integrate_trials(
        &engine, &cfg.jobs, &mcfg, cfg.trials,
    )
    .unwrap();
    assert_eq!(per_trial.len(), 2);
    // trial streams differ
    assert_ne!(per_trial[0][0].value, per_trial[1][0].value);
    for t in &per_trial {
        assert!(t[0].consistent_with(1.0, 6.0));
        assert!(t[1].consistent_with(6.0, 6.0));
    }
}

#[test]
fn normal_handles_higher_dimensions() {
    // the paper recommends ZMCintegral_normal for high-dim integrands;
    // exercise D=6 (2^6 = 64 initial cubes, splits capped at 4 dims)
    let Some(engine) = engine(1) else { return };
    let job = IntegralJob::parse(
        "x1*x2 + x3*x4 + x5*x6",
        &[(0.0, 1.0); 6],
    )
    .unwrap();
    let cfg = NormalConfig {
        initial_divisions: 2,
        n_trials: 3,
        max_depth: 1,
        seed: 21,
        exe: Some("stratified_c64_s1024".into()),
        ..Default::default()
    };
    let r = normal::integrate(&engine, &job, &cfg).unwrap();
    assert_eq!(r.cubes_per_level[0], 64);
    // truth: 3 * (1/2 * 1/2) = 0.75
    assert!(
        r.estimate.consistent_with(0.75, 8.0),
        "{:?}",
        r.estimate
    );
}

#[test]
fn multifunctions_at_two_hundred_functions() {
    // a mid-scale slice of the C1 workload with exact gates:
    // I_n = ∫ x1^2 + c_n over [0,1]^2 = 1/3 + c_n
    let Some(engine) = engine(1) else { return };
    let jobs: Vec<IntegralJob> = (0..200)
        .map(|i| {
            IntegralJob::with_params(
                "x1^2 + p0",
                &[(0.0, 1.0), (0.0, 1.0)],
                &[i as f64 * 0.01],
            )
            .unwrap()
        })
        .collect();
    let cfg = MultiConfig {
        samples_per_fn: 1 << 13,
        seed: 33,
        ..Default::default()
    };
    let ests = multifunctions::integrate(&engine, &jobs, &cfg).unwrap();
    for (i, e) in ests.iter().enumerate() {
        let truth = 1.0 / 3.0 + i as f64 * 0.01;
        assert!(e.consistent_with(truth, 6.0), "fn {i}: {e:?} vs {truth}");
    }
}

#[test]
fn stream_base_gives_independent_replicas() {
    // two runs differing only in stream_base must draw disjoint streams
    let Some(engine) = engine(1) else { return };
    let job = IntegralJob::parse("sin(9*x1)", &[(0.0, 1.0)]).unwrap();
    let mk = |stream_base| MultiConfig {
        samples_per_fn: 1 << 13,
        seed: 44,
        stream_base,
        exe: Some("vm_multi_f8_s4096".into()),
        ..Default::default()
    };
    let a = multifunctions::integrate(
        &engine,
        std::slice::from_ref(&job),
        &mk(0),
    )
    .unwrap()[0];
    let b = multifunctions::integrate(
        &engine,
        std::slice::from_ref(&job),
        &mk(1000),
    )
    .unwrap()[0];
    assert_ne!(a.value, b.value);
    // both still within 6 sigma of truth (1 - cos 9)/9
    let truth = (1.0 - 9.0f64.cos()) / 9.0;
    assert!(a.consistent_with(truth, 6.0));
    assert!(b.consistent_with(truth, 6.0));
}
