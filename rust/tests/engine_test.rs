//! Engine invariants: warm executable caches (compile-once-per-worker),
//! concurrent submission correctness, and the policy layer (retries,
//! fault injection, worker death) on the persistent path.
//!
//! Mock-backend tests run everywhere; device-backed tests use the CPU
//! emulator registry and are skipped under `--features pjrt` (where the
//! synthetic HLO bodies cannot be compiled).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Result};
use zmc::coordinator::fault::FaultPlan;
use zmc::coordinator::progress::Metrics;
use zmc::engine::{Backend, Engine, EngineConfig};

struct Mock;

fn mock_out(t: u64) -> u64 {
    t.wrapping_mul(0x9E37_79B9).rotate_left(13)
}

impl Backend for Mock {
    type Ctx = ();
    type Task = u64;
    type Out = u64;

    fn make_ctx(&self, _w: usize) -> Result<()> {
        Ok(())
    }

    fn run(&self, _ctx: &(), t: &u64) -> Result<u64> {
        Ok(mock_out(*t))
    }
}

#[test]
fn concurrent_submissions_match_serial() {
    // >= 4 submitter threads interleaving job sets on one engine; every
    // handle must resolve to exactly its own job's serial results.
    let engine = Engine::new(Mock, EngineConfig::new(4)).unwrap();
    let engine = &engine;
    std::thread::scope(|scope| {
        for submitter in 0..4u64 {
            scope.spawn(move || {
                for round in 0..8u64 {
                    let base = submitter * 1_000_000 + round * 1_000;
                    let tasks: Vec<u64> = (base..base + 50).collect();
                    let want: Vec<u64> =
                        tasks.iter().map(|&t| mock_out(t)).collect();
                    let h = engine.submit(tasks).unwrap();
                    assert_eq!(h.wait().unwrap(), want);
                }
            });
        }
    });
    assert_eq!(engine.metrics().done(), 4 * 8 * 50);
}

#[test]
fn wait_each_streams_outputs_in_task_order() {
    // the streaming-reduction keystone: however 4 workers race through
    // the queue, wait_each must deliver results in submission order —
    // one at a time, never materializing the full output vector
    let engine = Engine::new(Mock, EngineConfig::new(4)).unwrap();
    for round in 0..4u64 {
        let base = round * 10_000;
        let tasks: Vec<u64> = (base..base + 200).collect();
        let want: Vec<u64> = tasks.iter().map(|&t| mock_out(t)).collect();
        let h = engine.submit(tasks).unwrap();
        let mut got = Vec::new();
        h.wait_each(&mut |o| got.push(o)).unwrap();
        assert_eq!(got, want, "wait_each must drain in task order");
    }
}

#[test]
fn engine_fault_policy_retries_transiently() {
    let metrics = Arc::new(Metrics::new());
    let engine = Engine::with_policy(
        Mock,
        EngineConfig { n_workers: 3, max_retries: 10 },
        Arc::new(FaultPlan::transient(4)),
        Arc::clone(&metrics),
    )
    .unwrap();
    let tasks: Vec<u64> = (0..120).collect();
    let want: Vec<u64> = tasks.iter().map(|&t| mock_out(t)).collect();
    let out = engine.run(tasks).unwrap();
    assert_eq!(out, want);
    assert!(metrics.retried() > 0);
    assert_eq!(metrics.failed(), metrics.retried());
}

#[test]
fn engine_survives_worker_death() {
    let engine = Engine::with_policy(
        Mock,
        EngineConfig { n_workers: 3, max_retries: 3 },
        Arc::new(FaultPlan::kill(1, 3)),
        Arc::new(Metrics::new()),
    )
    .unwrap();
    let tasks: Vec<u64> = (0..60).collect();
    let want: Vec<u64> = tasks.iter().map(|&t| mock_out(t)).collect();
    assert_eq!(engine.run(tasks).unwrap(), want);
}

#[test]
fn all_workers_dead_fails_pending_jobs() {
    let engine = Engine::with_policy(
        Mock,
        EngineConfig { n_workers: 1, max_retries: 3 },
        Arc::new(FaultPlan::kill(0, 0)),
        Arc::new(Metrics::new()),
    )
    .unwrap();
    let err = match engine.submit(vec![1, 2, 3]) {
        Ok(h) => h.wait().unwrap_err(),
        Err(e) => e, // workers died before the submit landed
    };
    let msg = err.to_string();
    assert!(
        msg.contains("unfinished") || msg.contains("no live workers"),
        "{msg}"
    );
}

struct HalfDeadCtx;

impl Backend for HalfDeadCtx {
    type Ctx = usize;
    type Task = u64;
    type Out = u64;

    fn make_ctx(&self, w: usize) -> Result<usize> {
        if w == 0 {
            Err(anyhow!("simulated driver crash"))
        } else {
            Ok(w)
        }
    }

    fn run(&self, _ctx: &usize, t: &u64) -> Result<u64> {
        Ok(*t + 1)
    }
}

#[test]
fn context_failure_is_recorded_and_job_survives() {
    let metrics = Arc::new(Metrics::new());
    let engine = Engine::with_policy(
        HalfDeadCtx,
        EngineConfig { n_workers: 2, max_retries: 3 },
        Arc::new(FaultPlan::none()),
        Arc::clone(&metrics),
    )
    .unwrap();
    let out = engine.run((0..30).collect()).unwrap();
    assert_eq!(out.len(), 30);
    assert_eq!(out[0], 1);
    // the dead worker's error must be in the ledger even though the job
    // succeeded (it is recorded before the worker leaves the pool, but
    // give the thread a moment to get there)
    for _ in 0..200 {
        if !metrics.worker_errors().is_empty() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let errs = metrics.worker_errors();
    assert_eq!(errs.len(), 1, "{errs:?}");
    assert!(errs[0].contains("simulated driver crash"));
}

/// Backend whose task 0 blocks until released (signalling `entered`
/// first), making drop-cancellation tests deterministic.
struct GatedBackend {
    entered: Arc<(std::sync::Mutex<bool>, std::sync::Condvar)>,
    release: Arc<(std::sync::Mutex<bool>, std::sync::Condvar)>,
}

impl Backend for GatedBackend {
    type Ctx = ();
    type Task = u64;
    type Out = u64;

    fn make_ctx(&self, _w: usize) -> Result<()> {
        Ok(())
    }

    fn run(&self, _ctx: &(), t: &u64) -> Result<u64> {
        if *t == 0 {
            let (m, cv) = &*self.entered;
            *m.lock().unwrap() = true;
            cv.notify_all();
            let (m, cv) = &*self.release;
            let mut open = m.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        }
        Ok(*t)
    }
}

#[test]
fn dropped_handle_cancels_queued_tasks() {
    let entered = Arc::new((
        std::sync::Mutex::new(false),
        std::sync::Condvar::new(),
    ));
    let release = Arc::new((
        std::sync::Mutex::new(false),
        std::sync::Condvar::new(),
    ));
    let engine = Engine::new(
        GatedBackend {
            entered: Arc::clone(&entered),
            release: Arc::clone(&release),
        },
        EngineConfig::new(1),
    )
    .unwrap();
    // task 0 blocks the only worker; tasks 1..=50 sit in the queue
    let h = engine.submit((0..51).collect()).unwrap();
    {
        let (m, cv) = &*entered;
        let mut e = m.lock().unwrap();
        while !*e {
            e = cv.wait(e).unwrap();
        }
    }
    // dropping the un-awaited handle must purge all queued tasks so
    // they never occupy the worker
    drop(h);
    assert_eq!(engine.metrics().cancelled(), 50);
    {
        let (m, cv) = &*release;
        *m.lock().unwrap() = true;
        cv.notify_all();
    }
    // the engine still serves later jobs normally
    let out = engine.run((100..110).collect()).unwrap();
    assert_eq!(out, (100..110).collect::<Vec<u64>>());
    // only the in-hand task 0 and job B's 10 tasks ever executed
    assert!(engine.metrics().done() <= 11, "{}", engine.metrics().done());
}

struct CountingCtx {
    ctx_builds: AtomicU64,
}

impl Backend for CountingCtx {
    type Ctx = u64;
    type Task = u64;
    type Out = u64;

    fn make_ctx(&self, w: usize) -> Result<u64> {
        self.ctx_builds.fetch_add(1, Ordering::SeqCst);
        Ok(w as u64)
    }

    fn run(&self, ctx: &u64, t: &u64) -> Result<u64> {
        Ok(ctx * 1_000_000 + t)
    }
}

#[test]
fn contexts_are_built_once_per_worker_not_per_job() {
    // the heart of the persistence claim, backend-agnostic: 20 jobs on
    // 3 workers must build exactly 3 contexts
    let engine = Engine::new(
        CountingCtx { ctx_builds: AtomicU64::new(0) },
        EngineConfig::new(3),
    )
    .unwrap();
    for round in 0..20u64 {
        let out = engine.run(vec![round]).unwrap();
        assert_eq!(out.len(), 1);
    }
    // a worker that never won a task still builds its context at thread
    // start; allow it a moment in case it was scheduled late
    for _ in 0..200 {
        if engine.backend().ctx_builds.load(Ordering::SeqCst) == 3 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert_eq!(
        engine.backend().ctx_builds.load(Ordering::SeqCst),
        3,
        "contexts must persist across submits"
    );
}

// ------------------------------------------------------------------
// Device-backed tests (CPU emulator registry).
#[cfg(not(feature = "pjrt"))]
mod device_backed {
    use super::*;
    use zmc::engine::DeviceEngine;
    use zmc::integrator::multifunctions::{self, MultiConfig};
    use zmc::integrator::spec::IntegralJob;
    use zmc::runtime::device::DevicePool;
    use zmc::runtime::registry::Registry;
    use zmc::runtime::ExecTier;

    fn engine(workers: usize) -> (Arc<Registry>, DeviceEngine) {
        let reg = Arc::new(Registry::emulated());
        let pool = DevicePool::new(&reg, workers).unwrap();
        (reg, Engine::for_pool(&pool).unwrap())
    }

    /// Engine pinned to one execution tier (the ledger tests below
    /// assert per-tier counters, so they must not float with the
    /// process-wide `ZMC_EMU_TIER` default).
    fn engine_tiered(
        workers: usize,
        tier: ExecTier,
    ) -> (Arc<Registry>, DeviceEngine) {
        let reg = Arc::new(Registry::emulated());
        let pool =
            DevicePool::new(&reg, workers).unwrap().with_tier(tier);
        (reg, Engine::for_pool(&pool).unwrap())
    }

    fn jobs(n: usize) -> Vec<IntegralJob> {
        (0..n)
            .map(|i| {
                IntegralJob::with_params(
                    "x1^2 + p0",
                    &[(0.0, 1.0)],
                    &[i as f64 * 0.5],
                )
                .unwrap()
            })
            .collect()
    }

    fn cfg() -> MultiConfig {
        MultiConfig {
            samples_per_fn: 1 << 12,
            seed: 99,
            exe: Some("vm_multi_f8_s4096".into()),
            ..Default::default()
        }
    }

    #[test]
    fn single_worker_compiles_each_exe_exactly_once() {
        let (reg, engine) = engine(1);
        let js = jobs(12);
        let first =
            multifunctions::integrate(&engine, &js, &cfg()).unwrap();
        assert_eq!(reg.compile_count(), 1);
        // ten more submits of the same executable: ledger must not move
        for _ in 0..10 {
            let again =
                multifunctions::integrate(&engine, &js, &cfg()).unwrap();
            // idempotent Philox addressing: bit-identical estimates
            assert_eq!(again[0].value, first[0].value);
        }
        assert_eq!(
            reg.compile_count(),
            1,
            "repeated integrate() must not recompile"
        );
    }

    #[test]
    fn single_worker_lowers_each_program_row_exactly_once() {
        // the plan-ledger twin of the compile-ledger test above: every
        // distinct program row is decoded + lowered at most once per
        // worker, no matter how many times the batch is resubmitted
        let (reg, engine) = engine_tiered(1, ExecTier::Plan);
        // distinct *program rows* (the constant differs per function —
        // theta alone would share one row and one plan)
        let js: Vec<IntegralJob> = (0..6)
            .map(|i| {
                IntegralJob::parse(
                    &format!("x1^2 + {}.5", i),
                    &[(0.0, 1.0)],
                )
                .unwrap()
            })
            .collect();
        let first = multifunctions::integrate(&engine, &js, &cfg()).unwrap();
        assert_eq!(reg.plan_lower_count(), 6);
        let hits_after_first = reg.plan_hit_count();
        for _ in 0..10 {
            let again =
                multifunctions::integrate(&engine, &js, &cfg()).unwrap();
            // bit-identical results through the warm plan cache
            assert_eq!(again[0].value, first[0].value);
        }
        assert_eq!(
            reg.plan_lower_count(),
            6,
            "repeated integrate() must not re-lower program rows"
        );
        assert!(
            reg.plan_hit_count() > hits_after_first,
            "warm launches must hit the plan cache"
        );
        // the engine metrics see the same events the registry ledgered
        assert_eq!(engine.metrics().plan_misses(), 6);
        assert!(engine.metrics().plan_hits() > 0);
    }

    #[test]
    fn fused_tier_lowers_each_program_row_exactly_once() {
        // the fused-ledger mirror of the plan-ledger test above: the
        // default tier caches `FusedPlan`s under its own ledger and
        // leaves the plan ledger untouched
        let (reg, engine) = engine_tiered(1, ExecTier::Fused);
        let js: Vec<IntegralJob> = (0..6)
            .map(|i| {
                IntegralJob::parse(
                    &format!("x1^2 + {}.5", i),
                    &[(0.0, 1.0)],
                )
                .unwrap()
            })
            .collect();
        let first = multifunctions::integrate(&engine, &js, &cfg()).unwrap();
        assert_eq!(reg.fused_lower_count(), 6);
        for _ in 0..10 {
            let again =
                multifunctions::integrate(&engine, &js, &cfg()).unwrap();
            assert_eq!(again[0].value, first[0].value);
        }
        assert_eq!(
            reg.fused_lower_count(),
            6,
            "repeated integrate() must not re-lower fused rows"
        );
        assert!(reg.fused_hit_count() > 0);
        assert_eq!(engine.metrics().fused_misses(), 6);
        assert!(engine.metrics().fused_hits() > 0);
        // the plan-tier ledger never moved
        assert_eq!(reg.plan_lower_count(), 0);
        assert_eq!(engine.metrics().plan_misses(), 0);
    }

    #[test]
    fn fused_tier_bit_identical_across_engines_and_workers() {
        // the acceptance invariant: fused moments must not depend on
        // the topology the batch is sharded over
        use zmc::session::Session;
        let js = jobs(10);
        let run = |workers: usize, engines: usize| {
            let s = Session::builder()
                .emulated()
                .workers(workers)
                .engines(engines)
                .execution_tier(ExecTier::Fused)
                .build()
                .unwrap();
            s.multifunctions(&js)
                .samples(1 << 12)
                .seed(99)
                .run()
                .unwrap()
        };
        let base = run(1, 1);
        for (w, e) in [(3, 1), (1, 4), (2, 2)] {
            let got = run(w, e);
            for (g, b) in got.iter().zip(&base) {
                assert_eq!(g.value.to_bits(), b.value.to_bits());
                assert_eq!(g.std_err.to_bits(), b.std_err.to_bits());
            }
        }
    }

    #[test]
    fn multi_worker_lowers_each_row_at_most_once_per_worker() {
        let (reg, engine) = engine_tiered(2, ExecTier::Plan);
        let js: Vec<IntegralJob> = (0..8)
            .map(|i| {
                IntegralJob::parse(
                    &format!("x1*{}.25 + x1", i),
                    &[(0.0, 1.0)],
                )
                .unwrap()
            })
            .collect();
        for _ in 0..6 {
            multifunctions::integrate(&engine, &js, &cfg()).unwrap();
        }
        let lowers = reg.plan_lower_count();
        assert!(
            (8..=16).contains(&lowers),
            "lowers={lowers}: must be <= n_workers x distinct rows and \
             never grow with submit count"
        );
    }

    #[test]
    fn multi_worker_compiles_at_most_once_per_worker() {
        let (reg, engine) = engine(2);
        let js = jobs(40); // 5 blocks x 1 chunk: both workers get launches
        for _ in 0..8 {
            multifunctions::integrate(&engine, &js, &cfg()).unwrap();
        }
        let compiles = reg.compile_count();
        assert!(
            (1..=2).contains(&compiles),
            "compiles={compiles}: must be <= n_workers and never grow \
             with submit count"
        );
    }

    #[test]
    fn concurrent_device_submissions_are_deterministic() {
        // serial reference on a fresh engine
        let (_r1, e1) = engine(1);
        let js = jobs(10);
        let want = multifunctions::integrate(&e1, &js, &cfg()).unwrap();

        // four submitters sharing one 2-worker engine
        let (_r2, e2) = engine(2);
        let e2 = &e2;
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let js = js.clone();
                let want = want.clone();
                scope.spawn(move || {
                    for _ in 0..3 {
                        let h = multifunctions::submit(e2, &js, &cfg())
                            .unwrap();
                        let got = h.wait().unwrap();
                        for (g, w) in got.iter().zip(&want) {
                            assert_eq!(g.value, w.value);
                            assert_eq!(g.std_err, w.std_err);
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn interleaved_heterogeneous_handles_resolve_independently() {
        let (reg, engine) = engine(2);
        // two different executables in flight at once
        let vm_handle =
            multifunctions::submit(&engine, &jobs(6), &cfg()).unwrap();
        let strat_cfg = zmc::integrator::normal::NormalConfig {
            initial_divisions: 4,
            n_trials: 2,
            max_depth: 0,
            seed: 5,
            exe: Some("stratified_c16_s256".into()),
            ..Default::default()
        };
        let job =
            IntegralJob::parse("x1*x2", &[(0.0, 1.0), (0.0, 1.0)]).unwrap();
        let strat =
            zmc::integrator::normal::integrate(&engine, &job, &strat_cfg)
                .unwrap();
        let vm = vm_handle.wait().unwrap();
        assert_eq!(vm.len(), 6);
        assert!(
            (strat.estimate.value - 0.25).abs() < 0.05,
            "{:?}",
            strat.estimate
        );
        // two executables, at most one compile of each per worker
        assert!(reg.compile_count() <= 4);
    }
}
