//! Property tests for the expression pipeline:
//! random ASTs → (print→reparse), (fold ≡ eval), (compile ≡ eval).

use zmc::expr::{BinOp, Expr, UnOp};
use zmc::util::proptest::{check, Gen};
use zmc::vm::interp::eval_scalar;

/// Random AST generator. `depth` bounds recursion; leans on safe ops but
/// includes div/pow/log so NaN paths are exercised too.
fn gen_expr(g: &mut Gen, depth: usize) -> Expr {
    if depth == 0 || g.below(10) < 3 {
        return match g.below(3) {
            0 => Expr::Const(g.range_f64(-4.0, 4.0)),
            1 => Expr::Var(g.below(4)),
            _ => Expr::Param(g.below(4)),
        };
    }
    if g.bool() {
        let op = *g.choose(&[
            UnOp::Neg,
            UnOp::Abs,
            UnOp::Sin,
            UnOp::Cos,
            UnOp::Tanh,
            UnOp::Atan,
            UnOp::Floor,
            UnOp::Exp,
            UnOp::Sqrt,
            UnOp::Log,
        ]);
        Expr::Unary(op, gen_expr(g, depth - 1).into())
    } else {
        let op = *g.choose(&[
            BinOp::Add,
            BinOp::Sub,
            BinOp::Mul,
            BinOp::Div,
            BinOp::Min,
            BinOp::Max,
            BinOp::Pow,
        ]);
        Expr::Binary(
            op,
            gen_expr(g, depth - 1).into(),
            gen_expr(g, depth - 1).into(),
        )
    }
}

/// Structural AST equality with NaN == NaN (bitwise-agnostic).
fn ast_eq(a: &Expr, b: &Expr) -> bool {
    match (a, b) {
        (Expr::Const(x), Expr::Const(y)) => {
            x == y || (x.is_nan() && y.is_nan())
        }
        (Expr::Var(x), Expr::Var(y)) => x == y,
        (Expr::Param(x), Expr::Param(y)) => x == y,
        (Expr::Unary(o1, a1), Expr::Unary(o2, a2)) => {
            o1 == o2 && ast_eq(a1, a2)
        }
        (Expr::Binary(o1, a1, b1), Expr::Binary(o2, a2, b2)) => {
            o1 == o2 && ast_eq(a1, a2) && ast_eq(b1, b2)
        }
        _ => false,
    }
}

fn close(a: f64, b: f64) -> bool {
    if a.is_nan() && b.is_nan() {
        return true;
    }
    if a.is_infinite() || b.is_infinite() {
        return a == b || (a.is_infinite() && b.is_infinite());
    }
    (a - b).abs() <= 1e-4 * a.abs().max(b.abs()).max(1.0)
}

#[test]
fn print_reparse_roundtrip() {
    check(101, 300, |g| {
        let e = gen_expr(g, 4);
        let printed = e.to_string();
        let reparsed = Expr::parse_raw(&printed)
            .unwrap_or_else(|err| panic!("reparse '{printed}': {err}"));
        // The parser folds `-<literal>` into the constant, so compare
        // the constant-folded normal forms (identical ASTs otherwise;
        // gen_expr never emits Square/Recip, whose printing re-sugars).
        // NaN constants (e.g. folded sqrt(-c)) compare equal by intent.
        let a = zmc::expr::fold::fold(e.clone());
        let b = zmc::expr::fold::fold(reparsed);
        assert!(ast_eq(&a, &b), "printed: {printed}\n{a:?}\nvs {b:?}");
    });
}

#[test]
fn fold_preserves_semantics() {
    check(202, 300, |g| {
        let e = gen_expr(g, 4);
        let folded = zmc::expr::fold::fold(e.clone());
        let x: Vec<f64> = (0..4).map(|_| g.range_f64(-2.0, 2.0)).collect();
        let t: Vec<f64> = (0..4).map(|_| g.range_f64(-2.0, 2.0)).collect();
        let a = e.eval(&x, &t);
        let b = folded.eval(&x, &t);
        assert!(close(a, b), "{e} -> {folded}: {a} vs {b}");
    });
}

#[test]
fn compiled_vm_matches_tree_walk() {
    let mut tested = 0u32;
    check(303, 400, |g| {
        let e = gen_expr(g, 4);
        // deep trees can legitimately exceed the device stack — skip
        let Ok(prog) = e.compile() else { return };
        tested += 1;
        let x: Vec<f64> = (0..4).map(|_| g.range_f64(-2.0, 2.0)).collect();
        let t: Vec<f64> = (0..4).map(|_| g.range_f64(-2.0, 2.0)).collect();
        let want = e.eval(&x, &t);
        // VM runs in f32 — compare at f32 precision
        let got = eval_scalar(&prog, &x, &t);
        let (wf, gf) = (want as f32, got as f32);
        let ok = (wf.is_nan() && gf.is_nan())
            || (wf.is_infinite() && gf.is_infinite())
            || (gf - wf).abs() <= 1e-2 * wf.abs().max(1.0);
        assert!(ok, "{e}: vm={got} tree={want}");
    });
    assert!(tested > 200, "only {tested} programs compiled");
}

#[test]
fn compile_depth_never_exceeds_stack() {
    check(404, 300, |g| {
        let e = gen_expr(g, 5);
        if let Ok(p) = e.compile() {
            assert!(p.max_depth <= zmc::abi::STACK);
            assert!(p.len() <= zmc::abi::MAX_PROG);
        }
    });
}

#[test]
fn parse_rejects_random_mutations() {
    // valid source with one random character clobbered either still
    // parses or errors — never panics.
    check(505, 200, |g| {
        let mut src = String::from("sin(x1)*p0 + max(x2, 0.5)^2");
        let pos = g.below(src.len());
        let ch = *g.choose(&[b'$', b'(', b')', b'#', b'x', b'9', b'.']);
        // safety: all candidate bytes are ASCII
        unsafe { src.as_bytes_mut()[pos] = ch };
        let _ = Expr::parse(&src); // must not panic
    });
}
