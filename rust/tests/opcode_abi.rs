//! The rust opcode table must match the golden spec/opcodes.txt — the
//! same file `python/tests/test_opcode_abi.py` checks, which pins the
//! cross-language bytecode ABI.

use std::path::Path;

use zmc::vm::opcodes::{Kind, Op, ALL, N_OPS};

fn load_spec() -> Vec<(i32, String, String)> {
    let path =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("spec/opcodes.txt");
    let text = std::fs::read_to_string(path).expect("spec/opcodes.txt");
    let mut rows = Vec::new();
    for line in text.lines() {
        let line = line.split('#').next().unwrap().trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        rows.push((
            it.next().unwrap().parse().unwrap(),
            it.next().unwrap().to_string(),
            it.next().unwrap().to_string(),
        ));
    }
    rows
}

#[test]
fn table_matches_spec() {
    let spec = load_spec();
    assert_eq!(spec.len(), N_OPS, "spec row count");
    for (code, name, kind) in &spec {
        let op = Op::from_code(*code)
            .unwrap_or_else(|| panic!("code {code} missing in rust"));
        assert_eq!(op.name(), name, "name of code {code}");
        let want = match kind.as_str() {
            "nullary" => Kind::Nullary,
            "push" => Kind::Push,
            "unary" => Kind::Unary,
            "binary" => Kind::Binary,
            k => panic!("bad kind {k}"),
        };
        assert_eq!(op.kind(), want, "kind of {name}");
    }
}

#[test]
fn spec_codes_dense_and_complete() {
    let spec = load_spec();
    for (i, (code, ..)) in spec.iter().enumerate() {
        assert_eq!(*code, i as i32, "codes must be dense");
    }
    // every rust op appears in the spec
    for op in ALL {
        assert!(
            spec.iter().any(|(c, ..)| *c == op.code()),
            "{op:?} not in spec"
        );
    }
}

#[test]
fn manifest_nops_matches() {
    // if artifacts are built, their constant block must agree too
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    let j = zmc::util::json::Json::parse(&text).unwrap();
    assert_eq!(
        j.path(&["constants", "N_OPS"]).unwrap().as_i64(),
        Some(N_OPS as i64)
    );
}
