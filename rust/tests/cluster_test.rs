//! Cluster-layer merge verification on the CPU emulator backend:
//!
//! * **bit-exactness** — for any multifunction batch and any shard
//!   count 1..8, the cluster's merged `MomentSum`s and the final
//!   `Estimate`s are bit-identical to the 1-engine run over the same
//!   Philox counter ranges (shard planning preserves task order, so
//!   the floating-point merge sequence is identical, not just the
//!   sample set);
//! * **fault tolerance** — an engine whose workers die mid-round has
//!   its shard requeued onto the surviving engines, the job completes
//!   with the exact fault-free results, and the cluster `Metrics`
//!   records the retries;
//! * **adaptive parity** — Genz oscillatory/corner-peak batches hit
//!   the same `target_rel_err` with the same total sample spend
//!   (±1 round) on 1 vs 4 engines, because the Neyman allocation step
//!   stays centralized over merged moments.
//!
//! Emulator-only (`--features pjrt` skips: synthetic HLO bodies).
#![cfg(not(feature = "pjrt"))]

use std::sync::Arc;

use zmc::adaptive;
use zmc::cluster::{reduce_tagged, Cluster, DeviceCluster, LaunchExec};
use zmc::coordinator::fault::FaultPlan;
use zmc::coordinator::progress::Metrics;
use zmc::engine::{DeviceEngine, Engine};
use zmc::integrator::multifunctions::{self, MultiConfig};
use zmc::integrator::spec::{Estimate, IntegralJob};
use zmc::runtime::device::DevicePool;
use zmc::runtime::registry::Registry;
use zmc::util::proptest::{check, Gen};

fn engine() -> DeviceEngine {
    let reg = Arc::new(Registry::emulated());
    let pool = DevicePool::new(&reg, 1).unwrap();
    Engine::for_pool(&pool).unwrap()
}

fn cluster(n_engines: usize) -> DeviceCluster {
    let reg = Arc::new(Registry::emulated());
    let pool = DevicePool::new(&reg, 1).unwrap();
    DeviceCluster::for_pool(&pool, n_engines).unwrap()
}

/// Heterogeneous integrand pool (dims 1–3, smooth and peaked).
fn job_pool() -> Vec<IntegralJob> {
    let u1 = [(0.0, 1.0)];
    let u2 = [(0.0, 1.0), (0.0, 1.0)];
    let u3 = [(0.0, 1.0), (0.0, 1.0), (0.0, 1.0)];
    vec![
        IntegralJob::parse("x1^2 + 1", &u1).unwrap(),
        IntegralJob::parse("sin(x1)*x2", &u2).unwrap(),
        IntegralJob::with_params("exp(-p0*(x1+x2))", &u2, &[1.5]).unwrap(),
        IntegralJob::with_params(
            "1/(p0 + (x1-0.5)^2 + (x2-0.5)^2)",
            &u2,
            &[0.05],
        )
        .unwrap(),
        IntegralJob::parse("x1*x2*x3 + cos(x2)", &u3).unwrap(),
        IntegralJob::with_params("p0*abs(x1+x2-1)", &u2, &[2.0]).unwrap(),
    ]
}

fn assert_estimates_bit_identical(a: &[Estimate], b: &[Estimate], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.value.to_bits(),
            y.value.to_bits(),
            "{ctx}: fn {i} value {} vs {}",
            x.value,
            y.value
        );
        assert_eq!(
            x.std_err.to_bits(),
            y.std_err.to_bits(),
            "{ctx}: fn {i} std_err"
        );
        assert_eq!(x.n_samples, y.n_samples, "{ctx}: fn {i} n_samples");
        assert_eq!(x.rounds, y.rounds, "{ctx}: fn {i} rounds");
    }
}

/// The tentpole property: for a random batch and random sampling
/// config, every shard count 1..8 reproduces the single-engine
/// estimates bit-for-bit.
#[test]
fn cluster_estimates_bit_identical_for_shard_counts_1_to_8() {
    let pool = job_pool();
    let reference = engine();
    check(0xC1057E4, 5, |g: &mut Gen| {
        let n_jobs = 1 + g.below(pool.len());
        let first = g.below(pool.len());
        let jobs: Vec<IntegralJob> = (0..n_jobs)
            .map(|i| pool[(first + i) % pool.len()].clone())
            .collect();
        let cfg = MultiConfig {
            // 1–3 chunks per function block at 4096 samples/launch
            samples_per_fn: (1 + g.below(3)) << 12,
            seed: g.next_u64(),
            trial: g.below(4) as u32,
            stream_base: g.below(64) as u32,
            ..Default::default()
        };
        let base = multifunctions::integrate(&reference, &jobs, &cfg)
            .unwrap();
        for k in 1..=8usize {
            let c = cluster(k);
            let got = multifunctions::integrate(&c, &jobs, &cfg).unwrap();
            assert_estimates_bit_identical(
                &base,
                &got,
                &format!("{k} engines"),
            );
        }
    });
}

/// Same property one layer down: the merged `MomentSum`s coming out of
/// the centralized reducer are bit-identical for every shard count.
#[test]
fn merged_moment_sums_bit_identical_across_shard_counts() {
    let reg = Arc::new(Registry::emulated());
    let jobs = job_pool();
    let cfg = MultiConfig {
        samples_per_fn: 3 << 12,
        seed: 99,
        exe: Some("vm_multi_f8_s4096".into()),
        ..Default::default()
    };
    let (tasks, exe) =
        multifunctions::build_tasks(&reg, &jobs, &cfg).unwrap();
    assert!(tasks.len() >= 3, "want a multi-launch batch");
    let (n_fns, samples) = (exe.n_fns, exe.samples as u64);

    let outs = LaunchExec::submit_launches(&engine(), tasks.clone(), 3)
        .unwrap()
        .wait()
        .unwrap();
    let base = reduce_tagged(outs, n_fns, samples, jobs.len());
    assert!(base.iter().all(|m| m.n > 0));

    for k in 1..=8usize {
        let c = cluster(k);
        let outs = LaunchExec::submit_launches(&c, tasks.clone(), 3)
            .unwrap()
            .wait()
            .unwrap();
        let merged = reduce_tagged(outs, n_fns, samples, jobs.len());
        assert_eq!(base, merged, "{k} engines");
    }
}

/// A 1-engine cluster *is* the engine path (the plan is one shard over
/// the whole task list) — the CLI's `--num-engines 1` default changes
/// nothing.
#[test]
fn one_engine_cluster_is_the_engine_path() {
    let jobs = job_pool();
    let cfg = MultiConfig {
        samples_per_fn: 1 << 13,
        seed: 4242,
        ..Default::default()
    };
    let a = multifunctions::integrate(&engine(), &jobs, &cfg).unwrap();
    let b = multifunctions::integrate(&cluster(1), &jobs, &cfg).unwrap();
    assert_estimates_bit_identical(&a, &b, "1-engine cluster");
}

/// Kill one engine's workers mid-round: its shard must be requeued
/// onto the surviving engines, the batch must complete with the exact
/// fault-free results, and the cluster metrics must record the retry.
#[test]
fn engine_death_mid_round_requeues_shard_onto_survivors() {
    let jobs = job_pool()[..2].to_vec(); // 1 block of vm_multi rows
    let cfg = MultiConfig {
        // 9 chunks of 4096 → 9 launches → shards of 3 per engine
        samples_per_fn: 9 << 12,
        seed: 2021,
        exe: Some("vm_multi_f8_s4096".into()),
        ..Default::default()
    };
    let clean = multifunctions::integrate(&cluster(3), &jobs, &cfg)
        .unwrap();

    let reg = Arc::new(Registry::emulated());
    let pool = DevicePool::new(&reg, 1).unwrap();
    let mk = |fault: FaultPlan| {
        Engine::for_pool_with(
            &pool,
            3,
            Arc::new(fault),
            Arc::new(Metrics::new()),
        )
        .unwrap()
    };
    // engine 1's only worker dies after 2 attempts — mid-shard
    let engines = vec![
        mk(FaultPlan::none()),
        mk(FaultPlan::kill(0, 2)),
        mk(FaultPlan::none()),
    ];
    let metrics = Arc::new(Metrics::new());
    let c = Cluster::with_metrics(engines, Arc::clone(&metrics)).unwrap();

    let got = multifunctions::integrate(&c, &jobs, &cfg).unwrap();
    assert_estimates_bit_identical(&clean, &got, "after engine death");
    assert_eq!(c.n_alive(), 2, "dead engine must be retired");
    assert!(
        metrics.retried() >= 1,
        "cluster metrics must record the shard requeue: {}",
        metrics.summary()
    );
    assert_eq!(metrics.retried(), metrics.failed());
}

/// With every engine dead the failure surfaces instead of hanging.
#[test]
fn cluster_with_all_engines_dead_errors_out() {
    let reg = Arc::new(Registry::emulated());
    let pool = DevicePool::new(&reg, 1).unwrap();
    let engines = (0..2)
        .map(|_| {
            Engine::for_pool_with(
                &pool,
                3,
                Arc::new(FaultPlan::kill(0, 0)),
                Arc::new(Metrics::new()),
            )
            .unwrap()
        })
        .collect();
    let c = Cluster::from_engines(engines).unwrap();
    let jobs = job_pool()[..1].to_vec();
    let cfg = MultiConfig {
        samples_per_fn: 4 << 12,
        exe: Some("vm_multi_f8_s4096".into()),
        ..Default::default()
    };
    let err = match multifunctions::submit(&c, &jobs, &cfg) {
        Ok(h) => match h.wait() {
            Ok(_) => panic!("dead cluster must not produce results"),
            Err(e) => e,
        },
        Err(e) => e,
    };
    assert!(
        err.to_string().contains("no live engines"),
        "unexpected error: {err}"
    );
}

/// Genz oscillatory + corner-peak batches: the adaptive driver on a
/// 4-engine cluster must hit the same `target_rel_err` with the same
/// total sample spend (±1 round) as on 1 engine — allocation is
/// centralized, only the sampling fans out.
#[test]
fn adaptive_on_cluster_converges_with_equal_spend() {
    let u2 = [(0.0, 1.0), (0.0, 1.0)];
    let mut jobs = Vec::new();
    // oscillatory: cos(2πu + c1·x1 + c2·x2) at rising frequency
    // (scales kept moderate so |I| stays O(1) and the relative target
    // is reachable inside the budget)
    for scale in [1.0, 2.0] {
        jobs.push(
            IntegralJob::with_params(
                "cos(2*pi*p0 + p1*x1 + p2*x2)",
                &u2,
                &[0.25, scale * 1.3, scale * 0.7],
            )
            .unwrap(),
        );
    }
    // corner peak: (1 + c1·x1 + c2·x2)^-(d+1)
    for scale in [1.0, 3.0] {
        jobs.push(
            IntegralJob::with_params(
                "1/(1 + p0*x1 + p1*x2)^3",
                &u2,
                &[scale, scale * 0.6],
            )
            .unwrap(),
        );
    }
    let cfg = MultiConfig {
        samples_per_fn: 1 << 17,
        seed: 777,
        target_rel_err: Some(1e-2),
        ..Default::default()
    };
    let (e1, r1) =
        adaptive::integrate_with_report(&cluster(1), &jobs, &cfg).unwrap();
    let (e4, r4) =
        adaptive::integrate_with_report(&cluster(4), &jobs, &cfg).unwrap();

    for (i, e) in e1.iter().chain(e4.iter()).enumerate() {
        assert!(
            e.std_err <= 1e-2 * e.value.abs(),
            "fn {i} missed target: {e:?}"
        );
    }
    assert_eq!(r1.converged, jobs.len());
    assert_eq!(r4.converged, jobs.len());
    // same centralized allocation → same spend, same round structure
    assert_eq!(
        r1.total_samples, r4.total_samples,
        "sample spend must not depend on the engine count"
    );
    assert!(
        (r1.rounds as i64 - r4.rounds as i64).abs() <= 1,
        "rounds diverged: {} vs {}",
        r1.rounds,
        r4.rounds
    );
    assert_estimates_bit_identical(&e1, &e4, "adaptive 1 vs 4 engines");
}

/// Concurrent batches from multiple threads shard onto the same
/// cluster and each resolves to its own exact result (the engine-level
/// concurrency contract survives the cluster layer).
#[test]
fn concurrent_batches_on_one_cluster() {
    let c = Arc::new(cluster(3));
    let jobs = Arc::new(job_pool());
    let expected: Vec<Vec<Estimate>> = (0..4u64)
        .map(|t| {
            let cfg = MultiConfig {
                samples_per_fn: 1 << 12,
                seed: 1000 + t,
                ..Default::default()
            };
            multifunctions::integrate(&engine(), &jobs, &cfg).unwrap()
        })
        .collect();
    let handles: Vec<_> = (0..4u64)
        .map(|t| {
            let (c, jobs) = (Arc::clone(&c), Arc::clone(&jobs));
            std::thread::spawn(move || {
                let cfg = MultiConfig {
                    samples_per_fn: 1 << 12,
                    seed: 1000 + t,
                    ..Default::default()
                };
                multifunctions::integrate(&*c, &jobs, &cfg).unwrap()
            })
        })
        .collect();
    for (t, h) in handles.into_iter().enumerate() {
        let got = h.join().unwrap();
        assert_estimates_bit_identical(
            &expected[t],
            &got,
            &format!("thread {t}"),
        );
    }
}
