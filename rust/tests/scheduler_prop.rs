//! Scheduler invariants under randomized topology and faults.
//!
//! The coordinator's core guarantee: results are a pure function of the
//! task list — invariant to worker count, scheduling order, transient
//! failures and worker deaths (Philox addressing makes launches
//! idempotent; accumulator merge is commutative).

use std::sync::atomic::{AtomicU64, Ordering};

use zmc::coordinator::fault::FaultPlan;
use zmc::coordinator::progress::Metrics;
use zmc::coordinator::scheduler::Scheduler;
use zmc::util::proptest::{check, Gen};

/// A mock "launch": deterministic function of the task payload.
fn mock_launch(task: u64) -> u64 {
    task.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(17)
}

#[test]
fn results_invariant_to_worker_count() {
    let tasks: Vec<u64> = (0..200).collect();
    let baseline: Vec<u64> = tasks.iter().map(|&t| mock_launch(t)).collect();
    for workers in [1, 2, 3, 7, 16] {
        let s = Scheduler::new(workers);
        let out = s
            .run(
                tasks.clone(),
                &FaultPlan::none(),
                &Metrics::new(),
                |_| Ok(()),
                |_, &t| Ok(mock_launch(t)),
            )
            .unwrap();
        assert_eq!(out, baseline, "workers={workers}");
    }
}

#[test]
fn results_invariant_under_random_faults() {
    let tasks: Vec<u64> = (0..120).collect();
    let baseline: Vec<u64> = tasks.iter().map(|&t| mock_launch(t)).collect();
    check(42, 40, |g: &mut Gen| {
        let workers = 1 + g.below(6);
        let fault = match g.below(3) {
            0 => FaultPlan::none(),
            1 => FaultPlan::transient(2 + g.below(9) as u64),
            // killing a worker is only survivable with peers left
            _ if workers >= 2 => {
                FaultPlan::kill(g.below(workers), g.below(30) as u64)
            }
            _ => FaultPlan::transient(3),
        };
        let m = Metrics::new();
        let s = Scheduler { n_workers: workers, max_retries: 10 };
        let out = s
            .run(
                tasks.clone(),
                &fault,
                &m,
                |_| Ok(()),
                |_, &t| Ok(mock_launch(t)),
            )
            .unwrap();
        assert_eq!(out, baseline);
        assert_eq!(m.done(), 120);
    });
}

#[test]
fn every_task_executed_exactly_once_when_fault_free() {
    // count executions with an atomic; no dedup in the mock — proves the
    // scheduler itself never double-runs a succeeding task.
    check(77, 20, |g: &mut Gen| {
        let n_tasks = 1 + g.below(300);
        let workers = 1 + g.below(8);
        let counter = AtomicU64::new(0);
        let s = Scheduler::new(workers);
        let out = s
            .run(
                (0..n_tasks as u64).collect(),
                &FaultPlan::none(),
                &Metrics::new(),
                |_| Ok(()),
                |_, &t| {
                    counter.fetch_add(1, Ordering::Relaxed);
                    Ok(t)
                },
            )
            .unwrap();
        assert_eq!(out.len(), n_tasks);
        assert_eq!(counter.load(Ordering::Relaxed), n_tasks as u64);
    });
}

#[test]
fn retries_counted_and_bounded() {
    let m = Metrics::new();
    let s = Scheduler { n_workers: 2, max_retries: 5 };
    // every 4th attempt fails: 100 tasks → ~33 retries, all succeed
    let out = s
        .run(
            (0..100u64).collect(),
            &FaultPlan::transient(4),
            &m,
            |_| Ok(()),
            |_, &t| Ok(t),
        )
        .unwrap();
    assert_eq!(out.len(), 100);
    assert!(m.retried() >= 20, "retries={}", m.retried());
    assert_eq!(m.failed(), m.retried()); // every failure was retried
}

#[test]
fn all_workers_dead_reports_failure() {
    // kill worker 0 (the only worker) immediately: tasks never run
    let s = Scheduler::new(1);
    let err = s
        .run(
            vec![1u64, 2, 3],
            &FaultPlan::kill(0, 0),
            &Metrics::new(),
            |_| Ok(()),
            |_, &t| Ok(t),
        )
        .unwrap_err();
    assert!(
        err.to_string().contains("unfinished"),
        "unexpected error: {err}"
    );
}

#[test]
fn moment_merge_worker_invariance_end_to_end() {
    // simulate the integrator's merge: partial sums from tasks merged in
    // completion order must equal serial accumulation (commutativity).
    use zmc::stats::MomentSum;
    let tasks: Vec<u64> = (0..64).collect();
    let serial = {
        let mut m = MomentSum::new();
        for &t in &tasks {
            let v = (t as f64 * 0.618).sin();
            m.merge(&MomentSum { n: 100, sum: v, sumsq: v * v });
        }
        m
    };
    for workers in [1, 4, 8] {
        let s = Scheduler::new(workers);
        let outs = s
            .run(
                tasks.clone(),
                &FaultPlan::none(),
                &Metrics::new(),
                |_| Ok(()),
                |_, &t| {
                    let v = (t as f64 * 0.618).sin();
                    Ok(MomentSum { n: 100, sum: v, sumsq: v * v })
                },
            )
            .unwrap();
        let mut merged = MomentSum::new();
        for m in &outs {
            merged.merge(m);
        }
        assert_eq!(merged.n, serial.n);
        assert!((merged.sum - serial.sum).abs() < 1e-12);
        assert!((merged.sumsq - serial.sumsq).abs() < 1e-12);
    }
}
