//! Multi-host cluster transport verification over loopback TCP:
//!
//! * **wire codec** — random `LaunchTask`/`TaggedOutput` frames survive
//!   the byte round trip losslessly (floats as raw IEEE-754 bits, so
//!   NaN payloads included), and every corruption — truncation, bad
//!   magic, unknown version, unknown tag, oversized length prefix,
//!   trailing bytes — rejects with the matching typed [`WireError`];
//! * **bit-exactness** — for shard counts 1..8, a pure-remote cluster
//!   (k proxies into one `zmc worker` loop) and a mixed cluster
//!   (1 local engine + k remotes) reproduce the single-engine
//!   `Estimate`s and merged `MomentSum`s bit-for-bit, for all three
//!   integration classes (multifunction batch, functional grid scan,
//!   normal tree search);
//! * **fault tolerance** — a worker host killed mid-round (and a hung
//!   host caught only by the heartbeat) has its whole shard requeued
//!   onto a survivor, the batch completes with the exact fault-free
//!   results, and the cluster `Metrics` records the requeue;
//! * **dispatch hygiene** — empty shards (more nodes than tasks) never
//!   reach a worker (`WorkerStats::empty_submits` stays 0).
//!
//! Emulator-only (`--features pjrt` skips: synthetic HLO bodies, and
//! the emulated registry is what makes the remote side deterministic).
#![cfg(not(feature = "pjrt"))]

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use zmc::cluster::{
    reduce_tagged, serve_worker, DeviceCluster, Frame, LaunchExec,
    RemoteConfig, Wire, WireError, WorkerServer,
};
use zmc::engine::{DeviceEngine, Engine, LaunchTask, TaggedOutput};
use zmc::integrator::multifunctions::{self, MultiConfig};
use zmc::integrator::normal::{self, NormalConfig};
use zmc::integrator::spec::{Estimate, IntegralJob};
use zmc::integrator::functional;
use zmc::runtime::device::DevicePool;
use zmc::runtime::launch::Value;
use zmc::runtime::registry::Registry;
use zmc::session::Session;
use zmc::util::proptest::{check, Gen};

type DeviceFrame = Frame<LaunchTask, TaggedOutput>;

// ------------------------------------------------------------ fixtures

fn emulated_pool() -> DevicePool {
    let reg = Arc::new(Registry::emulated());
    DevicePool::new(&reg, 1).unwrap()
}

fn engine() -> DeviceEngine {
    Engine::for_pool(&emulated_pool()).unwrap()
}

/// A worker host on an ephemeral loopback port, serving a 1-worker
/// emulated device engine. The emulated registry is a pure function of
/// the build, so its results are bit-identical to any local engine's.
fn worker() -> WorkerServer {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    serve_worker(listener, engine()).unwrap()
}

/// Transport tuning for tests: fast heartbeats, fail fast.
fn fast_rcfg() -> RemoteConfig {
    RemoteConfig {
        ping_interval: Duration::from_millis(20),
        ping_timeout: Duration::from_millis(400),
        ..Default::default()
    }
}

/// `n_local` in-process engines + one proxy per address, short
/// heartbeats.
fn cluster_with(n_local: usize, addrs: &[String]) -> DeviceCluster {
    DeviceCluster::for_pool_with_remote_config(
        &emulated_pool(),
        n_local,
        addrs,
        fast_rcfg(),
    )
    .unwrap()
}

fn job_pool() -> Vec<IntegralJob> {
    let u1 = [(0.0, 1.0)];
    let u2 = [(0.0, 1.0), (0.0, 1.0)];
    let u3 = [(0.0, 1.0), (0.0, 1.0), (0.0, 1.0)];
    vec![
        IntegralJob::parse("x1^2 + 1", &u1).unwrap(),
        IntegralJob::parse("sin(x1)*x2", &u2).unwrap(),
        IntegralJob::with_params("exp(-p0*(x1+x2))", &u2, &[1.5]).unwrap(),
        IntegralJob::parse("x1*x2*x3 + cos(x2)", &u3).unwrap(),
    ]
}

fn assert_estimates_bit_identical(
    a: &[Estimate],
    b: &[Estimate],
    ctx: &str,
) {
    assert_eq!(a.len(), b.len(), "{ctx}");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.value.to_bits(),
            y.value.to_bits(),
            "{ctx}: fn {i} value {} vs {}",
            x.value,
            y.value
        );
        assert_eq!(
            x.std_err.to_bits(),
            y.std_err.to_bits(),
            "{ctx}: fn {i} std_err"
        );
        assert_eq!(x.n_samples, y.n_samples, "{ctx}: fn {i} n_samples");
    }
}

// ----------------------------------------------------------- the codec

fn random_value(g: &mut Gen) -> Value {
    let n = g.below(5);
    match g.below(3) {
        // arbitrary bit patterns: the codec must be lossless even for
        // NaN/Inf payloads, so equality is asserted on re-encoded bytes
        0 => Value::F32(
            (0..n).map(|_| f32::from_bits(g.next_u32())).collect(),
        ),
        1 => Value::I32((0..n).map(|_| g.next_u32() as i32).collect()),
        _ => Value::U32((0..n).map(|_| g.next_u32()).collect()),
    }
}

fn random_task(g: &mut Gen) -> LaunchTask {
    LaunchTask {
        exe: format!("vm_multi_f8_s{}", 1 << (10 + g.below(4))),
        tag: g.next_u64(),
        inputs: (0..g.below(4)).map(|_| random_value(g)).collect(),
    }
}

fn random_out(g: &mut Gen) -> TaggedOutput {
    TaggedOutput {
        tag: g.next_u64(),
        data: (0..g.below(6))
            .map(|_| f32::from_bits(g.next_u32()))
            .collect(),
        device_time: Duration::from_nanos(g.next_u64() >> 20),
    }
}

#[test]
fn wire_frames_round_trip_losslessly() {
    check(0x31BE_C0DE, 40, |g: &mut Gen| {
        let frame: DeviceFrame = match g.below(6) {
            0 => Frame::Ping { nonce: g.next_u64() },
            1 => Frame::Pong { nonce: g.next_u64() },
            2 => Frame::Submit {
                id: g.next_u64(),
                max_retries: g.next_u32() % 8,
                tasks: (0..g.below(4)).map(|_| random_task(g)).collect(),
            },
            3 => Frame::Result {
                id: g.next_u64(),
                outs: (0..g.below(4)).map(|_| random_out(g)).collect(),
            },
            4 => Frame::Error {
                id: g.next_u64(),
                msg: "worker 0: bad artifact ✗".to_string(),
            },
            _ => Frame::Cancel { id: g.next_u64() },
        };
        let bytes = frame.to_bytes();
        let back = DeviceFrame::from_bytes(&bytes).unwrap();
        // byte-level equality is NaN-proof and asserts the encoding
        // itself is canonical (decode ∘ encode = identity on bytes)
        assert_eq!(back.to_bytes(), bytes);
    });
}

#[test]
fn bare_wire_values_round_trip() {
    check(0x57A7_10AD, 40, |g: &mut Gen| {
        let task = random_task(g);
        let mut buf = Vec::new();
        task.encode(&mut buf);
        let mut r = zmc::cluster::wire::Reader::new(&buf);
        let back = LaunchTask::decode(&mut r).unwrap();
        assert_eq!(r.remaining(), 0);
        let mut buf2 = Vec::new();
        back.encode(&mut buf2);
        assert_eq!(buf2, buf);
    });
}

#[test]
fn corrupt_frames_reject_with_typed_errors() {
    let frame: DeviceFrame = Frame::Submit {
        id: 7,
        max_retries: 3,
        tasks: vec![LaunchTask {
            exe: "vm_multi_f8_s4096".into(),
            tag: 42,
            inputs: vec![Value::F32(vec![1.0, -0.5])],
        }],
    };
    let bytes = frame.to_bytes();

    // every strict prefix is a truncation, never a panic or a garbage
    // decode
    for cut in 0..bytes.len() {
        match DeviceFrame::from_bytes(&bytes[..cut]) {
            Err(WireError::Truncated { .. }) => {}
            other => panic!("prefix {cut}: expected Truncated, got {other:?}"),
        }
    }

    // bad magic
    let mut b = bytes.clone();
    b[0] = b'X';
    assert!(matches!(
        DeviceFrame::from_bytes(&b),
        Err(WireError::BadMagic { got }) if got[0] == b'X'
    ));

    // unknown version
    let mut b = bytes.clone();
    b[4] = 0x77;
    b[5] = 0x77;
    assert_eq!(
        DeviceFrame::from_bytes(&b),
        Err(WireError::BadVersion { got: 0x7777 })
    );

    // unknown message type
    let mut b = bytes.clone();
    b[6] = 99;
    assert_eq!(
        DeviceFrame::from_bytes(&b),
        Err(WireError::BadTag { got: 99 })
    );

    // oversized length prefix is corruption, not an allocation request
    let mut b = bytes.clone();
    b[7..11].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(matches!(
        DeviceFrame::from_bytes(&b),
        Err(WireError::TooLarge { .. })
    ));

    // trailing bytes after the declared payload
    let mut b = bytes.clone();
    b.push(0);
    assert_eq!(
        DeviceFrame::from_bytes(&b),
        Err(WireError::Trailing { extra: 1 })
    );
}

#[test]
fn stream_reads_type_eof_and_truncation() {
    use std::io::Cursor;
    // clean EOF at a frame boundary is not an error: Ok(None)
    let mut empty = Cursor::new(Vec::<u8>::new());
    assert!(DeviceFrame::read_from(&mut empty).unwrap().is_none());

    // EOF mid-frame is a typed truncation, recoverable through anyhow
    let frame: DeviceFrame = Frame::Ping { nonce: 0xDEAD };
    let bytes = frame.to_bytes();
    for cut in [3, 7, bytes.len() - 1] {
        let mut half = Cursor::new(bytes[..cut].to_vec());
        let err = DeviceFrame::read_from(&mut half).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<WireError>(),
                Some(WireError::Truncated { .. })
            ),
            "cut {cut}: {err:#}"
        );
    }

    // two frames back to back parse in order, then a clean EOF
    let mut two = frame.to_bytes();
    two.extend_from_slice(&DeviceFrame::to_bytes(&Frame::Cancel {
        id: 5,
    }));
    let mut rd = Cursor::new(two);
    assert!(matches!(
        DeviceFrame::read_from(&mut rd).unwrap(),
        Some(Frame::Ping { nonce: 0xDEAD })
    ));
    assert!(matches!(
        DeviceFrame::read_from(&mut rd).unwrap(),
        Some(Frame::Cancel { id: 5 })
    ));
    assert!(DeviceFrame::read_from(&mut rd).unwrap().is_none());
}

// ------------------------------------------------- bit-identity sweeps

/// The tentpole property: pure-remote and mixed clusters reproduce the
/// single-engine multifunction estimates AND the merged `MomentSum`s
/// bit-for-bit at every shard count 1..8. One worker process backs all
/// the proxies — placement is free, so fanning k shards into the same
/// host is indistinguishable from k hosts.
#[test]
fn remote_and_mixed_clusters_bit_identical_for_shard_counts_1_to_8() {
    let jobs = job_pool();
    let cfg = MultiConfig {
        // 9 launches of 4096 samples → shards stay non-trivial up to 8
        samples_per_fn: 9 << 12,
        seed: 20_26,
        exe: Some("vm_multi_f8_s4096".into()),
        ..Default::default()
    };
    let reference = engine();
    let base = multifunctions::integrate(&reference, &jobs, &cfg).unwrap();

    let reg = Arc::new(Registry::emulated());
    let (tasks, exe) =
        multifunctions::build_tasks(&reg, &jobs, &cfg).unwrap();
    let (n_fns, samples) = (exe.n_fns, exe.samples as u64);
    let outs = LaunchExec::submit_launches(&reference, tasks.clone(), 3)
        .unwrap()
        .wait()
        .unwrap();
    let base_moments = reduce_tagged(outs, n_fns, samples, jobs.len());

    let w = worker();
    let addr = w.addr().to_string();
    for k in 1..=8usize {
        // pure remote: k proxies, no local engine at all
        let remote = cluster_with(0, &vec![addr.clone(); k]);
        assert_eq!((remote.n_local(), remote.n_remote()), (0, k));
        let got =
            multifunctions::integrate(&remote, &jobs, &cfg).unwrap();
        assert_estimates_bit_identical(
            &base,
            &got,
            &format!("{k} remote shards"),
        );

        // mixed: 1 local + k remotes
        let mixed = cluster_with(1, &vec![addr.clone(); k]);
        assert_eq!((mixed.n_local(), mixed.n_remote()), (1, k));
        let got = multifunctions::integrate(&mixed, &jobs, &cfg).unwrap();
        assert_estimates_bit_identical(
            &base,
            &got,
            &format!("1 local + {k} remote shards"),
        );

        // one layer down: the merged moments match exactly too
        let outs = LaunchExec::submit_launches(&mixed, tasks.clone(), 3)
            .unwrap()
            .wait()
            .unwrap();
        let merged = reduce_tagged(outs, n_fns, samples, jobs.len());
        assert_eq!(base_moments, merged, "moments at {k} remotes");
    }
    assert_eq!(w.stats().empty_submits.load(Ordering::Relaxed), 0);
}

/// The other two paper classes ride the same `LaunchExec` surface:
/// a functional grid scan and a normal tree search are bit-identical
/// on local, pure-remote, and mixed topologies.
#[test]
fn functional_and_normal_classes_bit_identical_over_remote() {
    let w = worker();
    let addr = w.addr().to_string();
    let local = engine();
    let remote = cluster_with(0, &vec![addr.clone(); 2]);
    let mixed = cluster_with(1, &vec![addr.clone(); 2]);

    // functional: one integrand over a 6-point parameter grid
    let u2 = [(0.0, 1.0), (0.0, 1.0)];
    let job =
        IntegralJob::with_params("cos(p0*(x1+x2)) + p1*x1", &u2, &[1.0, 0.5])
            .unwrap();
    let thetas: Vec<Vec<f64>> = [0.5, 1.0, 2.0]
        .iter()
        .flat_map(|&a| [[a, 0.25], [a, 0.75]])
        .map(|t| t.to_vec())
        .collect();
    let cfg = MultiConfig {
        samples_per_fn: 2 << 12,
        seed: 909,
        ..Default::default()
    };
    let base = functional::scan(&local, &job, &thetas, &cfg).unwrap();
    for (exec, ctx) in [
        (&remote as &dyn LaunchExec, "pure remote"),
        (&mixed as &dyn LaunchExec, "mixed"),
    ] {
        let got = functional::scan(exec, &job, &thetas, &cfg).unwrap();
        assert_estimates_bit_identical(&base, &got, ctx);
    }

    // normal: stratified sampling + tree search
    let ncfg = NormalConfig {
        initial_divisions: 3,
        n_trials: 3,
        max_depth: 1,
        seed: 1717,
        ..Default::default()
    };
    let job = IntegralJob::parse("sin(x1)*x2 + 1", &u2).unwrap();
    let base = normal::integrate(&local, &job, &ncfg).unwrap();
    for (exec, ctx) in [
        (&remote as &dyn LaunchExec, "pure remote"),
        (&mixed as &dyn LaunchExec, "mixed"),
    ] {
        let got = normal::integrate(exec, &job, &ncfg).unwrap();
        assert_eq!(
            base.estimate.value.to_bits(),
            got.estimate.value.to_bits(),
            "{ctx}: estimate"
        );
        assert_eq!(
            base.estimate.std_err.to_bits(),
            got.estimate.std_err.to_bits(),
            "{ctx}: std_err"
        );
        assert_eq!(base.cubes_per_level, got.cubes_per_level, "{ctx}");
        assert_eq!(base.flagged_per_level, got.flagged_per_level, "{ctx}");
        assert_eq!(base.launches, got.launches, "{ctx}");
    }
}

/// End-to-end through the Session facade: `.remote_engines([addr])`
/// builds a mixed cluster, the topology accessors report it, and the
/// fluent-builder results match an all-local session bit-for-bit.
#[test]
fn session_remote_engines_end_to_end() {
    let w = worker();
    let local = Session::builder().emulated().build().unwrap();
    let s = Session::builder()
        .emulated()
        .remote_engines([w.addr().to_string()])
        .build()
        .unwrap();
    assert_eq!(s.num_engines(), 2, "1 local + 1 remote");
    assert_eq!(s.num_remote_engines(), 1);
    assert!(s.cluster().is_some());
    assert_eq!(s.cluster().unwrap().n_remote(), 1);

    let jobs = job_pool();
    let base = local
        .multifunctions(&jobs)
        .samples(4 << 12)
        .seed(31)
        .run()
        .unwrap();
    let got =
        s.multifunctions(&jobs).samples(4 << 12).seed(31).run().unwrap();
    assert_estimates_bit_identical(&base, &got, "session remote");
}

// ------------------------------------------------------- fault paths

/// Kill the worker host mid-round: its shard must be requeued onto the
/// local survivor, the batch must complete with the exact fault-free
/// results, and the cluster metrics must record the requeue.
#[test]
fn worker_host_killed_mid_round_requeues_shard_exactly() {
    let jobs = job_pool();
    let cfg = MultiConfig {
        samples_per_fn: 16 << 12,
        seed: 40_40,
        exe: Some("vm_multi_f8_s4096".into()),
        ..Default::default()
    };
    let clean = multifunctions::integrate(&engine(), &jobs, &cfg).unwrap();

    let w = worker();
    let c = cluster_with(1, &[w.addr().to_string()]);
    let handle = multifunctions::submit(&c, &jobs, &cfg).unwrap();
    // the remote shard (8 launches) is in flight now; severing the
    // connection forces the whole-shard requeue path. If the shard
    // somehow races to completion first the submit-side path of a
    // *later* batch would count instead, so assert on the requeue
    // metrics rather than the interleaving.
    w.kill();
    let got = handle.wait().unwrap();
    assert_estimates_bit_identical(&clean, &got, "after worker kill");
    assert_eq!(c.n_alive(), 1, "dead remote node must be retired");
    assert!(
        c.metrics().retried() >= 1,
        "cluster metrics must record the shard requeue: {}",
        c.metrics().summary()
    );
}

/// A hung host — TCP accepted, then silence — is caught by the
/// heartbeat (no pong within `ping_timeout`), not by a socket error,
/// and feeds the same requeue path with the same exact results.
#[test]
fn hung_host_heartbeat_timeout_feeds_requeue() {
    // a listener that accepts and then never reads nor writes
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let held: Arc<Mutex<Vec<TcpStream>>> = Arc::default();
    let sink = Arc::clone(&held);
    std::thread::spawn(move || {
        for conn in listener.incoming().flatten() {
            sink.lock().unwrap().push(conn);
        }
    });

    let jobs = job_pool();
    let cfg = MultiConfig {
        samples_per_fn: 4 << 12,
        seed: 51_51,
        exe: Some("vm_multi_f8_s4096".into()),
        ..Default::default()
    };
    let clean = multifunctions::integrate(&engine(), &jobs, &cfg).unwrap();

    let c = cluster_with(1, &[addr]);
    assert_eq!(c.n_alive(), 2);
    let got = multifunctions::integrate(&c, &jobs, &cfg).unwrap();
    assert_estimates_bit_identical(&clean, &got, "after heartbeat death");
    assert_eq!(c.n_alive(), 1, "hung node must be declared dead");
    assert!(
        c.metrics().retried() >= 1,
        "heartbeat death must be a counted requeue: {}",
        c.metrics().summary()
    );
    drop(held);
}

/// More nodes than tasks: the empty shards are skipped at dispatch and
/// no zero-task submit ever crosses the wire.
#[test]
fn empty_shards_never_reach_the_worker() {
    let jobs = job_pool()[..2].to_vec();
    let cfg = MultiConfig {
        // 2 launches over a 5-node cluster → 3 empty shards
        samples_per_fn: 2 << 12,
        seed: 7,
        exe: Some("vm_multi_f8_s4096".into()),
        ..Default::default()
    };
    let reg = Arc::new(Registry::emulated());
    let (tasks, _) = multifunctions::build_tasks(&reg, &jobs, &cfg).unwrap();
    assert_eq!(tasks.len(), 2);

    let w = worker();
    let c = cluster_with(1, &vec![w.addr().to_string(); 4]);
    assert_eq!(c.n_engines(), 5);
    let h = c.submit_with_retries(tasks, 3).unwrap();
    assert_eq!(h.n_shards(), 2, "only non-empty shards dispatched");
    assert_eq!(h.wait().unwrap().len(), 2);
    assert_eq!(w.stats().empty_submits.load(Ordering::Relaxed), 0);
    assert!(w.stats().submits.load(Ordering::Relaxed) >= 1);
}
