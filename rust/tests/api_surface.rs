//! Golden snapshot of the crate's public API surface.
//!
//! A deliberately simple, `syn`-free text scan: every line in
//! `src/**/*.rs` (excluding the `main.rs` binary) that declares a
//! `pub` item is extracted — name only, cut before any signature
//! detail — prefixed with its file path, sorted, and compared against
//! the checked-in `tests/api_surface_golden.txt`. Accidental surface
//! breaks (a renamed builder method, a dropped re-export, a module
//! made private) fail CI with a readable diff.
//!
//! Scanning rules (mirrored by the blessing path — keep them boring):
//! * a trimmed line equal to `#[cfg(test)]` ends the file's scan (the
//!   repo convention puts the test module last);
//! * `pub use` entries keep everything before the `;` (or the whole
//!   line for multi-line imports);
//! * other items are cut at the first `(`, `{`, `<`, `=` or `;`.
//!
//! After an intentional API change, re-bless and review the diff:
//!
//! ```text
//! ZMC_BLESS=1 cargo test --test api_surface
//! git diff rust/tests/api_surface_golden.txt
//! ```

use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};

const PREFIXES: [&str; 9] = [
    "pub fn ",
    "pub struct ",
    "pub enum ",
    "pub trait ",
    "pub mod ",
    "pub const ",
    "pub type ",
    "pub use ",
    "pub static ",
];

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    for entry in fs::read_dir(dir).unwrap() {
        let p = entry.unwrap().path();
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
}

/// The stable text of one declaration line.
fn item_of(t: &str) -> String {
    if t.starts_with("pub use ") {
        match t.find(';') {
            Some(i) => t[..i].trim_end().to_string(),
            None => t.trim_end().to_string(),
        }
    } else {
        let cut = t
            .char_indices()
            .find(|(_, c)| matches!(c, '(' | '{' | '<' | '=' | ';'))
            .map(|(i, _)| i)
            .unwrap_or(t.len());
        t[..cut].trim_end().to_string()
    }
}

/// Every `pub` declaration in the library source, sorted.
fn surface() -> Vec<String> {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut files = Vec::new();
    collect_rs(&root, &mut files);
    let mut rels: Vec<String> = files
        .iter()
        .map(|f| {
            f.strip_prefix(&root)
                .unwrap()
                .to_string_lossy()
                .replace('\\', "/")
        })
        .collect();
    rels.sort();
    let mut items = Vec::new();
    for rel in &rels {
        if rel == "main.rs" {
            continue; // the binary is not library surface
        }
        let text = fs::read_to_string(root.join(rel)).unwrap();
        for line in text.lines() {
            let t = line.trim();
            if t == "#[cfg(test)]" {
                break; // test module ends the file by convention
            }
            if PREFIXES.iter().any(|p| t.starts_with(p)) {
                items.push(format!("{rel}: {}", item_of(t)));
            }
        }
    }
    items.sort();
    items
}

#[test]
fn public_api_surface_matches_golden() {
    let golden_path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/api_surface_golden.txt");
    let actual = surface();
    assert!(
        actual.iter().any(|l| l.contains("session/mod.rs: pub fn builder")),
        "scanner failed to see the session module — rules drifted?"
    );
    if std::env::var("ZMC_BLESS").is_ok() {
        fs::write(&golden_path, actual.join("\n") + "\n").unwrap();
        return;
    }
    let golden_text =
        fs::read_to_string(&golden_path).unwrap_or_default();
    let golden: Vec<String> = golden_text
        .lines()
        .filter(|l| !l.is_empty())
        .map(str::to_string)
        .collect();
    if golden != actual {
        let gset: BTreeSet<&String> = golden.iter().collect();
        let aset: BTreeSet<&String> = actual.iter().collect();
        let mut msg = String::new();
        for miss in gset.difference(&aset) {
            msg.push_str(&format!("- removed: {miss}\n"));
        }
        for add in aset.difference(&gset) {
            msg.push_str(&format!("+ added:   {add}\n"));
        }
        panic!(
            "public API surface changed ({} -> {} items):\n{msg}\
             If intentional, re-bless with\n  \
             ZMC_BLESS=1 cargo test --test api_surface\n\
             and review the diff of tests/api_surface_golden.txt",
            golden.len(),
            actual.len()
        );
    }
}
