//! Runtime integration: execute every artifact kind from rust and
//! cross-check outputs against the in-process CPU implementations —
//! the rust-side half of the kernel-vs-oracle contract (the python half
//! is python/tests/test_kernel.py).
//!
//! With `--features pjrt` this requires `make artifacts` (skips
//! gracefully if missing); the default build falls back to the CPU
//! emulator registry, which pins the emulator to the same launch-input
//! packing and stream addressing the oracle uses.

use std::path::Path;
use std::sync::Arc;

use zmc::expr::Expr;
use zmc::runtime::device::DeviceRuntime;
use zmc::runtime::launch::{
    harmonic_inputs, stratified_inputs, vm_multi_inputs, RngCtr, VmFn,
};
use zmc::runtime::registry::Registry;
use zmc::sampler::StreamKey;
use zmc::vm::interp::eval_scalar;

fn registry() -> Option<Arc<Registry>> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        return Some(Arc::new(Registry::load(dir).unwrap()));
    }
    if cfg!(feature = "pjrt") {
        eprintln!("skipping: run `make artifacts` first");
        None
    } else {
        Some(Arc::new(Registry::emulated()))
    }
}

/// CPU mirror of one vm_multi launch row: same Philox stream, same
/// bytecode, f64 accumulation.
fn cpu_vm_sums(
    f: &VmFn,
    samples: usize,
    seed: [u32; 2],
    base: u32,
    trial: u32,
) -> (f64, f64) {
    let key = StreamKey {
        seed,
        stream: f.stream,
        trial,
    };
    let dims = f.bounds.len();
    let (mut s, mut q) = (0f64, 0f64);
    for i in 0..samples {
        let u = key.point(base.wrapping_add(i as u32), dims);
        let x: Vec<f64> = (0..dims)
            .map(|d| {
                let (lo, hi) = f.bounds[d];
                // device does the affine map in f32 — mirror it
                (lo as f32 + (hi - lo) as f32 * u[d]) as f64
            })
            .collect();
        let v = eval_scalar(&f.program, &x, &f.theta) as f32 as f64;
        s += v;
        q += v * v;
    }
    (s, q)
}

#[test]
fn vm_multi_artifact_matches_cpu_bit_path() {
    let Some(reg) = registry() else { return };
    let exe = reg.get("vm_multi_f8_s4096").unwrap();
    let dev = DeviceRuntime::new(Arc::clone(&reg)).unwrap();

    let mk = |src: &str, bounds: Vec<(f64, f64)>, theta: Vec<f64>, stream| {
        VmFn {
            program: Expr::parse(src).unwrap().compile().unwrap(),
            theta,
            bounds,
            stream,
        }
    };
    let fns = vec![
        mk("x1*x2", vec![(0.0, 1.0), (0.0, 1.0)], vec![], 11),
        mk(
            "p0*abs(x1+x2-x3)",
            vec![(0.0, 1.0); 3],
            vec![2.0],
            12,
        ),
        mk("sin(x1)+cos(x2)", vec![(-1.0, 1.0), (0.0, 2.0)], vec![], 13),
        mk("exp(-x1*x1)", vec![(-2.0, 2.0)], vec![], 14),
    ];
    let rng = RngCtr { seed: [7, 8], base: 0, trial: 3 };
    let inputs = vm_multi_inputs(exe, rng, &fns).unwrap();
    let out = dev.execute(&exe.name, &inputs).unwrap();
    assert_eq!(out.data.len(), exe.n_fns * 2);

    for (i, f) in fns.iter().enumerate() {
        let (s, q) = cpu_vm_sums(f, exe.samples, rng.seed, 0, 3);
        let (ds, dq) = (out.data[i * 2] as f64, out.data[i * 2 + 1] as f64);
        let tol = 1e-3 * q.abs().max(1.0);
        assert!(
            (ds - s).abs() < tol,
            "fn {i} sum: device={ds} cpu={s}"
        );
        assert!((dq - q).abs() < tol, "fn {i} sumsq: device={dq} cpu={q}");
    }
    // unused slots are the null program: sums exactly 0
    for i in fns.len()..exe.n_fns {
        assert_eq!(out.data[i * 2], 0.0);
        assert_eq!(out.data[i * 2 + 1], 0.0);
    }
}

#[test]
fn harmonic_artifact_matches_cpu() {
    let Some(reg) = registry() else { return };
    let exe = reg.get("harmonic_s8192_n128").unwrap();
    let dev = DeviceRuntime::new(Arc::clone(&reg)).unwrap();

    let n = 5;
    let k: Vec<Vec<f64>> = (1..=n)
        .map(|i| vec![i as f64 * 1.7, -(i as f64), 0.5 * i as f64])
        .collect();
    let a: Vec<f64> = (0..n).map(|i| 1.0 + i as f64 * 0.25).collect();
    let b: Vec<f64> = (0..n).map(|i| -(i as f64) * 0.5).collect();
    let lo = vec![0.0, -1.0, 0.0];
    let hi = vec![1.0, 1.0, 2.0];
    let rng = RngCtr { seed: [100, 200], base: 4096, trial: 1 };
    let stream = 77;
    let inputs =
        harmonic_inputs(exe, rng, stream, &k, &a, &b, &lo, &hi).unwrap();
    let out = dev.execute(&exe.name, &inputs).unwrap();

    // CPU mirror (f32 phases like the device MXU path)
    let key = StreamKey { seed: rng.seed, stream, trial: rng.trial };
    let mut sums = vec![0f64; n];
    let mut sqs = vec![0f64; n];
    for i in 0..exe.samples {
        let u = key.point(rng.base.wrapping_add(i as u32), exe.dims);
        let x: Vec<f32> = (0..3)
            .map(|d| lo[d] as f32 + (hi[d] - lo[d]) as f32 * u[d])
            .collect();
        for (j, kj) in k.iter().enumerate() {
            let phase: f32 = (0..3)
                .map(|d| kj[d] as f32 * x[d])
                .sum();
            let v =
                (a[j] as f32 * phase.cos() + b[j] as f32 * phase.sin()) as f64;
            sums[j] += v;
            sqs[j] += v * v;
        }
    }
    for j in 0..n {
        let ds = out.data[j] as f64;
        let dq = out.data[exe.n_fns + j] as f64;
        assert!(
            (ds - sums[j]).abs() < 1e-2 * sums[j].abs().max(10.0),
            "fn {j} sum: {ds} vs {}",
            sums[j]
        );
        assert!(
            (dq - sqs[j]).abs() < 1e-2 * sqs[j].abs().max(10.0),
            "fn {j} sumsq: {dq} vs {}",
            sqs[j]
        );
    }
}

#[test]
fn stratified_artifact_partitions_consistently() {
    let Some(reg) = registry() else { return };
    let exe = reg.get("stratified_c16_s256").unwrap();
    let dev = DeviceRuntime::new(Arc::clone(&reg)).unwrap();

    // integrand 1 over a 16-cube partition of [0,1]: each cube returns
    // exactly `samples` for sum and sumsq.
    let prog = Expr::parse("1").unwrap().compile().unwrap();
    let cubes: Vec<(Vec<f64>, Vec<f64>)> = (0..16)
        .map(|i| {
            (vec![i as f64 / 16.0], vec![(i + 1) as f64 / 16.0])
        })
        .collect();
    let streams: Vec<u32> = (0..16).collect();
    let rng = RngCtr { seed: [5, 6], base: 0, trial: 0 };
    let inputs =
        stratified_inputs(exe, rng, &prog, &[], &cubes, &streams).unwrap();
    let out = dev.execute(&exe.name, &inputs).unwrap();
    for c in 0..16 {
        assert_eq!(out.data[c * 2], exe.samples as f32, "cube {c}");
        assert_eq!(out.data[c * 2 + 1], exe.samples as f32);
    }
}

#[test]
fn chunked_counters_tile_seamlessly() {
    // two launches with base 0 and base=samples must equal one logical
    // stream (no sample reuse): their means differ, and the merged mean
    // approaches truth. Verified against the CPU mirror exactly.
    let Some(reg) = registry() else { return };
    let exe = reg.get("vm_multi_f8_s4096").unwrap();
    let dev = DeviceRuntime::new(Arc::clone(&reg)).unwrap();
    let f = VmFn {
        program: Expr::parse("x1").unwrap().compile().unwrap(),
        theta: vec![],
        bounds: vec![(0.0, 1.0)],
        stream: 0,
    };
    let mut totals = (0f64, 0f64);
    for chunk in 0..2u32 {
        let rng = RngCtr {
            seed: [9, 9],
            base: chunk * exe.samples as u32,
            trial: 0,
        };
        let inputs = vm_multi_inputs(exe, rng, std::slice::from_ref(&f))
            .unwrap();
        let out = dev.execute(&exe.name, &inputs).unwrap();
        totals.0 += out.data[0] as f64;
        totals.1 += out.data[1] as f64;
    }
    let (s, q) =
        cpu_vm_sums(&f, 2 * exe.samples, [9, 9], 0, 0);
    assert!((totals.0 - s).abs() < 1e-3 * s.abs());
    assert!((totals.1 - q).abs() < 1e-3 * q.abs());
}

#[test]
fn execute_rejects_malformed_inputs() {
    let Some(reg) = registry() else { return };
    let exe = reg.get("vm_multi_f8_s4096").unwrap();
    let dev = DeviceRuntime::new(Arc::clone(&reg)).unwrap();
    // wrong input count
    assert!(dev.execute(&exe.name, &[]).is_err());
    // unknown executable
    assert!(dev.execute("nope", &[]).is_err());
}
