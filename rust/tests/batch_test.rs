//! Batch-subsystem acceptance tests: hash-consed dedup + columnar
//! jobs + streaming reduction must be **bit-identical** to the boxed
//! multifunctions path — at every execution tier, engine count, and
//! watermark — while the dedup ledger proves the caches saw one
//! canonical program instead of one per function.
//!
//! Device-backed throughout (CPU emulator registry); skipped under
//! `--features pjrt` like the other emulator suites.

#![cfg(not(feature = "pjrt"))]

use zmc::batch::BatchJobs;
use zmc::integrator::spec::{Estimate, IntegralJob};
use zmc::runtime::ExecTier;
use zmc::session::Session;
use zmc::util::proptest::{check, Gen};

const TIERS: [ExecTier; 3] =
    [ExecTier::Naive, ExecTier::Plan, ExecTier::Fused];
const ENGINES: [usize; 3] = [1, 2, 4];

fn session(tier: ExecTier, engines: usize) -> Session {
    Session::builder()
        .emulated()
        .workers(2)
        .engines(engines)
        .execution_tier(tier)
        .build()
        .unwrap()
}

fn assert_bit_identical(got: &[Estimate], want: &[Estimate], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: result count");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.value.to_bits(),
            w.value.to_bits(),
            "{ctx}: fn {i} value {} vs {}",
            g.value,
            w.value
        );
        assert_eq!(
            g.std_err.to_bits(),
            w.std_err.to_bits(),
            "{ctx}: fn {i} std_err"
        );
        assert_eq!(g.n_samples, w.n_samples, "{ctx}: fn {i} n_samples");
    }
}

/// A parameter scan written the adversarial way: the parameter is a
/// *literal constant* in each source string, so every function is a
/// distinct `Program` that only dedup canonicalization can fold.
fn constant_scan(consts: &[f64]) -> Vec<IntegralJob> {
    consts
        .iter()
        .map(|c| {
            IntegralJob::parse(
                &format!("x1*x1*{c:.12} + {c:.12}"),
                &[(0.0, 1.0)],
            )
            .unwrap()
        })
        .collect()
}

#[test]
fn columnar_matches_boxed_at_every_tier_and_engine_count() {
    // near-collision constants: equal, off-by-one-ulp-ish, and far
    let consts =
        [0.5, 0.5, 0.500000000001, 1.25, 2.0, 0.499999999999, 3.75];
    let jobs = constant_scan(&consts);
    let jb = BatchJobs::from_jobs(&jobs).unwrap();
    assert!(jb.n_classes() < jobs.len(), "constants must fold");
    for tier in TIERS {
        for engines in ENGINES {
            let ctx = format!("tier={tier:?} engines={engines}");
            let s = session(tier, engines);
            let want = s
                .multifunctions(&jobs)
                .samples(1 << 10)
                .seed(7)
                .run()
                .unwrap();
            let got = s
                .batch(&jb)
                .samples(1 << 10)
                .seed(7)
                .run()
                .unwrap();
            assert_bit_identical(&got.to_estimates(), &want, &ctx);
        }
    }
}

#[test]
fn random_scans_dedup_bit_identically_to_boxed() {
    check(0xBA7C4, 12, |g: &mut Gen| {
        let n = 3 + g.below(9);
        // constants with deliberate exact and near collisions
        let mut consts = Vec::with_capacity(n);
        for i in 0..n {
            let c: f64 = match g.below(4) {
                0 if i > 0 => consts[i - 1],
                1 if i > 0 => consts[i - 1] + 1e-7,
                _ => g.range_f64(0.125, 3.0),
            };
            consts.push(c);
        }
        let jobs = constant_scan(&consts);
        let tier = TIERS[g.below(3)];
        let engines = ENGINES[g.below(3)];
        let seed = g.next_u64() >> 1;
        let ctx = format!("tier={tier:?} engines={engines} seed={seed}");
        let s = session(tier, engines);
        let want = s
            .multifunctions(&jobs)
            .samples(512)
            .seed(seed)
            .run()
            .unwrap();
        let jb = BatchJobs::from_jobs(&jobs).unwrap();
        let got =
            s.batch(&jb).samples(512).seed(seed).run().unwrap();
        assert_bit_identical(&got.to_estimates(), &want, &ctx);
    });
}

#[test]
fn scan_builder_matches_boxed_per_theta_binding() {
    // the intended 10⁶-regime entry point: one template, a theta
    // column — against the boxed path on the individually bound jobs
    let base = IntegralJob::with_params(
        "sin(x1*p0) + x2*p1",
        &[(0.0, 1.0), (0.0, 2.0)],
        &[0.0, 0.0],
    )
    .unwrap();
    let thetas: Vec<Vec<f64>> =
        (0..11).map(|i| vec![0.3 + i as f64 * 0.17, i as f64]).collect();
    let boxed: Vec<IntegralJob> =
        thetas.iter().map(|t| base.bind(t).unwrap()).collect();
    let jb = BatchJobs::scan(&base, &thetas).unwrap();
    assert_eq!(jb.n_classes(), 1);
    assert_eq!(jb.n_folded(), thetas.len() - 1);
    for engines in [1, 4] {
        let s = session(ExecTier::Fused, engines);
        let want = s
            .multifunctions(&boxed)
            .samples(1 << 11)
            .seed(42)
            .run()
            .unwrap();
        let got =
            s.batch(&jb).samples(1 << 11).seed(42).run().unwrap();
        assert_bit_identical(
            &got.to_estimates(),
            &want,
            &format!("scan engines={engines}"),
        );
    }
}

#[test]
fn watermark_choice_is_invisible_in_results() {
    let jobs = constant_scan(&[
        0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 1.75, 2.0, 2.25, 2.5, 2.75,
        3.0,
    ]);
    let jb = BatchJobs::from_jobs(&jobs).unwrap();
    let s = session(ExecTier::Fused, 2);
    let run = |wm: usize| {
        s.batch(&jb)
            .samples(1 << 11)
            .seed(3)
            .watermark(wm)
            .run()
            .unwrap()
    };
    let base = run(1);
    for wm in [2, 7, zmc::batch::DEFAULT_WATERMARK, 10_000] {
        let r = run(wm);
        assert_bit_identical(
            &r.to_estimates(),
            &base.to_estimates(),
            &format!("watermark={wm}"),
        );
        // merged moment columns, not just the derived estimates
        for i in 0..r.len() {
            let (a, b) = (r.moment(i), base.moment(i));
            assert_eq!(a.n, b.n, "watermark={wm}: fn {i} moment n");
            assert_eq!(
                a.sum.to_bits(),
                b.sum.to_bits(),
                "watermark={wm}: fn {i} moment sum"
            );
            assert_eq!(
                a.sumsq.to_bits(),
                b.sumsq.to_bits(),
                "watermark={wm}: fn {i} moment sumsq"
            );
        }
    }
}

#[test]
fn dedup_ledger_counts_unique_and_folded_programs() {
    // mirrors the plan/fused ledger tests in engine_test.rs: the
    // registry ledger and the engine metrics must both record how many
    // canonical programs the caches saw vs how many dedup folded away.
    // 7 constant-variants (one class) + 1 structurally distinct
    // program = exactly one 8-slot block, no padding.
    let mut jobs = constant_scan(&[
        0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5,
    ]);
    jobs.push(IntegralJob::parse("sin(x1)", &[(0.0, 1.0)]).unwrap());
    let jb = BatchJobs::from_jobs(&jobs).unwrap();
    assert_eq!(jb.n_classes(), 2);
    assert_eq!(jb.n_folded(), 6);

    let s = Session::builder()
        .emulated()
        .workers(1)
        .execution_tier(ExecTier::Fused)
        .build()
        .unwrap();
    assert_eq!(s.registry().dedup_unique_count(), 0);
    assert_eq!(s.registry().dedup_folded_count(), 0);

    s.batch(&jb).samples(512).run().unwrap();
    assert_eq!(s.registry().dedup_unique_count(), 2);
    assert_eq!(s.registry().dedup_folded_count(), 6);
    let em = s.engine().metrics();
    assert_eq!(em.dedup_unique(), 2);
    assert_eq!(em.dedup_folded(), 6);
    // the payoff the ledger certifies: one fused lowering per
    // canonical program on one worker — not one per function
    assert_eq!(
        s.registry().fused_lower_count(),
        2,
        "caches must see the canonical program, not 8 variants"
    );

    // each batch run ledgers its own dedup events
    s.batch(&jb).samples(512).run().unwrap();
    assert_eq!(s.registry().dedup_unique_count(), 4);
    assert_eq!(s.registry().dedup_folded_count(), 12);
    assert_eq!(
        s.registry().fused_lower_count(),
        2,
        "re-running the batch must hit the warm fused cache"
    );
}
