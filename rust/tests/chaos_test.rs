//! Deterministic fault-injection verification: the chaos suite.
//!
//! A seeded [`FaultPlan`] schedules transport faults — drop, delay,
//! truncate, corrupt, hang — at exact `(connection, frame)` points,
//! and for **every** fault class a mixed local+remote cluster must
//! reproduce the fault-free single-engine `Estimate`s bit-for-bit:
//! lethal faults degrade to a whole-shard requeue (counted in the
//! cluster `Metrics`) plus a supervised reconnect, while a latency
//! spike costs nothing. On top of the class-by-class sweep:
//!
//! * a seeded schedule replays identically and survives two batches;
//! * the `Session::builder().fault_plan(..)` knob threads a plan all
//!   the way to the transport;
//! * a worker killed and restarted on the same port rejoins the shard
//!   plan and serves later rounds (`reconnects` accounted);
//! * proptest fuzzing — random bit flips, truncations, and trailing
//!   garbage on random frames always decode to a typed [`WireError`],
//!   never a wrong frame;
//! * a peer that closes cleanly mid-handshake is a connect *failure*,
//!   not a hang.
//!
//! Emulator-only (`--features pjrt` skips): the emulated registry is
//! what makes remote results bit-identical to local ones.
#![cfg(not(feature = "pjrt"))]

use std::net::TcpListener;
use std::sync::Arc;
use std::time::{Duration, Instant};

use zmc::cluster::{
    reduce_tagged, serve_worker, DeviceCluster, Fault, Frame, LaunchExec,
    RemoteConfig, RemoteEngine, WireError, WireFaultPlan, WorkerServer,
};
use zmc::engine::{DeviceEngine, Engine, LaunchTask, TaggedOutput};
use zmc::integrator::multifunctions::{self, MultiConfig};
use zmc::integrator::spec::{Estimate, IntegralJob};
use zmc::runtime::device::DevicePool;
use zmc::runtime::launch::Value;
use zmc::runtime::registry::Registry;
use zmc::session::Session;
use zmc::util::proptest::{check, Gen};

type DeviceFrame = Frame<LaunchTask, TaggedOutput>;

// ------------------------------------------------------------ fixtures

fn emulated_pool() -> DevicePool {
    let reg = Arc::new(Registry::emulated());
    DevicePool::new(&reg, 1).unwrap()
}

fn engine() -> DeviceEngine {
    Engine::for_pool(&emulated_pool()).unwrap()
}

fn worker() -> WorkerServer {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    serve_worker(listener, engine()).unwrap()
}

/// Fast heartbeats and an eager reconnect supervisor, with `plan`
/// wired into the transport.
fn chaos_rcfg(plan: Option<Arc<WireFaultPlan>>) -> RemoteConfig {
    RemoteConfig {
        ping_interval: Duration::from_millis(20),
        ping_timeout: Duration::from_millis(400),
        reconnect_backoff: Duration::from_millis(20),
        reconnect_cap: Duration::from_millis(100),
        reconnect_retries: 200,
        chaos: plan,
        ..Default::default()
    }
}

/// 1 local engine + 1 remote proxy with `plan` on the wire.
fn chaos_cluster(plan: Arc<WireFaultPlan>, addr: &str) -> DeviceCluster {
    DeviceCluster::for_pool_with_remote_config(
        &emulated_pool(),
        1,
        &[addr.to_string()],
        chaos_rcfg(Some(plan)),
    )
    .unwrap()
}

fn job_pool() -> Vec<IntegralJob> {
    let u1 = [(0.0, 1.0)];
    let u2 = [(0.0, 1.0), (0.0, 1.0)];
    vec![
        IntegralJob::parse("x1^2 + 1", &u1).unwrap(),
        IntegralJob::parse("sin(x1)*x2", &u2).unwrap(),
        IntegralJob::with_params("exp(-p0*(x1+x2))", &u2, &[1.5]).unwrap(),
    ]
}

fn multi_cfg(seed: u64) -> MultiConfig {
    MultiConfig {
        // 8 launches of 4096 samples: both shards are non-trivial, so
        // the remote shard is in flight when the fault fires
        samples_per_fn: 8 << 12,
        seed,
        exe: Some("vm_multi_f8_s4096".into()),
        ..Default::default()
    }
}

fn assert_estimates_bit_identical(
    a: &[Estimate],
    b: &[Estimate],
    ctx: &str,
) {
    assert_eq!(a.len(), b.len(), "{ctx}");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.value.to_bits(),
            y.value.to_bits(),
            "{ctx}: fn {i} value {} vs {}",
            x.value,
            y.value
        );
        assert_eq!(
            x.std_err.to_bits(),
            y.std_err.to_bits(),
            "{ctx}: fn {i} std_err"
        );
        assert_eq!(x.n_samples, y.n_samples, "{ctx}: fn {i} n_samples");
    }
}

/// Spin until `pred` holds or `deadline` elapses; panic with `what`
/// on timeout.
fn wait_for(what: &str, deadline: Duration, mut pred: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !pred() {
        assert!(t0.elapsed() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

// ----------------------------------------------- the class-by-class sweep

/// The tentpole property: every fault class, injected at the first
/// Submit of the remote connection (conn 0, data frame 1) *and* at
/// the first Submit of the reconnected connection (conn 1, frame 1),
/// leaves both the `Estimate`s and the merged `MomentSum`s
/// bit-identical to a fault-free single-engine run. Lethal classes
/// must be *accounted* — a whole-shard requeue plus a reconnect in
/// the cluster metrics — and a latency spike must cost nothing.
#[test]
fn every_fault_class_is_bit_identical_to_fault_free() {
    let jobs = job_pool();
    let cfg = multi_cfg(61_61);
    let reference = engine();
    let clean =
        multifunctions::integrate(&reference, &jobs, &cfg).unwrap();
    let reg = Arc::new(Registry::emulated());
    let (tasks, exe) =
        multifunctions::build_tasks(&reg, &jobs, &cfg).unwrap();
    let (n_fns, samples) = (exe.n_fns, exe.samples as u64);
    let outs = LaunchExec::submit_launches(&reference, tasks.clone(), 3)
        .unwrap()
        .wait()
        .unwrap();
    let base_moments = reduce_tagged(outs, n_fns, samples, jobs.len());

    let classes: [(&str, Fault, bool); 5] = [
        ("drop", Fault::Drop, true),
        ("delay", Fault::Delay(Duration::from_millis(30)), false),
        ("truncate", Fault::Truncate(9), true),
        ("corrupt", Fault::Corrupt { offset: 18, xor: 0x40 }, true),
        ("hang", Fault::Hang, true),
    ];
    for (name, fault, lethal) in classes {
        let w = worker();
        let plan = Arc::new(
            WireFaultPlan::new().event(0, 1, fault).event(1, 1, fault),
        );
        let c = chaos_cluster(plan, &w.addr().to_string());
        let got = multifunctions::integrate(&c, &jobs, &cfg).unwrap();
        assert_estimates_bit_identical(&clean, &got, name);

        // one layer down: the moments batch rides the cluster too —
        // for lethal classes it lands on the reconnected connection,
        // whose first Submit is also faulted
        let outs = LaunchExec::submit_launches(&c, tasks.clone(), 3)
            .unwrap()
            .wait()
            .unwrap();
        let merged = reduce_tagged(outs, n_fns, samples, jobs.len());
        assert_eq!(base_moments, merged, "{name}: merged moments");

        let m = c.metrics();
        if lethal {
            assert!(
                m.retried() >= 1,
                "{name}: shard requeue must be counted: {}",
                m.summary()
            );
            wait_for(
                &format!("{name}: reconnect accounting"),
                Duration::from_secs(10),
                || c.metrics().reconnects() >= 1,
            );
        } else {
            assert_eq!(
                m.retried(),
                0,
                "{name}: a latency spike is not a death: {}",
                m.summary()
            );
            assert_eq!(m.reconnects(), 0, "{name}: {}", m.summary());
        }
    }
}

/// After an injected drop the supervisor reconnects to the (still
/// alive) worker on a fresh connection index — which the plan leaves
/// clean — the node revives, and a second round runs fault-free.
#[test]
fn injected_drop_reconnects_and_revives_the_node() {
    let jobs = job_pool();
    let cfg = multi_cfg(62_62);
    let clean = multifunctions::integrate(&engine(), &jobs, &cfg).unwrap();

    let w = worker();
    let plan = Arc::new(WireFaultPlan::new().event(0, 1, Fault::Drop));
    let c = chaos_cluster(plan, &w.addr().to_string());
    let got = multifunctions::integrate(&c, &jobs, &cfg).unwrap();
    assert_estimates_bit_identical(&clean, &got, "round 1 under drop");

    wait_for("reconnect + revival", Duration::from_secs(10), || {
        c.metrics().reconnects() >= 1 && c.n_alive() == 2
    });
    let got = multifunctions::integrate(&c, &jobs, &cfg).unwrap();
    assert_estimates_bit_identical(&clean, &got, "round 2 after rejoin");
    assert_eq!(
        c.metrics().reconnect_failures(),
        0,
        "worker never went away, so no attempt may fail: {}",
        c.metrics().summary()
    );
}

/// A seeded schedule is a pure function of its seed, and a cluster
/// riding one (faults across connections 0..3) still reproduces two
/// consecutive batches bit-for-bit.
#[test]
fn seeded_schedule_replays_and_stays_bit_identical() {
    let a = WireFaultPlan::seeded(0xC0FFEE, 5);
    let b = WireFaultPlan::seeded(0xC0FFEE, 5);
    assert_eq!(a.len(), b.len());
    for conn in 0..4 {
        for frame in 0..8 {
            assert_eq!(
                a.fault_for(conn, frame),
                b.fault_for(conn, frame),
                "schedule must replay at ({conn}, {frame})"
            );
        }
    }

    let jobs = job_pool();
    let cfg = multi_cfg(63_63);
    let clean = multifunctions::integrate(&engine(), &jobs, &cfg).unwrap();
    let w = worker();
    let c = chaos_cluster(Arc::new(a), &w.addr().to_string());
    for round in 1..=2 {
        let got = multifunctions::integrate(&c, &jobs, &cfg).unwrap();
        assert_estimates_bit_identical(
            &clean,
            &got,
            &format!("seeded storm, round {round}"),
        );
    }
}

/// `Session::builder().fault_plan(..)` reaches the transport: a
/// corrupted Submit costs a counted requeue, never a wrong estimate.
#[test]
fn session_fault_plan_threads_to_the_transport() {
    let w = worker();
    let plan = Arc::new(
        WireFaultPlan::new()
            .event(0, 1, Fault::Corrupt { offset: 20, xor: 0xFF }),
    );
    let local = Session::builder().emulated().build().unwrap();
    let s = Session::builder()
        .emulated()
        .remote_engines([w.addr().to_string()])
        .remote_config(chaos_rcfg(None))
        .fault_plan(plan)
        .build()
        .unwrap();

    let jobs = job_pool();
    let base = local
        .multifunctions(&jobs)
        .samples(4 << 12)
        .seed(77)
        .run()
        .unwrap();
    let got =
        s.multifunctions(&jobs).samples(4 << 12).seed(77).run().unwrap();
    assert_estimates_bit_identical(&base, &got, "session fault plan");
    let m = s.cluster().unwrap().metrics();
    assert!(
        m.retried() >= 1,
        "the corrupted shard must be a counted requeue: {}",
        m.summary()
    );
}

// --------------------------------------------------- worker bounce

/// Kill a worker, restart it on the same port, and the supervisor
/// rejoins it to the shard plan: `reconnects` is accounted, the node
/// is alive again, and the next rounds are bit-identical.
#[test]
fn killed_then_restarted_worker_rejoins_and_serves() {
    let w = worker();
    let port_addr = w.addr();
    let addr = port_addr.to_string();

    let local = Session::builder().emulated().build().unwrap();
    let s = Session::builder()
        .emulated()
        .remote_engines([addr])
        .remote_config(chaos_rcfg(None))
        .build()
        .unwrap();
    let jobs = job_pool();
    let base = local
        .multifunctions(&jobs)
        .samples(4 << 12)
        .seed(88)
        .run()
        .unwrap();
    let got =
        s.multifunctions(&jobs).samples(4 << 12).seed(88).run().unwrap();
    assert_estimates_bit_identical(&base, &got, "before the bounce");

    w.kill();
    w.join();
    // restart on the same port (the listener may linger briefly)
    let deadline = Instant::now() + Duration::from_secs(10);
    let w2 = loop {
        match TcpListener::bind(port_addr) {
            Ok(l) => break serve_worker(l, engine()).unwrap(),
            Err(e) => {
                assert!(Instant::now() < deadline, "rebind: {e}");
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    };

    let c = s.cluster().unwrap();
    wait_for("worker rejoin", Duration::from_secs(10), || {
        c.metrics().reconnects() >= 1 && c.n_alive() == 2
    });
    for round in 1..=2 {
        let got = s
            .multifunctions(&jobs)
            .samples(4 << 12)
            .seed(88)
            .run()
            .unwrap();
        assert_estimates_bit_identical(
            &base,
            &got,
            &format!("post-bounce round {round}"),
        );
    }
    assert!(w2.stats().submits.load(std::sync::atomic::Ordering::Relaxed) >= 1);
}

// ------------------------------------------------------- wire fuzzing

fn random_value(g: &mut Gen) -> Value {
    let n = g.below(5);
    match g.below(3) {
        0 => Value::F32(
            (0..n).map(|_| f32::from_bits(g.next_u32())).collect(),
        ),
        1 => Value::I32((0..n).map(|_| g.next_u32() as i32).collect()),
        _ => Value::U32((0..n).map(|_| g.next_u32()).collect()),
    }
}

fn random_frame(g: &mut Gen) -> DeviceFrame {
    match g.below(6) {
        0 => Frame::Submit {
            id: g.next_u64(),
            max_retries: g.next_u32() % 8,
            tasks: (0..g.below(3))
                .map(|_| LaunchTask {
                    exe: format!("vm_multi_f8_s{}", 1 << (10 + g.below(4))),
                    tag: g.next_u64(),
                    inputs: (0..g.below(3)).map(|_| random_value(g)).collect(),
                })
                .collect(),
        },
        1 => Frame::Result {
            id: g.next_u64(),
            outs: vec![],
        },
        2 => Frame::Error {
            id: g.next_u64(),
            msg: "chaos fuzz ✗".to_string(),
        },
        3 => Frame::Cancel { id: g.next_u64() },
        4 => Frame::Hello {
            min_version: g.next_u32() as u16,
            max_version: g.next_u32() as u16,
            digest: g.next_u64(),
        },
        _ => Frame::HelloAck {
            version: g.next_u32() as u16,
            digest: g.next_u64(),
        },
    }
}

/// Random single-bit flips, truncations, and trailing garbage on
/// random frames: decoding always yields a *typed* [`WireError`] —
/// never a panic, never a silently wrong frame. (The checksum covers
/// tag, length, and payload, so no single flip can slip through.)
#[test]
fn fuzzed_corruption_is_a_typed_error_never_a_wrong_frame() {
    check(0xFA11_5EED, 60, |g: &mut Gen| {
        let bytes = random_frame(g).to_bytes();
        match g.below(3) {
            0 => {
                let mut b = bytes.clone();
                let i = g.below(b.len());
                b[i] ^= 1u8 << g.below(8);
                let err = DeviceFrame::from_bytes(&b).unwrap_err();
                // exercise the error type: every variant displays
                assert!(!err.to_string().is_empty(), "flip at {i}");
            }
            1 => {
                let cut = g.below(bytes.len());
                assert!(
                    matches!(
                        DeviceFrame::from_bytes(&bytes[..cut]),
                        Err(WireError::Truncated { .. })
                    ),
                    "cut at {cut}"
                );
            }
            _ => {
                let mut b = bytes.clone();
                let extra = 1 + g.below(16);
                for _ in 0..extra {
                    b.push(g.next_u32() as u8);
                }
                assert!(matches!(
                    DeviceFrame::from_bytes(&b),
                    Err(WireError::Trailing { .. })
                ));
            }
        }
    });
}

/// A peer that accepts and then closes cleanly before answering the
/// handshake is a connect *failure* with a useful message — bounded
/// in time, never a hang.
#[test]
fn clean_eof_mid_handshake_fails_connect_without_hanging() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let t = std::thread::spawn(move || {
        for conn in listener.incoming().take(2).flatten() {
            drop(conn);
        }
    });

    let t0 = Instant::now();
    let cfg = RemoteConfig {
        connect_retries: 2,
        connect_backoff: Duration::from_millis(10),
        ping_timeout: Duration::from_millis(200),
        reconnect: false,
        ..Default::default()
    };
    let err = RemoteEngine::<LaunchTask, TaggedOutput>::connect(&addr, cfg)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("mid-handshake") || msg.contains("HelloAck"),
        "unexpected failure shape: {msg}"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "mid-handshake EOF must fail fast, not hang"
    );
    t.join().unwrap();
}
