//! `Session` façade verification: the fluent builders must be
//! bit-exact with the legacy free-function paths for all three paper
//! classes, at 1 and 4 engines, and must surface typed validation
//! errors before any device work happens.
//!
//! Runs entirely on the CPU emulator registry (like cluster_test), so
//! the suite is offline and deterministic.

use std::sync::Arc;

use zmc::cluster::{DeviceCluster, LaunchExec};
use zmc::config::{JobClass, JobConfig};
use zmc::engine::Engine;
use zmc::integrator::multifunctions::{self, MultiConfig};
use zmc::integrator::normal::{self, NormalConfig};
use zmc::integrator::{functional, spec::IntegralJob};
use zmc::runtime::device::DevicePool;
use zmc::runtime::registry::Registry;
use zmc::session::{Error, Session};
use zmc::util::proptest::{check, Gen};

fn session(engines: usize) -> Session {
    Session::builder()
        .emulated()
        .workers(1)
        .engines(engines)
        .build()
        .unwrap()
}

/// The legacy hand-wired path the builders must match bit-for-bit.
fn legacy_exec(engines: usize) -> Box<dyn LaunchExec> {
    let reg = Arc::new(Registry::emulated());
    let pool = DevicePool::new(&reg, 1).unwrap();
    if engines <= 1 {
        Box::new(Engine::for_pool(&pool).unwrap())
    } else {
        Box::new(DeviceCluster::for_pool(&pool, engines).unwrap())
    }
}

/// Heterogeneous integrand pool (dims 1–3, smooth and peaked).
fn job_pool() -> Vec<IntegralJob> {
    let u1 = [(0.0, 1.0)];
    let u2 = [(0.0, 1.0), (0.0, 1.0)];
    let u3 = [(0.0, 1.0), (0.0, 1.0), (0.0, 1.0)];
    vec![
        IntegralJob::parse("x1^2 + 1", &u1).unwrap(),
        IntegralJob::parse("sin(x1)*x2", &u2).unwrap(),
        IntegralJob::with_params("exp(-p0*(x1+x2))", &u2, &[1.5]).unwrap(),
        IntegralJob::with_params(
            "1/(p0 + (x1-0.5)^2 + (x2-0.5)^2)",
            &u2,
            &[0.05],
        )
        .unwrap(),
        IntegralJob::parse("abs(x1+x2-x3)", &u3).unwrap(),
    ]
}

// ------------------------------------------------- bit-exactness props

#[test]
fn multifunctions_builder_matches_legacy_prop() {
    let pool = job_pool();
    check(0xC0FFEE, 8, |g: &mut Gen| {
        let engines = *g.choose(&[1usize, 2, 4]);
        let n_jobs = 1 + g.below(pool.len());
        let jobs: Vec<IntegralJob> = (0..n_jobs)
            .map(|_| g.choose(&pool).clone())
            .collect();
        let cfg = MultiConfig {
            samples_per_fn: *g.choose(&[2048usize, 4096, 8192]),
            seed: g.next_u64(),
            trial: g.next_u32() % 4,
            exe: Some("vm_multi_f8_s4096".into()),
            ..Default::default()
        };
        let legacy = multifunctions::integrate(
            legacy_exec(engines).as_ref(),
            &jobs,
            &cfg,
        )
        .unwrap();
        let built = session(engines)
            .multifunctions(&jobs)
            .config(cfg)
            .run()
            .unwrap();
        assert_eq!(legacy, built, "builder diverged from free function");
    });
}

#[test]
fn functional_builder_matches_legacy_prop() {
    let job = IntegralJob::with_params(
        "cos(p0*(x1+x2)) + p1*x1",
        &[(0.0, 1.0), (0.0, 1.0)],
        &[1.0, 0.0],
    )
    .unwrap();
    check(0xFACADE, 6, |g: &mut Gen| {
        let engines = *g.choose(&[1usize, 4]);
        let n_points = 1 + g.below(6);
        let thetas: Vec<Vec<f64>> = (0..n_points)
            .map(|_| {
                vec![g.range_f64(0.5, 8.0), g.range_f64(-1.0, 1.0)]
            })
            .collect();
        let cfg = MultiConfig {
            samples_per_fn: 4096,
            seed: g.next_u64(),
            exe: Some("vm_multi_f8_s4096".into()),
            ..Default::default()
        };
        let legacy = functional::scan(
            legacy_exec(engines).as_ref(),
            &job,
            &thetas,
            &cfg,
        )
        .unwrap();
        let built = session(engines)
            .functional(&job, &thetas)
            .config(cfg)
            .run()
            .unwrap();
        assert_eq!(legacy, built, "scan builder diverged");
    });
}

#[test]
fn normal_builder_matches_legacy_prop() {
    let job = IntegralJob::parse(
        "exp(-50*((x1-0.5)^2 + (x2-0.5)^2))",
        &[(0.0, 1.0), (0.0, 1.0)],
    )
    .unwrap();
    check(0x7B33, 4, |g: &mut Gen| {
        let engines = *g.choose(&[1usize, 4]);
        let cfg = NormalConfig {
            initial_divisions: *g.choose(&[2usize, 4]),
            n_trials: 3,
            max_depth: g.below(3),
            seed: g.next_u64(),
            exe: Some("stratified_c16_s256".into()),
            ..Default::default()
        };
        let legacy = normal::integrate(
            legacy_exec(engines).as_ref(),
            &job,
            &cfg,
        )
        .unwrap();
        let built =
            session(engines).normal(&job).config(cfg).run().unwrap();
        assert_eq!(legacy.estimate, built.estimate);
        assert_eq!(legacy.cubes_per_level, built.cubes_per_level);
        assert_eq!(legacy.flagged_per_level, built.flagged_per_level);
        assert_eq!(legacy.launches, built.launches);
    });
}

/// The satellite requirement: stratified tree search on a 4-engine
/// cluster is bit-identical to the 1-engine run.
#[test]
fn normal_one_vs_four_engines_bit_identical() {
    let job = IntegralJob::parse(
        "max(0, 0.25-x1) * sin(60*x1) * 40",
        &[(0.0, 1.0)],
    )
    .unwrap();
    let cfg = NormalConfig {
        initial_divisions: 8,
        n_trials: 4,
        sigma_mult: 0.5,
        max_depth: 2,
        seed: 3,
        exe: Some("stratified_c16_s256".into()),
        ..Default::default()
    };
    let one = session(1).normal(&job).config(cfg.clone()).run().unwrap();
    let four = session(4).normal(&job).config(cfg).run().unwrap();
    assert_eq!(one.estimate, four.estimate);
    assert_eq!(one.cubes_per_level, four.cubes_per_level);
    assert_eq!(one.flagged_per_level, four.flagged_per_level);
    assert_eq!(one.launches, four.launches);
    // the tree actually refined something, so shards were non-trivial
    assert!(one.cubes_per_level.len() > 1, "{:?}", one.cubes_per_level);
}

// -------------------------------------------- knobs == config struct

#[test]
fn chained_knobs_equal_config_struct() {
    let jobs = job_pool();
    let s = session(1);
    let cfg = MultiConfig {
        samples_per_fn: 8192,
        seed: 99,
        trial: 2,
        stream_base: 5,
        exe: Some("vm_multi_f8_s4096".into()),
        ..Default::default()
    };
    let via_config = s
        .multifunctions(&jobs)
        .config(cfg.clone())
        .run()
        .unwrap();
    let via_knobs = s
        .multifunctions(&jobs)
        .samples(8192)
        .seed(99)
        .trial(2)
        .stream_base(5)
        .exe("vm_multi_f8_s4096")
        .run()
        .unwrap();
    assert_eq!(via_config, via_knobs);
}

#[test]
fn submit_then_wait_equals_run() {
    let jobs = job_pool();
    let s = session(2);
    let sync = s
        .multifunctions(&jobs)
        .samples(4096)
        .seed(11)
        .run()
        .unwrap();
    let handle = s
        .multifunctions(&jobs)
        .samples(4096)
        .seed(11)
        .submit()
        .unwrap();
    assert_eq!(sync, handle.wait().unwrap());
}

#[test]
fn adaptive_builder_matches_legacy() {
    let jobs = job_pool();
    let cfg = MultiConfig {
        samples_per_fn: 1 << 14,
        seed: 42,
        target_rel_err: Some(0.02),
        pilot_samples: 4096,
        exe: Some("vm_multi_f8_s4096".into()),
        ..Default::default()
    };
    let legacy =
        multifunctions::integrate(legacy_exec(1).as_ref(), &jobs, &cfg)
            .unwrap();
    let built = session(1)
        .multifunctions(&jobs)
        .samples(1 << 14)
        .seed(42)
        .target_rel_err(0.02)
        .pilot_samples(4096)
        .exe("vm_multi_f8_s4096")
        .run()
        .unwrap();
    assert_eq!(legacy, built);
    assert!(built.iter().all(|e| e.rounds >= 1));
}

// -------------------------------------------------- typed validation

#[test]
fn zero_samples_is_typed_error() {
    let s = session(1);
    let job = IntegralJob::parse("x1", &[(0.0, 1.0)]).unwrap();
    let err = s
        .multifunctions(std::slice::from_ref(&job))
        .samples(0)
        .run()
        .unwrap_err();
    assert_eq!(err.downcast_ref::<Error>(), Some(&Error::ZeroSamples));
    let err = s
        .functional(&job, &[vec![]])
        .samples(0)
        .run()
        .unwrap_err();
    assert_eq!(err.downcast_ref::<Error>(), Some(&Error::ZeroSamples));
}

#[test]
fn conflicting_targets_is_typed_error() {
    let s = session(1);
    let job = IntegralJob::parse("x1", &[(0.0, 1.0)]).unwrap();
    let err = s
        .multifunctions(std::slice::from_ref(&job))
        .target_rel_err(0.01)
        .target_abs_err(0.001)
        .run()
        .unwrap_err();
    assert_eq!(
        err.downcast_ref::<Error>(),
        Some(&Error::ConflictingTargets)
    );
    // clearing one side with None resolves the conflict
    let ok = s
        .multifunctions(std::slice::from_ref(&job))
        .samples(4096)
        .target_rel_err(0.05)
        .target_abs_err(None)
        .exe("vm_multi_f8_s4096")
        .run();
    assert!(ok.is_ok(), "{:?}", ok.err());

    // ...but the .config() escape hatch keeps the free functions'
    // combined-target semantics (stop at whichever is met) bit-exactly
    let both = MultiConfig {
        samples_per_fn: 8192,
        seed: 5,
        target_rel_err: Some(0.5),
        target_abs_err: Some(0.5),
        exe: Some("vm_multi_f8_s4096".into()),
        ..Default::default()
    };
    let legacy = multifunctions::integrate(
        legacy_exec(1).as_ref(),
        std::slice::from_ref(&job),
        &both,
    )
    .unwrap();
    let built = s
        .multifunctions(std::slice::from_ref(&job))
        .config(both)
        .run()
        .unwrap();
    assert_eq!(legacy, built);
}

#[test]
fn invalid_target_is_typed_error() {
    let s = session(1);
    let job = IntegralJob::parse("x1", &[(0.0, 1.0)]).unwrap();
    for bad in [-1.0, 0.0, f64::NAN, f64::INFINITY] {
        let err = s
            .multifunctions(std::slice::from_ref(&job))
            .target_rel_err(bad)
            .run()
            .unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<Error>(),
                Some(Error::InvalidTarget { .. })
            ),
            "target {bad} not rejected: {err}"
        );
    }
}

#[test]
fn grid_dim_mismatch_is_typed_error() {
    let s = session(1);
    let job = IntegralJob::with_params(
        "p0*p1*x1",
        &[(0.0, 1.0)],
        &[1.0, 2.0],
    )
    .unwrap();
    // a grid point binding only one of the two parameters
    let err =
        s.functional(&job, &[vec![1.0]]).samples(4096).run().unwrap_err();
    assert_eq!(
        err.downcast_ref::<Error>(),
        Some(&Error::DimMismatch { expected: 2, got: 1 })
    );
    // a grid point exceeding the ABI's parameter-slot capacity gets
    // its own error, not a bogus too-few-values message
    let wide = vec![vec![0.0; 17]];
    let err = s.functional(&job, &wide).samples(4096).run().unwrap_err();
    assert_eq!(
        err.downcast_ref::<Error>(),
        Some(&Error::TooManyParams { max: 16, got: 17 })
    );
}

#[test]
fn normal_too_few_trials_is_typed_error() {
    let s = session(1);
    let job = IntegralJob::parse("x1", &[(0.0, 1.0)]).unwrap();
    let err = s.normal(&job).trials(1).run().unwrap_err();
    assert_eq!(
        err.downcast_ref::<Error>(),
        Some(&Error::TooFewTrials { got: 1 })
    );
}

// ------------------------------------------------ job-config round trip

#[test]
fn from_job_config_builds_matching_topology() {
    let cfg = JobConfig::from_json_text(
        r#"{"workers": 2, "num_engines": 3,
             "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
    )
    .unwrap();
    let s = Session::from_job_config(&cfg).unwrap();
    assert_eq!(s.workers(), 2);
    assert_eq!(s.num_engines(), 3);
    assert!(s.cluster().is_some());
}

#[test]
fn job_config_round_trips_all_three_classes() {
    // multifunctions
    let text = JobConfig::example_json().replace("262144", "4096");
    let cfg = JobConfig::from_json_text(&text).unwrap();
    let s = Session::from_job_config(&cfg).unwrap();
    let ests = s
        .multifunctions(&cfg.jobs)
        .samples(cfg.samples_per_fn)
        .seed(cfg.seed)
        .run()
        .unwrap();
    assert_eq!(ests.len(), cfg.jobs.len());

    // functional: run the scan over the config's cartesian grid
    let text =
        JobConfig::example_json_functional().replace("65536", "4096");
    let cfg = JobConfig::from_json_text(&text).unwrap();
    let JobClass::Functional { axes } = cfg.class.clone() else {
        panic!("expected functional class");
    };
    let thetas = functional::grid(&axes);
    let s = Session::from_job_config(&cfg).unwrap();
    let ests = s
        .functional(&cfg.jobs[0], &thetas)
        .samples(cfg.samples_per_fn)
        .seed(cfg.seed)
        .run()
        .unwrap();
    assert_eq!(ests.len(), thetas.len());

    // normal: the tree-search knobs drive the builder
    let cfg =
        JobConfig::from_json_text(&JobConfig::example_json_normal())
            .unwrap();
    let JobClass::Normal(p) = cfg.class.clone() else {
        panic!("expected normal class");
    };
    let s = Session::from_job_config(&cfg).unwrap();
    let r = s
        .normal(&cfg.jobs[0])
        .divisions(p.divisions)
        .trials(p.n_trials)
        .sigma_mult(p.sigma_mult)
        .depth(p.depth)
        .max_split_dims(p.max_split_dims)
        .seed(cfg.seed)
        .exe("stratified_c16_s256")
        .run()
        .unwrap();
    // truth: ∫ sin(x1) over [0,π] = 2, ∫ x2 over [0,1] = 1/2 → 1.0;
    // ~20k stratified samples of a smooth integrand land well inside
    // an absolute 0.1 band
    assert!(
        (r.estimate.value - 1.0).abs() < 0.1,
        "normal class run off: {}",
        r.estimate
    );
    assert!(r.estimate.n_samples > 0 && r.launches > 0);
}
