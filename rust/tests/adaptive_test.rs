//! Adaptive-allocation integration tests on the CPU emulator backend:
//!
//! * the domain-remapping invariant — a stratified (sub-box) launch is
//!   bit-exact with a first-class unstratified launch of the same
//!   integrand over the same Philox counter ranges, so stratification
//!   adds no sampling perturbation and reuses the cached `vm_multi`
//!   executables unchanged;
//! * the pilot-then-refine loop — per-function stopping at an error
//!   target, budget flowing to the hard integrands, rounds/samples
//!   breakdown in every `Estimate`, determinism, and warm caches
//!   across rounds.
//!
//! Emulator-only (`--features pjrt` skips: synthetic HLO bodies).
#![cfg(not(feature = "pjrt"))]

use std::sync::Arc;

use zmc::adaptive::{self, strata::Stratum, Allocation};
use zmc::engine::{DeviceEngine, Engine};
use zmc::integrator::multifunctions::{self, MultiConfig};
use zmc::integrator::spec::IntegralJob;
use zmc::runtime::device::{DevicePool, DeviceRuntime};
use zmc::runtime::launch::{vm_multi_inputs, RngCtr, VmFn};
use zmc::runtime::registry::Registry;
use zmc::stats::{stratified_estimate, MomentSum};

fn engine(workers: usize) -> (Arc<Registry>, DeviceEngine) {
    let reg = Arc::new(Registry::emulated());
    let pool = DevicePool::new(&reg, workers).unwrap();
    let eng = Engine::for_pool(&pool).unwrap();
    (reg, eng)
}

/// 3 smooth integrands + 1 sharp 2-D peak (the error-dominating one).
fn mixed_jobs() -> Vec<IntegralJob> {
    let unit2 = [(0.0, 1.0), (0.0, 1.0)];
    vec![
        IntegralJob::parse("1 + x1*x2", &unit2).unwrap(),
        IntegralJob::parse("exp(-x1) + 1", &unit2).unwrap(),
        IntegralJob::parse("x1^2 + x2 + 1", &unit2).unwrap(),
        IntegralJob::with_params(
            "1/(p0 + (x1-0.5)^2 + (x2-0.5)^2)",
            &unit2,
            &[0.02],
        )
        .unwrap(),
    ]
}

/// A domain-remapped slot — the adaptive subsystem's stratified launch:
/// the stratum box simply replaces the integrand's bounds in an
/// ordinary `vm_multi` row — must be **bit-exact** with integrating the
/// sub-box as a first-class job over the same counter range
/// `[0, samples)` of the same stream. Emulated directly against the
/// engine path.
#[test]
fn remapped_launch_is_bit_exact_with_unstratified() {
    let reg = Arc::new(Registry::emulated());
    let exe = reg.get("vm_multi_f8_s4096").unwrap();
    let dev = DeviceRuntime::new(Arc::clone(&reg)).unwrap();

    // remapped slot: full-domain job "x1*x1 + p0", stratum [0.25, 0.5]
    let full = IntegralJob::with_params(
        "x1*x1 + p0",
        &[(0.0, 1.0)],
        &[0.5],
    )
    .unwrap();
    let stratum = Stratum::root(&[(0.25, 0.5)]);
    let slot = VmFn {
        program: full.program.clone(),
        theta: full.theta.clone(),
        bounds: stratum.bounds.clone(),
        stream: 9,
    };
    let rng = RngCtr { seed: [777, 0], base: 0, trial: 0 };
    let inputs =
        vm_multi_inputs(exe, rng, std::slice::from_ref(&slot)).unwrap();
    let out = dev.execute(&exe.name, &inputs).unwrap();
    let m = MomentSum::from_device(
        exe.samples as u64,
        out.data[0],
        out.data[1],
    );
    let (value, std_err) = m.estimate(stratum.volume());

    // unstratified: the same box as a first-class job via the engine
    let (_, eng) = engine(1);
    let job = IntegralJob::with_params(
        "x1*x1 + p0",
        &[(0.25, 0.5)],
        &[0.5],
    )
    .unwrap();
    let cfg = MultiConfig {
        samples_per_fn: exe.samples,
        seed: 777,
        stream_base: 9,
        exe: Some(exe.name.clone()),
        ..Default::default()
    };
    let est = multifunctions::integrate(&eng, &[job], &cfg).unwrap()[0];

    assert_eq!(est.value, value, "remapped launch must be bit-exact");
    assert_eq!(est.std_err, std_err);
    assert_eq!(est.n_samples, exe.samples as u64);
}

/// Two strata partitioning a domain, each sampled by its own remapped
/// launch, must combine to an estimate consistent with the analytic
/// integral — and with the single full-domain launch.
#[test]
fn strata_partition_combines_consistently() {
    let reg = Arc::new(Registry::emulated());
    let exe = reg.get("vm_multi_f8_s4096").unwrap();
    let dev = DeviceRuntime::new(Arc::clone(&reg)).unwrap();
    let job = IntegralJob::parse("x1", &[(0.0, 2.0)]).unwrap();
    let root = Stratum::root(&job.bounds);
    let (lo, hi) = root.split(0);
    assert_eq!(lo.bounds, vec![(0.0, 1.0)]);
    assert_eq!(hi.bounds, vec![(1.0, 2.0)]);

    let mut parts = Vec::new();
    for (i, s) in [&lo, &hi].into_iter().enumerate() {
        let slot = VmFn {
            program: job.program.clone(),
            theta: vec![],
            bounds: s.bounds.clone(),
            stream: 100 + i as u32,
        };
        let rng = RngCtr { seed: [5, 0], base: 0, trial: 0 };
        let inputs =
            vm_multi_inputs(exe, rng, std::slice::from_ref(&slot)).unwrap();
        let out = dev.execute(&exe.name, &inputs).unwrap();
        parts.push((
            s.volume(),
            MomentSum::from_device(
                exe.samples as u64,
                out.data[0],
                out.data[1],
            ),
        ));
    }
    let (value, std_err) = stratified_estimate(&parts);
    // ∫₀² x dx = 2; stratification must stay consistent with truth
    assert!(
        (value - 2.0).abs() <= 6.0 * std_err,
        "stratified {value} ± {std_err}"
    );
    assert!(std_err > 0.0 && std_err < 0.05);
}

#[test]
fn adaptive_meets_target_and_reports_breakdown() {
    let (reg, eng) = engine(2);
    let jobs = mixed_jobs();
    let cfg = MultiConfig {
        samples_per_fn: 1 << 17,
        seed: 424242,
        target_rel_err: Some(5e-3),
        ..Default::default()
    };
    let (ests, report) =
        adaptive::integrate_with_report(&eng, &jobs, &cfg).unwrap();
    assert_eq!(ests.len(), jobs.len());
    for (i, e) in ests.iter().enumerate() {
        assert!(
            e.std_err <= 5e-3 * e.value.abs(),
            "fn {i} missed target: {e:?}"
        );
        assert!(e.n_samples > 0);
        assert!(e.rounds >= 1);
    }
    assert_eq!(report.converged, jobs.len());
    // the peak must have soaked up more budget and more rounds than
    // the smooth integrands, which converge on the pilot
    let easy = &ests[0];
    let hard = &ests[3];
    assert!(
        hard.n_samples > easy.n_samples,
        "budget did not flow to the hard integrand: {easy:?} {hard:?}"
    );
    assert!(hard.rounds > easy.rounds);
    // ... while spending well under the uniform-equivalent budget
    let budget = (1u64 << 17) * jobs.len() as u64;
    assert!(
        report.total_samples < budget / 2,
        "adaptive spent {} of {budget}",
        report.total_samples
    );
    assert_eq!(
        report.samples_per_round.iter().sum::<u64>(),
        report.total_samples
    );
    assert!(report.launches > 0);
    // one executable, two workers: at most one compile per worker no
    // matter how many refinement rounds ran — stratified launches ride
    // the warm caches
    assert!(reg.compile_count() <= 2, "{}", reg.compile_count());
}

#[test]
fn adaptive_estimates_are_consistent_with_truth() {
    let (_, eng) = engine(1);
    let jobs = vec![
        IntegralJob::parse("x1^2", &[(0.0, 1.0)]).unwrap(), // 1/3
        IntegralJob::parse("x1*x2", &[(0.0, 1.0), (0.0, 2.0)]).unwrap(), // 1
        IntegralJob::parse("2", &[(0.0, 1.0)]).unwrap(), // 2 exactly
    ];
    let cfg = MultiConfig {
        samples_per_fn: 1 << 15,
        seed: 7,
        target_rel_err: Some(1e-2),
        target_abs_err: Some(1e-4),
        ..Default::default()
    };
    let ests = multifunctions::integrate(&eng, &jobs, &cfg).unwrap();
    assert!(ests[0].consistent_with(1.0 / 3.0, 6.0), "{:?}", ests[0]);
    assert!(ests[1].consistent_with(1.0, 6.0), "{:?}", ests[1]);
    // constant integrand: zero variance, converged on the pilot
    assert!(ests[2].consistent_with(2.0, 6.0), "{:?}", ests[2]);
    assert_eq!(ests[2].std_err, 0.0);
    assert_eq!(ests[2].rounds, 1);
}

#[test]
fn adaptive_is_deterministic() {
    let jobs = mixed_jobs();
    let cfg = MultiConfig {
        samples_per_fn: 1 << 15,
        seed: 99,
        target_rel_err: Some(1e-2),
        allocation: Allocation::Neyman,
        ..Default::default()
    };
    let (_, e1) = engine(1);
    let a = multifunctions::integrate(&e1, &jobs, &cfg).unwrap();
    // fresh engine, more workers: same Philox addressing, same results
    let (_, e2) = engine(3);
    let b = multifunctions::integrate(&e2, &jobs, &cfg).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.value, y.value);
        assert_eq!(x.std_err, y.std_err);
        assert_eq!(x.n_samples, y.n_samples);
        assert_eq!(x.rounds, y.rounds);
    }
}

#[test]
fn no_target_spends_the_full_budget_adaptively() {
    let (_, eng) = engine(1);
    let jobs = vec![
        IntegralJob::parse("x1 + 1", &[(0.0, 1.0)]).unwrap(),
        IntegralJob::parse("x2*x2 + x1", &[(0.0, 1.0), (0.0, 1.0)])
            .unwrap(),
    ];
    // no error target: pure budget shaping — the whole pool is spent
    let cfg = MultiConfig {
        samples_per_fn: 1 << 16,
        seed: 11,
        ..Default::default()
    };
    let (ests, report) =
        adaptive::integrate_with_report(&eng, &jobs, &cfg).unwrap();
    let budget = (1u64 << 16) * jobs.len() as u64;
    assert_eq!(report.total_samples, budget);
    assert_eq!(report.converged, 0);
    for e in &ests {
        assert!(e.n_samples > 0);
        assert!(e.rounds >= 2);
    }
}

#[test]
fn adaptive_handles_empty_and_single_batches() {
    let (_, eng) = engine(1);
    let cfg = MultiConfig {
        target_rel_err: Some(1e-2),
        samples_per_fn: 1 << 14,
        ..Default::default()
    };
    let empty = multifunctions::integrate(&eng, &[], &cfg).unwrap();
    assert!(empty.is_empty());
    let one = IntegralJob::parse("x1", &[(0.0, 1.0)]).unwrap();
    let ests = multifunctions::integrate(&eng, &[one], &cfg).unwrap();
    assert_eq!(ests.len(), 1);
    assert!(ests[0].consistent_with(0.5, 6.0), "{:?}", ests[0]);
}
