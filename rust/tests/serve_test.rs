//! End-to-end tests of `zmc serve`: a real server on a loopback port,
//! driven by a hand-rolled HTTP client.
//!
//! The load-bearing assertions are bit-identity ones: estimates
//! streamed over `POST /v1/jobs`, recalled via `GET /v1/jobs/{id}`,
//! and recomputed by journal replay after a simulated crash must all
//! equal `Session::run_job` on the same config exactly — the service
//! is a transport, never a perturbation. The production edges (429
//! busy, 429 rate-limited, 400 typed rejections, 404/405/413) are
//! exercised against the same live server.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use zmc::config::JobConfig;
use zmc::integrator::spec::Estimate;
use zmc::serve::{Journal, ServeConfig, Server, StopHandle};
use zmc::session::{ErrorPayload, Session};
use zmc::util::json::Json;
use zmc::util::proptest::{check, Gen};

// ------------------------------------------------------------ harness

/// A server on an OS-assigned loopback port, stopped (and its workers
/// drained) on drop.
struct TestServer {
    addr: SocketAddr,
    stop: StopHandle,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl TestServer {
    fn start(mut cfg: ServeConfig) -> TestServer {
        cfg.addr = "127.0.0.1:0".into();
        let server = Server::bind(cfg).unwrap();
        let addr = server.local_addr().unwrap();
        let stop = server.stop_handle();
        let thread =
            std::thread::spawn(move || server.run().unwrap());
        TestServer { addr, stop, thread: Some(thread) }
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.stop.stop();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// A session built exactly as the server builds its own, so local
/// results are the bit-identity reference.
fn local_session() -> Session {
    Session::builder()
        .artifacts_or_emulator("artifacts")
        .build()
        .unwrap()
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("zmc_serve_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

// ------------------------------------------------------- mini client

struct Response {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
}

impl Response {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }

    fn json(&self) -> Json {
        Json::parse(self.body.trim()).unwrap()
    }

    /// The streamed body as parsed JSON lines.
    fn lines(&self) -> Vec<Json> {
        self.body
            .lines()
            .filter(|l| !l.trim().is_empty())
            .map(|l| Json::parse(l).unwrap())
            .collect()
    }

    fn error_code(&self) -> String {
        self.json()
            .path(&["error", "code"])
            .and_then(Json::as_str)
            .unwrap()
            .to_string()
    }
}

fn raw_request(method: &str, path: &str, body: Option<&str>) -> String {
    match body {
        Some(b) => format!(
            "{method} {path} HTTP/1.1\r\nhost: t\r\n\
             content-length: {}\r\n\r\n{b}",
            b.len()
        ),
        None => format!("{method} {path} HTTP/1.1\r\nhost: t\r\n\r\n"),
    }
}

/// One full request/response cycle (waits for the job when POSTing).
fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> Response {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(raw_request(method, path, body).as_bytes()).unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    parse_response(&buf)
}

fn get(addr: SocketAddr, path: &str) -> Response {
    request(addr, "GET", path, None)
}

fn post_job(addr: SocketAddr, body: &str) -> Response {
    request(addr, "POST", "/v1/jobs", Some(body))
}

fn parse_response(buf: &[u8]) -> Response {
    let text = String::from_utf8(buf.to_vec()).unwrap();
    let (head, body) = text.split_once("\r\n\r\n").unwrap();
    let mut lines = head.lines();
    let status: u16 = lines
        .next()
        .unwrap()
        .split_whitespace()
        .nth(1)
        .unwrap()
        .parse()
        .unwrap();
    let headers: Vec<(String, String)> = lines
        .map(|l| {
            let (n, v) = l.split_once(':').unwrap();
            (n.trim().to_ascii_lowercase(), v.trim().to_string())
        })
        .collect();
    let chunked = headers
        .iter()
        .any(|(n, v)| n == "transfer-encoding" && v == "chunked");
    let body =
        if chunked { dechunk(body) } else { body.to_string() };
    Response { status, headers, body }
}

/// Reassemble a chunked body (sizes are hex, ASCII payload).
fn dechunk(s: &str) -> String {
    let mut out = String::new();
    let mut rest = s;
    loop {
        let Some((size_line, tail)) = rest.split_once("\r\n") else {
            break;
        };
        let Ok(size) = usize::from_str_radix(size_line.trim(), 16)
        else {
            break;
        };
        if size == 0 || tail.len() < size {
            break;
        }
        out.push_str(&tail[..size]);
        rest = tail[size..].strip_prefix("\r\n").unwrap_or(&tail[size..]);
    }
    out
}

/// Incremental stream reader: lets a test act mid-job (e.g. submit a
/// competing request while the first still holds the job slot).
struct JobStream {
    reader: BufReader<TcpStream>,
}

impl JobStream {
    fn post(addr: SocketAddr, body: &str) -> JobStream {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw_request("POST", "/v1/jobs", Some(body)).as_bytes())
            .unwrap();
        let mut reader = BufReader::new(s);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("HTTP/1.1 200"), "{line}");
        loop {
            line.clear();
            reader.read_line(&mut line).unwrap();
            if line == "\r\n" {
                break;
            }
        }
        JobStream { reader }
    }

    /// Next streamed JSON line, `None` at the terminal zero chunk.
    fn next_line(&mut self) -> Option<Json> {
        let mut size_line = String::new();
        if self.reader.read_line(&mut size_line).unwrap() == 0 {
            return None;
        }
        let size = usize::from_str_radix(size_line.trim(), 16).ok()?;
        if size == 0 {
            return None;
        }
        let mut chunk = vec![0u8; size];
        self.reader.read_exact(&mut chunk).unwrap();
        let mut crlf = [0u8; 2];
        self.reader.read_exact(&mut crlf).unwrap();
        Some(Json::parse(String::from_utf8(chunk).unwrap().trim()).unwrap())
    }
}

// -------------------------------------------------------- job configs

fn small_multi() -> JobConfig {
    let mut c =
        JobConfig::from_json_text(&JobConfig::example_json()).unwrap();
    c.samples_per_fn = 1 << 10;
    c.trials = 2;
    c.target_rel_err = None;
    c.target_abs_err = None;
    c
}

fn small_functional() -> JobConfig {
    let mut c = JobConfig::from_json_text(
        &JobConfig::example_json_functional(),
    )
    .unwrap();
    c.samples_per_fn = 1 << 10;
    c
}

fn small_normal() -> JobConfig {
    JobConfig::from_json_text(&JobConfig::example_json_normal()).unwrap()
}

/// An adaptive job with an unreachable target: runs its full round
/// budget, streaming a frame per round — the deterministic way to hold
/// the job slot while a test pokes the server from the side.
fn slow_adaptive() -> JobConfig {
    let mut c = small_multi();
    c.trials = 1;
    c.samples_per_fn = 1 << 14;
    c.target_rel_err = Some(1e-12);
    c.max_rounds = Some(12);
    c
}

/// `per_trial[t][i]` reconstructed from a stream's `"final": true`
/// frames — the client-side view of the job's result.
fn finals_per_trial(frames: &[Json]) -> Vec<Vec<Estimate>> {
    let mut per_trial: Vec<Vec<(i64, Estimate)>> = Vec::new();
    for f in frames {
        if !matches!(f.get("final"), Some(Json::Bool(true))) {
            continue;
        }
        let t = f.get("trial").and_then(Json::as_usize).unwrap();
        let i = f.get("fn").and_then(Json::as_i64).unwrap();
        let e = Estimate::from_json(f).unwrap();
        if per_trial.len() <= t {
            per_trial.resize(t + 1, Vec::new());
        }
        per_trial[t].push((i, e));
    }
    per_trial
        .into_iter()
        .map(|mut fns| {
            fns.sort_by_key(|(i, _)| *i);
            fns.into_iter().map(|(_, e)| e).collect()
        })
        .collect()
}

/// Estimates from a recall body's `result.trials` array.
fn recalled_trials(body: &Json) -> Vec<Vec<Estimate>> {
    body.path(&["result", "trials"])
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|t| {
            t.as_arr()
                .unwrap()
                .iter()
                .map(|e| Estimate::from_json(e).unwrap())
                .collect()
        })
        .collect()
}

// -------------------------------------------------------------- tests

#[test]
fn healthz_and_metrics_report_topology_and_counters() {
    let srv = TestServer::start(ServeConfig::default());
    let h = get(srv.addr, "/v1/healthz").json();
    assert_eq!(h.path(&["status"]).and_then(Json::as_str), Some("ok"));
    assert_eq!(h.get("v").and_then(Json::as_i64), Some(1));
    assert_eq!(h.get("engines").and_then(Json::as_i64), Some(1));

    let body = small_normal().to_json().to_string();
    assert_eq!(post_job(srv.addr, &body).status, 200);
    let m = get(srv.addr, "/v1/metrics").json();
    assert_eq!(
        m.path(&["server", "accepted"]).and_then(Json::as_i64),
        Some(1)
    );
    assert_eq!(
        m.path(&["server", "done"]).and_then(Json::as_i64),
        Some(1)
    );
    assert!(m.path(&["engine", "tasks_done"]).is_some());
    assert!(m.path(&["registry", "compiles"]).is_some());
}

#[test]
fn streamed_job_is_bit_identical_and_recallable() {
    let srv = TestServer::start(ServeConfig::default());
    let cfg = small_multi();
    let resp = post_job(srv.addr, &cfg.to_json().to_string());
    assert_eq!(resp.status, 200);
    assert_eq!(
        resp.header("content-type"),
        Some("application/x-ndjson")
    );
    let frames = resp.lines();
    let id = frames[0].get("id").and_then(Json::as_i64).unwrap();
    assert_eq!(
        frames[0].get("status").and_then(Json::as_str),
        Some("running")
    );
    let last = frames.last().unwrap();
    assert_eq!(last.get("status").and_then(Json::as_str), Some("done"));
    assert_eq!(last.get("id").and_then(Json::as_i64), Some(id));
    // every estimate frame carries the id and the codec fields
    assert!(frames[1..frames.len() - 1]
        .iter()
        .all(|f| f.get("id").and_then(Json::as_i64) == Some(id)));

    let streamed = finals_per_trial(&frames);
    let want = local_session().run_job(&cfg).unwrap();
    assert_eq!(streamed, want.per_trial, "stream diverged from local");

    let recall = get(srv.addr, &format!("/v1/jobs/{id}"));
    assert_eq!(recall.status, 200);
    let body = recall.json();
    assert_eq!(
        body.get("status").and_then(Json::as_str),
        Some("done")
    );
    assert_eq!(recalled_trials(&body), want.per_trial);
}

#[test]
fn all_three_classes_round_trip_bit_identically() {
    let srv = TestServer::start(ServeConfig::default());
    let local = local_session();
    for cfg in [small_multi(), small_functional(), small_normal()] {
        let resp = post_job(srv.addr, &cfg.to_json().to_string());
        assert_eq!(resp.status, 200, "{}: {}", cfg.class.name(), resp.body);
        let frames = resp.lines();
        assert_eq!(
            frames.last().unwrap().get("status").and_then(Json::as_str),
            Some("done"),
            "{}",
            cfg.class.name()
        );
        let want = local.run_job(&cfg).unwrap();
        assert_eq!(
            finals_per_trial(&frames),
            want.per_trial,
            "{} diverged over the wire",
            cfg.class.name()
        );
    }
}

#[test]
fn adaptive_job_streams_rounds_before_finals() {
    let srv = TestServer::start(ServeConfig::default());
    let mut cfg = small_multi();
    cfg.trials = 1;
    cfg.samples_per_fn = 1 << 12;
    cfg.target_rel_err = Some(0.05);
    let resp = post_job(srv.addr, &cfg.to_json().to_string());
    assert_eq!(resp.status, 200);
    let frames = resp.lines();
    let rounds = frames
        .iter()
        .filter(|f| f.get("round").is_some())
        .count();
    assert!(rounds >= cfg.jobs.len(), "pilot round streams per fn");
    assert_eq!(
        finals_per_trial(&frames),
        local_session().run_job(&cfg).unwrap().per_trial
    );
}

#[test]
fn invalid_jobs_are_rejected_with_typed_codes() {
    let srv = TestServer::start(ServeConfig {
        max_body: 4096,
        ..ServeConfig::default()
    });
    // malformed JSON
    let r = post_job(srv.addr, "not json");
    assert_eq!(r.status, 400);
    assert_eq!(r.error_code(), "bad_json");
    // wrong wire version
    let mut v2 = small_multi().to_json();
    if let Json::Obj(m) = &mut v2 {
        m.insert("v".to_string(), Json::Num(2.0));
    }
    let r = post_job(srv.addr, &v2.to_string());
    assert_eq!(r.status, 400);
    assert_eq!(r.error_code(), "unsupported_version");
    // class-inapplicable option
    let mut bad = small_normal();
    bad.trials = 3;
    let r = post_job(srv.addr, &bad.to_json().to_string());
    assert_eq!(r.status, 400);
    assert_eq!(r.error_code(), "inapplicable_option");
    // unknown job / route / method / oversized body
    let r = get(srv.addr, "/v1/jobs/999");
    assert_eq!(r.status, 404);
    assert_eq!(r.error_code(), "not_found");
    assert_eq!(get(srv.addr, "/v2/jobs").status, 404);
    let r = request(srv.addr, "POST", "/v1/metrics", Some("{}"));
    assert_eq!(r.status, 405);
    // oversized: declare a too-large body without sending it (the
    // server rejects on the declaration, before reading a body byte)
    let mut s = TcpStream::connect(srv.addr).unwrap();
    s.write_all(
        b"POST /v1/jobs HTTP/1.1\r\nhost: t\r\n\
          content-length: 8192\r\n\r\n",
    )
    .unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let r = parse_response(&buf);
    assert_eq!(r.status, 413);
    assert_eq!(r.error_code(), "too_large");
    // none of the rejections created a job
    let m = get(srv.addr, "/v1/metrics").json();
    assert_eq!(
        m.path(&["server", "accepted"]).and_then(Json::as_i64),
        Some(0)
    );
    // the three 400s and the 413 count; 404/405 routing misses don't
    assert_eq!(
        m.path(&["server", "bad_requests"]).and_then(Json::as_i64),
        Some(4)
    );
}

#[test]
fn full_server_answers_429_busy_with_retry_after() {
    let srv = TestServer::start(ServeConfig {
        max_jobs: 1,
        http_workers: 2,
        ..ServeConfig::default()
    });
    let mut stream =
        JobStream::post(srv.addr, &slow_adaptive().to_json().to_string());
    // the accepted frame proves the slot is held before we poke again
    let first = stream.next_line().unwrap();
    assert_eq!(
        first.get("status").and_then(Json::as_str),
        Some("running")
    );
    let r = post_job(srv.addr, "{}");
    assert_eq!(r.status, 429);
    assert_eq!(r.error_code(), "busy");
    assert_eq!(r.header("retry-after"), Some("1"));
    // drain the slow job; its stream still ends in a clean terminal
    let mut last = first;
    while let Some(l) = stream.next_line() {
        last = l;
    }
    assert_eq!(last.get("status").and_then(Json::as_str), Some("done"));
    // the slot frees once the job finishes (poll: release is
    // microseconds after the terminal frame, not atomic with it)
    let t0 = Instant::now();
    loop {
        let r = post_job(srv.addr, "{}");
        if r.status == 400 {
            break; // admitted past the slot check, rejected on parse
        }
        assert_eq!(r.status, 429);
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "job slot never released"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn rate_limiter_answers_429_with_retry_after() {
    let srv = TestServer::start(ServeConfig {
        rate_limit: Some(0.01),
        rate_burst: 1.0,
        ..ServeConfig::default()
    });
    // burst of 1: the first request consumes it (limiter runs before
    // parsing, so a 400 still spends the token)...
    assert_eq!(post_job(srv.addr, "{}").status, 400);
    // ...and the second is rate-limited with the refill wait
    let r = post_job(srv.addr, "{}");
    assert_eq!(r.status, 429);
    assert_eq!(r.error_code(), "rate_limited");
    let wait: u64 = r.header("retry-after").unwrap().parse().unwrap();
    assert!(wait >= 1, "retry-after {wait}");
    let m = get(srv.addr, "/v1/metrics").json();
    assert_eq!(
        m.path(&["server", "rejected_rate"]).and_then(Json::as_i64),
        Some(1)
    );
}

#[test]
fn journal_replays_interrupted_jobs_bit_identically() {
    let dir = temp_dir("replay");
    let cfg = small_multi();
    // simulate a server that accepted job 1 and died mid-flight: the
    // journal holds a submit record with no terminal
    {
        let j = Journal::open(&dir).unwrap();
        j.submitted(1, &cfg.to_json()).unwrap();
    }
    let srv = TestServer::start(ServeConfig {
        state_dir: Some(dir.clone()),
        ..ServeConfig::default()
    });
    // the replay thread re-runs job 1; poll until it lands
    let t0 = Instant::now();
    let body = loop {
        let r = get(srv.addr, "/v1/jobs/1");
        assert_eq!(r.status, 200, "journaled job must be known");
        let b = r.json();
        match b.get("status").and_then(Json::as_str) {
            Some("done") => break b,
            Some("running") => {
                assert!(
                    t0.elapsed() < Duration::from_secs(60),
                    "replay never finished"
                );
                std::thread::sleep(Duration::from_millis(20));
            }
            other => panic!("unexpected status {other:?}"),
        }
    };
    let want = local_session().run_job(&cfg).unwrap();
    assert_eq!(
        recalled_trials(&body),
        want.per_trial,
        "replayed result diverged"
    );
    // ids continue after the journaled ones
    let resp = post_job(srv.addr, &small_normal().to_json().to_string());
    let frames = resp.lines();
    assert_eq!(frames[0].get("id").and_then(Json::as_i64), Some(2));
    drop(srv);

    // a second restart recalls both results straight from the journal
    let srv = TestServer::start(ServeConfig {
        state_dir: Some(dir.clone()),
        ..ServeConfig::default()
    });
    let body = get(srv.addr, "/v1/jobs/1").json();
    assert_eq!(body.get("status").and_then(Json::as_str), Some("done"));
    assert_eq!(recalled_trials(&body), want.per_trial);
    assert_eq!(
        get(srv.addr, "/v1/jobs/2")
            .json()
            .get("status")
            .and_then(Json::as_str),
        Some("done")
    );
    drop(srv);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn recall_cap_answers_413_result_too_large() {
    // max_recall of 1 estimate: small_multi (2 trials) always exceeds
    // it, so recall must refuse rather than stream the stored columns
    let srv = TestServer::start(ServeConfig {
        max_recall: 1,
        ..ServeConfig::default()
    });
    let resp = post_job(srv.addr, &small_multi().to_json().to_string());
    assert_eq!(resp.status, 200);
    let frames = resp.lines();
    assert_eq!(
        frames.last().unwrap().get("status").and_then(Json::as_str),
        Some("done"),
        "the job itself still runs and streams"
    );
    let id = frames[0].get("id").and_then(Json::as_i64).unwrap();
    let r = get(srv.addr, &format!("/v1/jobs/{id}"));
    assert_eq!(r.status, 413);
    assert_eq!(r.error_code(), "result_too_large");
}

#[test]
fn journal_compaction_prunes_finished_jobs_but_keeps_ids() {
    let dir = temp_dir("compact");
    {
        let srv = TestServer::start(ServeConfig {
            state_dir: Some(dir.clone()),
            ..ServeConfig::default()
        });
        let resp =
            post_job(srv.addr, &small_normal().to_json().to_string());
        assert_eq!(
            resp.lines().last().unwrap().get("status").and_then(Json::as_str),
            Some("done")
        );
    }
    // restart with keep=0: the finished job's records compact away on
    // bind, but the seq record keeps its id retired
    let srv = TestServer::start(ServeConfig {
        state_dir: Some(dir.clone()),
        journal_keep: 0,
        ..ServeConfig::default()
    });
    assert_eq!(get(srv.addr, "/v1/jobs/1").status, 404);
    let resp = post_job(srv.addr, &small_normal().to_json().to_string());
    assert_eq!(
        resp.lines()[0].get("id").and_then(Json::as_i64),
        Some(2),
        "compaction must never reissue a pruned id"
    );
    drop(srv);
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------- codec round trips

fn wild_f64(g: &mut Gen) -> f64 {
    match g.below(8) {
        0 => 0.0,
        1 => -0.0,
        2 => f64::INFINITY,
        3 => f64::NEG_INFINITY,
        4 => g.range_f64(-1.0, 1.0) * 1e-300,
        5 => g.range_f64(-1.0, 1.0) * 1e300,
        6 => g.range_i64(-1_000_000, 1_000_000) as f64,
        _ => g.range_f64(-1e6, 1e6),
    }
}

#[test]
fn estimate_codec_round_trips_bit_exactly() {
    check(0xE57, 300, |g| {
        let e = Estimate {
            value: wild_f64(g),
            std_err: wild_f64(g).abs(),
            n_samples: g.next_u64() >> 14,
            rounds: g.below(1 << 16) as u32,
        };
        let back = Estimate::from_json(&e.to_json()).unwrap();
        assert_eq!(back.value.to_bits(), e.value.to_bits());
        assert_eq!(back.std_err.to_bits(), e.std_err.to_bits());
        assert_eq!(back.n_samples, e.n_samples);
        assert_eq!(back.rounds, e.rounds);
    });
}

#[test]
fn job_config_codec_round_trips() {
    let examples: [fn() -> String; 3] = [
        JobConfig::example_json,
        JobConfig::example_json_functional,
        JobConfig::example_json_normal,
    ];
    check(0xC0F, 100, |g| {
        let mut c =
            JobConfig::from_json_text(&examples[g.below(3)]()).unwrap();
        c.samples_per_fn = 1 << (6 + g.below(10));
        // seeds ride the wire as f64 — stay within exact-integer range
        c.seed = g.next_u64() >> 12;
        c.workers = 1 + g.below(4);
        c.num_engines = 1 + g.below(4);
        if matches!(c.class, zmc::config::JobClass::Multifunctions) {
            c.trials = 1 + g.below(5) as u32;
            if g.bool() {
                c.target_rel_err = Some(g.range_f64(1e-4, 0.5));
            }
            if g.bool() {
                c.max_rounds = Some(1 + g.below(20));
            }
        }
        let back = JobConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
    });
}

#[test]
fn error_payload_codec_round_trips() {
    let chars: Vec<char> =
        "ab\"\\\n\t{}[]:,€ 0".chars().collect();
    check(0xEA7, 200, |g| {
        let mut rand_str = |g: &mut Gen| -> String {
            (0..g.below(24)).map(|_| *g.choose(&chars)).collect()
        };
        let p = ErrorPayload::new(rand_str(g), rand_str(g));
        let back = ErrorPayload::from_json(&p.to_json()).unwrap();
        assert_eq!(back.code, p.code);
        assert_eq!(back.message, p.message);
    });
}
