//! Differential property suite for the optimizing VM pipeline: random
//! valid stack programs and inputs must evaluate **bit-exactly** the
//! same through (a) [`ExecPlan`] execution over raw uniforms with the
//! folded affine domain map, (b) the columnar stack oracle
//! [`BatchInterp`] over pre-mapped coordinates, and (c) the per-lane
//! scalar f32 interpreter [`eval_scalar_f32`] — exercising every
//! lowering pass (CSE duplicates, foldable constant clusters, uniform
//! parameter subtrees, MUL→ADD/SUB fusion sites) by construction, and
//! sanity-bounding against the f64 oracle [`eval_scalar`].

use zmc::abi::{MAX_DIM, MAX_PARAM, STACK};
use zmc::sampler::StreamKey;
use zmc::util::proptest::{check, Gen};
use zmc::vm::fused::{FusedPlan, FusedScratch, LANES};
use zmc::vm::interp::{eval_scalar, eval_scalar_f32, BatchInterp};
use zmc::vm::plan::{ExecPlan, PlanScratch};
use zmc::vm::program::{Instr, Program};
use zmc::vm::Op;

const UNARIES: &[Op] = &[
    Op::NEG,
    Op::ABS,
    Op::SIN,
    Op::COS,
    Op::TAN,
    Op::EXP,
    Op::LOG,
    Op::SQRT,
    Op::TANH,
    Op::ATAN,
    Op::FLOOR,
    Op::SQUARE,
    Op::RECIP,
];
const BINARIES: &[Op] =
    &[Op::ADD, Op::SUB, Op::MUL, Op::DIV, Op::POW, Op::MIN, Op::MAX];

/// Generate a random valid stack program: pushes and operations chosen
/// so the stack discipline holds, then the stack is reduced to depth 1
/// with binaries. Biases toward MUL-feeding-ADD shapes (fusion sites)
/// and repeated small leaf pools (CSE/fold sites).
fn gen_program(g: &mut Gen, dims: usize, params: usize) -> Program {
    let body = 3 + g.below(24);
    let mut instrs: Vec<Instr> = Vec::with_capacity(body + STACK);
    let mut depth = 0i32;
    // small leaf pools so identical subexpressions actually recur
    let consts: Vec<f32> =
        (0..3).map(|_| g.range_f32(-3.0, 3.0)).collect();
    for _ in 0..body {
        let can_bin = depth >= 2;
        let can_un = depth >= 1;
        let must_push = depth < (STACK as i32) && !can_un;
        let roll = g.below(10);
        if must_push || (depth < STACK as i32 && roll < 4) {
            instrs.push(match g.below(4) {
                0 => Instr::konst(*g.choose(&consts)),
                1 => Instr::var(g.below(dims)),
                2 if params > 0 => Instr::param(g.below(params)),
                _ => Instr::var(g.below(dims)),
            });
            depth += 1;
        } else if can_bin && (roll < 8 || !can_un) {
            // bias toward the fusion pair: MUL often directly under ADD
            let op = if g.below(3) == 0 {
                Op::MUL
            } else {
                *g.choose(BINARIES)
            };
            instrs.push(Instr::new(op));
            depth -= 1;
        } else if can_un {
            instrs.push(Instr::new(*g.choose(UNARIES)));
        }
    }
    while depth > 1 {
        instrs.push(Instr::new(if g.bool() {
            Op::ADD
        } else {
            *g.choose(BINARIES)
        }));
        depth -= 1;
    }
    if depth == 0 {
        instrs.push(Instr::konst(1.0));
    }
    Program::new(instrs).expect("generator keeps stack discipline")
}

#[test]
fn plan_batch_and_scalar_f32_agree_bitwise() {
    check(0x9C0F_FEE5, 300, |g| {
        let dims = 1 + g.below(MAX_DIM);
        let params = g.below(MAX_PARAM.min(6));
        let prog = gen_program(g, dims, params.max(1));
        let plan = ExecPlan::lower(&prog);
        assert_eq!(plan.dims, prog.dims);
        assert_eq!(plan.n_params, prog.n_params);

        let chunk = 64;
        let n = 1 + g.below(chunk);
        let theta: Vec<f32> =
            (0..MAX_PARAM).map(|_| g.range_f32(-2.0, 2.0)).collect();
        let lo: Vec<f32> =
            (0..dims).map(|_| g.range_f32(-2.0, 1.0)).collect();
        let hi: Vec<f32> = lo
            .iter()
            .map(|&l| l + g.range_f32(0.1, 3.0))
            .collect();
        let u: Vec<Vec<f32>> = (0..dims)
            .map(|_| (0..chunk).map(|_| g.range_f32(0.0, 1.0)).collect())
            .collect();
        // the affine domain map, applied exactly as the device does
        let xt: Vec<Vec<f32>> = (0..dims)
            .map(|d| {
                u[d].iter()
                    .map(|&ui| lo[d] + (hi[d] - lo[d]) * ui)
                    .collect()
            })
            .collect();

        let mut interp = BatchInterp::new(chunk);
        let mut want = vec![0f32; chunk];
        interp.eval(&prog, &xt, &theta, n, &mut want);

        let mut scratch = PlanScratch::new(chunk);
        let mut got = vec![0f32; chunk];
        plan.run(&u, &lo, &hi, &theta, n, &mut scratch, &mut got);

        let mut x = vec![0f32; dims];
        for i in 0..n {
            for d in 0..dims {
                x[d] = xt[d][i];
            }
            let scalar = eval_scalar_f32(&prog, &x, &theta);
            assert_eq!(
                got[i].to_bits(),
                want[i].to_bits(),
                "plan vs batch, lane {i}\n{}",
                prog.disasm()
            );
            assert_eq!(
                got[i].to_bits(),
                scalar.to_bits(),
                "plan vs scalar-f32, lane {i}\n{}",
                prog.disasm()
            );
        }
    });
}

#[test]
fn plan_tracks_f64_oracle_on_tame_programs() {
    // the f64 oracle can't be bit-exact (different rounding), but on
    // numerically tame programs the plan result must stay within a
    // loose f32 relative envelope of the f64 value
    check(0x0F64_0A11, 150, |g| {
        let dims = 1 + g.below(3);
        // tame ops only: no EXP/POW blowups, no LOG/SQRT domain edges
        let prog = {
            let mut instrs = vec![Instr::var(0)];
            let mut depth = 1i32;
            for _ in 0..8 {
                if depth >= 2 && g.bool() {
                    instrs.push(Instr::new(
                        *g.choose(&[Op::ADD, Op::SUB, Op::MUL]),
                    ));
                    depth -= 1;
                } else if g.bool() {
                    instrs.push(Instr::new(
                        *g.choose(&[Op::NEG, Op::SIN, Op::COS, Op::TANH]),
                    ));
                } else {
                    instrs.push(match g.below(3) {
                        0 => Instr::konst(g.range_f32(-2.0, 2.0)),
                        1 => Instr::var(g.below(dims)),
                        _ => Instr::param(g.below(2)),
                    });
                    depth += 1;
                }
            }
            while depth > 1 {
                instrs.push(Instr::new(Op::ADD));
                depth -= 1;
            }
            Program::new(instrs).unwrap()
        };
        let plan = ExecPlan::lower(&prog);
        let theta32 = [0.75f32, -0.5];
        let theta64: Vec<f64> = theta32.iter().map(|&t| t as f64).collect();
        let lo = vec![0.0f32; dims];
        let hi = vec![1.0f32; dims];
        let chunk = 16;
        let u: Vec<Vec<f32>> = (0..dims)
            .map(|_| (0..chunk).map(|_| g.range_f32(0.0, 1.0)).collect())
            .collect();
        let mut scratch = PlanScratch::new(chunk);
        let mut got = vec![0f32; chunk];
        plan.run(&u, &lo, &hi, &theta32, chunk, &mut scratch, &mut got);
        for i in 0..chunk {
            let x64: Vec<f64> =
                (0..dims).map(|d| u[d][i] as f64).collect();
            let want = eval_scalar(&prog, &x64, &theta64);
            // loose envelope: f32 rounding compounds through mul/sub
            // chains; the bit-exact contract is the test above, this
            // one only guards against gross semantic drift
            let tol = 5e-3 * want.abs().max(1.0);
            assert!(
                (got[i] as f64 - want).abs() <= tol,
                "lane {i}: {} vs f64 {want}\n{}",
                got[i],
                prog.disasm()
            );
        }
    });
}

/// The plan-tier moment fold: Philox columns per chunk → `plan.run` →
/// carried f64 accumulator in sample order. This is exactly what the
/// emulator's plan tier computes, at an arbitrary `chunk`, so the
/// fused tier's in-kernel epilogue must reproduce it bit-for-bit.
#[allow(clippy::too_many_arguments)]
fn moments_via_plan(
    plan: &ExecPlan,
    key: &StreamKey,
    base: u32,
    samples: u32,
    lo: &[f32],
    hi: &[f32],
    theta: &[f32],
    chunk: usize,
) -> (f64, f64) {
    let dims = plan.dims;
    let mut cols = vec![vec![0f32; chunk]; dims];
    let mut scratch = PlanScratch::new(chunk);
    let mut out = vec![0f32; chunk];
    let (mut sum, mut sumsq) = (0f64, 0f64);
    let mut done = 0u32;
    while done < samples {
        let n = ((samples - done) as usize).min(chunk);
        key.fill_columns(base.wrapping_add(done), n, dims, &mut cols);
        plan.run(&cols, lo, hi, theta, n, &mut scratch, &mut out);
        for &v in &out[..n] {
            let vd = v as f64;
            sum += vd;
            sumsq += vd * vd;
        }
        done += n as u32;
    }
    (sum, sumsq)
}

/// The naive-tier moment fold: per-sample `point()` uniforms, affine
/// domain map, the columnar stack oracle [`BatchInterp`], same carried
/// f64 accumulator.
fn moments_via_interp(
    prog: &Program,
    key: &StreamKey,
    base: u32,
    samples: u32,
    lo: &[f32],
    hi: &[f32],
    theta: &[f32],
) -> (f64, f64) {
    let dims = prog.dims;
    let n = samples as usize;
    let mut xt = vec![vec![0f32; n]; dims];
    for i in 0..n {
        let p = key.point(base.wrapping_add(i as u32), dims);
        for d in 0..dims {
            xt[d][i] = lo[d] + (hi[d] - lo[d]) * p[d];
        }
    }
    let mut interp = BatchInterp::new(n.max(1));
    let mut out = vec![0f32; n.max(1)];
    interp.eval(prog, &xt, theta, n, &mut out);
    let (mut sum, mut sumsq) = (0f64, 0f64);
    for &v in &out[..n] {
        let vd = v as f64;
        sum += vd;
        sumsq += vd * vd;
    }
    (sum, sumsq)
}

#[test]
fn fused_moments_match_plan_and_naive_folds_bitwise() {
    // the three-way tier differential on random programs: the fused
    // in-kernel epilogue must equal the plan-tier fold at EVERY chunk
    // size (the carried accumulator makes chunk boundaries invisible)
    // and the naive interpreter fold, bit for bit
    check(0xF05E_D001, 60, |g| {
        let dims = 1 + g.below(4);
        let prog = gen_program(g, dims, 2);
        let fused = FusedPlan::new(ExecPlan::lower(&prog));
        let plan = ExecPlan::lower(&prog);
        let theta: Vec<f32> =
            (0..MAX_PARAM).map(|_| g.range_f32(-2.0, 2.0)).collect();
        let lo: Vec<f32> =
            (0..dims).map(|_| g.range_f32(-2.0, 1.0)).collect();
        let hi: Vec<f32> =
            lo.iter().map(|&l| l + g.range_f32(0.1, 3.0)).collect();
        let key = StreamKey::new(
            g.below(1 << 20) as u64 | 0x5EED_0000_0000,
            g.below(16) as u32,
            g.below(3) as u32,
        );
        let base = if g.bool() {
            u32::MAX - 100 // counter wraparound mid-range
        } else {
            g.below(1 << 16) as u32
        };
        let samples = 1 + g.below(LANES * 3) as u32;

        let mut fs = FusedScratch::new();
        let (fsum, fsq) = fused
            .moment_sums(&key, base, samples, &lo, &hi, &theta, &mut fs);

        for chunk in [1usize, 13, 64, LANES, LANES * 2 + 7] {
            let (psum, psq) = moments_via_plan(
                &plan, &key, base, samples, &lo, &hi, &theta, chunk,
            );
            assert_eq!(
                fsum.to_bits(),
                psum.to_bits(),
                "Σf fused vs plan(chunk={chunk})\n{}",
                prog.disasm()
            );
            assert_eq!(
                fsq.to_bits(),
                psq.to_bits(),
                "Σf² fused vs plan(chunk={chunk})\n{}",
                prog.disasm()
            );
        }

        let (nsum, nsq) = moments_via_interp(
            &prog, &key, base, samples, &lo, &hi, &theta,
        );
        assert_eq!(
            fsum.to_bits(),
            nsum.to_bits(),
            "Σf fused vs naive\n{}",
            prog.disasm()
        );
        assert_eq!(fsq.to_bits(), nsq.to_bits(), "Σf² fused vs naive");
    });
}

#[test]
fn fused_mean_tracks_f64_oracle_on_tame_program() {
    // gross-drift guard against the f64 scalar oracle: E[f] of a tame
    // integrand over the fused tier must sit within a loose envelope
    // of the mean of per-sample f64 evaluations
    let prog = {
        // sin(x1) * x2 + p0  (tame everywhere on the unit square)
        let instrs = vec![
            Instr::var(0),
            Instr::new(Op::SIN),
            Instr::var(1),
            Instr::new(Op::MUL),
            Instr::param(0),
            Instr::new(Op::ADD),
        ];
        Program::new(instrs).unwrap()
    };
    let fused = FusedPlan::new(ExecPlan::lower(&prog));
    let key = StreamKey::new(2021, 3, 0);
    let theta = [0.25f32, 0.0];
    let (lo, hi) = ([0f32, 0.0], [1f32, 1.0]);
    let samples = 4096u32;
    let mut fs = FusedScratch::new();
    let (fsum, _) = fused
        .moment_sums(&key, 0, samples, &lo, &hi, &theta, &mut fs);
    let mut want = 0f64;
    for i in 0..samples {
        let p = key.point(i, 2);
        let x = [p[0] as f64, p[1] as f64];
        want += eval_scalar(&prog, &x, &[0.25, 0.0]);
    }
    let (got, want) = (fsum / samples as f64, want / samples as f64);
    assert!(
        (got - want).abs() <= 1e-4 * want.abs().max(1.0),
        "fused mean {got} vs f64 oracle {want}"
    );
}

#[test]
fn plan_reuse_across_programs_and_chunk_sizes() {
    // one scratch serves plans of different register pressure and
    // different programs back to back (the per-worker usage pattern)
    let mut g = Gen::new(77);
    let mut scratch = PlanScratch::new(96);
    let mut out = vec![0f32; 96];
    let mut interp = BatchInterp::new(96);
    let mut want = vec![0f32; 96];
    for _ in 0..50 {
        let dims = 1 + g.below(4);
        let prog = gen_program(&mut g, dims, 2);
        let plan = ExecPlan::lower(&prog);
        let n = 1 + g.below(96);
        let u: Vec<Vec<f32>> = (0..dims)
            .map(|_| (0..96).map(|_| g.range_f32(0.0, 1.0)).collect())
            .collect();
        let lo = vec![0.0f32; dims];
        let hi = vec![1.0f32; dims];
        let theta = [0.5f32, -1.5];
        plan.run(&u, &lo, &hi, &theta, n, &mut scratch, &mut out);
        // lo=0, hi=1 makes the affine map the identity (0 + 1*u)
        interp.eval(&prog, &u, &theta, n, &mut want);
        for i in 0..n {
            assert_eq!(out[i].to_bits(), want[i].to_bits(), "lane {i}");
        }
    }
}
