//! End-to-end CLI tests: run the real `zmc` binary as a user would.
//!
//! Device-touching subcommands run against real artifacts when present,
//! else the CLI's built-in CPU emulator registry (default build). Under
//! `--features pjrt` without artifacts they skip gracefully.

use std::path::Path;
use std::process::Command;

fn zmc() -> Command {
    Command::new(env!("CARGO_BIN_EXE_zmc"))
}

fn have_artifacts() -> bool {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

/// Can device subcommands run in this build?
fn device_ok() -> bool {
    have_artifacts() || !cfg!(feature = "pjrt")
}

/// Base args plus `--artifacts DIR` when a real artifact dir exists
/// (without it the CLI falls back to the emulated registry itself).
fn with_artifacts(args: &[&str]) -> Vec<String> {
    let mut v: Vec<String> = args.iter().map(|s| s.to_string()).collect();
    if have_artifacts() {
        v.push("--artifacts".into());
        v.push(
            Path::new(env!("CARGO_MANIFEST_DIR"))
                .join("artifacts")
                .display()
                .to_string(),
        );
    }
    v
}

#[test]
fn help_lists_commands() {
    let out = zmc().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["integrate", "fig1", "normal", "scan", "run", "serve"] {
        assert!(text.contains(cmd), "help missing '{cmd}'");
    }
}

#[test]
fn no_args_prints_help_and_succeeds() {
    let out = zmc().output().unwrap();
    assert!(out.status.success());
}

#[test]
fn unknown_command_fails_with_message() {
    let out = zmc().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn integrate_rejects_missing_flags() {
    let out = zmc().arg("integrate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--expr"));
}

#[test]
fn integrate_rejects_bad_expression() {
    let out = zmc()
        .args(with_artifacts(&[
            "integrate",
            "--expr",
            "frob(x1)",
            "--bounds",
            "0,1",
        ]))
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown function"));
}

#[test]
fn info_lists_executables() {
    if !device_ok() {
        return;
    }
    let out = zmc().args(with_artifacts(&["info"])).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("harmonic_s65536_n128"));
    assert!(text.contains("vm_multi_f32_s16384"));
    assert!(text.contains("MAX_PROG=48"));
}

#[test]
fn integrate_monomial_end_to_end() {
    if !device_ok() {
        return;
    }
    let out = zmc()
        .args(with_artifacts(&[
            "integrate",
            "--expr",
            "x1^2",
            "--bounds",
            "0,1",
            "--samples",
            "16384",
        ]))
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    let val: f64 = text
        .lines()
        .find(|l| l.trim_start().starts_with("I ="))
        .and_then(|l| l.split_whitespace().nth(2))
        .unwrap()
        .parse()
        .unwrap();
    assert!((val - 1.0 / 3.0).abs() < 0.02, "I = {val}");
}

#[test]
fn integrate_adaptive_to_target() {
    if !device_ok() {
        return;
    }
    let out = zmc()
        .args(with_artifacts(&[
            "integrate",
            "--expr",
            "x1^2",
            "--bounds",
            "0,1",
            "--samples",
            "65536",
            "--target-rel-err",
            "0.01",
        ]))
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("adaptive"), "{text}");
    assert!(text.contains("rounds"), "{text}");
    let val: f64 = text
        .lines()
        .find(|l| l.trim_start().starts_with("I ="))
        .and_then(|l| l.split_whitespace().nth(2))
        .unwrap()
        .parse()
        .unwrap();
    assert!((val - 1.0 / 3.0).abs() < 0.02, "I = {val}");
}

#[test]
fn integrate_num_engines_matches_single_engine() {
    if !device_ok() {
        return;
    }
    let run = |engines: &str| -> String {
        let out = zmc()
            .args(with_artifacts(&[
                "integrate",
                "--expr",
                "sin(x1)*x2",
                "--bounds",
                "0,3.1416;0,1",
                "--samples",
                "32768",
                "--num-engines",
                engines,
            ]))
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "{}",
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8_lossy(&out.stdout)
            .lines()
            .find(|l| l.trim_start().starts_with("I ="))
            .unwrap()
            .to_string()
    };
    // sharding across engines must not perturb the reported estimate
    let single = run("1");
    let quad = run("4");
    assert_eq!(single, quad, "cluster CLI output diverged");
}

#[test]
fn init_config_then_run() {
    if !device_ok() {
        return;
    }
    let dir = std::env::temp_dir().join(format!(
        "zmc_cli_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("job.json");
    let out = zmc()
        .args(["init-config", cfg.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    // shrink the sample count for test speed
    let text = std::fs::read_to_string(&cfg)
        .unwrap()
        .replace("262144", "8192")
        .replace("\"trials\": 10", "\"trials\": 2");
    std::fs::write(&cfg, text).unwrap();
    let out = zmc()
        .args(with_artifacts(&["run", "--config", cfg.to_str().unwrap()]))
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("2 functions x 2 trials"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_json_streams_wire_frames() {
    if !device_ok() {
        return;
    }
    let dir = std::env::temp_dir().join(format!(
        "zmc_cli_json_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("job.json");
    let out = zmc()
        .args(["init-config", cfg.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = std::fs::read_to_string(&cfg)
        .unwrap()
        .replace("262144", "8192")
        .replace("\"trials\": 10", "\"trials\": 2");
    std::fs::write(&cfg, text).unwrap();
    let out = zmc()
        .args(with_artifacts(&[
            "run",
            "--config",
            cfg.to_str().unwrap(),
            "--json",
        ]))
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    let lines: Vec<&str> = text.lines().collect();
    // every stdout line is one wire frame; nothing human-formatted leaks
    for l in &lines {
        assert!(l.starts_with('{') && l.ends_with('}'), "not a frame: {l}");
    }
    // example config: 2 functions x 2 trials -> 4 final frames
    assert_eq!(
        lines.iter().filter(|l| l.contains("\"final\":true")).count(),
        4,
        "{text}"
    );
    assert!(
        lines.last().unwrap().contains("\"status\":\"done\""),
        "{text}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn scan_sweeps_p0() {
    if !device_ok() {
        return;
    }
    let out = zmc()
        .args(with_artifacts(&[
            "scan",
            "--expr",
            "p0*x1",
            "--bounds",
            "0,1",
            "--grid",
            "0:2:3",
            "--samples",
            "8192",
        ]))
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    // I(p0) = p0/2 at p0 = 0, 1, 2
    assert!(text.lines().filter(|l| l.contains("0.")).count() >= 3);
}

#[test]
fn normal_tree_search_cli() {
    if !device_ok() {
        return;
    }
    let out = zmc()
        .args(with_artifacts(&[
            "normal",
            "--expr",
            "x1*x1 + x2",
            "--bounds",
            "0,1;0,1",
            "--divisions",
            "4",
            "--depth",
            "1",
            "--trials",
            "3",
        ]))
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("cubes/level"));
    // truth = 1/3 + 1/2 = 0.8333
    let val: f64 = text
        .lines()
        .find(|l| l.trim_start().starts_with("I ="))
        .and_then(|l| l.split_whitespace().nth(2))
        .unwrap()
        .parse()
        .unwrap();
    assert!((val - 5.0 / 6.0).abs() < 0.05, "I = {val}");
}
