//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment for this repository is fully offline (no
//! crates.io index), so the subset of `anyhow` the codebase actually
//! uses is vendored here as a path dependency: [`Error`], [`Result`],
//! the [`Context`] extension trait, and the `anyhow!` / `bail!` /
//! `ensure!` macros. Semantics follow the real crate closely enough
//! that swapping in upstream `anyhow = "1"` is a one-line Cargo.toml
//! change:
//!
//! * `Error` is a message plus an optional boxed cause chain;
//! * `Display` prints the outermost message, `{:#}` prints the whole
//!   chain separated by `": "` (like upstream), and `Debug` prints the
//!   message followed by a `Caused by:` list;
//! * `Error` deliberately does **not** implement `std::error::Error`,
//!   exactly like upstream, so the blanket `From<E: std::error::Error>`
//!   conversion used by `?` does not conflict with `From<T> for T`;
//! * a typed error converted via `?` / `From` stays recoverable with
//!   [`Error::downcast_ref`], including through later `.context(..)`
//!   wrapping (like upstream's downcast through context).

use std::any::Any;
use std::fmt;

/// `Result` with a defaulted error type, as in the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: message plus optional cause chain.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
    /// The typed error this was converted from, when there was one.
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string(), cause: None, payload: None }
    }

    /// Wrap `self` as the cause of a new outer message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: context.to_string(),
            cause: Some(Box::new(self)),
            payload: None,
        }
    }

    /// Innermost error message in the chain.
    pub fn root_cause(&self) -> &str {
        match &self.cause {
            Some(c) => c.root_cause(),
            None => &self.msg,
        }
    }

    /// The typed error this `Error` was converted from, if this error
    /// (or any error in its context chain) carries a `T`.
    pub fn downcast_ref<T: Any>(&self) -> Option<&T> {
        self.payload
            .as_ref()
            .and_then(|p| p.downcast_ref::<T>())
            .or_else(|| {
                self.cause.as_ref().and_then(|c| c.downcast_ref::<T>())
            })
    }

    /// True when [`Error::downcast_ref::<T>`](Error::downcast_ref)
    /// would succeed.
    pub fn is<T: Any>(&self) -> bool {
        self.downcast_ref::<T>().is_some()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = &self.cause;
            while let Some(c) = cur {
                write!(f, ": {}", c.msg)?;
                cur = &c.cause;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(first) = &self.cause {
            write!(f, "\n\nCaused by:")?;
            let mut cur = Some(first);
            while let Some(c) = cur {
                write!(f, "\n    {}", c.msg)?;
                cur = c.cause.as_ref();
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let msg = e.to_string();
        let mut chain: Vec<String> = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        let mut err = Error { msg, cause: None, payload: None };
        let mut tail = &mut err.cause;
        for msg in chain {
            *tail =
                Some(Box::new(Error { msg, cause: None, payload: None }));
            tail = &mut tail.as_mut().unwrap().cause;
        }
        err.payload = Some(Box::new(e));
        err
    }
}

/// Extension trait adding `.context()` / `.with_context()` to `Result`
/// and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Marker for the `Option` impl's unused error slot.
pub struct NoneError;

impl<T> Context<T, NoneError> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let v: i32 = s.parse().context("parsing an int")?;
        Ok(v)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("42").unwrap(), 42);
        let err = parse("nope").unwrap_err();
        assert_eq!(err.to_string(), "parsing an int");
        let full = format!("{err:#}");
        assert!(full.starts_with("parsing an int: "), "{full}");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let err = v.context("missing").unwrap_err();
        assert_eq!(err.to_string(), "missing");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert!(f(-1).unwrap_err().to_string().contains("positive"));
        assert_eq!(f(101).unwrap_err().to_string(), "too big");
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn downcast_recovers_typed_errors_through_context() {
        #[derive(Debug, PartialEq)]
        struct Typed(u32);
        impl fmt::Display for Typed {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "typed error {}", self.0)
            }
        }
        impl std::error::Error for Typed {}

        let err: Error = Typed(7).into();
        assert_eq!(err.downcast_ref::<Typed>(), Some(&Typed(7)));
        assert!(err.is::<Typed>());
        let wrapped = err.context("while doing a thing");
        assert_eq!(wrapped.downcast_ref::<Typed>(), Some(&Typed(7)));
        assert!(wrapped.downcast_ref::<std::io::Error>().is_none());
        assert!(Error::msg("plain").downcast_ref::<Typed>().is_none());
    }

    #[test]
    fn debug_prints_cause_chain() {
        let e = Error::msg("inner").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by"));
        assert_eq!(e.root_cause(), "inner");
    }
}
