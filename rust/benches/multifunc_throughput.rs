//! Bench: claim C1 — "for integrands less than 5 dimensions, it usually
//! takes less than 10 minutes to finish the evaluation of 10^3
//! integrations on one Tesla V100".
//!
//! Times a mixed batch of N distinct VM-bytecode integrands (dims 1–4),
//! reports functions/minute, and extrapolates to the paper's 10³ — plus
//! the batching ablation: the same workload issued one-function-per-
//! launch (what v4 effectively did) vs packed multifunction launches.
//! The packed path is measured once per execution tier (naive, plan,
//! fused) on tier-pinned sessions, so the ns/sample attribution shows
//! where each tier spends the budget.
//!
//! The batch legs measure the 10⁵–10⁶ columnar regime (`zmc::batch`):
//! ns/function and — via a counting global allocator — peak
//! bytes/function for the boxed oracle vs the columnar+dedup streaming
//! path, asserting the ≥10× per-function memory win and the
//! streaming-watermark peak bound in-process.
//!
//! Env knobs: ZMC_C1_FUNCS, ZMC_C1_SAMPLES, ZMC_MFT_FUNCS,
//! ZMC_MFT_SAMPLES, ZMC_MFT_HUGE=1 (10⁶ functions).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use zmc::batch::BatchJobs;
use zmc::integrator::multifunctions::{self, MultiConfig};
use zmc::integrator::spec::IntegralJob;
use zmc::runtime::ExecTier;
use zmc::session::Session;
use zmc::util::bench::{fmt_s, time, Bench};

/// Counting wrapper over the system allocator: tracks live bytes and
/// the high-water mark, so the batch legs can report *peak* memory —
/// the quantity the streaming watermark bounds — without an external
/// profiler.
struct CountingAlloc;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        let p = System.alloc(l);
        if !p.is_null() {
            let live =
                LIVE.fetch_add(l.size(), Ordering::Relaxed) + l.size();
            PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l);
        LIVE.fetch_sub(l.size(), Ordering::Relaxed);
    }

    unsafe fn realloc(
        &self,
        p: *mut u8,
        l: Layout,
        new: usize,
    ) -> *mut u8 {
        let q = System.realloc(p, l, new);
        if !q.is_null() {
            if new >= l.size() {
                let grow = new - l.size();
                let live =
                    LIVE.fetch_add(grow, Ordering::Relaxed) + grow;
                PEAK.fetch_max(live, Ordering::Relaxed);
            } else {
                LIVE.fetch_sub(l.size() - new, Ordering::Relaxed);
            }
        }
        q
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Run `f`, returning its value and the peak live bytes *above the
/// baseline at entry* reached while it ran.
fn peak_during<T>(f: impl FnOnce() -> T) -> (T, usize) {
    let baseline = LIVE.load(Ordering::Relaxed);
    PEAK.store(baseline, Ordering::Relaxed);
    let out = f();
    let peak = PEAK.load(Ordering::Relaxed);
    (out, peak.saturating_sub(baseline))
}

fn env(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// N distinct low-dimensional integrands (the C1 workload shape).
fn workload(n: usize) -> Vec<IntegralJob> {
    let forms: [(&str, usize); 5] = [
        ("p0*x1^2 + sin(p1*x1)", 1),
        ("p0*abs(x1+x2-1)", 2),
        ("exp(-p0*(x1*x1+x2*x2))", 2),
        ("cos(p0*(x1+x2+x3))", 3),
        ("p0*x1*x2*x3*x4 + tanh(p1*x2)", 4),
    ];
    (0..n)
        .map(|i| {
            let (src, dims) = forms[i % forms.len()];
            let bounds = vec![(0.0, 1.0); dims];
            let theta =
                vec![1.0 + (i as f64) * 0.01, 0.5 + (i % 7) as f64 * 0.1];
            IntegralJob::with_params(src, &bounds, &theta).unwrap()
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let n_funcs = env("ZMC_C1_FUNCS", 128);
    let samples = env("ZMC_C1_SAMPLES", 1 << 14);

    let jobs = workload(n_funcs);
    let mut b = Bench::new("multifunc_throughput");

    // packed multifunction path (v5.1); executable auto-picked — the
    // dims<=4 workload rides the d4 artifact (§Perf L1). One
    // tier-pinned session per execution tier: same workload, same
    // streams, bit-identical estimates — only the kernel shape differs.
    let cfg = MultiConfig {
        samples_per_fn: samples,
        seed: 7,
        ..Default::default()
    };
    let mut session = None;
    let mut t = None;
    for tier in [ExecTier::Naive, ExecTier::Plan, ExecTier::Fused] {
        let s = Session::builder()
            .artifacts_or_emulator("artifacts")
            .workers(1)
            .execution_tier(tier)
            .build()?;
        let tt = time(1, 3, || {
            multifunctions::integrate(s.engine(), &jobs, &cfg).unwrap();
        });
        let fns_per_min = n_funcs as f64 / tt.mean_s * 60.0;
        // per-sample attribution: future hot-path regressions show up
        // here before they move the batch wall time
        let ns_per_sample =
            tt.mean_s / (n_funcs * samples) as f64 * 1e9;
        b.row(
            &format!("packed_v5.1_{tier}"),
            &[
                ("tier", tier.name().to_string()),
                ("funcs", n_funcs.to_string()),
                ("samples", samples.to_string()),
                ("wall", fmt_s(tt.mean_s)),
                ("ns_per_sample", format!("{ns_per_sample:.1}")),
                ("fns_per_min", format!("{fns_per_min:.0}")),
                (
                    "extrap_1000fns",
                    fmt_s(1000.0 / n_funcs as f64 * tt.mean_s),
                ),
            ],
        );
        // the default tier's session carries into the ablation below
        if tier == ExecTier::Fused {
            t = Some(tt);
            session = Some(s);
        }
    }
    let (session, t) = (session.unwrap(), t.unwrap());
    let engine = session.engine();

    // per-function launches (v4-style ablation) on a subset
    let sub = &jobs[..n_funcs.min(16)];
    let cfg1 = MultiConfig {
        samples_per_fn: samples,
        seed: 7,
        exe: Some("vm_multi_f8_s4096".into()),
        ..Default::default()
    };
    let t1 = time(1, 2, || {
        for j in sub {
            multifunctions::integrate(
                engine,
                std::slice::from_ref(j),
                &cfg1,
            )
            .unwrap();
        }
    });
    let per_fn_1 = t1.mean_s / sub.len() as f64;
    let per_fn_packed = t.mean_s / n_funcs as f64;
    b.row(
        "one_per_launch_v4",
        &[
            ("tier", session.execution_tier().name().to_string()),
            ("funcs", sub.len().to_string()),
            ("wall", fmt_s(t1.mean_s)),
            ("per_fn", fmt_s(per_fn_1)),
            (
                "ns_per_sample",
                format!("{:.1}", per_fn_1 / samples as f64 * 1e9),
            ),
            (
                "packing_speedup",
                format!("{:.1}x", per_fn_1 / per_fn_packed),
            ),
        ],
    );
    // ---- batch legs: the 10⁵–10⁶ columnar regime ----
    //
    // One template, n theta rows with literal-constant variation: the
    // parameter-scan shape the batch subsystem exists for. The boxed
    // oracle runs at min(n, 1000) (its per-function boxes make the
    // full n pointless to materialize); the columnar path runs at the
    // full n. Both legs report ns/function and peak bytes/function.
    let n_batch = if env("ZMC_MFT_HUGE", 0) == 1 {
        1_000_000
    } else {
        env("ZMC_MFT_FUNCS", 100_000)
    };
    let batch_samples = env("ZMC_MFT_SAMPLES", 256);
    let template = IntegralJob::with_params(
        "p0*x1*x1 + p1",
        &[(0.0, 1.0)],
        &[0.0, 0.0],
    )?;
    let theta_of =
        |i: usize| [1.0 + i as f64 * 1e-5, 0.25 + (i % 97) as f64 * 1e-3];
    let bcfg = MultiConfig {
        samples_per_fn: batch_samples,
        seed: 11,
        ..Default::default()
    };

    // boxed oracle: per-function `IntegralJob` boxes, all launch
    // inputs materialized up front — the O(batch) memory shape
    let n_small = n_batch.min(1000);
    let t0 = std::time::Instant::now();
    let (boxed_est, boxed_peak) = peak_during(|| {
        let jobs: Vec<IntegralJob> = (0..n_small)
            .map(|i| template.bind(&theta_of(i)).unwrap())
            .collect();
        multifunctions::integrate(engine, &jobs, &bcfg).unwrap()
    });
    let boxed_wall = t0.elapsed().as_secs_f64();
    let boxed_bytes_fn = (boxed_peak / n_small).max(1);
    b.row(
        "boxed_oracle",
        &[
            ("funcs", n_small.to_string()),
            ("samples", batch_samples.to_string()),
            ("wall", fmt_s(boxed_wall)),
            (
                "ns_per_fn",
                format!("{:.0}", boxed_wall / n_small as f64 * 1e9),
            ),
            ("bytes_per_fn", boxed_bytes_fn.to_string()),
        ],
    );

    // bit-identity spot check at the oracle's size: the columnar
    // streaming path must reproduce the boxed estimates exactly
    let jb_small = BatchJobs::scan_with(&template, n_small, |i, row| {
        row.copy_from_slice(&theta_of(i));
    })?;
    let col_small = session
        .batch(&jb_small)
        .samples(batch_samples)
        .seed(11)
        .run()?;
    for (i, (g, w)) in col_small.iter().zip(&boxed_est).enumerate() {
        assert_eq!(
            g.value.to_bits(),
            w.value.to_bits(),
            "fn {i}: columnar diverged from boxed oracle"
        );
        assert_eq!(g.std_err.to_bits(), w.std_err.to_bits(), "fn {i}");
    }

    // columnar + dedup + streaming reduction at the full n
    let wm = zmc::batch::DEFAULT_WATERMARK;
    let t0 = std::time::Instant::now();
    let ((jb, col), col_peak) = peak_during(|| {
        let jb = BatchJobs::scan_with(&template, n_batch, |i, row| {
            row.copy_from_slice(&theta_of(i));
        })
        .unwrap();
        let res = session
            .batch(&jb)
            .samples(batch_samples)
            .seed(11)
            .run()
            .unwrap();
        (jb, res)
    });
    let col_wall = t0.elapsed().as_secs_f64();
    let col_bytes_fn = (col_peak / n_batch).max(1);
    b.row(
        "columnar_batch",
        &[
            ("funcs", n_batch.to_string()),
            ("classes", jb.n_classes().to_string()),
            ("folded", jb.n_folded().to_string()),
            ("watermark", wm.to_string()),
            ("samples", batch_samples.to_string()),
            ("wall", fmt_s(col_wall)),
            (
                "ns_per_fn",
                format!("{:.0}", col_wall / n_batch as f64 * 1e9),
            ),
            ("bytes_per_fn", col_bytes_fn.to_string()),
            (
                "boxed_bytes_ratio",
                format!(
                    "{:.1}",
                    boxed_bytes_fn as f64 / col_bytes_fn as f64
                ),
            ),
        ],
    );

    // watermark bound: peak live memory is the resident columns plus
    // at most two in-flight submission windows — O(watermark), not
    // O(batch). TASK_BYTES is a ~20× overestimate of one launch's
    // inputs+outputs (3×8×48 i32/f32 program rows ≈ 6 KB); the fixed
    // slack absorbs allocator and thread-cache noise.
    const TASK_BYTES: usize = 128 * 1024;
    let resident = jb.approx_bytes() + col.approx_bytes();
    let bound = resident + 2 * wm * TASK_BYTES + (32 << 20);
    assert!(
        col_peak <= bound,
        "columnar peak {col_peak} B exceeds streaming bound {bound} B \
         (resident columns {resident} B + 2 windows of {wm} tasks): \
         in-flight memory must be O(watermark), not O(batch)"
    );
    // the headline gate: ≥10× less peak memory per function than the
    // boxed path. Fixed window overhead stops amortizing below ~20k
    // functions, so the ratio is only asserted in the big regime.
    if n_batch >= 20_000 {
        assert!(
            boxed_bytes_fn >= 10 * col_bytes_fn,
            "columnar bytes/function ({col_bytes_fn}) not 10x below \
             boxed ({boxed_bytes_fn})"
        );
    }

    b.finish();
    Ok(())
}
