//! Bench: claim C1 — "for integrands less than 5 dimensions, it usually
//! takes less than 10 minutes to finish the evaluation of 10^3
//! integrations on one Tesla V100".
//!
//! Times a mixed batch of N distinct VM-bytecode integrands (dims 1–4),
//! reports functions/minute, and extrapolates to the paper's 10³ — plus
//! the batching ablation: the same workload issued one-function-per-
//! launch (what v4 effectively did) vs packed multifunction launches.
//! The packed path is measured once per execution tier (naive, plan,
//! fused) on tier-pinned sessions, so the ns/sample attribution shows
//! where each tier spends the budget.
//!
//! Env knobs: ZMC_C1_FUNCS, ZMC_C1_SAMPLES.

use zmc::integrator::multifunctions::{self, MultiConfig};
use zmc::integrator::spec::IntegralJob;
use zmc::runtime::ExecTier;
use zmc::session::Session;
use zmc::util::bench::{fmt_s, time, Bench};

fn env(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// N distinct low-dimensional integrands (the C1 workload shape).
fn workload(n: usize) -> Vec<IntegralJob> {
    let forms: [(&str, usize); 5] = [
        ("p0*x1^2 + sin(p1*x1)", 1),
        ("p0*abs(x1+x2-1)", 2),
        ("exp(-p0*(x1*x1+x2*x2))", 2),
        ("cos(p0*(x1+x2+x3))", 3),
        ("p0*x1*x2*x3*x4 + tanh(p1*x2)", 4),
    ];
    (0..n)
        .map(|i| {
            let (src, dims) = forms[i % forms.len()];
            let bounds = vec![(0.0, 1.0); dims];
            let theta =
                vec![1.0 + (i as f64) * 0.01, 0.5 + (i % 7) as f64 * 0.1];
            IntegralJob::with_params(src, &bounds, &theta).unwrap()
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let n_funcs = env("ZMC_C1_FUNCS", 128);
    let samples = env("ZMC_C1_SAMPLES", 1 << 14);

    let jobs = workload(n_funcs);
    let mut b = Bench::new("multifunc_throughput");

    // packed multifunction path (v5.1); executable auto-picked — the
    // dims<=4 workload rides the d4 artifact (§Perf L1). One
    // tier-pinned session per execution tier: same workload, same
    // streams, bit-identical estimates — only the kernel shape differs.
    let cfg = MultiConfig {
        samples_per_fn: samples,
        seed: 7,
        ..Default::default()
    };
    let mut session = None;
    let mut t = None;
    for tier in [ExecTier::Naive, ExecTier::Plan, ExecTier::Fused] {
        let s = Session::builder()
            .artifacts_or_emulator("artifacts")
            .workers(1)
            .execution_tier(tier)
            .build()?;
        let tt = time(1, 3, || {
            multifunctions::integrate(s.engine(), &jobs, &cfg).unwrap();
        });
        let fns_per_min = n_funcs as f64 / tt.mean_s * 60.0;
        // per-sample attribution: future hot-path regressions show up
        // here before they move the batch wall time
        let ns_per_sample =
            tt.mean_s / (n_funcs * samples) as f64 * 1e9;
        b.row(
            &format!("packed_v5.1_{tier}"),
            &[
                ("tier", tier.name().to_string()),
                ("funcs", n_funcs.to_string()),
                ("samples", samples.to_string()),
                ("wall", fmt_s(tt.mean_s)),
                ("ns_per_sample", format!("{ns_per_sample:.1}")),
                ("fns_per_min", format!("{fns_per_min:.0}")),
                (
                    "extrap_1000fns",
                    fmt_s(1000.0 / n_funcs as f64 * tt.mean_s),
                ),
            ],
        );
        // the default tier's session carries into the ablation below
        if tier == ExecTier::Fused {
            t = Some(tt);
            session = Some(s);
        }
    }
    let (session, t) = (session.unwrap(), t.unwrap());
    let engine = session.engine();

    // per-function launches (v4-style ablation) on a subset
    let sub = &jobs[..n_funcs.min(16)];
    let cfg1 = MultiConfig {
        samples_per_fn: samples,
        seed: 7,
        exe: Some("vm_multi_f8_s4096".into()),
        ..Default::default()
    };
    let t1 = time(1, 2, || {
        for j in sub {
            multifunctions::integrate(
                engine,
                std::slice::from_ref(j),
                &cfg1,
            )
            .unwrap();
        }
    });
    let per_fn_1 = t1.mean_s / sub.len() as f64;
    let per_fn_packed = t.mean_s / n_funcs as f64;
    b.row(
        "one_per_launch_v4",
        &[
            ("tier", session.execution_tier().name().to_string()),
            ("funcs", sub.len().to_string()),
            ("wall", fmt_s(t1.mean_s)),
            ("per_fn", fmt_s(per_fn_1)),
            (
                "ns_per_sample",
                format!("{:.1}", per_fn_1 / samples as f64 * 1e9),
            ),
            (
                "packing_speedup",
                format!("{:.1}x", per_fn_1 / per_fn_packed),
            ),
        ],
    );
    b.finish();
    Ok(())
}
