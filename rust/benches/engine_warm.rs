//! Bench: persistent engine vs per-call lifecycle (the tentpole win).
//!
//! Before the engine existed, every `integrate()` call spawned fresh
//! worker threads, constructed a new device client per worker, and
//! recompiled every HLO executable it touched. This bench measures that
//! cold lifecycle against warm steady-state `submit()` throughput on a
//! 100-function batch, two ways:
//!
//! 1. **sim** — a simulated-PJRT backend with calibrated costs (client
//!    construction ~25 ms, HLO compile ~150 ms, launch ~2 ms — the
//!    order of magnitude the TFRT CPU client shows on the shipped
//!    artifacts; see DESIGN.md "Substitutions" for why we model rather
//!    than require PJRT here). This isolates exactly what persistence
//!    amortizes, independent of integrand cost.
//! 2. **device** — the real `DeviceBackend` on the loaded registry
//!    (PJRT artifacts when present, else the CPU emulator), with the
//!    registry's compile ledger shown so the no-recompile claim is
//!    visible, not inferred.
//!
//! Env knobs: ZMC_WARM_FUNCS, ZMC_WARM_ROUNDS.

use std::cell::RefCell;
use std::collections::HashSet;
use std::time::Duration;

use anyhow::Result;
use zmc::engine::{Backend, Engine, EngineConfig};
use zmc::integrator::multifunctions::{self, MultiConfig};
use zmc::integrator::spec::IntegralJob;
use zmc::session::Session;
use zmc::util::bench::{fmt_s, time, Bench};

fn env(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

// ------------------------------------------------------- simulated PJRT

struct SimBackend {
    client_ms: u64,
    compile_ms: u64,
    exec_ms: u64,
}

struct SimCtx {
    compiled: RefCell<HashSet<String>>,
    compile_ms: u64,
    exec_ms: u64,
}

impl Backend for SimBackend {
    type Ctx = SimCtx;
    type Task = String; // executable name
    type Out = ();

    fn make_ctx(&self, _worker: usize) -> Result<SimCtx> {
        std::thread::sleep(Duration::from_millis(self.client_ms));
        Ok(SimCtx {
            compiled: RefCell::new(HashSet::new()),
            compile_ms: self.compile_ms,
            exec_ms: self.exec_ms,
        })
    }

    fn run(&self, ctx: &SimCtx, exe: &String) -> Result<()> {
        if !ctx.compiled.borrow().contains(exe) {
            std::thread::sleep(Duration::from_millis(ctx.compile_ms));
            ctx.compiled.borrow_mut().insert(exe.clone());
        }
        std::thread::sleep(Duration::from_millis(ctx.exec_ms));
        Ok(())
    }
}

fn sim_backend() -> SimBackend {
    SimBackend { client_ms: 25, compile_ms: 150, exec_ms: 2 }
}

// --------------------------------------------------------------- main

fn main() -> anyhow::Result<()> {
    let n_funcs = env("ZMC_WARM_FUNCS", 100);
    let rounds = env("ZMC_WARM_ROUNDS", 5);
    let mut b = Bench::new("engine_warm");

    // --- 1. simulated PJRT costs --------------------------------------
    // a 100-function batch on a 32-wide vm_multi exe = 4 launches
    let launches: Vec<String> =
        (0..n_funcs.div_ceil(32)).map(|_| "vm_multi".to_string()).collect();

    // cold: the pre-engine lifecycle — new engine (thread + client +
    // compile) per call, torn down after
    let tc = time(0, 3, || {
        let e = Engine::new(sim_backend(), EngineConfig::new(1)).unwrap();
        e.run(launches.clone()).unwrap();
        drop(e);
    });

    // warm: one persistent engine, repeated submits
    let engine = Engine::new(sim_backend(), EngineConfig::new(1))?;
    engine.run(launches.clone())?; // first call pays compile once
    let tw = time(1, rounds, || {
        engine.run(launches.clone()).unwrap();
    });
    let sim_speedup = tc.mean_s / tw.mean_s;
    b.row(
        "sim_cold_per_call",
        &[
            ("launches", launches.len().to_string()),
            ("wall", fmt_s(tc.mean_s)),
        ],
    );
    b.row(
        "sim_warm_per_submit",
        &[
            ("launches", launches.len().to_string()),
            ("wall", fmt_s(tw.mean_s)),
            ("speedup_vs_cold", format!("{sim_speedup:.1}x")),
        ],
    );
    drop(engine);

    // --- 2. real device backend ---------------------------------------
    let jobs: Vec<IntegralJob> = (0..n_funcs)
        .map(|i| {
            IntegralJob::with_params(
                "x1^2 + p0*sin(x2)",
                &[(0.0, 1.0), (0.0, 1.0)],
                &[i as f64 * 0.01],
            )
            .unwrap()
        })
        .collect();
    let cfg = MultiConfig {
        samples_per_fn: 1 << 14,
        seed: 7,
        exe: Some("vm_multi_f32_s16384".into()),
        ..Default::default()
    };

    // cold: a fresh session (registry + pool + engine) per call — the
    // full pre-engine lifecycle, per-call compile ledger included
    let td = time(0, 3, || {
        let s = Session::builder()
            .artifacts_or_emulator("artifacts")
            .workers(1)
            .build()
            .unwrap();
        multifunctions::integrate(s.engine(), &jobs, &cfg).unwrap();
    });

    // warm: one persistent session; the compile ledger must not move
    // after the first call
    let session = Session::builder()
        .artifacts_or_emulator("artifacts")
        .workers(1)
        .build()?;
    let engine = session.engine();
    multifunctions::integrate(engine, &jobs, &cfg)?;
    let compiles_after_first = session.registry().compile_count();
    let twd = time(1, rounds, || {
        multifunctions::integrate(engine, &jobs, &cfg).unwrap();
    });
    let compiles_after_all = session.registry().compile_count();
    b.row(
        "device_cold_per_call",
        &[
            ("funcs", n_funcs.to_string()),
            ("wall", fmt_s(td.mean_s)),
        ],
    );
    b.row(
        "device_warm_per_submit",
        &[
            ("funcs", n_funcs.to_string()),
            ("wall", fmt_s(twd.mean_s)),
            ("speedup_vs_cold", format!("{:.1}x", td.mean_s / twd.mean_s)),
            ("compiles_first_call", compiles_after_first.to_string()),
            ("compiles_after_warm_loop", compiles_after_all.to_string()),
        ],
    );
    assert_eq!(
        compiles_after_first, compiles_after_all,
        "warm engine recompiled an executable"
    );
    b.finish();
    Ok(())
}
