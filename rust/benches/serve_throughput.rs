//! Bench: the service front end's overhead and throughput.
//!
//! `zmc serve` exists to amortize session construction across requests,
//! so the number that matters is the per-job cost of the HTTP hop
//! itself: the same job run directly on a warm [`Session`] vs POSTed
//! to a loopback server (sequential, then concurrent clients), plus
//! the latency of a `GET /v1/jobs/{id}` recall — the pure
//! request/response path with no integration attached.
//!
//! Env knobs: ZMC_SRV_JOBS, ZMC_SRV_SAMPLES, ZMC_SRV_CLIENTS.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};

use zmc::config::JobConfig;
use zmc::serve::{ServeConfig, Server};
use zmc::session::Session;
use zmc::util::bench::{fmt_s, time, Bench};

fn env(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// One blocking request; returns the status code (the streamed body is
/// read to EOF and discarded — the server finishes the job either way).
fn roundtrip(addr: SocketAddr, method: &str, path: &str, body: &str) -> u16 {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(
        format!(
            "{method} {path} HTTP/1.1\r\nhost: b\r\n\
             content-length: {}\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )
    .unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let head = std::str::from_utf8(&buf[..buf.len().min(16)]).unwrap_or("");
    head.split_whitespace().nth(1).and_then(|c| c.parse().ok()).unwrap_or(0)
}

fn main() -> anyhow::Result<()> {
    let jobs = env("ZMC_SRV_JOBS", 32);
    let samples = env("ZMC_SRV_SAMPLES", 1 << 12);
    let clients = env("ZMC_SRV_CLIENTS", 4).max(1);

    let mut job = JobConfig::from_json_text(&JobConfig::example_json())?;
    job.samples_per_fn = samples;
    job.trials = 1;
    job.target_rel_err = None;
    job.target_abs_err = None;
    let body = job.to_json().to_string();

    let mut b = Bench::new("serve_throughput");

    // baseline: the same job on a warm local session, no HTTP
    let session =
        Session::builder().artifacts_or_emulator("artifacts").build()?;
    let t_direct = time(1, 3, || {
        for _ in 0..jobs {
            session.run_job(&job).unwrap();
        }
    });
    b.row(
        "direct_run_job",
        &[
            ("jobs", jobs.to_string()),
            ("samples", samples.to_string()),
            ("wall", fmt_s(t_direct.mean_s)),
            ("per_job", fmt_s(t_direct.per(jobs))),
        ],
    );

    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_jobs: clients,
        http_workers: clients + 2,
        ..Default::default()
    })?;
    let addr = server.local_addr()?;
    let stop = server.stop_handle();
    let serve_thread = std::thread::spawn(move || server.run());

    // one client, jobs in series: per-job delta vs direct is the
    // whole HTTP + journal-less bookkeeping overhead
    let t_seq = time(1, 3, || {
        for _ in 0..jobs {
            assert_eq!(roundtrip(addr, "POST", "/v1/jobs", &body), 200);
        }
    });
    let overhead = (t_seq.per(jobs) - t_direct.per(jobs)).max(0.0);
    b.row(
        "served_sequential",
        &[
            ("jobs", jobs.to_string()),
            ("wall", fmt_s(t_seq.mean_s)),
            ("per_job", fmt_s(t_seq.per(jobs))),
            ("http_overhead_per_job", fmt_s(overhead)),
        ],
    );

    // concurrent clients against one shared session
    let per_client = jobs.div_ceil(clients);
    let t_conc = time(1, 2, || {
        let threads: Vec<_> = (0..clients)
            .map(|_| {
                let body = body.clone();
                std::thread::spawn(move || {
                    for _ in 0..per_client {
                        assert_eq!(
                            roundtrip(addr, "POST", "/v1/jobs", &body),
                            200
                        );
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
    });
    let total = per_client * clients;
    b.row(
        "served_concurrent",
        &[
            ("clients", clients.to_string()),
            ("jobs", total.to_string()),
            ("wall", fmt_s(t_conc.mean_s)),
            ("per_job", fmt_s(t_conc.per(total))),
            (
                "jobs_per_s",
                format!("{:.1}", total as f64 / t_conc.mean_s),
            ),
        ],
    );

    // recall path: no integration, pure request/response
    let t_get = time(8, 200, || {
        assert_eq!(roundtrip(addr, "GET", "/v1/jobs/1", ""), 200);
    });
    b.row(
        "recall_get",
        &[
            ("per_get", fmt_s(t_get.mean_s)),
            (
                "gets_per_s",
                format!("{:.0}", 1.0 / t_get.mean_s.max(1e-12)),
            ),
        ],
    );

    stop.stop();
    serve_thread.join().unwrap()?;
    b.finish();
    Ok(())
}
