//! Bench: experiment A3 — device (PJRT artifact) path vs the pure-rust
//! CPU bytecode interpreter on identical workloads and sample streams.
//!
//! Reports samples/second for both backends across integrand costs
//! (cheap polynomial → transcendental-heavy), plus the harmonic
//! fast path vs routing the same harmonics through the generic VM.
//!
//! Env knobs: ZMC_A3_SAMPLES.

use zmc::integrator::direct;
use zmc::integrator::harmonic::{self, HarmonicBatch};
use zmc::integrator::multifunctions::{self, MultiConfig};
use zmc::integrator::spec::IntegralJob;
use zmc::session::Session;
use zmc::util::bench::{fmt_s, time, Bench};

fn env(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let samples = env("ZMC_A3_SAMPLES", 1 << 16);
    let session = Session::builder()
        .artifacts_or_emulator("artifacts")
        .workers(1)
        .build()?;
    let engine = session.engine();
    let mut b = Bench::new("backend_compare");

    let cases = [
        ("cheap_poly", "x1*x2 + x3^2"),
        ("abs_mix", "abs(x1+x2-x3)*x4"),
        ("transcendental", "exp(-x1)*sin(6*x2)*cos(4*x3)+tanh(x4)"),
    ];
    for (name, src) in cases {
        let job = IntegralJob::parse(src, &[(0.0, 1.0); 4])?;
        let cfg = MultiConfig {
            samples_per_fn: samples,
            seed: 3,
            exe: Some("vm_multi_f8_s4096".into()),
            ..Default::default()
        };
        let td = time(1, 3, || {
            multifunctions::integrate(
                engine,
                std::slice::from_ref(&job),
                &cfg,
            )
            .unwrap();
        });
        let tc = time(1, 3, || {
            direct::integrate_one(&job, samples, 3, 0, 0);
        });
        b.row(
            name,
            &[
                ("samples", samples.to_string()),
                (
                    "device_Msamp_s",
                    format!("{:.2}", samples as f64 / td.mean_s / 1e6),
                ),
                (
                    "cpu_Msamp_s",
                    format!("{:.2}", samples as f64 / tc.mean_s / 1e6),
                ),
                (
                    "device_over_cpu",
                    format!("{:.2}x", tc.mean_s / td.mean_s),
                ),
                ("device_wall", fmt_s(td.mean_s)),
            ],
        );
    }

    // harmonic fast path vs the same harmonics through the VM
    let n = 64u32;
    let batch = HarmonicBatch::fig1(n);
    let hcfg = MultiConfig {
        samples_per_fn: samples,
        seed: 3,
        exe: Some("harmonic_s65536_n128".into()),
        ..Default::default()
    };
    let th = time(1, 3, || {
        harmonic::integrate(engine, &batch, &hcfg).unwrap();
    });
    let vm_jobs: Vec<IntegralJob> = (1..=n)
        .map(|i| {
            let k = (i as f64 + 50.0) / (2.0 * std::f64::consts::PI);
            IntegralJob::with_params(
                "cos(p0*(x1+x2+x3+x4)) + sin(p0*(x1+x2+x3+x4))",
                &[(0.0, 1.0); 4],
                &[k],
            )
            .unwrap()
        })
        .collect();
    let vcfg = MultiConfig {
        samples_per_fn: samples,
        seed: 3,
        exe: Some("vm_multi_f32_s16384".into()),
        ..Default::default()
    };
    let tv = time(1, 2, || {
        multifunctions::integrate(engine, &vm_jobs, &vcfg).unwrap();
    });
    // function-samples per second (n functions × S samples per run)
    let fsamp = (n as usize * samples) as f64;
    b.row(
        "harmonic_fast_path",
        &[
            ("n_fns", n.to_string()),
            (
                "mxu_kernel_Mfs_s",
                format!("{:.1}", fsamp / th.mean_s / 1e6),
            ),
            (
                "generic_vm_Mfs_s",
                format!("{:.1}", fsamp / tv.mean_s / 1e6),
            ),
            (
                "specialization_speedup",
                format!("{:.1}x", tv.mean_s / th.mean_s),
            ),
        ],
    );
    b.finish();
    Ok(())
}
