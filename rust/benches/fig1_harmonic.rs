//! Bench: Fig. 1 — the paper's figure, regenerated.
//!
//! Reports, per series length n: mean band coverage vs the analytic
//! curve, per-evaluation wall time (claim C3: ~1 min per evaluation of
//! the full 100-series on one V100 at 1e6 samples), and launch stats.
//!
//! Env knobs: ZMC_FIG1_N, ZMC_FIG1_SAMPLES, ZMC_FIG1_TRIALS.

use zmc::integrator::harmonic::{self, HarmonicBatch};
use zmc::integrator::multifunctions::MultiConfig;
use zmc::session::Session;
use zmc::stats::Welford;
use zmc::util::bench::{fmt_s, time, Bench};

fn env(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let n = env("ZMC_FIG1_N", 100) as u32;
    let samples = env("ZMC_FIG1_SAMPLES", 1 << 18);
    let trials = env("ZMC_FIG1_TRIALS", 10) as u32;

    let session = Session::builder()
        .artifacts_or_emulator("artifacts")
        .workers(1)
        .build()?;
    let engine = session.engine();
    let batch = HarmonicBatch::fig1(n);
    let cfg = MultiConfig {
        samples_per_fn: samples,
        seed: 2021,
        ..Default::default()
    };

    let mut b = Bench::new("fig1_harmonic");

    // one warm evaluation for compile, then timed per-evaluation cost
    let t = time(1, 3, || {
        harmonic::integrate(engine, &batch, &cfg).unwrap();
    });
    b.row(
        "per_evaluation",
        &[
            ("n_fns", n.to_string()),
            ("samples", samples.to_string()),
            ("mean_s", format!("{:.4}", t.mean_s)),
            ("min_s", format!("{:.4}", t.min_s)),
            ("human", fmt_s(t.mean_s)),
        ],
    );

    // the statistical figure itself
    let per_trial =
        harmonic::integrate_trials(engine, &batch, &cfg, trials)?;
    let mut covered = 0usize;
    let mut mean_df = 0.0f64;
    for i in 0..n as usize {
        let mut w = Welford::new();
        for tr in &per_trial {
            w.push(tr[i].value);
        }
        let truth = batch.truth(i);
        if (w.mean() - truth).abs() <= 2.0 * w.std() {
            covered += 1;
        }
        mean_df += w.std();
    }
    b.row(
        "band_coverage",
        &[
            ("covered", covered.to_string()),
            ("total", n.to_string()),
            ("trials", trials.to_string()),
            ("mean_dF", format!("{:.3e}", mean_df / n as f64)),
        ],
    );

    // error-vs-samples shape: MC must contract ~1/sqrt(S)
    for s in [samples / 4, samples, samples * 4] {
        let c = MultiConfig { samples_per_fn: s, ..cfg.clone() };
        let ests = harmonic::integrate(engine, &batch, &c)?;
        let rms: f64 = ((0..n as usize)
            .map(|i| (ests[i].value - batch.truth(i)).powi(2))
            .sum::<f64>()
            / n as f64)
            .sqrt();
        b.row(
            "error_vs_samples",
            &[
                ("samples", s.to_string()),
                ("rms_err", format!("{rms:.3e}")),
            ],
        );
    }
    b.finish();
    Ok(())
}
