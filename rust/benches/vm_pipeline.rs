//! Bench: the optimizing VM pipeline — single-core samples/sec on a
//! Genz multifunction batch across all three execution tiers (naive
//! stack interpreter, columnar plan, fused lane-batched), with
//! per-family ns/sample attribution.
//!
//! The naive leg reproduces the pre-plan emulator launch exactly:
//! per-launch program decode from device rows, a fresh `BatchInterp`
//! and sample-column allocation per launch, per-sample `point()`
//! uniforms, full stack-row traffic per opcode. The plan leg is the
//! columnar tier: decode+lower once, block-major Philox column fill,
//! register-based execution over reusable scratch. The fused leg is
//! what `runtime/emulator.rs` runs by default now: SIMD Philox lane
//! blocks, in-register op chains, in-kernel `(Σf, Σf²)` epilogue with
//! no sample columns or output buffer. All legs produce bit-identical
//! moment sums (asserted before timing).
//!
//! Gates: plan/naive speedup ≥ `ZMC_VMP_GATE` (default 2.5) and
//! fused/plan speedup ≥ `ZMC_VMP_FUSED_GATE` (default 1.5). CI's
//! regression leg runs both at 1.0 — no tier may be slower than the
//! one below it. Setting a gate to 0 disables it.
//!
//! Env knobs: ZMC_VMP_SAMPLES (per function), ZMC_VMP_LAUNCH (samples
//! per launch), ZMC_VMP_GATE, ZMC_VMP_FUSED_GATE.

use zmc::abi::MAX_DIM;
use zmc::runtime::emulator::{moment_sums_naive, moment_sums_plan};
use zmc::sampler::StreamKey;
use zmc::util::bench::{time, Bench};
use zmc::vm::fused::{FusedPlan, FusedScratch};
use zmc::vm::interp::BatchInterp;
use zmc::vm::plan::{ExecPlan, PlanScratch};
use zmc::vm::program::{Instr, Program};
use zmc::vm::Op;

const CHUNK: usize = 2048;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

struct Fam {
    name: &'static str,
    prog: Program,
    theta: Vec<f32>,
    lo: Vec<f32>,
    hi: Vec<f32>,
    stream: u32,
}

/// The standard Genz battery (oscillatory, product peak, Gaussian,
/// corner peak, continuous) at the paper's sub-5-dimensional regime.
fn genz_batch() -> Vec<Fam> {
    let mk = |name, src: &str, dims: usize, theta: Vec<f32>, stream| {
        let prog = zmc::expr::Expr::parse(src)
            .unwrap_or_else(|e| panic!("{name}: {e}"))
            .compile()
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(prog.dims == dims, "{name}: dims {} != {dims}", prog.dims);
        Fam {
            name,
            prog,
            theta: {
                let mut t = theta;
                t.resize(16, 0.0);
                t
            },
            lo: vec![0.0; dims],
            hi: vec![1.0; dims],
            stream,
        }
    };
    vec![
        mk(
            "oscillatory_d5",
            "cos(2*pi*p0 + p1*x1 + p2*x2 + p3*x3 + p4*x4 + p5*x5)",
            5,
            vec![0.25, 1.3, 0.9, 0.7, 1.1, 0.5],
            11,
        ),
        mk(
            "product_peak_d4",
            "1/((p0^-2 + (x1-p4)^2) * (p1^-2 + (x2-p5)^2) \
             * (p2^-2 + (x3-p6)^2) * (p3^-2 + (x4-p7)^2))",
            4,
            vec![2.0, 3.0, 1.5, 2.5, 0.35, 0.65, 0.5, 0.4],
            12,
        ),
        mk(
            "gaussian_d3",
            "exp(-(p0*p0*(x1-p3)^2 + p1*p1*(x2-p4)^2 + p2*p2*(x3-p5)^2))",
            3,
            vec![1.5, 2.5, 1.0, 0.5, 0.5, 0.5],
            13,
        ),
        mk(
            "corner_peak_d4",
            "(1 + p0*x1 + p1*x2 + p2*x3 + p3*x4)^-5",
            4,
            vec![0.4, 0.6, 0.3, 0.5],
            14,
        ),
        mk(
            "continuous_d4",
            "exp(-(p0*abs(x1-p4) + p1*abs(x2-p5) + p2*abs(x3-p6) \
             + p3*abs(x4-p7)))",
            4,
            vec![2.0, 1.0, 1.5, 0.8, 0.5, 0.5, 0.5, 0.5],
            15,
        ),
    ]
}

/// One pre-plan launch: decode the program from its device rows (as the
/// old emulator did per launch), allocate the interpreter stack and
/// sample columns, then interpret.
fn naive_launch(
    fam: &Fam,
    key: &StreamKey,
    base: u32,
    samples: usize,
) -> (f64, f64) {
    let (ops, iargs, fargs) = fam.prog.device_rows();
    let mut instrs = Vec::with_capacity(fam.prog.len());
    for p in 0..fam.prog.len() {
        instrs.push(Instr {
            op: Op::from_code(ops[p]).expect("round-trip"),
            iarg: iargs[p],
            farg: fargs[p],
        });
    }
    let prog = Program::new(instrs).expect("round-trip");
    let mut interp = BatchInterp::new(CHUNK);
    let mut xt = vec![vec![0f32; CHUNK]; MAX_DIM];
    let mut buf = vec![0f32; CHUNK];
    moment_sums_naive(
        &prog, key, base, samples, &fam.lo, &fam.hi, &fam.theta,
        &mut interp, &mut xt, &mut buf,
    )
}

fn main() {
    let samples = env_usize("ZMC_VMP_SAMPLES", 1 << 16);
    let launch = env_usize("ZMC_VMP_LAUNCH", 1 << 14).max(1);
    let gate = env_f64("ZMC_VMP_GATE", 2.5);
    let fgate = env_f64("ZMC_VMP_FUSED_GATE", 1.5);
    let seed = [42u32, 7u32];

    let fams = genz_batch();
    let plans: Vec<ExecPlan> =
        fams.iter().map(|f| ExecPlan::lower(&f.prog)).collect();
    let fused_plans: Vec<FusedPlan> = fams
        .iter()
        .map(|f| FusedPlan::new(ExecPlan::lower(&f.prog)))
        .collect();
    let mut b = Bench::new("vm_pipeline");

    // warm per-tier scratch (per-worker state in production)
    let mut ucols = vec![vec![0f32; CHUNK]; MAX_DIM];
    let mut scratch = PlanScratch::new(CHUNK);
    let mut buf = vec![0f32; CHUNK];
    let mut fscratch = FusedScratch::new();

    let launches = samples.div_ceil(launch);
    let mut total_naive = 0f64;
    let mut total_plan = 0f64;
    let mut total_fused = 0f64;
    let mut sink = 0f64;
    for ((fam, plan), fp) in
        fams.iter().zip(&plans).zip(&fused_plans)
    {
        let key = StreamKey { seed, stream: fam.stream, trial: 0 };
        // three-way bit-exactness sanity before timing
        let a = naive_launch(fam, &key, 0, launch.min(samples));
        let p = moment_sums_plan(
            plan, &key, 0, launch.min(samples), &fam.lo, &fam.hi,
            &fam.theta, &mut ucols, &mut scratch, &mut buf,
        );
        assert_eq!(
            (a.0.to_bits(), a.1.to_bits()),
            (p.0.to_bits(), p.1.to_bits()),
            "{}: plan/naive moments diverged",
            fam.name
        );
        let f = fp.moment_sums(
            &key, 0, launch.min(samples) as u32, &fam.lo, &fam.hi,
            &fam.theta, &mut fscratch,
        );
        assert_eq!(
            (p.0.to_bits(), p.1.to_bits()),
            (f.0.to_bits(), f.1.to_bits()),
            "{}: fused/plan moments diverged",
            fam.name
        );

        let tn = time(1, 2, || {
            let mut acc = 0f64;
            for l in 0..launches {
                let base = (l * launch) as u32;
                let n = launch.min(samples - l * launch);
                acc += naive_launch(fam, &key, base, n).0;
            }
            sink += acc;
        });
        let tp = time(1, 2, || {
            let mut acc = 0f64;
            for l in 0..launches {
                let base = (l * launch) as u32;
                let n = launch.min(samples - l * launch);
                acc += moment_sums_plan(
                    plan, &key, base, n, &fam.lo, &fam.hi, &fam.theta,
                    &mut ucols, &mut scratch, &mut buf,
                )
                .0;
            }
            sink += acc;
        });
        let tf = time(1, 2, || {
            let mut acc = 0f64;
            for l in 0..launches {
                let base = (l * launch) as u32;
                let n = launch.min(samples - l * launch);
                acc += fp
                    .moment_sums(
                        &key, base, n as u32, &fam.lo, &fam.hi,
                        &fam.theta, &mut fscratch,
                    )
                    .0;
            }
            sink += acc;
        });
        total_naive += tn.mean_s;
        total_plan += tp.mean_s;
        total_fused += tf.mean_s;
        let s = plan.stats();
        b.row(
            fam.name,
            &[
                ("naive_ns_per_sample", format!("{:.1}", tn.mean_s / samples as f64 * 1e9)),
                ("plan_ns_per_sample", format!("{:.1}", tp.mean_s / samples as f64 * 1e9)),
                ("fused_ns_per_sample", format!("{:.1}", tf.mean_s / samples as f64 * 1e9)),
                ("speedup", format!("{:.2}", tn.mean_s / tp.mean_s)),
                ("fused_speedup", format!("{:.2}", tp.mean_s / tf.mean_s)),
                ("row_ops", format!("{}/{}", s.row_ops, s.instrs)),
                ("fused", s.fused.to_string()),
                ("regs", s.regs.to_string()),
            ],
        );
    }

    let n_samples_total = (samples * fams.len()) as f64;
    let speedup = total_naive / total_plan;
    let fused_speedup = total_plan / total_fused;
    b.row(
        "total",
        &[
            ("funcs", fams.len().to_string()),
            ("samples_per_fn", samples.to_string()),
            ("naive_sps", format!("{:.3e}", n_samples_total / total_naive)),
            ("plan_sps", format!("{:.3e}", n_samples_total / total_plan)),
            ("fused_sps", format!("{:.3e}", n_samples_total / total_fused)),
            ("speedup", format!("{speedup:.2}")),
            ("fused_speedup", format!("{fused_speedup:.2}")),
            ("gate", format!("{gate:.2}")),
            ("fused_gate", format!("{fgate:.2}")),
        ],
    );
    b.finish();
    // keep the accumulators observable so the timed loops can't be
    // optimized away
    eprintln!("# checksum {sink:.6e}");

    let mut fail = false;
    if gate > 0.0 && speedup < gate {
        eprintln!(
            "FAIL: vm_pipeline plan speedup {speedup:.2}x below gate \
             {gate:.2}x"
        );
        fail = true;
    }
    if fgate > 0.0 && fused_speedup < fgate {
        eprintln!(
            "FAIL: vm_pipeline fused speedup {fused_speedup:.2}x below \
             gate {fgate:.2}x"
        );
        fail = true;
    }
    if fail {
        std::process::exit(1);
    }
}
