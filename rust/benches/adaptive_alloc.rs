//! Bench: adaptive variance-driven allocation vs uniform sampling —
//! samples-to-target on a mixed easy/hard multifunction workload.
//!
//! The workload is 3/4 smooth low-variance integrands (which converge
//! on the pilot pass) and 1/4 sharply peaked ones (which dominate the
//! error). Three protocols reach the same per-function relative-error
//! target:
//!
//! * `adaptive_neyman`  — pilot-then-refine, shares ∝ V_s·σ_s;
//! * `adaptive_uniform` — pilot-then-refine, equal shares per
//!   unconverged function (isolates the value of variance shaping);
//! * `oneshot_uniform`  — classic fixed budget per function, doubled
//!   until every function meets the target (what the one-shot API
//!   costs when the batch must pay for its hardest member).
//!
//! Env knobs: ZMC_ADA_FUNCS, ZMC_ADA_TARGET, ZMC_ADA_CAP.

use zmc::adaptive::{self, Allocation};
use zmc::integrator::multifunctions::{self, MultiConfig};
use zmc::integrator::spec::{Estimate, IntegralJob};
use zmc::session::Session;
use zmc::util::bench::{fmt_s, Bench};

fn env(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Mixed workload: indices ≡ 3 (mod 4) are sharp 2-D peaks, the rest
/// smooth low-variance forms. All have clearly nonzero values so a
/// relative target is meaningful.
fn workload(n: usize) -> Vec<IntegralJob> {
    let unit2 = [(0.0, 1.0), (0.0, 1.0)];
    (0..n)
        .map(|i| {
            if i % 4 == 3 {
                // peak sharpness alternates: p0 ∈ {0.02, 0.03}
                let c = if i % 8 == 3 { 0.02 } else { 0.03 };
                IntegralJob::with_params(
                    "1/(p0 + (x1-0.5)^2 + (x2-0.5)^2)",
                    &unit2,
                    &[c],
                )
                .unwrap()
            } else {
                let forms = [
                    "1 + p0*x1*x2",
                    "exp(-p0*x1) + 1",
                    "x1^2 + p0*x2 + 1",
                ];
                IntegralJob::with_params(
                    forms[i % 3],
                    &unit2,
                    &[0.5 + (i % 5) as f64 * 0.1],
                )
                .unwrap()
            }
        })
        .collect()
}

/// Does every estimate meet the relative-error target?
fn all_converged(ests: &[Estimate], target: f64) -> bool {
    ests.iter().all(|e| e.std_err <= target * e.value.abs())
}

fn main() -> anyhow::Result<()> {
    let n_funcs = env("ZMC_ADA_FUNCS", 32);
    let target = env_f64("ZMC_ADA_TARGET", 0.005);
    let cap = env("ZMC_ADA_CAP", 1 << 18);

    let session = Session::builder()
        .artifacts_or_emulator("artifacts")
        .workers(1)
        .build()?;
    let engine = session.engine();
    let jobs = workload(n_funcs);
    let mut b = Bench::new("adaptive_alloc");

    let mut adaptive_totals = Vec::new();
    for (label, allocation) in [
        ("adaptive_neyman", Allocation::Neyman),
        ("adaptive_uniform", Allocation::Uniform),
    ] {
        let cfg = MultiConfig {
            samples_per_fn: cap,
            seed: 99,
            target_rel_err: Some(target),
            allocation,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let (ests, report) =
            adaptive::integrate_with_report(engine, &jobs, &cfg)?;
        let wall = t0.elapsed().as_secs_f64();
        let min_n = ests.iter().map(|e| e.n_samples).min().unwrap_or(0);
        let max_n = ests.iter().map(|e| e.n_samples).max().unwrap_or(0);
        let max_rounds = ests.iter().map(|e| e.rounds).max().unwrap_or(0);
        b.row(
            label,
            &[
                ("funcs", n_funcs.to_string()),
                ("target_rel", target.to_string()),
                ("total_samples", report.total_samples.to_string()),
                ("rounds", report.rounds.to_string()),
                ("splits", report.splits.to_string()),
                ("launches", report.launches.to_string()),
                ("converged", report.converged.to_string()),
                ("fn_samples_min", min_n.to_string()),
                ("fn_samples_max", max_n.to_string()),
                ("fn_rounds_max", max_rounds.to_string()),
                ("wall", fmt_s(wall)),
            ],
        );
        assert!(
            all_converged(&ests, target),
            "{label}: target not reached — raise ZMC_ADA_CAP"
        );
        // easy functions must not have been dragged to the hard
        // functions' budget: the breakdown is the whole point
        assert!(min_n < max_n, "{label}: allocation was flat");
        adaptive_totals.push(report.total_samples);
    }

    // one-shot uniform comparator: double the per-function budget until
    // every function (i.e. the hardest) meets the same target
    let mut samples_per_fn = 1 << 13;
    let mut oneshot = None;
    let t0 = std::time::Instant::now();
    while samples_per_fn <= cap {
        let cfg = MultiConfig {
            samples_per_fn,
            seed: 99,
            ..Default::default()
        };
        let ests = multifunctions::integrate(engine, &jobs, &cfg)?;
        if all_converged(&ests, target) {
            oneshot = Some(samples_per_fn as u64 * n_funcs as u64);
            break;
        }
        samples_per_fn *= 2;
    }
    let oneshot_wall = t0.elapsed().as_secs_f64();
    let oneshot_total =
        oneshot.unwrap_or(cap as u64 * n_funcs as u64);
    b.row(
        "oneshot_uniform",
        &[
            ("funcs", n_funcs.to_string()),
            ("samples_per_fn", samples_per_fn.min(cap).to_string()),
            ("total_samples", oneshot_total.to_string()),
            ("reached_target", oneshot.is_some().to_string()),
            ("wall", fmt_s(oneshot_wall)),
        ],
    );

    let neyman_total = adaptive_totals[0];
    b.row(
        "summary",
        &[
            (
                "neyman_saving",
                format!(
                    "{:.2}x",
                    oneshot_total as f64 / neyman_total as f64
                ),
            ),
            (
                "uniform_alloc_saving",
                format!(
                    "{:.2}x",
                    oneshot_total as f64 / adaptive_totals[1] as f64
                ),
            ),
        ],
    );
    if oneshot.is_some() {
        assert!(
            neyman_total < oneshot_total,
            "adaptive used {neyman_total} samples but uniform one-shot \
             only {oneshot_total}"
        );
    }
    b.finish();
    Ok(())
}
