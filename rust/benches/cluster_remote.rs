//! Bench: what the TCP hop costs — the same multifunction launch batch
//! run on an in-process engine, on a pure-remote cluster (one proxy
//! into a loopback `zmc worker`), and on a mixed 1-local + 1-remote
//! cluster. The workload and results are bit-identical across the
//! three (asserted below); only the transport differs, so the wall
//! delta prices frame encode/decode + loopback round trips + the
//! heartbeat thread.
//!
//! Loopback wall time is noisy, so the bench gates on correctness
//! (bit-equal outputs) and reports per-launch transport overhead for
//! the JSON trend line rather than asserting a latency bound.
//!
//! A final leg prices resilience: the worker is killed and restarted
//! on the same port, and the bench reports the time until the mixed
//! cluster's reconnect supervisor has rejoined it — gated, as above,
//! on the post-rejoin round staying bit-identical.
//!
//! Env knobs: ZMC_REM_FUNCS, ZMC_REM_SAMPLES, ZMC_REM_REPS,
//! ZMC_REM_REJOINS (0 skips the rejoin leg).

use std::net::TcpListener;
use std::sync::Arc;
use std::time::{Duration, Instant};

use zmc::cluster::{serve_worker, DeviceCluster, LaunchExec, RemoteConfig};
use zmc::engine::Engine;
use zmc::integrator::multifunctions::{self, MultiConfig};
use zmc::integrator::spec::IntegralJob;
use zmc::runtime::device::DevicePool;
use zmc::runtime::registry::Registry;
use zmc::util::bench::{fmt_s, Bench};

fn env(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn workload(n: usize) -> Vec<IntegralJob> {
    let forms: [(&str, usize); 4] = [
        ("p0*x1^2 + sin(p1*x1)", 1),
        ("p0*abs(x1+x2-1)", 2),
        ("exp(-p0*(x1*x1+x2*x2))", 2),
        ("cos(p0*(x1+x2+x3))", 3),
    ];
    (0..n)
        .map(|i| {
            let (src, dims) = forms[i % forms.len()];
            let bounds = vec![(0.0, 1.0); dims];
            let theta = vec![1.0 + i as f64 * 0.01, 0.5];
            IntegralJob::with_params(src, &bounds, &theta).unwrap()
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let n_funcs = env("ZMC_REM_FUNCS", 32);
    let samples = env("ZMC_REM_SAMPLES", 1 << 14);
    let reps = env("ZMC_REM_REPS", 3).max(1);

    let registry = Arc::new(
        Registry::load("artifacts").unwrap_or_else(|_| Registry::emulated()),
    );
    let pool = DevicePool::new(&registry, 1)?;
    let jobs = workload(n_funcs);
    let cfg = MultiConfig {
        samples_per_fn: samples,
        seed: 7,
        ..Default::default()
    };
    let (tasks, _exe) = multifunctions::build_tasks(&registry, &jobs, &cfg)?;
    let n_launches = tasks.len();

    // one worker host on loopback backs every remote topology below
    let worker_engine = Engine::for_pool(&pool)?;
    let w = serve_worker(TcpListener::bind("127.0.0.1:0")?, worker_engine)?;
    let addr = w.addr().to_string();

    let local = Engine::for_pool(&pool)?;
    let remote = DeviceCluster::for_pool_with_remote_config(
        &pool,
        0,
        std::slice::from_ref(&addr),
        RemoteConfig::default(),
    )?;
    let mixed = DeviceCluster::for_pool_with_remote_config(
        &pool,
        1,
        std::slice::from_ref(&addr),
        RemoteConfig::default(),
    )?;

    let topologies: [(&str, &dyn LaunchExec); 3] =
        [("local", &local), ("remote_1", &remote), ("mixed_1_1", &mixed)];

    let mut b = Bench::new("cluster_remote");
    let mut walls: Vec<(&str, f64)> = Vec::new();
    let mut reference: Option<Vec<(u64, Vec<u32>)>> = None;
    for (name, exec) in topologies {
        // warm pass: executable compiles + TCP connects are lifetime
        // cost, not per-launch cost
        exec.submit_launches(tasks.clone(), 3)?.wait()?;
        let t0 = Instant::now();
        let mut outs = Vec::new();
        for _ in 0..reps {
            outs = exec.submit_launches(tasks.clone(), 3)?.wait()?;
        }
        let wall = t0.elapsed().as_secs_f64() / reps as f64;
        // the gate: the transport may cost time but never bits
        let bits: Vec<(u64, Vec<u32>)> = outs
            .iter()
            .map(|o| {
                (o.tag, o.data.iter().map(|x| x.to_bits()).collect())
            })
            .collect();
        match &reference {
            None => reference = Some(bits),
            Some(base) => assert_eq!(
                base, &bits,
                "{name}: outputs must be bit-identical to local"
            ),
        }
        walls.push((name, wall));
        b.row(
            name,
            &[
                ("funcs", n_funcs.to_string()),
                ("launches", n_launches.to_string()),
                ("reps", reps.to_string()),
                ("wall", fmt_s(wall)),
                (
                    "per_launch",
                    fmt_s(wall / n_launches.max(1) as f64),
                ),
            ],
        );
    }
    // transport overhead per launch: remote wall minus local wall,
    // amortized over the batch (negative noise clamps to 0)
    let local_wall = walls[0].1;
    for &(name, wall) in &walls[1..] {
        b.row(
            &format!("{name}_overhead"),
            &[(
                "per_launch_overhead",
                fmt_s((wall - local_wall).max(0.0) / n_launches.max(1) as f64),
            )],
        );
    }

    // rejoin leg: bounce the worker and time kill → rebind → rejoined
    // (reconnect counted, node alive again), then gate on the next
    // round still being bit-exact. Reuses the mixed cluster, whose
    // default RemoteConfig has the reconnect supervisor on.
    let rejoins = env("ZMC_REM_REJOINS", 1);
    let mut host = Some(w);
    for rep in 0..rejoins {
        let current = host.take().expect("worker host");
        let port_addr = current.addr();
        let before = mixed.metrics().reconnects();
        current.kill();
        current.join();
        let t0 = Instant::now();
        let deadline = Duration::from_secs(60);
        let next = loop {
            match TcpListener::bind(port_addr) {
                Ok(l) => break serve_worker(l, Engine::for_pool(&pool)?)?,
                Err(_) if t0.elapsed() < deadline => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => anyhow::bail!("rejoin {rep}: rebind: {e}"),
            }
        };
        while mixed.metrics().reconnects() <= before || mixed.n_alive() < 2 {
            anyhow::ensure!(
                t0.elapsed() < deadline,
                "rejoin {rep}: worker never rejoined: {}",
                mixed.metrics().summary()
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        let rejoin_wall = t0.elapsed().as_secs_f64();
        let outs = mixed.submit_launches(tasks.clone(), 3)?.wait()?;
        let bits: Vec<(u64, Vec<u32>)> = outs
            .iter()
            .map(|o| {
                (o.tag, o.data.iter().map(|x| x.to_bits()).collect())
            })
            .collect();
        assert_eq!(
            reference.as_ref(),
            Some(&bits),
            "rejoin {rep}: post-bounce outputs must stay bit-identical"
        );
        b.row(
            &format!("rejoin_{rep}"),
            &[
                ("time_to_rejoin", fmt_s(rejoin_wall)),
                ("reconnects", mixed.metrics().reconnects().to_string()),
            ],
        );
        host = Some(next);
    }

    b.finish();
    Ok(())
}
