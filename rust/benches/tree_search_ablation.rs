//! Bench: experiment A1 — does the heuristic tree search
//! (ZMCintegral_normal) beat plain direct MC at equal sample budget?
//!
//! Workload: a sharply peaked 2-D Gaussian plus a localized oscillation —
//! the "fluctuating integrand" regime the tree heuristic targets. We
//! compare |error| and reported σ of (a) direct MC, (b) one-level
//! stratified, (c) stratified + tree refinement, at matched total
//! sample counts.

use zmc::analytic;
use zmc::integrator::multifunctions::{self, MultiConfig};
use zmc::integrator::normal::{self, NormalConfig};
use zmc::integrator::spec::IntegralJob;
use zmc::session::Session;
use zmc::util::bench::{fmt_s, Bench};

fn main() -> anyhow::Result<()> {
    let session = Session::builder()
        .artifacts_or_emulator("artifacts")
        .workers(1)
        .build()?;
    let engine = session.engine();

    // truth: separable gaussian (erf form)
    let a = 120.0f64;
    let c = a.sqrt();
    let one_d = (std::f64::consts::PI.sqrt() / (2.0 * c))
        * 2.0
        * analytic::erf(c * 0.5);
    let truth = one_d * one_d;
    let job = IntegralJob::with_params(
        "exp(-p0*((x1-0.5)^2 + (x2-0.5)^2))",
        &[(0.0, 1.0), (0.0, 1.0)],
        &[a],
    )?;

    let mut b = Bench::new("tree_search_ablation");
    let trials = 8u32;

    // (c) tree search, depth 2
    let cfg_tree = NormalConfig {
        initial_divisions: 8,
        n_trials: 4,
        sigma_mult: 0.5,
        max_depth: 2,
        seed: 11,
        exe: Some("stratified_c64_s1024".into()),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let tree = normal::integrate(engine, &job, &cfg_tree)?;
    let tree_wall = t0.elapsed().as_secs_f64();
    let budget = tree.estimate.n_samples as usize;

    // (a) direct MC at the same total budget, repeated for error stats
    let mut direct_err = 0.0f64;
    let mut direct_sigma = 0.0f64;
    let t0 = std::time::Instant::now();
    for t in 0..trials {
        let cfg = MultiConfig {
            samples_per_fn: budget,
            seed: 11,
            trial: t,
            exe: Some("vm_multi_f8_s4096".into()),
            ..Default::default()
        };
        let e = multifunctions::integrate(
            engine,
            std::slice::from_ref(&job),
            &cfg,
        )?[0];
        direct_err += (e.value - truth).abs();
        direct_sigma += e.std_err;
    }
    let direct_wall = t0.elapsed().as_secs_f64() / trials as f64;
    direct_err /= trials as f64;
    direct_sigma /= trials as f64;

    // (b) one-level stratified (depth 0)
    let cfg_flat = NormalConfig {
        max_depth: 0,
        ..cfg_tree.clone()
    };
    let flat = normal::integrate(engine, &job, &cfg_flat)?;

    b.row(
        "direct_mc",
        &[
            ("budget", budget.to_string()),
            ("mean_abs_err", format!("{direct_err:.3e}")),
            ("sigma", format!("{direct_sigma:.3e}")),
            ("wall", fmt_s(direct_wall)),
        ],
    );
    b.row(
        "stratified_flat",
        &[
            ("budget", flat.estimate.n_samples.to_string()),
            (
                "abs_err",
                format!("{:.3e}", (flat.estimate.value - truth).abs()),
            ),
            ("sigma", format!("{:.3e}", flat.estimate.std_err)),
            ("cubes", format!("{:?}", flat.cubes_per_level)),
        ],
    );
    b.row(
        "tree_search",
        &[
            ("budget", tree.estimate.n_samples.to_string()),
            (
                "abs_err",
                format!("{:.3e}", (tree.estimate.value - truth).abs()),
            ),
            ("sigma", format!("{:.3e}", tree.estimate.std_err)),
            ("cubes", format!("{:?}", tree.cubes_per_level)),
            ("flagged", format!("{:?}", tree.flagged_per_level)),
            ("wall", fmt_s(tree_wall)),
        ],
    );
    // (d) extension beyond the paper: scrambled-Halton QMC at the same
    // budget (CPU path) — the deterministic-sequence alternative
    let t0 = std::time::Instant::now();
    let seq = zmc::sampler::halton::HaltonSeq::new(11, 2);
    let qmc = zmc::sampler::halton::integrate_qmc(
        &seq,
        &[(0.0, 1.0), (0.0, 1.0)],
        budget,
        |x| {
            let (dx, dy) = (x[0] - 0.5, x[1] - 0.5);
            (-a * (dx * dx + dy * dy)).exp()
        },
    );
    b.row(
        "qmc_halton",
        &[
            ("budget", budget.to_string()),
            ("abs_err", format!("{:.3e}", (qmc - truth).abs())),
            ("wall", fmt_s(t0.elapsed().as_secs_f64())),
        ],
    );
    b.row(
        "who_wins",
        &[(
            "sigma_ratio_direct_over_tree",
            format!("{:.1}x", direct_sigma / tree.estimate.std_err),
        )],
    );
    b.finish();
    Ok(())
}
