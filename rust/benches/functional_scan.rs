//! Bench: experiment A2 — the parameter-scan class
//! (ZMCintegral_functional): sweeping one integrand over a large
//! parameter grid as packed launches vs naive per-point evaluation.
//!
//! Workload: I(p0) = ∫ cos(p0·(x1+x2+x3)) over [0,1]³ on a grid of p0 —
//! the "large parameter space" regime of the v5 paper, with closed-form
//! truth for validation.
//!
//! Env knobs: ZMC_A2_POINTS, ZMC_A2_SAMPLES.

use zmc::analytic;
use zmc::integrator::functional::{self, linspace};
use zmc::integrator::multifunctions::MultiConfig;
use zmc::integrator::spec::IntegralJob;
use zmc::session::Session;
use zmc::util::bench::{fmt_s, time, Bench};

fn env(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let n_points = env("ZMC_A2_POINTS", 256);
    let samples = env("ZMC_A2_SAMPLES", 1 << 14);

    let session = Session::builder()
        .artifacts_or_emulator("artifacts")
        .workers(1)
        .build()?;
    let engine = session.engine();
    let job = IntegralJob::with_params(
        "cos(p0*(x1+x2+x3))",
        &[(0.0, 1.0); 3],
        &[1.0],
    )?;
    let thetas: Vec<Vec<f64>> = linspace(0.5, 12.0, n_points)
        .into_iter()
        .map(|v| vec![v])
        .collect();
    let cfg = MultiConfig {
        samples_per_fn: samples,
        seed: 13,
        exe: Some("vm_multi_f32_s16384".into()),
        ..Default::default()
    };

    let mut b = Bench::new("functional_scan");
    let t = time(1, 3, || {
        functional::scan(engine, &job, &thetas, &cfg).unwrap();
    });
    b.row(
        "packed_scan",
        &[
            ("points", n_points.to_string()),
            ("samples", samples.to_string()),
            ("wall", fmt_s(t.mean_s)),
            (
                "points_per_min",
                format!("{:.0}", n_points as f64 / t.mean_s * 60.0),
            ),
        ],
    );

    // correctness: every point within 6σ of the closed form
    let ests = functional::scan(engine, &job, &thetas, &cfg)?;
    let mut worst: f64 = 0.0;
    for (th, e) in thetas.iter().zip(&ests) {
        let k = th[0];
        let truth = analytic::harmonic_box(
            &[k, k, k],
            1.0,
            0.0,
            &[(0.0, 1.0), (0.0, 1.0), (0.0, 1.0)],
        );
        worst = worst
            .max((e.value - truth).abs() / e.std_err.max(1e-12));
    }
    b.row("validation", &[("worst_z", format!("{worst:.2}"))]);

    // naive per-point path on a subset (the pre-v5 pattern)
    let sub = &thetas[..16.min(n_points)];
    let t1 = time(1, 2, || {
        for th in sub {
            let j = job.bind(th).unwrap();
            let c = MultiConfig {
                exe: Some("vm_multi_f8_s4096".into()),
                ..cfg.clone()
            };
            functional::scan(engine, &j, &[th.clone()], &c).unwrap();
        }
    });
    let per_pt_naive = t1.mean_s / sub.len() as f64;
    let per_pt_packed = t.mean_s / n_points as f64;
    b.row(
        "per_point_naive",
        &[
            ("points", sub.len().to_string()),
            ("per_point", fmt_s(per_pt_naive)),
            (
                "packing_speedup",
                format!("{:.1}x", per_pt_naive / per_pt_packed),
            ),
        ],
    );
    b.finish();
    Ok(())
}
