//! Bench: the paper's linear-scaling claim ("the performance scales
//! linearly with the increasing of the GPUs") on the **real** cluster
//! layer — a fixed multifunction workload sharded across 1/2/4/8
//! engines via the same `ShardPlan` the cluster uses in production.
//!
//! The host has only a couple of cores, so wall clock cannot show 8x;
//! as with `scaling_workers`, scheduling stays real and *time* goes
//! virtual: every launch's true device duration is measured once (the
//! engines report per-launch `device_time`), and each engine count is
//! priced as its shard plan's makespan over those measured durations
//! plus the measured serial dispatch overhead. Real wall time is
//! reported alongside for reference.
//!
//! Gates (emulator, short mode): >= 1.7x virtual speedup at 2 engines
//! and >= 3x at 4 engines vs 1 engine.
//!
//! Env knobs: ZMC_CLU_FUNCS, ZMC_CLU_SAMPLES, ZMC_CLU_ENGINES.

use std::sync::Arc;
use std::time::Instant;

use zmc::cluster::{LaunchExec, ShardPlan};
use zmc::integrator::multifunctions::{self, MultiConfig};
use zmc::integrator::spec::IntegralJob;
use zmc::runtime::registry::Registry;
use zmc::session::Session;
use zmc::util::bench::{fmt_s, Bench};

fn env(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_counts(key: &str, default: &[usize]) -> Vec<usize> {
    match std::env::var(key) {
        Ok(v) => v
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .filter(|&n| n > 0)
            .collect(),
        Err(_) => default.to_vec(),
    }
}

/// N distinct low-dimensional integrands (the C1 workload shape, so
/// every launch rides the same `vm_multi` artifact).
fn workload(n: usize) -> Vec<IntegralJob> {
    let forms: [(&str, usize); 5] = [
        ("p0*x1^2 + sin(p1*x1)", 1),
        ("p0*abs(x1+x2-1)", 2),
        ("exp(-p0*(x1*x1+x2*x2))", 2),
        ("cos(p0*(x1+x2+x3))", 3),
        ("p0*x1*x2*x3*x4 + tanh(p1*x2)", 4),
    ];
    (0..n)
        .map(|i| {
            let (src, dims) = forms[i % forms.len()];
            let bounds = vec![(0.0, 1.0); dims];
            let theta =
                vec![1.0 + (i as f64) * 0.01, 0.5 + (i % 7) as f64 * 0.1];
            IntegralJob::with_params(src, &bounds, &theta).unwrap()
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let n_funcs = env("ZMC_CLU_FUNCS", 64);
    let samples = env("ZMC_CLU_SAMPLES", 1 << 14);
    let counts = env_counts("ZMC_CLU_ENGINES", &[1, 2, 4, 8]);

    let registry = Arc::new(
        Registry::load("artifacts").unwrap_or_else(|_| Registry::emulated()),
    );
    // one session per engine count below, all sharing this registry
    let session_with_engines = |n: usize| {
        Session::builder()
            .registry(Arc::clone(&registry))
            .workers(1)
            .engines(n)
            .build()
    };
    let jobs = workload(n_funcs);
    let cfg = MultiConfig {
        samples_per_fn: samples,
        seed: 7,
        ..Default::default()
    };
    let (tasks, _exe) = multifunctions::build_tasks(&registry, &jobs, &cfg)?;
    let n_launches = tasks.len();
    let mut b = Bench::new("cluster_scaling");

    // measured per-launch device durations + serial dispatch overhead,
    // from a *warmed* 1-engine pass (the first run on a fresh engine
    // pays the per-worker executable compile, which is engine-lifetime
    // cost, not per-launch cost; task cost itself is engine-independent:
    // tasks carry their own Philox addressing and are placement-free)
    let (durations, dispatch_total) = {
        let s1 = session_with_engines(1)?;
        let c1 = s1.exec();
        c1.submit_launches(tasks.clone(), 3)?.wait()?;
        let t0 = Instant::now();
        let outs = c1.submit_launches(tasks.clone(), 3)?.wait()?;
        let wall = t0.elapsed().as_secs_f64();
        let d: Vec<f64> =
            outs.iter().map(|o| o.device_time.as_secs_f64()).collect();
        let device_total: f64 = d.iter().sum();
        (d, (wall - device_total).max(0.0))
    };
    // baseline: the 1-engine plan (one shard = every launch serial),
    // independent of which engine counts the sweep visits or in what
    // order
    let base_makespan =
        dispatch_total + durations.iter().sum::<f64>();
    let mut speedups: Vec<(usize, f64)> = Vec::new();

    for &n in &counts {
        let sn = session_with_engines(n)?;
        let t0 = Instant::now();
        sn.exec().submit_launches(tasks.clone(), 3)?.wait()?;
        let wall = t0.elapsed().as_secs_f64();
        // the real plan this cluster used, priced in measured time:
        // dispatch serializes on the submitter, shards run in parallel
        let plan = ShardPlan::contiguous(n_launches, n);
        let max_shard: f64 = plan
            .iter()
            .map(|r| durations[r].iter().sum::<f64>())
            .fold(0.0, f64::max);
        let makespan = dispatch_total + max_shard;
        let speedup = base_makespan / makespan.max(1e-12);
        speedups.push((n, speedup));
        b.row(
            &format!("engines_{n}"),
            &[
                ("engines", n.to_string()),
                ("funcs", n_funcs.to_string()),
                ("launches", n_launches.to_string()),
                ("wall", fmt_s(wall)),
                ("virt_makespan", format!("{makespan:.6}")),
                ("virt_speedup", format!("{speedup:.3}")),
                (
                    "fns_per_min_virt",
                    format!("{:.0}", n_funcs as f64 / makespan * 60.0),
                ),
            ],
        );
    }
    b.finish();

    // acceptance gates from ISSUE 3 (virtual time is deterministic up
    // to per-launch measurement noise, well inside these margins)
    for &(n, s) in &speedups {
        if n == 2 && n_launches >= 4 {
            assert!(s >= 1.7, "2-engine speedup {s:.3} < 1.7x");
        }
        if n == 4 && n_launches >= 8 {
            assert!(s >= 3.0, "4-engine speedup {s:.3} < 3x");
        }
    }
    Ok(())
}
