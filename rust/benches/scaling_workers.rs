//! Bench: claim C2 — "the performance scales linearly with the
//! increasing of the GPUs".
//!
//! Two measurements:
//! 1. *Real threads*: the same chunk workload on 1..4 worker threads
//!    (on a 1-core testbed this shows coordination overhead, not
//!    speedup — reported for honesty).
//! 2. *Virtual devices*: measured per-chunk durations + measured
//!    dispatch overhead replayed through the discrete-event cluster
//!    simulation for 1,2,4,8,16 devices — the paper's plotted quantity
//!    with the real scheduler policy. See DESIGN.md "Substitutions".
//!
//! Env knobs: ZMC_C2_FUNCS, ZMC_C2_SAMPLES.

use std::sync::Arc;
use std::time::Instant;

use zmc::cluster;
use zmc::integrator::multifunctions::{self, MultiConfig};
use zmc::integrator::spec::IntegralJob;
use zmc::runtime::device::DeviceRuntime;
use zmc::runtime::launch::{vm_multi_inputs, RngCtr, VmFn};
use zmc::runtime::registry::Registry;
use zmc::session::Session;
use zmc::util::bench::{fmt_s, Bench};

fn env(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    // 256 functions x 4 chunks = 32 launches: enough task granularity
    // for the device-scaling sweep to show its linear regime.
    let n_funcs = env("ZMC_C2_FUNCS", 256);
    let samples = env("ZMC_C2_SAMPLES", 1 << 16);

    let registry = Arc::new(
        Registry::load("artifacts").unwrap_or_else(|_| Registry::emulated()),
    );
    let jobs: Vec<IntegralJob> = (0..n_funcs)
        .map(|i| {
            IntegralJob::with_params(
                "cos(p0*(x1+x2+x3+x4))",
                &[(0.0, 1.0); 4],
                &[6.0 + i as f64 * 0.05],
            )
            .unwrap()
        })
        .collect();
    let mut b = Bench::new("scaling_workers");

    // --- 1. real threads -------------------------------------------------
    let mut wall1 = 0.0;
    for workers in [1usize, 2, 4] {
        // one session per worker count, sharing the loaded registry
        let session = Session::builder()
            .registry(Arc::clone(&registry))
            .workers(workers)
            .build()?;
        let engine = session.engine();
        let cfg = MultiConfig {
            samples_per_fn: samples,
            seed: 5,
            exe: Some("vm_multi_f32_s16384".into()),
            ..Default::default()
        };
        // warm (compiles once per worker), then measure on the hot engine
        multifunctions::integrate(engine, &jobs, &cfg)?;
        let t0 = Instant::now();
        multifunctions::integrate(engine, &jobs, &cfg)?;
        let dt = t0.elapsed().as_secs_f64();
        if workers == 1 {
            wall1 = dt;
        }
        b.row(
            "real_threads",
            &[
                ("workers", workers.to_string()),
                ("wall", fmt_s(dt)),
                ("speedup_vs_1", format!("{:.2}x", wall1 / dt)),
            ],
        );
    }

    // --- 2. virtual devices ----------------------------------------------
    // measure true per-chunk device durations + dispatch overhead
    let dev = DeviceRuntime::new(Arc::clone(&registry))?;
    let exe = registry.get("vm_multi_f32_s16384")?;
    let n_chunks = samples.div_ceil(exe.samples);
    let fns: Vec<VmFn> = jobs
        .iter()
        .enumerate()
        .map(|(i, j)| VmFn {
            program: j.program.clone(),
            theta: j.theta.clone(),
            bounds: j.bounds.clone(),
            stream: i as u32,
        })
        .collect();
    let mut durations = Vec::new();
    let mut dispatch = Vec::new();
    for block in fns.chunks(exe.n_fns) {
        for c in 0..n_chunks {
            let rng = RngCtr {
                seed: [5, 0],
                base: (c * exe.samples) as u32,
                trial: 0,
            };
            let t0 = Instant::now();
            let inputs = vm_multi_inputs(exe, rng, block)?;
            dispatch.push(t0.elapsed().as_secs_f64());
            let out = dev.execute(&exe.name, &inputs)?;
            durations.push(out.device_time.as_secs_f64());
        }
    }
    let mean_dispatch =
        dispatch.iter().sum::<f64>() / dispatch.len() as f64;
    b.row(
        "measured_chunks",
        &[
            ("launches", durations.len().to_string()),
            (
                "mean_device",
                fmt_s(durations.iter().sum::<f64>()
                    / durations.len() as f64),
            ),
            ("mean_dispatch", fmt_s(mean_dispatch)),
        ],
    );
    for n in [1usize, 2, 4, 8, 16] {
        let r = cluster::simulate(&durations, n, mean_dispatch);
        b.row(
            "virtual_devices",
            &[
                ("devices", n.to_string()),
                ("makespan", fmt_s(r.makespan)),
                ("speedup", format!("{:.2}x", r.speedup)),
                ("utilization", format!("{:.0}%", r.utilization * 100.0)),
            ],
        );
    }
    b.finish();
    Ok(())
}
