//! Adaptive variance-driven sample allocation for multifunction
//! batches — the precision-targeted mode of
//! [`crate::integrator::multifunctions`].
//!
//! The one-shot path gives every integrand of a heterogeneous batch
//! the same budget, so the whole batch pays for its hardest member.
//! This subsystem replaces that with a **pilot-then-refine loop**
//! ([`driver`]): a cheap equal pilot estimates per-function variance,
//! then successive rounds pour the remaining budget into the functions
//! (and, after stratified subdivision, the sub-domains) that still
//! dominate the error — Neyman allocation across strata
//! ([`alloc::Allocation::Neyman`]), per-function stopping at a
//! user-supplied absolute/relative error target, and domain-remapped
//! `vm_multi` launches ([`strata`]) so the persistent engine's warm
//! executable caches serve every round without a single new compile.
//!
//! Entry points: set `target_rel_err` / `target_abs_err` on a
//! [`crate::integrator::multifunctions::MultiConfig`] and call
//! `multifunctions::integrate` as usual, or call [`integrate_with_report`]
//! directly for the per-round diagnostics.

pub mod alloc;
mod driver;
pub mod strata;

pub use alloc::{apportion, Allocation};
pub use driver::{
    integrate, integrate_observed, integrate_with_report, AdaptiveReport,
    RoundObserver,
};
