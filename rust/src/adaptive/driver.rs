//! The pilot-then-refine loop: variance-driven sample allocation for
//! multifunction batches.
//!
//! 1. **Pilot** — every function gets a cheap equal pass
//!    (`pilot_samples`), producing a first per-function variance
//!    estimate.
//! 2. **Refine** — up to `max_rounds` rounds allocate a growing slice
//!    of the remaining budget across the strata of the functions that
//!    have not met their error target, proportionally to each
//!    stratum's `V_s·σ_s` (Neyman) or equally per function (Uniform).
//!    Each round is one engine job riding the async
//!    `submit() -> JobHandle` path, so refinement rounds of
//!    independent batches interleave on the same warm workers.
//! 3. **Stratify** — a function whose error stops shrinking at the
//!    expected `1/√n` rate gets its worst stratum probed along every
//!    axis and halved along the axis whose halves separate the most
//!    variance; the winning probes seed the children, and all stratum
//!    launches are plain `vm_multi` rows with remapped bounds — no new
//!    executables, so per-worker caches stay warm.
//!
//! Stopping is per-function: a function converges when its combined
//! standard error drops to `target_rel_err·|I|` or `target_abs_err`.
//! With no target configured the loop spends the whole budget
//! (`samples_per_fn × n_functions`) adaptively.

use anyhow::Result;

use crate::adaptive::alloc::{apportion, Allocation};
use crate::adaptive::strata::{partition_estimate, Stratum};
use crate::cluster::{fold_tagged, LaunchExec};
use crate::engine::LaunchTask;
use crate::integrator::multifunctions::{split_seed, MultiConfig};
use crate::integrator::spec::{Estimate, IntegralJob};
use crate::runtime::launch::{vm_multi_inputs, RngCtr, VmFn};
use crate::runtime::registry::{ExeKind, ExeSpec};
use crate::stats::MomentSum;

/// Hard cap on strata per function.
const MAX_STRATA: usize = 16;
/// A round's error must land within this factor of the ideal `1/√n`
/// projection, or the function is flagged for subdivision.
const STALL_TOLERANCE: f64 = 1.3;

/// Batch-level diagnostics of one adaptive run.
#[derive(Debug, Clone, Default)]
pub struct AdaptiveReport {
    /// Rounds executed, including the pilot.
    pub rounds: usize,
    /// Total samples drawn: pilot + refinement + split probes
    /// (probe draws along losing axes are counted here but discarded,
    /// so this can exceed the sum of per-function `n_samples`).
    pub total_samples: u64,
    /// Device launches issued.
    pub launches: usize,
    /// Stratified subdivisions performed.
    pub splits: usize,
    /// Functions that met their error target.
    pub converged: usize,
    /// Samples drawn in each round, pilot first.
    pub samples_per_round: Vec<u64>,
}

/// Per-function refinement state.
struct FnState {
    strata: Vec<Stratum>,
    rounds: u32,
    converged: bool,
    needs_split: bool,
    /// Set when a split just happened: the children's seed moments come
    /// from the probes that *won* the minimum-variance axis selection,
    /// so their variance estimate is biased low. Convergence (and stall
    /// detection) is suppressed for one round until fresh, unbiased
    /// samples dominate.
    fresh_split: bool,
    /// `(std_err, n_samples)` after the last round this function
    /// participated in — the baseline for stall detection.
    prev: Option<(f64, u64)>,
}

/// Per-round estimate snapshots for streaming consumers: called with
/// `(rounds_so_far, per-function estimates)` after the pilot and after
/// every refinement round. Snapshots are read-only views of the same
/// per-stratum moments the loop itself allocates from, so observing a
/// run never perturbs its results.
pub type RoundObserver<'a> = &'a mut dyn FnMut(usize, &[Estimate]);

/// Adaptive integration; returns one estimate per job, in order.
/// See the module docs for the loop; [`integrate_with_report`] exposes
/// the run diagnostics.
pub fn integrate<X: LaunchExec + ?Sized>(
    exec: &X,
    jobs: &[IntegralJob],
    cfg: &MultiConfig,
) -> Result<Vec<Estimate>> {
    Ok(run_loop(exec, jobs, cfg, &mut None)?.0)
}

/// [`integrate`] with a per-round observer — the streaming hook behind
/// `zmc run --json` and the server's chunked frames. The final return
/// value is bit-identical to [`integrate`] with the same config.
pub fn integrate_observed<X: LaunchExec + ?Sized>(
    exec: &X,
    jobs: &[IntegralJob],
    cfg: &MultiConfig,
    on_round: RoundObserver<'_>,
) -> Result<Vec<Estimate>> {
    Ok(run_loop(exec, jobs, cfg, &mut Some(on_round))?.0)
}

/// [`integrate`] plus the batch-level [`AdaptiveReport`].
///
/// Generic over [`LaunchExec`]: on a multi-engine cluster each round's
/// slot list fans out as contiguous shards while the allocation step
/// below stays centralized — the Neyman apportionment only ever sees
/// the merged per-stratum moments, so the round structure (and every
/// estimate) is bit-identical to the single-engine run.
pub fn integrate_with_report<X: LaunchExec + ?Sized>(
    exec: &X,
    jobs: &[IntegralJob],
    cfg: &MultiConfig,
) -> Result<(Vec<Estimate>, AdaptiveReport)> {
    run_loop(exec, jobs, cfg, &mut None)
}

/// The pilot-then-refine loop itself; both public entry points land
/// here. `observer` (when present) is called after the pilot and every
/// refinement round with a pure snapshot of the per-function state.
fn run_loop<X: LaunchExec + ?Sized>(
    exec: &X,
    jobs: &[IntegralJob],
    cfg: &MultiConfig,
    observer: &mut Option<RoundObserver<'_>>,
) -> Result<(Vec<Estimate>, AdaptiveReport)> {
    let mut report = AdaptiveReport::default();
    if jobs.is_empty() {
        return Ok((vec![], report));
    }
    let reg = exec.registry();
    let exe = match &cfg.exe {
        Some(name) => reg.get(name)?,
        None => {
            let want_dims = jobs.iter().map(|j| j.dims()).max().unwrap_or(1);
            // pick by the pilot size: refinement wants fine-grained
            // slots, not one huge launch per function
            reg.pick(ExeKind::VmMulti, cfg.pilot_samples.max(1), want_dims)?
        }
    };
    let slot = exe.samples as u64;
    let budget = cfg.samples_per_fn as u64 * jobs.len() as u64;
    let mut spent: u64 = 0;
    let mut next_stream: u32 = cfg.stream_base;
    let mut launches = 0usize;

    let mut state: Vec<FnState> = jobs
        .iter()
        .map(|j| FnState {
            strata: vec![Stratum::root(&j.bounds)],
            rounds: 0,
            converged: false,
            needs_split: false,
            fresh_split: false,
            prev: None,
        })
        .collect();

    // ---- pilot: equal cheap pass over every function ----------------
    // clamped to the per-function budget cap; one launch slot is the
    // hard floor (sampling granularity is exe.samples)
    let pilot_target =
        cfg.pilot_samples.clamp(1, cfg.samples_per_fn.max(1));
    let pilot_slots = pilot_target.div_ceil(exe.samples).max(1);
    let mut slots: Vec<(usize, Vec<(f64, f64)>)> = Vec::new();
    for (fi, j) in jobs.iter().enumerate() {
        for _ in 0..pilot_slots {
            slots.push((fi, j.bounds.clone()));
        }
    }
    let moments = run_remapped(
        exec, exe, jobs, cfg, &slots, &mut next_stream, &mut launches,
    )?;
    for ((fi, _), m) in slots.iter().zip(&moments) {
        state[*fi].strata[0].moments.merge(m);
    }
    spent += slots.len() as u64 * slot;
    report.samples_per_round.push(spent);
    report.rounds = 1;
    for st in state.iter_mut() {
        st.rounds = 1;
        let (value, err, n) = partition_estimate(&st.strata);
        if let Some(tol) = tolerance(cfg, value) {
            if err <= tol {
                st.converged = true;
            }
        }
        st.prev = Some((err, n));
    }
    notify(observer, report.rounds, &state);

    // ---- refinement rounds ------------------------------------------
    for _ in 0..cfg.max_rounds {
        let active: Vec<usize> =
            (0..jobs.len()).filter(|&fi| !state[fi].converged).collect();
        if active.is_empty() || budget.saturating_sub(spent) < slot {
            break;
        }
        let spent_before = spent;
        let mut touched = vec![false; jobs.len()];

        // stratified subdivision of stalled functions
        for &fi in &active {
            if !state[fi].needs_split
                || state[fi].strata.len() >= MAX_STRATA
            {
                continue;
            }
            let dims = jobs[fi].dims();
            let probe_cost = 2 * dims as u64 * slot;
            // keep at least one slot of budget for the round itself;
            // an unaffordable probe leaves the flag set so the split
            // happens as soon as budget allows
            if budget.saturating_sub(spent) < probe_cost + slot {
                continue;
            }
            state[fi].needs_split = false;
            let wi = worst_stratum(&state[fi].strata);
            let worst = state[fi].strata[wi].clone();
            let mut probes: Vec<(usize, Vec<(f64, f64)>)> =
                Vec::with_capacity(2 * dims);
            for axis in 0..dims {
                let (a, b) = worst.split(axis);
                probes.push((fi, a.bounds));
                probes.push((fi, b.bounds));
            }
            let pm = run_remapped(
                exec,
                exe,
                jobs,
                cfg,
                &probes,
                &mut next_stream,
                &mut launches,
            )?;
            spent += probes.len() as u64 * slot;
            // split along the axis whose halves separate the most
            // variance, i.e. the lowest within-half variance sum
            let mut best_axis = 0usize;
            let mut best_score = f64::INFINITY;
            for axis in 0..dims {
                let score =
                    pm[2 * axis].variance() + pm[2 * axis + 1].variance();
                if score < best_score {
                    best_score = score;
                    best_axis = axis;
                }
            }
            let (mut a, mut b) = worst.split(best_axis);
            a.moments = pm[2 * best_axis];
            b.moments = pm[2 * best_axis + 1];
            state[fi].strata[wi] = a;
            state[fi].strata.push(b);
            state[fi].fresh_split = true;
            report.splits += 1;
            touched[fi] = true;
        }

        // allocate this round's slot budget across active strata
        let remaining_slots = (budget.saturating_sub(spent) / slot) as usize;
        if remaining_slots == 0 {
            finish_round(
                cfg,
                &mut state,
                &touched,
                &mut report,
                spent - spent_before,
            );
            notify(observer, report.rounds, &state);
            break;
        }
        let spent_slots = (spent / slot).max(1) as usize;
        // geometric ramp: a round spends about as much as everything
        // before it, so convergence checks stay cheap early and the
        // budget is not burned before the variance map is trustworthy
        let round_slots = remaining_slots.min(spent_slots.max(active.len()));

        let mut keys: Vec<(usize, usize)> = Vec::new();
        let mut weights: Vec<f64> = Vec::new();
        for &fi in &active {
            let n_str = state[fi].strata.len();
            for (si, s) in state[fi].strata.iter().enumerate() {
                keys.push((fi, si));
                weights.push(match cfg.allocation {
                    Allocation::Neyman => s.neyman_weight(),
                    Allocation::Uniform => 1.0 / n_str as f64,
                });
            }
        }
        let shares = apportion(round_slots, &weights);
        let mut slots: Vec<(usize, Vec<(f64, f64)>)> = Vec::new();
        let mut owners: Vec<(usize, usize)> = Vec::new();
        for (k, &(fi, si)) in keys.iter().enumerate() {
            for _ in 0..shares[k] {
                slots.push((fi, state[fi].strata[si].bounds.clone()));
                owners.push((fi, si));
            }
        }
        let moments = run_remapped(
            exec, exe, jobs, cfg, &slots, &mut next_stream, &mut launches,
        )?;
        for (&(fi, si), m) in owners.iter().zip(&moments) {
            state[fi].strata[si].moments.merge(m);
            touched[fi] = true;
        }
        spent += slots.len() as u64 * slot;
        finish_round(
            cfg,
            &mut state,
            &touched,
            &mut report,
            spent - spent_before,
        );
        notify(observer, report.rounds, &state);
    }

    report.total_samples = spent;
    report.launches = launches;
    report.converged = state.iter().filter(|s| s.converged).count();
    Ok((snapshot(&state), report))
}

/// Pure per-function estimate snapshot of the current state — the same
/// partition math the final result uses, so the last observed snapshot
/// equals the returned estimates exactly.
fn snapshot(state: &[FnState]) -> Vec<Estimate> {
    state
        .iter()
        .map(|st| {
            let (value, std_err, n_samples) =
                partition_estimate(&st.strata);
            Estimate { value, std_err, n_samples, rounds: st.rounds }
        })
        .collect()
}

fn notify(
    observer: &mut Option<RoundObserver<'_>>,
    round: usize,
    state: &[FnState],
) {
    if let Some(cb) = observer.as_mut() {
        cb(round, &snapshot(state));
    }
}

/// Post-round bookkeeping: per-function convergence, stall detection,
/// round counters.
fn finish_round(
    cfg: &MultiConfig,
    state: &mut [FnState],
    touched: &[bool],
    report: &mut AdaptiveReport,
    round_samples: u64,
) {
    report.rounds += 1;
    report.samples_per_round.push(round_samples);
    for (st, t) in state.iter_mut().zip(touched.iter()) {
        if !*t {
            continue;
        }
        st.rounds += 1;
        let (value, err, n) = partition_estimate(&st.strata);
        // a just-split function's error estimate is built on the probe
        // samples that won the minimum-variance axis selection and is
        // biased low: suppress convergence and stall judgement for one
        // round, until fresh samples dominate the children
        if st.fresh_split {
            st.fresh_split = false;
            st.prev = Some((err, n));
            continue;
        }
        if let Some(tol) = tolerance(cfg, value) {
            if err <= tol {
                st.converged = true;
            }
        }
        if let Some((prev_err, prev_n)) = st.prev {
            if !st.converged
                && n > prev_n
                && prev_err.is_finite()
                && prev_err > 0.0
            {
                // ideal MC scaling projects err ~ prev_err·√(prev_n/n);
                // falling short means the variance estimate is unstable
                // (peaked/oscillatory integrand) — stratify it
                let expected =
                    prev_err * ((prev_n as f64) / (n as f64)).sqrt();
                if err > expected * STALL_TOLERANCE {
                    st.needs_split = true;
                }
            }
        }
        st.prev = Some((err, n));
    }
}

/// Convergence threshold for a function currently estimated at
/// `value`: met when the error is below `target_rel_err·|value|` *or*
/// `target_abs_err`. `None` when no target is configured.
fn tolerance(cfg: &MultiConfig, value: f64) -> Option<f64> {
    let mut tol: Option<f64> = None;
    if let Some(rel) = cfg.target_rel_err {
        tol = Some(rel * value.abs());
    }
    if let Some(abs) = cfg.target_abs_err {
        tol = Some(match tol {
            Some(t) => t.max(abs),
            None => abs,
        });
    }
    tol
}

/// Index of the stratum with the largest error contribution.
fn worst_stratum(strata: &[Stratum]) -> usize {
    let mut wi = 0usize;
    let mut worst = f64::NEG_INFINITY;
    for (i, s) in strata.iter().enumerate() {
        let e = s.error_contribution();
        if e > worst {
            worst = e;
            wi = i;
        }
    }
    wi
}

/// Launch a list of domain-remapped slots — `(function index, bounds)`
/// pairs, one `vm_multi` row each — and return the per-slot moment
/// sums in input order.
///
/// This is the adaptive subsystem's whole device interface: a stratum
/// launch is an ordinary `vm_multi` row whose bounds are the stratum
/// box instead of the function's full domain, with a fresh Philox
/// stream per slot (`base = 0`, so every slot covers the counter range
/// `[0, exe.samples)` of its own stream). Reusing the cached `vm_multi`
/// executables means refinement never compiles anything new, and the
/// per-slot streams make the task list shardable across a cluster's
/// engines without any counter-range coordination.
fn run_remapped<X: LaunchExec + ?Sized>(
    exec: &X,
    exe: &ExeSpec,
    jobs: &[IntegralJob],
    cfg: &MultiConfig,
    slots: &[(usize, Vec<(f64, f64)>)],
    next_stream: &mut u32,
    launches: &mut usize,
) -> Result<Vec<MomentSum>> {
    if slots.is_empty() {
        return Ok(vec![]);
    }
    let mut tasks = Vec::new();
    for (t, chunk) in slots.chunks(exe.n_fns).enumerate() {
        let mut fns = Vec::with_capacity(chunk.len());
        for (fi, bounds) in chunk {
            fns.push(VmFn {
                program: jobs[*fi].program.clone(),
                theta: jobs[*fi].theta.clone(),
                bounds: bounds.clone(),
                stream: *next_stream,
            });
            *next_stream = next_stream.wrapping_add(1);
        }
        let rng = RngCtr {
            seed: split_seed(cfg.seed),
            base: 0,
            trial: cfg.trial,
        };
        tasks.push(LaunchTask {
            exe: exe.name.clone(),
            tag: t as u64,
            inputs: vm_multi_inputs(exe, rng, &fns)?,
        });
    }
    *launches += tasks.len();
    // centralized reduce: merged per-slot moments feed the (also
    // centralized) allocation step of the next round; folding results
    // as they land (in task order) is bit-identical to collecting the
    // full output list first and avoids buffering O(launches) outputs
    let mut moments = vec![MomentSum::new(); slots.len()];
    exec.submit_launches(tasks, cfg.max_retries)?.wait_each(&mut |out| {
        fold_tagged(&mut moments, &out, exe.n_fns, exe.samples as u64)
    })?;
    Ok(moments)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tolerance_combines_rel_and_abs() {
        let none = MultiConfig::default();
        assert_eq!(tolerance(&none, 2.0), None);
        let rel = MultiConfig {
            target_rel_err: Some(0.01),
            ..Default::default()
        };
        assert_eq!(tolerance(&rel, 2.0), Some(0.02));
        assert_eq!(tolerance(&rel, -2.0), Some(0.02));
        let both = MultiConfig {
            target_rel_err: Some(0.01),
            target_abs_err: Some(0.5),
            ..Default::default()
        };
        assert_eq!(tolerance(&both, 2.0), Some(0.5)); // abs dominates
        let tight = MultiConfig {
            target_rel_err: Some(0.01),
            target_abs_err: Some(0.001),
            ..Default::default()
        };
        assert_eq!(tolerance(&tight, 2.0), Some(0.02)); // rel dominates
        let abs = MultiConfig {
            target_abs_err: Some(0.001),
            ..Default::default()
        };
        assert_eq!(tolerance(&abs, 2.0), Some(0.001));
    }

    /// Build a one-stratum state over [0,1] with `n` samples of
    /// mean 0 / variance 1 (so err = 1/√n exactly).
    fn unit_var_state(n: u64) -> FnState {
        let mut s = Stratum::root(&[(0.0, 1.0)]);
        s.moments = MomentSum { n, sum: 0.0, sumsq: n as f64 };
        FnState {
            strata: vec![s],
            rounds: 1,
            converged: false,
            needs_split: false,
            fresh_split: false,
            prev: None,
        }
    }

    #[test]
    fn stall_detection_flags_non_scaling_errors() {
        let cfg = MultiConfig {
            target_abs_err: Some(1e-12), // unreachably tight
            ..Default::default()
        };
        let mut report = AdaptiveReport::default();

        // healthy: n 1000 -> 4000 with unit variance halves the error
        // exactly as 1/√n projects — no split
        let mut healthy = unit_var_state(4000);
        healthy.prev = Some((1.0 / 1000f64.sqrt(), 1000));
        finish_round(&cfg, std::slice::from_mut(&mut healthy), &[true], &mut report, 0);
        assert!(!healthy.needs_split);
        assert_eq!(healthy.rounds, 2);

        // stalled: 4x the samples but the error did not move (variance
        // estimate quadrupled underneath) — flagged for subdivision
        let mut stalled = unit_var_state(4000);
        stalled.prev = Some((1.0 / 4000f64.sqrt() / 1.5, 1000));
        finish_round(&cfg, std::slice::from_mut(&mut stalled), &[true], &mut report, 0);
        assert!(stalled.needs_split);

        // converged functions are never flagged, however badly scaled
        let mut done = unit_var_state(4000);
        done.prev = Some((1e-9, 1000));
        let loose = MultiConfig {
            target_abs_err: Some(1.0),
            ..Default::default()
        };
        finish_round(&loose, std::slice::from_mut(&mut done), &[true], &mut report, 0);
        assert!(done.converged);
        assert!(!done.needs_split);

        // untouched functions keep their round count and baseline
        let mut idle = unit_var_state(4000);
        idle.prev = Some((0.5, 77));
        finish_round(&cfg, std::slice::from_mut(&mut idle), &[false], &mut report, 0);
        assert_eq!(idle.rounds, 1);
        assert_eq!(idle.prev, Some((0.5, 77)));

        // a just-split function is never judged on its biased probe
        // seed: neither converged (despite a loose target) nor stalled
        let mut split = unit_var_state(4000);
        split.fresh_split = true;
        split.prev = Some((1.0, 10));
        finish_round(&loose, std::slice::from_mut(&mut split), &[true], &mut report, 0);
        assert!(!split.converged);
        assert!(!split.needs_split);
        assert!(!split.fresh_split); // judged normally from next round
    }

    #[test]
    fn worst_stratum_prefers_unsampled_then_contribution() {
        let mut a = Stratum::root(&[(0.0, 1.0)]);
        for v in [0.0, 1.0] {
            a.moments.push(v);
        }
        let b = Stratum::root(&[(0.0, 1.0)]); // unsampled: infinite
        assert_eq!(worst_stratum(&[a.clone(), b]), 1);
        let mut c = Stratum::root(&[(0.0, 4.0)]); // same var, 4x volume
        for v in [0.0, 1.0] {
            c.moments.push(v);
        }
        assert_eq!(worst_stratum(&[a, c]), 1);
    }
}
