//! Sample-budget apportionment across functions and strata.
//!
//! Each refinement round has a whole number of launch slots to hand out
//! (one slot = one `vm_multi` function row = `exe.samples` draws); the
//! allocation policy turns per-stratum statistics into slot counts.

/// How a refinement round's slot budget is distributed across the
/// strata of the unconverged functions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Allocation {
    /// Equal shares per unconverged function (split evenly across that
    /// function's strata). Converged functions still drop out, so this
    /// is the ablation baseline that isolates the value of
    /// variance-driven shaping.
    Uniform,
    /// Neyman-style allocation: shares proportional to each stratum's
    /// `V_s·σ_s` — the weight that minimizes the combined variance of a
    /// stratified estimator for a fixed total sample count.
    #[default]
    Neyman,
}

/// Apportion `slots` whole slots proportionally to `weights` using the
/// largest-remainder method. Deterministic (remainder ties break toward
/// the lower index), conserves the total exactly, and never hands a
/// remainder slot to a zero-weight entry unless every weight is zero —
/// in which case the slots are spread round-robin (no information means
/// uniform).
pub fn apportion(slots: usize, weights: &[f64]) -> Vec<usize> {
    let n = weights.len();
    if n == 0 || slots == 0 {
        return vec![0; n];
    }
    let clean: Vec<f64> = weights
        .iter()
        .map(|&w| if w.is_finite() && w > 0.0 { w } else { 0.0 })
        .collect();
    let total: f64 = clean.iter().sum();
    if total <= 0.0 {
        let mut out = vec![slots / n; n];
        for slot in out.iter_mut().take(slots % n) {
            *slot += 1;
        }
        return out;
    }
    let mut out = vec![0usize; n];
    let mut assigned = 0usize;
    // (fractional part, index), for distributing the remainder
    let mut fracs: Vec<(f64, usize)> = Vec::with_capacity(n);
    for (i, &w) in clean.iter().enumerate() {
        let share = slots as f64 * w / total;
        let base = share.floor() as usize;
        out[i] = base;
        assigned += base;
        if w > 0.0 {
            fracs.push((share - base as f64, i));
        }
    }
    fracs.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    let mut left = slots.saturating_sub(assigned);
    for &(_, i) in &fracs {
        if left == 0 {
            break;
        }
        out[i] += 1;
        left -= 1;
    }
    // fp pathologies only: dump any residue on the heaviest entry
    if left > 0 {
        let heaviest = clean
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        out[heaviest] += left;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conserves_total() {
        for slots in [0usize, 1, 7, 100] {
            let got = apportion(slots, &[3.0, 1.0, 0.0, 2.5]);
            assert_eq!(got.iter().sum::<usize>(), slots, "{got:?}");
        }
    }

    #[test]
    fn proportional_in_the_large() {
        let got = apportion(1000, &[1.0, 3.0]);
        assert_eq!(got, vec![250, 750]);
    }

    #[test]
    fn zero_weights_fall_back_to_round_robin() {
        assert_eq!(apportion(5, &[0.0, 0.0, 0.0]), vec![2, 2, 1]);
        assert_eq!(apportion(2, &[f64::NAN, 0.0]), vec![1, 1]);
    }

    #[test]
    fn zero_weight_entries_get_nothing_when_others_exist() {
        let got = apportion(3, &[0.0, 1.0, 0.0, 1.0]);
        assert_eq!(got[0], 0);
        assert_eq!(got[2], 0);
        assert_eq!(got.iter().sum::<usize>(), 3);
    }

    #[test]
    fn deterministic_remainder_ties() {
        let a = apportion(3, &[1.0, 1.0]);
        let b = apportion(3, &[1.0, 1.0]);
        assert_eq!(a, b);
        assert_eq!(a, vec![2, 1]); // tie broken toward lower index
    }

    #[test]
    fn empty_weights() {
        assert!(apportion(10, &[]).is_empty());
    }
}
