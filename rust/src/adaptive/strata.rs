//! Per-function domain partitions: rectangular strata with streaming
//! moment accumulators.
//!
//! A function starts as one root stratum covering its whole box. When
//! refinement stalls, the driver halves the worst stratum along the
//! axis whose halves separate the most variance; every stratum is
//! sampled by domain-remapped `vm_multi` launches (the stratum bounds
//! simply replace the function's bounds in the launch row), so no new
//! artifacts are compiled and warm executable caches stay warm.

use crate::sampler::volume;
use crate::stats::{stratified_estimate, MomentSum};

/// One rectangular stratum of an integrand's domain, with the moment
/// sums accumulated over every launch that sampled it.
#[derive(Debug, Clone)]
pub struct Stratum {
    /// Per-dimension (lo, hi); same length as the owning job's bounds.
    pub bounds: Vec<(f64, f64)>,
    pub moments: MomentSum,
}

impl Stratum {
    /// A fresh stratum covering `bounds`, with no samples yet.
    pub fn root(bounds: &[(f64, f64)]) -> Self {
        Stratum { bounds: bounds.to_vec(), moments: MomentSum::new() }
    }

    pub fn volume(&self) -> f64 {
        volume(&self.bounds)
    }

    /// Halve along `axis` at the midpoint. Children start with empty
    /// moments — the caller seeds them (e.g. from the axis probes).
    pub fn split(&self, axis: usize) -> (Stratum, Stratum) {
        let (lo, hi) = self.bounds[axis];
        let mid = 0.5 * (lo + hi);
        let mut a = Stratum::root(&self.bounds);
        let mut b = Stratum::root(&self.bounds);
        a.bounds[axis].1 = mid;
        b.bounds[axis].0 = mid;
        (a, b)
    }

    /// This stratum's standard-error contribution `V_s·√(var_s/n_s)`
    /// to the combined estimate (infinite when unsampled).
    pub fn error_contribution(&self) -> f64 {
        if self.moments.n == 0 {
            return f64::INFINITY;
        }
        self.volume()
            * (self.moments.variance() / self.moments.n as f64).sqrt()
    }

    /// Neyman allocation weight `V_s·σ_s` (falls back to the bare
    /// volume when the stratum has no samples to estimate σ from).
    pub fn neyman_weight(&self) -> f64 {
        if self.moments.n == 0 {
            return self.volume();
        }
        self.volume() * self.moments.variance().sqrt()
    }
}

/// Combined `(value, std_err, n_samples)` over a function's partition.
pub fn partition_estimate(strata: &[Stratum]) -> (f64, f64, u64) {
    let parts: Vec<(f64, MomentSum)> =
        strata.iter().map(|s| (s.volume(), s.moments)).collect();
    let (value, std_err) = stratified_estimate(&parts);
    (value, std_err, strata.iter().map(|s| s.moments.n).sum())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_partitions_volume() {
        let s = Stratum::root(&[(0.0, 2.0), (-1.0, 1.0)]);
        assert_eq!(s.volume(), 4.0);
        let (a, b) = s.split(0);
        assert_eq!(a.bounds[0], (0.0, 1.0));
        assert_eq!(b.bounds[0], (1.0, 2.0));
        assert_eq!(a.bounds[1], (-1.0, 1.0));
        assert_eq!(a.volume() + b.volume(), s.volume());
        assert_eq!(a.moments.n, 0);
    }

    #[test]
    fn weights_and_contributions() {
        let mut s = Stratum::root(&[(0.0, 2.0)]);
        assert!(s.error_contribution().is_infinite());
        assert_eq!(s.neyman_weight(), 2.0); // volume fallback
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.moments.push(v);
        }
        // var = 1.25, n = 4, V = 2
        let want_err = 2.0 * (1.25f64 / 4.0).sqrt();
        assert!((s.error_contribution() - want_err).abs() < 1e-12);
        assert!((s.neyman_weight() - 2.0 * 1.25f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn partition_estimate_sums_strata() {
        let mut a = Stratum::root(&[(0.0, 1.0)]);
        let mut b = Stratum::root(&[(1.0, 2.0)]);
        for v in [0.5, 0.5] {
            a.moments.push(v);
        }
        for v in [1.5, 1.5] {
            b.moments.push(v);
        }
        let (value, err, n) = partition_estimate(&[a, b]);
        assert!((value - 2.0).abs() < 1e-12);
        assert_eq!(err, 0.0); // zero variance in both strata
        assert_eq!(n, 4);
    }
}
