//! Pratt parser: tokens → AST.
//!
//! Precedence (low→high): `+ -` < `* /` < unary `-` < `^` (right-assoc)
//! < atoms. `2^-3` and `-x1^2 == -(x1^2)` follow the usual math rules.

use super::lexer::{lex, Tok};
use super::{BinOp, Expr, UnOp};

pub fn parse(src: &str) -> Result<Expr, String> {
    let toks = lex(src)?;
    let mut p = P { toks, i: 0 };
    let e = p.expr()?;
    if p.i != p.toks.len() {
        return Err(format!("unexpected token at position {}", p.i));
    }
    Ok(e)
}

struct P {
    toks: Vec<Tok>,
    i: usize,
}

impl P {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.i)
    }

    fn next(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.i).cloned();
        if t.is_some() {
            self.i += 1;
        }
        t
    }

    fn expect(&mut self, t: &Tok) -> Result<(), String> {
        match self.next() {
            Some(ref got) if got == t => Ok(()),
            got => Err(format!("expected {t:?}, got {got:?}")),
        }
    }

    /// expr := term (('+'|'-') term)*
    fn expr(&mut self) -> Result<Expr, String> {
        let mut lhs = self.term()?;
        loop {
            match self.peek() {
                Some(Tok::Plus) => {
                    self.i += 1;
                    let rhs = self.term()?;
                    lhs = Expr::Binary(BinOp::Add, lhs.into(), rhs.into());
                }
                Some(Tok::Minus) => {
                    self.i += 1;
                    let rhs = self.term()?;
                    lhs = Expr::Binary(BinOp::Sub, lhs.into(), rhs.into());
                }
                _ => return Ok(lhs),
            }
        }
    }

    /// term := unary (('*'|'/') unary)*
    fn term(&mut self) -> Result<Expr, String> {
        let mut lhs = self.unary()?;
        loop {
            match self.peek() {
                Some(Tok::Star) => {
                    self.i += 1;
                    let rhs = self.unary()?;
                    lhs = Expr::Binary(BinOp::Mul, lhs.into(), rhs.into());
                }
                Some(Tok::Slash) => {
                    self.i += 1;
                    let rhs = self.unary()?;
                    lhs = Expr::Binary(BinOp::Div, lhs.into(), rhs.into());
                }
                _ => return Ok(lhs),
            }
        }
    }

    /// unary := '-' unary | power
    fn unary(&mut self) -> Result<Expr, String> {
        if self.peek() == Some(&Tok::Minus) {
            self.i += 1;
            let inner = self.unary()?;
            // fold a negated literal into the constant so that the
            // Display round-trip `(-3.5)` reparses to the same AST
            if let Expr::Const(c) = inner {
                return Ok(Expr::Const(-c));
            }
            return Ok(Expr::Unary(UnOp::Neg, inner.into()));
        }
        self.power()
    }

    /// power := atom ('^' unary)?   — right-associative, binds tighter
    /// than unary minus on the left (so `-x^2 = -(x^2)`), and allows a
    /// signed exponent (`x^-2`).
    fn power(&mut self) -> Result<Expr, String> {
        let base = self.atom()?;
        if self.peek() == Some(&Tok::Caret) {
            self.i += 1;
            let exp = self.unary()?;
            return Ok(Expr::Binary(BinOp::Pow, base.into(), exp.into()));
        }
        Ok(base)
    }

    fn atom(&mut self) -> Result<Expr, String> {
        match self.next() {
            Some(Tok::Num(n)) => Ok(Expr::Const(n)),
            Some(Tok::Var(i)) => Ok(Expr::Var(i)),
            Some(Tok::Param(i)) => Ok(Expr::Param(i)),
            Some(Tok::LParen) => {
                let e = self.expr()?;
                self.expect(&Tok::RParen)?;
                Ok(e)
            }
            Some(Tok::Ident(name)) => self.call_or_const(&name),
            t => Err(format!("expected a value, got {t:?}")),
        }
    }

    fn call_or_const(&mut self, name: &str) -> Result<Expr, String> {
        // named constants
        match name {
            "pi" => return Ok(Expr::Const(std::f64::consts::PI)),
            "e" => return Ok(Expr::Const(std::f64::consts::E)),
            _ => {}
        }
        let un = match name {
            "sin" => Some(UnOp::Sin),
            "cos" => Some(UnOp::Cos),
            "tan" => Some(UnOp::Tan),
            "exp" => Some(UnOp::Exp),
            "log" | "ln" => Some(UnOp::Log),
            "sqrt" => Some(UnOp::Sqrt),
            "abs" => Some(UnOp::Abs),
            "tanh" => Some(UnOp::Tanh),
            "atan" | "arctan" => Some(UnOp::Atan),
            "floor" => Some(UnOp::Floor),
            _ => None,
        };
        if let Some(op) = un {
            self.expect(&Tok::LParen)?;
            let a = self.expr()?;
            self.expect(&Tok::RParen)?;
            return Ok(Expr::Unary(op, a.into()));
        }
        let bin = match name {
            "min" => Some(BinOp::Min),
            "max" => Some(BinOp::Max),
            "pow" => Some(BinOp::Pow),
            _ => None,
        };
        if let Some(op) = bin {
            self.expect(&Tok::LParen)?;
            let a = self.expr()?;
            self.expect(&Tok::Comma)?;
            let b = self.expr()?;
            self.expect(&Tok::RParen)?;
            return Ok(Expr::Binary(op, a.into(), b.into()));
        }
        Err(format!("unknown function or constant '{name}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(v: f64) -> Expr {
        Expr::Const(v)
    }

    #[test]
    fn precedence() {
        // 1 + 2*3 = 1 + (2*3)
        assert_eq!(
            parse("1 + 2*3").unwrap(),
            Expr::Binary(
                BinOp::Add,
                c(1.0).into(),
                Expr::Binary(BinOp::Mul, c(2.0).into(), c(3.0).into()).into()
            )
        );
    }

    #[test]
    fn power_right_assoc() {
        // 2^3^2 = 2^(3^2) = 512
        let e = parse("2^3^2").unwrap();
        assert_eq!(e.eval(&[], &[]), 512.0);
    }

    #[test]
    fn unary_minus_vs_power() {
        // -2^2 = -(2^2) = -4 ; 2^-2 = 0.25
        assert_eq!(parse("-2^2").unwrap().eval(&[], &[]), -4.0);
        assert_eq!(parse("2^-2").unwrap().eval(&[], &[]), 0.25);
        assert_eq!(parse("--2").unwrap().eval(&[], &[]), 2.0);
    }

    #[test]
    fn functions_and_constants() {
        let e = parse("sin(pi/2) + min(1, 2) + pow(2, 3)").unwrap();
        assert!((e.eval(&[], &[]) - 10.0).abs() < 1e-12);
        assert!((parse("ln(e)").unwrap().eval(&[], &[]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn division_left_assoc() {
        assert_eq!(parse("8/4/2").unwrap().eval(&[], &[]), 1.0);
        assert_eq!(parse("8-4-2").unwrap().eval(&[], &[]), 2.0);
    }

    #[test]
    fn errors() {
        assert!(parse("1 +").is_err());
        assert!(parse("(1").is_err());
        assert!(parse("foo(1)").is_err());
        assert!(parse("min(1)").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("sin x1").is_err());
    }

    #[test]
    fn eq1_and_eq2_parse() {
        assert!(parse(
            "cos(9.07*(x1+x2+x3+x4)) + sin(9.07*(x1+x2+x3+x4))"
        )
        .is_ok());
        assert!(parse("p0 * abs(x1 + x2 - x3)").is_ok());
    }
}
