//! Expression language — how users hand integrands to the coordinator.
//!
//! The paper's Python API accepts integrand *source strings* that
//! Numba JIT-compiles at run time; with no Python in our runtime, the
//! equivalent flexibility comes from this small math-expression language,
//! compiled to device bytecode at job-submission time:
//!
//! ```text
//! "cos(9.07*(x1+x2+x3+x4)) + sin(9.07*(x1+x2+x3+x4))"   // Eq. (1)
//! "p0 * abs(x1 + x2 - x3)"                              // Eq. (2)
//! ```
//!
//! * variables `x1`..`x8` (1-based, paper notation)
//! * parameters `p0`..`p15` (bound per function at run time)
//! * constants `pi`, `e`; literals `1`, `2.5`, `1e-3`
//! * operators `+ - * / ^` (with unary minus; `^` right-associative)
//! * functions `sin cos tan exp log sqrt abs tanh atan floor`
//!   and 2-argument `min max pow`
//!
//! Pipeline: [`lexer`] → [`parser`] → [`fold`] (constant folding +
//! strength reduction) → [`compile`] (bytecode emission with stack-depth
//! validation). [`Expr::eval`] is the tree-walk oracle the property tests
//! compare the VM against.

pub mod compile;
pub mod eval;
pub mod fold;
pub mod lexer;
pub mod parser;

use std::fmt;

use crate::vm::program::Program;

/// Unary operators / functions (all map 1:1 onto VM opcodes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnOp {
    Neg,
    Abs,
    Sin,
    Cos,
    Tan,
    Exp,
    Log,
    Sqrt,
    Tanh,
    Atan,
    Floor,
    /// Introduced by strength reduction of `x^2` (no surface syntax).
    Square,
    /// Introduced by strength reduction of `1/x` (no surface syntax).
    Recip,
}

/// Binary operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Pow,
    Min,
    Max,
}

/// Expression AST.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Const(f64),
    /// 0-based variable index (`x1` parses to `Var(0)`).
    Var(usize),
    /// Parameter slot (`p3` parses to `Param(3)`).
    Param(usize),
    Unary(UnOp, Box<Expr>),
    Binary(BinOp, Box<Expr>, Box<Expr>),
}

impl Expr {
    /// Parse source text into an AST (no folding).
    pub fn parse_raw(src: &str) -> Result<Expr, String> {
        parser::parse(src)
    }

    /// Parse + constant-fold + strength-reduce.
    pub fn parse(src: &str) -> Result<Expr, String> {
        Ok(fold::fold(parser::parse(src)?))
    }

    /// Compile to validated device bytecode.
    pub fn compile(&self) -> Result<Program, String> {
        compile::compile(self)
    }

    /// Tree-walk evaluation (f64) — the oracle.
    pub fn eval(&self, x: &[f64], theta: &[f64]) -> f64 {
        eval::eval(self, x, theta)
    }

    /// Highest variable index used + 1.
    pub fn dims(&self) -> usize {
        match self {
            Expr::Const(_) => 0,
            Expr::Var(i) => i + 1,
            Expr::Param(_) => 0,
            Expr::Unary(_, a) => a.dims(),
            Expr::Binary(_, a, b) => a.dims().max(b.dims()),
        }
    }

    /// Highest parameter index used + 1.
    pub fn n_params(&self) -> usize {
        match self {
            Expr::Const(_) | Expr::Var(_) => 0,
            Expr::Param(i) => i + 1,
            Expr::Unary(_, a) => a.n_params(),
            Expr::Binary(_, a, b) => a.n_params().max(b.n_params()),
        }
    }
}

impl UnOp {
    pub fn name(self) -> &'static str {
        match self {
            UnOp::Neg => "-",
            UnOp::Abs => "abs",
            UnOp::Sin => "sin",
            UnOp::Cos => "cos",
            UnOp::Tan => "tan",
            UnOp::Exp => "exp",
            UnOp::Log => "log",
            UnOp::Sqrt => "sqrt",
            UnOp::Tanh => "tanh",
            UnOp::Atan => "atan",
            UnOp::Floor => "floor",
            UnOp::Square => "square",
            UnOp::Recip => "recip",
        }
    }
}

impl fmt::Display for Expr {
    /// Fully-parenthesized form; `parse(format!("{e}"))` reproduces the
    /// AST (modulo Square/Recip, printed via `^2` and `1/x`) — the
    /// round-trip property in `tests/expr_prop.rs`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Const(c) => {
                if *c < 0.0 {
                    write!(f, "({c})")
                } else {
                    write!(f, "{c}")
                }
            }
            Expr::Var(i) => write!(f, "x{}", i + 1),
            Expr::Param(i) => write!(f, "p{i}"),
            Expr::Unary(UnOp::Neg, a) => write!(f, "(-{a})"),
            Expr::Unary(UnOp::Square, a) => write!(f, "({a}^2)"),
            Expr::Unary(UnOp::Recip, a) => write!(f, "(1/{a})"),
            Expr::Unary(op, a) => write!(f, "{}({a})", op.name()),
            Expr::Binary(op, a, b) => {
                let s = match op {
                    BinOp::Add => "+",
                    BinOp::Sub => "-",
                    BinOp::Mul => "*",
                    BinOp::Div => "/",
                    BinOp::Pow => "^",
                    BinOp::Min => return write!(f, "min({a}, {b})"),
                    BinOp::Max => return write!(f, "max({a}, {b})"),
                };
                write!(f, "({a} {s} {b})")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_parse_compile_eval() {
        let e = Expr::parse("p0 * abs(x1 + x2 - x3)").unwrap();
        assert_eq!(e.dims(), 3);
        assert_eq!(e.n_params(), 1);
        let prog = e.compile().unwrap();
        let x = [0.3, 0.9, 2.0];
        let got = crate::vm::interp::eval_scalar(&prog, &x, &[2.5]);
        assert!((got - 2.5 * (0.3f64 + 0.9 - 2.0).abs()).abs() < 1e-9);
    }

    #[test]
    fn display_roundtrip_simple() {
        for src in [
            "x1 + 2 * x2",
            "sin(x1) ^ 2",
            "min(x1, max(x2, 0.5))",
            "-x1 + pi",
        ] {
            let e = Expr::parse_raw(src).unwrap();
            let e2 = Expr::parse_raw(&e.to_string()).unwrap();
            assert_eq!(e, e2, "{src}");
        }
    }
}
