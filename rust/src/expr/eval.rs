//! Tree-walk evaluator — the semantic reference for both the rust VM and
//! (transitively, through the ABI tests) the device kernels.

use super::{BinOp, Expr, UnOp};

pub fn eval(e: &Expr, x: &[f64], theta: &[f64]) -> f64 {
    match e {
        Expr::Const(c) => *c,
        Expr::Var(i) => x[*i],
        Expr::Param(i) => theta[*i],
        Expr::Unary(op, a) => {
            let a = eval(a, x, theta);
            match op {
                UnOp::Neg => -a,
                UnOp::Abs => a.abs(),
                UnOp::Sin => a.sin(),
                UnOp::Cos => a.cos(),
                UnOp::Tan => a.tan(),
                UnOp::Exp => a.exp(),
                UnOp::Log => a.ln(),
                UnOp::Sqrt => a.sqrt(),
                UnOp::Tanh => a.tanh(),
                UnOp::Atan => a.atan(),
                UnOp::Floor => a.floor(),
                UnOp::Square => a * a,
                UnOp::Recip => 1.0 / a,
            }
        }
        Expr::Binary(op, a, b) => {
            let a = eval(a, x, theta);
            let b = eval(b, x, theta);
            match op {
                BinOp::Add => a + b,
                BinOp::Sub => a - b,
                BinOp::Mul => a * b,
                BinOp::Div => a / b,
                BinOp::Pow => a.powf(b),
                BinOp::Min => a.min(b),
                BinOp::Max => a.max(b),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::Expr as E;

    #[test]
    fn vars_and_params() {
        let e = E::parse_raw("x1*p0 + x2*p1").unwrap();
        assert_eq!(eval(&e, &[2.0, 3.0], &[10.0, 100.0]), 320.0);
    }

    #[test]
    fn special_values() {
        let e = E::parse_raw("log(x1)").unwrap();
        assert!(eval(&e, &[-1.0], &[]).is_nan());
        assert_eq!(eval(&e, &[0.0], &[]), f64::NEG_INFINITY);
        let d = E::parse_raw("1/x1").unwrap();
        assert_eq!(eval(&d, &[0.0], &[]), f64::INFINITY);
    }
}
