//! Constant folding + strength reduction.
//!
//! Rewrites applied bottom-up (all exactly value-preserving for the
//! tree-walk semantics — verified by the `fold_preserves_semantics`
//! property test):
//!
//! * subtree of constants → the constant (via [`super::eval`])
//! * `x ^ 2` → `square(x)`, `x ^ 1` → `x`, `x ^ 0.5` → `sqrt(x)`
//! * `1 / x` → `recip(x)` (cheaper VM op; identical IEEE result)
//! * `x * 1`, `1 * x`, `x + 0`, `0 + x`, `x - 0`, `x / 1` → `x`
//! * `neg(neg(x))` → `x`
//!
//! `x * 0 → 0` is deliberately NOT applied: it changes NaN/Inf
//! propagation (`Inf * 0 = NaN`, not `0`).

use super::{BinOp, Expr, UnOp};

pub fn fold(e: Expr) -> Expr {
    match e {
        Expr::Unary(op, a) => {
            let a = fold(*a);
            if let Expr::Const(ca) = a {
                return Expr::Const(super::eval::eval(
                    &Expr::Unary(op, Expr::Const(ca).into()),
                    &[],
                    &[],
                ));
            }
            // --x → x
            if op == UnOp::Neg {
                if let Expr::Unary(UnOp::Neg, inner) = a {
                    return *inner;
                }
                return Expr::Unary(UnOp::Neg, a.into());
            }
            Expr::Unary(op, a.into())
        }
        Expr::Binary(op, a, b) => {
            let a = fold(*a);
            let b = fold(*b);
            if let (Expr::Const(_), Expr::Const(_)) = (&a, &b) {
                return Expr::Const(super::eval::eval(
                    &Expr::Binary(op, a.into(), b.into()),
                    &[],
                    &[],
                ));
            }
            match (op, &a, &b) {
                // identities
                (BinOp::Add, Expr::Const(c), _) if *c == 0.0 => return b,
                (BinOp::Add, _, Expr::Const(c)) if *c == 0.0 => return a,
                (BinOp::Sub, _, Expr::Const(c)) if *c == 0.0 => return a,
                (BinOp::Mul, Expr::Const(c), _) if *c == 1.0 => return b,
                (BinOp::Mul, _, Expr::Const(c)) if *c == 1.0 => return a,
                (BinOp::Div, _, Expr::Const(c)) if *c == 1.0 => return a,
                // strength reduction
                (BinOp::Pow, _, Expr::Const(c)) if *c == 2.0 => {
                    return Expr::Unary(UnOp::Square, a.into())
                }
                (BinOp::Pow, _, Expr::Const(c)) if *c == 1.0 => return a,
                (BinOp::Pow, _, Expr::Const(c)) if *c == 0.5 => {
                    return Expr::Unary(UnOp::Sqrt, a.into())
                }
                (BinOp::Div, Expr::Const(c), _) if *c == 1.0 => {
                    return Expr::Unary(UnOp::Recip, b.into())
                }
                _ => {}
            }
            Expr::Binary(op, a.into(), b.into())
        }
        leaf => leaf,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::Expr as E;

    fn f(src: &str) -> Expr {
        fold(E::parse_raw(src).unwrap())
    }

    #[test]
    fn constant_subtrees_collapse() {
        assert_eq!(f("2 + 3*4"), Expr::Const(14.0));
        assert_eq!(f("sin(0)"), Expr::Const(0.0));
        assert_eq!(f("2^10"), Expr::Const(1024.0));
    }

    #[test]
    fn identities() {
        assert_eq!(f("x1 + 0"), Expr::Var(0));
        assert_eq!(f("0 + x1"), Expr::Var(0));
        assert_eq!(f("x1 * 1"), Expr::Var(0));
        assert_eq!(f("x1 / 1"), Expr::Var(0));
        assert_eq!(f("x1 - 0"), Expr::Var(0));
        assert_eq!(f("--x1"), Expr::Var(0));
    }

    #[test]
    fn strength_reduction() {
        assert_eq!(f("x1^2"), Expr::Unary(UnOp::Square, Expr::Var(0).into()));
        assert_eq!(f("x1^1"), Expr::Var(0));
        assert_eq!(f("x1^0.5"), Expr::Unary(UnOp::Sqrt, Expr::Var(0).into()));
        assert_eq!(f("1/x1"), Expr::Unary(UnOp::Recip, Expr::Var(0).into()));
    }

    #[test]
    fn mul_zero_not_folded() {
        // would change Inf*0 semantics
        assert!(matches!(f("x1 * 0"), Expr::Binary(BinOp::Mul, _, _)));
    }

    #[test]
    fn partial_fold_in_context() {
        // (2+3) stays folded inside a var expression
        let e = f("x1 * (2 + 3)");
        assert_eq!(
            e,
            Expr::Binary(
                BinOp::Mul,
                Expr::Var(0).into(),
                Expr::Const(5.0).into()
            )
        );
    }
}
