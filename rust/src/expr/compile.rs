//! AST → bytecode. Post-order emission; operand order matches the VM's
//! stack convention (left operand pushed first, so `SUB`/`DIV`/`POW`
//! compute `a op b` with `b` on top).
//!
//! Stack pressure: for a binary node we emit the *deeper* side first when
//! both orders are legal (commutative ops), which keeps the maximum stack
//! depth at the Strahler number of the tree rather than its height —
//! letting considerably larger expressions fit the device STACK=16.

use super::{BinOp, Expr, UnOp};
use crate::vm::opcodes::Op;
use crate::vm::program::{Instr, Program};

pub fn compile(e: &Expr) -> Result<Program, String> {
    let mut out = Vec::new();
    emit(e, &mut out);
    Program::new(out).map_err(|err| format!("{err} (in: {e})"))
}

fn emit(e: &Expr, out: &mut Vec<Instr>) {
    match e {
        Expr::Const(c) => out.push(Instr::konst(*c as f32)),
        Expr::Var(i) => out.push(Instr::var(*i)),
        Expr::Param(i) => out.push(Instr::param(*i)),
        Expr::Unary(op, a) => {
            emit(a, out);
            out.push(Instr::new(unop_code(*op)));
        }
        Expr::Binary(op, a, b) => {
            let commutative =
                matches!(op, BinOp::Add | BinOp::Mul | BinOp::Min | BinOp::Max);
            if commutative && pressure(b) > pressure(a) {
                // evaluate the deeper operand first; commutativity keeps
                // semantics identical while reducing peak stack depth.
                emit(b, out);
                emit(a, out);
            } else {
                emit(a, out);
                emit(b, out);
            }
            out.push(Instr::new(binop_code(*op)));
        }
    }
}

/// Minimum stack registers needed to evaluate this subtree (Strahler-ish).
fn pressure(e: &Expr) -> u32 {
    match e {
        Expr::Const(_) | Expr::Var(_) | Expr::Param(_) => 1,
        Expr::Unary(_, a) => pressure(a),
        Expr::Binary(_, a, b) => {
            let (pa, pb) = (pressure(a), pressure(b));
            if pa == pb {
                pa + 1
            } else {
                pa.max(pb)
            }
        }
    }
}

fn unop_code(op: UnOp) -> Op {
    match op {
        UnOp::Neg => Op::NEG,
        UnOp::Abs => Op::ABS,
        UnOp::Sin => Op::SIN,
        UnOp::Cos => Op::COS,
        UnOp::Tan => Op::TAN,
        UnOp::Exp => Op::EXP,
        UnOp::Log => Op::LOG,
        UnOp::Sqrt => Op::SQRT,
        UnOp::Tanh => Op::TANH,
        UnOp::Atan => Op::ATAN,
        UnOp::Floor => Op::FLOOR,
        UnOp::Square => Op::SQUARE,
        UnOp::Recip => Op::RECIP,
    }
}

fn binop_code(op: BinOp) -> Op {
    match op {
        BinOp::Add => Op::ADD,
        BinOp::Sub => Op::SUB,
        BinOp::Mul => Op::MUL,
        BinOp::Div => Op::DIV,
        BinOp::Pow => Op::POW,
        BinOp::Min => Op::MIN,
        BinOp::Max => Op::MAX,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vm::interp::eval_scalar;

    fn check(src: &str, x: &[f64], theta: &[f64]) {
        let e = Expr::parse(src).unwrap();
        let prog = e.compile().unwrap();
        let want = e.eval(x, theta);
        let got = eval_scalar(&prog, x, theta);
        let tol = 1e-5 * want.abs().max(1.0);
        assert!(
            (got - want).abs() < tol || (got.is_nan() && want.is_nan()),
            "{src}: vm={got} tree={want}"
        );
    }

    #[test]
    fn compiled_matches_tree_walk() {
        check("x1 + x2*x3 - 4", &[1.0, 2.0, 3.0], &[]);
        check("sin(x1)^2 + cos(x1)^2", &[0.7], &[]);
        check("p0*abs(x1+x2-x3)", &[0.1, 0.5, 0.9], &[3.0]);
        check("min(x1, max(x2, 0.25))", &[0.4, 0.1], &[]);
        check("2^x1", &[3.0], &[]);
        check("x1/x2", &[1.0, 3.0], &[]);
    }

    #[test]
    fn noncommutative_order_preserved() {
        check("x1 - x2", &[10.0, 3.0], &[]);
        check("x1 / x2", &[10.0, 4.0], &[]);
        check("x1 ^ x2", &[2.0, 5.0], &[]);
    }

    #[test]
    fn pressure_reorder_reduces_depth() {
        // left-leaning vs right-leaning sums compile to the same depth
        let left = Expr::parse_raw("((x1+x2)+x3)+x4").unwrap();
        let right = Expr::parse_raw("x1+(x2+(x3+x4))").unwrap();
        let pl = compile(&left).unwrap();
        let pr = compile(&right).unwrap();
        assert_eq!(pl.max_depth, 2);
        assert_eq!(pr.max_depth, 2);
    }

    #[test]
    fn too_deep_expression_errors() {
        // a full binary tree of SUBs (non-commutative, no reordering)
        // with depth 17 needs stack 17 > 16.
        fn deep(n: usize) -> String {
            if n == 0 {
                "x1".into()
            } else {
                format!("({} - {})", deep(n - 1), deep(n - 1))
            }
        }
        // depth-5 tree: 2^5=32 leaves, needs stack 6 — fine but long;
        // verify the length error path too.
        let e = Expr::parse_raw(&deep(5)).unwrap();
        assert!(compile(&e).is_err()); // 63 instrs > MAX_PROG=48
    }

    #[test]
    fn fig1_program_fits() {
        let e = Expr::parse(
            "cos(9.07*(x1+x2+x3+x4)) + sin(9.07*(x1+x2+x3+x4))",
        )
        .unwrap();
        let p = e.compile().unwrap();
        assert!(p.len() <= 24, "len={}", p.len());
        assert!(p.max_depth <= 4);
    }
}
