//! Tokenizer for the expression language.

#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    Num(f64),
    /// `x1`..`x8` → 0-based index.
    Var(usize),
    /// `p0`..`p15`.
    Param(usize),
    /// Function / named-constant identifier (`sin`, `pi`, ...).
    Ident(String),
    Plus,
    Minus,
    Star,
    Slash,
    Caret,
    LParen,
    RParen,
    Comma,
}

pub fn lex(src: &str) -> Result<Vec<Tok>, String> {
    let b = src.as_bytes();
    let mut i = 0;
    let mut out = Vec::new();
    while i < b.len() {
        let c = b[i];
        match c {
            b' ' | b'\t' | b'\n' | b'\r' => i += 1,
            b'+' => {
                out.push(Tok::Plus);
                i += 1;
            }
            b'-' => {
                out.push(Tok::Minus);
                i += 1;
            }
            b'*' => {
                // tolerate python-style `**` for power
                if b.get(i + 1) == Some(&b'*') {
                    out.push(Tok::Caret);
                    i += 2;
                } else {
                    out.push(Tok::Star);
                    i += 1;
                }
            }
            b'/' => {
                out.push(Tok::Slash);
                i += 1;
            }
            b'^' => {
                out.push(Tok::Caret);
                i += 1;
            }
            b'(' => {
                out.push(Tok::LParen);
                i += 1;
            }
            b')' => {
                out.push(Tok::RParen);
                i += 1;
            }
            b',' => {
                out.push(Tok::Comma);
                i += 1;
            }
            b'0'..=b'9' | b'.' => {
                let start = i;
                while i < b.len() && (b[i].is_ascii_digit() || b[i] == b'.') {
                    i += 1;
                }
                // exponent part
                if i < b.len() && (b[i] == b'e' || b[i] == b'E') {
                    let mut j = i + 1;
                    if j < b.len() && (b[j] == b'+' || b[j] == b'-') {
                        j += 1;
                    }
                    if j < b.len() && b[j].is_ascii_digit() {
                        i = j;
                        while i < b.len() && b[i].is_ascii_digit() {
                            i += 1;
                        }
                    }
                }
                let s = &src[start..i];
                let n: f64 = s
                    .parse()
                    .map_err(|_| format!("bad number literal '{s}'"))?;
                out.push(Tok::Num(n));
            }
            b'a'..=b'z' | b'A'..=b'Z' | b'_' => {
                let start = i;
                while i < b.len()
                    && (b[i].is_ascii_alphanumeric() || b[i] == b'_')
                {
                    i += 1;
                }
                let s = &src[start..i];
                out.push(classify_ident(s)?);
            }
            _ => {
                return Err(format!(
                    "unexpected character '{}' at byte {i}",
                    c as char
                ))
            }
        }
    }
    if out.is_empty() {
        return Err("empty expression".into());
    }
    Ok(out)
}

fn classify_ident(s: &str) -> Result<Tok, String> {
    // x<k>: 1-based variable
    if let Some(rest) = s.strip_prefix('x') {
        if let Ok(k) = rest.parse::<usize>() {
            if k == 0 {
                return Err("variables are 1-based: x1, x2, ...".into());
            }
            if k > crate::abi::MAX_DIM {
                return Err(format!(
                    "variable x{k} exceeds MAX_DIM={}",
                    crate::abi::MAX_DIM
                ));
            }
            return Ok(Tok::Var(k - 1));
        }
    }
    // p<k>: 0-based parameter
    if let Some(rest) = s.strip_prefix('p') {
        if let Ok(k) = rest.parse::<usize>() {
            if k >= crate::abi::MAX_PARAM {
                return Err(format!(
                    "parameter p{k} exceeds MAX_PARAM={}",
                    crate::abi::MAX_PARAM
                ));
            }
            return Ok(Tok::Param(k));
        }
    }
    Ok(Tok::Ident(s.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_tokens() {
        let t = lex("x1 + 2.5*sin(p0)^2").unwrap();
        assert_eq!(
            t,
            vec![
                Tok::Var(0),
                Tok::Plus,
                Tok::Num(2.5),
                Tok::Star,
                Tok::Ident("sin".into()),
                Tok::LParen,
                Tok::Param(0),
                Tok::RParen,
                Tok::Caret,
                Tok::Num(2.0),
            ]
        );
    }

    #[test]
    fn python_power() {
        assert_eq!(lex("x1**2").unwrap()[1], Tok::Caret);
    }

    #[test]
    fn scientific_notation() {
        assert_eq!(lex("1e-3").unwrap(), vec![Tok::Num(1e-3)]);
        assert_eq!(lex("2.5E+2").unwrap(), vec![Tok::Num(250.0)]);
        // 'e' not followed by digits is an identifier (Euler constant)
        assert_eq!(
            lex("2e").unwrap(),
            vec![Tok::Num(2.0), Tok::Ident("e".into())]
        );
    }

    #[test]
    fn index_bounds() {
        assert!(lex("x0").is_err());
        assert!(lex("x9").is_err());
        assert!(lex("p16").is_err());
        assert!(lex("x8").is_ok());
        assert!(lex("p15").is_ok());
    }

    #[test]
    fn bad_chars_rejected() {
        assert!(lex("x1 $ 2").is_err());
        assert!(lex("").is_err());
        assert!(lex("1..2").is_err());
    }
}
