//! Deterministic transport fault injection for the cluster wire.
//!
//! A [`FaultPlan`] is a *seeded schedule* of faults keyed by
//! `(connection index, data-frame index)`: connection indices are
//! handed out in connect order (reconnects get fresh indices), and
//! frame indices count the frames a connection actually ships in
//! order — heartbeat `Ping`/`Pong` frames are excluded because their
//! timing is wall-clock, not program order, and counting them would
//! make the schedule racy. Frame 0 of every connection is its
//! `Hello`, frame 1 its first `Submit`, and so on.
//!
//! The plan is threaded through the [`Transport`] trait, the one seam
//! every client-side frame write crosses. Production uses
//! [`DirectTcp`] (a plain `write_all` + flush); tests and `ZMC_CHAOS`
//! wrap the same socket in a [`ChaosTcp`] that consults the plan
//! before each send. Every fault class degrades to something the
//! transport already survives — a dead connection (whole-shard
//! requeue + reconnect) or a latency spike — so results stay
//! bit-identical to a fault-free run; `tests/chaos_test.rs` proves
//! it for each class.
//!
//! Schedule text format (the `ZMC_CHAOS` env var and
//! [`FaultPlan::parse`]):
//!
//! ```text
//! ZMC_CHAOS="drop@0:1,corrupt@0:3,hang@1:2"   # class@conn:frame
//! ZMC_CHAOS="seeded:42:5"                     # seeded:<seed>:<events>
//! ```
//!
//! There is deliberately no randomness source in this module beyond
//! splitmix64 of the caller's seed: the same plan replays the same
//! faults at the same frames on every run.

use std::collections::BTreeMap;
use std::io::{self, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::wire::{TAG_PING, TAG_PONG};

/// One scheduled transport fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Sever the connection instead of sending the frame.
    Drop,
    /// Sleep this long, then send the frame normally (a latency
    /// spike; never affects results or liveness accounting).
    Delay(Duration),
    /// Send only the first `n` bytes of the frame, then sever — the
    /// peer sees a typed mid-frame truncation.
    Truncate(usize),
    /// XOR one byte of the frame (`offset` is taken modulo the frame
    /// length) — the peer sees a typed decode error, never a wrong
    /// value, because the frame checksum covers everything past the
    /// version field.
    Corrupt { offset: usize, xor: u8 },
    /// Write nothing, keep the socket open, and swallow every later
    /// frame on this connection — a peer gone catatonic, detected by
    /// heartbeat silence.
    Hang,
}

/// A deterministic schedule of [`Fault`]s, keyed by connection and
/// data-frame index. Shared (via `Arc`) between every connection a
/// cluster opens so connection indices are globally ordered.
#[derive(Debug, Default)]
pub struct FaultPlan {
    events: BTreeMap<(u64, u64), Fault>,
    next_conn: AtomicU64,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `fault` at data-frame `frame` of connection `conn`
    /// (builder style).
    pub fn event(mut self, conn: u64, frame: u64, fault: Fault) -> Self {
        self.events.insert((conn, frame), fault);
        self
    }

    /// A pseudo-random schedule of `events` faults derived entirely
    /// from `seed` — same seed, same schedule, every run. Faults land
    /// on connections 0..3 and data frames 1.. (never frame 0, so an
    /// initial handshake always completes and cluster construction
    /// cannot fail before the plan gets a chance to bite).
    pub fn seeded(seed: u64, events: usize) -> Self {
        let mut plan = FaultPlan::new();
        let mut s = seed;
        for _ in 0..events {
            s = splitmix64(s);
            let conn = s % 3;
            let frame = 1 + (splitmix64(s ^ 0xA5A5) % 6);
            let h = splitmix64(s ^ 0x5A5A);
            let fault = match h % 5 {
                0 => Fault::Drop,
                1 => Fault::Delay(Duration::from_millis(5 + h % 40)),
                2 => Fault::Truncate((h % 20) as usize),
                3 => Fault::Corrupt {
                    offset: ((h >> 8) % 64) as usize,
                    xor: ((h >> 16) as u8) | 1,
                },
                _ => Fault::Hang,
            };
            plan.events.insert((conn, frame), fault);
        }
        plan
    }

    /// The plan described by `ZMC_CHAOS`, if the variable is set and
    /// parses (a malformed schedule is reported and ignored — chaos
    /// is a debugging knob, not a correctness input).
    pub fn from_env() -> Option<Arc<FaultPlan>> {
        let spec = std::env::var("ZMC_CHAOS").ok()?;
        match Self::parse(&spec) {
            Ok(p) => Some(Arc::new(p)),
            Err(e) => {
                eprintln!("note: ignoring ZMC_CHAOS ({e})");
                None
            }
        }
    }

    /// Parse a schedule: either `seeded:<seed>:<events>` or a
    /// comma-separated list of `class@conn:frame` entries with class
    /// one of `drop|delay|truncate|corrupt|hang`. List entries take
    /// their parameters (delay length, truncation point, corrupted
    /// byte) from a hash of their position, so the text form stays
    /// one token per event.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let spec = spec.trim();
        if let Some(rest) = spec.strip_prefix("seeded:") {
            let (seed, events) = rest
                .split_once(':')
                .ok_or("expected seeded:<seed>:<events>")?;
            let seed: u64 = seed
                .parse()
                .map_err(|_| format!("bad seed `{seed}`"))?;
            let events: usize = events
                .parse()
                .map_err(|_| format!("bad event count `{events}`"))?;
            return Ok(Self::seeded(seed, events));
        }
        let mut plan = FaultPlan::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (class, at) = part
                .split_once('@')
                .ok_or_else(|| format!("`{part}`: expected class@conn:frame"))?;
            let (conn, frame) = at
                .split_once(':')
                .ok_or_else(|| format!("`{part}`: expected class@conn:frame"))?;
            let conn: u64 = conn
                .parse()
                .map_err(|_| format!("`{part}`: bad connection index"))?;
            let frame: u64 = frame
                .parse()
                .map_err(|_| format!("`{part}`: bad frame index"))?;
            let h = splitmix64(conn.rotate_left(32) ^ frame);
            let fault = match class {
                "drop" => Fault::Drop,
                "delay" => Fault::Delay(Duration::from_millis(50)),
                "truncate" => Fault::Truncate((h % 11) as usize),
                "corrupt" => Fault::Corrupt {
                    offset: ((h >> 8) % 97) as usize,
                    xor: (h as u8) | 1,
                },
                "hang" => Fault::Hang,
                other => return Err(format!("unknown fault class `{other}`")),
            };
            plan.events.insert((conn, frame), fault);
        }
        if plan.events.is_empty() {
            return Err("empty schedule".into());
        }
        Ok(plan)
    }

    /// The fault scheduled for data frame `frame` of connection
    /// `conn`, if any.
    pub fn fault_for(&self, conn: u64, frame: u64) -> Option<Fault> {
        self.events.get(&(conn, frame)).copied()
    }

    /// Number of scheduled events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Hand out the next connection index (connect order, shared
    /// across every connection built against this plan).
    pub(crate) fn next_conn(&self) -> u64 {
        self.next_conn.fetch_add(1, Ordering::SeqCst)
    }
}

/// How one connection's encoded frames reach the wire — the seam the
/// fault layer hooks. Exactly one frame per call; an `Err` means the
/// connection is unusable and is handled like any socket failure
/// (death detection, whole-shard requeue, reconnect).
pub trait Transport: Send + Sync {
    fn send(&self, stream: &mut TcpStream, frame: &[u8]) -> io::Result<()>;
}

/// The production transport: one `write_all` + flush per frame.
#[derive(Debug, Default)]
pub struct DirectTcp;

impl Transport for DirectTcp {
    fn send(&self, stream: &mut TcpStream, frame: &[u8]) -> io::Result<()> {
        stream.write_all(frame)?;
        stream.flush()
    }
}

/// A [`Transport`] that consults a [`FaultPlan`] before each send.
/// Holds this connection's index (allocated from the plan at
/// construction) and counts the data frames it ships.
pub struct ChaosTcp {
    plan: Arc<FaultPlan>,
    conn: u64,
    data_frames: AtomicU64,
    hung: AtomicBool,
}

impl ChaosTcp {
    pub fn new(plan: Arc<FaultPlan>) -> Self {
        let conn = plan.next_conn();
        ChaosTcp {
            plan,
            conn,
            data_frames: AtomicU64::new(0),
            hung: AtomicBool::new(false),
        }
    }

    /// The connection index this transport was assigned.
    pub fn conn(&self) -> u64 {
        self.conn
    }
}

impl Transport for ChaosTcp {
    fn send(&self, stream: &mut TcpStream, frame: &[u8]) -> io::Result<()> {
        if self.hung.load(Ordering::SeqCst) {
            // a hung peer writes nothing, forever — heartbeats too
            return Ok(());
        }
        let tag = frame.get(6).copied().unwrap_or(0);
        if tag == TAG_PING || tag == TAG_PONG {
            // heartbeats are wall-clock, not program order; they ride
            // outside the schedule so frame indices stay deterministic
            return DirectTcp.send(stream, frame);
        }
        let idx = self.data_frames.fetch_add(1, Ordering::SeqCst);
        match self.plan.fault_for(self.conn, idx) {
            None => DirectTcp.send(stream, frame),
            Some(Fault::Delay(d)) => {
                std::thread::sleep(d);
                DirectTcp.send(stream, frame)
            }
            Some(Fault::Drop) => {
                let _ = stream.shutdown(Shutdown::Both);
                Err(io::Error::new(
                    io::ErrorKind::ConnectionReset,
                    "chaos: injected connection drop",
                ))
            }
            Some(Fault::Truncate(n)) => {
                let n = n.min(frame.len());
                stream.write_all(&frame[..n])?;
                let _ = stream.flush();
                let _ = stream.shutdown(Shutdown::Both);
                Err(io::Error::new(
                    io::ErrorKind::ConnectionReset,
                    "chaos: injected mid-frame truncation",
                ))
            }
            Some(Fault::Corrupt { offset, xor }) => {
                let mut bytes = frame.to_vec();
                let i = offset % bytes.len().max(1);
                bytes[i] ^= if xor == 0 { 1 } else { xor };
                DirectTcp.send(stream, &bytes)
            }
            Some(Fault::Hang) => {
                self.hung.store(true, Ordering::SeqCst);
                Ok(())
            }
        }
    }
}

/// splitmix64 — the repo vendors no rand crate, so chaos schedules
/// and reconnect jitter both derive from this tiny bijective mixer.
pub(crate) fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Exponential backoff with deterministic jitter: `base · 2^attempt`
/// capped at `cap`, then scaled into [75%, 125%] by a hash of
/// `(salt, attempt)` — decorrelated across peers (salt the peer
/// address), reproducible across runs.
pub(crate) fn backoff_delay(
    attempt: u32,
    base: Duration,
    cap: Duration,
    salt: u64,
) -> Duration {
    let exp = base.saturating_mul(1u32 << attempt.min(16));
    let capped = exp.min(cap);
    let h = splitmix64(salt ^ u64::from(attempt).wrapping_mul(0x9e37_79b9));
    let pct = 75 + (h % 51); // 75..=125
    capped.mul_f64(pct as f64 / 100.0).min(cap)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_schedules_are_reproducible() {
        let a = FaultPlan::seeded(42, 8);
        let b = FaultPlan::seeded(42, 8);
        assert_eq!(a.events, b.events);
        assert!(!a.is_empty());
        // never frame 0: the initial handshake always completes
        assert!(a.events.keys().all(|&(_, frame)| frame >= 1));
        let c = FaultPlan::seeded(43, 8);
        assert_ne!(a.events, c.events, "seed must matter");
    }

    #[test]
    fn parse_explicit_schedule() {
        let p = FaultPlan::parse("drop@0:1, corrupt@1:3,hang@2:2").unwrap();
        assert_eq!(p.len(), 3);
        assert_eq!(p.fault_for(0, 1), Some(Fault::Drop));
        assert!(matches!(p.fault_for(1, 3), Some(Fault::Corrupt { .. })));
        assert_eq!(p.fault_for(2, 2), Some(Fault::Hang));
        assert_eq!(p.fault_for(0, 0), None);
    }

    #[test]
    fn parse_seeded_and_errors() {
        let p = FaultPlan::parse("seeded:7:4").unwrap();
        assert!(!p.is_empty());
        assert!(FaultPlan::parse("").is_err());
        assert!(FaultPlan::parse("warp@0:1").is_err());
        assert!(FaultPlan::parse("drop@x:1").is_err());
        assert!(FaultPlan::parse("drop@1").is_err());
        assert!(FaultPlan::parse("seeded:banana:4").is_err());
    }

    #[test]
    fn connection_indices_are_ordered() {
        let p = FaultPlan::new();
        assert_eq!(p.next_conn(), 0);
        assert_eq!(p.next_conn(), 1);
        assert_eq!(p.next_conn(), 2);
    }

    #[test]
    fn backoff_grows_caps_and_jitters_deterministically() {
        let base = Duration::from_millis(10);
        let cap = Duration::from_millis(500);
        let d0 = backoff_delay(0, base, cap, 99);
        let d5 = backoff_delay(5, base, cap, 99);
        let d20 = backoff_delay(20, base, cap, 99);
        assert!(d0 >= base.mul_f64(0.74) && d0 <= base.mul_f64(1.26));
        assert!(d5 > d0);
        assert!(d20 <= cap, "{d20:?} exceeds cap");
        assert_eq!(d5, backoff_delay(5, base, cap, 99), "jitter must replay");
        assert_ne!(
            backoff_delay(5, base, cap, 1),
            backoff_delay(5, base, cap, 2),
            "salt decorrelates peers"
        );
    }
}
