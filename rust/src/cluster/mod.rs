//! Multi-engine cluster layer — the paper's "performance scales
//! linearly with the increasing of the GPUs" claim as a first-class
//! subsystem instead of a simulation-only figure.
//!
//! A [`Cluster`] owns N persistent [`crate::engine::Engine`]s, each
//! modeling one device/host with its own workers and warm executable
//! caches, behind the same `submit() -> handle` surface the single
//! engine exposes. Submission splits the task list into contiguous
//! per-engine shards ([`plan::ShardPlan`]); because every launch task
//! carries its own Philox `(stream, counter base, trial)` addressing,
//! shards sample **disjoint counter ranges by construction** and a
//! task's output is independent of which engine runs it. The
//! centralized reducer ([`reduce::reduce_tagged`]) folds the returned
//! per-function/per-stratum [`crate::stats::MomentSum`]s back together
//! in task order, so a K-engine run is **bit-identical** to the
//! 1-engine run (floating-point merge order is preserved, not just the
//! sample set — asserted by `tests/cluster_test.rs` for shard counts
//! 1..8).
//!
//! Fault model: an engine whose shard job fails (all its workers died,
//! or its retry budget drained) is marked dead and the whole shard is
//! requeued onto a surviving engine — idempotent Philox addressing
//! makes the rerun exact. Allocation stays centralized: the adaptive
//! driver's Neyman step ([`crate::adaptive`]) sees merged moments only
//! and never knows how many engines sampled them.
//!
//! The same machinery spans hosts: [`wire`] defines a versioned
//! length-prefixed binary frame protocol (bit-exact float transport),
//! [`remote`] hosts an engine behind a TCP accept loop
//! ([`remote::serve_worker`], the `zmc worker` subcommand) and proxies
//! it client-side as a [`RemoteEngine`] with heartbeat death
//! detection, and [`Cluster`] mixes local and remote nodes behind the
//! unchanged submit surface — a killed worker host mid-round feeds the
//! same whole-shard requeue path, so survivors still produce
//! bit-identical results.
//!
//! [`sim`] keeps the original discrete-event scaling model (virtual
//! devices, measured per-chunk durations) used by the C2 figure;
//! `benches/cluster_scaling.rs` drives the *real* cluster and prices
//! its shard plan with the same measured-time approach.

pub mod chaos;
pub mod core;
pub mod exec;
pub mod plan;
pub mod reduce;
pub mod remote;
pub mod sim;
pub mod wire;

// the transport fault plan is re-exported under a qualified name so it
// never shadows the engine-level `crate::engine::core::FaultPlan`
pub use self::chaos::{Fault, FaultPlan as WireFaultPlan};
pub use self::core::{Cluster, ClusterHandle, DeviceCluster};
pub use self::exec::{ExecHandle, LaunchExec};
pub use self::plan::ShardPlan;
pub use self::reduce::{fold_tagged, reduce_tagged};
pub use self::remote::{
    serve_worker, serve_worker_with_digest, HandshakeError, RemoteConfig,
    RemoteEngine, RemoteHandle, WorkerServer,
};
pub use self::sim::{scaling_sweep, simulate, SimResult};
pub use self::wire::{Frame, Wire, WireError};
