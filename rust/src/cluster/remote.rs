//! Remote engines over TCP: the worker host (`serve_worker`) exposes a
//! local [`Engine`] behind an accept loop speaking the
//! [`wire`](super::wire) frame protocol, and [`RemoteEngine`] is the
//! client-side proxy whose submit surface matches `Engine` closely
//! enough for [`Cluster`](super::Cluster) to mix local and remote
//! nodes transparently.
//!
//! Failure model: the transport never retries on its own. A dead
//! connection (EOF, write error, or heartbeat timeout) marks the
//! proxy dead and fails every pending job; the cluster's existing
//! whole-shard requeue path then resubmits the shard to a survivor.
//! Because every task bakes its Philox counter range into its inputs,
//! the requeued shard recomputes bit-identical results wherever it
//! lands — the transport only has to detect death, not preserve
//! progress.
//!
//! Death detection is two-tier:
//! - **instant**: the reader thread sees EOF / a socket error the
//!   moment the peer closes (a killed process closes its sockets);
//! - **heartbeat**: a pinger thread sends [`Frame::Ping`] every
//!   [`RemoteConfig::ping_interval`] and declares death when no pong
//!   arrives within [`RemoteConfig::ping_timeout`] — this catches
//!   hung hosts and dead network paths where TCP would block for
//!   minutes before noticing.

use std::collections::HashMap;
use std::io::BufReader;
use std::marker::PhantomData;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::engine::core::{lock_ok, wait_ok, Backend, Engine, JobHandle};

use super::wire::{Frame, Wire};

/// Transport tuning knobs. Defaults suit LAN workers; tests inject
/// short timeouts to make hung-host detection fast.
#[derive(Debug, Clone)]
pub struct RemoteConfig {
    /// How often the proxy pings the worker.
    pub ping_interval: Duration,
    /// Silence (no pong, no result) after which the worker is
    /// declared dead. Should be several multiples of `ping_interval`.
    pub ping_timeout: Duration,
    /// Connection attempts before `connect` gives up (covers the
    /// worker still starting up).
    pub connect_retries: u32,
    /// Backoff between connection attempts, doubled each retry up to
    /// 8× the base.
    pub connect_backoff: Duration,
}

impl Default for RemoteConfig {
    fn default() -> Self {
        RemoteConfig {
            ping_interval: Duration::from_millis(250),
            ping_timeout: Duration::from_secs(2),
            connect_retries: 20,
            connect_backoff: Duration::from_millis(50),
        }
    }
}

// ---------------------------------------------------------------------------
// client side: RemoteEngine proxy
// ---------------------------------------------------------------------------

/// One in-flight remote job: result slot + wakeup for `wait`.
struct Pending<R> {
    result: Mutex<Option<std::result::Result<Vec<R>, String>>>,
    cv: Condvar,
}

impl<R> Pending<R> {
    fn new() -> Self {
        Pending { result: Mutex::new(None), cv: Condvar::new() }
    }

    /// First completion wins; later ones (e.g. a result racing the
    /// death sweep) are dropped.
    fn complete(&self, res: std::result::Result<Vec<R>, String>) {
        let mut slot = lock_ok(&self.result);
        if slot.is_none() {
            *slot = Some(res);
            self.cv.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        lock_ok(&self.result).is_some()
    }
}

struct RemoteShared<R> {
    peer: String,
    /// Write half; one whole-frame `write_all` per lock hold, so
    /// submit/ping/cancel frames never interleave.
    writer: Mutex<TcpStream>,
    /// Socket handle kept for `shutdown` — unblocks the reader thread
    /// on drop and on heartbeat death.
    sock: TcpStream,
    pending: Mutex<HashMap<u64, Arc<Pending<R>>>>,
    next_id: AtomicU64,
    dead: AtomicBool,
    stop: AtomicBool,
    /// Last proof of life from the worker (pong or any result frame),
    /// as millis since `born`.
    last_alive_ms: AtomicU64,
    born: Instant,
}

impl<R> RemoteShared<R> {
    fn touch(&self) {
        let ms = self.born.elapsed().as_millis() as u64;
        self.last_alive_ms.store(ms, Ordering::Relaxed);
    }

    fn silence(&self) -> Duration {
        let last = self.last_alive_ms.load(Ordering::Relaxed);
        let now = self.born.elapsed().as_millis() as u64;
        Duration::from_millis(now.saturating_sub(last))
    }

    /// Declare the worker dead: fail every pending job and unblock
    /// the reader. Idempotent; the `dead` flag is set *before* any
    /// job observes its failure, so `Cluster` always sees
    /// `is_dead() == true` when a shard comes back with an error.
    fn mark_dead(&self, why: &str) {
        if self.dead.swap(true, Ordering::SeqCst) {
            return;
        }
        let _ = self.sock.shutdown(Shutdown::Both);
        let jobs: Vec<Arc<Pending<R>>> =
            lock_ok(&self.pending).drain().map(|(_, j)| j).collect();
        for job in jobs {
            job.complete(Err(format!(
                "remote engine {}: {why}",
                self.peer
            )));
        }
    }

    fn complete_id(
        &self,
        id: u64,
        res: std::result::Result<Vec<R>, String>,
    ) {
        if let Some(job) = lock_ok(&self.pending).remove(&id) {
            job.complete(res);
        }
    }
}

/// Client-side proxy for an engine hosted by a `zmc worker` process.
/// Generic over the task/result payload so the transport is testable
/// against mock backends; production uses
/// `RemoteEngine<LaunchTask, TaggedOutput>`.
pub struct RemoteEngine<T, R> {
    shared: Arc<RemoteShared<R>>,
    reader: Option<thread::JoinHandle<()>>,
    pinger: Option<thread::JoinHandle<()>>,
    _task: PhantomData<fn(T) -> T>,
}

impl<T, R> RemoteEngine<T, R>
where
    T: Wire,
    R: Wire + Send + 'static,
{
    /// Connect to a worker, retrying with backoff while it starts up.
    pub fn connect(addr: &str, cfg: RemoteConfig) -> Result<Self> {
        let mut backoff = cfg.connect_backoff;
        let mut last_err = None;
        for _ in 0..cfg.connect_retries.max(1) {
            match TcpStream::connect(addr) {
                Ok(stream) => return Self::from_stream(stream, addr, &cfg),
                Err(e) => {
                    last_err = Some(e);
                    thread::sleep(backoff);
                    backoff =
                        (backoff * 2).min(cfg.connect_backoff * 8);
                }
            }
        }
        Err(anyhow!(last_err.unwrap())).with_context(|| {
            format!(
                "connecting to remote worker {addr} \
                 ({} attempts)",
                cfg.connect_retries.max(1)
            )
        })
    }

    fn from_stream(
        stream: TcpStream,
        addr: &str,
        cfg: &RemoteConfig,
    ) -> Result<Self> {
        stream.set_nodelay(true).ok();
        let writer = stream
            .try_clone()
            .context("cloning worker socket for writes")?;
        let read_half = stream
            .try_clone()
            .context("cloning worker socket for reads")?;
        let shared = Arc::new(RemoteShared::<R> {
            peer: addr.to_string(),
            writer: Mutex::new(writer),
            sock: stream,
            pending: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            dead: AtomicBool::new(false),
            stop: AtomicBool::new(false),
            last_alive_ms: AtomicU64::new(0),
            born: Instant::now(),
        });
        shared.touch();

        let reader = {
            let shared = Arc::clone(&shared);
            thread::Builder::new()
                .name(format!("zmc-remote-rx-{addr}"))
                .spawn(move || reader_loop::<T, R>(shared, read_half))
                .context("spawning remote reader thread")?
        };
        let pinger = {
            let shared = Arc::clone(&shared);
            let cfg = cfg.clone();
            thread::Builder::new()
                .name(format!("zmc-remote-ping-{addr}"))
                .spawn(move || ping_loop::<T, R>(shared, cfg))
                .context("spawning remote heartbeat thread")?
        };

        Ok(RemoteEngine {
            shared,
            reader: Some(reader),
            pinger: Some(pinger),
            _task: PhantomData,
        })
    }

    /// Address this proxy connected to.
    pub fn peer(&self) -> &str {
        &self.shared.peer
    }

    /// True once the connection is closed, errored, or heartbeat
    /// timed out. Mirrors `Engine::is_dead` for the cluster's
    /// dead-node requeue decision.
    pub fn is_dead(&self) -> bool {
        self.shared.dead.load(Ordering::SeqCst)
    }

    /// Ship a task batch to the worker as one engine job. Mirrors
    /// `Engine::submit_with_retries`; the retry budget applies on the
    /// worker's engine (task-level retries stay local to the host).
    pub fn submit_with_retries(
        &self,
        tasks: Vec<T>,
        max_retries: u32,
    ) -> Result<RemoteHandle<R>> {
        if self.is_dead() {
            bail!("remote engine {} is dead", self.shared.peer);
        }
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        let job = Arc::new(Pending::new());
        lock_ok(&self.shared.pending).insert(id, Arc::clone(&job));

        let frame = Frame::<T, R>::Submit { id, max_retries, tasks };
        let wrote = {
            let mut w = lock_ok(&self.shared.writer);
            frame.write_to(&mut *w)
        };
        if let Err(e) = wrote {
            self.shared.mark_dead(&format!("send failed: {e}"));
        } else if self.is_dead() {
            // death raced the insert: the sweep may have missed this
            // job, so fail it explicitly rather than hang its waiter
            self.shared
                .complete_id(id, Err(format!(
                    "remote engine {} died during submit",
                    self.shared.peer
                )));
        }
        if self.is_dead() {
            // the pending entry (if any) was already failed above
            let _ = lock_ok(&self.shared.pending).remove(&id);
            bail!(
                "remote engine {} died during submit",
                self.shared.peer
            );
        }
        Ok(RemoteHandle {
            id,
            job,
            shared: Arc::downgrade(&self.shared),
            waited: false,
        })
    }
}

impl<T, R> Drop for RemoteEngine<T, R> {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        let _ = self.shared.sock.shutdown(Shutdown::Both);
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
        if let Some(h) = self.pinger.take() {
            let _ = h.join();
        }
    }
}

fn reader_loop<T, R>(shared: Arc<RemoteShared<R>>, stream: TcpStream)
where
    T: Wire,
    R: Wire,
{
    let mut rd = BufReader::new(stream);
    loop {
        match Frame::<T, R>::read_from(&mut rd) {
            Ok(Some(Frame::Pong { .. })) => shared.touch(),
            Ok(Some(Frame::Result { id, outs })) => {
                shared.touch();
                shared.complete_id(id, Ok(outs));
            }
            Ok(Some(Frame::Error { id, msg })) => {
                shared.touch();
                shared.complete_id(id, Err(msg));
            }
            // Ping/Submit/Cancel from a worker are protocol noise;
            // still proof the peer is alive
            Ok(Some(_)) => shared.touch(),
            Ok(None) => {
                shared.mark_dead("connection closed by worker");
                return;
            }
            Err(e) => {
                if shared.stop.load(Ordering::SeqCst) {
                    // local shutdown raced the read; not a failure
                    shared.mark_dead("proxy shut down");
                } else {
                    shared.mark_dead(&format!("read failed: {e:#}"));
                }
                return;
            }
        }
        if shared.stop.load(Ordering::SeqCst) {
            shared.mark_dead("proxy shut down");
            return;
        }
    }
}

fn ping_loop<T, R>(shared: Arc<RemoteShared<R>>, cfg: RemoteConfig)
where
    T: Wire,
    R: Wire,
{
    let step = Duration::from_millis(25).min(cfg.ping_interval);
    let mut nonce = 0u64;
    let mut since_ping = Duration::ZERO;
    loop {
        if shared.stop.load(Ordering::SeqCst)
            || shared.dead.load(Ordering::SeqCst)
        {
            return;
        }
        if shared.silence() > cfg.ping_timeout {
            shared.mark_dead(&format!(
                "heartbeat timeout ({}ms without a pong)",
                cfg.ping_timeout.as_millis()
            ));
            return;
        }
        if since_ping >= cfg.ping_interval {
            since_ping = Duration::ZERO;
            nonce += 1;
            let wrote = {
                let mut w = lock_ok(&shared.writer);
                Frame::<T, R>::Ping { nonce }.write_to(&mut *w)
            };
            if let Err(e) = wrote {
                shared.mark_dead(&format!("ping failed: {e}"));
                return;
            }
        }
        thread::sleep(step);
        since_ping += step;
    }
}

/// Handle to one remote job; mirrors `JobHandle`'s wait/is_done/Drop
/// contract (dropping an unawaited handle sends a best-effort cancel).
pub struct RemoteHandle<R> {
    id: u64,
    job: Arc<Pending<R>>,
    shared: Weak<RemoteShared<R>>,
    waited: bool,
}

impl<R> RemoteHandle<R> {
    /// Block until the worker answers (or the connection dies).
    pub fn wait(mut self) -> Result<Vec<R>> {
        self.waited = true;
        let mut slot = lock_ok(&self.job.result);
        loop {
            if let Some(res) = slot.take() {
                return res.map_err(|msg| anyhow!(msg));
            }
            slot = wait_ok(&self.job.cv, slot);
        }
    }

    pub fn is_done(&self) -> bool {
        self.job.is_done()
    }
}

impl<R> Drop for RemoteHandle<R> {
    fn drop(&mut self) {
        if self.waited || self.job.is_done() {
            return;
        }
        if let Some(shared) = self.shared.upgrade() {
            let _ = lock_ok(&shared.pending).remove(&self.id);
            if !shared.dead.load(Ordering::SeqCst) {
                let mut w = lock_ok(&shared.writer);
                let _ = Frame::<u64, R>::Cancel { id: self.id }
                    .write_to(&mut *w);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// server side: worker host
// ---------------------------------------------------------------------------

/// Counters exposed by a [`WorkerServer`] — the cluster tests assert
/// `empty_submits == 0` (empty shards must be skipped at dispatch,
/// never shipped).
#[derive(Debug, Default)]
pub struct WorkerStats {
    pub connections: AtomicU64,
    pub submits: AtomicU64,
    pub empty_submits: AtomicU64,
    pub tasks: AtomicU64,
}

/// A running worker host: TCP accept loop in front of one local
/// engine. Connections multiplex jobs; each gets its own service
/// thread so one slow peer cannot stall another.
pub struct WorkerServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    stats: Arc<WorkerStats>,
    accept: Option<thread::JoinHandle<()>>,
}

impl WorkerServer {
    /// Bound address (use port 0 in tests to get an ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> &WorkerStats {
        &self.stats
    }

    /// Abrupt shutdown: sever every client connection mid-flight and
    /// stop accepting. Clients observe EOF instantly — this is the
    /// "kill the worker host mid-round" test hook (in production the
    /// same effect comes from the process dying).
    pub fn kill(&self) {
        self.stop.store(true, Ordering::SeqCst);
        for s in lock_ok(&self.conns).drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
    }

    /// Block until the server is stopped (the `zmc worker` foreground
    /// mode). Returns after [`kill`](Self::kill) from another thread
    /// or process signal teardown.
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerServer {
    fn drop(&mut self) {
        self.kill();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// Host `engine` behind `listener`. Returns immediately; the accept
/// loop and per-connection service threads run in the background until
/// the server is killed or dropped.
pub fn serve_worker<B>(
    listener: TcpListener,
    engine: Engine<B>,
) -> Result<WorkerServer>
where
    B: Backend + Send + Sync + 'static,
    B::Task: Wire + Clone + Send + Sync + 'static,
    B::Out: Wire + Send + 'static,
{
    listener
        .set_nonblocking(true)
        .context("setting worker listener non-blocking")?;
    let addr = listener
        .local_addr()
        .context("reading worker listener address")?;
    let stop = Arc::new(AtomicBool::new(false));
    let conns: Arc<Mutex<Vec<TcpStream>>> =
        Arc::new(Mutex::new(Vec::new()));
    let stats = Arc::new(WorkerStats::default());
    let engine = Arc::new(engine);

    let accept = {
        let stop = Arc::clone(&stop);
        let conns = Arc::clone(&conns);
        let stats = Arc::clone(&stats);
        thread::Builder::new()
            .name("zmc-worker-accept".to_string())
            .spawn(move || {
                accept_loop(listener, engine, stop, conns, stats)
            })
            .context("spawning worker accept thread")?
    };

    Ok(WorkerServer { addr, stop, conns, stats, accept: Some(accept) })
}

fn accept_loop<B>(
    listener: TcpListener,
    engine: Arc<Engine<B>>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    stats: Arc<WorkerStats>,
) where
    B: Backend + Send + Sync + 'static,
    B::Task: Wire + Clone + Send + Sync + 'static,
    B::Out: Wire + Send + 'static,
{
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                stream.set_nodelay(true).ok();
                stats.connections.fetch_add(1, Ordering::Relaxed);
                if let Ok(clone) = stream.try_clone() {
                    lock_ok(&conns).push(clone);
                }
                let engine = Arc::clone(&engine);
                let stop = Arc::clone(&stop);
                let stats = Arc::clone(&stats);
                // service threads are detached: they exit when their
                // socket closes (kill/Drop shuts every socket down)
                let _ = thread::Builder::new()
                    .name(format!("zmc-worker-conn-{peer}"))
                    .spawn(move || {
                        serve_conn(stream, engine, stop, stats)
                    });
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                thread::sleep(Duration::from_millis(5));
            }
            Err(_) => thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Service one client connection: a blocking reader thread feeds
/// frames through a channel; this loop answers pings immediately,
/// submits jobs to the engine, and polls in-flight handles so results
/// stream back as soon as each job finishes (heartbeats keep flowing
/// while jobs run — the whole point of the two-thread split).
fn serve_conn<B>(
    stream: TcpStream,
    engine: Arc<Engine<B>>,
    stop: Arc<AtomicBool>,
    stats: Arc<WorkerStats>,
) where
    B: Backend + Send + Sync + 'static,
    B::Task: Wire + Clone + Send + Sync + 'static,
    B::Out: Wire + Send + 'static,
{
    type Fr<B> =
        Frame<<B as Backend>::Task, <B as Backend>::Out>;

    let Ok(read_half) = stream.try_clone() else { return };
    let (tx, rx) = mpsc::channel::<Fr<B>>();
    let reader = thread::spawn(move || {
        let mut rd = BufReader::new(read_half);
        loop {
            match Frame::read_from(&mut rd) {
                Ok(Some(frame)) => {
                    if tx.send(frame).is_err() {
                        return;
                    }
                }
                // EOF or corrupt frame: stop reading; the service
                // loop sees the channel hang up and tears down
                Ok(None) | Err(_) => return,
            }
        }
    });

    let mut write = stream;
    let mut inflight: Vec<(u64, JobHandle<B::Task, B::Out>)> =
        Vec::new();
    'serve: loop {
        match rx.recv_timeout(Duration::from_millis(2)) {
            Ok(Frame::Ping { nonce }) => {
                if Fr::<B>::Pong { nonce }.write_to(&mut write).is_err()
                {
                    break 'serve;
                }
            }
            Ok(Frame::Submit { id, max_retries, tasks }) => {
                stats.submits.fetch_add(1, Ordering::Relaxed);
                if tasks.is_empty() {
                    stats
                        .empty_submits
                        .fetch_add(1, Ordering::Relaxed);
                }
                stats
                    .tasks
                    .fetch_add(tasks.len() as u64, Ordering::Relaxed);
                match engine.submit_with_retries(tasks, max_retries) {
                    Ok(h) => inflight.push((id, h)),
                    Err(e) => {
                        let frame = Fr::<B>::Error {
                            id,
                            msg: format!("{e:#}"),
                        };
                        if frame.write_to(&mut write).is_err() {
                            break 'serve;
                        }
                    }
                }
            }
            Ok(Frame::Cancel { id }) => {
                // dropping the handle cancels + purges engine-side
                inflight.retain(|(jid, _)| *jid != id);
            }
            Ok(_) => {} // Pong/Result/Error from a client: ignore
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break 'serve,
        }

        let mut i = 0;
        while i < inflight.len() {
            if !inflight[i].1.is_done() {
                i += 1;
                continue;
            }
            let (id, handle) = inflight.swap_remove(i);
            let frame = match handle.wait() {
                Ok(outs) => Fr::<B>::Result { id, outs },
                Err(e) => {
                    Fr::<B>::Error { id, msg: format!("{e:#}") }
                }
            };
            if frame.write_to(&mut write).is_err() {
                break 'serve;
            }
        }

        if stop.load(Ordering::SeqCst) && inflight.is_empty() {
            break 'serve;
        }
    }
    // closing the socket unblocks the reader thread (same underlying
    // socket as the clone it reads from)
    let _ = write.shutdown(Shutdown::Both);
    drop(write);
    let _ = reader.join();
    // any still-inflight handles drop here -> engine-side cancel
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;
    use crate::engine::core::{EngineConfig, FaultPlan};

    /// Mock backend over the same `u64 -> u64` function the cluster
    /// core tests use, so remote results are directly comparable.
    struct Mock;

    impl Backend for Mock {
        type Task = u64;
        type Out = u64;
        type Ctx = ();

        fn make_ctx(&self, _worker: usize) -> Result<()> {
            Ok(())
        }

        fn run(&self, _ctx: &(), task: &u64) -> Result<u64> {
            Ok(task * 31 + 7)
        }
    }

    fn worker(n_workers: usize) -> WorkerServer {
        let engine = Engine::new(
            Mock,
            EngineConfig { n_workers, ..Default::default() },
        )
        .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        serve_worker(listener, engine).unwrap()
    }

    fn fast_cfg() -> RemoteConfig {
        RemoteConfig {
            ping_interval: Duration::from_millis(20),
            ping_timeout: Duration::from_millis(250),
            connect_retries: 10,
            connect_backoff: Duration::from_millis(10),
        }
    }

    fn connect(w: &WorkerServer) -> RemoteEngine<u64, u64> {
        RemoteEngine::connect(&w.addr().to_string(), fast_cfg())
            .unwrap()
    }

    #[test]
    fn loopback_submit_round_trips() {
        let w = worker(2);
        let eng = connect(&w);
        let tasks: Vec<u64> = (0..40).collect();
        let outs = eng
            .submit_with_retries(tasks.clone(), 0)
            .unwrap()
            .wait()
            .unwrap();
        let want: Vec<u64> =
            tasks.iter().map(|t| t * 31 + 7).collect();
        assert_eq!(outs, want);
        assert_eq!(w.stats().submits.load(Ordering::Relaxed), 1);
        assert_eq!(w.stats().empty_submits.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn multiple_jobs_multiplex_one_connection() {
        let w = worker(2);
        let eng = connect(&w);
        let handles: Vec<_> = (0..6)
            .map(|k| {
                let tasks: Vec<u64> =
                    (k * 10..k * 10 + 10).collect();
                (tasks.clone(),
                 eng.submit_with_retries(tasks, 0).unwrap())
            })
            .collect();
        for (tasks, h) in handles {
            let want: Vec<u64> =
                tasks.iter().map(|t| t * 31 + 7).collect();
            assert_eq!(h.wait().unwrap(), want);
        }
    }

    #[test]
    fn worker_kill_fails_pending_and_marks_dead() {
        struct Stuck;
        impl Backend for Stuck {
            type Task = u64;
            type Out = u64;
            type Ctx = ();
            fn make_ctx(&self, _w: usize) -> Result<()> {
                Ok(())
            }
            fn run(&self, _ctx: &(), _task: &u64) -> Result<u64> {
                thread::sleep(Duration::from_secs(30));
                Ok(0)
            }
        }
        let engine = Engine::new(
            Stuck,
            EngineConfig { n_workers: 1, ..Default::default() },
        )
        .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let w = serve_worker(listener, engine).unwrap();
        let eng: RemoteEngine<u64, u64> =
            RemoteEngine::connect(&w.addr().to_string(), fast_cfg())
                .unwrap();
        let h = eng.submit_with_retries(vec![1, 2, 3], 0).unwrap();
        assert!(!h.is_done());
        w.kill();
        let err = h.wait().unwrap_err().to_string();
        assert!(err.contains("remote engine"), "{err}");
        assert!(eng.is_dead());
        assert!(eng.submit_with_retries(vec![4], 0).is_err());
    }

    #[test]
    fn heartbeat_detects_hung_host() {
        // a listener that accepts and then never reads or writes —
        // TCP stays "connected", only the heartbeat can notice
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let hold = thread::spawn(move || {
            let conn = listener.accept().map(|(s, _)| s);
            thread::sleep(Duration::from_secs(2));
            drop(conn);
        });
        let eng: RemoteEngine<u64, u64> =
            RemoteEngine::connect(&addr.to_string(), fast_cfg())
                .unwrap();
        let h = eng.submit_with_retries(vec![9], 0).unwrap();
        let err = h.wait().unwrap_err().to_string();
        assert!(err.contains("heartbeat timeout"), "{err}");
        assert!(eng.is_dead());
        hold.join().unwrap();
    }

    #[test]
    fn live_engine_task_failure_is_not_death() {
        struct BadThirteen;
        impl Backend for BadThirteen {
            type Task = u64;
            type Out = u64;
            type Ctx = ();
            fn make_ctx(&self, _w: usize) -> Result<()> {
                Ok(())
            }
            fn run(&self, _ctx: &(), task: &u64) -> Result<u64> {
                if *task == 13 {
                    bail!("unlucky task");
                }
                Ok(task * 31 + 7)
            }
        }
        let engine = Engine::with_policy(
            BadThirteen,
            EngineConfig { n_workers: 2, ..Default::default() },
            FaultPlan::none(),
            Arc::new(Metrics::new()),
        )
        .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let w = serve_worker(listener, engine).unwrap();
        let eng: RemoteEngine<u64, u64> =
            RemoteEngine::connect(&w.addr().to_string(), fast_cfg())
                .unwrap();
        let err = eng
            .submit_with_retries(vec![12, 13, 14], 0)
            .unwrap()
            .wait()
            .unwrap_err()
            .to_string();
        assert!(err.contains("unlucky"), "{err}");
        // the worker host is fine: not dead, next job succeeds
        assert!(!eng.is_dead());
        let outs = eng
            .submit_with_retries(vec![1], 0)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(outs, vec![38]);
    }

    #[test]
    fn dropped_handle_cancels_without_killing_connection() {
        let w = worker(1);
        let eng = connect(&w);
        let h = eng.submit_with_retries(vec![5], 0).unwrap();
        drop(h);
        // connection still serves new jobs after the cancel
        let outs = eng
            .submit_with_retries(vec![2], 0)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(outs, vec![69]);
        assert!(!eng.is_dead());
    }
}
