//! Remote engines over TCP: the worker host (`serve_worker`) exposes a
//! local [`Engine`] behind an accept loop speaking the
//! [`wire`](super::wire) frame protocol, and [`RemoteEngine`] is the
//! client-side proxy whose submit surface matches `Engine` closely
//! enough for [`Cluster`](super::Cluster) to mix local and remote
//! nodes transparently.
//!
//! Failure model: the transport never retries on its own. A dead
//! connection (EOF, write error, or heartbeat timeout) marks the
//! proxy dead and fails every pending job; the cluster's existing
//! whole-shard requeue path then resubmits the shard to a survivor.
//! Because every task bakes its Philox counter range into its inputs,
//! the requeued shard recomputes bit-identical results wherever it
//! lands — the transport only has to detect death, not preserve
//! progress.
//!
//! Death detection is two-tier:
//! - **instant**: the reader thread sees EOF / a socket error the
//!   moment the peer closes (a killed process closes its sockets);
//! - **heartbeat**: a pinger thread sends [`Frame::Ping`] every
//!   [`RemoteConfig::ping_interval`] and declares death when no pong
//!   arrives within [`RemoteConfig::ping_timeout`] — this catches
//!   hung hosts and dead network paths where TCP would block for
//!   minutes before noticing.
//!
//! Death is no longer permanent: every connection opens with a
//! [`Frame::Hello`]/[`Frame::HelloAck`] handshake (wire-version range
//! + registry digest, so a mismatched worker is a typed
//! [`HandshakeError`] at connect time), and a proxy built by
//! [`RemoteEngine::connect`] runs a supervisor thread that, when the
//! connection dies, retries the connect with exponential backoff +
//! deterministic jitter up to [`RemoteConfig::reconnect_retries`]
//! attempts per outage, re-handshakes, and swaps the fresh connection
//! in behind the same proxy. In-flight jobs on the dead connection
//! still fail fast onto the cluster's whole-shard requeue path; the
//! reconnect only makes the *next* submit land on the revived host.
//!
//! Every client-side frame write crosses the
//! [`Transport`](super::chaos::Transport) seam, so a
//! [`FaultPlan`](super::chaos::FaultPlan) in
//! [`RemoteConfig::chaos`] can deterministically drop, delay,
//! truncate, corrupt, or hang any scheduled frame — see
//! `cluster/chaos.rs` and `tests/chaos_test.rs`.

use std::collections::HashMap;
use std::io::BufReader;
use std::marker::PhantomData;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::Metrics;
use crate::engine::core::{lock_ok, wait_ok, Backend, Engine, JobHandle};

use super::chaos::{backoff_delay, splitmix64, ChaosTcp, DirectTcp, FaultPlan, Transport};
use super::wire::{Frame, Wire, WIRE_VERSION, WIRE_VERSION_MIN};

/// Transport tuning knobs. Defaults suit LAN workers; tests inject
/// short timeouts to make hung-host detection fast.
#[derive(Debug, Clone)]
pub struct RemoteConfig {
    /// How often the proxy pings the worker.
    pub ping_interval: Duration,
    /// Silence (no pong, no result) after which the worker is
    /// declared dead. Should be several multiples of `ping_interval`.
    /// Also bounds how long the connect-time handshake waits for a
    /// `HelloAck` before declaring the peer silent.
    pub ping_timeout: Duration,
    /// Connection attempts before `connect` gives up (covers the
    /// worker still starting up).
    pub connect_retries: u32,
    /// Backoff between connection attempts, doubled each retry up to
    /// 8× the base.
    pub connect_backoff: Duration,
    /// Registry digest presented in the `Hello` (0 = unchecked, for
    /// registry-less mock transports). The cluster fills this from
    /// `Registry::digest()` so both sides prove they hold the same
    /// artifacts before any task ships.
    pub digest: u64,
    /// Reconnect-and-resume: when the connection dies, a supervisor
    /// thread re-establishes it with backoff and the proxy rejoins
    /// the shard plan. `false` restores permanent death.
    pub reconnect: bool,
    /// First reconnect delay; doubles per failed attempt.
    pub reconnect_backoff: Duration,
    /// Upper bound on one reconnect delay (the backoff cap).
    pub reconnect_cap: Duration,
    /// Reconnect attempts per outage before the proxy stays dead.
    pub reconnect_retries: u32,
    /// Deterministic fault-injection schedule applied to every
    /// connection this config opens (tests / `ZMC_CHAOS`).
    pub chaos: Option<Arc<FaultPlan>>,
}

impl Default for RemoteConfig {
    fn default() -> Self {
        RemoteConfig {
            ping_interval: Duration::from_millis(250),
            ping_timeout: Duration::from_secs(2),
            connect_retries: 20,
            connect_backoff: Duration::from_millis(50),
            digest: 0,
            reconnect: true,
            reconnect_backoff: Duration::from_millis(100),
            reconnect_cap: Duration::from_secs(5),
            reconnect_retries: 30,
            chaos: None,
        }
    }
}

/// Typed connect-time handshake failures — permanent conditions (the
/// peer speaks the wrong protocol version or holds different
/// artifacts), distinguished from transient connect errors so callers
/// fail fast instead of retrying into the same wall.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HandshakeError {
    /// No overlap between our supported wire-version range and what
    /// the worker chose (0 = the worker found no overlap either).
    VersionMismatch { ours_min: u16, ours_max: u16, theirs: u16 },
    /// The worker's registry digest differs from ours: its artifacts
    /// have drifted and results could silently diverge.
    DigestMismatch { ours: u64, theirs: u64 },
}

impl std::fmt::Display for HandshakeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HandshakeError::VersionMismatch { ours_min, ours_max, theirs } => {
                write!(
                    f,
                    "wire-version mismatch: we speak v{ours_min}..=v{ours_max}, \
                     worker answered v{theirs}"
                )
            }
            HandshakeError::DigestMismatch { ours, theirs } => write!(
                f,
                "registry digest mismatch: ours {ours:#018x}, worker \
                 {theirs:#018x} — artifacts have drifted between hosts"
            ),
        }
    }
}

impl std::error::Error for HandshakeError {}

// ---------------------------------------------------------------------------
// client side: RemoteEngine proxy
// ---------------------------------------------------------------------------

/// One in-flight remote job: result slot + wakeup for `wait`.
struct Pending<R> {
    result: Mutex<Option<std::result::Result<Vec<R>, String>>>,
    cv: Condvar,
}

impl<R> Pending<R> {
    fn new() -> Self {
        Pending { result: Mutex::new(None), cv: Condvar::new() }
    }

    /// First completion wins; later ones (e.g. a result racing the
    /// death sweep) are dropped.
    fn complete(&self, res: std::result::Result<Vec<R>, String>) {
        let mut slot = lock_ok(&self.result);
        if slot.is_none() {
            *slot = Some(res);
            self.cv.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        lock_ok(&self.result).is_some()
    }
}

struct RemoteShared<R> {
    peer: String,
    /// Write half; one whole-frame `write_all` per lock hold, so
    /// submit/ping/cancel frames never interleave.
    writer: Mutex<TcpStream>,
    /// The seam every outgoing frame crosses — `DirectTcp` in
    /// production, `ChaosTcp` under a fault plan.
    transport: Arc<dyn Transport>,
    /// Socket handle kept for `shutdown` — unblocks the reader thread
    /// on drop and on heartbeat death.
    sock: TcpStream,
    pending: Mutex<HashMap<u64, Arc<Pending<R>>>>,
    next_id: AtomicU64,
    dead: AtomicBool,
    stop: AtomicBool,
    /// Last proof of life from the worker (pong or any result frame),
    /// as millis since `born`.
    last_alive_ms: AtomicU64,
    born: Instant,
}

impl<R> RemoteShared<R> {
    /// Ship one encoded frame through the transport under the writer
    /// lock (frames never interleave).
    fn send_frame(&self, bytes: &[u8]) -> std::io::Result<()> {
        let mut w = lock_ok(&self.writer);
        self.transport.send(&mut w, bytes)
    }

    fn touch(&self) {
        let ms = self.born.elapsed().as_millis() as u64;
        self.last_alive_ms.store(ms, Ordering::Relaxed);
    }

    fn silence(&self) -> Duration {
        let last = self.last_alive_ms.load(Ordering::Relaxed);
        let now = self.born.elapsed().as_millis() as u64;
        Duration::from_millis(now.saturating_sub(last))
    }

    /// Declare the worker dead: fail every pending job and unblock
    /// the reader. Idempotent; the `dead` flag is set *before* any
    /// job observes its failure, so `Cluster` always sees
    /// `is_dead() == true` when a shard comes back with an error.
    fn mark_dead(&self, why: &str) {
        if self.dead.swap(true, Ordering::SeqCst) {
            return;
        }
        let _ = self.sock.shutdown(Shutdown::Both);
        let jobs: Vec<Arc<Pending<R>>> =
            lock_ok(&self.pending).drain().map(|(_, j)| j).collect();
        for job in jobs {
            job.complete(Err(format!(
                "remote engine {}: {why}",
                self.peer
            )));
        }
    }

    fn complete_id(
        &self,
        id: u64,
        res: std::result::Result<Vec<R>, String>,
    ) {
        if let Some(job) = lock_ok(&self.pending).remove(&id) {
            job.complete(res);
        }
    }
}

/// One established connection epoch: shared state plus its service
/// threads. The reconnect supervisor swaps a whole `Conn` in behind
/// the proxy, so jobs submitted on the old epoch keep their
/// death-path semantics while new submits land on the fresh socket.
struct Conn<R> {
    shared: Arc<RemoteShared<R>>,
    reader: Option<thread::JoinHandle<()>>,
    pinger: Option<thread::JoinHandle<()>>,
}

impl<R> Conn<R> {
    /// Stop this epoch's threads and close its socket. Idempotent;
    /// joins are quick because death ends both loops.
    fn teardown(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        let _ = self.shared.sock.shutdown(Shutdown::Both);
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
        if let Some(h) = self.pinger.take() {
            let _ = h.join();
        }
    }
}

/// Client-side proxy for an engine hosted by a `zmc worker` process.
/// Generic over the task/result payload so the transport is testable
/// against mock backends; production uses
/// `RemoteEngine<LaunchTask, TaggedOutput>`.
pub struct RemoteEngine<T, R> {
    peer: String,
    /// Current connection epoch; replaced wholesale on reconnect.
    conn: Arc<Mutex<Conn<R>>>,
    /// Proxy-lifetime stop flag (ends the supervisor on drop).
    stop: Arc<AtomicBool>,
    supervisor: Option<thread::JoinHandle<()>>,
    _task: PhantomData<fn(T) -> T>,
}

impl<T, R> RemoteEngine<T, R>
where
    T: Wire,
    R: Wire + Send + 'static,
{
    /// Connect to a worker, retrying with backoff while it starts up.
    /// A typed [`HandshakeError`] (version or digest mismatch) fails
    /// immediately — retrying into the same wall cannot help.
    pub fn connect(addr: &str, cfg: RemoteConfig) -> Result<Self> {
        Self::connect_with_metrics(addr, cfg, Arc::new(Metrics::new()))
    }

    /// Like [`connect`](Self::connect), with reconnect events
    /// accounted on the caller's [`Metrics`] (the cluster passes its
    /// own, so `reconnects`/`reconnect_failures` show up in the same
    /// summary as retries and failures).
    pub fn connect_with_metrics(
        addr: &str,
        cfg: RemoteConfig,
        metrics: Arc<Metrics>,
    ) -> Result<Self> {
        let mut backoff = cfg.connect_backoff;
        let mut last_err = None;
        for _ in 0..cfg.connect_retries.max(1) {
            match Self::establish(addr, &cfg) {
                Ok(conn) => {
                    return Ok(Self::from_conn(addr, cfg, conn, metrics))
                }
                Err(e) => {
                    if e.downcast_ref::<HandshakeError>().is_some() {
                        return Err(e.context(format!(
                            "connecting to remote worker {addr}"
                        )));
                    }
                    last_err = Some(e);
                    thread::sleep(backoff);
                    backoff =
                        (backoff * 2).min(cfg.connect_backoff * 8);
                }
            }
        }
        Err(last_err.unwrap()).with_context(|| {
            format!(
                "connecting to remote worker {addr} \
                 ({} attempts)",
                cfg.connect_retries.max(1)
            )
        })
    }

    /// One full connection attempt: TCP connect, transport setup,
    /// `Hello`/`HelloAck` under a read deadline (a silent peer or a
    /// clean EOF mid-handshake is a connect *failure*, never a hang),
    /// then spawn the reader and heartbeat threads.
    fn establish(addr: &str, cfg: &RemoteConfig) -> Result<Conn<R>> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to {addr}"))?;
        stream.set_nodelay(true).ok();
        let mut writer = stream
            .try_clone()
            .context("cloning worker socket for writes")?;
        let read_half = stream
            .try_clone()
            .context("cloning worker socket for reads")?;
        let transport: Arc<dyn Transport> = match &cfg.chaos {
            Some(plan) => Arc::new(ChaosTcp::new(Arc::clone(plan))),
            None => Arc::new(DirectTcp),
        };

        // clones share the underlying socket, so this deadline also
        // governs reads on `read_half` until cleared below
        let deadline = cfg.ping_timeout.max(Duration::from_millis(50));
        stream
            .set_read_timeout(Some(deadline))
            .context("setting handshake read deadline")?;
        let hello = Frame::<T, R>::Hello {
            min_version: WIRE_VERSION_MIN,
            max_version: WIRE_VERSION,
            digest: cfg.digest,
        };
        transport
            .send(&mut writer, &hello.to_bytes())
            .with_context(|| format!("sending Hello to {addr}"))?;
        let mut rd = BufReader::new(read_half);
        match Frame::<T, R>::read_from(&mut rd) {
            Ok(Some(Frame::HelloAck { version, digest })) => {
                if version < WIRE_VERSION_MIN || version > WIRE_VERSION
                {
                    return Err(HandshakeError::VersionMismatch {
                        ours_min: WIRE_VERSION_MIN,
                        ours_max: WIRE_VERSION,
                        theirs: version,
                    }
                    .into());
                }
                if cfg.digest != 0
                    && digest != 0
                    && digest != cfg.digest
                {
                    return Err(HandshakeError::DigestMismatch {
                        ours: cfg.digest,
                        theirs: digest,
                    }
                    .into());
                }
            }
            Ok(Some(Frame::Error { msg, .. })) => {
                bail!("worker {addr} rejected the handshake: {msg}")
            }
            Ok(Some(_)) => bail!(
                "worker {addr} answered the Hello with a \
                 non-handshake frame"
            ),
            Ok(None) => bail!(
                "worker {addr} closed the connection mid-handshake"
            ),
            Err(e) => {
                return Err(e.context(format!(
                    "waiting for HelloAck from {addr}"
                )))
            }
        }
        stream
            .set_read_timeout(None)
            .context("clearing handshake read deadline")?;

        let shared = Arc::new(RemoteShared::<R> {
            peer: addr.to_string(),
            writer: Mutex::new(writer),
            transport,
            sock: stream,
            pending: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            dead: AtomicBool::new(false),
            stop: AtomicBool::new(false),
            last_alive_ms: AtomicU64::new(0),
            born: Instant::now(),
        });
        shared.touch();

        let reader = {
            let shared = Arc::clone(&shared);
            thread::Builder::new()
                .name(format!("zmc-remote-rx-{addr}"))
                .spawn(move || reader_loop::<T, R>(shared, rd))
                .context("spawning remote reader thread")?
        };
        let pinger = {
            let shared = Arc::clone(&shared);
            let cfg = cfg.clone();
            thread::Builder::new()
                .name(format!("zmc-remote-ping-{addr}"))
                .spawn(move || ping_loop::<T, R>(shared, cfg))
                .context("spawning remote heartbeat thread")?
        };
        Ok(Conn { shared, reader: Some(reader), pinger: Some(pinger) })
    }

    fn from_conn(
        addr: &str,
        cfg: RemoteConfig,
        conn: Conn<R>,
        metrics: Arc<Metrics>,
    ) -> Self {
        let conn = Arc::new(Mutex::new(conn));
        let stop = Arc::new(AtomicBool::new(false));
        let supervisor = if cfg.reconnect && cfg.reconnect_retries > 0
        {
            let addr_owned = addr.to_string();
            let conn2 = Arc::clone(&conn);
            let stop2 = Arc::clone(&stop);
            thread::Builder::new()
                .name(format!("zmc-remote-sup-{addr}"))
                .spawn(move || {
                    supervisor_loop::<T, R>(
                        addr_owned, cfg, conn2, stop2, metrics,
                    )
                })
                .ok()
        } else {
            None
        };
        RemoteEngine {
            peer: addr.to_string(),
            conn,
            stop,
            supervisor,
            _task: PhantomData,
        }
    }

    /// The current connection epoch.
    fn current(&self) -> Arc<RemoteShared<R>> {
        Arc::clone(&lock_ok(&self.conn).shared)
    }

    /// Address this proxy connected to.
    pub fn peer(&self) -> &str {
        &self.peer
    }

    /// True while the *current* connection is closed, errored, or
    /// heartbeat timed out. Flips back to `false` once the reconnect
    /// supervisor establishes a fresh connection — the cluster's
    /// alive-set scan uses exactly this to let a revived host rejoin
    /// the shard plan.
    pub fn is_dead(&self) -> bool {
        self.current().dead.load(Ordering::SeqCst)
    }

    /// Ship a task batch to the worker as one engine job. Mirrors
    /// `Engine::submit_with_retries`; the retry budget applies on the
    /// worker's engine (task-level retries stay local to the host).
    pub fn submit_with_retries(
        &self,
        tasks: Vec<T>,
        max_retries: u32,
    ) -> Result<RemoteHandle<R>> {
        let shared = self.current();
        if shared.dead.load(Ordering::SeqCst) {
            bail!("remote engine {} is dead", shared.peer);
        }
        let id = shared.next_id.fetch_add(1, Ordering::Relaxed);
        let job = Arc::new(Pending::new());
        lock_ok(&shared.pending).insert(id, Arc::clone(&job));

        let frame = Frame::<T, R>::Submit { id, max_retries, tasks };
        if let Err(e) = shared.send_frame(&frame.to_bytes()) {
            shared.mark_dead(&format!("send failed: {e}"));
        } else if shared.dead.load(Ordering::SeqCst) {
            // death raced the insert: the sweep may have missed this
            // job, so fail it explicitly rather than hang its waiter
            shared.complete_id(
                id,
                Err(format!(
                    "remote engine {} died during submit",
                    shared.peer
                )),
            );
        }
        if shared.dead.load(Ordering::SeqCst) {
            // the pending entry (if any) was already failed above
            let _ = lock_ok(&shared.pending).remove(&id);
            bail!("remote engine {} died during submit", shared.peer);
        }
        Ok(RemoteHandle {
            id,
            job,
            shared: Arc::downgrade(&shared),
            waited: false,
        })
    }
}

impl<T, R> Drop for RemoteEngine<T, R> {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        {
            // unblock the current epoch's threads; the supervisor
            // checks `stop` before and after every sleep
            let conn = lock_ok(&self.conn);
            conn.shared.stop.store(true, Ordering::SeqCst);
            let _ = conn.shared.sock.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
        lock_ok(&self.conn).teardown();
    }
}

/// Sleep in small steps so a proxy drop never waits out a backoff.
fn sleep_unless_stopped(stop: &AtomicBool, total: Duration) {
    let step = Duration::from_millis(10);
    let mut slept = Duration::ZERO;
    while slept < total {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let s = step.min(total - slept);
        thread::sleep(s);
        slept += s;
    }
}

/// Watch one proxy's connection; after death, re-establish it with
/// exponential backoff + deterministic jitter (salted by the peer
/// address) up to `reconnect_retries` attempts per outage, then swap
/// the fresh epoch in. Exits when the attempt budget drains (the
/// proxy stays dead) or the proxy is dropped.
fn supervisor_loop<T, R>(
    addr: String,
    cfg: RemoteConfig,
    conn: Arc<Mutex<Conn<R>>>,
    stop: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
) where
    T: Wire,
    R: Wire + Send + 'static,
{
    let salt = addr
        .bytes()
        .fold(0u64, |h, b| splitmix64(h ^ u64::from(b)));
    let poll = Duration::from_millis(20);
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        if !lock_ok(&conn).shared.dead.load(Ordering::SeqCst) {
            thread::sleep(poll);
            continue;
        }
        let mut fresh = None;
        for attempt in 0..cfg.reconnect_retries {
            sleep_unless_stopped(
                &stop,
                backoff_delay(
                    attempt,
                    cfg.reconnect_backoff,
                    cfg.reconnect_cap,
                    salt,
                ),
            );
            if stop.load(Ordering::SeqCst) {
                return;
            }
            match RemoteEngine::<T, R>::establish(&addr, &cfg) {
                Ok(c) => {
                    fresh = Some(c);
                    break;
                }
                Err(_) => metrics.reconnect_failure(),
            }
        }
        let Some(new_conn) = fresh else {
            // attempt budget drained: this outage is final
            return;
        };
        metrics.reconnect();
        let mut old = {
            let mut guard = lock_ok(&conn);
            std::mem::replace(&mut *guard, new_conn)
        };
        old.teardown();
    }
}

fn reader_loop<T, R>(
    shared: Arc<RemoteShared<R>>,
    mut rd: BufReader<TcpStream>,
) where
    T: Wire,
    R: Wire,
{
    loop {
        match Frame::<T, R>::read_from(&mut rd) {
            Ok(Some(Frame::Pong { .. })) => shared.touch(),
            Ok(Some(Frame::Result { id, outs })) => {
                shared.touch();
                shared.complete_id(id, Ok(outs));
            }
            Ok(Some(Frame::Error { id, msg })) => {
                shared.touch();
                shared.complete_id(id, Err(msg));
            }
            // Ping/Submit/Cancel from a worker are protocol noise;
            // still proof the peer is alive
            Ok(Some(_)) => shared.touch(),
            Ok(None) => {
                shared.mark_dead("connection closed by worker");
                return;
            }
            Err(e) => {
                if shared.stop.load(Ordering::SeqCst) {
                    // local shutdown raced the read; not a failure
                    shared.mark_dead("proxy shut down");
                } else {
                    shared.mark_dead(&format!("read failed: {e:#}"));
                }
                return;
            }
        }
        if shared.stop.load(Ordering::SeqCst) {
            shared.mark_dead("proxy shut down");
            return;
        }
    }
}

fn ping_loop<T, R>(shared: Arc<RemoteShared<R>>, cfg: RemoteConfig)
where
    T: Wire,
    R: Wire,
{
    let step = Duration::from_millis(25).min(cfg.ping_interval);
    let mut nonce = 0u64;
    let mut since_ping = Duration::ZERO;
    loop {
        if shared.stop.load(Ordering::SeqCst)
            || shared.dead.load(Ordering::SeqCst)
        {
            return;
        }
        if shared.silence() > cfg.ping_timeout {
            shared.mark_dead(&format!(
                "heartbeat timeout ({}ms without a pong)",
                cfg.ping_timeout.as_millis()
            ));
            return;
        }
        if since_ping >= cfg.ping_interval {
            since_ping = Duration::ZERO;
            nonce += 1;
            let bytes = Frame::<T, R>::Ping { nonce }.to_bytes();
            if let Err(e) = shared.send_frame(&bytes) {
                shared.mark_dead(&format!("ping failed: {e}"));
                return;
            }
        }
        thread::sleep(step);
        since_ping += step;
    }
}

/// Handle to one remote job; mirrors `JobHandle`'s wait/is_done/Drop
/// contract (dropping an unawaited handle sends a best-effort cancel).
pub struct RemoteHandle<R> {
    id: u64,
    job: Arc<Pending<R>>,
    shared: Weak<RemoteShared<R>>,
    waited: bool,
}

impl<R> RemoteHandle<R> {
    /// Block until the worker answers (or the connection dies).
    pub fn wait(mut self) -> Result<Vec<R>> {
        self.waited = true;
        let mut slot = lock_ok(&self.job.result);
        loop {
            if let Some(res) = slot.take() {
                return res.map_err(|msg| anyhow!(msg));
            }
            slot = wait_ok(&self.job.cv, slot);
        }
    }

    pub fn is_done(&self) -> bool {
        self.job.is_done()
    }
}

impl<R> Drop for RemoteHandle<R> {
    fn drop(&mut self) {
        if self.waited || self.job.is_done() {
            return;
        }
        if let Some(shared) = self.shared.upgrade() {
            let _ = lock_ok(&shared.pending).remove(&self.id);
            if !shared.dead.load(Ordering::SeqCst) {
                let bytes =
                    Frame::<u64, R>::Cancel { id: self.id }.to_bytes();
                let _ = shared.send_frame(&bytes);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// server side: worker host
// ---------------------------------------------------------------------------

/// Counters exposed by a [`WorkerServer`] — the cluster tests assert
/// `empty_submits == 0` (empty shards must be skipped at dispatch,
/// never shipped).
#[derive(Debug, Default)]
pub struct WorkerStats {
    pub connections: AtomicU64,
    pub submits: AtomicU64,
    pub empty_submits: AtomicU64,
    pub tasks: AtomicU64,
    /// `Cancel` frames honored (a client dropped a job's handle; the
    /// matching in-flight engine job was dropped, purging its queue).
    pub cancels: AtomicU64,
}

/// A running worker host: TCP accept loop in front of one local
/// engine. Connections multiplex jobs; each gets its own service
/// thread so one slow peer cannot stall another.
pub struct WorkerServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    stats: Arc<WorkerStats>,
    accept: Option<thread::JoinHandle<()>>,
}

impl WorkerServer {
    /// Bound address (use port 0 in tests to get an ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> &WorkerStats {
        &self.stats
    }

    /// Abrupt shutdown: sever every client connection mid-flight and
    /// stop accepting. Clients observe EOF instantly — this is the
    /// "kill the worker host mid-round" test hook (in production the
    /// same effect comes from the process dying).
    pub fn kill(&self) {
        self.stop.store(true, Ordering::SeqCst);
        for s in lock_ok(&self.conns).drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
    }

    /// Block until the server is stopped (the `zmc worker` foreground
    /// mode). Returns after [`kill`](Self::kill) from another thread
    /// or process signal teardown.
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerServer {
    fn drop(&mut self) {
        self.kill();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// Host `engine` behind `listener`. Returns immediately; the accept
/// loop and per-connection service threads run in the background until
/// the server is killed or dropped. Handshakes with digest 0
/// (unchecked) — production workers use
/// [`serve_worker_with_digest`] so clients can verify artifact parity.
pub fn serve_worker<B>(
    listener: TcpListener,
    engine: Engine<B>,
) -> Result<WorkerServer>
where
    B: Backend + Send + Sync + 'static,
    B::Task: Wire + Clone + Send + Sync + 'static,
    B::Out: Wire + Send + 'static,
{
    serve_worker_with_digest(listener, engine, 0)
}

/// [`serve_worker`] with a registry digest answered in every
/// `HelloAck`, letting clients reject this worker at connect time if
/// its artifacts drifted from theirs.
pub fn serve_worker_with_digest<B>(
    listener: TcpListener,
    engine: Engine<B>,
    digest: u64,
) -> Result<WorkerServer>
where
    B: Backend + Send + Sync + 'static,
    B::Task: Wire + Clone + Send + Sync + 'static,
    B::Out: Wire + Send + 'static,
{
    listener
        .set_nonblocking(true)
        .context("setting worker listener non-blocking")?;
    let addr = listener
        .local_addr()
        .context("reading worker listener address")?;
    let stop = Arc::new(AtomicBool::new(false));
    let conns: Arc<Mutex<Vec<TcpStream>>> =
        Arc::new(Mutex::new(Vec::new()));
    let stats = Arc::new(WorkerStats::default());
    let engine = Arc::new(engine);

    let accept = {
        let stop = Arc::clone(&stop);
        let conns = Arc::clone(&conns);
        let stats = Arc::clone(&stats);
        thread::Builder::new()
            .name("zmc-worker-accept".to_string())
            .spawn(move || {
                accept_loop(
                    listener, engine, stop, conns, stats, digest,
                )
            })
            .context("spawning worker accept thread")?
    };

    Ok(WorkerServer { addr, stop, conns, stats, accept: Some(accept) })
}

fn accept_loop<B>(
    listener: TcpListener,
    engine: Arc<Engine<B>>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    stats: Arc<WorkerStats>,
    digest: u64,
) where
    B: Backend + Send + Sync + 'static,
    B::Task: Wire + Clone + Send + Sync + 'static,
    B::Out: Wire + Send + 'static,
{
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                stream.set_nodelay(true).ok();
                stats.connections.fetch_add(1, Ordering::Relaxed);
                if let Ok(clone) = stream.try_clone() {
                    lock_ok(&conns).push(clone);
                }
                let engine = Arc::clone(&engine);
                let stop = Arc::clone(&stop);
                let stats = Arc::clone(&stats);
                // service threads are detached: they exit when their
                // socket closes (kill/Drop shuts every socket down)
                let _ = thread::Builder::new()
                    .name(format!("zmc-worker-conn-{peer}"))
                    .spawn(move || {
                        serve_conn(stream, engine, stop, stats, digest)
                    });
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                thread::sleep(Duration::from_millis(5));
            }
            Err(_) => thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Service one client connection: a blocking reader thread feeds
/// frames through a channel; this loop answers pings immediately,
/// submits jobs to the engine, and polls in-flight handles so results
/// stream back as soon as each job finishes (heartbeats keep flowing
/// while jobs run — the whole point of the two-thread split).
fn serve_conn<B>(
    stream: TcpStream,
    engine: Arc<Engine<B>>,
    stop: Arc<AtomicBool>,
    stats: Arc<WorkerStats>,
    digest: u64,
) where
    B: Backend + Send + Sync + 'static,
    B::Task: Wire + Clone + Send + Sync + 'static,
    B::Out: Wire + Send + 'static,
{
    type Fr<B> =
        Frame<<B as Backend>::Task, <B as Backend>::Out>;

    let Ok(read_half) = stream.try_clone() else { return };
    let (tx, rx) = mpsc::channel::<Fr<B>>();
    let reader = thread::spawn(move || {
        let mut rd = BufReader::new(read_half);
        loop {
            match Frame::read_from(&mut rd) {
                Ok(Some(frame)) => {
                    if tx.send(frame).is_err() {
                        return;
                    }
                }
                // EOF or corrupt frame: stop reading; the service
                // loop sees the channel hang up and tears down
                Ok(None) | Err(_) => return,
            }
        }
    });

    let mut write = stream;
    let mut inflight: Vec<(u64, JobHandle<B::Task, B::Out>)> =
        Vec::new();
    'serve: loop {
        match rx.recv_timeout(Duration::from_millis(2)) {
            Ok(Frame::Ping { nonce }) => {
                if Fr::<B>::Pong { nonce }.write_to(&mut write).is_err()
                {
                    break 'serve;
                }
            }
            Ok(Frame::Hello { min_version, max_version, .. }) => {
                // the worker answers permissively: offer the best
                // overlap (or 0 for "none") and let the client decide
                let lo = min_version.max(WIRE_VERSION_MIN);
                let hi = max_version.min(WIRE_VERSION);
                let version = if lo <= hi { hi } else { 0 };
                let ack = Fr::<B>::HelloAck { version, digest };
                if ack.write_to(&mut write).is_err() {
                    break 'serve;
                }
            }
            Ok(Frame::Submit { id, max_retries, tasks }) => {
                stats.submits.fetch_add(1, Ordering::Relaxed);
                if tasks.is_empty() {
                    stats
                        .empty_submits
                        .fetch_add(1, Ordering::Relaxed);
                }
                stats
                    .tasks
                    .fetch_add(tasks.len() as u64, Ordering::Relaxed);
                match engine.submit_with_retries(tasks, max_retries) {
                    Ok(h) => inflight.push((id, h)),
                    Err(e) => {
                        let frame = Fr::<B>::Error {
                            id,
                            msg: format!("{e:#}"),
                        };
                        if frame.write_to(&mut write).is_err() {
                            break 'serve;
                        }
                    }
                }
            }
            Ok(Frame::Cancel { id }) => {
                // dropping the handle cancels + purges engine-side
                let before = inflight.len();
                inflight.retain(|(jid, _)| *jid != id);
                if inflight.len() < before {
                    stats.cancels.fetch_add(1, Ordering::Relaxed);
                }
            }
            Ok(_) => {} // Pong/Result/Error from a client: ignore
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break 'serve,
        }

        let mut i = 0;
        while i < inflight.len() {
            if !inflight[i].1.is_done() {
                i += 1;
                continue;
            }
            let (id, handle) = inflight.swap_remove(i);
            let frame = match handle.wait() {
                Ok(outs) => Fr::<B>::Result { id, outs },
                Err(e) => {
                    Fr::<B>::Error { id, msg: format!("{e:#}") }
                }
            };
            if frame.write_to(&mut write).is_err() {
                break 'serve;
            }
        }

        if stop.load(Ordering::SeqCst) && inflight.is_empty() {
            break 'serve;
        }
    }
    // closing the socket unblocks the reader thread (same underlying
    // socket as the clone it reads from)
    let _ = write.shutdown(Shutdown::Both);
    drop(write);
    let _ = reader.join();
    // any still-inflight handles drop here -> engine-side cancel
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;
    use crate::engine::core::{EngineConfig, FaultPlan};

    /// Mock backend over the same `u64 -> u64` function the cluster
    /// core tests use, so remote results are directly comparable.
    struct Mock;

    impl Backend for Mock {
        type Task = u64;
        type Out = u64;
        type Ctx = ();

        fn make_ctx(&self, _worker: usize) -> Result<()> {
            Ok(())
        }

        fn run(&self, _ctx: &(), task: &u64) -> Result<u64> {
            Ok(task * 31 + 7)
        }
    }

    fn worker(n_workers: usize) -> WorkerServer {
        let engine = Engine::new(
            Mock,
            EngineConfig { n_workers, ..Default::default() },
        )
        .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        serve_worker(listener, engine).unwrap()
    }

    fn fast_cfg() -> RemoteConfig {
        RemoteConfig {
            ping_interval: Duration::from_millis(20),
            ping_timeout: Duration::from_millis(250),
            connect_retries: 10,
            connect_backoff: Duration::from_millis(10),
            reconnect_backoff: Duration::from_millis(10),
            reconnect_cap: Duration::from_millis(40),
            reconnect_retries: 3,
            ..Default::default()
        }
    }

    fn connect(w: &WorkerServer) -> RemoteEngine<u64, u64> {
        RemoteEngine::connect(&w.addr().to_string(), fast_cfg())
            .unwrap()
    }

    #[test]
    fn loopback_submit_round_trips() {
        let w = worker(2);
        let eng = connect(&w);
        let tasks: Vec<u64> = (0..40).collect();
        let outs = eng
            .submit_with_retries(tasks.clone(), 0)
            .unwrap()
            .wait()
            .unwrap();
        let want: Vec<u64> =
            tasks.iter().map(|t| t * 31 + 7).collect();
        assert_eq!(outs, want);
        assert_eq!(w.stats().submits.load(Ordering::Relaxed), 1);
        assert_eq!(w.stats().empty_submits.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn multiple_jobs_multiplex_one_connection() {
        let w = worker(2);
        let eng = connect(&w);
        let handles: Vec<_> = (0..6)
            .map(|k| {
                let tasks: Vec<u64> =
                    (k * 10..k * 10 + 10).collect();
                (tasks.clone(),
                 eng.submit_with_retries(tasks, 0).unwrap())
            })
            .collect();
        for (tasks, h) in handles {
            let want: Vec<u64> =
                tasks.iter().map(|t| t * 31 + 7).collect();
            assert_eq!(h.wait().unwrap(), want);
        }
    }

    #[test]
    fn worker_kill_fails_pending_and_marks_dead() {
        struct Stuck;
        impl Backend for Stuck {
            type Task = u64;
            type Out = u64;
            type Ctx = ();
            fn make_ctx(&self, _w: usize) -> Result<()> {
                Ok(())
            }
            fn run(&self, _ctx: &(), _task: &u64) -> Result<u64> {
                thread::sleep(Duration::from_secs(30));
                Ok(0)
            }
        }
        let engine = Engine::new(
            Stuck,
            EngineConfig { n_workers: 1, ..Default::default() },
        )
        .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let w = serve_worker(listener, engine).unwrap();
        let eng: RemoteEngine<u64, u64> =
            RemoteEngine::connect(&w.addr().to_string(), fast_cfg())
                .unwrap();
        let h = eng.submit_with_retries(vec![1, 2, 3], 0).unwrap();
        assert!(!h.is_done());
        w.kill();
        let err = h.wait().unwrap_err().to_string();
        assert!(err.contains("remote engine"), "{err}");
        assert!(eng.is_dead());
        assert!(eng.submit_with_retries(vec![4], 0).is_err());
    }

    #[test]
    fn heartbeat_detects_hung_host() {
        // a listener that completes the handshake and then never
        // reads or writes again — TCP stays "connected", only the
        // heartbeat can notice
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let hold = thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut rd = BufReader::new(s.try_clone().unwrap());
            match Frame::<u64, u64>::read_from(&mut rd) {
                Ok(Some(Frame::Hello { .. })) => {}
                other => panic!("expected Hello, got {other:?}"),
            }
            Frame::<u64, u64>::HelloAck {
                version: WIRE_VERSION,
                digest: 0,
            }
            .write_to(&mut s)
            .unwrap();
            thread::sleep(Duration::from_secs(2));
            drop(s);
        });
        let cfg = RemoteConfig { reconnect: false, ..fast_cfg() };
        let eng: RemoteEngine<u64, u64> =
            RemoteEngine::connect(&addr.to_string(), cfg).unwrap();
        let h = eng.submit_with_retries(vec![9], 0).unwrap();
        let err = h.wait().unwrap_err().to_string();
        assert!(err.contains("heartbeat timeout"), "{err}");
        assert!(eng.is_dead());
        hold.join().unwrap();
    }

    #[test]
    fn handshake_rejects_version_mismatch() {
        // a "worker" that answers the Hello with a version outside
        // our range: connect must fail fast with the typed error,
        // not burn its retry budget
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let fake = thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut rd = BufReader::new(s.try_clone().unwrap());
            let _ = Frame::<u64, u64>::read_from(&mut rd);
            Frame::<u64, u64>::HelloAck { version: 0, digest: 0 }
                .write_to(&mut s)
                .unwrap();
            thread::sleep(Duration::from_millis(200));
        });
        let start = Instant::now();
        let err = RemoteEngine::<u64, u64>::connect(
            &addr.to_string(),
            fast_cfg(),
        )
        .unwrap_err();
        assert!(
            err.chain().any(|c| c
                .to_string()
                .contains("wire-version mismatch")),
            "{err:#}"
        );
        // fail-fast: nowhere near 10 retries x backoff
        assert!(start.elapsed() < Duration::from_secs(2));
        fake.join().unwrap();
    }

    #[test]
    fn handshake_rejects_digest_mismatch() {
        let engine = Engine::new(
            Mock,
            EngineConfig { n_workers: 1, ..Default::default() },
        )
        .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let w = serve_worker_with_digest(listener, engine, 7).unwrap();
        let cfg = RemoteConfig { digest: 8, ..fast_cfg() };
        let err = RemoteEngine::<u64, u64>::connect(
            &w.addr().to_string(),
            cfg,
        )
        .unwrap_err();
        assert!(
            err.chain().any(|c| c
                .to_string()
                .contains("registry digest mismatch")),
            "{err:#}"
        );
        // matching digest connects fine
        let cfg = RemoteConfig { digest: 7, ..fast_cfg() };
        let eng: RemoteEngine<u64, u64> =
            RemoteEngine::connect(&w.addr().to_string(), cfg)
                .unwrap();
        let outs =
            eng.submit_with_retries(vec![1], 0).unwrap().wait().unwrap();
        assert_eq!(outs, vec![38]);
    }

    #[test]
    fn eof_mid_handshake_is_connect_failure_not_hang() {
        // accept and immediately close: the client sees a clean EOF
        // where the HelloAck should be
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let slam = thread::spawn(move || {
            for _ in 0..3 {
                if let Ok((s, _)) = listener.accept() {
                    drop(s);
                }
            }
        });
        let cfg = RemoteConfig {
            connect_retries: 3,
            ..fast_cfg()
        };
        let start = Instant::now();
        let err = RemoteEngine::<u64, u64>::connect(
            &addr.to_string(),
            cfg,
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("mid-handshake")
                || msg.contains("HelloAck"),
            "{msg}"
        );
        assert!(start.elapsed() < Duration::from_secs(5));
        drop(slam); // listener thread may still be in accept()
    }

    #[test]
    fn worker_restart_reconnects_and_serves() {
        let w = worker(1);
        let addr = w.addr();
        let metrics = Arc::new(Metrics::new());
        let eng: RemoteEngine<u64, u64> =
            RemoteEngine::connect_with_metrics(
                &addr.to_string(),
                RemoteConfig {
                    reconnect_retries: 100,
                    ..fast_cfg()
                },
                Arc::clone(&metrics),
            )
            .unwrap();
        assert_eq!(
            eng.submit_with_retries(vec![1], 0)
                .unwrap()
                .wait()
                .unwrap(),
            vec![38]
        );

        // kill the worker (clients see EOF), then restart one on the
        // same port — the supervisor should re-handshake and revive
        w.kill();
        w.join();
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            // rebinding can race the old listener's close
            match TcpListener::bind(addr) {
                Ok(l) => {
                    let engine = Engine::new(
                        Mock,
                        EngineConfig {
                            n_workers: 1,
                            ..Default::default()
                        },
                    )
                    .unwrap();
                    let _w2 = serve_worker(l, engine).unwrap();
                    while eng.is_dead() {
                        assert!(
                            Instant::now() < deadline,
                            "proxy never revived"
                        );
                        thread::sleep(Duration::from_millis(10));
                    }
                    assert!(metrics.reconnects() >= 1);
                    let outs = eng
                        .submit_with_retries(vec![2], 0)
                        .unwrap()
                        .wait()
                        .unwrap();
                    assert_eq!(outs, vec![69]);
                    return;
                }
                Err(_) if Instant::now() < deadline => {
                    thread::sleep(Duration::from_millis(10))
                }
                Err(e) => panic!("could not rebind {addr}: {e}"),
            }
        }
    }

    #[test]
    fn cancel_frame_purges_worker_side_job() {
        struct Slow;
        impl Backend for Slow {
            type Task = u64;
            type Out = u64;
            type Ctx = ();
            fn make_ctx(&self, _w: usize) -> Result<()> {
                Ok(())
            }
            fn run(&self, _ctx: &(), task: &u64) -> Result<u64> {
                thread::sleep(Duration::from_millis(150));
                Ok(task * 31 + 7)
            }
        }
        let engine = Engine::new(
            Slow,
            EngineConfig { n_workers: 1, ..Default::default() },
        )
        .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let w = serve_worker(listener, engine).unwrap();
        let eng: RemoteEngine<u64, u64> =
            RemoteEngine::connect(&w.addr().to_string(), fast_cfg())
                .unwrap();
        let h = eng.submit_with_retries(vec![1, 2, 3, 4], 0).unwrap();
        // let the Submit land worker-side before cancelling
        let deadline = Instant::now() + Duration::from_secs(5);
        while w.stats().submits.load(Ordering::Relaxed) == 0 {
            assert!(Instant::now() < deadline, "submit never landed");
            thread::sleep(Duration::from_millis(5));
        }
        drop(h); // sends Cancel
        while w.stats().cancels.load(Ordering::Relaxed) == 0 {
            assert!(Instant::now() < deadline, "cancel never honored");
            thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(w.stats().cancels.load(Ordering::Relaxed), 1);
        // the connection is still healthy for new work
        let outs = eng
            .submit_with_retries(vec![2], 0)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(outs, vec![69]);
        assert!(!eng.is_dead());
    }

    #[test]
    fn live_engine_task_failure_is_not_death() {
        struct BadThirteen;
        impl Backend for BadThirteen {
            type Task = u64;
            type Out = u64;
            type Ctx = ();
            fn make_ctx(&self, _w: usize) -> Result<()> {
                Ok(())
            }
            fn run(&self, _ctx: &(), task: &u64) -> Result<u64> {
                if *task == 13 {
                    bail!("unlucky task");
                }
                Ok(task * 31 + 7)
            }
        }
        let engine = Engine::with_policy(
            BadThirteen,
            EngineConfig { n_workers: 2, ..Default::default() },
            FaultPlan::none(),
            Arc::new(Metrics::new()),
        )
        .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let w = serve_worker(listener, engine).unwrap();
        let eng: RemoteEngine<u64, u64> =
            RemoteEngine::connect(&w.addr().to_string(), fast_cfg())
                .unwrap();
        let err = eng
            .submit_with_retries(vec![12, 13, 14], 0)
            .unwrap()
            .wait()
            .unwrap_err()
            .to_string();
        assert!(err.contains("unlucky"), "{err}");
        // the worker host is fine: not dead, next job succeeds
        assert!(!eng.is_dead());
        let outs = eng
            .submit_with_retries(vec![1], 0)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(outs, vec![38]);
    }

    #[test]
    fn dropped_handle_cancels_without_killing_connection() {
        let w = worker(1);
        let eng = connect(&w);
        let h = eng.submit_with_retries(vec![5], 0).unwrap();
        drop(h);
        // connection still serves new jobs after the cancel
        let outs = eng
            .submit_with_retries(vec![2], 0)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(outs, vec![69]);
        assert!(!eng.is_dead());
    }
}
