//! Binary wire format for the cluster transport: versioned,
//! length-prefixed frames carrying task batches and result batches
//! between a [`crate::cluster::RemoteEngine`] proxy and a `zmc worker`
//! host.
//!
//! Layout of one frame (all integers little-endian):
//!
//! ```text
//! +--------+---------+------+-------------+----------+----------+
//! | "ZMCW" | version | type | payload len | checksum | payload  |
//! |  4 B   |  u16    | u8   |    u32      |   u32    |  len B   |
//! +--------+---------+------+-------------+----------+----------+
//! ```
//!
//! The checksum is FNV-1a/32 over the type byte, the length prefix,
//! and the payload. It exists for *fault detection*, not security: a
//! single flipped bit anywhere past the version field decodes as a
//! typed [`WireError::BadChecksum`] instead of a silently wrong
//! float (every per-byte FNV step is a bijection of the running
//! state, so one corrupted byte always changes the final hash).
//!
//! The payload is the [`Wire`]-encoded body of one [`Frame`] variant.
//! Floats travel as raw IEEE-754 bit patterns (`f32::to_bits` /
//! `f64::to_bits`), so a task executed remotely sees **bit-identical**
//! inputs and the caller sees bit-identical outputs — the same
//! lossless-codec discipline `util::json::wire_f64` established for
//! the JSON surface, in a compact binary form (a `LaunchTask` is
//! mostly `Vec<f32>` payloads; base-10 round-tripping them would cost
//! ~3× the bytes for zero fidelity gain).
//!
//! Every decode failure is a typed [`WireError`] (truncated frame, bad
//! magic, unknown version, unknown message type, oversized payload,
//! trailing bytes), recoverable from an `anyhow` chain with
//! `err.downcast_ref::<WireError>()` — the transport tests assert on
//! the variants, and the worker drops a connection on the first
//! malformed frame instead of guessing at resynchronization.

use std::io::{Read, Write};
use std::time::Duration;

use crate::engine::{LaunchTask, TaggedOutput};
use crate::runtime::launch::Value;

/// Leading frame bytes; anything else on the socket is not this
/// protocol (catches HTTP requests, random port scans, stream
/// desynchronization).
pub const WIRE_MAGIC: [u8; 4] = *b"ZMCW";

/// Version of the frame layout + payload encodings this build speaks.
/// Bump on any incompatible change; a worker answering a newer client
/// fails with a typed [`WireError::BadVersion`] instead of
/// misinterpreting bytes. v2 added the per-frame integrity checksum
/// and the `Hello`/`HelloAck` handshake.
pub const WIRE_VERSION: u16 = 2;

/// Oldest frame version this build still speaks. Together with
/// [`WIRE_VERSION`] it forms the range a [`Frame::Hello`] advertises;
/// the worker picks the highest version both ranges contain.
pub const WIRE_VERSION_MIN: u16 = 2;

/// Upper bound on one frame's payload (64 MiB). A length prefix above
/// it is treated as stream corruption, not an allocation request.
pub const MAX_PAYLOAD: u32 = 64 << 20;

const HEADER_LEN: usize = 4 + 2 + 1 + 4 + 4;

/// FNV-1a/32 over the frame type byte, the payload length prefix and
/// the payload bytes — the integrity word stored in the header.
fn checksum(tag: u8, payload: &[u8]) -> u32 {
    const PRIME: u32 = 0x0100_0193;
    let mut h: u32 = 0x811c_9dc5;
    h = (h ^ u32::from(tag)).wrapping_mul(PRIME);
    for &b in &(payload.len() as u32).to_le_bytes() {
        h = (h ^ u32::from(b)).wrapping_mul(PRIME);
    }
    for &b in payload {
        h = (h ^ u32::from(b)).wrapping_mul(PRIME);
    }
    h
}

/// Typed decode failures of the cluster wire format.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// The buffer ended before the value it should contain.
    Truncated {
        /// Bytes the decoder needed next.
        need: usize,
        /// Bytes that were left.
        have: usize,
    },
    /// The frame does not start with [`WIRE_MAGIC`].
    BadMagic {
        got: [u8; 4],
    },
    /// The frame declares a version this build does not speak.
    BadVersion {
        got: u16,
    },
    /// Unknown message-type byte.
    BadTag {
        got: u8,
    },
    /// Unknown enum discriminant inside a payload (e.g. a `Value`
    /// dtype byte).
    BadDiscriminant {
        what: &'static str,
        got: u8,
    },
    /// Payload length prefix above [`MAX_PAYLOAD`].
    TooLarge {
        got: u32,
        max: u32,
    },
    /// Bytes were left over after the payload decoded completely.
    Trailing {
        extra: usize,
    },
    /// The frame body does not hash to the checksum in its header —
    /// bit corruption somewhere between the type byte and the last
    /// payload byte.
    BadChecksum {
        want: u32,
        got: u32,
    },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { need, have } => write!(
                f,
                "truncated frame: needed {need} more byte(s), had {have}"
            ),
            WireError::BadMagic { got } => {
                write!(f, "bad frame magic {got:02x?} (expected \"ZMCW\")")
            }
            WireError::BadVersion { got } => write!(
                f,
                "unsupported wire version {got} (this build speaks v{})",
                WIRE_VERSION
            ),
            WireError::BadTag { got } => {
                write!(f, "unknown frame type {got}")
            }
            WireError::BadDiscriminant { what, got } => {
                write!(f, "unknown {what} discriminant {got}")
            }
            WireError::TooLarge { got, max } => write!(
                f,
                "frame payload of {got} bytes exceeds the {max}-byte cap"
            ),
            WireError::Trailing { extra } => {
                write!(f, "{extra} trailing byte(s) after frame payload")
            }
            WireError::BadChecksum { want, got } => write!(
                f,
                "frame checksum mismatch: header says {want:#010x}, \
                 body hashes to {got:#010x}"
            ),
        }
    }
}

impl std::error::Error for WireError {}

/// Cursor over one frame's payload bytes.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated {
                need: n,
                have: self.remaining(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    /// A length prefix that must also fit in the bytes that remain —
    /// rejects absurd lengths before any allocation.
    fn len_prefix(&mut self, unit: usize) -> Result<usize, WireError> {
        let n = self.u32()? as usize;
        let need = n.saturating_mul(unit.max(1));
        if need > self.remaining() {
            return Err(WireError::Truncated {
                need,
                have: self.remaining(),
            });
        }
        Ok(n)
    }
}

/// A value that travels inside a frame payload. Encoding is
/// infallible (append to a buffer); decoding reports typed
/// [`WireError`]s.
pub trait Wire: Sized {
    fn encode(&self, out: &mut Vec<u8>);
    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError>;
}

impl Wire for u32 {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        r.u32()
    }
}

impl Wire for u64 {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        r.u64()
    }
}

impl Wire for f32 {
    /// Raw IEEE-754 bits: bit-exact for every value incl. NaN payloads
    /// and -0.0.
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_bits().to_le_bytes());
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(f32::from_bits(r.u32()?))
    }
}

impl Wire for String {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.len() as u32).to_le_bytes());
        out.extend_from_slice(self.as_bytes());
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        let n = r.len_prefix(1)?;
        let b = r.take(n)?;
        // executable names and error messages only; lossy keeps the
        // decode total without a dedicated utf-8 error variant
        Ok(String::from_utf8_lossy(b).into_owned())
    }
}

impl Wire for Duration {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.as_nanos() as u64).encode(out);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(Duration::from_nanos(r.u64()?))
    }
}

impl<T: Wire> Wire for Vec<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.len() as u32).to_le_bytes());
        for item in self {
            item.encode(out);
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        // every Wire value occupies >= 1 byte, so the prefix is
        // bounded by the remaining payload before any allocation
        let n = r.len_prefix(1)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(T::decode(r)?);
        }
        Ok(v)
    }
}

impl Wire for Value {
    /// dtype byte (0 = F32, 1 = I32, 2 = U32) + element count + raw
    /// little-endian element bytes.
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Value::F32(v) => {
                out.push(0);
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for x in v {
                    out.extend_from_slice(&x.to_bits().to_le_bytes());
                }
            }
            Value::I32(v) => {
                out.push(1);
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Value::U32(v) => {
                out.push(2);
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        let dtype = r.u8()?;
        let n = r.len_prefix(4)?;
        Ok(match dtype {
            0 => {
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    v.push(f32::from_bits(r.u32()?));
                }
                Value::F32(v)
            }
            1 => {
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    v.push(r.u32()? as i32);
                }
                Value::I32(v)
            }
            2 => {
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    v.push(r.u32()?);
                }
                Value::U32(v)
            }
            got => {
                return Err(WireError::BadDiscriminant {
                    what: "Value dtype",
                    got,
                })
            }
        })
    }
}

impl Wire for LaunchTask {
    fn encode(&self, out: &mut Vec<u8>) {
        self.exe.encode(out);
        self.tag.encode(out);
        self.inputs.encode(out);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(LaunchTask {
            exe: String::decode(r)?,
            tag: u64::decode(r)?,
            inputs: Vec::<Value>::decode(r)?,
        })
    }
}

impl Wire for TaggedOutput {
    fn encode(&self, out: &mut Vec<u8>) {
        self.tag.encode(out);
        self.data.encode(out);
        self.device_time.encode(out);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(TaggedOutput {
            tag: u64::decode(r)?,
            data: Vec::<f32>::decode(r)?,
            device_time: Duration::decode(r)?,
        })
    }
}

/// One message of the worker protocol, generic over the task/result
/// payload types so the transport is testable with mock backends and
/// production-typed with `LaunchTask`/`TaggedOutput`.
///
/// Protocol shape: the client sends `Submit` (a whole shard as one
/// job), `Cancel`, and periodic `Ping`s; the worker answers `Pong`
/// immediately (also while jobs run — heartbeats must flow during long
/// rounds) and exactly one `Result` or `Error` per submitted job id.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame<T, R> {
    /// Liveness probe; `nonce` is echoed back.
    Ping { nonce: u64 },
    /// Answer to [`Frame::Ping`].
    Pong { nonce: u64 },
    /// Run `tasks` as one engine job with the given retry budget.
    Submit { id: u64, max_retries: u32, tasks: Vec<T> },
    /// Successful job completion: outputs in task order.
    Result { id: u64, outs: Vec<R> },
    /// Job failure (the engine's error text).
    Error { id: u64, msg: String },
    /// Best-effort cancellation of a submitted job.
    Cancel { id: u64 },
    /// First frame on every connection, client → worker: the wire
    /// versions the client speaks and the FNV-1a digest of its
    /// registry (0 = unchecked, for registry-less mock transports).
    Hello { min_version: u16, max_version: u16, digest: u64 },
    /// Worker's answer to [`Frame::Hello`]: the highest version both
    /// ranges contain (0 = no overlap) and the worker's own registry
    /// digest. The *client* decides rejection, so every typed
    /// handshake failure surfaces at connect time on the caller.
    HelloAck { version: u16, digest: u64 },
}

pub(crate) const TAG_PING: u8 = 1;
pub(crate) const TAG_PONG: u8 = 2;
const TAG_SUBMIT: u8 = 3;
const TAG_RESULT: u8 = 4;
const TAG_ERROR: u8 = 5;
const TAG_CANCEL: u8 = 6;
const TAG_HELLO: u8 = 7;
const TAG_HELLO_ACK: u8 = 8;

impl<T: Wire, R: Wire> Frame<T, R> {
    fn tag(&self) -> u8 {
        match self {
            Frame::Ping { .. } => TAG_PING,
            Frame::Pong { .. } => TAG_PONG,
            Frame::Submit { .. } => TAG_SUBMIT,
            Frame::Result { .. } => TAG_RESULT,
            Frame::Error { .. } => TAG_ERROR,
            Frame::Cancel { .. } => TAG_CANCEL,
            Frame::Hello { .. } => TAG_HELLO,
            Frame::HelloAck { .. } => TAG_HELLO_ACK,
        }
    }

    /// Header + payload as one buffer (a single `write_all`, so a
    /// frame is never interleaved with another writer's bytes).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut payload = Vec::new();
        match self {
            Frame::Ping { nonce } | Frame::Pong { nonce } => {
                nonce.encode(&mut payload);
            }
            Frame::Submit { id, max_retries, tasks } => {
                id.encode(&mut payload);
                max_retries.encode(&mut payload);
                tasks.encode(&mut payload);
            }
            Frame::Result { id, outs } => {
                id.encode(&mut payload);
                outs.encode(&mut payload);
            }
            Frame::Error { id, msg } => {
                id.encode(&mut payload);
                msg.encode(&mut payload);
            }
            Frame::Cancel { id } => {
                id.encode(&mut payload);
            }
            Frame::Hello { min_version, max_version, digest } => {
                u32::from(*min_version).encode(&mut payload);
                u32::from(*max_version).encode(&mut payload);
                digest.encode(&mut payload);
            }
            Frame::HelloAck { version, digest } => {
                u32::from(*version).encode(&mut payload);
                digest.encode(&mut payload);
            }
        }
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
        out.extend_from_slice(&WIRE_MAGIC);
        out.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        out.push(self.tag());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(
            &checksum(self.tag(), &payload).to_le_bytes(),
        );
        out.extend_from_slice(&payload);
        out
    }

    /// Write one frame (single syscall-sized `write_all` + flush).
    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        w.write_all(&self.to_bytes())?;
        w.flush()
    }

    /// Decode one payload given its already-validated type byte.
    pub fn decode_payload(
        tag: u8,
        payload: &[u8],
    ) -> Result<Self, WireError> {
        let mut r = Reader::new(payload);
        let frame = match tag {
            TAG_PING => Frame::Ping { nonce: u64::decode(&mut r)? },
            TAG_PONG => Frame::Pong { nonce: u64::decode(&mut r)? },
            TAG_SUBMIT => Frame::Submit {
                id: u64::decode(&mut r)?,
                max_retries: u32::decode(&mut r)?,
                tasks: Vec::<T>::decode(&mut r)?,
            },
            TAG_RESULT => Frame::Result {
                id: u64::decode(&mut r)?,
                outs: Vec::<R>::decode(&mut r)?,
            },
            TAG_ERROR => Frame::Error {
                id: u64::decode(&mut r)?,
                msg: String::decode(&mut r)?,
            },
            TAG_CANCEL => Frame::Cancel { id: u64::decode(&mut r)? },
            TAG_HELLO => Frame::Hello {
                min_version: u32::decode(&mut r)? as u16,
                max_version: u32::decode(&mut r)? as u16,
                digest: u64::decode(&mut r)?,
            },
            TAG_HELLO_ACK => Frame::HelloAck {
                version: u32::decode(&mut r)? as u16,
                digest: u64::decode(&mut r)?,
            },
            got => return Err(WireError::BadTag { got }),
        };
        if r.remaining() != 0 {
            return Err(WireError::Trailing { extra: r.remaining() });
        }
        Ok(frame)
    }

    /// Parse one frame from a byte buffer (header validation +
    /// payload decode) — the pure core of [`read_from`](Self::read_from),
    /// used directly by the corruption tests.
    pub fn from_bytes(buf: &[u8]) -> Result<Self, WireError> {
        if buf.len() < HEADER_LEN {
            return Err(WireError::Truncated {
                need: HEADER_LEN,
                have: buf.len(),
            });
        }
        let magic = [buf[0], buf[1], buf[2], buf[3]];
        if magic != WIRE_MAGIC {
            return Err(WireError::BadMagic { got: magic });
        }
        let version = u16::from_le_bytes([buf[4], buf[5]]);
        if version != WIRE_VERSION {
            return Err(WireError::BadVersion { got: version });
        }
        let tag = buf[6];
        let len =
            u32::from_le_bytes([buf[7], buf[8], buf[9], buf[10]]);
        if len > MAX_PAYLOAD {
            return Err(WireError::TooLarge { got: len, max: MAX_PAYLOAD });
        }
        let want =
            u32::from_le_bytes([buf[11], buf[12], buf[13], buf[14]]);
        let body = &buf[HEADER_LEN..];
        if body.len() < len as usize {
            return Err(WireError::Truncated {
                need: len as usize,
                have: body.len(),
            });
        }
        if body.len() > len as usize {
            return Err(WireError::Trailing {
                extra: body.len() - len as usize,
            });
        }
        let got = checksum(tag, body);
        if got != want {
            return Err(WireError::BadChecksum { want, got });
        }
        Self::decode_payload(tag, body)
    }

    /// Read one frame from a stream. `Ok(None)` is a clean EOF **at a
    /// frame boundary** (the peer closed); EOF inside a frame is a
    /// typed [`WireError::Truncated`]. Decode failures carry the
    /// `WireError` through the `anyhow` chain for `downcast_ref`.
    pub fn read_from(
        rd: &mut impl Read,
    ) -> anyhow::Result<Option<Self>> {
        use anyhow::Context as _;
        let mut header = [0u8; HEADER_LEN];
        // distinguish boundary EOF (fine) from mid-header EOF (corrupt)
        let mut got = 0usize;
        while got < HEADER_LEN {
            match rd.read(&mut header[got..]) {
                Ok(0) => {
                    if got == 0 {
                        return Ok(None);
                    }
                    return Err(WireError::Truncated {
                        need: HEADER_LEN,
                        have: got,
                    }
                    .into());
                }
                Ok(n) => got += n,
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    return Err(anyhow::Error::from(e)
                        .context("reading frame header"))
                }
            }
        }
        let magic = [header[0], header[1], header[2], header[3]];
        if magic != WIRE_MAGIC {
            return Err(WireError::BadMagic { got: magic }.into());
        }
        let version = u16::from_le_bytes([header[4], header[5]]);
        if version != WIRE_VERSION {
            return Err(WireError::BadVersion { got: version }.into());
        }
        let tag = header[6];
        let len = u32::from_le_bytes([
            header[7], header[8], header[9], header[10],
        ]);
        if len > MAX_PAYLOAD {
            return Err(
                WireError::TooLarge { got: len, max: MAX_PAYLOAD }.into()
            );
        }
        let want = u32::from_le_bytes([
            header[11], header[12], header[13], header[14],
        ]);
        let mut payload = vec![0u8; len as usize];
        rd.read_exact(&mut payload).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                anyhow::Error::from(WireError::Truncated {
                    need: len as usize,
                    have: 0,
                })
            } else {
                anyhow::Error::from(e)
            }
            .context("reading frame payload")
        })?;
        let got = checksum(tag, &payload);
        if got != want {
            return Err(WireError::BadChecksum { want, got }.into());
        }
        Ok(Some(Self::decode_payload(tag, &payload)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    type MockFrame = Frame<u64, u64>;

    fn round_trip(f: &MockFrame) -> MockFrame {
        MockFrame::from_bytes(&f.to_bytes()).unwrap()
    }

    #[test]
    fn frames_round_trip() {
        let frames = [
            MockFrame::Ping { nonce: 7 },
            MockFrame::Pong { nonce: u64::MAX },
            MockFrame::Submit {
                id: 3,
                max_retries: 2,
                tasks: vec![1, 2, 3, u64::MAX],
            },
            MockFrame::Result { id: 3, outs: vec![] },
            MockFrame::Error { id: 9, msg: "boom — bad".into() },
            MockFrame::Cancel { id: 11 },
            MockFrame::Hello {
                min_version: WIRE_VERSION_MIN,
                max_version: WIRE_VERSION,
                digest: 0xdead_beef_cafe_f00d,
            },
            MockFrame::HelloAck {
                version: WIRE_VERSION,
                digest: u64::MAX,
            },
        ];
        for f in &frames {
            assert_eq!(&round_trip(f), f, "{f:?}");
        }
    }

    #[test]
    fn value_codec_is_bit_exact() {
        let vals = [
            Value::F32(vec![0.0, -0.0, 1.5, f32::NAN, f32::INFINITY]),
            Value::I32(vec![i32::MIN, -1, 0, i32::MAX]),
            Value::U32(vec![0, 1, u32::MAX]),
            Value::F32(vec![]),
        ];
        for v in &vals {
            let mut buf = Vec::new();
            v.encode(&mut buf);
            let back = Value::decode(&mut Reader::new(&buf)).unwrap();
            match (v, &back) {
                (Value::F32(a), Value::F32(b)) => {
                    assert_eq!(a.len(), b.len());
                    for (x, y) in a.iter().zip(b) {
                        assert_eq!(x.to_bits(), y.to_bits());
                    }
                }
                (Value::I32(a), Value::I32(b)) => assert_eq!(a, b),
                (Value::U32(a), Value::U32(b)) => assert_eq!(a, b),
                _ => panic!("dtype changed in flight"),
            }
        }
    }

    #[test]
    fn launch_task_round_trips() {
        let task = LaunchTask {
            exe: "vm_multi_f8_s4096".into(),
            tag: 42,
            inputs: vec![
                Value::U32(vec![1, 2, 3, 4]),
                Value::F32(vec![0.25, -1.0e-20, 3.5e20]),
            ],
        };
        let f = Frame::<LaunchTask, TaggedOutput>::Submit {
            id: 1,
            max_retries: 3,
            tasks: vec![task.clone()],
        };
        let back =
            Frame::<LaunchTask, TaggedOutput>::from_bytes(&f.to_bytes())
                .unwrap();
        let Frame::Submit { tasks, .. } = back else {
            panic!("wrong frame");
        };
        assert_eq!(tasks[0].exe, task.exe);
        assert_eq!(tasks[0].tag, task.tag);
        assert_eq!(tasks[0].inputs.len(), 2);
    }

    #[test]
    fn corrupt_frames_are_typed_errors() {
        let good = MockFrame::Ping { nonce: 5 }.to_bytes();

        // truncation at every prefix length
        for cut in 0..good.len() {
            let err = MockFrame::from_bytes(&good[..cut]).unwrap_err();
            assert!(
                matches!(err, WireError::Truncated { .. }),
                "cut={cut}: {err}"
            );
        }

        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(matches!(
            MockFrame::from_bytes(&bad).unwrap_err(),
            WireError::BadMagic { .. }
        ));

        let mut bad = good.clone();
        bad[4] = 9;
        assert_eq!(
            MockFrame::from_bytes(&bad).unwrap_err(),
            WireError::BadVersion { got: 9 }
        );

        // the type byte is under the checksum, so flipping it is
        // caught as corruption, not misread as another frame kind
        let mut bad = good.clone();
        bad[6] = 77;
        assert!(matches!(
            MockFrame::from_bytes(&bad).unwrap_err(),
            WireError::BadChecksum { .. }
        ));

        let mut bad = good.clone();
        bad.push(0);
        assert_eq!(
            MockFrame::from_bytes(&bad).unwrap_err(),
            WireError::Trailing { extra: 1 }
        );
    }

    #[test]
    fn unknown_tag_with_valid_checksum_is_bad_tag() {
        // a *well-formed* frame of an unknown type (version skew, not
        // corruption) still surfaces as BadTag
        let payload = Vec::new();
        let mut buf = Vec::new();
        buf.extend_from_slice(&WIRE_MAGIC);
        buf.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        buf.push(99);
        buf.extend_from_slice(&0u32.to_le_bytes());
        buf.extend_from_slice(&checksum(99, &payload).to_le_bytes());
        assert_eq!(
            MockFrame::from_bytes(&buf).unwrap_err(),
            WireError::BadTag { got: 99 }
        );
    }

    #[test]
    fn every_single_bit_flip_is_a_typed_error() {
        // the property FaultPlan::Corrupt leans on: one flipped bit
        // anywhere in the frame is *always* a typed decode error,
        // never a silently different frame
        let good = MockFrame::Submit {
            id: 5,
            max_retries: 2,
            tasks: vec![0, 1, u64::MAX, 0x0123_4567_89ab_cdef],
        }
        .to_bytes();
        for i in 0..good.len() {
            for bit in 0..8u8 {
                let mut bad = good.clone();
                bad[i] ^= 1 << bit;
                assert!(
                    MockFrame::from_bytes(&bad).is_err(),
                    "byte {i} bit {bit}: corruption decoded cleanly"
                );
            }
        }
    }

    #[test]
    fn oversized_length_prefix_rejected_before_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&WIRE_MAGIC);
        buf.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        buf.push(1); // Ping
        buf.extend_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes()); // checksum slot
        assert!(matches!(
            MockFrame::from_bytes(&buf).unwrap_err(),
            WireError::TooLarge { .. }
        ));

        // an inner Vec length prefix larger than the payload is a
        // Truncated error, not an allocation attempt
        let mut payload = Vec::new();
        3u64.encode(&mut payload); // id
        2u32.encode(&mut payload); // max_retries
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // task count
        let mut buf = Vec::new();
        buf.extend_from_slice(&WIRE_MAGIC);
        buf.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        buf.push(3); // Submit
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&checksum(3, &payload).to_le_bytes());
        buf.extend_from_slice(&payload);
        assert!(matches!(
            MockFrame::from_bytes(&buf).unwrap_err(),
            WireError::Truncated { .. }
        ));
    }

    #[test]
    fn stream_reader_distinguishes_eof_kinds() {
        let bytes = MockFrame::Cancel { id: 4 }.to_bytes();
        // boundary EOF after a complete frame -> Ok(None)
        let mut rd = std::io::Cursor::new(bytes.clone());
        assert!(MockFrame::read_from(&mut rd).unwrap().is_some());
        assert!(MockFrame::read_from(&mut rd).unwrap().is_none());
        // EOF mid-frame -> typed Truncated through the anyhow chain
        let mut rd = std::io::Cursor::new(bytes[..5].to_vec());
        let err = MockFrame::read_from(&mut rd).unwrap_err();
        assert!(
            err.downcast_ref::<WireError>().is_some(),
            "untyped: {err:#}"
        );
    }
}
