//! Shard planning: split an ordered task list into contiguous
//! per-engine ranges.
//!
//! The plan is **positional**: shard k covers `ranges[k]` of the task
//! list, and results are stitched back at the same positions, so the
//! merge order of the reduced moments never depends on the shard count
//! or on which engine finished first. Philox counter-range disjointness
//! is inherited from the tasks themselves — every `LaunchTask` bakes
//! its `(stream, counter base, trial)` addressing into its inputs at
//! build time, so *any* partition of the list samples disjoint counter
//! ranges; the plan only has to keep the list order intact.

use std::ops::Range;

/// Contiguous split of `n_items` tasks into at most `n_shards` ranges.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    ranges: Vec<Range<usize>>,
}

impl ShardPlan {
    /// Balanced contiguous plan: every shard gets `n_items / n_shards`
    /// tasks and the first `n_items % n_shards` shards get one extra,
    /// so shard sizes differ by at most one. Shards may be empty when
    /// there are fewer items than shards.
    pub fn contiguous(n_items: usize, n_shards: usize) -> ShardPlan {
        assert!(n_shards > 0, "shard plan needs >= 1 shard");
        let base = n_items / n_shards;
        let extra = n_items % n_shards;
        let mut ranges = Vec::with_capacity(n_shards);
        let mut start = 0;
        for k in 0..n_shards {
            let len = base + usize::from(k < extra);
            ranges.push(start..start + len);
            start += len;
        }
        ShardPlan { ranges }
    }

    pub fn n_shards(&self) -> usize {
        self.ranges.len()
    }

    pub fn n_items(&self) -> usize {
        self.ranges.last().map_or(0, |r| r.end)
    }

    pub fn range(&self, shard: usize) -> Range<usize> {
        self.ranges[shard].clone()
    }

    pub fn iter(&self) -> impl Iterator<Item = Range<usize>> + '_ {
        self.ranges.iter().cloned()
    }

    /// Non-empty shards with their original shard indices — what
    /// dispatch should iterate: an empty shard (more engines than
    /// tasks) must never become a submitted job, which for a remote
    /// engine would be a wasted round-trip per empty shard.
    pub fn nonempty(
        &self,
    ) -> impl Iterator<Item = (usize, Range<usize>)> + '_ {
        self.ranges
            .iter()
            .cloned()
            .enumerate()
            .filter(|(_, r)| !r.is_empty())
    }

    /// Largest shard size — the balance bound the scaling bench prices.
    pub fn max_shard_len(&self) -> usize {
        self.ranges.iter().map(|r| r.len()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every item covered exactly once, in order, by adjacent ranges.
    fn assert_partition(plan: &ShardPlan, n_items: usize) {
        let mut next = 0;
        for r in plan.iter() {
            assert_eq!(r.start, next, "{plan:?}");
            next = r.end;
        }
        assert_eq!(next, n_items);
        assert_eq!(plan.n_items(), n_items);
    }

    #[test]
    fn balanced_partition_for_all_small_shapes() {
        for n_items in 0..40 {
            for n_shards in 1..=8 {
                let plan = ShardPlan::contiguous(n_items, n_shards);
                assert_eq!(plan.n_shards(), n_shards);
                assert_partition(&plan, n_items);
                let lens: Vec<usize> =
                    plan.iter().map(|r| r.len()).collect();
                let (lo, hi) = (
                    lens.iter().min().unwrap(),
                    lens.iter().max().unwrap(),
                );
                assert!(hi - lo <= 1, "unbalanced {plan:?}");
                assert_eq!(plan.max_shard_len(), *hi);
            }
        }
    }

    #[test]
    fn one_shard_is_the_whole_list() {
        let plan = ShardPlan::contiguous(17, 1);
        assert_eq!(plan.range(0), 0..17);
    }

    #[test]
    fn more_shards_than_items_leaves_empties() {
        let plan = ShardPlan::contiguous(3, 8);
        let lens: Vec<usize> = plan.iter().map(|r| r.len()).collect();
        assert_eq!(lens[..3], [1, 1, 1]);
        assert!(lens[3..].iter().all(|&l| l == 0));
    }

    #[test]
    fn nonempty_skips_empties_and_keeps_indices() {
        let plan = ShardPlan::contiguous(3, 8);
        let got: Vec<(usize, Range<usize>)> = plan.nonempty().collect();
        assert_eq!(got, vec![(0, 0..1), (1, 1..2), (2, 2..3)]);
        // a full plan passes through untouched
        let plan = ShardPlan::contiguous(10, 4);
        assert_eq!(plan.nonempty().count(), 4);
        assert!(plan
            .nonempty()
            .zip(plan.iter().enumerate())
            .all(|((ka, ra), (kb, rb))| ka == kb && ra == rb));
    }

    #[test]
    #[should_panic(expected = ">= 1 shard")]
    fn zero_shards_panics() {
        ShardPlan::contiguous(4, 0);
    }
}
