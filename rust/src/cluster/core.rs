//! The cluster: N persistent engines behind one `submit()` surface.
//!
//! Each engine models one device/host — its workers and their warm
//! executable caches are private to it, exactly as in the single-engine
//! path. [`Cluster::submit`] shards the ordered task list contiguously
//! across the live engines ([`crate::cluster::plan::ShardPlan`]), fans
//! the shards out as independent engine jobs, and the returned
//! [`ClusterHandle`] stitches per-shard results back at their original
//! positions, so `wait()` yields results in task order no matter how
//! many engines ran them.
//!
//! Fault policy (the Ray node-loss model): a shard job that fails
//! because its engine **died** (every worker exited —
//! [`Engine::is_dead`]) marks that engine dead and requeues the whole
//! shard onto the next surviving engine; idempotent Philox task
//! addressing makes the rerun bit-exact. A job that fails on a *live*
//! engine (a task drained its retry budget — a deterministic error
//! would fail anywhere) surfaces its error directly, like the
//! single-engine path. Every requeue is counted on the cluster's
//! [`Metrics`] (`failure` + `retry`). With every engine dead the error
//! of the last shard surfaces to the caller.

use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Weak};

use anyhow::{anyhow, Result};

use crate::cluster::plan::ShardPlan;
use crate::coordinator::progress::Metrics;
use crate::engine::{Backend, DeviceBackend, Engine, JobHandle};
use crate::runtime::device::DevicePool;
use crate::runtime::registry::Registry;

/// One engine plus its liveness flag (cleared on shard failure).
struct EngineSlot<B: Backend> {
    engine: Engine<B>,
    alive: AtomicBool,
}

/// State shared between the cluster and its in-flight handles.
pub(crate) struct ClusterShared<B: Backend> {
    slots: Vec<EngineSlot<B>>,
    metrics: Arc<Metrics>,
}

impl<B> ClusterShared<B>
where
    B: Backend + Send + Sync + 'static,
    B::Task: Clone + Send + Sync + 'static,
    B::Out: Send + 'static,
{
    fn alive_indices(&self) -> Vec<usize> {
        (0..self.slots.len())
            .filter(|&i| self.slots[i].alive.load(Ordering::Relaxed))
            .collect()
    }

    fn mark_dead(&self, i: usize) {
        self.slots[i].alive.store(false, Ordering::Relaxed);
    }

    /// Submit `tasks` to the first live engine at or after `preferred`
    /// (wrapping); an engine whose submit fails synchronously is marked
    /// dead and skipped, counted on the cluster metrics exactly like a
    /// mid-round death (`failure` for the engine, `retry` for moving
    /// the shard on). Errors when no live engine accepts the shard.
    fn submit_to_alive(
        &self,
        tasks: &[B::Task],
        preferred: usize,
        max_retries: u32,
    ) -> Result<(usize, JobHandle<B::Task, B::Out>)> {
        let n = self.slots.len();
        let mut last_err: Option<anyhow::Error> = None;
        for off in 0..n {
            let i = (preferred + off) % n;
            let slot = &self.slots[i];
            if !slot.alive.load(Ordering::Relaxed) {
                continue;
            }
            match slot.engine.submit_with_retries(tasks.to_vec(), max_retries)
            {
                Ok(h) => return Ok((i, h)),
                Err(e) => {
                    slot.alive.store(false, Ordering::Relaxed);
                    self.metrics.failure();
                    self.metrics.retry();
                    last_err = Some(e);
                }
            }
        }
        Err(match last_err {
            Some(e) => e.context("no live engines left in the cluster"),
            None => anyhow!("no live engines left in the cluster"),
        })
    }
}

/// A pool of N persistent engines with centralized shard planning and
/// result reduction. A 1-engine cluster is the plain engine path: one
/// shard covering the whole task list, no extra merge step.
pub struct Cluster<B: Backend> {
    shared: Arc<ClusterShared<B>>,
    default_retries: u32,
}

impl<B> Cluster<B>
where
    B: Backend + Send + Sync + 'static,
    B::Task: Clone + Send + Sync + 'static,
    B::Out: Send + 'static,
{
    /// Assemble a cluster from already-spawned engines (each brings its
    /// own fault plan and per-engine metrics).
    pub fn from_engines(engines: Vec<Engine<B>>) -> Result<Cluster<B>> {
        Cluster::with_metrics(engines, Arc::new(Metrics::new()))
    }

    /// [`Cluster::from_engines`] with an explicit cluster-level metrics
    /// sink; shard requeues are recorded here (the engines keep their
    /// own in-engine retry counts).
    pub fn with_metrics(
        engines: Vec<Engine<B>>,
        metrics: Arc<Metrics>,
    ) -> Result<Cluster<B>> {
        if engines.is_empty() {
            return Err(anyhow!("cluster needs >= 1 engine"));
        }
        let slots = engines
            .into_iter()
            .map(|engine| EngineSlot { engine, alive: AtomicBool::new(true) })
            .collect();
        Ok(Cluster {
            shared: Arc::new(ClusterShared { slots, metrics }),
            default_retries: 3,
        })
    }

    pub fn n_engines(&self) -> usize {
        self.shared.slots.len()
    }

    /// Engines not yet marked dead by a shard failure.
    pub fn n_alive(&self) -> usize {
        self.shared.alive_indices().len()
    }

    /// Cluster-level metrics: shard requeue failures/retries.
    pub fn metrics(&self) -> &Metrics {
        &self.shared.metrics
    }

    pub fn engine(&self, i: usize) -> &Engine<B> {
        &self.shared.slots[i].engine
    }

    /// Shard `tasks` across the live engines and fan them out; returns
    /// immediately with the stitching handle.
    pub fn submit(&self, tasks: Vec<B::Task>) -> Result<ClusterHandle<B>> {
        self.submit_with_retries(tasks, self.default_retries)
    }

    /// `submit` with an explicit per-shard-job retry budget (passed
    /// through to each engine).
    pub fn submit_with_retries(
        &self,
        tasks: Vec<B::Task>,
        max_retries: u32,
    ) -> Result<ClusterHandle<B>> {
        let alive = self.shared.alive_indices();
        if alive.is_empty() {
            return Err(anyhow!("no live engines left in the cluster"));
        }
        let plan = ShardPlan::contiguous(tasks.len(), alive.len());
        let mut shards = Vec::new();
        for (k, range) in plan.iter().enumerate() {
            if range.is_empty() {
                continue;
            }
            let (engine, handle) = self.shared.submit_to_alive(
                &tasks[range.clone()],
                alive[k],
                max_retries,
            )?;
            shards.push(ShardState { range, engine, handle: Some(handle) });
        }
        Ok(ClusterHandle {
            tasks,
            shards,
            shared: Arc::downgrade(&self.shared),
            max_retries,
        })
    }

    /// Synchronous convenience: submit then wait.
    pub fn run(&self, tasks: Vec<B::Task>) -> Result<Vec<B::Out>> {
        self.submit(tasks)?.wait()
    }
}

/// One in-flight shard: its task range, the engine currently running
/// it, and the engine job handle.
struct ShardState<B: Backend> {
    range: Range<usize>,
    engine: usize,
    handle: Option<JobHandle<B::Task, B::Out>>,
}

/// Handle to one sharded submission. `wait()` awaits the shards in
/// order, requeues any shard whose engine died onto a survivor, and
/// returns results at their original task positions — the same
/// contract as the single engine's [`JobHandle`]. Dropping the handle
/// un-awaited cancels every outstanding shard job (each engine purges
/// its queue), exactly like dropping a `JobHandle`.
pub struct ClusterHandle<B: Backend> {
    /// The full ordered task list, retained so a failed shard can be
    /// requeued verbatim (tasks are idempotent: Philox addressing is
    /// baked into each one). This is the price of requeueability: task
    /// payloads exist twice while a job is in flight (here and in the
    /// engines' job state). Sharing them instead would need the engine
    /// job state to hold ranges of an `Arc<[Task]>` — worth doing if
    /// launch payloads ever grow beyond their current few KB.
    tasks: Vec<B::Task>,
    shards: Vec<ShardState<B>>,
    shared: Weak<ClusterShared<B>>,
    max_retries: u32,
}

impl<B> ClusterHandle<B>
where
    B: Backend + Send + Sync + 'static,
    B::Task: Clone + Send + Sync + 'static,
    B::Out: Send + 'static,
{
    /// Block until every shard landed; results in task order. A shard
    /// whose **engine died** is requeued onto the next surviving engine
    /// (whole-shard rerun — exact, because tasks are idempotent); a
    /// shard job that failed on a *healthy* engine (a task drained its
    /// retry budget) surfaces its error directly, exactly like the
    /// single-engine path — rerunning a deterministic failure elsewhere
    /// would only cascade-kill the cluster. The requeue error surfaces
    /// only when no engine is left to take the shard.
    pub fn wait(mut self) -> Result<Vec<B::Out>> {
        let n = self.tasks.len();
        let mut results: Vec<Option<B::Out>> =
            (0..n).map(|_| None).collect();
        for s in self.shards.iter_mut() {
            let mut handle =
                s.handle.take().expect("unawaited shard has a handle");
            let outs = loop {
                match handle.wait() {
                    Ok(outs) => break outs,
                    Err(err) => {
                        let shared = self.shared.upgrade().ok_or_else(
                            || {
                                anyhow!(
                                    "cluster dropped with shards in flight"
                                )
                            },
                        )?;
                        // engine alive ⇒ the job itself failed (task
                        // error past its retry budget): not a placement
                        // problem, so don't burn the other engines on it
                        if !shared.slots[s.engine].engine.is_dead() {
                            return Err(err.context(format!(
                                "shard {:?} failed on live engine {}",
                                s.range, s.engine
                            )));
                        }
                        shared.mark_dead(s.engine);
                        shared.metrics.failure();
                        let (engine, h) = shared
                            .submit_to_alive(
                                &self.tasks[s.range.clone()],
                                s.engine + 1,
                                self.max_retries,
                            )
                            .map_err(|e| {
                                e.context(format!(
                                    "no live engines left to requeue \
                                     shard {:?} (engine {} failed: \
                                     {err})",
                                    s.range, s.engine
                                ))
                            })?;
                        shared.metrics.retry();
                        s.engine = engine;
                        handle = h;
                    }
                }
            };
            for (slot, out) in
                results[s.range.clone()].iter_mut().zip(outs)
            {
                *slot = Some(out);
            }
        }
        Ok(results
            .into_iter()
            .map(|r| r.expect("every shard covers its range"))
            .collect())
    }

    /// Non-blocking completion probe across all shards.
    pub fn is_done(&self) -> bool {
        self.shards
            .iter()
            .all(|s| s.handle.as_ref().map_or(true, |h| h.is_done()))
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Shards this submission was planned into (empty ranges skipped).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Cancel outstanding shard jobs (identical to dropping the handle
    /// un-awaited: each engine purges its queued tasks).
    pub fn cancel(self) {
        drop(self);
    }
}

/// The cluster every integrator runs on in production.
pub type DeviceCluster = Cluster<DeviceBackend>;

impl Cluster<DeviceBackend> {
    /// N engines over the same artifact registry, each with the pool's
    /// worker topology (`pool.n_devices` workers per engine) — one
    /// engine per device/host of the paper's cluster.
    pub fn for_pool(pool: &DevicePool, n_engines: usize) -> Result<Self> {
        let engines = (0..n_engines.max(1))
            .map(|_| Engine::for_pool(pool))
            .collect::<Result<Vec<_>>>()?;
        Cluster::from_engines(engines)
    }

    /// The artifact registry the cluster's engines execute from.
    pub fn registry(&self) -> &Registry {
        self.shared.slots[0].engine.registry()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fault::FaultPlan;
    use crate::engine::EngineConfig;

    struct Mock;

    impl Backend for Mock {
        type Ctx = ();
        type Task = u64;
        type Out = u64;

        fn make_ctx(&self, _w: usize) -> Result<()> {
            Ok(())
        }

        fn run(&self, _ctx: &(), t: &u64) -> Result<u64> {
            Ok(t.wrapping_mul(31).wrapping_add(7))
        }
    }

    fn expect(tasks: &[u64]) -> Vec<u64> {
        tasks.iter().map(|t| t.wrapping_mul(31).wrapping_add(7)).collect()
    }

    fn mock_cluster(n_engines: usize) -> Cluster<Mock> {
        let engines = (0..n_engines)
            .map(|_| Engine::new(Mock, EngineConfig::new(1)).unwrap())
            .collect();
        Cluster::from_engines(engines).unwrap()
    }

    #[test]
    fn results_in_task_order_for_any_engine_count() {
        let tasks: Vec<u64> = (0..97).collect();
        for n in [1, 2, 3, 5, 8] {
            let c = mock_cluster(n);
            let out = c.run(tasks.clone()).unwrap();
            assert_eq!(out, expect(&tasks), "n_engines={n}");
        }
    }

    #[test]
    fn empty_and_tiny_submissions() {
        let c = mock_cluster(4);
        assert!(c.run(vec![]).unwrap().is_empty());
        // fewer tasks than engines: empty shards are skipped
        let h = c.submit(vec![1, 2]).unwrap();
        assert_eq!(h.n_shards(), 2);
        assert_eq!(h.wait().unwrap(), expect(&[1, 2]));
    }

    #[test]
    fn rejects_zero_engines() {
        assert!(Cluster::<Mock>::from_engines(vec![]).is_err());
    }

    #[test]
    fn dead_engine_shard_requeued_onto_survivor() {
        // engine 1 dies on its first pull; its shard must migrate
        let metrics = Arc::new(Metrics::new());
        let engines = vec![
            Engine::new(Mock, EngineConfig::new(1)).unwrap(),
            Engine::with_policy(
                Mock,
                EngineConfig::new(1),
                Arc::new(FaultPlan::kill(0, 0)),
                Arc::new(Metrics::new()),
            )
            .unwrap(),
        ];
        let c = Cluster::with_metrics(engines, Arc::clone(&metrics)).unwrap();
        let tasks: Vec<u64> = (0..40).collect();
        let out = c.run(tasks.clone()).unwrap();
        assert_eq!(out, expect(&tasks));
        assert_eq!(c.n_alive(), 1);
        assert!(metrics.retried() >= 1, "{}", metrics.summary());
        assert_eq!(metrics.retried(), metrics.failed());
    }

    #[test]
    fn all_engines_dead_surfaces_the_error() {
        let engines = (0..2)
            .map(|_| {
                Engine::with_policy(
                    Mock,
                    EngineConfig::new(1),
                    Arc::new(FaultPlan::kill(0, 0)),
                    Arc::new(Metrics::new()),
                )
                .unwrap()
            })
            .collect();
        let c = Cluster::from_engines(engines).unwrap();
        let err = match c.submit((0..10).collect()) {
            Ok(h) => h.wait().unwrap_err(),
            // both engines may already be dead at submit time
            Err(e) => e,
        };
        assert!(
            err.to_string().contains("no live engines"),
            "unexpected error: {err}"
        );
        assert_eq!(c.n_alive(), 0);
    }

    /// A task that fails deterministically (backend error, not worker
    /// death) must surface once, not cascade-kill every engine.
    struct BadThirteen;

    impl Backend for BadThirteen {
        type Ctx = ();
        type Task = u64;
        type Out = u64;

        fn make_ctx(&self, _w: usize) -> Result<()> {
            Ok(())
        }

        fn run(&self, _ctx: &(), t: &u64) -> Result<u64> {
            if *t == 13 {
                Err(anyhow!("bad artifact"))
            } else {
                Ok(*t)
            }
        }
    }

    #[test]
    fn task_failure_on_live_engine_does_not_cascade() {
        let engines = (0..3)
            .map(|_| {
                Engine::new(
                    BadThirteen,
                    EngineConfig { n_workers: 1, max_retries: 1 },
                )
                .unwrap()
            })
            .collect();
        let c = Cluster::from_engines(engines).unwrap();
        // task 13 lands in shard [10..20] on engine 1 and fails there
        // past its retry budget while the engine's worker stays alive
        let err = c.run((0..30).collect()).unwrap_err();
        assert!(err.to_string().contains("live engine"), "{err}");
        // no engine was blamed; the cluster still serves good batches
        assert_eq!(c.n_alive(), 3);
        assert_eq!(c.metrics().retried(), 0);
        let ok: Vec<u64> = (20..40).collect();
        assert_eq!(c.run(ok.clone()).unwrap(), ok);
    }

    #[test]
    fn drop_unawaited_cancels_all_shards() {
        let c = mock_cluster(3);
        let h = c.submit((0..50).collect()).unwrap();
        assert_eq!(h.n_tasks(), 50);
        drop(h); // each shard's JobHandle cancels its engine job
        let h2 = c.submit((0..6).collect()).unwrap();
        assert_eq!(h2.wait().unwrap().len(), 6);
    }
}
