//! The cluster: N persistent engine nodes behind one `submit()`
//! surface.
//!
//! A node is either a **local** [`Engine`] (one device/host in this
//! process — its workers and their warm executable caches are private
//! to it) or a **remote** [`RemoteEngine`] proxy for an engine hosted
//! by a `zmc worker` process on another machine, reached over the
//! cluster wire protocol. The two are interchangeable here: sharding
//! is placement-free (every task bakes its Philox counter range into
//! its inputs), so [`Cluster::submit`] shards the ordered task list
//! contiguously across the live nodes
//! ([`crate::cluster::plan::ShardPlan`]), fans the non-empty shards
//! out as independent node jobs, and the returned [`ClusterHandle`]
//! stitches per-shard results back at their original positions —
//! `wait()` yields results in task order no matter how many nodes of
//! either kind ran them.
//!
//! Fault policy (the Ray node-loss model): a shard job that fails
//! because its node **died** (every worker exited — [`Engine::is_dead`]
//! — or the remote connection closed / heartbeat timed out —
//! [`RemoteEngine::is_dead`]) marks that node dead and requeues the
//! whole shard onto the next surviving node; idempotent Philox task
//! addressing makes the rerun bit-exact. A job that fails on a *live*
//! node (a task drained its retry budget — a deterministic error
//! would fail anywhere) surfaces its error directly, like the
//! single-engine path. Every requeue is counted on the cluster's
//! [`Metrics`] (`failure` + `retry`). With every node dead the error
//! of the last shard surfaces to the caller.

use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Weak};

use anyhow::{anyhow, Result};

use crate::cluster::plan::ShardPlan;
use crate::cluster::remote::{RemoteConfig, RemoteEngine, RemoteHandle};
use crate::cluster::wire::Wire;
use crate::coordinator::progress::Metrics;
use crate::engine::{Backend, DeviceBackend, Engine, JobHandle};
use crate::runtime::device::DevicePool;
use crate::runtime::registry::Registry;

/// One cluster node: a local engine or a remote proxy. Everything the
/// cluster needs from a node — submit a task batch, probe death — is
/// identical across the two, so shard planning and requeue never look
/// inside.
enum Node<B: Backend> {
    Local(Engine<B>),
    Remote(RemoteEngine<B::Task, B::Out>),
}

impl<B> Node<B>
where
    B: Backend + Send + Sync + 'static,
    B::Task: Wire + Clone + Send + Sync + 'static,
    B::Out: Wire + Send + 'static,
{
    fn submit_with_retries(
        &self,
        tasks: Vec<B::Task>,
        max_retries: u32,
    ) -> Result<NodeHandle<B::Task, B::Out>> {
        match self {
            Node::Local(e) => Ok(NodeHandle::Local(
                e.submit_with_retries(tasks, max_retries)?,
            )),
            Node::Remote(r) => Ok(NodeHandle::Remote(
                r.submit_with_retries(tasks, max_retries)?,
            )),
        }
    }

    fn is_dead(&self) -> bool {
        match self {
            Node::Local(e) => e.is_dead(),
            Node::Remote(r) => r.is_dead(),
        }
    }
}

/// Handle to one shard job on either node kind.
enum NodeHandle<T, R> {
    Local(JobHandle<T, R>),
    Remote(RemoteHandle<R>),
}

impl<T, R> NodeHandle<T, R> {
    fn wait(self) -> Result<Vec<R>> {
        match self {
            NodeHandle::Local(h) => h.wait(),
            NodeHandle::Remote(h) => h.wait(),
        }
    }

    fn is_done(&self) -> bool {
        match self {
            NodeHandle::Local(h) => h.is_done(),
            NodeHandle::Remote(h) => h.is_done(),
        }
    }
}

/// One node plus its liveness flag (cleared on shard failure).
struct NodeSlot<B: Backend> {
    node: Node<B>,
    alive: AtomicBool,
}

/// State shared between the cluster and its in-flight handles.
pub(crate) struct ClusterShared<B: Backend> {
    slots: Vec<NodeSlot<B>>,
    metrics: Arc<Metrics>,
}

impl<B> ClusterShared<B>
where
    B: Backend + Send + Sync + 'static,
    B::Task: Wire + Clone + Send + Sync + 'static,
    B::Out: Wire + Send + 'static,
{
    /// Live node indices. A slot marked dead whose node reports
    /// healthy again — a remote proxy revived by its reconnect
    /// supervisor; local engines never recover — is flipped back
    /// alive here, so the next submission's shard plan includes the
    /// rejoined host.
    fn alive_indices(&self) -> Vec<usize> {
        (0..self.slots.len())
            .filter(|&i| {
                let slot = &self.slots[i];
                if slot.alive.load(Ordering::Relaxed) {
                    return true;
                }
                if !slot.node.is_dead() {
                    slot.alive.store(true, Ordering::Relaxed);
                    return true;
                }
                false
            })
            .collect()
    }

    fn mark_dead(&self, i: usize) {
        self.slots[i].alive.store(false, Ordering::Relaxed);
    }

    /// Submit `tasks` to the first live node at or after `preferred`
    /// (wrapping); a node whose submit fails synchronously is marked
    /// dead and skipped, counted on the cluster metrics exactly like a
    /// mid-round death (`failure` for the node, `retry` for moving
    /// the shard on). Errors when no live node accepts the shard.
    fn submit_to_alive(
        &self,
        tasks: &[B::Task],
        preferred: usize,
        max_retries: u32,
    ) -> Result<(usize, NodeHandle<B::Task, B::Out>)> {
        let n = self.slots.len();
        let mut last_err: Option<anyhow::Error> = None;
        for off in 0..n {
            let i = (preferred + off) % n;
            let slot = &self.slots[i];
            if !slot.alive.load(Ordering::Relaxed) {
                continue;
            }
            match slot.node.submit_with_retries(tasks.to_vec(), max_retries)
            {
                Ok(h) => return Ok((i, h)),
                Err(e) => {
                    slot.alive.store(false, Ordering::Relaxed);
                    self.metrics.failure();
                    self.metrics.retry();
                    last_err = Some(e);
                }
            }
        }
        Err(match last_err {
            Some(e) => e.context("no live engines left in the cluster"),
            None => anyhow!("no live engines left in the cluster"),
        })
    }
}

/// A pool of N persistent engine nodes (local and/or remote) with
/// centralized shard planning and result reduction. A 1-node cluster
/// is the plain engine path: one shard covering the whole task list,
/// no extra merge step.
pub struct Cluster<B: Backend> {
    shared: Arc<ClusterShared<B>>,
    default_retries: u32,
    /// Artifact registry for device clusters whose nodes may all be
    /// remote (a remote node carries no local registry handle);
    /// `None` for generic/mock clusters and when a local engine can
    /// answer instead.
    registry: Option<Arc<Registry>>,
}

impl<B> Cluster<B>
where
    B: Backend + Send + Sync + 'static,
    B::Task: Wire + Clone + Send + Sync + 'static,
    B::Out: Wire + Send + 'static,
{
    /// Assemble a cluster from already-spawned local engines (each
    /// brings its own fault plan and per-engine metrics).
    pub fn from_engines(engines: Vec<Engine<B>>) -> Result<Cluster<B>> {
        Cluster::with_metrics(engines, Arc::new(Metrics::new()))
    }

    /// [`Cluster::from_engines`] with an explicit cluster-level metrics
    /// sink; shard requeues are recorded here (the engines keep their
    /// own in-engine retry counts).
    pub fn with_metrics(
        engines: Vec<Engine<B>>,
        metrics: Arc<Metrics>,
    ) -> Result<Cluster<B>> {
        Cluster::with_remotes(engines, Vec::new(), metrics)
    }

    /// Assemble a mixed cluster: local engines first, then remote
    /// proxies. Either list may be empty, but not both — a pure-remote
    /// cluster is how a coordinator host with no device of its own
    /// drives a fleet of `zmc worker` machines.
    pub fn with_remotes(
        engines: Vec<Engine<B>>,
        remotes: Vec<RemoteEngine<B::Task, B::Out>>,
        metrics: Arc<Metrics>,
    ) -> Result<Cluster<B>> {
        if engines.is_empty() && remotes.is_empty() {
            return Err(anyhow!("cluster needs >= 1 engine"));
        }
        // locals first: `engine(i)` keeps indexing local engines and
        // shard placement prefers in-process nodes for small plans
        let slots = engines
            .into_iter()
            .map(Node::Local)
            .chain(remotes.into_iter().map(Node::Remote))
            .map(|node| NodeSlot { node, alive: AtomicBool::new(true) })
            .collect();
        Ok(Cluster {
            shared: Arc::new(ClusterShared { slots, metrics }),
            default_retries: 3,
            registry: None,
        })
    }

    /// Total nodes, local + remote.
    pub fn n_engines(&self) -> usize {
        self.shared.slots.len()
    }

    /// Local in-process engines (stored before any remotes).
    pub fn n_local(&self) -> usize {
        self.shared
            .slots
            .iter()
            .filter(|s| matches!(s.node, Node::Local(_)))
            .count()
    }

    /// Remote worker connections.
    pub fn n_remote(&self) -> usize {
        self.n_engines() - self.n_local()
    }

    /// Nodes not yet marked dead by a shard failure.
    pub fn n_alive(&self) -> usize {
        self.shared.alive_indices().len()
    }

    /// Cluster-level metrics: shard requeue failures/retries.
    pub fn metrics(&self) -> &Metrics {
        &self.shared.metrics
    }

    /// The i-th **local** engine (locals occupy the low indices).
    /// Panics on a remote node's index — remote engines expose no
    /// in-process surface beyond submit.
    pub fn engine(&self, i: usize) -> &Engine<B> {
        match &self.shared.slots[i].node {
            Node::Local(e) => e,
            Node::Remote(r) => panic!(
                "cluster node {i} is remote ({}); only local engines \
                 can be borrowed",
                r.peer()
            ),
        }
    }

    /// Shard `tasks` across the live nodes and fan them out; returns
    /// immediately with the stitching handle.
    pub fn submit(&self, tasks: Vec<B::Task>) -> Result<ClusterHandle<B>> {
        self.submit_with_retries(tasks, self.default_retries)
    }

    /// `submit` with an explicit per-shard-job retry budget (passed
    /// through to each node's engine).
    pub fn submit_with_retries(
        &self,
        tasks: Vec<B::Task>,
        max_retries: u32,
    ) -> Result<ClusterHandle<B>> {
        let alive = self.shared.alive_indices();
        if alive.is_empty() {
            return Err(anyhow!("no live engines left in the cluster"));
        }
        let plan = ShardPlan::contiguous(tasks.len(), alive.len());
        let mut shards = Vec::new();
        // empty shards (more nodes than tasks) are skipped at dispatch:
        // shipping a zero-task job to a remote node would be a wasted
        // round-trip, and even locally it is a pointless queue cycle
        for (k, range) in plan.nonempty() {
            let (node, handle) = self.shared.submit_to_alive(
                &tasks[range.clone()],
                alive[k],
                max_retries,
            )?;
            shards.push(ShardState { range, node, handle: Some(handle) });
        }
        Ok(ClusterHandle {
            tasks,
            shards,
            shared: Arc::downgrade(&self.shared),
            max_retries,
        })
    }

    /// Synchronous convenience: submit then wait.
    pub fn run(&self, tasks: Vec<B::Task>) -> Result<Vec<B::Out>> {
        self.submit(tasks)?.wait()
    }
}

/// One in-flight shard: its task range, the node currently running
/// it, and the node job handle.
struct ShardState<B: Backend> {
    range: Range<usize>,
    node: usize,
    handle: Option<NodeHandle<B::Task, B::Out>>,
}

/// Handle to one sharded submission. `wait()` awaits the shards in
/// order, requeues any shard whose node died onto a survivor, and
/// returns results at their original task positions — the same
/// contract as the single engine's [`JobHandle`]. Dropping the handle
/// un-awaited cancels every outstanding shard job (each engine purges
/// its queue; remote nodes are sent a best-effort cancel frame),
/// exactly like dropping a `JobHandle`.
pub struct ClusterHandle<B: Backend> {
    /// The full ordered task list, retained so a failed shard can be
    /// requeued verbatim (tasks are idempotent: Philox addressing is
    /// baked into each one). This is the price of requeueability: task
    /// payloads exist twice while a job is in flight (here and in the
    /// engines' job state). Sharing them instead would need the engine
    /// job state to hold ranges of an `Arc<[Task]>` — worth doing if
    /// launch payloads ever grow beyond their current few KB.
    tasks: Vec<B::Task>,
    shards: Vec<ShardState<B>>,
    shared: Weak<ClusterShared<B>>,
    max_retries: u32,
}

impl<B> ClusterHandle<B>
where
    B: Backend + Send + Sync + 'static,
    B::Task: Wire + Clone + Send + Sync + 'static,
    B::Out: Wire + Send + 'static,
{
    /// Block until every shard landed; results in task order. A shard
    /// whose **node died** is requeued onto the next surviving node
    /// (whole-shard rerun — exact, because tasks are idempotent); a
    /// shard job that failed on a *healthy* node (a task drained its
    /// retry budget) surfaces its error directly, exactly like the
    /// single-engine path — rerunning a deterministic failure elsewhere
    /// would only cascade-kill the cluster. The requeue error surfaces
    /// only when no node is left to take the shard.
    pub fn wait(mut self) -> Result<Vec<B::Out>> {
        let n = self.tasks.len();
        let mut results: Vec<Option<B::Out>> =
            (0..n).map(|_| None).collect();
        for s in self.shards.iter_mut() {
            let outs = Self::resolve_shard(
                &self.shared,
                &self.tasks,
                self.max_retries,
                s,
            )?;
            for (slot, out) in
                results[s.range.clone()].iter_mut().zip(outs)
            {
                *slot = Some(out);
            }
        }
        Ok(results
            .into_iter()
            .map(|r| r.expect("every shard covers its range"))
            .collect())
    }

    /// Stream results to `sink` **in task order** as shards complete,
    /// without accumulating the full result vector: each shard's
    /// outputs are flushed (and freed) before the next shard is
    /// awaited, so peak memory is O(largest shard), not O(batch).
    /// Shard ranges are contiguous and ascending
    /// ([`ShardPlan::contiguous`]), so flushing shards in order yields
    /// exactly the task order `wait()` returns — the fold is
    /// bit-identical. Dead-node requeue behaves exactly as in
    /// [`ClusterHandle::wait`]; on error the caller should discard its
    /// partial fold.
    pub fn wait_each(
        mut self,
        sink: &mut dyn FnMut(B::Out),
    ) -> Result<()> {
        let mut shards = std::mem::take(&mut self.shards);
        for s in shards.iter_mut() {
            let outs = Self::resolve_shard(
                &self.shared,
                &self.tasks,
                self.max_retries,
                s,
            )?;
            for out in outs {
                sink(out);
            }
        }
        Ok(())
    }

    /// Await one shard, requeueing it across surviving nodes until it
    /// lands or no node is left (the shared fault policy of `wait` /
    /// `wait_each`; see [`ClusterHandle::wait`] for the rationale).
    fn resolve_shard(
        shared: &Weak<ClusterShared<B>>,
        tasks: &[B::Task],
        max_retries: u32,
        s: &mut ShardState<B>,
    ) -> Result<Vec<B::Out>> {
        let mut handle =
            s.handle.take().expect("unawaited shard has a handle");
        loop {
            match handle.wait() {
                Ok(outs) => return Ok(outs),
                Err(err) => {
                    let shared = shared.upgrade().ok_or_else(|| {
                        anyhow!("cluster dropped with shards in flight")
                    })?;
                    // node alive ⇒ the job itself failed (task
                    // error past its retry budget): not a placement
                    // problem, so don't burn the other nodes on it
                    if !shared.slots[s.node].node.is_dead() {
                        return Err(err.context(format!(
                            "shard {:?} failed on live engine {}",
                            s.range, s.node
                        )));
                    }
                    shared.mark_dead(s.node);
                    shared.metrics.failure();
                    let (node, h) = shared
                        .submit_to_alive(
                            &tasks[s.range.clone()],
                            s.node + 1,
                            max_retries,
                        )
                        .map_err(|e| {
                            e.context(format!(
                                "no live engines left to requeue \
                                 shard {:?} (engine {} failed: \
                                 {err})",
                                s.range, s.node
                            ))
                        })?;
                    shared.metrics.retry();
                    s.node = node;
                    handle = h;
                }
            }
        }
    }

    /// Non-blocking completion probe across all shards.
    pub fn is_done(&self) -> bool {
        self.shards
            .iter()
            .all(|s| s.handle.as_ref().map_or(true, |h| h.is_done()))
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Shards this submission was planned into (empty ranges skipped).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Cancel outstanding shard jobs (identical to dropping the handle
    /// un-awaited: each engine purges its queued tasks).
    pub fn cancel(self) {
        drop(self);
    }
}

/// The cluster every integrator runs on in production.
pub type DeviceCluster = Cluster<DeviceBackend>;

impl Cluster<DeviceBackend> {
    /// N local engines over the same artifact registry, each with the
    /// pool's worker topology (`pool.n_devices` workers per engine) —
    /// one engine per device of the paper's single-host cluster.
    pub fn for_pool(pool: &DevicePool, n_engines: usize) -> Result<Self> {
        Self::for_pool_with_remotes(pool, n_engines.max(1), &[])
    }

    /// `n_local` in-process engines plus one remote proxy per address
    /// in `remotes` (`host:port` of a running `zmc worker`), with
    /// default transport tuning. `n_local` may be 0 when at least one
    /// remote is given.
    pub fn for_pool_with_remotes(
        pool: &DevicePool,
        n_local: usize,
        remotes: &[String],
    ) -> Result<Self> {
        Self::for_pool_with_remote_config(
            pool,
            n_local,
            remotes,
            RemoteConfig::default(),
        )
    }

    /// [`Cluster::for_pool_with_remotes`] with explicit transport
    /// tuning (tests shorten the heartbeat to fail fast).
    pub fn for_pool_with_remote_config(
        pool: &DevicePool,
        n_local: usize,
        remotes: &[String],
        rcfg: RemoteConfig,
    ) -> Result<Self> {
        let metrics = Arc::new(Metrics::new());
        let engines = (0..n_local)
            .map(|_| Engine::for_pool(pool))
            .collect::<Result<Vec<_>>>()?;
        // every production connect proves artifact parity: the Hello
        // digest comes from the pool's registry unless the caller
        // already pinned one
        let rcfg = if rcfg.digest == 0 {
            RemoteConfig { digest: pool.registry.digest(), ..rcfg }
        } else {
            rcfg
        };
        let proxies = remotes
            .iter()
            .map(|addr| {
                RemoteEngine::connect_with_metrics(
                    addr,
                    rcfg.clone(),
                    Arc::clone(&metrics),
                )
            })
            .collect::<Result<Vec<_>>>()?;
        let mut cluster =
            Cluster::with_remotes(engines, proxies, metrics)?;
        // remote nodes carry no registry handle, so the cluster keeps
        // its own: LaunchExec::registry works even when all-remote
        cluster.registry = Some(Arc::clone(&pool.registry));
        Ok(cluster)
    }

    /// The artifact registry the cluster's tasks are built against.
    pub fn registry(&self) -> &Registry {
        if let Some(r) = &self.registry {
            return r;
        }
        for slot in &self.shared.slots {
            if let Node::Local(e) = &slot.node {
                return e.registry();
            }
        }
        unreachable!(
            "cluster built without a registry and without local engines"
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fault::FaultPlan;
    use crate::engine::EngineConfig;

    struct Mock;

    impl Backend for Mock {
        type Ctx = ();
        type Task = u64;
        type Out = u64;

        fn make_ctx(&self, _w: usize) -> Result<()> {
            Ok(())
        }

        fn run(&self, _ctx: &(), t: &u64) -> Result<u64> {
            Ok(t.wrapping_mul(31).wrapping_add(7))
        }
    }

    fn expect(tasks: &[u64]) -> Vec<u64> {
        tasks.iter().map(|t| t.wrapping_mul(31).wrapping_add(7)).collect()
    }

    fn mock_cluster(n_engines: usize) -> Cluster<Mock> {
        let engines = (0..n_engines)
            .map(|_| Engine::new(Mock, EngineConfig::new(1)).unwrap())
            .collect();
        Cluster::from_engines(engines).unwrap()
    }

    #[test]
    fn results_in_task_order_for_any_engine_count() {
        let tasks: Vec<u64> = (0..97).collect();
        for n in [1, 2, 3, 5, 8] {
            let c = mock_cluster(n);
            let out = c.run(tasks.clone()).unwrap();
            assert_eq!(out, expect(&tasks), "n_engines={n}");
        }
    }

    #[test]
    fn empty_and_tiny_submissions() {
        let c = mock_cluster(4);
        assert!(c.run(vec![]).unwrap().is_empty());
        // fewer tasks than engines: empty shards are skipped
        let h = c.submit(vec![1, 2]).unwrap();
        assert_eq!(h.n_shards(), 2);
        assert_eq!(h.wait().unwrap(), expect(&[1, 2]));
    }

    #[test]
    fn rejects_zero_engines() {
        assert!(Cluster::<Mock>::from_engines(vec![]).is_err());
    }

    #[test]
    fn dead_engine_shard_requeued_onto_survivor() {
        // engine 1 dies on its first pull; its shard must migrate
        let metrics = Arc::new(Metrics::new());
        let engines = vec![
            Engine::new(Mock, EngineConfig::new(1)).unwrap(),
            Engine::with_policy(
                Mock,
                EngineConfig::new(1),
                Arc::new(FaultPlan::kill(0, 0)),
                Arc::new(Metrics::new()),
            )
            .unwrap(),
        ];
        let c = Cluster::with_metrics(engines, Arc::clone(&metrics)).unwrap();
        let tasks: Vec<u64> = (0..40).collect();
        let out = c.run(tasks.clone()).unwrap();
        assert_eq!(out, expect(&tasks));
        assert_eq!(c.n_alive(), 1);
        assert!(metrics.retried() >= 1, "{}", metrics.summary());
        assert_eq!(metrics.retried(), metrics.failed());
    }

    #[test]
    fn all_engines_dead_surfaces_the_error() {
        let engines = (0..2)
            .map(|_| {
                Engine::with_policy(
                    Mock,
                    EngineConfig::new(1),
                    Arc::new(FaultPlan::kill(0, 0)),
                    Arc::new(Metrics::new()),
                )
                .unwrap()
            })
            .collect();
        let c = Cluster::from_engines(engines).unwrap();
        let err = match c.submit((0..10).collect()) {
            Ok(h) => h.wait().unwrap_err(),
            // both engines may already be dead at submit time
            Err(e) => e,
        };
        assert!(
            err.to_string().contains("no live engines"),
            "unexpected error: {err}"
        );
        assert_eq!(c.n_alive(), 0);
    }

    /// A task that fails deterministically (backend error, not worker
    /// death) must surface once, not cascade-kill every engine.
    struct BadThirteen;

    impl Backend for BadThirteen {
        type Ctx = ();
        type Task = u64;
        type Out = u64;

        fn make_ctx(&self, _w: usize) -> Result<()> {
            Ok(())
        }

        fn run(&self, _ctx: &(), t: &u64) -> Result<u64> {
            if *t == 13 {
                Err(anyhow!("bad artifact"))
            } else {
                Ok(*t)
            }
        }
    }

    #[test]
    fn task_failure_on_live_engine_does_not_cascade() {
        let engines = (0..3)
            .map(|_| {
                Engine::new(
                    BadThirteen,
                    EngineConfig { n_workers: 1, max_retries: 1 },
                )
                .unwrap()
            })
            .collect();
        let c = Cluster::from_engines(engines).unwrap();
        // task 13 lands in shard [10..20] on engine 1 and fails there
        // past its retry budget while the engine's worker stays alive
        let err = c.run((0..30).collect()).unwrap_err();
        assert!(err.to_string().contains("live engine"), "{err}");
        // no engine was blamed; the cluster still serves good batches
        assert_eq!(c.n_alive(), 3);
        assert_eq!(c.metrics().retried(), 0);
        let ok: Vec<u64> = (20..40).collect();
        assert_eq!(c.run(ok.clone()).unwrap(), ok);
    }

    #[test]
    fn drop_unawaited_cancels_all_shards() {
        let c = mock_cluster(3);
        let h = c.submit((0..50).collect()).unwrap();
        assert_eq!(h.n_tasks(), 50);
        drop(h); // each shard's JobHandle cancels its engine job
        let h2 = c.submit((0..6).collect()).unwrap();
        assert_eq!(h2.wait().unwrap().len(), 6);
    }

    // -- mixed local/remote clusters over a loopback worker ------------

    use crate::cluster::remote::{serve_worker, RemoteConfig, RemoteEngine};
    use std::net::TcpListener;
    use std::time::Duration;

    fn loopback_worker() -> crate::cluster::remote::WorkerServer {
        let engine = Engine::new(Mock, EngineConfig::new(2)).unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        serve_worker(listener, engine).unwrap()
    }

    fn proxy(
        w: &crate::cluster::remote::WorkerServer,
    ) -> RemoteEngine<u64, u64> {
        RemoteEngine::connect(
            &w.addr().to_string(),
            RemoteConfig {
                ping_interval: Duration::from_millis(20),
                ping_timeout: Duration::from_millis(500),
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn mixed_cluster_matches_local_results() {
        let tasks: Vec<u64> = (0..97).collect();
        let want = expect(&tasks);
        let w = loopback_worker();
        for n_remote in [1, 2] {
            let engines =
                vec![Engine::new(Mock, EngineConfig::new(1)).unwrap()];
            let remotes: Vec<_> =
                (0..n_remote).map(|_| proxy(&w)).collect();
            let c = Cluster::with_remotes(
                engines,
                remotes,
                Arc::new(Metrics::new()),
            )
            .unwrap();
            assert_eq!(c.n_local(), 1);
            assert_eq!(c.n_remote(), n_remote);
            assert_eq!(c.run(tasks.clone()).unwrap(), want);
        }
        assert_eq!(
            w.stats().empty_submits.load(Ordering::Relaxed),
            0
        );
    }

    #[test]
    fn pure_remote_cluster_works() {
        let w = loopback_worker();
        let remotes = vec![proxy(&w), proxy(&w)];
        let c = Cluster::with_remotes(
            Vec::new(),
            remotes,
            Arc::new(Metrics::new()),
        )
        .unwrap();
        assert_eq!(c.n_local(), 0);
        assert_eq!(c.n_remote(), 2);
        let tasks: Vec<u64> = (0..31).collect();
        assert_eq!(c.run(tasks.clone()).unwrap(), expect(&tasks));
    }

    #[test]
    fn killed_worker_shard_requeues_onto_local_survivor() {
        let metrics = Arc::new(Metrics::new());
        let w = loopback_worker();
        let c = Cluster::with_remotes(
            vec![Engine::new(Mock, EngineConfig::new(1)).unwrap()],
            vec![proxy(&w)],
            Arc::clone(&metrics),
        )
        .unwrap();
        // sever the worker before the round; both interleavings
        // converge: if the proxy's reader already saw the EOF the
        // remote submit fails synchronously (marked dead in
        // submit_to_alive), otherwise the submit lands in the dead
        // socket and the shard fails mid-round (requeued by wait()) —
        // either way the shard reruns on the local survivor exactly
        w.kill();
        std::thread::sleep(Duration::from_millis(20));
        let tasks: Vec<u64> = (0..40).collect();
        let out = c.run(tasks.clone()).unwrap();
        assert_eq!(out, expect(&tasks));
        assert_eq!(c.n_alive(), 1);
        assert!(
            metrics.retried() >= 1 || metrics.failed() >= 1,
            "{}",
            metrics.summary()
        );
    }

    #[test]
    fn restarted_worker_rejoins_the_shard_plan() {
        use std::time::Instant;

        let metrics = Arc::new(Metrics::new());
        let w = loopback_worker();
        let addr = w.addr();
        let remote: RemoteEngine<u64, u64> =
            RemoteEngine::connect_with_metrics(
                &addr.to_string(),
                RemoteConfig {
                    ping_interval: Duration::from_millis(20),
                    ping_timeout: Duration::from_millis(300),
                    reconnect_backoff: Duration::from_millis(20),
                    reconnect_cap: Duration::from_millis(100),
                    reconnect_retries: 100,
                    ..Default::default()
                },
                Arc::clone(&metrics),
            )
            .unwrap();
        let c = Cluster::with_remotes(
            vec![Engine::new(Mock, EngineConfig::new(1)).unwrap()],
            vec![remote],
            Arc::clone(&metrics),
        )
        .unwrap();

        // kill the worker: the round survives on the local engine and
        // the remote slot is marked dead
        w.kill();
        drop(w);
        let tasks: Vec<u64> = (0..40).collect();
        assert_eq!(c.run(tasks.clone()).unwrap(), expect(&tasks));
        assert_eq!(c.n_alive(), 1);

        // restart a worker on the same port: the supervisor
        // re-handshakes and the node rejoins the next shard plan
        let deadline = Instant::now() + Duration::from_secs(10);
        let _w2 = loop {
            match TcpListener::bind(addr) {
                Ok(l) => {
                    let engine =
                        Engine::new(Mock, EngineConfig::new(2)).unwrap();
                    break serve_worker(l, engine).unwrap();
                }
                Err(e) => {
                    assert!(
                        Instant::now() < deadline,
                        "could not rebind {addr}: {e}"
                    );
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        };
        while c.n_alive() < 2 {
            assert!(
                Instant::now() < deadline,
                "remote node never rejoined ({})",
                metrics.summary()
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(metrics.reconnects() >= 1, "{}", metrics.summary());
        // the revived node serves subsequent rounds
        assert_eq!(c.run(tasks.clone()).unwrap(), expect(&tasks));
        assert_eq!(c.n_alive(), 2);
    }
}
