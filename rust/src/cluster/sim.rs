//! Multi-device scaling model (paper claim C2: "performance scales
//! linearly with the increasing of the GPUs").
//!
//! The physical testbed has one CPU core, so adding real worker threads
//! cannot demonstrate device scaling. Instead we keep the *scheduling
//! logic* real and make *time* virtual: measure true per-chunk device
//! durations once, then replay the coordinator's greedy FIFO assignment
//! over N virtual devices with a discrete-event simulation, including the
//! measured per-launch dispatch overhead. This reproduces exactly the
//! quantity the paper plots — completion time of a fixed workload vs
//! device count — with the real chunk structure and real measured costs.

/// One virtual device's clock.
#[derive(Debug, Clone, Copy, Default)]
struct Device {
    free_at: f64,
    busy: f64,
}

/// Result of simulating a workload on N devices.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub n_devices: usize,
    /// Wall-clock completion time (s).
    pub makespan: f64,
    /// Mean device utilization in [0,1].
    pub utilization: f64,
    /// Speedup vs the same workload on one device.
    pub speedup: f64,
}

/// Greedy list-scheduling simulation (the coordinator's FIFO policy):
/// each task goes to the earliest-free device; `dispatch_s` models the
/// coordinator-side per-launch cost (literal building + PJRT dispatch),
/// which serializes on the leader exactly as in the real scheduler.
pub fn simulate(task_durations_s: &[f64], n_devices: usize, dispatch_s: f64) -> SimResult {
    assert!(n_devices > 0);
    let mut devices = vec![Device::default(); n_devices];
    let mut leader_free = 0.0f64; // dispatch serializes on the leader
    for &d in task_durations_s {
        // pick earliest-free device
        let dev = devices
            .iter_mut()
            .min_by(|a, b| a.free_at.total_cmp(&b.free_at))
            .unwrap();
        // dispatch happens on the leader, then the device runs
        let dispatch_start = leader_free.max(0.0);
        leader_free = dispatch_start + dispatch_s;
        let start = leader_free.max(dev.free_at);
        dev.free_at = start + d;
        dev.busy += d;
    }
    let makespan = devices
        .iter()
        .map(|d| d.free_at)
        .fold(0.0, f64::max)
        .max(leader_free);
    let total: f64 = task_durations_s.iter().sum();
    let serial = total + dispatch_s * task_durations_s.len() as f64;
    let utilization = if makespan > 0.0 {
        devices.iter().map(|d| d.busy).sum::<f64>()
            / (n_devices as f64 * makespan)
    } else {
        0.0
    };
    SimResult {
        n_devices,
        makespan,
        utilization,
        speedup: if makespan > 0.0 { serial / makespan } else { 1.0 },
    }
}

/// Sweep device counts for the C2 figure.
pub fn scaling_sweep(
    task_durations_s: &[f64],
    device_counts: &[usize],
    dispatch_s: f64,
) -> Vec<SimResult> {
    device_counts
        .iter()
        .map(|&n| simulate(task_durations_s, n, dispatch_s))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_device_is_serial() {
        let r = simulate(&[1.0, 1.0, 1.0], 1, 0.0);
        assert!((r.makespan - 3.0).abs() < 1e-12);
        assert!((r.utilization - 1.0).abs() < 1e-12);
    }

    #[test]
    fn equal_tasks_scale_linearly() {
        let tasks = vec![1.0; 64];
        let r1 = simulate(&tasks, 1, 0.0);
        let r4 = simulate(&tasks, 4, 0.0);
        let r8 = simulate(&tasks, 8, 0.0);
        assert!((r1.makespan / r4.makespan - 4.0).abs() < 1e-9);
        assert!((r1.makespan / r8.makespan - 8.0).abs() < 1e-9);
    }

    #[test]
    fn dispatch_overhead_caps_scaling() {
        // 64 tasks of 10ms with 5ms dispatch: leader saturates at
        // 1/0.005 = 200 launches/s → max ~2 devices' worth of 10ms work.
        let tasks = vec![0.010; 64];
        let r16 = simulate(&tasks, 16, 0.005);
        // makespan bounded below by leader serialization
        assert!(r16.makespan >= 64.0 * 0.005);
        let r2 = simulate(&tasks, 2, 0.005);
        // going 2 → 16 devices cannot give 8x when the leader is the wall
        assert!(r2.makespan / r16.makespan < 3.0);
    }

    #[test]
    fn stragglers_break_perfect_scaling() {
        // one long task dominates
        let mut tasks = vec![0.01; 31];
        tasks.push(1.0);
        let r4 = simulate(&tasks, 4, 0.0);
        assert!(r4.makespan >= 1.0);
        assert!(r4.utilization < 0.9);
    }

    #[test]
    fn sweep_shapes() {
        let tasks = vec![0.5; 32];
        let rs = scaling_sweep(&tasks, &[1, 2, 4, 8], 0.0);
        assert_eq!(rs.len(), 4);
        // monotone non-increasing makespan
        for w in rs.windows(2) {
            assert!(w[1].makespan <= w[0].makespan + 1e-12);
        }
    }
}
