//! Centralized result reduction: fold tagged device outputs back into
//! per-slot [`MomentSum`]s.
//!
//! Every `vm_multi` launch returns `(Σf, Σf²)` pairs for the function
//! rows it carried, tagged with the block index its submitter assigned.
//! Reduction is the same for every caller — the one-shot multifunction
//! path (several counter-advancing chunks merge into one block), the
//! adaptive driver (one launch row per stratum slot, merged before the
//! next Neyman allocation step), and the cluster (shard outputs arrive
//! already ordered, so reduction is oblivious to the engine count).
//!
//! Determinism: outputs are consumed **in task order** (engine jobs and
//! cluster handles both guarantee it) and each row folds in via the
//! pure [`MomentSum::merge`], so the merged sums are bit-identical for
//! any worker count and any shard count — the property
//! `tests/cluster_test.rs` checks for shard counts 1..8.

use crate::engine::TaggedOutput;
use crate::stats::MomentSum;

/// Merge tagged launch outputs into `n_slots` moment accumulators.
///
/// Launch `out` with tag `t` carries `n_fns` rows; row `k` belongs to
/// slot `t * n_fns + k` and contributes `samples_per_row` samples.
/// Rows addressing slots past `n_slots` are padding (the last block of
/// a batch is rarely full) and are skipped.
pub fn reduce_tagged(
    outs: impl IntoIterator<Item = TaggedOutput>,
    n_fns: usize,
    samples_per_row: u64,
    n_slots: usize,
) -> Vec<MomentSum> {
    let mut moments = vec![MomentSum::new(); n_slots];
    for out in outs {
        fold_tagged(&mut moments, &out, n_fns, samples_per_row);
    }
    moments
}

/// Fold **one** tagged output into the slot accumulators — the
/// streaming unit of [`reduce_tagged`]. Calling this per output in
/// task order is bit-identical to reducing the collected vector (the
/// per-slot merge sequence is the same), which is how the batch
/// subsystem's streaming reduction flushes results as they land
/// instead of accumulating O(batch) outputs first.
pub fn fold_tagged(
    moments: &mut [MomentSum],
    out: &TaggedOutput,
    n_fns: usize,
    samples_per_row: u64,
) {
    let start = out.tag as usize * n_fns;
    for k in 0..n_fns {
        let slot = start + k;
        if slot >= moments.len() {
            break;
        }
        moments[slot].merge(&MomentSum::from_device(
            samples_per_row,
            out.data[k * 2],
            out.data[k * 2 + 1],
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn out(tag: u64, rows: &[(f32, f32)]) -> TaggedOutput {
        let mut data = Vec::new();
        for &(s, sq) in rows {
            data.push(s);
            data.push(sq);
        }
        TaggedOutput { tag, data, device_time: Duration::ZERO }
    }

    #[test]
    fn chunks_of_one_block_accumulate() {
        // two chunks of the same block: moments add up
        let outs = vec![
            out(0, &[(1.0, 1.0), (2.0, 4.0)]),
            out(0, &[(3.0, 9.0), (4.0, 16.0)]),
        ];
        let m = reduce_tagged(outs, 2, 10, 2);
        assert_eq!(m[0].n, 20);
        assert_eq!(m[0].sum, 4.0);
        assert_eq!(m[0].sumsq, 10.0);
        assert_eq!(m[1].sum, 6.0);
        assert_eq!(m[1].sumsq, 20.0);
    }

    #[test]
    fn blocks_address_disjoint_slots_and_padding_is_skipped() {
        let outs = vec![
            out(0, &[(1.0, 1.0), (2.0, 4.0)]),
            out(1, &[(5.0, 25.0), (99.0, 99.0)]), // second row = padding
        ];
        let m = reduce_tagged(outs, 2, 7, 3);
        assert_eq!(m[0].sum, 1.0);
        assert_eq!(m[1].sum, 2.0);
        assert_eq!(m[2].sum, 5.0);
        assert_eq!(m[2].n, 7);
    }

    #[test]
    fn split_outputs_merge_like_the_whole() {
        // the cluster property in miniature: reducing a shard-split
        // output list in order is bit-identical to reducing it whole
        let all: Vec<TaggedOutput> = (0..8)
            .map(|t| {
                out(t, &[((t as f32).sin(), (t as f32).cos().abs())])
            })
            .collect();
        let whole = reduce_tagged(all.clone(), 1, 5, 8);
        for cut in 1..8 {
            let (a, b) = (all[..cut].to_vec(), all[cut..].to_vec());
            let merged =
                reduce_tagged(a.into_iter().chain(b), 1, 5, 8);
            for (x, y) in whole.iter().zip(&merged) {
                assert_eq!(x, y);
            }
        }
    }

    #[test]
    fn empty_outputs_leave_zero_moments() {
        let m = reduce_tagged(Vec::new(), 4, 100, 3);
        assert_eq!(m.len(), 3);
        assert!(m.iter().all(|x| x.n == 0));
    }
}
