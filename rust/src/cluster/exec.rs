//! One submission surface for both topologies.
//!
//! The integrators ([`crate::integrator::multifunctions`], the
//! [`crate::adaptive`] driver) build `vm_multi` launch tasks and do not
//! care whether one engine or a cluster of engines runs them — only
//! that results come back in task order. [`LaunchExec`] is that
//! contract: implemented by [`DeviceEngine`] (the existing path,
//! unchanged semantics) and by [`DeviceCluster`] (shard + fan-out +
//! centralized reduce). A 1-engine cluster plans a single shard over
//! the whole task list, so its behavior is the engine path by
//! construction.
//!
//! The trait is object safe: a [`crate::session::Session`] (the
//! topology the CLI's `--num-engines` builds) hands integrators a
//! `&dyn LaunchExec`.

use anyhow::Result;

use crate::cluster::core::{ClusterHandle, DeviceCluster};
use crate::coordinator::progress::Metrics;
use crate::engine::{DeviceBackend, DeviceEngine, DeviceHandle, LaunchTask, TaggedOutput};
use crate::runtime::registry::Registry;

/// An in-flight launch set on either topology; same waiting contract
/// as the engine's [`DeviceHandle`] (results in task order).
pub enum ExecHandle {
    Engine(DeviceHandle),
    Cluster(ClusterHandle<DeviceBackend>),
}

impl ExecHandle {
    /// Block until every launch landed; outputs in task order.
    pub fn wait(self) -> Result<Vec<TaggedOutput>> {
        match self {
            ExecHandle::Engine(h) => h.wait(),
            ExecHandle::Cluster(h) => h.wait(),
        }
    }

    /// Stream outputs to `sink` **in task order** as they land,
    /// without accumulating the full `Vec<TaggedOutput>`: the engine
    /// path flushes per task, the cluster path per shard. The fold
    /// order is bit-identical to `wait()` + iterating the vec; peak
    /// memory is O(in-flight), not O(batch). This is what the batch
    /// subsystem's streaming reduction drains through.
    pub fn wait_each(
        self,
        sink: &mut dyn FnMut(TaggedOutput),
    ) -> Result<()> {
        match self {
            ExecHandle::Engine(h) => h.wait_each(sink),
            ExecHandle::Cluster(h) => h.wait_each(sink),
        }
    }

    /// Non-blocking completion probe.
    pub fn is_done(&self) -> bool {
        match self {
            ExecHandle::Engine(h) => h.is_done(),
            ExecHandle::Cluster(h) => h.is_done(),
        }
    }

    pub fn n_tasks(&self) -> usize {
        match self {
            ExecHandle::Engine(h) => h.n_tasks(),
            ExecHandle::Cluster(h) => h.n_tasks(),
        }
    }

    /// Cancel outstanding launches (same as dropping un-awaited).
    pub fn cancel(self) {
        drop(self);
    }
}

/// Anything that can execute a batch of device launches: a single
/// persistent engine or a multi-engine cluster.
pub trait LaunchExec {
    /// The artifact registry launches are resolved against.
    fn registry(&self) -> &Registry;

    /// The execution metrics sink for this topology (the engine's own
    /// counters, or the cluster-level sink for a cluster). Lets layers
    /// above record per-run events — e.g. the batch subsystem's dedup
    /// fold counts — without knowing the topology.
    fn metrics(&self) -> &Metrics;

    /// Enqueue `tasks`; returns immediately with a waitable handle.
    fn submit_launches(
        &self,
        tasks: Vec<LaunchTask>,
        max_retries: u32,
    ) -> Result<ExecHandle>;
}

impl LaunchExec for DeviceEngine {
    fn registry(&self) -> &Registry {
        self.backend().registry()
    }

    fn metrics(&self) -> &Metrics {
        self.metrics()
    }

    fn submit_launches(
        &self,
        tasks: Vec<LaunchTask>,
        max_retries: u32,
    ) -> Result<ExecHandle> {
        Ok(ExecHandle::Engine(self.submit_with_retries(tasks, max_retries)?))
    }
}

impl LaunchExec for DeviceCluster {
    fn registry(&self) -> &Registry {
        // the cluster's own accessor: answers from a local engine or
        // the stored pool registry (a pure-remote cluster has no
        // local engine to borrow one from)
        self.registry()
    }

    fn metrics(&self) -> &Metrics {
        self.metrics()
    }

    fn submit_launches(
        &self,
        tasks: Vec<LaunchTask>,
        max_retries: u32,
    ) -> Result<ExecHandle> {
        Ok(ExecHandle::Cluster(self.submit_with_retries(tasks, max_retries)?))
    }
}
