//! # zmc — multi-function Monte-Carlo integration on (simulated) GPU clusters
//!
//! A rust + JAX + Pallas reproduction of **ZMCintegral-v5.1**
//! (Cao & Zhang, Comput. Phys. Commun. 2021, 10.1016/j.cpc.2021.107994):
//! a distributed Monte-Carlo integration framework whose v5.1 contribution
//! is *multi-function integration* — evaluating ≥10³ integrands of
//! different forms, dimensions and domains concurrently on GPU clusters.
//!
//! ## Architecture (three layers, python never at run time)
//!
//! * **L1/L2 (build time)** — Pallas kernels + jax compute graphs in
//!   `python/compile/`, AOT-lowered once by `make artifacts` into
//!   `artifacts/*.hlo.txt` plus a manifest.
//! * **L3 (run time, this crate)** — the coordinator: loads artifacts
//!   ([`runtime`]; PJRT with `--features pjrt`, else the bit-compatible
//!   CPU emulator), compiles user expression strings to bytecode
//!   ([`expr`], [`vm`]), and submits chunked launches to the persistent
//!   execution [`engine`] — long-lived device workers with warm
//!   executable caches, a condvar-backed task queue, retry-on-failure
//!   policy ([`coordinator`]), and concurrent `submit() -> JobHandle`
//!   semantics — on which the paper's three integration classes
//!   ([`integrator`]) are built. Multi-device runs put a [`cluster`]
//!   of engines behind the same submit surface: contiguous shards,
//!   disjoint Philox counter ranges, centralized moment reduction —
//!   bit-identical to the single engine at any engine count.
//!
//! ## The paper's three classes
//!
//! | paper API | here |
//! |---|---|
//! | `ZMCintegral_normal`         | [`integrator::normal`] — stratified sampling + heuristic tree search |
//! | `ZMCintegral_functional`     | [`integrator::functional`] — one integrand over a parameter grid |
//! | `ZMCintegral_multifunctions` | [`integrator::multifunctions`] — heterogeneous integrand batches |
//!
//! Beyond the paper: setting an error target on a
//! [`integrator::multifunctions::MultiConfig`] switches multifunction
//! batches to the [`adaptive`] pilot-then-refine loop — variance-driven
//! (Neyman) budget allocation with per-function stopping and stratified
//! subdivision of stalling integrands.
//!
//! ## Quickstart
//!
//! ```no_run
//! use std::sync::Arc;
//! use zmc::prelude::*;
//!
//! // one engine per process: workers + executable caches stay warm
//! let reg = Arc::new(Registry::load("artifacts").unwrap());
//! let pool = DevicePool::new(&reg, 1).unwrap();
//! let engine = Engine::for_pool(&pool).unwrap();
//!
//! let job = IntegralJob::parse("sin(x1)*x2", &[(0.0, 1.0), (0.0, 2.0)])
//!     .unwrap();
//! let est = zmc::integrator::multifunctions::integrate_one(
//!     &engine, &job, 1 << 20, 42).unwrap();
//! println!("I = {} ± {}", est.value, est.std_err);
//!
//! // async form: independent job sets in flight concurrently
//! let cfg = zmc::integrator::multifunctions::MultiConfig::default();
//! let h1 = zmc::integrator::multifunctions::submit(
//!     &engine, std::slice::from_ref(&job), &cfg).unwrap();
//! let h2 = zmc::integrator::multifunctions::submit(
//!     &engine, std::slice::from_ref(&job), &cfg).unwrap();
//! let (_a, _b) = (h1.wait().unwrap(), h2.wait().unwrap());
//!
//! // multi-device: the same calls accept a cluster of engines (the
//! // CLI's `--num-engines N`); batches shard across engines with
//! // disjoint Philox counter ranges and merge to bit-identical results
//! let cluster = DeviceCluster::for_pool(&pool, 4).unwrap();
//! let est4 = zmc::integrator::multifunctions::integrate_one(
//!     &cluster, &job, 1 << 20, 42).unwrap();
//! assert_eq!(est.value, est4.value);
//! ```

pub mod adaptive;
pub mod analytic;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod expr;
pub mod integrator;
pub mod runtime;
pub mod sampler;
pub mod stats;
pub mod util;
pub mod vm;

/// Convenience re-exports for the common workflow.
pub mod prelude {
    pub use crate::adaptive::Allocation;
    pub use crate::cluster::{
        Cluster, ClusterHandle, DeviceCluster, ExecHandle, LaunchExec,
        ShardPlan,
    };
    pub use crate::coordinator::scheduler::Scheduler;
    pub use crate::engine::{
        DeviceBackend, DeviceEngine, Engine, EngineConfig, JobHandle,
    };
    pub use crate::expr::Expr;
    pub use crate::integrator::spec::{Estimate, IntegralJob};
    pub use crate::runtime::device::DevicePool;
    pub use crate::runtime::registry::Registry;
    pub use crate::vm::program::Program;
}

/// ABI constants — must match `python/compile/opcodes.py` and the
/// `constants` block of `artifacts/manifest.json` (checked at registry
/// load time and by `tests/opcode_abi.rs`).
pub mod abi {
    /// Manifest/bytecode ABI version understood by this build.
    pub const ABI_VERSION: i64 = 1;
    /// Padded sample dimensionality of every artifact.
    pub const MAX_DIM: usize = 8;
    /// Instructions per bytecode program (HALT-padded).
    pub const MAX_PROG: usize = 48;
    /// VM value-stack depth.
    pub const STACK: usize = 16;
    /// Per-function parameter slots.
    pub const MAX_PARAM: usize = 16;
}
