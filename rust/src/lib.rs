//! # zmc — multi-function Monte-Carlo integration on (simulated) GPU clusters
//!
//! A rust + JAX + Pallas reproduction of **ZMCintegral-v5.1**
//! (Cao & Zhang, Comput. Phys. Commun. 2021, 10.1016/j.cpc.2021.107994):
//! a distributed Monte-Carlo integration framework whose v5.1 contribution
//! is *multi-function integration* — evaluating ≥10³ integrands of
//! different forms, dimensions and domains concurrently on GPU clusters.
//!
//! ## Architecture (three layers, python never at run time)
//!
//! * **L1/L2 (build time)** — Pallas kernels + jax compute graphs in
//!   `python/compile/`, AOT-lowered once by `make artifacts` into
//!   `artifacts/*.hlo.txt` plus a manifest.
//! * **L3 (run time, this crate)** — the coordinator: loads artifacts
//!   ([`runtime`]; PJRT with `--features pjrt`, else the bit-compatible
//!   CPU emulator), compiles user expression strings to bytecode
//!   ([`expr`], [`vm`]), and submits chunked launches to the persistent
//!   execution [`engine`] — long-lived device workers with warm
//!   executable caches, a condvar-backed task queue, retry-on-failure
//!   policy ([`coordinator`]), and concurrent `submit() -> JobHandle`
//!   semantics — on which the paper's three integration classes
//!   ([`integrator`]) are built. Multi-device runs put a [`cluster`]
//!   of engines behind the same submit surface: contiguous shards,
//!   disjoint Philox counter ranges, centralized moment reduction —
//!   bit-identical to the single engine at any engine count.
//!
//! ## The paper's three classes — one [`session::Session`]
//!
//! | paper API | session builder | legacy free functions |
//! |---|---|---|
//! | `ZMCintegral_multifunctions(fns).evaluate()` | `session.multifunctions(&jobs).samples(n).run()` | [`integrator::multifunctions`] |
//! | `ZMCintegral_functional(f, grid).evaluate()` | `session.functional(&job, &grid).samples(n).run()` | [`integrator::functional`] |
//! | `ZMCintegral_normal(f).evaluate()` | `session.normal(&job).depth(d).run()` | [`integrator::normal`] |
//!
//! The [`session`] module is the front door: a `Session` owns
//! `Registry → DevicePool → Engine/DeviceCluster` construction and
//! hands out fluent per-class builders, so sync and async (`.run()` vs
//! `.submit()`), one engine and N engines (`.engines(n)`), one-shot
//! and adaptive (`.target_rel_err(..)`) are all the same call shape.
//! The module-level free functions remain as the thin compatibility
//! layer the builders delegate to — results are bit-identical
//! (`tests/session_test.rs`).
//!
//! Beyond the paper: setting an error target (builder
//! `.target_rel_err(..)` or [`integrator::multifunctions::MultiConfig`])
//! switches multifunction batches to the [`adaptive`] pilot-then-refine
//! loop — variance-driven (Neyman) budget allocation with per-function
//! stopping and stratified subdivision of stalling integrands.
//!
//! ## Quickstart
//!
//! ```no_run
//! use zmc::prelude::*;
//!
//! // one session per process: it owns the registry, the device pool
//! // and the persistent engine(s); workers + executable caches stay
//! // warm for everything run through it
//! let session = Session::builder()
//!     .artifacts_or_emulator("artifacts")
//!     .workers(1)
//!     .build()
//!     .unwrap();
//!
//! let job = IntegralJob::parse("sin(x1)*x2", &[(0.0, 1.0), (0.0, 2.0)])
//!     .unwrap();
//! let est = session
//!     .multifunctions(std::slice::from_ref(&job))
//!     .samples(1 << 20)
//!     .seed(42)
//!     .run()
//!     .unwrap()[0];
//! println!("{est}"); // I = .. ± .. (n samples, r rounds)
//!
//! // async form: independent job sets in flight concurrently
//! let h1 = session
//!     .multifunctions(std::slice::from_ref(&job))
//!     .submit()
//!     .unwrap();
//! let h2 = session
//!     .multifunctions(std::slice::from_ref(&job))
//!     .submit()
//!     .unwrap();
//! let (_a, _b) = (h1.wait().unwrap(), h2.wait().unwrap());
//!
//! // multi-device: same call shape behind a 4-engine session (the
//! // CLI's `--num-engines N`); batches shard across engines with
//! // disjoint Philox counter ranges and merge to bit-identical results
//! let four = Session::builder()
//!     .artifacts_or_emulator("artifacts")
//!     .engines(4)
//!     .build()
//!     .unwrap();
//! let est4 = four
//!     .multifunctions(std::slice::from_ref(&job))
//!     .samples(1 << 20)
//!     .seed(42)
//!     .run()
//!     .unwrap()[0];
//! assert_eq!(est.value, est4.value);
//! ```

pub mod adaptive;
pub mod analytic;
pub mod batch;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod expr;
pub mod integrator;
pub mod runtime;
pub mod sampler;
pub mod serve;
pub mod session;
pub mod stats;
pub mod util;
pub mod vm;

/// Convenience re-exports for the common workflow.
pub mod prelude {
    pub use crate::adaptive::Allocation;
    pub use crate::batch::{BatchConfig, BatchJobs, BatchResults};
    pub use crate::cluster::{
        Cluster, ClusterHandle, DeviceCluster, ExecHandle, LaunchExec,
        ShardPlan,
    };
    pub use crate::coordinator::scheduler::Scheduler;
    pub use crate::engine::{
        DeviceBackend, DeviceEngine, Engine, EngineConfig, JobHandle,
    };
    pub use crate::expr::Expr;
    pub use crate::integrator::spec::{Estimate, IntegralJob};
    pub use crate::runtime::device::DevicePool;
    pub use crate::runtime::registry::Registry;
    pub use crate::serve::{ServeConfig, Server};
    pub use crate::session::{Session, SessionBuilder};
    pub use crate::vm::program::Program;
}

/// ABI constants — must match `python/compile/opcodes.py` and the
/// `constants` block of `artifacts/manifest.json` (checked at registry
/// load time and by `tests/opcode_abi.rs`).
pub mod abi {
    /// Manifest/bytecode ABI version understood by this build.
    pub const ABI_VERSION: i64 = 1;
    /// Padded sample dimensionality of every artifact.
    pub const MAX_DIM: usize = 8;
    /// Instructions per bytecode program (HALT-padded).
    pub const MAX_PROG: usize = 48;
    /// VM value-stack depth.
    pub const STACK: usize = 16;
    /// Per-function parameter slots.
    pub const MAX_PARAM: usize = 16;
}
