//! Route dispatch: one connection in, one response (or stream) out.

use std::io::BufReader;
use std::net::TcpStream;

use anyhow::Context;

use crate::config::JobConfig;
use crate::session::{validate_job, ErrorPayload};
use crate::util::json::Json;

use super::http::{self, ChunkedWriter, ReadError};
use super::{
    error_body, status_frame, JobStatus, ServerState, StoredResult,
};

use std::sync::atomic::Ordering;

/// Handle one connection end to end. All I/O failures are swallowed:
/// the peer is gone, and any in-flight job still reaches the ledger
/// and journal through [`ServerState::run_and_record`].
pub(crate) fn handle_connection(state: &ServerState, stream: TcpStream) {
    let peer = stream
        .peer_addr()
        .map(|a| a.ip().to_string())
        .unwrap_or_else(|_| "unknown".to_string());
    let Ok(reader) = stream.try_clone() else { return };
    let mut stream = stream;
    let req = match http::read_request(
        &mut BufReader::new(reader),
        state.cfg.max_body,
    ) {
        Ok(req) => req,
        Err(ReadError::Closed) => return,
        // an idle or drip-feeding client tripped the read deadline
        // (`ServeConfig::read_timeout`): tell it so and hang up, so a
        // slowloris cannot pin an http worker
        Err(ReadError::Io(e))
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock
                    | std::io::ErrorKind::TimedOut
            ) =>
        {
            state.metrics.bad_requests.fetch_add(1, Ordering::Relaxed);
            let _ = http::write_json(
                &mut stream,
                408,
                &error_body(&ErrorPayload::new(
                    "timeout",
                    "request not received within the read timeout",
                )),
            );
            return;
        }
        Err(ReadError::Io(_)) => return,
        Err(ReadError::Bad(msg)) => {
            state.metrics.bad_requests.fetch_add(1, Ordering::Relaxed);
            let _ = http::write_json(
                &mut stream,
                400,
                &error_body(&ErrorPayload::new("bad_request", msg)),
            );
            return;
        }
        Err(ReadError::TooLarge { limit }) => {
            state.metrics.bad_requests.fetch_add(1, Ordering::Relaxed);
            let _ = http::write_json(
                &mut stream,
                413,
                &error_body(&ErrorPayload::new(
                    "too_large",
                    format!("request body exceeds {limit} bytes"),
                )),
            );
            return;
        }
    };

    let path = req.path.split('?').next().unwrap_or("");
    match (req.method.as_str(), path) {
        ("POST", "/v1/jobs") => post_job(state, stream, &req.body, &peer),
        ("GET", p) if job_id(p).is_some() => {
            get_job(state, stream, job_id(p).unwrap())
        }
        ("GET", "/v1/healthz") => {
            let _ =
                http::write_json(&mut stream, 200, &state.healthz_json());
        }
        ("GET", "/v1/metrics") => {
            let _ =
                http::write_json(&mut stream, 200, &state.metrics_json());
        }
        ("GET" | "POST", "/v1/jobs" | "/v1/healthz" | "/v1/metrics") => {
            let _ = http::write_json(
                &mut stream,
                405,
                &error_body(&ErrorPayload::new(
                    "method_not_allowed",
                    format!("{} not allowed on {path}", req.method),
                )),
            );
        }
        _ => {
            let _ = http::write_json(
                &mut stream,
                404,
                &error_body(&ErrorPayload::new(
                    "not_found",
                    format!("no route {path}"),
                )),
            );
        }
    }
}

/// `/v1/jobs/{id}` → `Some(id)`.
fn job_id(path: &str) -> Option<u64> {
    path.strip_prefix("/v1/jobs/")?.parse().ok()
}

/// `POST /v1/jobs`: rate limit → admission → parse+validate → stream.
fn post_job(
    state: &ServerState,
    mut stream: TcpStream,
    body: &[u8],
    peer: &str,
) {
    if let Some(limiter) = &state.limiter {
        if let Err(wait) = limiter.admit(peer) {
            state.metrics.rejected_rate.fetch_add(1, Ordering::Relaxed);
            let _ = http::write_json_with(
                &mut stream,
                429,
                &[("retry-after", wait.to_string())],
                &error_body(&ErrorPayload::new(
                    "rate_limited",
                    format!("client {peer} over the submission rate"),
                )),
            );
            return;
        }
    }
    let Some(_slot) = state.try_admit() else {
        state.metrics.rejected_busy.fetch_add(1, Ordering::Relaxed);
        let _ = http::write_json_with(
            &mut stream,
            429,
            &[("retry-after", "1".to_string())],
            &error_body(&ErrorPayload::new(
                "busy",
                format!(
                    "{} jobs already in flight",
                    state.cfg.max_jobs.max(1)
                ),
            )),
        );
        return;
    };

    // Everything that can be rejected is rejected before the 200:
    // once the chunked stream starts, the job runs to a terminal frame.
    let parsed = std::str::from_utf8(body)
        .context("request body is not utf-8")
        .and_then(|text| Ok(Json::parse(text)?))
        .and_then(|j| Ok((JobConfig::from_json(&j)?, j)));
    let (cfg, raw) = match parsed {
        Ok(pair) => pair,
        Err(err) => {
            state.metrics.bad_requests.fetch_add(1, Ordering::Relaxed);
            let _ = http::write_json(
                &mut stream,
                400,
                &error_body(&ErrorPayload::from_error(&err)),
            );
            return;
        }
    };
    if let Err(err) = validate_job(&cfg) {
        state.metrics.bad_requests.fetch_add(1, Ordering::Relaxed);
        let _ = http::write_json(
            &mut stream,
            400,
            &error_body(&ErrorPayload::from_error(&err)),
        );
        return;
    }

    let id = state.create_job(&raw);
    let mut cw = match ChunkedWriter::start(stream) {
        Ok(cw) => cw,
        Err(_) => {
            // Peer vanished between accept and headers: the job was
            // journaled, so run it anyway and record the outcome.
            state.run_and_record(id, &cfg, &mut |_| {});
            return;
        }
    };
    let mut live = cw
        .write_line(&status_frame(id, JobStatus::Running, None))
        .is_ok();
    state.run_and_record(id, &cfg, &mut |frame| {
        if live {
            live = cw.write_line(frame).is_ok();
        }
    });
    if live {
        let _ = cw.finish();
    }
}

/// `GET /v1/jobs/{id}`: status for running jobs, streamed result or
/// error payload for finished ones.
fn get_job(state: &ServerState, mut stream: TcpStream, id: u64) {
    let entry = crate::engine::core::lock_ok(&state.jobs)
        .get(&id)
        .map(|e| (e.status, e.result.clone(), e.error.clone()));
    let Some((status, result, error)) = entry else {
        let _ = http::write_json(
            &mut stream,
            404,
            &error_body(&ErrorPayload::new(
                "not_found",
                format!("no job {id}"),
            )),
        );
        return;
    };
    let Some(result) = result else {
        // running, failed, or done with no recallable result: the
        // status frame (plus any error payload) is the whole story
        let _ = http::write_json(
            &mut stream,
            200,
            &status_frame(id, status, error),
        );
        return;
    };
    if result.n_estimates() > state.cfg.max_recall {
        let _ = http::write_json(
            &mut stream,
            413,
            &error_body(&ErrorPayload::new(
                "result_too_large",
                format!(
                    "result holds {} estimates, over the recall \
                     bound {}",
                    result.n_estimates(),
                    state.cfg.max_recall
                ),
            )),
        );
        return;
    }
    stream_result(stream, id, status, &result);
}

/// Bytes buffered before a chunk is flushed on the recall stream.
const RECALL_FLUSH: usize = 32 * 1024;

/// Stream a finished job's result as one chunked JSON document,
/// serialized straight from the stored columns through a bounded
/// buffer — recall memory is O(buffer), never O(result), which is
/// what lets a server recall 10⁶-estimate batches it could not
/// afford to materialize as one `String`.
fn stream_result(
    stream: TcpStream,
    id: u64,
    status: JobStatus,
    result: &StoredResult,
) {
    let Ok(mut cw) = ChunkedWriter::start(stream) else { return };
    // The envelope is the status frame with a `result` key spliced in
    // before the closing brace, so the streamed body parses to the
    // same object shape the ledger used to materialize.
    let frame = status_frame(id, status, None).to_string();
    let mut buf = String::with_capacity(2 * RECALL_FLUSH);
    buf.push_str(frame.strip_suffix('}').unwrap_or(&frame));
    buf.push_str(",\"result\":{\"trials\":[");
    for (t, trial) in result.trials().iter().enumerate() {
        if t > 0 {
            buf.push(',');
        }
        buf.push('[');
        for (i, est) in trial.iter().enumerate() {
            if i > 0 {
                buf.push(',');
            }
            buf.push_str(&est.to_json().to_string());
            if buf.len() >= RECALL_FLUSH {
                if cw.write_part(&buf).is_err() {
                    return;
                }
                buf.clear();
            }
        }
        buf.push(']');
    }
    buf.push_str("]}}\n");
    if cw.write_part(&buf).is_ok() {
        let _ = cw.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_id_parsing() {
        assert_eq!(job_id("/v1/jobs/17"), Some(17));
        assert_eq!(job_id("/v1/jobs/"), None);
        assert_eq!(job_id("/v1/jobs/x"), None);
        assert_eq!(job_id("/v1/jobs"), None);
        assert_eq!(job_id("/v1/metrics"), None);
    }
}
