//! Minimal HTTP/1.1 on `std::io` — exactly what the jobs API needs.
//!
//! One request per connection (`Connection: close` on every response),
//! `Content-Length` request bodies, and chunked transfer encoding for
//! the job stream. Hand-rolled on purpose: the repo vendors no HTTP
//! dependency, and the wire surface is four routes of line-oriented
//! JSON, not a framework's worth of protocol.

use std::io::{BufRead, Read, Write};

use crate::util::json::Json;

/// Header count / line-length bounds — a parser this small refuses
/// pathological requests instead of buffering them.
const MAX_HEADERS: usize = 64;
const MAX_LINE: usize = 8 * 1024;

/// A parsed request: method, path, lowercased headers, body.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First value of `name` (ASCII case-insensitive, stored
    /// lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Why a request could not be read. The router maps these straight to
/// status codes; [`ReadError::Closed`] (peer hung up before a request
/// line) gets no response at all.
#[derive(Debug)]
pub enum ReadError {
    Closed,
    /// Malformed request line/headers → 400.
    Bad(String),
    /// Declared body over the server's bound → 413.
    TooLarge { limit: usize },
    Io(std::io::Error),
}

impl From<std::io::Error> for ReadError {
    fn from(e: std::io::Error) -> Self {
        ReadError::Io(e)
    }
}

fn read_line<R: BufRead>(r: &mut R) -> Result<String, ReadError> {
    let mut line = String::new();
    r.take(MAX_LINE as u64).read_line(&mut line)?;
    if line.len() >= MAX_LINE {
        return Err(ReadError::Bad("header line too long".into()));
    }
    while line.ends_with('\n') || line.ends_with('\r') {
        line.pop();
    }
    Ok(line)
}

/// Read one request. `max_body` bounds the declared `Content-Length`;
/// anything larger is refused before a single body byte is read.
pub fn read_request<R: BufRead>(
    r: &mut R,
    max_body: usize,
) -> Result<Request, ReadError> {
    let start = read_line(r)?;
    if start.is_empty() {
        return Err(ReadError::Closed);
    }
    let mut parts = start.split_whitespace();
    let (method, path, version) =
        match (parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(p), Some(v)) => (m, p, v),
            _ => {
                return Err(ReadError::Bad(format!(
                    "malformed request line '{start}'"
                )))
            }
        };
    if !version.starts_with("HTTP/1.") {
        return Err(ReadError::Bad(format!(
            "unsupported version '{version}'"
        )));
    }
    let mut headers = Vec::new();
    loop {
        let line = read_line(r)?;
        if line.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(ReadError::Bad("too many headers".into()));
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(ReadError::Bad(format!("malformed header '{line}'")));
        };
        headers
            .push((name.trim().to_ascii_lowercase(), value.trim().into()));
    }
    let req = Request {
        method: method.to_string(),
        path: path.to_string(),
        headers,
        body: vec![],
    };
    let len = match req.header("content-length") {
        None => 0,
        Some(v) => v.parse::<usize>().map_err(|_| {
            ReadError::Bad(format!("bad content-length '{v}'"))
        })?,
    };
    if len > max_body {
        return Err(ReadError::TooLarge { limit: max_body });
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(Request { body, ..req })
}

/// Reason phrase for the handful of codes the server speaks.
pub fn status_reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

/// Write a full response with `Content-Length` framing and
/// `Connection: close`.
pub fn write_response<W: Write>(
    w: &mut W,
    code: u16,
    headers: &[(&str, String)],
    body: &[u8],
) -> std::io::Result<()> {
    write!(w, "HTTP/1.1 {} {}\r\n", code, status_reason(code))?;
    write!(
        w,
        "connection: close\r\ncontent-length: {}\r\n",
        body.len()
    )?;
    for (name, value) in headers {
        write!(w, "{name}: {value}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// Write a JSON body (newline-terminated, `application/json`).
pub fn write_json<W: Write>(
    w: &mut W,
    code: u16,
    j: &Json,
) -> std::io::Result<()> {
    write_json_with(w, code, &[], j)
}

/// [`write_json`] plus extra headers (the 429 path's `Retry-After`).
pub fn write_json_with<W: Write>(
    w: &mut W,
    code: u16,
    headers: &[(&str, String)],
    j: &Json,
) -> std::io::Result<()> {
    let mut hs: Vec<(&str, String)> =
        vec![("content-type", "application/json".into())];
    hs.extend(headers.iter().map(|(n, v)| (*n, v.clone())));
    write_response(w, code, &hs, format!("{j}\n").as_bytes())
}

/// Chunked-encoding JSON-lines stream: one chunk per line, flushed
/// immediately so clients see each frame as the engine produces it.
pub struct ChunkedWriter<W: Write> {
    w: W,
}

impl<W: Write> ChunkedWriter<W> {
    /// Write the 200 header block and switch to chunked framing.
    pub fn start(mut w: W) -> std::io::Result<Self> {
        write!(
            w,
            "HTTP/1.1 200 OK\r\nconnection: close\r\n\
             content-type: application/x-ndjson\r\n\
             transfer-encoding: chunked\r\n\r\n"
        )?;
        w.flush()?;
        Ok(ChunkedWriter { w })
    }

    /// One JSON value as one newline-terminated chunk.
    pub fn write_line(&mut self, j: &Json) -> std::io::Result<()> {
        let line = format!("{j}\n");
        write!(self.w, "{:x}\r\n", line.len())?;
        self.w.write_all(line.as_bytes())?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    /// A raw body fragment as one chunk — no newline appended. The
    /// streaming recall path writes one large JSON document through
    /// here in bounded pieces, so the server never materializes the
    /// full body (the OOM guard for million-estimate results).
    pub fn write_part(&mut self, part: &str) -> std::io::Result<()> {
        if part.is_empty() {
            return Ok(()); // an empty chunk would terminate the stream
        }
        write!(self.w, "{:x}\r\n", part.len())?;
        self.w.write_all(part.as_bytes())?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    /// Terminal zero chunk.
    pub fn finish(mut self) -> std::io::Result<()> {
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_request_with_body() {
        let raw = b"POST /v1/jobs HTTP/1.1\r\nHost: x\r\n\
                    Content-Length: 4\r\n\r\n{\"a\"";
        let req = read_request(&mut Cursor::new(&raw[..]), 1024).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/jobs");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("Content-Length"), Some("4"));
        assert_eq!(req.body, b"{\"a\"");
    }

    #[test]
    fn rejects_bad_requests() {
        let e = read_request(&mut Cursor::new(&b"\r\n"[..]), 10)
            .unwrap_err();
        assert!(matches!(e, ReadError::Closed));
        let e = read_request(&mut Cursor::new(&b"GET /\r\n\r\n"[..]), 10)
            .unwrap_err();
        assert!(matches!(e, ReadError::Bad(_)));
        let e = read_request(
            &mut Cursor::new(&b"GET / SPDY/9\r\n\r\n"[..]),
            10,
        )
        .unwrap_err();
        assert!(matches!(e, ReadError::Bad(_)));
        let raw = b"POST / HTTP/1.1\r\ncontent-length: 99\r\n\r\n";
        let e = read_request(&mut Cursor::new(&raw[..]), 10).unwrap_err();
        assert!(matches!(e, ReadError::TooLarge { limit: 10 }));
    }

    #[test]
    fn response_framing() {
        let mut out = Vec::new();
        write_json_with(
            &mut out,
            429,
            &[("retry-after", "2".into())],
            &Json::parse(r#"{"e":1}"#).unwrap(),
        )
        .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("retry-after: 2\r\n"));
        assert!(text.contains("content-length: 8\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"e\":1}\n"));
    }

    #[test]
    fn chunked_framing() {
        let mut out = Vec::new();
        let mut cw = ChunkedWriter::start(&mut out).unwrap();
        cw.write_line(&Json::parse("[1,2]").unwrap()).unwrap();
        cw.finish().unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("transfer-encoding: chunked\r\n"));
        // "[1,2]\n" is 6 bytes -> chunk header "6"
        assert!(text.ends_with("\r\n6\r\n[1,2]\n\r\n0\r\n\r\n"), "{text}");
    }

    #[test]
    fn chunked_parts_concatenate_without_newlines() {
        let mut out = Vec::new();
        let mut cw = ChunkedWriter::start(&mut out).unwrap();
        cw.write_part("{\"a\":").unwrap();
        cw.write_part("").unwrap(); // must NOT terminate the stream
        cw.write_part("1}").unwrap();
        cw.finish().unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(
            text.ends_with("\r\n5\r\n{\"a\":\r\n2\r\n1}\r\n0\r\n\r\n"),
            "{text}"
        );
    }
}
