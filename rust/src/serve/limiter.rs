//! Per-client token-bucket rate limiting for the jobs endpoint.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

/// Classic token bucket, one bucket per client key (the peer IP).
/// Buckets start full at `burst` tokens, refill at `rate` tokens per
/// second, and each admitted request costs one token; an empty bucket
/// rejects with the whole-second wait until the next token — the 429
/// response's `Retry-After` value.
///
/// Time is measured against the limiter's construction instant and
/// injected into [`admit_at`](Self::admit_at) as plain seconds, so
/// tests exercise refill arithmetic without sleeping.
pub struct RateLimiter {
    rate: f64,
    burst: f64,
    t0: Instant,
    buckets: Mutex<HashMap<String, Bucket>>,
}

struct Bucket {
    tokens: f64,
    /// Seconds-since-`t0` of the last refill.
    last: f64,
}

impl RateLimiter {
    /// `rate` requests/second sustained, bursts up to `burst` (both
    /// clamped to sane minima).
    pub fn new(rate: f64, burst: f64) -> Self {
        RateLimiter {
            rate: rate.max(1e-9),
            burst: burst.max(1.0),
            t0: Instant::now(),
            buckets: Mutex::new(HashMap::new()),
        }
    }

    /// Admit or reject a request from `key` now. `Err(secs)` is the
    /// suggested `Retry-After`.
    pub fn admit(&self, key: &str) -> Result<(), u64> {
        self.admit_at(key, self.t0.elapsed().as_secs_f64())
    }

    /// [`admit`](Self::admit) at an explicit time (seconds since the
    /// limiter was built) — the test seam.
    pub fn admit_at(&self, key: &str, now: f64) -> Result<(), u64> {
        let mut buckets = self.buckets.lock().unwrap();
        let b = buckets
            .entry(key.to_string())
            .or_insert(Bucket { tokens: self.burst, last: now });
        b.tokens = (b.tokens + (now - b.last).max(0.0) * self.rate)
            .min(self.burst);
        b.last = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            Ok(())
        } else {
            let wait = (1.0 - b.tokens) / self.rate;
            Err((wait.ceil() as u64).max(1))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_then_refill() {
        let l = RateLimiter::new(1.0, 2.0);
        assert!(l.admit_at("a", 0.0).is_ok());
        assert!(l.admit_at("a", 0.0).is_ok());
        // bucket empty: one token is a second away
        assert_eq!(l.admit_at("a", 0.0), Err(1));
        // half a second refills half a token -> still rejected
        assert_eq!(l.admit_at("a", 0.5), Err(1));
        // past one second of refill -> admitted again
        assert!(l.admit_at("a", 1.6).is_ok());
    }

    #[test]
    fn keys_are_independent_and_capped() {
        let l = RateLimiter::new(0.5, 1.0);
        assert!(l.admit_at("a", 0.0).is_ok());
        // a different client has its own bucket
        assert!(l.admit_at("b", 0.0).is_ok());
        // retry-after reflects the slow rate: 1 token / 0.5 per sec
        assert_eq!(l.admit_at("a", 0.0), Err(2));
        // a long idle stretch never overfills past the burst cap
        assert!(l.admit_at("a", 1e6).is_ok());
        assert_eq!(l.admit_at("a", 1e6), Err(2));
    }
}
