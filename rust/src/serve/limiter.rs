//! Per-client token-bucket rate limiting for the jobs endpoint.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::engine::core::lock_ok;

/// Classic token bucket, one bucket per client key (the peer IP).
/// Buckets start full at `burst` tokens, refill at `rate` tokens per
/// second, and each admitted request costs one token; an empty bucket
/// rejects with the whole-second wait until the next token — the 429
/// response's `Retry-After` value.
///
/// The bucket map is **bounded by the live client set**, not by every
/// IP ever seen: a periodic sweep evicts buckets that have been idle
/// long enough to refill completely. A refill-complete bucket is
/// indistinguishable from a fresh one (`tokens == burst`), so eviction
/// never changes an admit decision — it only caps memory on a server
/// exposed to IP churn.
///
/// Time is measured against the limiter's construction instant and
/// injected into [`admit_at`](Self::admit_at) as plain seconds, so
/// tests exercise refill arithmetic without sleeping.
pub struct RateLimiter {
    rate: f64,
    burst: f64,
    t0: Instant,
    state: Mutex<Buckets>,
}

struct Buckets {
    map: HashMap<String, Bucket>,
    /// Seconds-since-`t0` of the last eviction sweep.
    last_sweep: f64,
}

struct Bucket {
    tokens: f64,
    /// Seconds-since-`t0` of the last refill.
    last: f64,
}

impl RateLimiter {
    /// `rate` requests/second sustained, bursts up to `burst` (both
    /// clamped to sane minima).
    pub fn new(rate: f64, burst: f64) -> Self {
        RateLimiter {
            rate: rate.max(1e-9),
            burst: burst.max(1.0),
            t0: Instant::now(),
            state: Mutex::new(Buckets {
                map: HashMap::new(),
                last_sweep: 0.0,
            }),
        }
    }

    /// Admit or reject a request from `key` now. `Err(secs)` is the
    /// suggested `Retry-After`.
    pub fn admit(&self, key: &str) -> Result<(), u64> {
        self.admit_at(key, self.t0.elapsed().as_secs_f64())
    }

    /// [`admit`](Self::admit) at an explicit time (seconds since the
    /// limiter was built) — the test seam.
    pub fn admit_at(&self, key: &str, now: f64) -> Result<(), u64> {
        let mut state = lock_ok(&self.state);
        // sweep at most once per full-refill period: an O(n) pass
        // amortized over at least n token grants
        let sweep_every = (self.burst / self.rate).max(1.0);
        if now - state.last_sweep >= sweep_every {
            state.last_sweep = now;
            let (rate, burst) = (self.rate, self.burst);
            // idle >= time-to-full ⇒ the bucket is full again, i.e.
            // exactly the state a brand-new entry would start in
            state
                .map
                .retain(|_, b| now - b.last < (burst - b.tokens) / rate);
        }
        let (rate, burst) = (self.rate, self.burst);
        let b = state
            .map
            .entry(key.to_string())
            .or_insert(Bucket { tokens: burst, last: now });
        b.tokens =
            (b.tokens + (now - b.last).max(0.0) * rate).min(burst);
        b.last = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            Ok(())
        } else {
            let wait = (1.0 - b.tokens) / rate;
            Err((wait.ceil() as u64).max(1))
        }
    }

    /// Buckets currently retained (test seam for the eviction sweep).
    pub fn n_buckets(&self) -> usize {
        lock_ok(&self.state).map.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_then_refill() {
        let l = RateLimiter::new(1.0, 2.0);
        assert!(l.admit_at("a", 0.0).is_ok());
        assert!(l.admit_at("a", 0.0).is_ok());
        // bucket empty: one token is a second away
        assert_eq!(l.admit_at("a", 0.0), Err(1));
        // half a second refills half a token -> still rejected
        assert_eq!(l.admit_at("a", 0.5), Err(1));
        // past one second of refill -> admitted again
        assert!(l.admit_at("a", 1.6).is_ok());
    }

    #[test]
    fn keys_are_independent_and_capped() {
        let l = RateLimiter::new(0.5, 1.0);
        assert!(l.admit_at("a", 0.0).is_ok());
        // a different client has its own bucket
        assert!(l.admit_at("b", 0.0).is_ok());
        // retry-after reflects the slow rate: 1 token / 0.5 per sec
        assert_eq!(l.admit_at("a", 0.0), Err(2));
        // a long idle stretch never overfills past the burst cap
        assert!(l.admit_at("a", 1e6).is_ok());
        assert_eq!(l.admit_at("a", 1e6), Err(2));
    }

    #[test]
    fn key_churn_does_not_retain_every_bucket() {
        // rate 1/s, burst 2 -> full refill takes 2 s; clients arrive
        // 10 s apart, so each sweep can evict everyone idle before it
        let l = RateLimiter::new(1.0, 2.0);
        for k in 0..1000u32 {
            let now = 10.0 * k as f64;
            assert!(l.admit_at(&format!("ip-{k}"), now).is_ok());
            assert!(
                l.n_buckets() <= 2,
                "retained {} buckets after {} distinct keys",
                l.n_buckets(),
                k + 1
            );
        }
    }

    #[test]
    fn eviction_never_changes_admit_decisions() {
        // a client that drained its bucket and waited a *partial*
        // refill must keep its debt across sweeps triggered by others
        let l = RateLimiter::new(1.0, 2.0);
        assert!(l.admit_at("slow", 0.0).is_ok());
        assert!(l.admit_at("slow", 0.0).is_ok());
        assert_eq!(l.admit_at("slow", 0.0), Err(1));
        // another key triggers a sweep at t=3; "slow" updated at t=0
        // with 0 tokens needs 2 s to refill, so 3 s idle evicts it —
        // but an evicted-then-recreated bucket is full, exactly what
        // 3 s of refill (capped at burst) would have produced anyway
        assert!(l.admit_at("other", 3.0).is_ok());
        assert!(l.admit_at("slow", 3.0).is_ok());
        assert!(l.admit_at("slow", 3.0).is_ok());
        assert_eq!(l.admit_at("slow", 3.0), Err(1));
        // partial refill is preserved: at t=3.5 "slow" (last=3.0,
        // 0 tokens) is NOT refill-complete, so a sweep cannot evict
        // it and its half-token debt stands
        assert_eq!(l.admit_at("slow", 3.5), Err(1));
    }
}
