//! Append-only JSON-lines job journal + restart replay.
//!
//! Every accepted job writes a `submit` record before its first
//! launch and exactly one terminal record (`done`/`failed`) after; a
//! restarted server replays the file to recover finished results for
//! `GET /v1/jobs/{id}` recall and to **re-run** jobs that were cut off
//! mid-flight — jobs are data, and the engine is deterministic, so a
//! re-run reproduces the lost results bit-for-bit.
//!
//! Line shapes (one JSON object per line, `"v": 1` like every other
//! wire surface):
//!
//! ```json
//! {"v":1,"event":"submit","id":3,"config":{...job config...}}
//! {"v":1,"event":"done","id":3,"result":{"trials":[[...]]}}
//! {"v":1,"event":"failed","id":3,"error":{"code":"...","message":"..."}}
//! ```
//!
//! A crash can truncate the final line; [`Journal::load`] skips
//! unparseable lines instead of refusing the whole file.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::util::json::Json;

const FILE_NAME: &str = "jobs.jsonl";

/// The append side: owned by a running server, one line per event.
pub struct Journal {
    path: PathBuf,
    file: Mutex<File>,
}

impl Journal {
    /// Open (creating `state_dir` and the journal file as needed) for
    /// appending.
    pub fn open(state_dir: &Path) -> Result<Journal> {
        std::fs::create_dir_all(state_dir).with_context(|| {
            format!("creating state dir {}", state_dir.display())
        })?;
        let path = state_dir.join(FILE_NAME);
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening {}", path.display()))?;
        Ok(Journal { path, file: Mutex::new(file) })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn submitted(&self, id: u64, config: &Json) -> Result<()> {
        self.record("submit", id, ("config", config))
    }

    pub fn done(&self, id: u64, result: &Json) -> Result<()> {
        self.record("done", id, ("result", result))
    }

    pub fn failed(&self, id: u64, error: &Json) -> Result<()> {
        self.record("failed", id, ("error", error))
    }

    fn record(
        &self,
        event: &str,
        id: u64,
        payload: (&str, &Json),
    ) -> Result<()> {
        let mut m = BTreeMap::new();
        m.insert("v".to_string(), Json::Num(1.0));
        m.insert("event".to_string(), Json::Str(event.to_string()));
        m.insert("id".to_string(), Json::Num(id as f64));
        m.insert(payload.0.to_string(), payload.1.clone());
        let line = format!("{}\n", Json::Obj(m));
        let mut f = self.file.lock().unwrap();
        f.write_all(line.as_bytes())?;
        f.flush()?;
        Ok(())
    }
}

/// One journaled job after replay.
#[derive(Debug, Clone)]
pub struct ReplayJob {
    pub id: u64,
    pub config: Json,
    /// `None` = the server died with this job in flight (re-run it).
    pub outcome: Option<Outcome>,
}

/// A job's terminal record.
#[derive(Debug, Clone)]
pub enum Outcome {
    Done(Json),
    Failed(Json),
}

/// Everything [`Journal::load`] recovered.
#[derive(Debug, Default)]
pub struct Replay {
    /// Jobs in id order.
    pub jobs: Vec<ReplayJob>,
    /// First unused job id.
    pub next_id: u64,
}

impl Journal {
    /// Parse the journal under `state_dir` (absent file = empty
    /// replay). Unparseable lines — a crash-truncated tail — are
    /// skipped; terminal records without a `submit` are ignored.
    pub fn load(state_dir: &Path) -> Result<Replay> {
        let path = state_dir.join(FILE_NAME);
        let mut jobs: BTreeMap<u64, ReplayJob> = BTreeMap::new();
        let mut max_id = 0u64;
        if path.exists() {
            let f = File::open(&path)
                .with_context(|| format!("opening {}", path.display()))?;
            for line in BufReader::new(f).lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                let Ok(j) = Json::parse(&line) else {
                    continue; // truncated tail
                };
                let Some(id) =
                    j.get("id").and_then(Json::as_i64).filter(|&i| i > 0)
                else {
                    continue;
                };
                let id = id as u64;
                match j.get("event").and_then(Json::as_str) {
                    Some("submit") => {
                        let Some(config) = j.get("config") else {
                            continue;
                        };
                        max_id = max_id.max(id);
                        jobs.insert(
                            id,
                            ReplayJob {
                                id,
                                config: config.clone(),
                                outcome: None,
                            },
                        );
                    }
                    Some("done") => {
                        if let (Some(job), Some(r)) =
                            (jobs.get_mut(&id), j.get("result"))
                        {
                            job.outcome = Some(Outcome::Done(r.clone()));
                        }
                    }
                    Some("failed") => {
                        if let (Some(job), Some(e)) =
                            (jobs.get_mut(&id), j.get("error"))
                        {
                            job.outcome =
                                Some(Outcome::Failed(e.clone()));
                        }
                    }
                    _ => {}
                }
            }
        }
        Ok(Replay {
            jobs: jobs.into_values().collect(),
            next_id: max_id + 1,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "zmc_journal_{tag}_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    #[test]
    fn round_trip_and_unfinished_detection() {
        let dir = temp_dir("rt");
        let j = Journal::open(&dir).unwrap();
        let cfg = Json::parse(r#"{"seed": 7}"#).unwrap();
        j.submitted(1, &cfg).unwrap();
        j.done(1, &Json::parse(r#"{"trials":[]}"#).unwrap()).unwrap();
        j.submitted(2, &cfg).unwrap();
        j.failed(2, &Json::parse(r#"{"code":"error"}"#).unwrap())
            .unwrap();
        j.submitted(3, &cfg).unwrap(); // no terminal: died in flight
        let replay = Journal::load(&dir).unwrap();
        assert_eq!(replay.next_id, 4);
        assert_eq!(replay.jobs.len(), 3);
        assert!(matches!(replay.jobs[0].outcome, Some(Outcome::Done(_))));
        assert!(matches!(
            replay.jobs[1].outcome,
            Some(Outcome::Failed(_))
        ));
        assert!(replay.jobs[2].outcome.is_none());
        assert_eq!(
            replay.jobs[2].config.get("seed").and_then(Json::as_i64),
            Some(7)
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tolerates_truncated_tail_and_missing_file() {
        let dir = temp_dir("tail");
        let empty = Journal::load(&dir).unwrap();
        assert_eq!(empty.next_id, 1);
        assert!(empty.jobs.is_empty());

        let j = Journal::open(&dir).unwrap();
        j.submitted(5, &Json::parse("{}").unwrap()).unwrap();
        // simulate a crash mid-append
        let mut f = OpenOptions::new()
            .append(true)
            .open(dir.join(FILE_NAME))
            .unwrap();
        f.write_all(b"{\"v\":1,\"event\":\"done\",\"id\":5,\"res")
            .unwrap();
        drop(f);
        let replay = Journal::load(&dir).unwrap();
        assert_eq!(replay.jobs.len(), 1);
        assert!(replay.jobs[0].outcome.is_none()); // still unfinished
        assert_eq!(replay.next_id, 6);
        std::fs::remove_dir_all(&dir).ok();
    }
}
