//! Append-only JSON-lines job journal + restart replay.
//!
//! Every accepted job writes a `submit` record before its first
//! launch and exactly one terminal record (`done`/`failed`) after; a
//! restarted server replays the file to recover finished results for
//! `GET /v1/jobs/{id}` recall and to **re-run** jobs that were cut off
//! mid-flight — jobs are data, and the engine is deterministic, so a
//! re-run reproduces the lost results bit-for-bit.
//!
//! Line shapes (one JSON object per line, `"v": 1` like every other
//! wire surface):
//!
//! ```json
//! {"v":1,"event":"submit","id":3,"config":{...job config...}}
//! {"v":1,"event":"done","id":3,"result":{"trials":[[...]]}}
//! {"v":1,"event":"failed","id":3,"error":{"code":"...","message":"..."}}
//! {"v":1,"event":"seq","id":12}
//! ```
//!
//! A crash can truncate the final line; [`Journal::load`] skips
//! unparseable lines instead of refusing the whole file.
//!
//! Append-only means unbounded: a long-lived server rewrites the file
//! on restart ([`Journal::compact`]) down to its unfinished jobs plus
//! the last N finished ones. The `seq` record pins the id counter so
//! pruned ids are never reissued, and the rewrite goes through a tmp
//! file and an atomic rename — a crash mid-compaction leaves either
//! the old journal or the new one, never a torn hybrid.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::util::json::Json;

const FILE_NAME: &str = "jobs.jsonl";

/// The append side: owned by a running server, one line per event.
pub struct Journal {
    path: PathBuf,
    file: Mutex<File>,
}

impl Journal {
    /// Open (creating `state_dir` and the journal file as needed) for
    /// appending.
    pub fn open(state_dir: &Path) -> Result<Journal> {
        std::fs::create_dir_all(state_dir).with_context(|| {
            format!("creating state dir {}", state_dir.display())
        })?;
        let path = state_dir.join(FILE_NAME);
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening {}", path.display()))?;
        Ok(Journal { path, file: Mutex::new(file) })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn submitted(&self, id: u64, config: &Json) -> Result<()> {
        self.record("submit", id, ("config", config))
    }

    pub fn done(&self, id: u64, result: &Json) -> Result<()> {
        self.record("done", id, ("result", result))
    }

    pub fn failed(&self, id: u64, error: &Json) -> Result<()> {
        self.record("failed", id, ("error", error))
    }

    fn record(
        &self,
        event: &str,
        id: u64,
        payload: (&str, &Json),
    ) -> Result<()> {
        let line = event_line(event, id, Some(payload));
        let mut f = self.file.lock().unwrap();
        f.write_all(line.as_bytes())?;
        f.flush()?;
        Ok(())
    }
}

/// One journal line, newline-terminated.
fn event_line(
    event: &str,
    id: u64,
    payload: Option<(&str, &Json)>,
) -> String {
    let mut m = BTreeMap::new();
    m.insert("v".to_string(), Json::Num(1.0));
    m.insert("event".to_string(), Json::Str(event.to_string()));
    m.insert("id".to_string(), Json::Num(id as f64));
    if let Some((k, v)) = payload {
        m.insert(k.to_string(), v.clone());
    }
    format!("{}\n", Json::Obj(m))
}

/// One journaled job after replay.
#[derive(Debug, Clone)]
pub struct ReplayJob {
    pub id: u64,
    pub config: Json,
    /// `None` = the server died with this job in flight (re-run it).
    pub outcome: Option<Outcome>,
}

/// A job's terminal record.
#[derive(Debug, Clone)]
pub enum Outcome {
    Done(Json),
    Failed(Json),
}

/// Everything [`Journal::load`] recovered.
#[derive(Debug, Default)]
pub struct Replay {
    /// Jobs in id order.
    pub jobs: Vec<ReplayJob>,
    /// First unused job id.
    pub next_id: u64,
}

impl Journal {
    /// Parse the journal under `state_dir` (absent file = empty
    /// replay). Unparseable lines — a crash-truncated tail — are
    /// skipped; terminal records without a `submit` are ignored.
    pub fn load(state_dir: &Path) -> Result<Replay> {
        let path = state_dir.join(FILE_NAME);
        let mut jobs: BTreeMap<u64, ReplayJob> = BTreeMap::new();
        let mut max_id = 0u64;
        if path.exists() {
            let f = File::open(&path)
                .with_context(|| format!("opening {}", path.display()))?;
            for line in BufReader::new(f).lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                let Ok(j) = Json::parse(&line) else {
                    continue; // truncated tail
                };
                let Some(id) =
                    j.get("id").and_then(Json::as_i64).filter(|&i| i > 0)
                else {
                    continue;
                };
                let id = id as u64;
                match j.get("event").and_then(Json::as_str) {
                    Some("submit") => {
                        let Some(config) = j.get("config") else {
                            continue;
                        };
                        max_id = max_id.max(id);
                        jobs.insert(
                            id,
                            ReplayJob {
                                id,
                                config: config.clone(),
                                outcome: None,
                            },
                        );
                    }
                    Some("done") => {
                        if let (Some(job), Some(r)) =
                            (jobs.get_mut(&id), j.get("result"))
                        {
                            job.outcome = Some(Outcome::Done(r.clone()));
                        }
                    }
                    Some("failed") => {
                        if let (Some(job), Some(e)) =
                            (jobs.get_mut(&id), j.get("error"))
                        {
                            job.outcome =
                                Some(Outcome::Failed(e.clone()));
                        }
                    }
                    // compaction's id pin: ids up to here were issued
                    // even though their records are gone
                    Some("seq") => max_id = max_id.max(id),
                    _ => {}
                }
            }
        }
        Ok(Replay {
            jobs: jobs.into_values().collect(),
            next_id: max_id + 1,
        })
    }

    /// Rewrite the journal down to every unfinished job (those get
    /// re-run on restart) plus the last `keep` finished ones, and
    /// return the correspondingly pruned replay. A `seq` record pins
    /// the id counter so pruned ids are never reissued. The rewrite is
    /// tmp-file + atomic rename: a crash mid-compaction leaves either
    /// the old journal or the new one on disk (a stale `.tmp` is
    /// truncated by the next compaction and never loaded). When
    /// nothing is over the bound the file is left untouched.
    pub fn compact(
        state_dir: &Path,
        replay: Replay,
        keep: usize,
    ) -> Result<Replay> {
        let path = state_dir.join(FILE_NAME);
        let finished = replay
            .jobs
            .iter()
            .filter(|job| job.outcome.is_some())
            .count();
        if !path.exists() || finished <= keep {
            return Ok(replay);
        }
        let next_id = replay.next_id;
        // jobs are in id order, so dropping the first (finished -
        // keep) finished ones keeps the most recent `keep`
        let mut drop_left = finished - keep;
        let jobs: Vec<ReplayJob> = replay
            .jobs
            .into_iter()
            .filter(|job| {
                if job.outcome.is_some() && drop_left > 0 {
                    drop_left -= 1;
                    false
                } else {
                    true
                }
            })
            .collect();

        let tmp = state_dir.join(format!("{FILE_NAME}.tmp"));
        let mut f = File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        let max_id = next_id.saturating_sub(1);
        if max_id > 0 {
            f.write_all(event_line("seq", max_id, None).as_bytes())?;
        }
        for job in &jobs {
            f.write_all(
                event_line("submit", job.id, Some(("config", &job.config)))
                    .as_bytes(),
            )?;
            match &job.outcome {
                Some(Outcome::Done(r)) => f.write_all(
                    event_line("done", job.id, Some(("result", r)))
                        .as_bytes(),
                )?,
                Some(Outcome::Failed(e)) => f.write_all(
                    event_line("failed", job.id, Some(("error", e)))
                        .as_bytes(),
                )?,
                None => {}
            }
        }
        f.sync_all()
            .with_context(|| format!("syncing {}", tmp.display()))?;
        drop(f);
        std::fs::rename(&tmp, &path).with_context(|| {
            format!("renaming {} over {}", tmp.display(), path.display())
        })?;
        Ok(Replay { jobs, next_id })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "zmc_journal_{tag}_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    #[test]
    fn round_trip_and_unfinished_detection() {
        let dir = temp_dir("rt");
        let j = Journal::open(&dir).unwrap();
        let cfg = Json::parse(r#"{"seed": 7}"#).unwrap();
        j.submitted(1, &cfg).unwrap();
        j.done(1, &Json::parse(r#"{"trials":[]}"#).unwrap()).unwrap();
        j.submitted(2, &cfg).unwrap();
        j.failed(2, &Json::parse(r#"{"code":"error"}"#).unwrap())
            .unwrap();
        j.submitted(3, &cfg).unwrap(); // no terminal: died in flight
        let replay = Journal::load(&dir).unwrap();
        assert_eq!(replay.next_id, 4);
        assert_eq!(replay.jobs.len(), 3);
        assert!(matches!(replay.jobs[0].outcome, Some(Outcome::Done(_))));
        assert!(matches!(
            replay.jobs[1].outcome,
            Some(Outcome::Failed(_))
        ));
        assert!(replay.jobs[2].outcome.is_none());
        assert_eq!(
            replay.jobs[2].config.get("seed").and_then(Json::as_i64),
            Some(7)
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn compaction_bounds_history_and_pins_ids() {
        let dir = temp_dir("compact");
        let j = Journal::open(&dir).unwrap();
        let cfg = Json::parse(r#"{"seed":1}"#).unwrap();
        let res = Json::parse(r#"{"trials":[]}"#).unwrap();
        for id in 1..=10u64 {
            j.submitted(id, &cfg).unwrap();
            j.done(id, &res).unwrap();
        }
        j.submitted(11, &cfg).unwrap(); // died in flight
        drop(j);

        let replay = Journal::load(&dir).unwrap();
        let compacted = Journal::compact(&dir, replay, 3).unwrap();
        let ids: Vec<u64> =
            compacted.jobs.iter().map(|job| job.id).collect();
        // the last 3 finished jobs plus the unfinished one survive
        assert_eq!(ids, vec![8, 9, 10, 11]);
        assert_eq!(compacted.next_id, 12);

        // the rewritten file reloads to the same state: pruned ids
        // stay retired via the seq record
        let reloaded = Journal::load(&dir).unwrap();
        assert_eq!(reloaded.next_id, 12);
        assert_eq!(
            reloaded.jobs.iter().map(|job| job.id).collect::<Vec<_>>(),
            ids
        );
        assert!(matches!(
            reloaded.jobs[0].outcome,
            Some(Outcome::Done(_))
        ));
        assert!(reloaded.jobs[3].outcome.is_none());

        // under the bound: a second compaction is a no-op
        let before = std::fs::read(dir.join(FILE_NAME)).unwrap();
        let again = Journal::compact(&dir, reloaded, 3).unwrap();
        assert_eq!(again.jobs.len(), 4);
        assert_eq!(before, std::fs::read(dir.join(FILE_NAME)).unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn seq_record_pins_ids_even_when_everything_is_pruned() {
        let dir = temp_dir("seq");
        let j = Journal::open(&dir).unwrap();
        let cfg = Json::parse("{}").unwrap();
        let res = Json::parse(r#"{"trials":[]}"#).unwrap();
        for id in 1..=5u64 {
            j.submitted(id, &cfg).unwrap();
            j.done(id, &res).unwrap();
        }
        drop(j);
        let replay = Journal::load(&dir).unwrap();
        let compacted = Journal::compact(&dir, replay, 0).unwrap();
        assert!(compacted.jobs.is_empty());
        assert_eq!(compacted.next_id, 6);
        let reloaded = Journal::load(&dir).unwrap();
        assert!(reloaded.jobs.is_empty());
        assert_eq!(reloaded.next_id, 6, "ids must never be reissued");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crash_mid_compaction_leaves_a_loadable_journal() {
        let dir = temp_dir("crash");
        let j = Journal::open(&dir).unwrap();
        let cfg = Json::parse(r#"{"seed":9}"#).unwrap();
        let res = Json::parse(r#"{"trials":[]}"#).unwrap();
        for id in 1..=4u64 {
            j.submitted(id, &cfg).unwrap();
            j.done(id, &res).unwrap();
        }
        drop(j);
        // simulate a crash before the rename: a torn tmp file next to
        // an intact journal
        let tmp = dir.join(format!("{FILE_NAME}.tmp"));
        std::fs::write(&tmp, b"{\"v\":1,\"event\":\"seq\",\"i").unwrap();
        // the torn tmp is never loaded...
        let replay = Journal::load(&dir).unwrap();
        assert_eq!(replay.jobs.len(), 4);
        assert_eq!(replay.next_id, 5);
        // ...and the next compaction truncates it and completes
        let compacted = Journal::compact(&dir, replay, 1).unwrap();
        assert_eq!(
            compacted.jobs.iter().map(|job| job.id).collect::<Vec<_>>(),
            vec![4]
        );
        assert!(!tmp.exists(), "tmp renamed over the journal");
        let reloaded = Journal::load(&dir).unwrap();
        assert_eq!(reloaded.jobs.len(), 1);
        assert_eq!(reloaded.next_id, 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tolerates_truncated_tail_and_missing_file() {
        let dir = temp_dir("tail");
        let empty = Journal::load(&dir).unwrap();
        assert_eq!(empty.next_id, 1);
        assert!(empty.jobs.is_empty());

        let j = Journal::open(&dir).unwrap();
        j.submitted(5, &Json::parse("{}").unwrap()).unwrap();
        // simulate a crash mid-append
        let mut f = OpenOptions::new()
            .append(true)
            .open(dir.join(FILE_NAME))
            .unwrap();
        f.write_all(b"{\"v\":1,\"event\":\"done\",\"id\":5,\"res")
            .unwrap();
        drop(f);
        let replay = Journal::load(&dir).unwrap();
        assert_eq!(replay.jobs.len(), 1);
        assert!(replay.jobs[0].outcome.is_none()); // still unfinished
        assert_eq!(replay.next_id, 6);
        std::fs::remove_dir_all(&dir).ok();
    }
}
