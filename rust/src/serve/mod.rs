//! `zmc serve` — integration as a service: a versioned jobs-as-data
//! wire API over one warm [`Session`].
//!
//! The paper's deployment story stops at a Python script per run; this
//! module turns the repo's job files into a *service*. A hand-rolled
//! HTTP/1.1 front end (no new dependencies — [`http`] is ~200 lines on
//! `std::net`) exposes four routes:
//!
//! | route | does |
//! |---|---|
//! | `POST /v1/jobs` | submit a [`JobConfig`] JSON body; streams per-round/per-trial estimate frames as chunked JSON lines while the job runs, ending in a terminal `status` frame |
//! | `GET /v1/jobs/{id}` | recall a job's status and (once finished) its result |
//! | `GET /v1/metrics` | engine metrics + registry ledgers + server counters |
//! | `GET /v1/healthz` | liveness + session topology |
//!
//! Every payload carries `"v": 1` — the same wire version as the job
//! files themselves ([`crate::config::WIRE_VERSION`]) — and every
//! estimate frame is the [`Estimate::to_json`] shape, so `zmc run
//! --json` output, stream frames, and recalled results are one codec.
//!
//! All jobs run on **one** shared session: its registry, device
//! workers, and executable caches stay warm across requests, which is
//! the entire point of serving (the per-run session build the CLI pays
//! is amortized to zero). Because the engine is deterministic, results
//! are bit-identical to `zmc run` with the same config, at any
//! `--workers`/`--engines` topology, under any request interleaving.
//!
//! Production edges: per-client token-bucket rate limiting
//! ([`limiter`], 429 + `Retry-After`), admission control bounding
//! concurrent jobs (429) and pending connections (503), a bounded
//! worker pool with graceful drain on shutdown, and an append-only
//! job journal ([`journal`]) that replays unfinished jobs on restart —
//! deterministically reproducing the results a crash threw away.

mod http;
mod journal;
mod limiter;
mod router;

pub use self::journal::{Journal, Outcome, Replay, ReplayJob};
pub use self::limiter::RateLimiter;

use std::collections::{BTreeMap, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::batch::BatchResults;
use crate::config::JobConfig;
use crate::coordinator::progress::Metrics;
use crate::engine::core::{lock_ok, panic_message, wait_ok};
use crate::integrator::spec::Estimate;
use crate::runtime::ExecTier;
use crate::session::{ErrorPayload, JobOutput, Session};
use crate::util::json::Json;

/// Everything `zmc serve` configures.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address (`host:port`; port 0 picks a free one).
    pub addr: String,
    /// Device workers per engine of the shared session.
    pub workers: usize,
    /// Engines behind the shared session.
    pub engines: usize,
    /// Remote worker addresses (`host:port` of running `zmc worker`
    /// processes) added to the shared session's cluster.
    pub remotes: Vec<String>,
    /// Connection-handler threads; each runs at most one job at a
    /// time, so this also caps streaming clients.
    pub http_workers: usize,
    /// Admitted jobs in flight; beyond it `POST /v1/jobs` answers 429
    /// with `Retry-After`.
    pub max_jobs: usize,
    /// Accepted-but-unhandled connections; beyond it the acceptor
    /// answers 503 immediately.
    pub queue_cap: usize,
    /// Per-client sustained job submissions per second (burst size
    /// [`rate_burst`](Self::rate_burst)); `None` = unlimited.
    pub rate_limit: Option<f64>,
    pub rate_burst: f64,
    /// Journal directory; `None` = no persistence, no restart replay.
    pub state_dir: Option<PathBuf>,
    /// Explicit artifact dir (strict load); `None` = `artifacts` with
    /// emulator fallback, like the CLI.
    pub artifacts: Option<String>,
    /// Pin the session's emulator execution tier.
    pub tier: Option<ExecTier>,
    /// Request-body bound; larger submissions answer 413.
    pub max_body: usize,
    /// Per-read deadline on client sockets: an idle or drip-feeding
    /// connection (slowloris) is answered 408 and closed instead of
    /// pinning an http worker forever. `Duration::ZERO` disables the
    /// guard.
    pub read_timeout: Duration,
    /// Estimate-count bound on `GET /v1/jobs/{id}` recall; a stored
    /// result with more total estimates answers 413 instead of
    /// streaming gigabytes to a casual poll.
    pub max_recall: usize,
    /// Finished jobs kept when the journal is compacted on restart
    /// (unfinished jobs are always kept).
    pub journal_keep: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7311".into(),
            workers: 1,
            engines: 1,
            remotes: Vec::new(),
            http_workers: 4,
            max_jobs: 2,
            queue_cap: 16,
            rate_limit: None,
            rate_burst: 8.0,
            state_dir: None,
            artifacts: None,
            tier: None,
            max_body: 1 << 20,
            read_timeout: Duration::from_secs(10),
            max_recall: 1 << 20,
            journal_keep: 256,
        }
    }
}

/// Server-side request counters (engine metrics live on the session).
#[derive(Debug, Default)]
pub struct ServerMetrics {
    pub accepted: AtomicU64,
    pub done: AtomicU64,
    pub failed: AtomicU64,
    /// 429s from the concurrent-jobs bound.
    pub rejected_busy: AtomicU64,
    /// 429s from the per-client rate limiter.
    pub rejected_rate: AtomicU64,
    /// 503s from the connection-queue bound.
    pub rejected_queue: AtomicU64,
    pub bad_requests: AtomicU64,
}

impl ServerMetrics {
    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        let pairs: [(&str, &AtomicU64); 7] = [
            ("accepted", &self.accepted),
            ("done", &self.done),
            ("failed", &self.failed),
            ("rejected_busy", &self.rejected_busy),
            ("rejected_rate", &self.rejected_rate),
            ("rejected_queue", &self.rejected_queue),
            ("bad_requests", &self.bad_requests),
        ];
        for (k, v) in pairs {
            m.insert(
                k.to_string(),
                Json::Num(v.load(Ordering::Relaxed) as f64),
            );
        }
        Json::Obj(m)
    }
}

/// A job's lifecycle state as the API reports it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    Running,
    Done,
    Failed,
}

impl JobStatus {
    pub fn name(self) -> &'static str {
        match self {
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed => "failed",
        }
    }
}

/// A finished job's result held for recall — columnar
/// ([`BatchResults`] per trial), not a JSON tree. A million-estimate
/// result is four `f64`/`u64` columns (~32 bytes each) instead of a
/// million boxed `Json::Obj` maps, and recall serializes estimates
/// straight from the columns through a bounded buffer.
pub(crate) struct StoredResult {
    trials: Vec<BatchResults>,
}

impl StoredResult {
    pub(crate) fn from_output(out: &JobOutput) -> StoredResult {
        StoredResult {
            trials: out
                .per_trial
                .iter()
                .map(|ests| BatchResults::from_estimates(ests))
                .collect(),
        }
    }

    /// Rebuild columns from a journaled `{"trials": [[est, ..], ..]}`
    /// body; `None` on any shape mismatch (the job then recalls as
    /// status-only rather than poisoning the ledger).
    pub(crate) fn from_result_json(j: &Json) -> Option<StoredResult> {
        let trials = j
            .get("trials")
            .and_then(Json::as_arr)?
            .iter()
            .map(|t| {
                let ests = t
                    .as_arr()?
                    .iter()
                    .map(|e| Estimate::from_json(e).ok())
                    .collect::<Option<Vec<_>>>()?;
                Some(BatchResults::from_estimates(&ests))
            })
            .collect::<Option<Vec<_>>>()?;
        Some(StoredResult { trials })
    }

    pub(crate) fn trials(&self) -> &[BatchResults] {
        &self.trials
    }

    /// Total estimates across trials — the `max_recall` unit.
    pub(crate) fn n_estimates(&self) -> usize {
        self.trials.iter().map(BatchResults::len).sum()
    }
}

/// Ledger entry behind `GET /v1/jobs/{id}`.
pub(crate) struct JobEntry {
    pub status: JobStatus,
    pub result: Option<Arc<StoredResult>>,
    pub error: Option<Json>,
}

/// Shared state of a running server: the warm session, the job
/// ledger, and every production-edge mechanism.
pub(crate) struct ServerState {
    pub session: Session,
    pub cfg: ServeConfig,
    pub jobs: Mutex<BTreeMap<u64, JobEntry>>,
    next_id: AtomicU64,
    running: AtomicUsize,
    pub limiter: Option<RateLimiter>,
    pub journal: Option<Journal>,
    pub metrics: ServerMetrics,
}

/// RAII token for one admitted job slot.
pub(crate) struct JobSlot<'a>(&'a ServerState);

impl Drop for JobSlot<'_> {
    fn drop(&mut self) {
        self.0.running.fetch_sub(1, Ordering::SeqCst);
    }
}

impl ServerState {
    /// Claim a job slot; `None` = at the `max_jobs` bound (429).
    pub(crate) fn try_admit(&self) -> Option<JobSlot<'_>> {
        let cap = self.cfg.max_jobs.max(1);
        self.running
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                (n < cap).then_some(n + 1)
            })
            .ok()
            .map(|_| JobSlot(self))
    }

    /// Register a freshly admitted job: ledger entry + journal record.
    pub(crate) fn create_job(&self, config: &Json) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        lock_ok(&self.jobs).insert(
            id,
            JobEntry {
                status: JobStatus::Running,
                result: None,
                error: None,
            },
        );
        self.metrics.accepted.fetch_add(1, Ordering::Relaxed);
        if let Some(j) = &self.journal {
            if let Err(e) = j.submitted(id, config) {
                eprintln!("journal write failed for job {id}: {e:#}");
            }
        }
        id
    }

    /// Run a parsed job to completion, streaming frames into `sink`
    /// (round + final estimate frames, then the terminal status
    /// frame), and record the outcome in the ledger and journal. Sink
    /// errors never abort the computation — the journal still gets a
    /// terminal record a restarted server can serve.
    pub(crate) fn run_and_record(
        &self,
        id: u64,
        cfg: &JobConfig,
        sink: &mut dyn FnMut(&Json),
    ) {
        // A panic inside the job runner (engine, reducer, codec) must
        // fail *this job*, not unwind through the HTTP worker thread
        // and shrink the pool until the server is dead. The panic text
        // becomes the job's error payload so clients see the cause.
        let outcome = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                self.session.run_job_observed(cfg, &mut |ev| {
                    for frame in ev.frames() {
                        sink(&with_id(frame, id));
                    }
                })
            }),
        )
        .unwrap_or_else(|payload| {
            Err(anyhow::anyhow!(
                "job panicked: {}",
                panic_message(payload.as_ref())
            ))
        });
        match outcome {
            Ok(out) => {
                if let Some(j) = &self.journal {
                    // The JSON tree is transient — built for the
                    // append, dropped before the ledger stores the
                    // columnar form.
                    if let Err(e) = j.done(id, &result_json(&out)) {
                        eprintln!(
                            "journal write failed for job {id}: {e:#}"
                        );
                    }
                }
                let stored = Arc::new(StoredResult::from_output(&out));
                self.set_status(id, JobStatus::Done, Some(stored), None);
                self.metrics.done.fetch_add(1, Ordering::Relaxed);
                sink(&status_frame(id, JobStatus::Done, None));
            }
            Err(err) => {
                let payload = ErrorPayload::from_error(&err).to_json();
                if let Some(j) = &self.journal {
                    if let Err(e) = j.failed(id, &payload) {
                        eprintln!(
                            "journal write failed for job {id}: {e:#}"
                        );
                    }
                }
                self.set_status(
                    id,
                    JobStatus::Failed,
                    None,
                    Some(payload.clone()),
                );
                self.metrics.failed.fetch_add(1, Ordering::Relaxed);
                sink(&status_frame(id, JobStatus::Failed, Some(payload)));
            }
        }
    }

    /// Re-run one journaled job that never reached a terminal record.
    /// No client is attached, so frames go nowhere; the ledger and the
    /// journal get the deterministic re-computed result.
    fn replay_job(&self, job: &ReplayJob) {
        match JobConfig::from_json(&job.config) {
            Ok(cfg) => self.run_and_record(job.id, &cfg, &mut |_| {}),
            Err(err) => {
                let payload = ErrorPayload::from_error(&err).to_json();
                if let Some(j) = &self.journal {
                    let _ = j.failed(job.id, &payload);
                }
                self.set_status(
                    job.id,
                    JobStatus::Failed,
                    None,
                    Some(payload),
                );
                self.metrics.failed.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    fn set_status(
        &self,
        id: u64,
        status: JobStatus,
        result: Option<Arc<StoredResult>>,
        error: Option<Json>,
    ) {
        if let Some(entry) = lock_ok(&self.jobs).get_mut(&id) {
            entry.status = status;
            entry.result = result;
            entry.error = error;
        }
    }

    /// The engine (or cluster) metrics of the shared session.
    fn engine_metrics(&self) -> &Metrics {
        match self.session.cluster() {
            Some(c) => c.metrics(),
            None => self.session.engine().metrics(),
        }
    }

    /// `GET /v1/healthz` body.
    pub(crate) fn healthz_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("v".to_string(), Json::Num(1.0));
        m.insert("status".to_string(), Json::Str("ok".into()));
        m.insert(
            "engines".to_string(),
            Json::Num(self.session.num_engines() as f64),
        );
        m.insert(
            "workers".to_string(),
            Json::Num(self.session.workers() as f64),
        );
        m.insert(
            "tier".to_string(),
            Json::Str(self.session.execution_tier().name().into()),
        );
        m.insert(
            "remote_engines".to_string(),
            Json::Num(self.session.num_remote_engines() as f64),
        );
        m.insert(
            "jobs".to_string(),
            Json::Num(lock_ok(&self.jobs).len() as f64),
        );
        Json::Obj(m)
    }

    /// `GET /v1/metrics` body: server counters + engine metrics +
    /// registry ledgers.
    pub(crate) fn metrics_json(&self) -> Json {
        let em = self.engine_metrics();
        let mut engine = BTreeMap::new();
        let counters: [(&str, u64); 10] = [
            ("tasks_done", em.done()),
            ("retries", em.retried()),
            ("failures", em.failed()),
            ("cancelled", em.cancelled()),
            ("plan_hits", em.plan_hits()),
            ("plan_misses", em.plan_misses()),
            ("fused_hits", em.fused_hits()),
            ("fused_misses", em.fused_misses()),
            ("dedup_unique", em.dedup_unique()),
            ("dedup_folded", em.dedup_folded()),
        ];
        for (k, v) in counters {
            engine.insert(k.to_string(), Json::Num(v as f64));
        }
        engine.insert(
            "utilization".to_string(),
            Json::from_f64(em.utilization()),
        );
        let reg = self.session.registry();
        let mut registry = BTreeMap::new();
        let ledgers: [(&str, u64); 7] = [
            ("compiles", reg.compile_count()),
            ("plan_lowers", reg.plan_lower_count()),
            ("plan_hits", reg.plan_hit_count()),
            ("fused_lowers", reg.fused_lower_count()),
            ("fused_hits", reg.fused_hit_count()),
            ("dedup_unique", reg.dedup_unique_count()),
            ("dedup_folded", reg.dedup_folded_count()),
        ];
        for (k, v) in ledgers {
            registry.insert(k.to_string(), Json::Num(v as f64));
        }
        let mut m = BTreeMap::new();
        m.insert("v".to_string(), Json::Num(1.0));
        m.insert("server".to_string(), self.metrics.to_json());
        m.insert("engine".to_string(), Json::Obj(engine));
        m.insert("registry".to_string(), Json::Obj(registry));
        Json::Obj(m)
    }
}

/// Annotate a wire frame with the job id.
fn with_id(frame: Json, id: u64) -> Json {
    match frame {
        Json::Obj(mut m) => {
            m.insert("id".to_string(), Json::Num(id as f64));
            Json::Obj(m)
        }
        other => other,
    }
}

/// The stored/recalled result shape: `{"trials": [[estimate, ..], ..]}`.
fn result_json(out: &JobOutput) -> Json {
    let trials = out
        .per_trial
        .iter()
        .map(|ests| {
            Json::Arr(ests.iter().map(|e| e.to_json()).collect())
        })
        .collect();
    let mut m = BTreeMap::new();
    m.insert("trials".to_string(), Json::Arr(trials));
    Json::Obj(m)
}

/// Terminal stream frame / recall skeleton:
/// `{"v":1,"id":N,"status":..}` plus the error payload when failed.
fn status_frame(id: u64, status: JobStatus, error: Option<Json>) -> Json {
    let mut m = BTreeMap::new();
    m.insert("v".to_string(), Json::Num(1.0));
    m.insert("id".to_string(), Json::Num(id as f64));
    m.insert("status".to_string(), Json::Str(status.name().into()));
    if let Some(e) = error {
        m.insert("error".to_string(), e);
    }
    Json::Obj(m)
}

/// `{"v":1,"error":{code,message}}` — the body of every non-200.
pub(crate) fn error_body(payload: &ErrorPayload) -> Json {
    let mut m = BTreeMap::new();
    m.insert("v".to_string(), Json::Num(1.0));
    m.insert("error".to_string(), payload.to_json());
    Json::Obj(m)
}

/// Bounded handoff between the acceptor and the worker pool.
struct ConnQueue {
    inner: Mutex<(VecDeque<TcpStream>, bool)>,
    cv: Condvar,
    cap: usize,
}

impl ConnQueue {
    fn new(cap: usize) -> Self {
        ConnQueue {
            inner: Mutex::new((VecDeque::new(), false)),
            cv: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// `Err` hands the stream back when the queue is full or closed
    /// (the acceptor answers 503 on it).
    fn push(&self, s: TcpStream) -> Result<(), TcpStream> {
        let mut g = lock_ok(&self.inner);
        if g.1 || g.0.len() >= self.cap {
            return Err(s);
        }
        g.0.push_back(s);
        self.cv.notify_one();
        Ok(())
    }

    /// Block for the next connection; `None` = closed and drained.
    fn pop(&self) -> Option<TcpStream> {
        let mut g = lock_ok(&self.inner);
        loop {
            if let Some(s) = g.0.pop_front() {
                return Some(s);
            }
            if g.1 {
                return None;
            }
            g = wait_ok(&self.cv, g);
        }
    }

    fn close(&self) {
        lock_ok(&self.inner).1 = true;
        self.cv.notify_all();
    }
}

/// A bound-but-not-yet-running server. [`bind`](Self::bind) resolves
/// everything that can fail loudly (address, session, journal) before
/// [`run`](Self::run) starts serving, so callers learn the actual
/// port (`local_addr`) and can take a [`StopHandle`] first.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
    replays: Vec<ReplayJob>,
    stop: Arc<AtomicBool>,
}

/// Signals a running server to stop accepting and drain.
#[derive(Clone)]
pub struct StopHandle(Arc<AtomicBool>);

impl StopHandle {
    pub fn stop(&self) {
        self.0.store(true, Ordering::SeqCst);
    }
}

impl Server {
    /// Bind the listener, build the shared session, open the journal
    /// and load its replay state.
    pub fn bind(cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        listener.set_nonblocking(true).context("nonblocking listener")?;

        let mut b = Session::builder()
            .workers(cfg.workers)
            .engines(cfg.engines)
            .remote_engines(cfg.remotes.iter().cloned());
        b = match &cfg.artifacts {
            Some(dir) => b.artifacts(dir.clone()),
            None => b.artifacts_or_emulator("artifacts"),
        };
        if let Some(t) = cfg.tier {
            b = b.execution_tier(t);
        }
        let session = b.build()?;

        // Load, compact, then open: compaction rewrites `jobs.jsonl`
        // to the unfinished jobs plus the last `journal_keep` finished
        // ones (atomically, via tmp + rename), so the journal cannot
        // grow without bound across restarts. The append handle is
        // opened only after the rewrite so it points at the compact
        // file.
        let (journal, replay) = match &cfg.state_dir {
            Some(dir) => {
                let replay = Journal::load(dir)?;
                let replay =
                    Journal::compact(dir, replay, cfg.journal_keep)?;
                (Some(Journal::open(dir)?), replay)
            }
            None => (None, Replay::default()),
        };
        let mut jobs = BTreeMap::new();
        let mut replays = Vec::new();
        for job in replay.jobs {
            let entry = match &job.outcome {
                Some(Outcome::Done(r)) => JobEntry {
                    status: JobStatus::Done,
                    result: StoredResult::from_result_json(r)
                        .map(Arc::new),
                    error: None,
                },
                Some(Outcome::Failed(e)) => JobEntry {
                    status: JobStatus::Failed,
                    result: None,
                    error: Some(e.clone()),
                },
                None => {
                    replays.push(job.clone());
                    JobEntry {
                        status: JobStatus::Running,
                        result: None,
                        error: None,
                    }
                }
            };
            jobs.insert(job.id, entry);
        }

        let limiter = cfg
            .rate_limit
            .map(|rate| RateLimiter::new(rate, cfg.rate_burst));
        let state = Arc::new(ServerState {
            session,
            jobs: Mutex::new(jobs),
            next_id: AtomicU64::new(replay.next_id.max(1)),
            running: AtomicUsize::new(0),
            limiter,
            journal,
            metrics: ServerMetrics::default(),
            cfg,
        });
        Ok(Server {
            listener,
            state,
            replays,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound address (reports the picked port for `:0` binds).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn stop_handle(&self) -> StopHandle {
        StopHandle(Arc::clone(&self.stop))
    }

    /// Serve until [`StopHandle::stop`]: spawn the replay thread and
    /// the worker pool, then accept connections into the bounded
    /// queue. On stop the queue drains, workers finish their in-flight
    /// jobs (journaling terminal records), and everything joins.
    pub fn run(self) -> Result<()> {
        let queue = Arc::new(ConnQueue::new(self.state.cfg.queue_cap));

        let replay_thread = (!self.replays.is_empty()).then(|| {
            let state = Arc::clone(&self.state);
            let jobs = self.replays;
            std::thread::spawn(move || {
                for job in &jobs {
                    state.replay_job(job);
                }
            })
        });

        let workers: Vec<_> = (0..self.state.cfg.http_workers.max(1))
            .map(|_| {
                let state = Arc::clone(&self.state);
                let q = Arc::clone(&queue);
                std::thread::spawn(move || {
                    while let Some(stream) = q.pop() {
                        router::handle_connection(&state, stream);
                    }
                })
            })
            .collect();

        while !self.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let deadline = self.state.cfg.read_timeout;
                    if deadline > Duration::ZERO {
                        let _ =
                            stream.set_read_timeout(Some(deadline));
                    }
                    if let Err(mut rejected) = queue.push(stream) {
                        self.state
                            .metrics
                            .rejected_queue
                            .fetch_add(1, Ordering::Relaxed);
                        let _ = http::write_json(
                            &mut rejected,
                            503,
                            &error_body(&ErrorPayload::new(
                                "overloaded",
                                "connection queue full",
                            )),
                        );
                    }
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    eprintln!("accept error: {e}");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }

        queue.close();
        for w in workers {
            let _ = w.join();
        }
        if let Some(t) = replay_thread {
            let _ = t.join();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::integrator::spec::Estimate;

    #[test]
    fn wire_helpers_shape() {
        let est = Estimate {
            value: 0.5,
            std_err: 0.01,
            n_samples: 128,
            rounds: 1,
        };
        let out = JobOutput {
            per_trial: vec![vec![est], vec![est]],
            normal: None,
        };
        let r = result_json(&out);
        let trials = r.get("trials").and_then(Json::as_arr).unwrap();
        assert_eq!(trials.len(), 2);
        let back = Estimate::from_json(&trials[1].as_arr().unwrap()[0])
            .unwrap();
        assert_eq!(back, est);

        let f = status_frame(9, JobStatus::Done, None);
        assert_eq!(f.get("id").and_then(Json::as_i64), Some(9));
        assert_eq!(f.get("status").and_then(Json::as_str), Some("done"));
        assert_eq!(f.get("v").and_then(Json::as_i64), Some(1));
        assert!(f.get("error").is_none());

        let e = error_body(&ErrorPayload::new("bad_json", "nope"));
        assert_eq!(
            e.path(&["error", "code"]).and_then(Json::as_str),
            Some("bad_json")
        );

        let tagged = with_id(Json::parse(r#"{"value":1}"#).unwrap(), 4);
        assert_eq!(tagged.get("id").and_then(Json::as_i64), Some(4));
    }

    #[test]
    fn stored_result_round_trips_columns() {
        let est = Estimate {
            value: 1.25,
            std_err: 0.5,
            n_samples: 64,
            rounds: 2,
        };
        let out = JobOutput {
            per_trial: vec![vec![est; 3], vec![est; 2]],
            normal: None,
        };
        let s = StoredResult::from_output(&out);
        assert_eq!(s.n_estimates(), 5);
        assert_eq!(s.trials().len(), 2);
        assert_eq!(s.trials()[0].get(2), est);
        // journaled JSON → columns → same estimates
        let back =
            StoredResult::from_result_json(&result_json(&out)).unwrap();
        assert_eq!(back.n_estimates(), 5);
        assert_eq!(back.trials()[1].get(1), est);
        // malformed journal bodies degrade to status-only recall
        assert!(StoredResult::from_result_json(
            &Json::parse("{}").unwrap()
        )
        .is_none());
    }

    #[test]
    fn conn_queue_bounds_and_close() {
        let q = ConnQueue::new(1);
        // no real connections needed to exercise close semantics
        q.close();
        assert!(q.pop().is_none());
    }

    #[test]
    fn idle_connection_times_out_with_408() {
        use std::io::Read;
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            read_timeout: Duration::from_millis(80),
            ..Default::default()
        };
        let srv = Server::bind(cfg).unwrap();
        let addr = srv.local_addr().unwrap();
        let stop = srv.stop_handle();
        let t = std::thread::spawn(move || srv.run().unwrap());
        // connect and send nothing: the read deadline must fire and
        // the server answers 408 instead of waiting forever
        let mut c = TcpStream::connect(addr).unwrap();
        let mut buf = String::new();
        c.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 408"), "{buf}");
        assert!(buf.contains("timeout"), "{buf}");
        stop.stop();
        t.join().unwrap();
    }

    #[test]
    fn admission_is_bounded() {
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            max_jobs: 1,
            ..Default::default()
        };
        let srv = Server::bind(cfg).unwrap();
        let slot = srv.state.try_admit().expect("first slot");
        assert!(srv.state.try_admit().is_none(), "bound enforced");
        drop(slot);
        assert!(srv.state.try_admit().is_some(), "slot released");
    }
}
