//! Job configuration files (JSON) → typed specs.
//!
//! A job file describes one run of any of the paper's three classes —
//! the `"class"` tag selects which (defaulting to the v5.1
//! multifunction batch) — plus the execution topology
//! (`workers`/`num_engines`) that [`crate::session::Session::from_job_config`]
//! turns into a live session. Example (`zmc init-config` writes one):
//!
//! ```json
//! {
//!   "class": "multifunctions",
//!   "workers": 2,
//!   "samples_per_fn": 262144,
//!   "trials": 10,
//!   "seed": 2021,
//!   "target_rel_err": 0.005,
//!   "functions": [
//!     {"expr": "p0*abs(x1+x2)", "bounds": [[0,1],[0,1]], "theta": [1.5]},
//!     {"expr": "sin(x1)*x2",    "bounds": [[0,3.14],[0,1]]}
//!   ]
//! }
//! ```
//!
//! * `"class": "functional"` adds an `"axes"` array (one array of
//!   values per parameter axis; the scan runs over their cartesian
//!   product) and takes exactly one function;
//! * `"class": "normal"` adds an optional `"normal"` object with the
//!   tree-search knobs (`divisions`, `trials`, `sigma_mult`, `depth`,
//!   `max_split_dims`) and takes exactly one function.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use crate::integrator::normal::NormalConfig;
use crate::integrator::spec::IntegralJob;
use crate::runtime::ExecTier;
use crate::util::json::Json;

/// The job-config wire schema version this build reads and writes
/// (the top-level `"v"` field). Configs without a `"v"` field are
/// accepted as v1 for compatibility with pre-versioned files; any
/// other value is a typed [`UnsupportedVersion`] error.
pub const WIRE_VERSION: i64 = 1;

/// Typed parse error for a job config whose `"v"` field names a schema
/// version this build does not speak. Recover it from the `anyhow`
/// chain with `err.downcast_ref::<zmc::config::UnsupportedVersion>()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UnsupportedVersion {
    /// The version the config declared (`i64::MIN` when the field was
    /// present but not an integer).
    pub got: i64,
}

impl std::fmt::Display for UnsupportedVersion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unsupported job-config version {} (this build speaks v{})",
            self.got, WIRE_VERSION
        )
    }
}

impl std::error::Error for UnsupportedVersion {}

/// Which paper class a job file drives (the `"class"` tag).
#[derive(Debug, Clone, PartialEq)]
pub enum JobClass {
    /// Heterogeneous batch over the `functions` array — the v5.1
    /// headline (and the default when no tag is present).
    Multifunctions,
    /// One integrand scanned over the cartesian product of `axes`.
    Functional {
        /// `axes[j]` lists the values parameter `p<j>` takes.
        axes: Vec<Vec<f64>>,
    },
    /// Stratified sampling + tree search on one integrand.
    Normal(NormalParams),
}

impl JobClass {
    /// The wire tag of this class (the `"class"` field's value).
    pub fn name(&self) -> &'static str {
        match self {
            JobClass::Multifunctions => "multifunctions",
            JobClass::Functional { .. } => "functional",
            JobClass::Normal(_) => "normal",
        }
    }
}

/// Tree-search knobs of a `"class": "normal"` job file (the JSON
/// `"normal"` object; all fields optional, defaulting to
/// [`NormalConfig`]'s values).
#[derive(Debug, Clone, PartialEq)]
pub struct NormalParams {
    /// Initial divisions per dimension.
    pub divisions: usize,
    /// Independent evaluations per cube per level.
    pub n_trials: u32,
    /// Flag threshold multiplier.
    pub sigma_mult: f64,
    /// Maximum refinement depth.
    pub depth: usize,
    /// Dimensions split per subdivision.
    pub max_split_dims: usize,
}

impl Default for NormalParams {
    fn default() -> Self {
        let c = NormalConfig::default();
        NormalParams {
            divisions: c.initial_divisions,
            n_trials: c.n_trials,
            sigma_mult: c.sigma_mult,
            depth: c.max_depth,
            max_split_dims: c.max_split_dims,
        }
    }
}

/// A fully-parsed job file.
#[derive(Debug, Clone)]
pub struct JobConfig {
    /// Which integration class to run.
    pub class: JobClass,
    pub workers: usize,
    /// Engines in the cluster (1 = single-engine path); each engine
    /// gets `workers` workers. Results are bit-identical at any value.
    pub num_engines: usize,
    /// Remote worker hosts (`"remotes": ["host:port", ..]` — addresses
    /// of running `zmc worker` processes) joined into the cluster
    /// alongside the local engines. Empty = all-local execution.
    pub remotes: Vec<String>,
    /// Reconnect attempts before a dead remote host is abandoned
    /// (`"reconnect_retries"`; 0 disables the reconnect supervisor,
    /// `None` defers to the transport default).
    pub reconnect_retries: Option<u32>,
    /// Base reconnect backoff in milliseconds, doubled per attempt
    /// with deterministic jitter (`"reconnect_backoff_ms"`; `None`
    /// defers to the transport default).
    pub reconnect_backoff_ms: Option<u64>,
    pub samples_per_fn: usize,
    pub trials: u32,
    pub seed: u64,
    /// Adaptive stopping: per-function relative error target.
    pub target_rel_err: Option<f64>,
    /// Adaptive stopping: per-function absolute error target.
    pub target_abs_err: Option<f64>,
    /// Adaptive refinement rounds after the pilot (None = default).
    pub max_rounds: Option<usize>,
    /// Emulator execution tier the session pins its workers to
    /// (`"tier": "naive" | "plan" | "fused"`); `None` defers to the
    /// process-wide `ZMC_EMU_TIER` default.
    pub tier: Option<ExecTier>,
    pub jobs: Vec<IntegralJob>,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig {
            class: JobClass::Multifunctions,
            workers: 1,
            num_engines: 1,
            remotes: Vec::new(),
            reconnect_retries: None,
            reconnect_backoff_ms: None,
            samples_per_fn: 1 << 18,
            trials: 1,
            seed: 2021,
            target_rel_err: None,
            target_abs_err: None,
            max_rounds: None,
            tier: None,
            jobs: vec![],
        }
    }
}

impl JobConfig {
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        Self::from_json_text(&text)
    }

    pub fn from_json_text(text: &str) -> Result<Self> {
        // the JsonError payload survives the context wrap, so callers
        // (the server's 400 path) can still type a malformed body
        let j = Json::parse(text).context("config")?;
        Self::from_json(&j)
    }

    /// Parse a job config from an already-parsed [`Json`] value — the
    /// inverse of [`to_json`](Self::to_json). A `"v"` field naming any
    /// version other than [`WIRE_VERSION`] is a typed
    /// [`UnsupportedVersion`] error; a missing `"v"` is accepted as v1
    /// (pre-versioned files).
    pub fn from_json(j: &Json) -> Result<Self> {
        if let Some(v) = j.get("v") {
            let got = v.as_i64().unwrap_or(i64::MIN);
            if got != WIRE_VERSION {
                return Err(UnsupportedVersion { got }.into());
            }
        }
        let mut cfg = JobConfig::default();
        if let Some(w) = j.get("workers").and_then(Json::as_usize) {
            cfg.workers = w.max(1);
        }
        if let Some(n) = j.get("num_engines").and_then(Json::as_usize) {
            cfg.num_engines = n.max(1);
        }
        if let Some(rs) = j.get("remotes").and_then(Json::as_arr) {
            for (i, r) in rs.iter().enumerate() {
                cfg.remotes.push(
                    r.as_str()
                        .with_context(|| {
                            format!(
                                "remotes[{i}] must be a \"host:port\" \
                                 string"
                            )
                        })?
                        .to_string(),
                );
            }
        }
        if let Some(r) =
            j.get("reconnect_retries").and_then(Json::as_usize)
        {
            cfg.reconnect_retries = Some(r as u32);
        }
        if let Some(b) =
            j.get("reconnect_backoff_ms").and_then(Json::as_usize)
        {
            cfg.reconnect_backoff_ms = Some(b as u64);
        }
        if let Some(s) = j.get("samples_per_fn").and_then(Json::as_usize) {
            cfg.samples_per_fn = s;
        }
        if let Some(t) = j.get("trials").and_then(Json::as_usize) {
            cfg.trials = t.max(1) as u32;
        }
        if let Some(s) = j.get("seed").and_then(Json::as_i64) {
            cfg.seed = s as u64;
        }
        if let Some(e) = j.get("target_rel_err").and_then(Json::as_f64) {
            cfg.target_rel_err = Some(e);
        }
        if let Some(e) = j.get("target_abs_err").and_then(Json::as_f64) {
            cfg.target_abs_err = Some(e);
        }
        if let Some(r) = j.get("max_rounds").and_then(Json::as_usize) {
            cfg.max_rounds = Some(r);
        }
        if let Some(t) = j.get("tier").and_then(Json::as_str) {
            cfg.tier = Some(ExecTier::parse(t).ok_or_else(|| {
                anyhow!(
                    "unknown tier '{t}' (expected naive | plan | fused)"
                )
            })?);
        }
        let fns = j
            .get("functions")
            .and_then(Json::as_arr)
            .context("config missing 'functions' array")?;
        for (i, f) in fns.iter().enumerate() {
            cfg.jobs.push(
                parse_function(f)
                    .with_context(|| format!("functions[{i}]"))?,
            );
        }
        if cfg.jobs.is_empty() {
            return Err(anyhow!("config has no functions"));
        }
        cfg.class = parse_class(&j)?;
        match &cfg.class {
            JobClass::Multifunctions => {}
            JobClass::Functional { axes } => {
                if cfg.jobs.len() != 1 {
                    return Err(anyhow!(
                        "class 'functional' takes exactly one function \
                         (got {})",
                        cfg.jobs.len()
                    ));
                }
                let expected = cfg.jobs[0].expr.n_params();
                if axes.len() < expected {
                    return Err(anyhow!(
                        "'axes' has {} axis(es) but the expression reads \
                         {} parameter(s)",
                        axes.len(),
                        expected
                    ));
                }
            }
            JobClass::Normal(_) => {
                if cfg.jobs.len() != 1 {
                    return Err(anyhow!(
                        "class 'normal' takes exactly one function (got {})",
                        cfg.jobs.len()
                    ));
                }
            }
        }
        Ok(cfg)
    }

    /// Serialize to the canonical versioned wire form (`"v": 1` plus
    /// every field, optional ones only when set). Symmetric with
    /// [`from_json`](Self::from_json): the round trip reproduces the
    /// config exactly — functions re-parse from their `expr` source
    /// text, floats print shortest-round-trip decimals.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        let num = |v: f64| Json::Num(v);
        m.insert("v".to_string(), num(WIRE_VERSION as f64));
        m.insert(
            "class".to_string(),
            Json::Str(self.class.name().to_string()),
        );
        m.insert("workers".to_string(), num(self.workers as f64));
        m.insert("num_engines".to_string(), num(self.num_engines as f64));
        if !self.remotes.is_empty() {
            m.insert(
                "remotes".to_string(),
                Json::Arr(
                    self.remotes
                        .iter()
                        .map(|r| Json::Str(r.clone()))
                        .collect(),
                ),
            );
        }
        if let Some(r) = self.reconnect_retries {
            m.insert("reconnect_retries".to_string(), num(r as f64));
        }
        if let Some(b) = self.reconnect_backoff_ms {
            m.insert("reconnect_backoff_ms".to_string(), num(b as f64));
        }
        m.insert(
            "samples_per_fn".to_string(),
            num(self.samples_per_fn as f64),
        );
        m.insert("trials".to_string(), num(self.trials as f64));
        m.insert("seed".to_string(), num(self.seed as f64));
        if let Some(e) = self.target_rel_err {
            m.insert("target_rel_err".to_string(), num(e));
        }
        if let Some(e) = self.target_abs_err {
            m.insert("target_abs_err".to_string(), num(e));
        }
        if let Some(r) = self.max_rounds {
            m.insert("max_rounds".to_string(), num(r as f64));
        }
        if let Some(t) = self.tier {
            m.insert("tier".to_string(), Json::Str(t.name().to_string()));
        }
        match &self.class {
            JobClass::Multifunctions => {}
            JobClass::Functional { axes } => {
                let axes_json = axes
                    .iter()
                    .map(|axis| {
                        Json::Arr(axis.iter().map(|&v| num(v)).collect())
                    })
                    .collect();
                m.insert("axes".to_string(), Json::Arr(axes_json));
            }
            JobClass::Normal(p) => {
                let mut n = BTreeMap::new();
                n.insert("divisions".to_string(), num(p.divisions as f64));
                n.insert("trials".to_string(), num(p.n_trials as f64));
                n.insert("sigma_mult".to_string(), num(p.sigma_mult));
                n.insert("depth".to_string(), num(p.depth as f64));
                n.insert(
                    "max_split_dims".to_string(),
                    num(p.max_split_dims as f64),
                );
                m.insert("normal".to_string(), Json::Obj(n));
            }
        }
        let fns = self
            .jobs
            .iter()
            .map(|job| {
                let mut f = BTreeMap::new();
                f.insert(
                    "expr".to_string(),
                    Json::Str(job.source.clone()),
                );
                let bounds = job
                    .bounds
                    .iter()
                    .map(|&(lo, hi)| Json::Arr(vec![num(lo), num(hi)]))
                    .collect();
                f.insert("bounds".to_string(), Json::Arr(bounds));
                if !job.theta.is_empty() {
                    f.insert(
                        "theta".to_string(),
                        Json::Arr(
                            job.theta.iter().map(|&v| num(v)).collect(),
                        ),
                    );
                }
                Json::Obj(f)
            })
            .collect();
        m.insert("functions".to_string(), Json::Arr(fns));
        Json::Obj(m)
    }

    /// The example job file of the requested class (`init-config`'s
    /// `--class` flag); `None` for an unknown class name.
    pub fn example_json_for(class: &str) -> Option<String> {
        match class {
            "multifunctions" => Some(Self::example_json()),
            "functional" => Some(Self::example_json_functional()),
            "normal" => Some(Self::example_json_normal()),
            _ => None,
        }
    }

    /// Example multifunction job file (for `init-config` and reports).
    pub fn example_json() -> String {
        r#"{
  "v": 1,
  "class": "multifunctions",
  "workers": 1,
  "num_engines": 1,
  "samples_per_fn": 262144,
  "trials": 10,
  "seed": 2021,
  "functions": [
    {"expr": "p0*abs(x1+x2)", "bounds": [[0,1],[0,1]], "theta": [1.5]},
    {"expr": "cos(9.07*(x1+x2+x3+x4)) + sin(9.07*(x1+x2+x3+x4))",
     "bounds": [[0,1],[0,1],[0,1],[0,1]]}
  ]
}
"#
        .to_string()
    }

    /// Example parameter-scan job file (`"class": "functional"`).
    pub fn example_json_functional() -> String {
        r#"{
  "v": 1,
  "class": "functional",
  "workers": 1,
  "num_engines": 1,
  "samples_per_fn": 65536,
  "seed": 2021,
  "axes": [[0.5, 1.0, 2.0, 4.0], [0.25, 0.75]],
  "functions": [
    {"expr": "cos(p0*(x1+x2+x3)) + p1*x1",
     "bounds": [[0,1],[0,1],[0,1]], "theta": [1.0, 0.5]}
  ]
}
"#
        .to_string()
    }

    /// Example tree-search job file (`"class": "normal"`).
    pub fn example_json_normal() -> String {
        r#"{
  "v": 1,
  "class": "normal",
  "workers": 1,
  "seed": 2021,
  "normal": {"divisions": 4, "trials": 5, "sigma_mult": 1.0, "depth": 2},
  "functions": [
    {"expr": "sin(x1)*x2", "bounds": [[0, 3.141592653589793], [0, 1]]}
  ]
}
"#
        .to_string()
    }
}

/// Wire-level equality: every scalar field plus, per function, the
/// `(source, bounds, theta)` triple that survives the JSON round trip
/// (the compiled `Expr`/`Program` are deterministic functions of the
/// source, so comparing them would be redundant).
impl PartialEq for JobConfig {
    fn eq(&self, other: &Self) -> bool {
        self.class == other.class
            && self.workers == other.workers
            && self.num_engines == other.num_engines
            && self.remotes == other.remotes
            && self.reconnect_retries == other.reconnect_retries
            && self.reconnect_backoff_ms == other.reconnect_backoff_ms
            && self.samples_per_fn == other.samples_per_fn
            && self.trials == other.trials
            && self.seed == other.seed
            && self.target_rel_err == other.target_rel_err
            && self.target_abs_err == other.target_abs_err
            && self.max_rounds == other.max_rounds
            && self.tier == other.tier
            && self.jobs.len() == other.jobs.len()
            && self.jobs.iter().zip(&other.jobs).all(|(a, b)| {
                a.source == b.source
                    && a.bounds == b.bounds
                    && a.theta == b.theta
            })
    }
}

fn parse_class(j: &Json) -> Result<JobClass> {
    match j.get("class").and_then(Json::as_str) {
        None | Some("multifunctions") => Ok(JobClass::Multifunctions),
        Some("functional") => {
            let axes_json = j
                .get("axes")
                .and_then(Json::as_arr)
                .context("class 'functional' needs an 'axes' array")?;
            let mut axes = Vec::new();
            for (i, a) in axes_json.iter().enumerate() {
                let vals = a
                    .as_arr()
                    .with_context(|| format!("axes[{i}] must be an array"))?;
                let axis: Vec<f64> = vals
                    .iter()
                    .map(|v| v.as_f64().context("axis value not a number"))
                    .collect::<Result<_>>()?;
                if axis.is_empty() {
                    return Err(anyhow!("axes[{i}] is empty"));
                }
                axes.push(axis);
            }
            if axes.is_empty() {
                return Err(anyhow!("'axes' must list at least one axis"));
            }
            Ok(JobClass::Functional { axes })
        }
        Some("normal") => {
            let mut p = NormalParams::default();
            if let Some(n) = j.get("normal") {
                if let Some(v) = n.get("divisions").and_then(Json::as_usize)
                {
                    p.divisions = v;
                }
                if let Some(v) = n.get("trials").and_then(Json::as_usize) {
                    p.n_trials = v as u32;
                }
                if let Some(v) = n.get("sigma_mult").and_then(Json::as_f64)
                {
                    p.sigma_mult = v;
                }
                if let Some(v) = n.get("depth").and_then(Json::as_usize) {
                    p.depth = v;
                }
                if let Some(v) =
                    n.get("max_split_dims").and_then(Json::as_usize)
                {
                    p.max_split_dims = v;
                }
            }
            Ok(JobClass::Normal(p))
        }
        Some(other) => Err(anyhow!(
            "unknown class '{other}' \
             (expected multifunctions | functional | normal)"
        )),
    }
}

fn parse_function(f: &Json) -> Result<IntegralJob> {
    let expr = f
        .get("expr")
        .and_then(Json::as_str)
        .context("function missing 'expr'")?;
    let bounds_json = f
        .get("bounds")
        .and_then(Json::as_arr)
        .context("function missing 'bounds'")?;
    let mut bounds = Vec::new();
    for b in bounds_json {
        let pair = b.as_arr().context("bounds entry must be [lo, hi]")?;
        if pair.len() != 2 {
            return Err(anyhow!("bounds entry must be [lo, hi]"));
        }
        bounds.push((
            pair[0].as_f64().context("lo not a number")?,
            pair[1].as_f64().context("hi not a number")?,
        ));
    }
    let theta: Vec<f64> = match f.get("theta").and_then(Json::as_arr) {
        Some(arr) => arr
            .iter()
            .map(|v| v.as_f64().context("theta not a number"))
            .collect::<Result<_>>()?,
        None => vec![],
    };
    IntegralJob::with_params(expr, &bounds, &theta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_example() {
        let cfg = JobConfig::from_json_text(&JobConfig::example_json())
            .unwrap();
        assert_eq!(cfg.class, JobClass::Multifunctions);
        assert_eq!(cfg.trials, 10);
        assert_eq!(cfg.jobs.len(), 2);
        assert_eq!(cfg.jobs[0].theta, vec![1.5]);
        assert_eq!(cfg.jobs[1].dims(), 4);
    }

    #[test]
    fn parses_functional_example() {
        let cfg = JobConfig::from_json_text(
            &JobConfig::example_json_functional(),
        )
        .unwrap();
        let JobClass::Functional { axes } = &cfg.class else {
            panic!("expected functional class, got {:?}", cfg.class);
        };
        assert_eq!(axes.len(), 2);
        assert_eq!(axes[0], vec![0.5, 1.0, 2.0, 4.0]);
        assert_eq!(cfg.jobs.len(), 1);
        // the grid binds every parameter the expression reads
        assert!(axes.len() >= cfg.jobs[0].expr.n_params());
    }

    #[test]
    fn parses_normal_example() {
        let cfg =
            JobConfig::from_json_text(&JobConfig::example_json_normal())
                .unwrap();
        let JobClass::Normal(p) = &cfg.class else {
            panic!("expected normal class, got {:?}", cfg.class);
        };
        assert_eq!(p.divisions, 4);
        assert_eq!(p.n_trials, 5);
        assert_eq!(p.depth, 2);
        // unspecified knobs keep the NormalConfig defaults
        assert_eq!(
            p.max_split_dims,
            NormalConfig::default().max_split_dims
        );
    }

    #[test]
    fn example_json_for_dispatches() {
        for class in ["multifunctions", "functional", "normal"] {
            let text = JobConfig::example_json_for(class).unwrap();
            let cfg = JobConfig::from_json_text(&text).unwrap();
            match class {
                "multifunctions" => {
                    assert_eq!(cfg.class, JobClass::Multifunctions)
                }
                "functional" => assert!(matches!(
                    cfg.class,
                    JobClass::Functional { .. }
                )),
                _ => assert!(matches!(cfg.class, JobClass::Normal(_))),
            }
        }
        assert!(JobConfig::example_json_for("frobnicate").is_none());
    }

    #[test]
    fn adaptive_fields_parsed() {
        let cfg = JobConfig::from_json_text(
            r#"{"target_rel_err": 0.01, "max_rounds": 5,
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap();
        assert_eq!(cfg.target_rel_err, Some(0.01));
        assert_eq!(cfg.target_abs_err, None);
        assert_eq!(cfg.max_rounds, Some(5));
    }

    #[test]
    fn defaults_applied() {
        let cfg = JobConfig::from_json_text(
            r#"{"functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap();
        assert_eq!(cfg.class, JobClass::Multifunctions);
        assert_eq!(cfg.workers, 1);
        assert_eq!(cfg.num_engines, 1);
        assert_eq!(cfg.seed, 2021);
    }

    #[test]
    fn num_engines_parsed_and_clamped() {
        let cfg = JobConfig::from_json_text(
            r#"{"num_engines": 4,
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap();
        assert_eq!(cfg.num_engines, 4);
        let cfg = JobConfig::from_json_text(
            r#"{"num_engines": 0,
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap();
        assert_eq!(cfg.num_engines, 1);
    }

    #[test]
    fn remotes_parsed_and_round_tripped() {
        let cfg = JobConfig::from_json_text(
            r#"{"remotes": ["10.0.0.2:7777", "worker-b:7777"],
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap();
        assert_eq!(cfg.remotes, vec!["10.0.0.2:7777", "worker-b:7777"]);
        // the wire form carries remotes and the round trip is exact
        let back = JobConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        // absent -> all-local, and to_json omits the empty field
        let cfg = JobConfig::from_json_text(
            r#"{"functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap();
        assert!(cfg.remotes.is_empty());
        assert!(cfg.to_json().get("remotes").is_none());
        // non-string entries are a hard error
        assert!(JobConfig::from_json_text(
            r#"{"remotes": [7777],
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#
        )
        .is_err());
    }

    #[test]
    fn reconnect_knobs_parsed_and_round_tripped() {
        let cfg = JobConfig::from_json_text(
            r#"{"remotes": ["10.0.0.2:7777"],
                 "reconnect_retries": 12, "reconnect_backoff_ms": 250,
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap();
        assert_eq!(cfg.reconnect_retries, Some(12));
        assert_eq!(cfg.reconnect_backoff_ms, Some(250));
        let back = JobConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        // absent -> defer to the transport defaults, omitted on emit
        let cfg = JobConfig::from_json_text(
            r#"{"functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap();
        assert_eq!(cfg.reconnect_retries, None);
        assert_eq!(cfg.reconnect_backoff_ms, None);
        assert!(cfg.to_json().get("reconnect_retries").is_none());
        assert!(cfg.to_json().get("reconnect_backoff_ms").is_none());
    }

    #[test]
    fn tier_parsed_and_validated() {
        let cfg = JobConfig::from_json_text(
            r#"{"tier": "plan",
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap();
        assert_eq!(cfg.tier, Some(ExecTier::Plan));
        // absent -> defer to the process-wide default
        let cfg = JobConfig::from_json_text(
            r#"{"functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap();
        assert_eq!(cfg.tier, None);
        // unknown names are a hard error, not a silent default
        assert!(JobConfig::from_json_text(
            r#"{"tier": "warp",
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#
        )
        .is_err());
    }

    #[test]
    fn version_field_checked() {
        // v1 and absent both parse
        for head in [r#""v": 1, "#, ""] {
            let text = format!(
                r#"{{{head}"functions":
                     [{{"expr": "x1", "bounds": [[0, 1]]}}]}}"#
            );
            assert!(JobConfig::from_json_text(&text).is_ok(), "{head}");
        }
        // any other version is a *typed* error
        let err = JobConfig::from_json_text(
            r#"{"v": 2,
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap_err();
        assert_eq!(
            err.downcast_ref::<UnsupportedVersion>(),
            Some(&UnsupportedVersion { got: 2 })
        );
        // a non-integer version is also typed (got = i64::MIN)
        let err = JobConfig::from_json_text(
            r#"{"v": "latest",
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap_err();
        assert!(err.is::<UnsupportedVersion>());
    }

    #[test]
    fn to_json_round_trips_examples() {
        for class in ["multifunctions", "functional", "normal"] {
            let text = JobConfig::example_json_for(class).unwrap();
            let cfg = JobConfig::from_json_text(&text).unwrap();
            let wire = cfg.to_json();
            // the emitted form is versioned
            assert_eq!(wire.get("v").and_then(Json::as_i64), Some(1));
            let back = JobConfig::from_json(&wire).unwrap();
            assert_eq!(cfg, back, "{class}");
            // and survives a serialize -> parse -> parse cycle
            let reparsed =
                JobConfig::from_json_text(&wire.to_string()).unwrap();
            assert_eq!(cfg, reparsed, "{class}");
        }
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(JobConfig::from_json_text("{}").is_err());
        assert!(JobConfig::from_json_text(
            r#"{"functions": []}"#
        )
        .is_err());
        assert!(JobConfig::from_json_text(
            r#"{"functions": [{"expr": "x1"}]}"#
        )
        .is_err());
        assert!(JobConfig::from_json_text(
            r#"{"functions": [{"expr": "x1", "bounds": [[1]]}]}"#
        )
        .is_err());
        assert!(JobConfig::from_json_text(
            r#"{"functions": [{"expr": "p0", "bounds": [[0,1]]}]}"#
        )
        .is_err());
    }

    #[test]
    fn rejects_bad_classes() {
        // unknown tag
        assert!(JobConfig::from_json_text(
            r#"{"class": "frobnicate",
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#
        )
        .is_err());
        // functional without axes
        assert!(JobConfig::from_json_text(
            r#"{"class": "functional",
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#
        )
        .is_err());
        // functional with two functions
        assert!(JobConfig::from_json_text(
            r#"{"class": "functional", "axes": [[1.0]],
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]},
                               {"expr": "x1", "bounds": [[0, 1]]}]}"#
        )
        .is_err());
        // functional whose axes under-bind the expression
        assert!(JobConfig::from_json_text(
            r#"{"class": "functional", "axes": [[1.0]],
                 "functions": [{"expr": "p0*p1*x1", "bounds": [[0, 1]],
                                "theta": [1.0, 2.0]}]}"#
        )
        .is_err());
        // normal with two functions
        assert!(JobConfig::from_json_text(
            r#"{"class": "normal",
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]},
                               {"expr": "x1", "bounds": [[0, 1]]}]}"#
        )
        .is_err());
        // empty axis
        assert!(JobConfig::from_json_text(
            r#"{"class": "functional", "axes": [[]],
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#
        )
        .is_err());
    }
}
