//! Job configuration files (JSON) → typed specs.
//!
//! A job file describes one run of any of the paper's three classes —
//! the `"class"` tag selects which (defaulting to the v5.1
//! multifunction batch) — plus the execution topology
//! (`workers`/`num_engines`) that [`crate::session::Session::from_job_config`]
//! turns into a live session. Example (`zmc init-config` writes one):
//!
//! ```json
//! {
//!   "class": "multifunctions",
//!   "workers": 2,
//!   "samples_per_fn": 262144,
//!   "trials": 10,
//!   "seed": 2021,
//!   "target_rel_err": 0.005,
//!   "functions": [
//!     {"expr": "p0*abs(x1+x2)", "bounds": [[0,1],[0,1]], "theta": [1.5]},
//!     {"expr": "sin(x1)*x2",    "bounds": [[0,3.14],[0,1]]}
//!   ]
//! }
//! ```
//!
//! * `"class": "functional"` adds an `"axes"` array (one array of
//!   values per parameter axis; the scan runs over their cartesian
//!   product) and takes exactly one function;
//! * `"class": "normal"` adds an optional `"normal"` object with the
//!   tree-search knobs (`divisions`, `trials`, `sigma_mult`, `depth`,
//!   `max_split_dims`) and takes exactly one function.

use anyhow::{anyhow, Context, Result};

use crate::integrator::normal::NormalConfig;
use crate::integrator::spec::IntegralJob;
use crate::runtime::ExecTier;
use crate::util::json::Json;

/// Which paper class a job file drives (the `"class"` tag).
#[derive(Debug, Clone, PartialEq)]
pub enum JobClass {
    /// Heterogeneous batch over the `functions` array — the v5.1
    /// headline (and the default when no tag is present).
    Multifunctions,
    /// One integrand scanned over the cartesian product of `axes`.
    Functional {
        /// `axes[j]` lists the values parameter `p<j>` takes.
        axes: Vec<Vec<f64>>,
    },
    /// Stratified sampling + tree search on one integrand.
    Normal(NormalParams),
}

/// Tree-search knobs of a `"class": "normal"` job file (the JSON
/// `"normal"` object; all fields optional, defaulting to
/// [`NormalConfig`]'s values).
#[derive(Debug, Clone, PartialEq)]
pub struct NormalParams {
    /// Initial divisions per dimension.
    pub divisions: usize,
    /// Independent evaluations per cube per level.
    pub n_trials: u32,
    /// Flag threshold multiplier.
    pub sigma_mult: f64,
    /// Maximum refinement depth.
    pub depth: usize,
    /// Dimensions split per subdivision.
    pub max_split_dims: usize,
}

impl Default for NormalParams {
    fn default() -> Self {
        let c = NormalConfig::default();
        NormalParams {
            divisions: c.initial_divisions,
            n_trials: c.n_trials,
            sigma_mult: c.sigma_mult,
            depth: c.max_depth,
            max_split_dims: c.max_split_dims,
        }
    }
}

/// A fully-parsed job file.
#[derive(Debug, Clone)]
pub struct JobConfig {
    /// Which integration class to run.
    pub class: JobClass,
    pub workers: usize,
    /// Engines in the cluster (1 = single-engine path); each engine
    /// gets `workers` workers. Results are bit-identical at any value.
    pub num_engines: usize,
    pub samples_per_fn: usize,
    pub trials: u32,
    pub seed: u64,
    /// Adaptive stopping: per-function relative error target.
    pub target_rel_err: Option<f64>,
    /// Adaptive stopping: per-function absolute error target.
    pub target_abs_err: Option<f64>,
    /// Adaptive refinement rounds after the pilot (None = default).
    pub max_rounds: Option<usize>,
    /// Emulator execution tier the session pins its workers to
    /// (`"tier": "naive" | "plan" | "fused"`); `None` defers to the
    /// process-wide `ZMC_EMU_TIER` default.
    pub tier: Option<ExecTier>,
    pub jobs: Vec<IntegralJob>,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig {
            class: JobClass::Multifunctions,
            workers: 1,
            num_engines: 1,
            samples_per_fn: 1 << 18,
            trials: 1,
            seed: 2021,
            target_rel_err: None,
            target_abs_err: None,
            max_rounds: None,
            tier: None,
            jobs: vec![],
        }
    }
}

impl JobConfig {
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        Self::from_json_text(&text)
    }

    pub fn from_json_text(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("config: {e}"))?;
        let mut cfg = JobConfig::default();
        if let Some(w) = j.get("workers").and_then(Json::as_usize) {
            cfg.workers = w.max(1);
        }
        if let Some(n) = j.get("num_engines").and_then(Json::as_usize) {
            cfg.num_engines = n.max(1);
        }
        if let Some(s) = j.get("samples_per_fn").and_then(Json::as_usize) {
            cfg.samples_per_fn = s;
        }
        if let Some(t) = j.get("trials").and_then(Json::as_usize) {
            cfg.trials = t.max(1) as u32;
        }
        if let Some(s) = j.get("seed").and_then(Json::as_i64) {
            cfg.seed = s as u64;
        }
        if let Some(e) = j.get("target_rel_err").and_then(Json::as_f64) {
            cfg.target_rel_err = Some(e);
        }
        if let Some(e) = j.get("target_abs_err").and_then(Json::as_f64) {
            cfg.target_abs_err = Some(e);
        }
        if let Some(r) = j.get("max_rounds").and_then(Json::as_usize) {
            cfg.max_rounds = Some(r);
        }
        if let Some(t) = j.get("tier").and_then(Json::as_str) {
            cfg.tier = Some(ExecTier::parse(t).ok_or_else(|| {
                anyhow!(
                    "unknown tier '{t}' (expected naive | plan | fused)"
                )
            })?);
        }
        let fns = j
            .get("functions")
            .and_then(Json::as_arr)
            .context("config missing 'functions' array")?;
        for (i, f) in fns.iter().enumerate() {
            cfg.jobs.push(
                parse_function(f)
                    .with_context(|| format!("functions[{i}]"))?,
            );
        }
        if cfg.jobs.is_empty() {
            return Err(anyhow!("config has no functions"));
        }
        cfg.class = parse_class(&j)?;
        match &cfg.class {
            JobClass::Multifunctions => {}
            JobClass::Functional { axes } => {
                if cfg.jobs.len() != 1 {
                    return Err(anyhow!(
                        "class 'functional' takes exactly one function \
                         (got {})",
                        cfg.jobs.len()
                    ));
                }
                let expected = cfg.jobs[0].expr.n_params();
                if axes.len() < expected {
                    return Err(anyhow!(
                        "'axes' has {} axis(es) but the expression reads \
                         {} parameter(s)",
                        axes.len(),
                        expected
                    ));
                }
            }
            JobClass::Normal(_) => {
                if cfg.jobs.len() != 1 {
                    return Err(anyhow!(
                        "class 'normal' takes exactly one function (got {})",
                        cfg.jobs.len()
                    ));
                }
            }
        }
        Ok(cfg)
    }

    /// The example job file of the requested class (`init-config`'s
    /// `--class` flag); `None` for an unknown class name.
    pub fn example_json_for(class: &str) -> Option<String> {
        match class {
            "multifunctions" => Some(Self::example_json()),
            "functional" => Some(Self::example_json_functional()),
            "normal" => Some(Self::example_json_normal()),
            _ => None,
        }
    }

    /// Example multifunction job file (for `init-config` and reports).
    pub fn example_json() -> String {
        r#"{
  "class": "multifunctions",
  "workers": 1,
  "num_engines": 1,
  "samples_per_fn": 262144,
  "trials": 10,
  "seed": 2021,
  "functions": [
    {"expr": "p0*abs(x1+x2)", "bounds": [[0,1],[0,1]], "theta": [1.5]},
    {"expr": "cos(9.07*(x1+x2+x3+x4)) + sin(9.07*(x1+x2+x3+x4))",
     "bounds": [[0,1],[0,1],[0,1],[0,1]]}
  ]
}
"#
        .to_string()
    }

    /// Example parameter-scan job file (`"class": "functional"`).
    pub fn example_json_functional() -> String {
        r#"{
  "class": "functional",
  "workers": 1,
  "num_engines": 1,
  "samples_per_fn": 65536,
  "seed": 2021,
  "axes": [[0.5, 1.0, 2.0, 4.0], [0.25, 0.75]],
  "functions": [
    {"expr": "cos(p0*(x1+x2+x3)) + p1*x1",
     "bounds": [[0,1],[0,1],[0,1]], "theta": [1.0, 0.5]}
  ]
}
"#
        .to_string()
    }

    /// Example tree-search job file (`"class": "normal"`).
    pub fn example_json_normal() -> String {
        r#"{
  "class": "normal",
  "workers": 1,
  "seed": 2021,
  "normal": {"divisions": 4, "trials": 5, "sigma_mult": 1.0, "depth": 2},
  "functions": [
    {"expr": "sin(x1)*x2", "bounds": [[0, 3.141592653589793], [0, 1]]}
  ]
}
"#
        .to_string()
    }
}

fn parse_class(j: &Json) -> Result<JobClass> {
    match j.get("class").and_then(Json::as_str) {
        None | Some("multifunctions") => Ok(JobClass::Multifunctions),
        Some("functional") => {
            let axes_json = j
                .get("axes")
                .and_then(Json::as_arr)
                .context("class 'functional' needs an 'axes' array")?;
            let mut axes = Vec::new();
            for (i, a) in axes_json.iter().enumerate() {
                let vals = a
                    .as_arr()
                    .with_context(|| format!("axes[{i}] must be an array"))?;
                let axis: Vec<f64> = vals
                    .iter()
                    .map(|v| v.as_f64().context("axis value not a number"))
                    .collect::<Result<_>>()?;
                if axis.is_empty() {
                    return Err(anyhow!("axes[{i}] is empty"));
                }
                axes.push(axis);
            }
            if axes.is_empty() {
                return Err(anyhow!("'axes' must list at least one axis"));
            }
            Ok(JobClass::Functional { axes })
        }
        Some("normal") => {
            let mut p = NormalParams::default();
            if let Some(n) = j.get("normal") {
                if let Some(v) = n.get("divisions").and_then(Json::as_usize)
                {
                    p.divisions = v;
                }
                if let Some(v) = n.get("trials").and_then(Json::as_usize) {
                    p.n_trials = v as u32;
                }
                if let Some(v) = n.get("sigma_mult").and_then(Json::as_f64)
                {
                    p.sigma_mult = v;
                }
                if let Some(v) = n.get("depth").and_then(Json::as_usize) {
                    p.depth = v;
                }
                if let Some(v) =
                    n.get("max_split_dims").and_then(Json::as_usize)
                {
                    p.max_split_dims = v;
                }
            }
            Ok(JobClass::Normal(p))
        }
        Some(other) => Err(anyhow!(
            "unknown class '{other}' \
             (expected multifunctions | functional | normal)"
        )),
    }
}

fn parse_function(f: &Json) -> Result<IntegralJob> {
    let expr = f
        .get("expr")
        .and_then(Json::as_str)
        .context("function missing 'expr'")?;
    let bounds_json = f
        .get("bounds")
        .and_then(Json::as_arr)
        .context("function missing 'bounds'")?;
    let mut bounds = Vec::new();
    for b in bounds_json {
        let pair = b.as_arr().context("bounds entry must be [lo, hi]")?;
        if pair.len() != 2 {
            return Err(anyhow!("bounds entry must be [lo, hi]"));
        }
        bounds.push((
            pair[0].as_f64().context("lo not a number")?,
            pair[1].as_f64().context("hi not a number")?,
        ));
    }
    let theta: Vec<f64> = match f.get("theta").and_then(Json::as_arr) {
        Some(arr) => arr
            .iter()
            .map(|v| v.as_f64().context("theta not a number"))
            .collect::<Result<_>>()?,
        None => vec![],
    };
    IntegralJob::with_params(expr, &bounds, &theta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_example() {
        let cfg = JobConfig::from_json_text(&JobConfig::example_json())
            .unwrap();
        assert_eq!(cfg.class, JobClass::Multifunctions);
        assert_eq!(cfg.trials, 10);
        assert_eq!(cfg.jobs.len(), 2);
        assert_eq!(cfg.jobs[0].theta, vec![1.5]);
        assert_eq!(cfg.jobs[1].dims(), 4);
    }

    #[test]
    fn parses_functional_example() {
        let cfg = JobConfig::from_json_text(
            &JobConfig::example_json_functional(),
        )
        .unwrap();
        let JobClass::Functional { axes } = &cfg.class else {
            panic!("expected functional class, got {:?}", cfg.class);
        };
        assert_eq!(axes.len(), 2);
        assert_eq!(axes[0], vec![0.5, 1.0, 2.0, 4.0]);
        assert_eq!(cfg.jobs.len(), 1);
        // the grid binds every parameter the expression reads
        assert!(axes.len() >= cfg.jobs[0].expr.n_params());
    }

    #[test]
    fn parses_normal_example() {
        let cfg =
            JobConfig::from_json_text(&JobConfig::example_json_normal())
                .unwrap();
        let JobClass::Normal(p) = &cfg.class else {
            panic!("expected normal class, got {:?}", cfg.class);
        };
        assert_eq!(p.divisions, 4);
        assert_eq!(p.n_trials, 5);
        assert_eq!(p.depth, 2);
        // unspecified knobs keep the NormalConfig defaults
        assert_eq!(
            p.max_split_dims,
            NormalConfig::default().max_split_dims
        );
    }

    #[test]
    fn example_json_for_dispatches() {
        for class in ["multifunctions", "functional", "normal"] {
            let text = JobConfig::example_json_for(class).unwrap();
            let cfg = JobConfig::from_json_text(&text).unwrap();
            match class {
                "multifunctions" => {
                    assert_eq!(cfg.class, JobClass::Multifunctions)
                }
                "functional" => assert!(matches!(
                    cfg.class,
                    JobClass::Functional { .. }
                )),
                _ => assert!(matches!(cfg.class, JobClass::Normal(_))),
            }
        }
        assert!(JobConfig::example_json_for("frobnicate").is_none());
    }

    #[test]
    fn adaptive_fields_parsed() {
        let cfg = JobConfig::from_json_text(
            r#"{"target_rel_err": 0.01, "max_rounds": 5,
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap();
        assert_eq!(cfg.target_rel_err, Some(0.01));
        assert_eq!(cfg.target_abs_err, None);
        assert_eq!(cfg.max_rounds, Some(5));
    }

    #[test]
    fn defaults_applied() {
        let cfg = JobConfig::from_json_text(
            r#"{"functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap();
        assert_eq!(cfg.class, JobClass::Multifunctions);
        assert_eq!(cfg.workers, 1);
        assert_eq!(cfg.num_engines, 1);
        assert_eq!(cfg.seed, 2021);
    }

    #[test]
    fn num_engines_parsed_and_clamped() {
        let cfg = JobConfig::from_json_text(
            r#"{"num_engines": 4,
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap();
        assert_eq!(cfg.num_engines, 4);
        let cfg = JobConfig::from_json_text(
            r#"{"num_engines": 0,
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap();
        assert_eq!(cfg.num_engines, 1);
    }

    #[test]
    fn tier_parsed_and_validated() {
        let cfg = JobConfig::from_json_text(
            r#"{"tier": "plan",
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap();
        assert_eq!(cfg.tier, Some(ExecTier::Plan));
        // absent -> defer to the process-wide default
        let cfg = JobConfig::from_json_text(
            r#"{"functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#,
        )
        .unwrap();
        assert_eq!(cfg.tier, None);
        // unknown names are a hard error, not a silent default
        assert!(JobConfig::from_json_text(
            r#"{"tier": "warp",
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#
        )
        .is_err());
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(JobConfig::from_json_text("{}").is_err());
        assert!(JobConfig::from_json_text(
            r#"{"functions": []}"#
        )
        .is_err());
        assert!(JobConfig::from_json_text(
            r#"{"functions": [{"expr": "x1"}]}"#
        )
        .is_err());
        assert!(JobConfig::from_json_text(
            r#"{"functions": [{"expr": "x1", "bounds": [[1]]}]}"#
        )
        .is_err());
        assert!(JobConfig::from_json_text(
            r#"{"functions": [{"expr": "p0", "bounds": [[0,1]]}]}"#
        )
        .is_err());
    }

    #[test]
    fn rejects_bad_classes() {
        // unknown tag
        assert!(JobConfig::from_json_text(
            r#"{"class": "frobnicate",
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#
        )
        .is_err());
        // functional without axes
        assert!(JobConfig::from_json_text(
            r#"{"class": "functional",
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#
        )
        .is_err());
        // functional with two functions
        assert!(JobConfig::from_json_text(
            r#"{"class": "functional", "axes": [[1.0]],
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]},
                               {"expr": "x1", "bounds": [[0, 1]]}]}"#
        )
        .is_err());
        // functional whose axes under-bind the expression
        assert!(JobConfig::from_json_text(
            r#"{"class": "functional", "axes": [[1.0]],
                 "functions": [{"expr": "p0*p1*x1", "bounds": [[0, 1]],
                                "theta": [1.0, 2.0]}]}"#
        )
        .is_err());
        // normal with two functions
        assert!(JobConfig::from_json_text(
            r#"{"class": "normal",
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]},
                               {"expr": "x1", "bounds": [[0, 1]]}]}"#
        )
        .is_err());
        // empty axis
        assert!(JobConfig::from_json_text(
            r#"{"class": "functional", "axes": [[]],
                 "functions": [{"expr": "x1", "bounds": [[0, 1]]}]}"#
        )
        .is_err());
    }
}
