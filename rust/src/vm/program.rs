//! Validated bytecode container — the unit shipped to device kernels.

use std::fmt;

use crate::abi::{MAX_DIM, MAX_PARAM, MAX_PROG, STACK};
use crate::vm::opcodes::{Kind, Op};

/// One instruction: opcode plus its (possibly unused) operands.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Instr {
    pub op: Op,
    /// VAR/PARAM index operand.
    pub iarg: i32,
    /// CONST immediate operand.
    pub farg: f32,
}

impl Instr {
    pub fn new(op: Op) -> Self {
        Instr { op, iarg: 0, farg: 0.0 }
    }

    pub fn konst(v: f32) -> Self {
        Instr { op: Op::CONST, iarg: 0, farg: v }
    }

    pub fn var(i: usize) -> Self {
        Instr { op: Op::VAR, iarg: i as i32, farg: 0.0 }
    }

    pub fn param(i: usize) -> Self {
        Instr { op: Op::PARAM, iarg: i as i32, farg: 0.0 }
    }
}

/// Validation failure for a candidate program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProgramError {
    TooLong { len: usize },
    StackOverflow { at: usize },
    StackUnderflow { at: usize },
    BadVarIndex { at: usize, idx: i32 },
    BadParamIndex { at: usize, idx: i32 },
    HaltInBody { at: usize },
    /// Terminal stack depth != 1.
    BadTerminalDepth { depth: i32 },
    Empty,
}

impl fmt::Display for ProgramError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProgramError::TooLong { len } => {
                write!(f, "program too long: {len} > {MAX_PROG}")
            }
            ProgramError::StackOverflow { at } => {
                write!(f, "stack overflow (> {STACK}) at instruction {at}")
            }
            ProgramError::StackUnderflow { at } => {
                write!(f, "stack underflow at instruction {at}")
            }
            ProgramError::BadVarIndex { at, idx } => {
                write!(f, "variable index {idx} out of range at {at}")
            }
            ProgramError::BadParamIndex { at, idx } => {
                write!(f, "parameter index {idx} out of range at {at}")
            }
            ProgramError::HaltInBody { at } => {
                write!(f, "HALT inside program body at {at}")
            }
            ProgramError::BadTerminalDepth { depth } => {
                write!(f, "program leaves {depth} values on the stack")
            }
            ProgramError::Empty => write!(f, "empty program"),
        }
    }
}

impl std::error::Error for ProgramError {}

/// A validated program: guaranteed to fit device limits and to leave
/// exactly one value in stack slot 0 — the same invariant the hypothesis
/// strategy in `python/tests/test_vm.py` generates.
#[derive(Debug, Clone, PartialEq)]
pub struct Program {
    instrs: Vec<Instr>,
    /// Number of sample dimensions the program reads (max VAR index + 1).
    pub dims: usize,
    /// Number of parameter slots the program reads (max PARAM index + 1).
    pub n_params: usize,
    /// Maximum stack depth reached.
    pub max_depth: usize,
}

impl Program {
    /// Validate and freeze an instruction sequence.
    pub fn new(instrs: Vec<Instr>) -> Result<Program, ProgramError> {
        if instrs.is_empty() {
            return Err(ProgramError::Empty);
        }
        if instrs.len() > MAX_PROG {
            return Err(ProgramError::TooLong { len: instrs.len() });
        }
        let mut depth: i32 = 0;
        let mut max_depth: i32 = 0;
        let mut dims = 0usize;
        let mut n_params = 0usize;
        for (at, ins) in instrs.iter().enumerate() {
            match ins.op {
                Op::HALT => return Err(ProgramError::HaltInBody { at }),
                Op::VAR => {
                    if ins.iarg < 0 || ins.iarg as usize >= MAX_DIM {
                        return Err(ProgramError::BadVarIndex {
                            at,
                            idx: ins.iarg,
                        });
                    }
                    dims = dims.max(ins.iarg as usize + 1);
                }
                Op::PARAM => {
                    if ins.iarg < 0 || ins.iarg as usize >= MAX_PARAM {
                        return Err(ProgramError::BadParamIndex {
                            at,
                            idx: ins.iarg,
                        });
                    }
                    n_params = n_params.max(ins.iarg as usize + 1);
                }
                _ => {}
            }
            if (ins.op.arity() as i32) > depth {
                return Err(ProgramError::StackUnderflow { at });
            }
            depth += ins.op.stack_delta();
            if depth > STACK as i32 {
                return Err(ProgramError::StackOverflow { at });
            }
            max_depth = max_depth.max(depth);
        }
        if depth != 1 {
            return Err(ProgramError::BadTerminalDepth { depth });
        }
        Ok(Program {
            instrs,
            dims,
            n_params,
            max_depth: max_depth as usize,
        })
    }

    pub fn instrs(&self) -> &[Instr] {
        &self.instrs
    }

    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// HALT-padded device rows `(ops, iargs, fargs)`, each MAX_PROG wide —
    /// the exact layout of one row of the `vm_multi` artifact inputs.
    pub fn device_rows(&self) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let mut ops = vec![Op::HALT.code(); MAX_PROG];
        let mut iargs = vec![0i32; MAX_PROG];
        let mut fargs = vec![0f32; MAX_PROG];
        for (p, ins) in self.instrs.iter().enumerate() {
            ops[p] = ins.op.code();
            iargs[p] = ins.iarg;
            fargs[p] = ins.farg;
        }
        (ops, iargs, fargs)
    }

    /// Disassemble for logs / error messages.
    pub fn disasm(&self) -> String {
        self.instrs
            .iter()
            .enumerate()
            .map(|(i, ins)| match ins.op.kind() {
                Kind::Push => match ins.op {
                    Op::CONST => format!("{i:3}: CONST {}", ins.farg),
                    Op::VAR => format!("{i:3}: VAR x{}", ins.iarg + 1),
                    _ => format!("{i:3}: PARAM p{}", ins.iarg),
                },
                _ => format!("{i:3}: {}", ins.op.name()),
            })
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(instrs: Vec<Instr>) -> Result<Program, ProgramError> {
        Program::new(instrs)
    }

    #[test]
    fn valid_program_metadata() {
        let prog = p(vec![
            Instr::var(2),
            Instr::param(5),
            Instr::new(Op::MUL),
        ])
        .unwrap();
        assert_eq!(prog.dims, 3);
        assert_eq!(prog.n_params, 6);
        assert_eq!(prog.max_depth, 2);
        assert_eq!(prog.len(), 3);
    }

    #[test]
    fn underflow_rejected() {
        assert_eq!(
            p(vec![Instr::new(Op::ADD)]),
            Err(ProgramError::StackUnderflow { at: 0 })
        );
        assert_eq!(
            p(vec![Instr::konst(1.0), Instr::new(Op::ADD)]),
            Err(ProgramError::StackUnderflow { at: 1 })
        );
    }

    #[test]
    fn overflow_rejected() {
        let instrs: Vec<Instr> =
            (0..STACK + 1).map(|i| Instr::konst(i as f32)).collect();
        assert_eq!(
            p(instrs),
            Err(ProgramError::StackOverflow { at: STACK })
        );
    }

    #[test]
    fn terminal_depth_enforced() {
        assert_eq!(
            p(vec![Instr::konst(1.0), Instr::konst(2.0)]),
            Err(ProgramError::BadTerminalDepth { depth: 2 })
        );
        assert_eq!(p(vec![]), Err(ProgramError::Empty));
    }

    #[test]
    fn bad_indices_rejected() {
        assert!(matches!(
            p(vec![Instr::var(MAX_DIM)]),
            Err(ProgramError::BadVarIndex { .. })
        ));
        assert!(matches!(
            p(vec![Instr::param(MAX_PARAM)]),
            Err(ProgramError::BadParamIndex { .. })
        ));
    }

    #[test]
    fn halt_in_body_rejected() {
        assert_eq!(
            p(vec![Instr::new(Op::HALT), Instr::konst(0.0)]),
            Err(ProgramError::HaltInBody { at: 0 })
        );
    }

    #[test]
    fn too_long_rejected() {
        let mut instrs = vec![Instr::konst(0.0)];
        for _ in 0..MAX_PROG {
            instrs.push(Instr::new(Op::SIN));
        }
        assert_eq!(
            p(instrs),
            Err(ProgramError::TooLong { len: MAX_PROG + 1 })
        );
    }

    #[test]
    fn device_rows_padded() {
        let prog = p(vec![Instr::konst(2.5)]).unwrap();
        let (ops, iargs, fargs) = prog.device_rows();
        assert_eq!(ops.len(), MAX_PROG);
        assert_eq!(ops[0], Op::CONST.code());
        assert_eq!(fargs[0], 2.5);
        assert!(ops[1..].iter().all(|&o| o == Op::HALT.code()));
        assert!(iargs.iter().all(|&i| i == 0));
    }

    #[test]
    fn disasm_mentions_ops() {
        let prog = p(vec![Instr::var(0), Instr::new(Op::SIN)]).unwrap();
        let d = prog.disasm();
        assert!(d.contains("VAR x1"));
        assert!(d.contains("SIN"));
    }
}
