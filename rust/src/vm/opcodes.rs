//! Opcode table — must match `spec/opcodes.txt` and
//! `python/compile/opcodes.py` (enforced by `tests/opcode_abi.rs`).

/// Stack-effect class of an opcode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// No stack effect (HALT — padding no-op).
    Nullary,
    /// Pushes one value (operand in `iargs` or `fargs`).
    Push,
    /// Pops one, pushes one.
    Unary,
    /// Pops two, pushes one.
    Binary,
}

macro_rules! ops {
    ($(($code:literal, $name:ident, $kind:ident)),+ $(,)?) => {
        /// VM opcodes, numbered per the golden ABI spec.
        #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
        #[repr(i32)]
        pub enum Op {
            $($name = $code),+
        }

        /// All opcodes in ABI order.
        pub const ALL: &[Op] = &[$(Op::$name),+];

        impl Op {
            pub fn code(self) -> i32 {
                self as i32
            }

            pub fn from_code(code: i32) -> Option<Op> {
                match code {
                    $($code => Some(Op::$name),)+
                    _ => None,
                }
            }

            pub fn name(self) -> &'static str {
                match self {
                    $(Op::$name => stringify!($name)),+
                }
            }

            pub fn kind(self) -> Kind {
                match self {
                    $(Op::$name => Kind::$kind),+
                }
            }
        }
    };
}

ops![
    (0, HALT, Nullary),
    (1, CONST, Push),
    (2, VAR, Push),
    (3, PARAM, Push),
    (4, ADD, Binary),
    (5, SUB, Binary),
    (6, MUL, Binary),
    (7, DIV, Binary),
    (8, POW, Binary),
    (9, MIN, Binary),
    (10, MAX, Binary),
    (11, NEG, Unary),
    (12, ABS, Unary),
    (13, SIN, Unary),
    (14, COS, Unary),
    (15, TAN, Unary),
    (16, EXP, Unary),
    (17, LOG, Unary),
    (18, SQRT, Unary),
    (19, TANH, Unary),
    (20, ATAN, Unary),
    (21, FLOOR, Unary),
    (22, SQUARE, Unary),
    (23, RECIP, Unary),
];

/// Number of opcodes in the ABI (dispatch-table width on device).
pub const N_OPS: usize = ALL.len();

impl Op {
    /// Net stack-depth change.
    pub fn stack_delta(self) -> i32 {
        match self.kind() {
            Kind::Nullary => 0,
            Kind::Push => 1,
            Kind::Unary => 0,
            Kind::Binary => -1,
        }
    }

    /// Values consumed from the stack.
    pub fn arity(self) -> usize {
        match self.kind() {
            Kind::Nullary | Kind::Push => 0,
            Kind::Unary => 1,
            Kind::Binary => 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_dense_and_roundtrip() {
        assert_eq!(N_OPS, 24);
        for (i, op) in ALL.iter().enumerate() {
            assert_eq!(op.code(), i as i32);
            assert_eq!(Op::from_code(i as i32), Some(*op));
        }
        assert_eq!(Op::from_code(24), None);
        assert_eq!(Op::from_code(-1), None);
    }

    #[test]
    fn deltas() {
        assert_eq!(Op::CONST.stack_delta(), 1);
        assert_eq!(Op::SIN.stack_delta(), 0);
        assert_eq!(Op::ADD.stack_delta(), -1);
        assert_eq!(Op::HALT.stack_delta(), 0);
        assert_eq!(Op::POW.arity(), 2);
    }
}
