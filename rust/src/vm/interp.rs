//! In-process bytecode interpreter — CPU baseline + correctness oracle.
//!
//! Two evaluation modes:
//! * [`eval_scalar`] — one point at a time, f64 stack. Used by the expr
//!   test oracle and the tree-walk cross-check.
//! * [`BatchInterp`] — columnar (structure-of-arrays) evaluation over a
//!   chunk of samples with an f32 stack, mirroring the device kernel's
//!   tile layout. This is the "one CPU core" baseline the backend
//!   comparison bench (A3) runs against the PJRT path.

use crate::abi::STACK;
use crate::vm::opcodes::Op;
use crate::vm::program::Program;

/// Evaluate at a single point (f64 precision — oracle use).
pub fn eval_scalar(prog: &Program, x: &[f64], theta: &[f64]) -> f64 {
    let mut stack = [0f64; STACK];
    let mut sp = 0usize;
    for ins in prog.instrs() {
        match ins.op {
            Op::HALT => {}
            Op::CONST => {
                stack[sp] = ins.farg as f64;
                sp += 1;
            }
            Op::VAR => {
                stack[sp] = x[ins.iarg as usize];
                sp += 1;
            }
            Op::PARAM => {
                stack[sp] = theta[ins.iarg as usize];
                sp += 1;
            }
            op => {
                if op.arity() == 1 {
                    let a = stack[sp - 1];
                    stack[sp - 1] = unary_f64(op, a);
                } else {
                    let b = stack[sp - 1];
                    let a = stack[sp - 2];
                    stack[sp - 2] = binary_f64(op, a, b);
                    sp -= 1;
                }
            }
        }
    }
    stack[0]
}

/// Evaluate at a single point in f32 — the per-lane scalar twin of the
/// columnar interpreter below (same opcode → f32 operation mapping), so
/// one lane of [`BatchInterp::eval`] equals `eval_scalar_f32` on that
/// lane's inputs bit-for-bit. The plan differential suite uses this as
/// the third corner of its bit-exactness triangle (plan / batch /
/// scalar-f32).
pub fn eval_scalar_f32(prog: &Program, x: &[f32], theta: &[f32]) -> f32 {
    let mut stack = [0f32; STACK];
    let mut sp = 0usize;
    for ins in prog.instrs() {
        match ins.op {
            Op::HALT => {}
            Op::CONST => {
                stack[sp] = ins.farg;
                sp += 1;
            }
            Op::VAR => {
                stack[sp] = x[ins.iarg as usize];
                sp += 1;
            }
            Op::PARAM => {
                stack[sp] = theta[ins.iarg as usize];
                sp += 1;
            }
            op => {
                if op.arity() == 1 {
                    stack[sp - 1] = unary_f32(op, stack[sp - 1]);
                } else {
                    stack[sp - 2] = binary_f32(op, stack[sp - 2], stack[sp - 1]);
                    sp -= 1;
                }
            }
        }
    }
    stack[0]
}

/// Scalar f32 semantics of a unary opcode — the single source the row
/// loops below and the plan lowering's constant folder both follow, so
/// folding a constant at plan-build time produces exactly the bits the
/// interpreter would produce per lane at run time.
#[inline(always)]
pub fn unary_f32(op: Op, a: f32) -> f32 {
    match op {
        Op::NEG => -a,
        Op::ABS => a.abs(),
        Op::SIN => a.sin(),
        Op::COS => a.cos(),
        Op::TAN => a.tan(),
        Op::EXP => a.exp(),
        Op::LOG => a.ln(),
        Op::SQRT => a.sqrt(),
        Op::TANH => a.tanh(),
        Op::ATAN => a.atan(),
        Op::FLOOR => a.floor(),
        Op::SQUARE => a * a,
        Op::RECIP => 1.0 / a,
        _ => unreachable!("not unary: {op:?}"),
    }
}

/// Scalar f32 semantics of a binary opcode (see [`unary_f32`]).
#[inline(always)]
pub fn binary_f32(op: Op, a: f32, b: f32) -> f32 {
    match op {
        Op::ADD => a + b,
        Op::SUB => a - b,
        Op::MUL => a * b,
        Op::DIV => a / b,
        Op::POW => a.powf(b),
        Op::MIN => a.min(b),
        Op::MAX => a.max(b),
        _ => unreachable!("not binary: {op:?}"),
    }
}

fn unary_f64(op: Op, a: f64) -> f64 {
    match op {
        Op::NEG => -a,
        Op::ABS => a.abs(),
        Op::SIN => a.sin(),
        Op::COS => a.cos(),
        Op::TAN => a.tan(),
        Op::EXP => a.exp(),
        Op::LOG => a.ln(),
        Op::SQRT => a.sqrt(),
        Op::TANH => a.tanh(),
        Op::ATAN => a.atan(),
        Op::FLOOR => a.floor(),
        Op::SQUARE => a * a,
        Op::RECIP => 1.0 / a,
        _ => unreachable!("not unary: {op:?}"),
    }
}

fn binary_f64(op: Op, a: f64, b: f64) -> f64 {
    match op {
        Op::ADD => a + b,
        Op::SUB => a - b,
        Op::MUL => a * b,
        Op::DIV => a / b,
        Op::POW => a.powf(b),
        Op::MIN => a.min(b),
        Op::MAX => a.max(b),
        _ => unreachable!("not binary: {op:?}"),
    }
}

/// Columnar f32 interpreter over sample chunks (device-kernel mirror).
///
/// The stack is `STACK` rows of `chunk` f32 lanes; every instruction
/// processes a whole row, which vectorizes well and keeps the per-
/// instruction dispatch cost amortized over the chunk — the same
/// trade-off the Pallas kernel makes with its (STACK, TILE) layout.
pub struct BatchInterp {
    chunk: usize,
    stack: Vec<f32>, // STACK * chunk, row-major
}

impl BatchInterp {
    pub fn new(chunk: usize) -> Self {
        BatchInterp { chunk, stack: vec![0f32; STACK * chunk] }
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// Evaluate `prog` over `n <= chunk` samples stored dimension-major
    /// (`xt[d]` is the d-th dimension row). Results land in `out[..n]`.
    pub fn eval(
        &mut self,
        prog: &Program,
        xt: &[Vec<f32>],
        theta: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        assert!(n <= self.chunk);
        let c = self.chunk;
        let mut sp = 0usize;
        for ins in prog.instrs() {
            match ins.op {
                Op::HALT => {}
                Op::CONST => {
                    self.stack[sp * c..sp * c + n].fill(ins.farg);
                    sp += 1;
                }
                Op::VAR => {
                    self.stack[sp * c..sp * c + n]
                        .copy_from_slice(&xt[ins.iarg as usize][..n]);
                    sp += 1;
                }
                Op::PARAM => {
                    self.stack[sp * c..sp * c + n]
                        .fill(theta[ins.iarg as usize]);
                    sp += 1;
                }
                op if op.arity() == 1 => {
                    let row = &mut self.stack[(sp - 1) * c..(sp - 1) * c + n];
                    unary_row(op, row);
                }
                op => {
                    let (lo, hi) = self.stack.split_at_mut((sp - 1) * c);
                    let a = &mut lo[(sp - 2) * c..(sp - 2) * c + n];
                    let b = &hi[..n];
                    binary_row(op, a, b);
                    sp -= 1;
                }
            }
        }
        out[..n].copy_from_slice(&self.stack[..n]);
    }
}

fn unary_row(op: Op, row: &mut [f32]) {
    match op {
        Op::NEG => row.iter_mut().for_each(|v| *v = -*v),
        Op::ABS => row.iter_mut().for_each(|v| *v = v.abs()),
        Op::SIN => row.iter_mut().for_each(|v| *v = v.sin()),
        Op::COS => row.iter_mut().for_each(|v| *v = v.cos()),
        Op::TAN => row.iter_mut().for_each(|v| *v = v.tan()),
        Op::EXP => row.iter_mut().for_each(|v| *v = v.exp()),
        Op::LOG => row.iter_mut().for_each(|v| *v = v.ln()),
        Op::SQRT => row.iter_mut().for_each(|v| *v = v.sqrt()),
        Op::TANH => row.iter_mut().for_each(|v| *v = v.tanh()),
        Op::ATAN => row.iter_mut().for_each(|v| *v = v.atan()),
        Op::FLOOR => row.iter_mut().for_each(|v| *v = v.floor()),
        Op::SQUARE => row.iter_mut().for_each(|v| *v = *v * *v),
        Op::RECIP => row.iter_mut().for_each(|v| *v = 1.0 / *v),
        _ => unreachable!(),
    }
}

fn binary_row(op: Op, a: &mut [f32], b: &[f32]) {
    match op {
        Op::ADD => a.iter_mut().zip(b).for_each(|(x, y)| *x += y),
        Op::SUB => a.iter_mut().zip(b).for_each(|(x, y)| *x -= y),
        Op::MUL => a.iter_mut().zip(b).for_each(|(x, y)| *x *= y),
        Op::DIV => a.iter_mut().zip(b).for_each(|(x, y)| *x /= y),
        Op::POW => a.iter_mut().zip(b).for_each(|(x, y)| *x = x.powf(*y)),
        Op::MIN => a.iter_mut().zip(b).for_each(|(x, y)| *x = x.min(*y)),
        Op::MAX => a.iter_mut().zip(b).for_each(|(x, y)| *x = x.max(*y)),
        _ => unreachable!(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vm::program::Instr;

    fn prog(instrs: Vec<Instr>) -> Program {
        Program::new(instrs).unwrap()
    }

    #[test]
    fn scalar_arithmetic() {
        // (x1 + 2) * p0
        let p = prog(vec![
            Instr::var(0),
            Instr::konst(2.0),
            Instr::new(Op::ADD),
            Instr::param(0),
            Instr::new(Op::MUL),
        ]);
        assert_eq!(eval_scalar(&p, &[3.0], &[10.0]), 50.0);
    }

    #[test]
    fn scalar_all_unaries() {
        for (op, x, want) in [
            (Op::NEG, 2.0, -2.0),
            (Op::ABS, -2.0, 2.0),
            (Op::SQRT, 9.0, 3.0),
            (Op::SQUARE, 3.0, 9.0),
            (Op::RECIP, 4.0, 0.25),
            (Op::FLOOR, 2.7, 2.0),
            (Op::EXP, 0.0, 1.0),
            (Op::LOG, 1.0, 0.0),
        ] {
            let p = prog(vec![Instr::var(0), Instr::new(op)]);
            assert_eq!(eval_scalar(&p, &[x], &[]), want, "{op:?}");
        }
        let p = prog(vec![Instr::var(0), Instr::new(Op::SIN)]);
        assert!((eval_scalar(&p, &[std::f64::consts::PI], &[])).abs() < 1e-12);
    }

    #[test]
    fn scalar_all_binaries() {
        for (op, a, b, want) in [
            (Op::ADD, 2.0, 3.0, 5.0),
            (Op::SUB, 2.0, 3.0, -1.0),
            (Op::MUL, 2.0, 3.0, 6.0),
            (Op::DIV, 3.0, 2.0, 1.5),
            (Op::POW, 2.0, 10.0, 1024.0),
            (Op::MIN, 2.0, 3.0, 2.0),
            (Op::MAX, 2.0, 3.0, 3.0),
        ] {
            let p = prog(vec![
                Instr::konst(a as f32),
                Instr::konst(b as f32),
                Instr::new(op),
            ]);
            assert_eq!(eval_scalar(&p, &[], &[]), want, "{op:?}");
        }
    }

    #[test]
    fn batch_matches_scalar() {
        // |x1 - x2| * p1 + sin(x1)
        let p = prog(vec![
            Instr::var(0),
            Instr::var(1),
            Instr::new(Op::SUB),
            Instr::new(Op::ABS),
            Instr::param(1),
            Instr::new(Op::MUL),
            Instr::var(0),
            Instr::new(Op::SIN),
            Instr::new(Op::ADD),
        ]);
        let n = 257;
        let x0: Vec<f32> = (0..n).map(|i| i as f32 * 0.01 - 1.0).collect();
        let x1: Vec<f32> = (0..n).map(|i| (i as f32 * 0.03).cos()).collect();
        let xt = vec![x0.clone(), x1.clone()];
        let theta = [0.0f32, 2.5];
        let mut bi = BatchInterp::new(512);
        let mut out = vec![0f32; 512];
        bi.eval(&p, &xt, &theta, n, &mut out);
        for i in 0..n {
            let want = eval_scalar(
                &p,
                &[x0[i] as f64, x1[i] as f64],
                &[0.0, 2.5],
            ) as f32;
            assert!(
                (out[i] - want).abs() <= 1e-5 * want.abs().max(1.0),
                "i={i}: {} vs {want}",
                out[i]
            );
        }
    }

    #[test]
    fn scalar_f32_matches_batch_lanes_bitwise() {
        let p = prog(vec![
            Instr::var(0),
            Instr::var(1),
            Instr::new(Op::SUB),
            Instr::new(Op::SIN),
            Instr::param(0),
            Instr::new(Op::POW),
        ]);
        let n = 97;
        let x0: Vec<f32> = (0..n).map(|i| 0.3 + i as f32 * 0.011).collect();
        let x1: Vec<f32> = (0..n).map(|i| (i as f32 * 0.07).cos()).collect();
        let xt = vec![x0.clone(), x1.clone()];
        let mut bi = BatchInterp::new(128);
        let mut out = vec![0f32; 128];
        bi.eval(&p, &xt, &[1.7], n, &mut out);
        for i in 0..n {
            let want = eval_scalar_f32(&p, &[x0[i], x1[i]], &[1.7]);
            assert_eq!(out[i].to_bits(), want.to_bits(), "lane {i}");
        }
    }

    #[test]
    fn batch_reuse_across_programs() {
        let mut bi = BatchInterp::new(64);
        let mut out = vec![0f32; 64];
        let xt = vec![vec![0.5f32; 64]];
        let p1 = prog(vec![Instr::var(0), Instr::new(Op::SQUARE)]);
        bi.eval(&p1, &xt, &[], 64, &mut out);
        assert!(out.iter().all(|&v| v == 0.25));
        let p2 = prog(vec![Instr::konst(7.0)]);
        bi.eval(&p2, &xt, &[], 10, &mut out);
        assert!(out[..10].iter().all(|&v| v == 7.0));
    }
}
