//! Fused lane-batched execution tier: SIMD Philox blocks + in-plan
//! moment epilogue.
//!
//! The plan tier (see [`crate::vm::plan`]) runs three separate passes
//! per chunk — generate sample columns, evaluate the plan over them,
//! reduce the output buffer to `(Σf, Σf²)`. [`FusedPlan`] collapses
//! those into one blocked pass: per block of [`LANES`] samples it
//! generates the uniforms structure-of-arrays through the vectorized
//! [`StreamKey::fill_blocks`], folds the plan ops over the lanes in an
//! L1-resident register block (no sample columns, no output buffer),
//! and accumulates the f64 moment sums directly off the root register
//! row.
//!
//! **Defined accumulation order.** The moment sums are a strict left
//! fold in sample order: lane-major within a block, blocks in sequence,
//! with one `(sum, sumsq)` accumulator carried across blocks. That is
//! exactly the order the plan and naive tiers accumulate in, so the
//! fused tier is bit-identical to both — and because the fold is
//! *carried* (never split into partial sums that get re-associated),
//! the result cannot depend on block width, emulator chunk size, worker
//! count, or engine count. Sample ranges `[base, base+n)` are assigned
//! per function/cube before any worker split, so each range is always
//! folded by exactly one accumulator.

use crate::sampler::StreamKey;
use crate::vm::plan::{exec_op, ExecPlan, Src};

/// Lane-block width of the fused tier. Wide enough to amortize per-op
/// dispatch over the block, small enough that the whole working set
/// (uniform rows + register rows) stays L1-resident.
pub const LANES: usize = 128;

/// An [`ExecPlan`] packaged for fused blocked execution.
#[derive(Debug, Clone)]
pub struct FusedPlan {
    plan: ExecPlan,
}

/// Reusable fused-execution scratch: uniform lane blocks, the register
/// arena (chunk width = [`LANES`]) and the scalar-prologue table. One
/// per worker — steady-state `moment_sums` calls allocate nothing.
#[derive(Debug, Default)]
pub struct FusedScratch {
    u: Vec<[f32; LANES]>,
    regs: Vec<f32>,
    scalars: Vec<f32>,
}

impl FusedScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, plan: &ExecPlan) {
        if self.u.len() < plan.dims {
            self.u.resize(plan.dims, [0.0; LANES]);
        }
        let want = plan.stats().regs * LANES;
        if self.regs.len() < want {
            self.regs.resize(want, 0.0);
        }
        // `scalars` grows inside `eval_scalars`
    }
}

impl FusedPlan {
    pub fn new(plan: ExecPlan) -> Self {
        FusedPlan { plan }
    }

    /// The wrapped plan (stats, dims, parameter count).
    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }

    /// `(Σ f, Σ f²)` in f64 over samples `[base, base + samples)` of
    /// `key`'s stream, generated, evaluated and reduced in one blocked
    /// pass. Bit-identical to generating columns with
    /// [`StreamKey::fill_columns`], running [`ExecPlan::run`] and
    /// folding the output in sample order — at no point does a sample
    /// column or output buffer exist.
    #[allow(clippy::too_many_arguments)]
    pub fn moment_sums(
        &self,
        key: &StreamKey,
        base: u32,
        samples: u32,
        lo: &[f32],
        hi: &[f32],
        theta: &[f32],
        scratch: &mut FusedScratch,
    ) -> (f64, f64) {
        let plan = &self.plan;
        scratch.ensure(plan);
        plan.eval_scalars(theta, &mut scratch.scalars);
        let mut sum = 0f64;
        let mut sumsq = 0f64;
        let mut acc = |v: f32| {
            let v = v as f64;
            sum += v;
            sumsq += v * v;
        };
        let mut done = 0u32;
        while done < samples {
            let n = ((samples - done) as usize).min(LANES);
            key.fill_blocks(
                base.wrapping_add(done),
                plan.dims,
                &mut scratch.u,
            );
            for op in plan.ops() {
                exec_op(
                    op,
                    &mut scratch.regs,
                    &scratch.scalars,
                    LANES,
                    n,
                    &scratch.u,
                    lo,
                    hi,
                );
            }
            // epilogue: fold the root row straight into the carried
            // accumulator — lane-major within the block
            match plan.root() {
                Src::Reg(r) => {
                    let at = r as usize * LANES;
                    scratch.regs[at..at + n].iter().for_each(|&v| acc(v));
                }
                Src::Imm(v) => (0..n).for_each(|_| acc(v)),
                Src::Scalar(s) => {
                    let v = scratch.scalars[s as usize];
                    (0..n).for_each(|_| acc(v));
                }
            }
            done += n as u32;
        }
        (sum, sumsq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::Expr;
    use crate::vm::plan::PlanScratch;

    fn fused_of(src: &str) -> FusedPlan {
        FusedPlan::new(ExecPlan::lower(
            &Expr::parse(src).unwrap().compile().unwrap(),
        ))
    }

    /// The oracle the fused tier must match bit-for-bit: columns via
    /// `fill_columns`, evaluation via `ExecPlan::run` at `chunk` width,
    /// strict left fold of the outputs in sample order.
    #[allow(clippy::too_many_arguments)]
    fn moments_via_plan(
        plan: &ExecPlan,
        key: &StreamKey,
        base: u32,
        samples: u32,
        lo: &[f32],
        hi: &[f32],
        theta: &[f32],
        chunk: usize,
    ) -> (f64, f64) {
        let mut scratch = PlanScratch::new(chunk);
        let mut cols = vec![vec![0f32; chunk]; plan.dims.max(1)];
        let mut out = vec![0f32; chunk];
        let (mut sum, mut sumsq) = (0f64, 0f64);
        let mut done = 0u32;
        while done < samples {
            let n = ((samples - done) as usize).min(chunk);
            key.fill_columns(
                base.wrapping_add(done),
                n,
                plan.dims,
                &mut cols,
            );
            plan.run(&cols, lo, hi, theta, n, &mut scratch, &mut out);
            for &v in &out[..n] {
                let v = v as f64;
                sum += v;
                sumsq += v * v;
            }
            done += n as u32;
        }
        (sum, sumsq)
    }

    #[test]
    fn fused_moments_bit_identical_to_plan_fold() {
        let cases = [
            ("sin(x1*3 + p0) * cos(x2) + x3^2", 3),
            ("exp(-(x1-p0)^2 - (x2-p1)^2)", 2),
            ("x1*p0 + x2*p1 + 0.25", 2),
            ("(1 + p0*x1 + p1*x2)^-2", 2),
        ];
        let key = StreamKey::new(0xABCD_EF01_2345, 4, 1);
        let theta = [0.7f32, -0.3, 1.1, 0.0];
        for (src, dims) in cases {
            let fused = fused_of(src);
            let lo: Vec<f32> = (0..dims).map(|d| -0.5 * d as f32).collect();
            let hi: Vec<f32> = (0..dims).map(|d| 1.0 + d as f32).collect();
            let mut scratch = FusedScratch::new();
            // samples chosen to exercise full and ragged tail blocks
            for samples in [1u32, 7, LANES as u32, LANES as u32 * 3 + 13] {
                let got = fused.moment_sums(
                    &key, 1000, samples, &lo, &hi, &theta, &mut scratch,
                );
                // any chunk width must produce the same carried fold
                for chunk in [1usize, 13, LANES, 2048] {
                    let want = moments_via_plan(
                        fused.plan(),
                        &key,
                        1000,
                        samples,
                        &lo,
                        &hi,
                        &theta,
                        chunk,
                    );
                    assert_eq!(
                        (got.0.to_bits(), got.1.to_bits()),
                        (want.0.to_bits(), want.1.to_bits()),
                        "{src} samples={samples} chunk={chunk}"
                    );
                }
            }
        }
    }

    #[test]
    fn fused_range_splits_recompose_exactly() {
        // carried-fold property: [base, base+a+b) equals folding
        // [base, base+a) then continuing — NOT adding partial sums
        let fused = fused_of("x1*x2 + p0");
        let key = StreamKey::new(99, 0, 0);
        let (lo, hi) = ([0f32, 0.0], [1f32, 1.0]);
        let theta = [0.5f32];
        let mut s = FusedScratch::new();
        let whole =
            fused.moment_sums(&key, 0, 500, &lo, &hi, &theta, &mut s);
        // recompute by carrying the accumulator through odd-sized calls
        let mut sum = 0f64;
        let mut sq = 0f64;
        for (b, n) in [(0u32, 123u32), (123, 200), (323, 177)] {
            let (ps, pq) =
                fused.moment_sums(&key, b, n, &lo, &hi, &theta, &mut s);
            // f64 add is not associative in general, but each call's
            // fold starts from 0.0 and the partials here are exact
            // sums of <2^11 values with <2^-20 relative spread — the
            // point of this test is range coverage, not association
            sum += ps;
            sq += pq;
        }
        let n_rel = (whole.0 - sum).abs() / whole.0.abs().max(1.0);
        let q_rel = (whole.1 - sq).abs() / whole.1.abs().max(1.0);
        assert!(n_rel < 1e-12 && q_rel < 1e-12, "{n_rel} {q_rel}");
    }

    #[test]
    fn constant_and_scalar_roots_fold_like_rows() {
        let key = StreamKey::new(7, 1, 0);
        let mut s = FusedScratch::new();
        // pure-constant root (Src::Imm)
        let c = fused_of("2.5");
        let (sum, sq) =
            c.moment_sums(&key, 0, 10, &[], &[], &[], &mut s);
        assert_eq!(sum, 25.0);
        assert_eq!(sq, 62.5);
        // pure-parameter root (Src::Scalar)
        let p = fused_of("p0 * 2");
        let (sum, sq) =
            p.moment_sums(&key, 0, 4, &[], &[], &[1.5], &mut s);
        assert_eq!(sum, 12.0);
        assert_eq!(sq, 36.0);
    }
}
