//! Optimizing VM pipeline: lower a validated stack [`Program`] into an
//! [`ExecPlan`] — a register-based columnar form the emulator executes
//! with far less dispatch and memory traffic than [`BatchInterp`].
//!
//! Lowering passes (all **bit-exactness preserving** — see below):
//!
//! * **Hash-consed CSE** — the stack program is rebuilt as an expression
//!   DAG; structurally identical subexpressions collapse to one node, so
//!   each is computed once per chunk instead of once per occurrence.
//! * **Constant folding** — operations whose operands are all constants
//!   are evaluated at plan-build time with the *same scalar f32
//!   functions* the interpreter applies per lane
//!   ([`interp::unary_f32`]/[`interp::binary_f32`]), so the folded
//!   immediate is bit-identical to what every lane would have computed.
//! * **Uniform (lane-invariant) hoisting** — subexpressions built only
//!   from CONST/PARAM are evaluated once per launch as a tiny scalar
//!   prologue instead of once per lane per chunk (generalizes constant
//!   folding to values only known at launch time, e.g. `2*pi*p0`).
//! * **Stack → register allocation** — DAG nodes get reusable register
//!   rows; CONST/PARAM pushes become inline scalar operands of the
//!   consuming operation, eliminating the `STACK×CHUNK` row fills and
//!   copies the stack interpreter pays for every push.
//! * **Peephole fusion** — single-use `MUL` feeding `ADD`/`SUB` fuses
//!   into one [`PlanOp::MulAcc`] superinstruction (one pass over the
//!   rows instead of two), and `VAR` loads fuse the affine domain map
//!   `x = lo + (hi-lo)·u` into the sample load
//!   ([`PlanOp::VarAffine`]), so the unit-cube uniforms never have to
//!   be materialized as mapped coordinates first.
//! * **Dead-code elimination** — nodes not reachable from the root are
//!   never emitted. (Validated stack programs are fully live by
//!   construction, so in practice this only triggers for the
//!   instructions consumed by folding/CSE, counted in [`PlanStats`].)
//!
//! ## Bit-exactness contract
//!
//! Every fusion changes *dispatch and memory traffic only*, never
//! rounding: each output lane is produced by the identical sequence of
//! f32 operations the naive interpreter would apply, in the same
//! per-lane order (`MulAcc` computes `a*b` then the add/sub as two f32
//! ops — never an FMA — and preserves operand order, so even NaN
//! payload propagation matches). [`BatchInterp`] remains in-tree as the
//! oracle; `tests/vm_plan_test.rs` proves `to_bits()` agreement on
//! random programs, and the emulator's launch paths therefore produce
//! bit-identical moment sums through either pipeline.

use std::collections::HashMap;

use crate::vm::interp::{binary_f32, unary_f32};
use crate::vm::opcodes::Op;
use crate::vm::program::Program;

/// Operand of a columnar plan operation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Src {
    /// A register row in the scratch arena.
    Reg(u16),
    /// Immediate known at plan-build time (constant folding output).
    Imm(f32),
    /// Launch-time scalar: slot in the uniform prologue's value table
    /// (parameter-dependent but lane-invariant).
    Scalar(u16),
}

/// One scalar-prologue operation, evaluated once per launch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScalarOp {
    /// Load `theta[idx]` into the slot.
    Theta(u16),
    Un(Op, SSrc),
    Bin(Op, SSrc, SSrc),
}

/// Operand of a scalar-prologue operation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SSrc {
    Imm(f32),
    Slot(u16),
}

/// One columnar operation over register rows.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PlanOp {
    /// `dst[i] = lo[dim] + (hi[dim] - lo[dim]) * u[dim][i]` — the VAR
    /// load with the affine domain map folded in.
    VarAffine { dst: u16, dim: u16 },
    /// `dst[i] = op(dst[i])` (operand register reused in place).
    UnInPlace { op: Op, dst: u16 },
    /// `dst[i] = op(a[i])`, `a != dst`.
    Un { op: Op, dst: u16, a: u16 },
    /// `dst[i] = dst[i] op b` (left operand register reused in place).
    BinAccA { op: Op, dst: u16, b: Src },
    /// `dst[i] = a op dst[i]` (right operand register reused in place).
    BinAccB { op: Op, dst: u16, a: Src },
    /// `dst[i] = a op b`, `dst` distinct from any register operand.
    Bin { op: Op, dst: u16, a: Src, b: Src },
    /// Fused multiply-accumulate: `t = a*b` then
    /// `dst = t op c` (`mul_first`) or `dst = c op t` (`!mul_first`),
    /// as two f32 operations per lane (never FMA). `dst` is distinct
    /// from any register operand.
    MulAcc { op: Op, mul_first: bool, dst: u16, a: Src, b: Src, c: Src },
}

/// Lowering statistics — exposed so tests can assert each pass fired.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanStats {
    /// Instructions in the source program.
    pub instrs: usize,
    /// Columnar ops emitted (row passes per chunk).
    pub row_ops: usize,
    /// Scalar-prologue ops (per launch, not per lane).
    pub scalar_ops: usize,
    /// DAG nodes deduplicated by hash-consing.
    pub cse_merged: usize,
    /// Operations folded to immediates at build time.
    pub folded: usize,
    /// MUL+ADD / MUL+SUB pairs fused into `MulAcc`.
    pub fused: usize,
    /// Peak register rows required.
    pub regs: usize,
}

/// A lowered, ready-to-execute plan for one program.
#[derive(Debug, Clone)]
pub struct ExecPlan {
    ops: Vec<PlanOp>,
    scalars: Vec<ScalarOp>,
    root: Src,
    /// Sample dimensions read (equals the source program's `dims`).
    pub dims: usize,
    /// Parameter slots read (equals the source program's `n_params`).
    pub n_params: usize,
    stats: PlanStats,
}

/// Reusable execution scratch: the register arena plus the scalar
/// table. One per worker — steady-state `run` calls allocate nothing.
#[derive(Debug)]
pub struct PlanScratch {
    chunk: usize,
    regs: Vec<f32>,
    scalars: Vec<f32>,
}

impl PlanScratch {
    pub fn new(chunk: usize) -> Self {
        PlanScratch { chunk, regs: Vec::new(), scalars: Vec::new() }
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }

    fn ensure(&mut self, plan: &ExecPlan) {
        let want = plan.stats.regs * self.chunk;
        if self.regs.len() < want {
            self.regs.resize(want, 0.0);
        }
        if self.scalars.len() < plan.scalars.len() {
            self.scalars.resize(plan.scalars.len(), 0.0);
        }
    }
}

// ---------------------------------------------------------------------
// DAG construction (hash-consing + folding)

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum NodeKey {
    Const(u32), // f32 bits
    Var(u16),
    Param(u16),
    Un(Op, u32),
    Bin(Op, u32, u32),
}

#[derive(Debug, Clone, Copy)]
struct Node {
    key: NodeKey,
    uniform: bool,
    uses: u32,
}

struct Builder {
    nodes: Vec<Node>,
    table: HashMap<NodeKey, u32>,
    cse_merged: usize,
    folded: usize,
}

impl Builder {
    fn intern(&mut self, key: NodeKey, uniform: bool) -> u32 {
        if let Some(&id) = self.table.get(&key) {
            self.cse_merged += 1;
            return id;
        }
        let id = self.nodes.len() as u32;
        self.nodes.push(Node { key, uniform, uses: 0 });
        self.table.insert(key, id);
        id
    }

    fn const_of(&self, id: u32) -> Option<f32> {
        match self.nodes[id as usize].key {
            NodeKey::Const(bits) => Some(f32::from_bits(bits)),
            _ => None,
        }
    }

    /// Rebuild the stack program as a DAG, folding const-only ops with
    /// the interpreter's own scalar f32 semantics.
    fn build(prog: &Program) -> (Builder, u32) {
        let mut b = Builder {
            nodes: Vec::with_capacity(prog.len()),
            table: HashMap::with_capacity(prog.len()),
            cse_merged: 0,
            folded: 0,
        };
        let mut stack: Vec<u32> = Vec::with_capacity(prog.len());
        for ins in prog.instrs() {
            match ins.op {
                Op::HALT => {}
                Op::CONST => {
                    let id =
                        b.intern(NodeKey::Const(ins.farg.to_bits()), true);
                    stack.push(id);
                }
                Op::VAR => {
                    let id = b.intern(NodeKey::Var(ins.iarg as u16), false);
                    stack.push(id);
                }
                Op::PARAM => {
                    let id = b.intern(NodeKey::Param(ins.iarg as u16), true);
                    stack.push(id);
                }
                op if op.arity() == 1 => {
                    let a = stack.pop().expect("validated program");
                    let id = if let Some(av) = b.const_of(a) {
                        b.folded += 1;
                        b.intern(
                            NodeKey::Const(unary_f32(op, av).to_bits()),
                            true,
                        )
                    } else {
                        let uni = b.nodes[a as usize].uniform;
                        b.intern(NodeKey::Un(op, a), uni)
                    };
                    stack.push(id);
                }
                op => {
                    let rb = stack.pop().expect("validated program");
                    let ra = stack.pop().expect("validated program");
                    let id = match (b.const_of(ra), b.const_of(rb)) {
                        (Some(av), Some(bv)) => {
                            b.folded += 1;
                            b.intern(
                                NodeKey::Const(
                                    binary_f32(op, av, bv).to_bits(),
                                ),
                                true,
                            )
                        }
                        _ => {
                            let uni = b.nodes[ra as usize].uniform
                                && b.nodes[rb as usize].uniform;
                            b.intern(NodeKey::Bin(op, ra, rb), uni)
                        }
                    };
                    stack.push(id);
                }
            }
        }
        let root = stack.pop().expect("validated program leaves one value");
        debug_assert!(stack.is_empty());
        // Use counts over DAG edges (root gets one extra so its register
        // is never recycled before the copy-out).
        for i in 0..b.nodes.len() {
            match b.nodes[i].key {
                NodeKey::Un(_, a) => b.nodes[a as usize].uses += 1,
                NodeKey::Bin(_, a, bb) => {
                    b.nodes[a as usize].uses += 1;
                    b.nodes[bb as usize].uses += 1;
                }
                _ => {}
            }
        }
        b.nodes[root as usize].uses += 1;
        (b, root)
    }
}

// ---------------------------------------------------------------------
// Lowering (register allocation + peephole fusion)

struct Lowerer {
    nodes: Vec<Node>,
    ops: Vec<PlanOp>,
    scalars: Vec<ScalarOp>,
    lowered: Vec<Option<Src>>,
    slot_of: Vec<Option<u16>>,
    free: Vec<u16>,
    n_regs: u16,
    fused: usize,
}

impl Lowerer {
    fn alloc(&mut self) -> u16 {
        if let Some(r) = self.free.pop() {
            r
        } else {
            let r = self.n_regs;
            self.n_regs += 1;
            r
        }
    }

    /// Count one consumption of `id`; recycle its register after the
    /// last use (emission order == execution order, so this is safe).
    fn consume(&mut self, id: u32) {
        let n = &mut self.nodes[id as usize];
        n.uses -= 1;
        if n.uses == 0 {
            if let Some(Src::Reg(r)) = self.lowered[id as usize] {
                self.free.push(r);
            }
        }
    }

    /// Lower a uniform node into the scalar prologue; returns its slot
    /// (or immediate for constants).
    fn lower_scalar(&mut self, id: u32) -> SSrc {
        if let Some(slot) = self.slot_of[id as usize] {
            return SSrc::Slot(slot);
        }
        let key = self.nodes[id as usize].key;
        match key {
            NodeKey::Const(bits) => SSrc::Imm(f32::from_bits(bits)),
            NodeKey::Param(i) => {
                let slot = self.push_scalar(ScalarOp::Theta(i));
                self.slot_of[id as usize] = Some(slot);
                SSrc::Slot(slot)
            }
            NodeKey::Un(op, a) => {
                let sa = self.lower_scalar(a);
                let slot = self.push_scalar(ScalarOp::Un(op, sa));
                self.slot_of[id as usize] = Some(slot);
                SSrc::Slot(slot)
            }
            NodeKey::Bin(op, a, b) => {
                let sa = self.lower_scalar(a);
                let sb = self.lower_scalar(b);
                let slot = self.push_scalar(ScalarOp::Bin(op, sa, sb));
                self.slot_of[id as usize] = Some(slot);
                SSrc::Slot(slot)
            }
            NodeKey::Var(_) => unreachable!("uniform node cannot read VAR"),
        }
    }

    fn push_scalar(&mut self, op: ScalarOp) -> u16 {
        self.scalars.push(op);
        (self.scalars.len() - 1) as u16
    }

    /// True if `id` is a non-uniform single-use MUL — fusable into the
    /// consuming ADD/SUB without changing any lane's op sequence.
    fn fusable_mul(&self, id: u32) -> bool {
        let n = &self.nodes[id as usize];
        !n.uniform
            && n.uses == 1
            && self.lowered[id as usize].is_none()
            && matches!(n.key, NodeKey::Bin(Op::MUL, _, _))
    }

    /// Lower `id` to an operand, emitting ops for it if needed.
    fn lower(&mut self, id: u32) -> Src {
        if let Some(src) = self.lowered[id as usize] {
            return src;
        }
        let node = self.nodes[id as usize];
        let src = if node.uniform {
            match self.lower_scalar(id) {
                SSrc::Imm(v) => Src::Imm(v),
                SSrc::Slot(s) => Src::Scalar(s),
            }
        } else {
            match node.key {
                NodeKey::Var(d) => {
                    let dst = self.alloc();
                    self.ops.push(PlanOp::VarAffine { dst, dim: d });
                    Src::Reg(dst)
                }
                NodeKey::Un(op, a) => {
                    let sa = self.lower(a);
                    let Src::Reg(ra) = sa else {
                        unreachable!("non-uniform unary has a reg operand")
                    };
                    // consume-then-alloc: a dying operand's row comes
                    // straight back off the free list as the
                    // destination, making the op in-place.
                    self.consume(a);
                    let dst = self.alloc();
                    if dst == ra {
                        self.ops.push(PlanOp::UnInPlace { op, dst });
                    } else {
                        self.ops.push(PlanOp::Un { op, dst, a: ra });
                    }
                    Src::Reg(dst)
                }
                NodeKey::Bin(op, a, b) => self.lower_bin(op, a, b),
                NodeKey::Const(_) | NodeKey::Param(_) => {
                    unreachable!("leaf constants/params are uniform")
                }
            }
        };
        self.lowered[id as usize] = Some(src);
        src
    }

    fn lower_bin(&mut self, op: Op, a: u32, b: u32) -> Src {
        // Peephole: a single-use MUL feeding ADD/SUB fuses into MulAcc.
        // Operand order is preserved exactly (mul_first records which
        // side of the add/sub the product sits on).
        if matches!(op, Op::ADD | Op::SUB) {
            if self.fusable_mul(a) {
                let NodeKey::Bin(_, ma, mb) = self.nodes[a as usize].key
                else {
                    unreachable!()
                };
                return self.emit_mulacc(op, true, ma, mb, b, a);
            }
            if self.fusable_mul(b) {
                let NodeKey::Bin(_, ma, mb) = self.nodes[b as usize].key
                else {
                    unreachable!()
                };
                return self.emit_mulacc(op, false, ma, mb, a, b);
            }
        }
        let sa = self.lower(a);
        let sb = self.lower(b);
        // consume-then-alloc (see the unary case): if either operand's
        // row died, `alloc` hands it back and the op runs in place.
        self.consume(a);
        self.consume(b);
        let dst = self.alloc();
        if sa == Src::Reg(dst) {
            // covers `a == b` too: BinAccA with b aliased to dst is the
            // `dst = f(dst, dst)` loop at execution time
            self.ops.push(PlanOp::BinAccA { op, dst, b: sb });
        } else if sb == Src::Reg(dst) {
            self.ops.push(PlanOp::BinAccB { op, dst, a: sa });
        } else {
            self.ops.push(PlanOp::Bin { op, dst, a: sa, b: sb });
        }
        Src::Reg(dst)
    }

    /// Emit a fused multiply-accumulate for `ADD/SUB(MUL(ma, mb), c)`
    /// (`mul_first`) or `ADD/SUB(c, MUL(ma, mb))`. `mul_node` is the
    /// consumed MUL (never materialized). The destination register is
    /// allocated *before* the operands are recycled, so it can never
    /// alias a live operand row.
    fn emit_mulacc(
        &mut self,
        op: Op,
        mul_first: bool,
        ma: u32,
        mb: u32,
        c: u32,
        mul_node: u32,
    ) -> Src {
        let sa = self.lower(ma);
        let sb = self.lower(mb);
        let sc = self.lower(c);
        let dst = self.alloc();
        self.consume(ma);
        self.consume(mb);
        self.consume(c);
        // account the fused MUL's single consumption (by this op)
        self.nodes[mul_node as usize].uses -= 1;
        debug_assert!(
            sa != Src::Reg(dst) && sb != Src::Reg(dst) && sc != Src::Reg(dst)
        );
        self.fused += 1;
        self.ops.push(PlanOp::MulAcc { op, mul_first, dst, a: sa, b: sb, c: sc });
        Src::Reg(dst)
    }
}

impl ExecPlan {
    /// Lower a validated program. Infallible: every `Program` invariant
    /// the stack interpreter relies on holds here too.
    pub fn lower(prog: &Program) -> ExecPlan {
        let (builder, root) = Builder::build(prog);
        let n = builder.nodes.len();
        let mut lw = Lowerer {
            nodes: builder.nodes,
            ops: Vec::with_capacity(n),
            scalars: Vec::new(),
            lowered: vec![None; n],
            slot_of: vec![None; n],
            free: Vec::new(),
            n_regs: 0,
            fused: 0,
        };
        let root_src = lw.lower(root);
        let stats = PlanStats {
            instrs: prog.len(),
            row_ops: lw.ops.len(),
            scalar_ops: lw.scalars.len(),
            cse_merged: builder.cse_merged,
            folded: builder.folded,
            fused: lw.fused,
            regs: lw.n_regs as usize,
        };
        ExecPlan {
            ops: lw.ops,
            scalars: lw.scalars,
            root: root_src,
            dims: prog.dims,
            n_params: prog.n_params,
            stats,
        }
    }

    pub fn stats(&self) -> PlanStats {
        self.stats
    }

    pub fn ops(&self) -> &[PlanOp] {
        &self.ops
    }

    /// Where the final value lives once the op sequence has run.
    pub(crate) fn root(&self) -> Src {
        self.root
    }

    /// Run the scalar prologue (once per launch, not per lane) into
    /// `out`, growing it to the plan's slot count if needed.
    pub(crate) fn eval_scalars(&self, theta: &[f32], out: &mut Vec<f32>) {
        if out.len() < self.scalars.len() {
            out.resize(self.scalars.len(), 0.0);
        }
        for (i, sop) in self.scalars.iter().enumerate() {
            let v = match *sop {
                ScalarOp::Theta(t) => theta[t as usize],
                ScalarOp::Un(op, a) => unary_f32(op, sval(a, out)),
                ScalarOp::Bin(op, a, b) => {
                    binary_f32(op, sval(a, out), sval(b, out))
                }
            };
            out[i] = v;
        }
    }

    /// Evaluate over `n <= scratch.chunk()` samples given *unit-cube*
    /// uniform columns `u` (dimension-major, `u[d][i]`), per-dimension
    /// bounds `lo`/`hi`, and parameters `theta`. Results land in
    /// `out[..n]`, bit-identical to mapping `x = lo + (hi-lo)*u`
    /// per dimension and running [`BatchInterp::eval`] on the result.
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &self,
        u: &[impl AsRef<[f32]>],
        lo: &[f32],
        hi: &[f32],
        theta: &[f32],
        n: usize,
        scratch: &mut PlanScratch,
        out: &mut [f32],
    ) {
        assert!(n <= scratch.chunk);
        assert!(u.len() >= self.dims && lo.len() >= self.dims);
        assert!(theta.len() >= self.n_params);
        scratch.ensure(self);
        // scalar prologue: once per launch chunk, not per lane
        self.eval_scalars(theta, &mut scratch.scalars);
        let chunk = scratch.chunk;
        for op in &self.ops {
            exec_op(op, &mut scratch.regs, &scratch.scalars, chunk, n, u, lo, hi);
        }
        match self.root {
            Src::Reg(r) => out[..n].copy_from_slice(
                &scratch.regs[r as usize * chunk..r as usize * chunk + n],
            ),
            Src::Imm(v) => out[..n].fill(v),
            Src::Scalar(s) => out[..n].fill(scratch.scalars[s as usize]),
        }
    }
}

#[inline(always)]
fn sval(s: SSrc, scalars: &[f32]) -> f32 {
    match s {
        SSrc::Imm(v) => v,
        SSrc::Slot(i) => scalars[i as usize],
    }
}

/// Either a register row (sliced to the live lanes) or a broadcast
/// scalar — resolved from a [`Src`] before entering the lane loops.
enum Rowed<'a> {
    Row(&'a [f32]),
    Val(f32),
}

/// Carve one mutable row plus up to three shared rows out of the
/// register arena. All indices must be distinct from `dst` (duplicate
/// *read* indices are fine — they share a slice). Pure safe code: the
/// arena is progressively `split_at_mut` at sorted row boundaries.
fn carve<'a>(
    regs: &'a mut [f32],
    chunk: usize,
    n: usize,
    dst: u16,
    reads: [Option<u16>; 3],
) -> (&'a mut [f32], [Option<&'a [f32]>; 3]) {
    // unique sorted row indices involved
    let mut uniq = [0u16; 4];
    let mut m = 0usize;
    for idx in std::iter::once(dst).chain(reads.iter().copied().flatten()) {
        if !uniq[..m].contains(&idx) {
            uniq[m] = idx;
            m += 1;
        }
    }
    uniq[..m].sort_unstable();
    // progressively split the arena so each involved row is its own piece
    let mut pieces: [Option<&'a mut [f32]>; 4] = [None, None, None, None];
    let mut rest: &'a mut [f32] = regs;
    let mut consumed = 0usize;
    for (k, &idx) in uniq[..m].iter().enumerate() {
        let start = idx as usize * chunk - consumed;
        let tail = std::mem::take(&mut rest);
        let (_, at_row) = tail.split_at_mut(start);
        let (row, after) = at_row.split_at_mut(chunk);
        pieces[k] = Some(row);
        rest = after;
        consumed = (idx as usize + 1) * chunk;
    }
    // hand the dst piece back mutable, demote the rest to shared
    let mut dst_row: Option<&'a mut [f32]> = None;
    let mut shared: [Option<&'a [f32]>; 4] = [None; 4];
    for (k, &idx) in uniq[..m].iter().enumerate() {
        let piece = pieces[k].take().expect("carved above");
        if idx == dst {
            dst_row = Some(piece);
        } else {
            shared[k] = Some(&piece[..n]);
        }
    }
    let find = |want: u16| -> Option<&'a [f32]> {
        debug_assert_ne!(want, dst, "read row may not alias dst");
        uniq[..m]
            .iter()
            .position(|&i| i == want)
            .and_then(|k| shared[k])
    };
    let out_reads = [
        reads[0].and_then(find),
        reads[1].and_then(find),
        reads[2].and_then(find),
    ];
    let d: &'a mut [f32] = dst_row.expect("dst is always carved");
    (&mut d[..n], out_reads)
}

#[inline(always)]
fn reg_of(s: Src) -> Option<u16> {
    match s {
        Src::Reg(r) => Some(r),
        _ => None,
    }
}

/// Execute one plan op over the first `n` lanes of a `chunk`-wide
/// register arena. Generic over the uniform-column storage so both the
/// plan tier (`Vec<f32>` chunks) and the fused tier (`[f32; LANES]`
/// blocks) run the *same* monomorphized lane loops — the foundation of
/// the tiers' bit-for-bit agreement.
#[allow(clippy::too_many_arguments)]
pub(crate) fn exec_op(
    op: &PlanOp,
    regs: &mut [f32],
    scalars: &[f32],
    chunk: usize,
    n: usize,
    u: &[impl AsRef<[f32]>],
    lo: &[f32],
    hi: &[f32],
) {
    match *op {
        PlanOp::VarAffine { dst, dim } => {
            let d = dim as usize;
            let l = lo[d];
            let w = hi[d] - lo[d];
            let row =
                &mut regs[dst as usize * chunk..dst as usize * chunk + n];
            for (x, &ui) in row.iter_mut().zip(&u[d].as_ref()[..n]) {
                *x = l + w * ui;
            }
        }
        PlanOp::UnInPlace { op, dst } => {
            let row =
                &mut regs[dst as usize * chunk..dst as usize * chunk + n];
            unary_each(op, |f| row.iter_mut().for_each(|x| *x = f(*x)));
        }
        PlanOp::Un { op, dst, a } => {
            let (drow, [ar, _, _]) =
                carve(regs, chunk, n, dst, [Some(a), None, None]);
            let ar = ar.expect("unary operand row");
            unary_each(op, |f| {
                drow.iter_mut().zip(ar).for_each(|(x, &v)| *x = f(v))
            });
        }
        PlanOp::BinAccA { op, dst, b } => match reg_of(b) {
            // both operands were the same dying row (e.g. t*t)
            Some(rb) if rb == dst => {
                let row =
                    &mut regs[dst as usize * chunk..dst as usize * chunk + n];
                binary_each(op, |f| {
                    row.iter_mut().for_each(|x| *x = f(*x, *x))
                });
            }
            Some(rb) => {
                let (drow, [br, _, _]) =
                    carve(regs, chunk, n, dst, [Some(rb), None, None]);
                let br = br.expect("acc operand row");
                binary_each(op, |f| {
                    drow.iter_mut().zip(br).for_each(|(x, &y)| *x = f(*x, y))
                });
            }
            None => {
                let v = src_val(b, scalars);
                let row =
                    &mut regs[dst as usize * chunk..dst as usize * chunk + n];
                binary_each(op, |f| row.iter_mut().for_each(|x| *x = f(*x, v)));
            }
        },
        PlanOp::BinAccB { op, dst, a } => match reg_of(a) {
            Some(ra) => {
                let (drow, [ar, _, _]) =
                    carve(regs, chunk, n, dst, [Some(ra), None, None]);
                let ar = ar.expect("acc operand row");
                binary_each(op, |f| {
                    drow.iter_mut().zip(ar).for_each(|(x, &y)| *x = f(y, *x))
                });
            }
            None => {
                let v = src_val(a, scalars);
                let row =
                    &mut regs[dst as usize * chunk..dst as usize * chunk + n];
                binary_each(op, |f| row.iter_mut().for_each(|x| *x = f(v, *x)));
            }
        },
        PlanOp::Bin { op, dst, a, b } => {
            let (drow, [ar, br, _]) =
                carve(regs, chunk, n, dst, [reg_of(a), reg_of(b), None]);
            match (ar, br) {
                (Some(ar), Some(br)) => binary_each(op, |f| {
                    drow.iter_mut()
                        .zip(ar)
                        .zip(br)
                        .for_each(|((x, &y), &z)| *x = f(y, z))
                }),
                (Some(ar), None) => {
                    let v = src_val(b, scalars);
                    binary_each(op, |f| {
                        drow.iter_mut().zip(ar).for_each(|(x, &y)| *x = f(y, v))
                    });
                }
                (None, Some(br)) => {
                    let v = src_val(a, scalars);
                    binary_each(op, |f| {
                        drow.iter_mut().zip(br).for_each(|(x, &y)| *x = f(v, y))
                    });
                }
                (None, None) => unreachable!("uniform op reached row loop"),
            }
        }
        PlanOp::MulAcc { op, mul_first, dst, a, b, c } => {
            let (drow, rows) = carve(
                regs,
                chunk,
                n,
                dst,
                [reg_of(a), reg_of(b), reg_of(c)],
            );
            let ga = rowed(a, rows[0], scalars);
            let gb = rowed(b, rows[1], scalars);
            let gc = rowed(c, rows[2], scalars);
            binary_each(op, |f| {
                mulacc_loop(drow, &ga, &gb, &gc, mul_first, f)
            });
        }
    }
}

#[inline(always)]
fn src_val(s: Src, scalars: &[f32]) -> f32 {
    match s {
        Src::Imm(v) => v,
        Src::Scalar(i) => scalars[i as usize],
        Src::Reg(_) => unreachable!("register operand resolved elsewhere"),
    }
}

fn rowed<'a>(s: Src, row: Option<&'a [f32]>, scalars: &[f32]) -> Rowed<'a> {
    match s {
        Src::Reg(_) => Rowed::Row(row.expect("carved row for reg operand")),
        other => Rowed::Val(src_val(other, scalars)),
    }
}

/// `dst[i] = f(a*b, c)` / `f(c, a*b)` — the product is one explicit f32
/// multiply followed by the f32 accumulate, exactly the two operations
/// the unfused pair performs (FP contraction is off in Rust, so this
/// never becomes an FMA).
#[inline(always)]
fn mulacc_loop<F: Fn(f32, f32) -> f32>(
    dst: &mut [f32],
    a: &Rowed<'_>,
    b: &Rowed<'_>,
    c: &Rowed<'_>,
    mul_first: bool,
    f: F,
) {
    let get = |r: &Rowed<'_>, i: usize| match *r {
        Rowed::Row(s) => s[i],
        Rowed::Val(v) => v,
    };
    for i in 0..dst.len() {
        let t = get(a, i) * get(b, i);
        dst[i] = if mul_first { f(t, get(c, i)) } else { f(get(c, i), t) };
    }
}

/// Monomorphize a lane loop per unary opcode: the `match` runs once per
/// row, the closure body inlines the concrete operation. Expressions
/// are textually identical to [`unary_f32`].
#[inline(always)]
fn unary_each<G: FnOnce(fn(f32) -> f32)>(op: Op, go: G) {
    match op {
        Op::NEG => go(|a| -a),
        Op::ABS => go(|a| a.abs()),
        Op::SIN => go(|a| a.sin()),
        Op::COS => go(|a| a.cos()),
        Op::TAN => go(|a| a.tan()),
        Op::EXP => go(|a| a.exp()),
        Op::LOG => go(|a| a.ln()),
        Op::SQRT => go(|a| a.sqrt()),
        Op::TANH => go(|a| a.tanh()),
        Op::ATAN => go(|a| a.atan()),
        Op::FLOOR => go(|a| a.floor()),
        Op::SQUARE => go(|a| a * a),
        Op::RECIP => go(|a| 1.0 / a),
        _ => unreachable!("not unary: {op:?}"),
    }
}

/// Binary twin of [`unary_each`] (expressions match [`binary_f32`]).
#[inline(always)]
fn binary_each<G: FnOnce(fn(f32, f32) -> f32)>(op: Op, go: G) {
    match op {
        Op::ADD => go(|a, b| a + b),
        Op::SUB => go(|a, b| a - b),
        Op::MUL => go(|a, b| a * b),
        Op::DIV => go(|a, b| a / b),
        Op::POW => go(|a, b| a.powf(b)),
        Op::MIN => go(|a, b| a.min(b)),
        Op::MAX => go(|a, b| a.max(b)),
        _ => unreachable!("not binary: {op:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::Expr;
    use crate::vm::interp::BatchInterp;
    use crate::vm::program::Instr;

    fn plan_of(src: &str) -> ExecPlan {
        ExecPlan::lower(&Expr::parse(src).unwrap().compile().unwrap())
    }

    /// Bit-exact cross-check of one plan against the stack interpreter
    /// over deterministic pseudo-random lanes.
    fn check(src: &str, dims: usize, theta: &[f32]) {
        let prog = Expr::parse(src).unwrap().compile().unwrap();
        let plan = ExecPlan::lower(&prog);
        assert_eq!(plan.dims, prog.dims);
        let n = 197;
        let chunk = 256;
        let lo: Vec<f32> = (0..dims).map(|d| -0.5 + d as f32 * 0.25).collect();
        let hi: Vec<f32> = (0..dims).map(|d| 1.5 + d as f32 * 0.5).collect();
        let u: Vec<Vec<f32>> = (0..dims)
            .map(|d| {
                (0..chunk)
                    .map(|i| ((i * 37 + d * 101) % 1000) as f32 / 1000.0)
                    .collect()
            })
            .collect();
        // oracle: affine-map then stack-interpret
        let xt: Vec<Vec<f32>> = (0..dims)
            .map(|d| {
                u[d].iter().map(|&ui| lo[d] + (hi[d] - lo[d]) * ui).collect()
            })
            .collect();
        let mut interp = BatchInterp::new(chunk);
        let mut want = vec![0f32; chunk];
        interp.eval(&prog, &xt, theta, n, &mut want);
        let mut scratch = PlanScratch::new(chunk);
        let mut got = vec![0f32; chunk];
        plan.run(&u, &lo, &hi, theta, n, &mut scratch, &mut got);
        for i in 0..n {
            assert_eq!(
                got[i].to_bits(),
                want[i].to_bits(),
                "{src}: lane {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn constant_folding_to_immediate() {
        // raw instructions (the expr layer would pre-fold this): the
        // plan-level folder must collapse 2*3+1 to one immediate with
        // zero row ops
        let p = Program::new(vec![
            Instr::konst(2.0),
            Instr::konst(3.0),
            Instr::new(Op::MUL),
            Instr::konst(1.0),
            Instr::new(Op::ADD),
        ])
        .unwrap();
        let plan = ExecPlan::lower(&p);
        assert_eq!(plan.stats().row_ops, 0);
        assert_eq!(plan.root, Src::Imm(7.0));
        assert_eq!(plan.stats().folded, 2);
    }

    #[test]
    fn uniform_subtree_hoisted_to_scalar_prologue() {
        // 2*pi*p0 is lane-invariant: zero row ops for it, evaluated per
        // launch in the scalar prologue instead.
        let plan = plan_of("cos(2*pi*p0 + p1*x1)");
        let s = plan.stats();
        assert!(s.scalar_ops >= 2, "{s:?}"); // theta loads + the product
        // row side: affine var load, fused mul-add, cos
        assert!(s.row_ops <= 3, "{s:?}");
        assert_eq!(s.fused, 1);
        check("cos(2*pi*p0 + p1*x1)", 1, &[0.3, 1.7]);
    }

    #[test]
    fn cse_merges_repeated_subtrees() {
        let plan = plan_of("sin(x1*x2) + sin(x1*x2)");
        let s = plan.stats();
        assert!(s.cse_merged >= 1, "{s:?}");
        // two var loads, one product, one sin, one add — the stack
        // interpreter would pay nine row passes for this program
        assert!(s.row_ops <= 5, "{s:?}");
        check("sin(x1*x2) + sin(x1*x2)", 2, &[]);
    }

    #[test]
    fn mul_add_fuses_without_changing_bits() {
        let plan = plan_of("x1*x2 + x3");
        assert_eq!(plan.stats().fused, 1);
        assert!(plan
            .ops()
            .iter()
            .any(|o| matches!(o, PlanOp::MulAcc { op: Op::ADD, .. })));
        check("x1*x2 + x3", 3, &[]);
        check("x3 - x1*x2", 3, &[]);
        check("x1*x2 - x3", 3, &[]);
    }

    #[test]
    fn shared_mul_is_not_fused() {
        // the product is used twice — fusing would either duplicate the
        // multiply or break CSE, so it must materialize
        let plan = plan_of("(x1*x2 + x3) + (x1*x2)");
        let s = plan.stats();
        assert_eq!(s.fused, 0, "{s:?}");
        check("(x1*x2 + x3) + (x1*x2)", 3, &[]);
    }

    #[test]
    fn register_rows_are_recycled() {
        // a long sum chain needs O(1) registers, not O(len)
        let plan = plan_of("x1*p1 + x2*p2 + x3*p3 + x4*p4 + x5*p5");
        assert!(plan.stats().regs <= 4, "{:?}", plan.stats());
        check("x1*p1 + x2*p2 + x3*p3 + x4*p4 + x5*p5", 5, &[
            0.0, 1.1, 2.2, 3.3, 4.4, 5.5,
        ]);
    }

    #[test]
    fn affine_domain_map_is_folded_into_var_loads() {
        let plan = plan_of("x1 + x2");
        assert!(plan
            .ops()
            .iter()
            .any(|o| matches!(o, PlanOp::VarAffine { .. })));
        check("x1 + x2", 2, &[]);
    }

    #[test]
    fn genz_style_programs_bit_exact() {
        check("cos(2*pi*p0 + p1*x1 + p2*x2 + p3*x3)", 3, &[
            0.25, 1.3, 0.7, 2.1,
        ]);
        check(
            "1/((p0^(0-2) + (x1-p2)^2) * (p1^(0-2) + (x2-p3)^2))",
            2,
            &[2.0, 3.0, 0.35, 0.65],
        );
        check("exp(0 - (p0*p0*(x1-p2)^2 + p1*p1*(x2-p3)^2))", 2, &[
            1.5, 2.5, 0.5, 0.5,
        ]);
        check("(1 + p0*x1 + p1*x2)^(0-3)", 2, &[0.4, 0.6]);
        check("exp(0 - p0*abs(x1 - p1))", 1, &[2.0, 0.5]);
    }

    #[test]
    fn special_values_fold_bit_exactly() {
        // folding 1/0 and sqrt(-1) must produce the exact inf/NaN bits
        // the runtime op would
        let p = Program::new(vec![
            Instr::konst(1.0),
            Instr::konst(0.0),
            Instr::new(Op::DIV),
            Instr::var(0),
            Instr::new(Op::ADD),
        ])
        .unwrap();
        let plan = ExecPlan::lower(&p);
        let mut interp = BatchInterp::new(8);
        let u = vec![vec![0.25f32; 8]];
        let xt = vec![vec![0.25f32; 8]];
        let (lo, hi) = (vec![0.0f32], vec![1.0f32]);
        let mut want = vec![0f32; 8];
        interp.eval(&p, &xt, &[], 8, &mut want);
        let mut scratch = PlanScratch::new(8);
        let mut got = vec![0f32; 8];
        plan.run(&u, &lo, &hi, &[], 8, &mut scratch, &mut got);
        for i in 0..8 {
            assert_eq!(got[i].to_bits(), want[i].to_bits());
        }
    }

    #[test]
    fn single_leaf_programs() {
        // pure-constant program: root is an immediate
        let p = Program::new(vec![Instr::konst(2.5)]).unwrap();
        let plan = ExecPlan::lower(&p);
        let mut scratch = PlanScratch::new(4);
        let mut out = vec![0f32; 4];
        plan.run(&[], &[], &[], &[], 4, &mut scratch, &mut out);
        assert_eq!(out, vec![2.5; 4]);
        // pure-param program: root is a launch-time scalar
        let p = Program::new(vec![Instr::param(1)]).unwrap();
        let plan = ExecPlan::lower(&p);
        plan.run(&[], &[], &[], &[0.0, 9.0], 4, &mut scratch, &mut out);
        assert_eq!(out, vec![9.0; 4]);
        // pure-var program: affine load only
        let p = Program::new(vec![Instr::var(0)]).unwrap();
        let plan = ExecPlan::lower(&p);
        let u = vec![vec![0.5f32; 4]];
        plan.run(&u, &[2.0], &[4.0], &[], 4, &mut scratch, &mut out);
        assert_eq!(out, vec![3.0; 4]);
    }

    #[test]
    fn lane_dispatch_tables_cover_every_opcode_bitwise() {
        // unary_each/binary_each textually duplicate the scalar op
        // tables; this guards against drift — a new opcode missing from
        // either copy panics here (unreachable!), and a divergent
        // expression fails the bit-compare.
        for &op in crate::vm::opcodes::ALL {
            match op.kind() {
                crate::vm::opcodes::Kind::Unary => {
                    for x in [0.7f32, -1.3, 4.0, 0.0] {
                        let mut got = f32::NAN;
                        unary_each(op, |f| got = f(x));
                        let want = unary_f32(op, x);
                        assert!(
                            got.to_bits() == want.to_bits()
                                || (got.is_nan() && want.is_nan()),
                            "{op:?}({x}): {got} vs {want}"
                        );
                    }
                }
                crate::vm::opcodes::Kind::Binary => {
                    for (x, y) in [(0.7f32, -1.3f32), (2.0, 3.0), (0.0, 0.5)]
                    {
                        let mut got = f32::NAN;
                        binary_each(op, |f| got = f(x, y));
                        let want = binary_f32(op, x, y);
                        assert!(
                            got.to_bits() == want.to_bits()
                                || (got.is_nan() && want.is_nan()),
                            "{op:?}({x},{y}): {got} vs {want}"
                        );
                    }
                }
                _ => {}
            }
        }
    }

    #[test]
    fn carve_handles_duplicate_reads() {
        let mut regs = vec![0f32; 4 * 8];
        regs[8..16].copy_from_slice(&[1.0; 8]);
        let (d, reads) =
            carve(&mut regs, 8, 8, 3, [Some(1), Some(1), Some(0)]);
        assert_eq!(d.len(), 8);
        assert_eq!(reads[0].unwrap(), &[1.0; 8]);
        assert_eq!(reads[1].unwrap(), &[1.0; 8]);
        assert_eq!(reads[2].unwrap(), &[0.0; 8]);
    }
}
