//! The bytecode VM — rust half of the integrand ABI.
//!
//! User expression strings are compiled (see [`crate::expr`]) into
//! fixed-width bytecode [`program::Program`]s that both the AOT device
//! kernels (`python/compile/vm_core.py`) and the in-process interpreter
//! ([`interp`]) evaluate identically. The interpreter serves as (a) the
//! CPU baseline comparator for the backend benches and (b) the
//! correctness oracle for property tests.

pub mod fused;
pub mod interp;
pub mod opcodes;
pub mod plan;
pub mod program;

pub use fused::{FusedPlan, FusedScratch, LANES};
pub use opcodes::Op;
pub use plan::{ExecPlan, PlanScratch};
pub use program::Program;
