//! Streaming execution of a columnar batch: bounded-window submission
//! with as-they-land reduction.
//!
//! The boxed path materializes **all** launch inputs up front
//! (`build_tasks`) and collects **all** launch outputs before reducing
//! — both O(batch) in memory, which is exactly what breaks at 10⁶
//! functions. This driver walks the identical global task sequence
//! (function blocks outer, sample chunks inner) through a
//! double-buffered window loop: submit window *k+1*, then drain window
//! *k* result-by-result through [`fold_tagged`] into the per-function
//! [`MomentSum`] column. At most two windows of launch tasks exist at
//! any moment, so peak memory is
//! `O(columns + watermark · launch_bytes)` — independent of the batch
//! size — while the device never idles between windows.
//!
//! Bit-identity with the boxed oracle holds because nothing about the
//! arithmetic changed, only its residency: tasks carry the same Philox
//! `(stream, base, trial)` addressing in the same global order, engine
//! and cluster handles both deliver results in task order (shards are
//! contiguous and drained ascending), and folding one output at a time
//! performs the per-slot merges in the very sequence `reduce_tagged`
//! would. `tests/batch_test.rs` asserts estimates and merged moments
//! bitwise against the boxed path across tiers, engine counts and
//! watermarks.

use anyhow::Result;

use crate::batch::columnar::{BatchJobs, BatchResults};
use crate::cluster::{fold_tagged, ExecHandle, LaunchExec};
use crate::engine::LaunchTask;
use crate::integrator::multifunctions::split_seed;
use crate::runtime::launch::RngCtr;
use crate::runtime::registry::ExeKind;
use crate::stats::MomentSum;

/// Default in-flight watermark: launch tasks per submission window.
/// Two windows ride the engine at once (one draining, one queued), so
/// the default bounds in-flight launch memory to 64 launches' worth of
/// inputs/outputs regardless of batch size.
pub const DEFAULT_WATERMARK: usize = 32;

/// Options for a streaming batch run — the one-shot subset of
/// [`crate::integrator::multifunctions::MultiConfig`] plus the
/// watermark. (Adaptive targets refine per-function sample counts and
/// are a boxed-path feature; a parameter scan wants uniform budgets.)
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Samples per function (rounded up to whole launches).
    pub samples_per_fn: usize,
    pub seed: u64,
    /// Independent-repeat id.
    pub trial: u32,
    /// First Philox stream id; function i uses `stream_base + i`.
    pub stream_base: u32,
    /// Per-window retry budget on the engine.
    pub max_retries: u32,
    /// Force a specific executable (default: best fit by dims+samples).
    pub exe: Option<String>,
    /// Max launch tasks per submission window (≥ 1); at most two
    /// windows are in flight. Any value yields bit-identical results —
    /// this knob trades peak memory against submission overhead.
    pub watermark: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            samples_per_fn: 1 << 20,
            seed: 2021,
            trial: 0,
            stream_base: 0,
            max_retries: 3,
            exe: None,
            watermark: DEFAULT_WATERMARK,
        }
    }
}

/// Integrate a columnar batch through an engine or cluster with
/// streaming reduction; returns columnar results (one estimate row per
/// function, in order) bit-identical to the boxed
/// [`crate::integrator::multifunctions::integrate`] on the same jobs.
pub fn integrate<X: LaunchExec + ?Sized>(
    exec: &X,
    jobs: &BatchJobs,
    cfg: &BatchConfig,
) -> Result<BatchResults> {
    if jobs.is_empty() {
        return Ok(BatchResults::from_moments(Vec::new(), jobs));
    }
    let reg = exec.registry();
    let exe = match &cfg.exe {
        Some(name) => reg.get(name)?,
        None => reg.pick(ExeKind::VmMulti, cfg.samples_per_fn, jobs.dims())?,
    };
    let n_chunks = cfg.samples_per_fn.div_ceil(exe.samples).max(1);
    let n_blocks = jobs.len().div_ceil(exe.n_fns);
    let total = n_blocks * n_chunks;
    let watermark = cfg.watermark.max(1);

    // one ledger line per batch run: how many programs the caches
    // actually see vs how many the dedup folded away
    let (unique, folded) = (jobs.n_classes() as u64, jobs.n_folded() as u64);
    reg.note_dedup(unique, folded);
    exec.metrics().record_dedup_events(unique, folded);

    // Global task index t enumerates the boxed path's exact sequence:
    // block b = t / n_chunks (outer), chunk c = t % n_chunks (inner).
    let window = |t0: usize, t1: usize| -> Result<Vec<LaunchTask>> {
        (t0..t1)
            .map(|t| {
                let (b, c) = (t / n_chunks, t % n_chunks);
                let rng = RngCtr {
                    seed: split_seed(cfg.seed),
                    base: (c * exe.samples) as u32,
                    trial: cfg.trial,
                };
                Ok(LaunchTask {
                    exe: exe.name.clone(),
                    tag: b as u64,
                    inputs: jobs.block_inputs(
                        exe,
                        rng,
                        b * exe.n_fns,
                        cfg.stream_base,
                    )?,
                })
            })
            .collect()
    };

    let mut moments = vec![MomentSum::new(); jobs.len()];
    let mut draining: Option<ExecHandle> = None;
    let mut t = 0usize;
    while t < total || draining.is_some() {
        // keep the next window queued before draining the current one,
        // so workers never starve at a window boundary
        let next = if t < total {
            let hi = (t + watermark).min(total);
            let tasks = window(t, hi)?;
            t = hi;
            Some(exec.submit_launches(tasks, cfg.max_retries)?)
        } else {
            None
        };
        if let Some(h) = draining.take() {
            h.wait_each(&mut |out| {
                fold_tagged(&mut moments, &out, exe.n_fns, exe.samples as u64)
            })?;
        }
        draining = next;
    }
    Ok(BatchResults::from_moments(moments, jobs))
}
