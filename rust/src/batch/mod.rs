//! Million-integrand batch subsystem: columnar jobs, hash-consed
//! program dedup, streaming reduction.
//!
//! The boxed multifunction path ([`crate::integrator::multifunctions`])
//! is comfortable at the paper's 10³ scale but carries three O(batch)
//! costs that wall it off from 10⁵–10⁶ functions: per-function boxed
//! jobs (a dozen heap allocations each), per-function program rows
//! (defeating every program-keyed cache below), and
//! materialize-everything execution (all launch inputs built up front,
//! all outputs collected before reduction). This module removes all
//! three without changing a single sampled bit:
//!
//! * [`dedup`] — hash-consed program identity *modulo constants*: a
//!   parameter scan's 10⁶ programs collapse to one canonical program
//!   whose constants ride the per-function theta column, so plan/fused
//!   LRUs and registry ledgers see **one** program;
//! * [`columnar`] — [`BatchJobs`]/[`BatchResults`], struct-of-arrays
//!   batches with iterator views yielding ordinary
//!   [`crate::integrator::spec::Estimate`]s;
//! * [`stream`] — bounded-watermark submission with as-they-land
//!   [`crate::cluster::fold_tagged`] reduction: peak memory is
//!   O(columns + watermark), not O(batch).
//!
//! The boxed path stays untouched as the bit-exact oracle at small n;
//! `tests/batch_test.rs` holds the two paths bitwise equal across
//! execution tiers, engine counts and watermarks.

pub mod columnar;
pub(crate) mod dedup;
pub mod stream;

pub use self::columnar::{BatchJobs, BatchResults};
pub use self::stream::{integrate, BatchConfig, DEFAULT_WATERMARK};
