//! Hash-consed program dedup: structural program identity **modulo
//! constant operands**.
//!
//! A parameter-scan batch of 10⁶ functions is typically one integrand
//! body instantiated with 10⁶ constant vectors. Shipping 10⁶ distinct
//! program rows defeats every cache below us — the per-worker
//! `ExecPlan`/`FusedPlan` LRUs key on the row bytes (constants
//! included), so each function is a miss and a fresh lowering. This
//! module folds such a batch onto its canonical shape: every `CONST`
//! occurrence is rewritten to a fresh `PARAM` slot after the
//! function's real parameters, and the constant values move into the
//! per-function theta vector. All members of a class then share **one**
//! program row — one LRU entry, one lowering, one ledger line — while
//! their constants ride the theta column that is per-function anyway.
//!
//! Bit-exactness: `CONST` and `PARAM` are both `Push` opcodes with
//! identical stack effect, and every execution tier (naive interpreter,
//! `ExecPlan`, fused) evaluates a pushed constant and a pushed theta
//! slot through the same scalar path — constant folding and uniform
//! hoisting in `vm/plan.rs` use the interpreter's own f32 kernels for
//! both. Rewriting `CONST c` to `PARAM j` with `theta[j] = c as f64`
//! (exact f32→f64→f32 round trip) therefore produces bit-identical
//! per-lane results on every tier; `tests/batch_test.rs` asserts it
//! end-to-end against the boxed oracle.
//!
//! Functions whose real parameters plus constants would overflow
//! `MAX_PARAM` theta slots keep their **verbatim** program (no
//! rewrite); they still dedup against byte-identical programs, which
//! covers the scan-over-theta case where the program carries no
//! varying constants at all.

use std::collections::HashMap;

use crate::abi::MAX_PARAM;
use crate::vm::opcodes::Op;
use crate::vm::program::{Instr, Program};

/// Exact structural identity of a program class. Two functions share a
/// class iff their keys are equal — a `HashMap` key, not a lossy hash,
/// so near-collision programs (same shape, one differing non-constant
/// operand) can never be merged by accident.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub(crate) struct ClassKey {
    /// Verbatim classes keep constant bits in `shape`; canonical
    /// classes mask them out (that is the dedup).
    verbatim: bool,
    /// First theta slot available for hoisted constants
    /// (`max(theta_len, program.n_params)`); part of the identity
    /// because it fixes the rewritten `PARAM` indices.
    base: usize,
    /// Per-instruction `(opcode, iarg, farg bits)`; `farg` of a
    /// `CONST` is masked to 0 in canonical keys.
    shape: Vec<(i32, i32, u32)>,
}

/// One function's dedup decision: which class it belongs to and how to
/// build that class's program / this function's extended theta.
pub(crate) struct Canon {
    pub key: ClassKey,
    /// First hoisted-constant theta slot (== original theta width for
    /// verbatim classes).
    pub base: usize,
    /// Constants hoisted into theta (0 for verbatim classes).
    pub n_consts: usize,
    pub verbatim: bool,
}

impl Canon {
    /// Width of this function's extended theta row.
    pub fn theta_width(&self) -> usize {
        self.base + self.n_consts
    }
}

/// Classify one function. `theta_len` is the function's bound
/// parameter count; the canonical rewrite allocates constant slots
/// after `max(theta_len, program.n_params)` so slots the program reads
/// as zero padding today still read zero padding afterwards.
pub(crate) fn classify(program: &Program, theta_len: usize) -> Canon {
    let base = theta_len.max(program.n_params);
    let n_consts =
        program.instrs().iter().filter(|i| i.op == Op::CONST).count();
    let verbatim = base + n_consts > MAX_PARAM;
    let shape = program
        .instrs()
        .iter()
        .map(|i| {
            let farg = if !verbatim && i.op == Op::CONST {
                0
            } else {
                i.farg.to_bits()
            };
            (i.op.code(), i.iarg, farg)
        })
        .collect();
    Canon {
        key: ClassKey { verbatim, base, shape },
        base,
        n_consts: if verbatim { 0 } else { n_consts },
        verbatim,
    }
}

/// Build the class's canonical program: each `CONST` occurrence `k`
/// (in order of appearance) becomes `PARAM(base + k)`. Only called for
/// non-verbatim classes, whose width was already checked against
/// `MAX_PARAM`, so revalidation cannot fail (same length, same stack
/// profile, in-range indices).
pub(crate) fn canonical_program(program: &Program, base: usize) -> Program {
    let mut k = 0usize;
    let instrs: Vec<Instr> = program
        .instrs()
        .iter()
        .map(|i| {
            if i.op == Op::CONST {
                let slot = base + k;
                k += 1;
                Instr::param(slot)
            } else {
                *i
            }
        })
        .collect();
    Program::new(instrs)
        .expect("CONST->PARAM rewrite preserves program validity")
}

/// Write one function's extended theta row: the original theta, zero
/// padding up to `base`, then each hoisted constant as f64 (exact
/// round trip back to f32 at launch build). `out` must be at least
/// `canon.theta_width()` wide; trailing slots are left untouched (the
/// caller's columns are zero-initialized, matching the launch
/// builder's own zero fill).
pub(crate) fn extended_theta_into(
    out: &mut [f64],
    canon: &Canon,
    program: &Program,
    theta: &[f64],
) {
    out[..theta.len()].copy_from_slice(theta);
    if !canon.verbatim {
        let mut k = 0usize;
        for i in program.instrs() {
            if i.op == Op::CONST {
                out[canon.base + k] = i.farg as f64;
                k += 1;
            }
        }
    }
}

/// Interning table: class key → dense class index.
#[derive(Default)]
pub(crate) struct ClassTable {
    map: HashMap<ClassKey, u32>,
}

impl ClassTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern a key; `Ok(existing)` or `Err(new_index)` when the
    /// caller must materialize the class program for `new_index`.
    pub fn intern(&mut self, key: ClassKey) -> Result<u32, u32> {
        let next = self.map.len() as u32;
        match self.map.entry(key) {
            std::collections::hash_map::Entry::Occupied(e) => Ok(*e.get()),
            std::collections::hash_map::Entry::Vacant(v) => {
                v.insert(next);
                Err(next)
            }
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::Expr;

    fn prog(src: &str) -> Program {
        Expr::parse(src).unwrap().compile().unwrap()
    }

    #[test]
    fn constants_fold_into_one_class() {
        // same shape, different constants: one canonical class
        let a = classify(&prog("2.5*x1 + 1.0"), 0);
        let b = classify(&prog("7.0*x1 + 3.5"), 0);
        assert!(!a.verbatim);
        assert_eq!(a.key, b.key);
        assert_eq!(a.base, 0);
        // structurally different programs stay apart
        let c = classify(&prog("2.5*x2 + 1.0"), 0);
        assert_ne!(a.key, c.key);
        let d = classify(&prog("2.5*x1 - 1.0"), 0);
        assert_ne!(a.key, d.key);
    }

    #[test]
    fn theta_width_separates_classes() {
        // same program shape bound with different theta widths must
        // not share a class: the rewritten PARAM indices differ
        let a = classify(&prog("p0*x1 + 2.0"), 1);
        let b = classify(&prog("p0*x1 + 2.0"), 3);
        assert_ne!(a.key, b.key);
        assert_eq!(a.base, 1);
        assert_eq!(b.base, 3);
    }

    #[test]
    fn canonical_program_rewrites_consts_in_order() {
        let p = prog("2.0*x1 + 3.0");
        let canon = classify(&p, 1); // one real param slot reserved
        assert_eq!(canon.n_consts, 2);
        let cp = canonical_program(&p, canon.base);
        assert_eq!(cp.len(), p.len());
        assert!(cp.instrs().iter().all(|i| i.op != Op::CONST));
        let params: Vec<i32> = cp
            .instrs()
            .iter()
            .filter(|i| i.op == Op::PARAM)
            .map(|i| i.iarg)
            .collect();
        assert_eq!(params, vec![1, 2]);

        let mut theta = vec![0.0f64; canon.theta_width()];
        extended_theta_into(&mut theta, &canon, &p, &[9.0]);
        assert_eq!(theta, vec![9.0, 2.0, 3.0]);
    }

    #[test]
    fn overflow_falls_back_to_verbatim() {
        // 17 constants summed: base 0 + 17 consts > MAX_PARAM=16
        let many = (0..17)
            .map(|i| format!("{}.5", i))
            .collect::<Vec<_>>()
            .join("+");
        let p = prog(&many);
        let canon = classify(&p, 0);
        assert!(canon.verbatim);
        assert_eq!(canon.n_consts, 0);
        assert_eq!(canon.theta_width(), 0);
        // byte-identical programs still share the verbatim class
        let again = classify(&prog(&many), 0);
        assert_eq!(canon.key, again.key);
        // a one-constant difference splits verbatim classes
        let other = many.replace("16.5", "16.25");
        assert_ne!(canon.key, classify(&prog(&other), 0).key);
    }

    #[test]
    fn interning_assigns_dense_indices() {
        let mut t = ClassTable::new();
        let a = classify(&prog("x1+1.0"), 0);
        let b = classify(&prog("x1+2.0"), 0);
        let c = classify(&prog("x1*x1"), 0);
        assert_eq!(t.intern(a.key.clone()), Err(0));
        assert_eq!(t.intern(b.key), Ok(0)); // folded into a's class
        assert_eq!(t.intern(c.key), Err(1));
        assert_eq!(t.len(), 2);
    }
}
