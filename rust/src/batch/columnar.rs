//! Columnar batch storage: struct-of-arrays jobs and results.
//!
//! The boxed path represents an N-function batch as `Vec<IntegralJob>`
//! — per function a `String`, an `Expr` tree, a `Program` vec, a
//! bounds vec and a theta vec, roughly a dozen heap allocations each.
//! At 10⁵–10⁶ functions that is the dominant memory and allocation
//! cost, before a single sample is drawn. [`BatchJobs`] stores the
//! same batch as a handful of contiguous columns: one interned
//! [`dedup`](super::dedup) class table (each class carries its
//! HALT-padded device rows exactly once) plus per-function `u32`
//! class ids, `f64` theta rows, `f32` bound rows and volumes.
//! [`BatchResults`] is the mirror on the way out — `f64` columns for
//! value/std-err, `u64`/`u32` columns for samples/rounds, and the
//! merged [`MomentSum`] column — with iterator views yielding the same
//! [`Estimate`] values the boxed path returns, so downstream callers
//! are unchanged.
//!
//! Layout notes: theta rows are padded to the batch-wide widest class
//! with zeros and bound rows with `(0, 1)` — exactly the defaults the
//! launch builder fills unused slots with, so padding is
//! indistinguishable from the boxed path's shorter rows and the
//! per-launch inputs come out byte-identical.

use anyhow::{bail, Result};

use crate::abi::{MAX_PARAM, MAX_PROG};
use crate::batch::dedup::{
    canonical_program, classify, extended_theta_into, ClassTable,
};
use crate::integrator::spec::{Estimate, IntegralJob};
use crate::runtime::launch::{RngCtr, Value};
use crate::runtime::registry::ExeSpec;
use crate::sampler::volume;
use crate::stats::MomentSum;
use crate::vm::program::Program;

/// One deduped program class: the canonical (or verbatim) program plus
/// its device rows, materialized once per class instead of once per
/// function.
pub(crate) struct BatchClass {
    pub program: Program,
    plen: i32,
    ops: Vec<i32>,
    iargs: Vec<i32>,
    fargs: Vec<f32>,
}

impl BatchClass {
    fn new(program: Program) -> Self {
        let plen = program.len() as i32;
        let (ops, iargs, fargs) = program.device_rows();
        BatchClass { program, plen, ops, iargs, fargs }
    }
}

/// A columnar batch of integrands: the million-function counterpart of
/// `&[IntegralJob]`. Built either from boxed jobs
/// ([`BatchJobs::from_jobs`]) or directly as a parameter scan
/// ([`BatchJobs::scan`] / [`BatchJobs::scan_with`]) without ever
/// materializing per-function boxes.
pub struct BatchJobs {
    classes: Vec<BatchClass>,
    class_of: Vec<u32>,
    /// Extended theta rows (real params ++ hoisted constants),
    /// row-major with stride `theta_stride`, zero-padded.
    theta: Vec<f64>,
    theta_stride: usize,
    /// Bound rows as f32 (converted once at build; the boxed path
    /// converts identically per launch), `(0, 1)`-padded. When
    /// `shared_bounds` one row serves every function.
    lo: Vec<f32>,
    hi: Vec<f32>,
    bounds_stride: usize,
    shared_bounds: bool,
    /// Per-function domain volumes (one entry when `shared_bounds`).
    volumes: Vec<f64>,
    /// Max per-function dimensionality — drives executable selection
    /// exactly like the boxed path's `jobs.map(dims).max()`.
    max_dims: usize,
    n: usize,
}

impl BatchJobs {
    /// Columnarize a boxed job set, interning structurally-equal
    /// programs (modulo constants) into shared classes. The batch is
    /// semantically identical to `jobs` — executing it yields
    /// bit-identical estimates.
    pub fn from_jobs(jobs: &[IntegralJob]) -> Result<BatchJobs> {
        // width pass: strides must be known before columns can fill
        let mut theta_stride = 0usize;
        let mut bounds_stride = 0usize;
        for (i, j) in jobs.iter().enumerate() {
            if j.theta.len() > MAX_PARAM {
                bail!("batch fn {i}: {} params > {MAX_PARAM}", j.theta.len());
            }
            if j.program.dims > j.bounds.len() {
                bail!(
                    "batch fn {i}: program reads x{} but only {} bounds \
                     given",
                    j.program.dims,
                    j.bounds.len()
                );
            }
            let canon = classify(&j.program, j.theta.len());
            theta_stride = theta_stride.max(canon.theta_width());
            bounds_stride = bounds_stride.max(j.bounds.len());
        }

        let n = jobs.len();
        let mut table = ClassTable::new();
        let mut classes: Vec<BatchClass> = Vec::new();
        let mut class_of = Vec::with_capacity(n);
        let mut theta = vec![0.0f64; n * theta_stride];
        let mut lo = vec![0.0f32; n * bounds_stride];
        let mut hi = vec![1.0f32; n * bounds_stride];
        let mut volumes = Vec::with_capacity(n);
        let mut max_dims = 0usize;
        for (i, j) in jobs.iter().enumerate() {
            let canon = classify(&j.program, j.theta.len());
            let cls = match table.intern(canon.key.clone()) {
                Ok(existing) => existing,
                Err(fresh) => {
                    let program = if canon.verbatim {
                        j.program.clone()
                    } else {
                        canonical_program(&j.program, canon.base)
                    };
                    classes.push(BatchClass::new(program));
                    fresh
                }
            };
            class_of.push(cls);
            extended_theta_into(
                &mut theta[i * theta_stride..(i + 1) * theta_stride],
                &canon,
                &j.program,
                &j.theta,
            );
            for (d, &(l, h)) in j.bounds.iter().enumerate() {
                lo[i * bounds_stride + d] = l as f32;
                hi[i * bounds_stride + d] = h as f32;
            }
            volumes.push(j.volume());
            max_dims = max_dims.max(j.dims());
        }
        Ok(BatchJobs {
            classes,
            class_of,
            theta,
            theta_stride,
            lo,
            hi,
            bounds_stride,
            shared_bounds: false,
            volumes,
            max_dims,
            n,
        })
    }

    /// Parameter scan: `n` instances of one integrand, theta row `i`
    /// produced by `fill(i, row)` into a `job.theta.len()`-wide slice
    /// (pre-zeroed). This is the 10⁵–10⁶ fast path — one class, no
    /// per-function boxes, O(columns) memory total.
    pub fn scan_with(
        job: &IntegralJob,
        n: usize,
        mut fill: impl FnMut(usize, &mut [f64]),
    ) -> Result<BatchJobs> {
        let width = job.theta.len();
        if width > MAX_PARAM {
            bail!("scan: {} params > {MAX_PARAM}", width);
        }
        if job.program.dims > job.bounds.len() {
            bail!(
                "scan: program reads x{} but only {} bounds given",
                job.program.dims,
                job.bounds.len()
            );
        }
        let canon = classify(&job.program, width);
        let program = if canon.verbatim {
            job.program.clone()
        } else {
            canonical_program(&job.program, canon.base)
        };
        let theta_stride = canon.theta_width();
        // the hoisted-constant tail is identical for every row
        let mut tail = vec![0.0f64; theta_stride];
        extended_theta_into(&mut tail, &canon, &job.program, &job.theta);
        let consts = &tail[canon.base..];

        let mut theta = vec![0.0f64; n * theta_stride];
        for i in 0..n {
            let row = &mut theta[i * theta_stride..(i + 1) * theta_stride];
            fill(i, &mut row[..width]);
            row[canon.base..].copy_from_slice(consts);
        }
        let bounds_stride = job.bounds.len();
        let mut lo = vec![0.0f32; bounds_stride];
        let mut hi = vec![1.0f32; bounds_stride];
        for (d, &(l, h)) in job.bounds.iter().enumerate() {
            lo[d] = l as f32;
            hi[d] = h as f32;
        }
        Ok(BatchJobs {
            classes: vec![BatchClass::new(program)],
            class_of: vec![0; n],
            theta,
            theta_stride,
            lo,
            hi,
            bounds_stride,
            shared_bounds: true,
            volumes: vec![volume(&job.bounds)],
            max_dims: job.dims(),
            n,
        })
    }

    /// [`BatchJobs::scan_with`] from explicit theta rows (each must be
    /// `job.theta.len()` long).
    pub fn scan(job: &IntegralJob, thetas: &[Vec<f64>]) -> Result<BatchJobs> {
        let width = job.theta.len();
        for (i, t) in thetas.iter().enumerate() {
            if t.len() != width {
                bail!(
                    "scan point {i}: {} params, expected {width}",
                    t.len()
                );
            }
        }
        Self::scan_with(job, thetas.len(), |i, row| {
            row.copy_from_slice(&thetas[i]);
        })
    }

    /// Functions in the batch.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Distinct program classes after dedup (what the plan/fused
    /// caches and registry ledgers actually see).
    pub fn n_classes(&self) -> usize {
        self.classes.len()
    }

    /// Functions folded into an already-interned class: programs that
    /// never reach the caches because a structural twin already did.
    /// (Saturating: a zero-function scan still carries its one class.)
    pub fn n_folded(&self) -> usize {
        self.n.saturating_sub(self.classes.len())
    }

    /// Max per-function dimensionality (executable selection).
    pub fn dims(&self) -> usize {
        self.max_dims
    }

    /// Resident column bytes (jobs side) — what the streaming bench
    /// compares against peak allocation to assert the watermark bound.
    pub fn approx_bytes(&self) -> usize {
        self.theta.len() * 8
            + (self.lo.len() + self.hi.len()) * 4
            + self.class_of.len() * 4
            + self.volumes.len() * 8
            + self.classes.len() * (MAX_PROG * 12 + 64)
    }

    pub(crate) fn volume(&self, i: usize) -> f64 {
        if self.shared_bounds {
            self.volumes[0]
        } else {
            self.volumes[i]
        }
    }

    /// Build the `vm_multi` inputs for the launch block starting at
    /// function `start` — the column-direct mirror of
    /// `runtime::launch::vm_multi_inputs` over `VmFn` boxes, producing
    /// byte-identical tensors (asserted by `tests/batch_test.rs` via
    /// end-to-end bit-equality with the boxed path).
    pub(crate) fn block_inputs(
        &self,
        exe: &ExeSpec,
        rng: RngCtr,
        start: usize,
        stream_base: u32,
    ) -> Result<Vec<Value>> {
        let (n, d, p) = (exe.n_fns, exe.dims, MAX_PROG);
        if self.bounds_stride > d {
            bail!(
                "batch: {} bound dims > executable dims {d}",
                self.bounds_stride
            );
        }
        debug_assert!(self.theta_stride <= MAX_PARAM);
        let count = self.n.saturating_sub(start).min(n);
        let mut streams = vec![0u32; n];
        let mut plens = vec![0i32; n];
        let mut ops = vec![0i32; n * p];
        let mut iargs = vec![0i32; n * p];
        let mut fargs = vec![0f32; n * p];
        let mut theta = vec![0f32; n * MAX_PARAM];
        let mut lo = vec![0f32; n * d];
        let mut hi = vec![1f32; n * d];
        for k in 0..count {
            let i = start + k;
            let cls = &self.classes[self.class_of[i] as usize];
            streams[k] = stream_base + i as u32;
            plens[k] = cls.plen;
            ops[k * p..(k + 1) * p].copy_from_slice(&cls.ops);
            iargs[k * p..(k + 1) * p].copy_from_slice(&cls.iargs);
            fargs[k * p..(k + 1) * p].copy_from_slice(&cls.fargs);
            let trow = &self.theta[i * self.theta_stride..];
            for j in 0..self.theta_stride {
                theta[k * MAX_PARAM + j] = trow[j] as f32;
            }
            let b = if self.shared_bounds { 0 } else { i };
            let (lrow, hrow) = (
                &self.lo[b * self.bounds_stride..],
                &self.hi[b * self.bounds_stride..],
            );
            for j in 0..self.bounds_stride {
                lo[k * d + j] = lrow[j];
                hi[k * d + j] = hrow[j];
            }
        }
        Ok(vec![
            Value::U32(vec![rng.seed[0], rng.seed[1]]),
            Value::U32(vec![rng.base, rng.trial]),
            Value::U32(streams),
            Value::I32(plens),
            Value::I32(ops),
            Value::I32(iargs),
            Value::F32(fargs),
            Value::F32(theta),
            Value::F32(lo),
            Value::F32(hi),
        ])
    }
}

/// Columnar results: one row per function, same values the boxed path
/// produces (`Estimate` per function plus the merged moment sums),
/// without a million boxed allocations.
pub struct BatchResults {
    values: Vec<f64>,
    std_errs: Vec<f64>,
    n_samples: Vec<u64>,
    rounds: Vec<u32>,
    moments: Vec<MomentSum>,
}

impl BatchResults {
    /// Finalize merged moments into estimate columns (the streaming
    /// reducer hands its accumulators straight in).
    pub(crate) fn from_moments(
        moments: Vec<MomentSum>,
        jobs: &BatchJobs,
    ) -> BatchResults {
        let n = moments.len();
        let mut values = Vec::with_capacity(n);
        let mut std_errs = Vec::with_capacity(n);
        let mut n_samples = Vec::with_capacity(n);
        let mut rounds = Vec::with_capacity(n);
        for (i, m) in moments.iter().enumerate() {
            let (value, std_err) = m.estimate(jobs.volume(i));
            values.push(value);
            std_errs.push(std_err);
            n_samples.push(m.n);
            rounds.push(1);
        }
        BatchResults { values, std_errs, n_samples, rounds, moments }
    }

    /// Columnarize an existing estimate list (no moment column — the
    /// boxed/adaptive paths discard per-function moment sums after
    /// estimation). This is how the serve layer stores finished-job
    /// results for recall: four flat columns instead of a boxed
    /// `Estimate` (or JSON node) per function.
    pub fn from_estimates(ests: &[Estimate]) -> BatchResults {
        let mut values = Vec::with_capacity(ests.len());
        let mut std_errs = Vec::with_capacity(ests.len());
        let mut n_samples = Vec::with_capacity(ests.len());
        let mut rounds = Vec::with_capacity(ests.len());
        for e in ests {
            values.push(e.value);
            std_errs.push(e.std_err);
            n_samples.push(e.n_samples);
            rounds.push(e.rounds);
        }
        BatchResults { values, std_errs, n_samples, rounds, moments: vec![] }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Function `i`'s estimate — identical to what the boxed path's
    /// `Vec<Estimate>` holds at index `i`.
    pub fn get(&self, i: usize) -> Estimate {
        Estimate {
            value: self.values[i],
            std_err: self.std_errs[i],
            n_samples: self.n_samples[i],
            rounds: self.rounds[i],
        }
    }

    /// Function `i`'s merged `(n, Σf, Σf²)` accumulator.
    ///
    /// Panics if these results carry no moment column
    /// ([`from_estimates`](Self::from_estimates) builds none — only
    /// streaming runs keep the accumulators).
    pub fn moment(&self, i: usize) -> MomentSum {
        self.moments[i]
    }

    /// Iterator view for existing `Estimate`-based callers.
    pub fn iter(&self) -> impl Iterator<Item = Estimate> + '_ {
        (0..self.len()).map(|i| self.get(i))
    }

    /// Materialize boxed estimates (compat shim for small batches).
    pub fn to_estimates(&self) -> Vec<Estimate> {
        self.iter().collect()
    }

    /// Resident column bytes (results side).
    pub fn approx_bytes(&self) -> usize {
        self.values.len() * (8 + 8 + 8 + 4 + 24)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan_jobs(n: usize) -> (IntegralJob, Vec<IntegralJob>) {
        let base = IntegralJob::with_params(
            "p0*x1*x2 + 0.5",
            &[(0.0, 1.0), (0.0, 2.0)],
            &[1.0],
        )
        .unwrap();
        let boxed: Vec<IntegralJob> = (0..n)
            .map(|i| base.bind(&[1.0 + i as f64 * 0.25]).unwrap())
            .collect();
        (base, boxed)
    }

    #[test]
    fn scan_and_from_jobs_agree() {
        let (base, boxed) = scan_jobs(17);
        let a = BatchJobs::from_jobs(&boxed).unwrap();
        let b = BatchJobs::scan_with(&base, 17, |i, row| {
            row[0] = 1.0 + i as f64 * 0.25;
        })
        .unwrap();
        assert_eq!(a.len(), 17);
        assert_eq!(a.n_classes(), 1);
        assert_eq!(a.n_folded(), 16);
        assert_eq!(b.n_classes(), 1);
        assert_eq!(a.theta_stride, b.theta_stride);
        assert_eq!(a.theta, b.theta);
        assert_eq!(a.dims(), b.dims());
        assert_eq!(a.volume(3), b.volume(3));
        // identical block inputs from either construction
        let exe = crate::runtime::registry::Registry::emulated()
            .pick(crate::runtime::registry::ExeKind::VmMulti, 64, 2)
            .unwrap()
            .clone();
        let rng = RngCtr { seed: [1, 2], base: 0, trial: 0 };
        let ia = a.block_inputs(&exe, rng, 0, 7).unwrap();
        let ib = b.block_inputs(&exe, rng, 0, 7).unwrap();
        for (x, y) in ia.iter().zip(&ib) {
            match (x, y) {
                (Value::F32(u), Value::F32(v)) => assert_eq!(u, v),
                (Value::I32(u), Value::I32(v)) => assert_eq!(u, v),
                (Value::U32(u), Value::U32(v)) => assert_eq!(u, v),
                _ => panic!("dtype mismatch"),
            }
        }
    }

    #[test]
    fn heterogeneous_batch_keeps_classes_apart() {
        let j1 = IntegralJob::parse("x1*x1", &[(0.0, 1.0)]).unwrap();
        let j2 = IntegralJob::parse("x1*x1 + 2.0", &[(0.0, 1.0)]).unwrap();
        let j3 = IntegralJob::parse("x1*x1 + 9.0", &[(0.0, 1.0)]).unwrap();
        let b = BatchJobs::from_jobs(&[j1, j2, j3]).unwrap();
        assert_eq!(b.n_classes(), 2); // j2/j3 fold, j1 stays its own
        assert_eq!(b.n_folded(), 1);
    }

    #[test]
    fn scan_rejects_bad_theta_width() {
        let (base, _) = scan_jobs(1);
        assert!(BatchJobs::scan(&base, &[vec![1.0, 2.0]]).is_err());
        assert!(BatchJobs::scan(&base, &[vec![1.0]]).is_ok());
    }

    #[test]
    fn results_columns_roundtrip_estimates() {
        let (base, _) = scan_jobs(3);
        let jobs = BatchJobs::scan(&base, &[vec![1.0], vec![2.0], vec![3.0]])
            .unwrap();
        let mut m = MomentSum::new();
        m.push(0.5);
        m.push(1.5);
        let res =
            BatchResults::from_moments(vec![m, MomentSum::new(), m], &jobs);
        assert_eq!(res.len(), 3);
        let (v, e) = m.estimate(jobs.volume(0));
        assert_eq!(res.get(0).value, v);
        assert_eq!(res.get(0).std_err, e);
        assert_eq!(res.get(0).n_samples, 2);
        assert_eq!(res.get(0).rounds, 1);
        assert_eq!(res.moment(2), m);
        assert_eq!(res.to_estimates().len(), 3);
        assert_eq!(res.iter().count(), 3);
    }
}
