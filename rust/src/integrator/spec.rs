//! Job definitions and estimate types shared by all integrators.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::abi::{MAX_DIM, MAX_PARAM};
use crate::expr::Expr;
use crate::sampler::volume;
use crate::util::json::Json;
use crate::vm::program::Program;

/// One integral: an expression, its box domain, and parameter bindings.
#[derive(Debug, Clone)]
pub struct IntegralJob {
    /// Original source text (for logs/reports).
    pub source: String,
    pub expr: Expr,
    pub program: Program,
    /// Per-dimension (lo, hi); length = integration dimensionality.
    pub bounds: Vec<(f64, f64)>,
    /// Parameter slot values (`p0`, `p1`, ... in the expression).
    pub theta: Vec<f64>,
}

impl IntegralJob {
    /// Parse + compile a parameter-free integrand.
    pub fn parse(src: &str, bounds: &[(f64, f64)]) -> Result<Self> {
        Self::with_params(src, bounds, &[])
    }

    /// Parse + compile with parameter bindings.
    pub fn with_params(
        src: &str,
        bounds: &[(f64, f64)],
        theta: &[f64],
    ) -> Result<Self> {
        let expr = Expr::parse(src).map_err(|e| anyhow!("{e}"))?;
        let program = expr.compile().map_err(|e| anyhow!("{e}"))?;
        if bounds.is_empty() || bounds.len() > MAX_DIM {
            bail!("bounds must have 1..={MAX_DIM} dimensions");
        }
        for (d, (lo, hi)) in bounds.iter().enumerate() {
            if !lo.is_finite() || !hi.is_finite() || hi <= lo {
                bail!("bad bounds for x{}: [{lo}, {hi}]", d + 1);
            }
        }
        if expr.dims() > bounds.len() {
            bail!(
                "expression reads x{} but only {} bounds given",
                expr.dims(),
                bounds.len()
            );
        }
        if theta.len() > MAX_PARAM {
            bail!("too many parameters: {} > {MAX_PARAM}", theta.len());
        }
        if expr.n_params() > theta.len() {
            bail!(
                "expression reads p{} but only {} parameters bound",
                expr.n_params() - 1,
                theta.len()
            );
        }
        Ok(IntegralJob {
            source: src.to_string(),
            expr,
            program,
            bounds: bounds.to_vec(),
            theta: theta.to_vec(),
        })
    }

    /// Rebind parameters (used by the functional scan).
    pub fn bind(&self, theta: &[f64]) -> Result<Self> {
        if self.expr.n_params() > theta.len() || theta.len() > MAX_PARAM {
            bail!("bad parameter binding of length {}", theta.len());
        }
        Ok(IntegralJob { theta: theta.to_vec(), ..self.clone() })
    }

    pub fn dims(&self) -> usize {
        self.bounds.len()
    }

    pub fn volume(&self) -> f64 {
        volume(&self.bounds)
    }
}

/// A Monte-Carlo integral estimate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Estimate {
    pub value: f64,
    /// One standard error of `value`.
    pub std_err: f64,
    /// Samples actually spent on this estimate.
    pub n_samples: u64,
    /// Sampling rounds that contributed: 1 for one-shot estimates,
    /// pilot + refinements for adaptive runs (`crate::adaptive`).
    pub rounds: u32,
}

impl Estimate {
    pub fn zero() -> Self {
        Estimate { value: 0.0, std_err: 0.0, n_samples: 0, rounds: 0 }
    }

    /// Is `truth` within z standard errors?
    pub fn consistent_with(&self, truth: f64, z: f64) -> bool {
        crate::stats::within_sigma(self.value, truth, self.std_err, z)
    }

    /// Relative error `std_err / |value|` — the quantity the adaptive
    /// loop's `target_rel_err` stops on. Infinite for a zero estimate
    /// with nonzero error; NaN only for the degenerate `0 ± 0`.
    pub fn rel_err(&self) -> f64 {
        self.std_err / self.value.abs()
    }

    /// Wire codec: `{"value", "std_err", "samples", "rounds"}`. The
    /// one JSON shape an estimate takes everywhere — `zmc run --json`
    /// lines, the server's stream frames and result recall. Floats ride
    /// [`Json::from_f64`], so the round-trip through
    /// [`from_json`](Self::from_json) is bit-exact (non-finite values
    /// included).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("value".to_string(), Json::from_f64(self.value));
        m.insert("std_err".to_string(), Json::from_f64(self.std_err));
        m.insert("samples".to_string(), Json::Num(self.n_samples as f64));
        m.insert("rounds".to_string(), Json::Num(self.rounds as f64));
        Json::Obj(m)
    }

    /// Parse the [`to_json`](Self::to_json) shape. Extra keys (the
    /// stream frames' `fn`/`trial`/`round` annotations) are ignored.
    pub fn from_json(j: &Json) -> Result<Estimate> {
        let value = j
            .get("value")
            .and_then(Json::wire_f64)
            .context("estimate missing 'value'")?;
        let std_err = j
            .get("std_err")
            .and_then(Json::wire_f64)
            .context("estimate missing 'std_err'")?;
        let n_samples = j
            .get("samples")
            .and_then(Json::as_i64)
            .context("estimate missing 'samples'")? as u64;
        let rounds = j
            .get("rounds")
            .and_then(Json::as_i64)
            .context("estimate missing 'rounds'")? as u32;
        Ok(Estimate { value, std_err, n_samples, rounds })
    }
}

/// `I = {value} ± {std_err} ({n} samples, {r} rounds)` — the one
/// report shape the CLI and examples print instead of hand-rolled
/// formats.
impl std::fmt::Display for Estimate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "I = {:.8} ± {:.3e} ({} samples, {} rounds)",
            self.value, self.std_err, self.n_samples, self.rounds
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_ok() {
        let j = IntegralJob::parse("x1*x2", &[(0.0, 1.0), (0.0, 2.0)])
            .unwrap();
        assert_eq!(j.dims(), 2);
        assert_eq!(j.volume(), 2.0);
        assert_eq!(j.theta.len(), 0);
    }

    #[test]
    fn dims_validated() {
        assert!(IntegralJob::parse("x3", &[(0.0, 1.0)]).is_err());
        assert!(IntegralJob::parse("x1", &[]).is_err());
        let nine = vec![(0.0, 1.0); 9];
        assert!(IntegralJob::parse("x1", &nine).is_err());
    }

    #[test]
    fn bounds_validated() {
        assert!(IntegralJob::parse("x1", &[(1.0, 0.0)]).is_err());
        assert!(IntegralJob::parse("x1", &[(0.0, f64::NAN)]).is_err());
        assert!(IntegralJob::parse("x1", &[(2.0, 2.0)]).is_err());
    }

    #[test]
    fn params_validated() {
        assert!(IntegralJob::parse("p0*x1", &[(0.0, 1.0)]).is_err());
        let j = IntegralJob::with_params("p0*x1", &[(0.0, 1.0)], &[3.0])
            .unwrap();
        assert_eq!(j.theta, vec![3.0]);
        let j2 = j.bind(&[5.0]).unwrap();
        assert_eq!(j2.theta, vec![5.0]);
        assert!(j.bind(&[]).is_err());
    }

    #[test]
    fn estimate_consistency() {
        let e = Estimate {
            value: 1.02,
            std_err: 0.01,
            n_samples: 100,
            rounds: 1,
        };
        assert!(e.consistent_with(1.0, 3.0));
        assert!(!e.consistent_with(1.1, 3.0));
    }

    #[test]
    fn estimate_rel_err_and_display() {
        let e = Estimate {
            value: -2.0,
            std_err: 0.01,
            n_samples: 4096,
            rounds: 3,
        };
        assert!((e.rel_err() - 0.005).abs() < 1e-15);
        let text = e.to_string();
        assert_eq!(text, "I = -2.00000000 ± 1.000e-2 (4096 samples, 3 rounds)");

        let zero = Estimate {
            value: 0.0,
            std_err: 0.1,
            n_samples: 1,
            rounds: 1,
        };
        assert!(zero.rel_err().is_infinite());
    }

    #[test]
    fn estimate_json_roundtrip() {
        let e = Estimate {
            value: -0.0,
            std_err: 1.0 / 3.0,
            n_samples: 1 << 40,
            rounds: 7,
        };
        let j = Json::parse(&e.to_json().to_string()).unwrap();
        let back = Estimate::from_json(&j).unwrap();
        assert_eq!(back.value.to_bits(), e.value.to_bits());
        assert_eq!(back.std_err.to_bits(), e.std_err.to_bits());
        assert_eq!(back.n_samples, e.n_samples);
        assert_eq!(back.rounds, e.rounds);
        // extra keys (stream-frame annotations) are ignored
        let annotated = Json::parse(
            r#"{"value":1,"std_err":0.5,"samples":8,"rounds":1,"fn":3}"#,
        )
        .unwrap();
        assert!(Estimate::from_json(&annotated).is_ok());
        // missing keys are an error
        assert!(Estimate::from_json(&Json::parse("{}").unwrap()).is_err());
    }
}
