//! `ZMCintegral_functional` — one integrand swept over a parameter grid
//! (the v5 feature: "scanning of large parameter space").
//!
//! A scan point is the same compiled bytecode with a different `theta`
//! binding, so the sweep packs into `vm_multi` launches exactly like a
//! multifunction batch — each grid point gets its own Philox stream and
//! its own estimate. Compilation happens once, not per point.

use anyhow::Result;

use crate::cluster::LaunchExec;
use crate::integrator::multifunctions::{self, MultiConfig, MultiHandle};
use crate::integrator::spec::{Estimate, IntegralJob};

/// Cartesian grid over parameter axes: `axes[j]` lists the values taken
/// by `p<j>`. Iteration order: last axis fastest (row-major).
pub fn grid(axes: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let mut points: Vec<Vec<f64>> = vec![vec![]];
    for axis in axes {
        let mut next = Vec::with_capacity(points.len() * axis.len());
        for p in &points {
            for &v in axis {
                let mut q = p.clone();
                q.push(v);
                next.push(q);
            }
        }
        points = next;
    }
    points
}

/// `n` evenly spaced values over [lo, hi] inclusive.
pub fn linspace(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    if n == 1 {
        return vec![lo];
    }
    (0..n)
        .map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64)
        .collect()
}

/// Submit the scan (every parameter point as its own packed integrand)
/// without waiting — points ride the warm engine (or cluster)
/// concurrently with any other in-flight work.
pub fn submit_scan<X: LaunchExec + ?Sized>(
    exec: &X,
    job: &IntegralJob,
    thetas: &[Vec<f64>],
    cfg: &MultiConfig,
) -> Result<MultiHandle> {
    let jobs: Vec<IntegralJob> = thetas
        .iter()
        .map(|t| job.bind(t))
        .collect::<Result<_>>()?;
    multifunctions::submit(exec, &jobs, cfg)
}

/// Integrate `job`'s expression at every parameter point. Returns one
/// estimate per point, in `thetas` order.
pub fn scan<X: LaunchExec + ?Sized>(
    exec: &X,
    job: &IntegralJob,
    thetas: &[Vec<f64>],
    cfg: &MultiConfig,
) -> Result<Vec<Estimate>> {
    submit_scan(exec, job, thetas, cfg)?.wait()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_row_major() {
        let g = grid(&[vec![1.0, 2.0], vec![10.0, 20.0, 30.0]]);
        assert_eq!(g.len(), 6);
        assert_eq!(g[0], vec![1.0, 10.0]);
        assert_eq!(g[1], vec![1.0, 20.0]);
        assert_eq!(g[3], vec![2.0, 10.0]);
    }

    #[test]
    fn grid_empty_axes() {
        assert_eq!(grid(&[]), vec![Vec::<f64>::new()]);
    }

    #[test]
    fn linspace_endpoints() {
        let l = linspace(0.0, 1.0, 5);
        assert_eq!(l, vec![0.0, 0.25, 0.5, 0.75, 1.0]);
        assert_eq!(linspace(2.0, 9.0, 1), vec![2.0]);
    }
}
