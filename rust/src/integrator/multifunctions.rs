//! `ZMCintegral_multifunctions` — the v5.1 headline feature.
//!
//! Integrates an arbitrary set of integrands (different expressions,
//! dimensions, domains, parameters) by packing them into `vm_multi`
//! artifact launches: F functions per launch, S samples per function per
//! launch, chunked over the sample budget with advancing Philox counter
//! bases, scheduled over the device pool with retries. One launch
//! evaluates F·S integrand samples — the batching that gives the paper's
//! "10³ integrations in under 10 minutes" throughput, reproduced as
//! experiment C1.

use anyhow::Result;

use crate::coordinator::fault::FaultPlan;
use crate::coordinator::progress::Metrics;
use crate::coordinator::scheduler::Scheduler;
use crate::integrator::spec::{Estimate, IntegralJob};
use crate::runtime::device::{DevicePool, DeviceRuntime};
use crate::runtime::launch::{vm_multi_inputs, RngCtr, Value, VmFn};
use crate::runtime::registry::ExeKind;
use crate::stats::MomentSum;

/// Options for a multifunction run.
#[derive(Debug, Clone)]
pub struct MultiConfig {
    /// Target samples per function (rounded up to whole launches).
    pub samples_per_fn: usize,
    pub seed: u64,
    /// Independent-repeat id (Fig 1 uses trials 0..10).
    pub trial: u32,
    /// First Philox stream id; function i uses `stream_base + i`.
    pub stream_base: u32,
    pub max_retries: u32,
    /// Force a specific executable (default: best fit by samples).
    pub exe: Option<String>,
}

impl Default for MultiConfig {
    fn default() -> Self {
        MultiConfig {
            samples_per_fn: 1 << 20,
            seed: 2021,
            trial: 0,
            stream_base: 0,
            max_retries: 3,
            exe: None,
        }
    }
}

/// One scheduled launch: functions `block` covering chunk `chunk`.
struct ChunkTask {
    exe: String,
    block: usize,
    inputs: Vec<Value>,
}

/// Integrate a heterogeneous job set; returns one estimate per job, in
/// order. See [`MultiConfig`] for sampling/addressing options.
pub fn integrate(
    pool: &DevicePool,
    jobs: &[IntegralJob],
    cfg: &MultiConfig,
) -> Result<Vec<Estimate>> {
    integrate_with_fault(pool, jobs, cfg, &FaultPlan::none(), &Metrics::new())
}

/// Full-control variant used by tests and benches.
pub fn integrate_with_fault(
    pool: &DevicePool,
    jobs: &[IntegralJob],
    cfg: &MultiConfig,
    fault: &FaultPlan,
    metrics: &Metrics,
) -> Result<Vec<Estimate>> {
    if jobs.is_empty() {
        return Ok(vec![]);
    }
    let reg = &pool.registry;
    let exe = match &cfg.exe {
        Some(name) => reg.get(name)?,
        None => {
            // dims-aware: a batch of dims<=4 jobs rides the d4 artifact,
            // halving the in-kernel RNG cost (§Perf L1).
            let want_dims =
                jobs.iter().map(|j| j.dims()).max().unwrap_or(1);
            reg.pick(ExeKind::VmMulti, cfg.samples_per_fn, want_dims)?
        }
    };
    let n_chunks = cfg.samples_per_fn.div_ceil(exe.samples).max(1);

    // Pack jobs into function blocks of the artifact's width.
    let fns: Vec<VmFn> = jobs
        .iter()
        .enumerate()
        .map(|(i, j)| VmFn {
            program: j.program.clone(),
            theta: j.theta.clone(),
            bounds: j.bounds.clone(),
            stream: cfg.stream_base + i as u32,
        })
        .collect();

    let mut tasks = Vec::new();
    for (b, block) in fns.chunks(exe.n_fns).enumerate() {
        for c in 0..n_chunks {
            let rng = RngCtr {
                seed: split_seed(cfg.seed),
                base: (c * exe.samples) as u32,
                trial: cfg.trial,
            };
            tasks.push(ChunkTask {
                exe: exe.name.clone(),
                block: b,
                inputs: vm_multi_inputs(exe, rng, block)?,
            });
        }
    }

    let sched = Scheduler {
        n_workers: pool.n_devices,
        max_retries: cfg.max_retries,
    };
    let registry = std::sync::Arc::clone(reg);
    let outs = sched.run(
        tasks,
        fault,
        metrics,
        move |_w| DeviceRuntime::new(std::sync::Arc::clone(&registry)),
        |dev: &DeviceRuntime, t: &ChunkTask| {
            dev.execute(&t.exe, &t.inputs).map(|o| (t.block, o.data))
        },
    )?;

    // Merge (Σf, Σf²) per function across chunks.
    let mut moments = vec![MomentSum::new(); jobs.len()];
    for (block, data) in outs {
        for f in 0..exe.n_fns {
            let j = block * exe.n_fns + f;
            if j >= jobs.len() {
                break;
            }
            moments[j].merge(&MomentSum::from_device(
                exe.samples as u64,
                data[f * 2],
                data[f * 2 + 1],
            ));
        }
    }
    Ok(moments
        .iter()
        .zip(jobs)
        .map(|(m, j)| {
            let (value, std_err) = m.estimate(j.volume());
            Estimate { value, std_err, n_samples: m.n }
        })
        .collect())
}

/// Convenience: single integrand.
pub fn integrate_one(
    pool: &DevicePool,
    job: &IntegralJob,
    samples: usize,
    seed: u64,
) -> Result<Estimate> {
    let cfg = MultiConfig {
        samples_per_fn: samples,
        seed,
        ..Default::default()
    };
    Ok(integrate(pool, std::slice::from_ref(job), &cfg)?[0])
}

/// Independent repeats (the paper's "10 independent evaluations"):
/// returns `trials` estimate vectors, each from a disjoint trial stream.
pub fn integrate_trials(
    pool: &DevicePool,
    jobs: &[IntegralJob],
    cfg: &MultiConfig,
    trials: u32,
) -> Result<Vec<Vec<Estimate>>> {
    (0..trials)
        .map(|t| {
            let c = MultiConfig { trial: cfg.trial + t, ..cfg.clone() };
            integrate(pool, jobs, &c)
        })
        .collect()
}

pub(crate) fn split_seed(seed: u64) -> [u32; 2] {
    [(seed & 0xFFFF_FFFF) as u32, (seed >> 32) as u32]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_math() {
        // pure logic test (device tests live in tests/integrator_integration.rs)
        assert_eq!(10usize.div_ceil(4), 3);
        assert_eq!(split_seed(0x1122334455667788),
                   [0x55667788, 0x11223344]);
    }

    #[test]
    fn empty_jobs_short_circuit() {
        // must not touch the registry at all
        let cfg = MultiConfig::default();
        assert_eq!(cfg.samples_per_fn, 1 << 20);
        // (constructing a DevicePool needs artifacts; covered in
        // integration tests)
    }
}
