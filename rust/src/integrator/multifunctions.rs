//! `ZMCintegral_multifunctions` — the v5.1 headline feature.
//!
//! Integrates an arbitrary set of integrands (different expressions,
//! dimensions, domains, parameters) by packing them into `vm_multi`
//! artifact launches: F functions per launch, S samples per function per
//! launch, chunked over the sample budget with advancing Philox counter
//! bases, submitted to a persistent [`crate::engine::DeviceEngine`] or
//! sharded over a [`crate::cluster::DeviceCluster`]. One launch
//! evaluates F·S integrand samples — the batching that gives the paper's
//! "10³ integrations in under 10 minutes" throughput, reproduced as
//! experiment C1.
//!
//! Two entry styles:
//! * [`integrate`] — synchronous: submit + wait;
//! * [`submit`] — asynchronous: returns a [`MultiHandle`] immediately,
//!   so independent batches (different users, different trials) ride the
//!   same warm engine concurrently and are awaited per-handle.
//!
//! Both are generic over [`LaunchExec`]: pass a
//! [`crate::engine::DeviceEngine`] for the single-device path or a
//! [`crate::cluster::DeviceCluster`] to shard the packed launches
//! across engines — results are bit-identical either way (tasks carry
//! disjoint Philox counter ranges and the reduce preserves task order).

use anyhow::Result;

use crate::adaptive::Allocation;
use crate::cluster::{fold_tagged, ExecHandle, LaunchExec};
use crate::engine::LaunchTask;
use crate::integrator::spec::{Estimate, IntegralJob};
use crate::runtime::launch::{vm_multi_inputs, RngCtr, VmFn};
use crate::runtime::registry::{ExeKind, ExeSpec, Registry};
use crate::stats::MomentSum;

/// Options for a multifunction run.
#[derive(Debug, Clone)]
pub struct MultiConfig {
    /// Target samples per function (rounded up to whole launches).
    /// In adaptive mode (an error target is set) this is the per-
    /// function *budget cap*: the pool of `samples_per_fn × n_jobs`
    /// samples flows to whichever functions still need it.
    pub samples_per_fn: usize,
    pub seed: u64,
    /// Independent-repeat id (Fig 1 uses trials 0..10).
    pub trial: u32,
    /// First Philox stream id; function i uses `stream_base + i`
    /// (adaptive runs draw consecutive streams from here, one per
    /// launch slot).
    pub stream_base: u32,
    /// Per-job retry budget on the engine.
    pub max_retries: u32,
    /// Force a specific executable (default: best fit by dims+samples).
    pub exe: Option<String>,
    /// Stop refining a function once `std_err <= target_rel_err·|I|`.
    /// Setting this (or `target_abs_err`) switches [`integrate`] to
    /// the adaptive pilot-then-refine loop ([`crate::adaptive`]).
    pub target_rel_err: Option<f64>,
    /// Stop refining a function once `std_err <= target_abs_err`.
    pub target_abs_err: Option<f64>,
    /// Maximum refinement rounds after the pilot (adaptive mode).
    pub max_rounds: usize,
    /// Samples per function in the adaptive pilot pass (clamped to
    /// `samples_per_fn`, rounded up to at least one launch).
    pub pilot_samples: usize,
    /// How refinement rounds distribute the budget (adaptive mode).
    pub allocation: Allocation,
    /// Requested execution topology: how many engines the caller should
    /// put behind the batch (1 = single engine). **Advisory**: the
    /// integrators never build engines — the topology of a call is
    /// whatever `exec` you pass in, and this field does not override
    /// it. Owners of the execution surface (the CLI's `--num-engines`,
    /// job files, benches) read it to size the
    /// [`crate::cluster::DeviceCluster`] they submit through. Results
    /// are bit-identical for any value.
    pub num_engines: usize,
}

impl Default for MultiConfig {
    fn default() -> Self {
        MultiConfig {
            samples_per_fn: 1 << 20,
            seed: 2021,
            trial: 0,
            stream_base: 0,
            max_retries: 3,
            exe: None,
            target_rel_err: None,
            target_abs_err: None,
            max_rounds: 12,
            pilot_samples: 1 << 12,
            allocation: Allocation::Neyman,
            num_engines: 1,
        }
    }
}

impl MultiConfig {
    /// True when an error target is configured, i.e. [`integrate`]
    /// runs the adaptive pilot-then-refine loop instead of one-shot
    /// uniform sampling.
    pub fn is_adaptive(&self) -> bool {
        self.target_rel_err.is_some() || self.target_abs_err.is_some()
    }
}

/// In-flight multifunction batch: wait to get one [`Estimate`] per job,
/// in submission order.
pub struct MultiHandle {
    inner: Option<ExecHandle>,
    n_fns: usize,
    samples: usize,
    volumes: Vec<f64>,
}

impl MultiHandle {
    /// Block until every launch landed; results are folded into the
    /// per-function `(Σf, Σf²)` accumulators **as they complete**
    /// (engine and cluster handles deliver them in task order, so the
    /// streamed fold is bit-identical to collecting everything and
    /// reducing — see [`fold_tagged`]) instead of buffering O(launches)
    /// outputs first.
    pub fn wait(self) -> Result<Vec<Estimate>> {
        let mut moments = vec![MomentSum::new(); self.volumes.len()];
        if let Some(handle) = self.inner {
            let (n_fns, samples) = (self.n_fns, self.samples as u64);
            handle.wait_each(&mut |out| {
                fold_tagged(&mut moments, &out, n_fns, samples)
            })?;
        }
        Ok(moments
            .iter()
            .zip(&self.volumes)
            .map(|(m, &vol)| {
                let (value, std_err) = m.estimate(vol);
                Estimate { value, std_err, n_samples: m.n, rounds: 1 }
            })
            .collect())
    }

    /// Cancel outstanding launches and discard any results. Dropping
    /// an un-awaited handle does the same implicitly: queued launches
    /// are purged from the engine so they never occupy a worker slot.
    pub fn cancel(self) {
        if let Some(h) = self.inner {
            h.cancel();
        }
    }

    /// Non-blocking completion probe.
    pub fn is_done(&self) -> bool {
        match &self.inner {
            Some(h) => h.is_done(),
            None => true,
        }
    }

    /// Launches this batch was packed into.
    pub fn n_launches(&self) -> usize {
        match &self.inner {
            Some(h) => h.n_tasks(),
            None => 0,
        }
    }
}

/// Pack a job set into `vm_multi` launch tasks: F functions per launch
/// row block, the sample budget chunked with advancing Philox counter
/// bases. Every task's `(stream, base, trial)` addressing is baked into
/// its inputs here, which is what makes task placement free — any
/// engine (or cluster shard) may run any task and the sampled counter
/// ranges stay disjoint. Exposed so benches/tests can drive the launch
/// layer directly; returns the tasks plus the executable they target.
pub fn build_tasks<'r>(
    reg: &'r Registry,
    jobs: &[IntegralJob],
    cfg: &MultiConfig,
) -> Result<(Vec<LaunchTask>, &'r ExeSpec)> {
    let exe = match &cfg.exe {
        Some(name) => reg.get(name)?,
        None => {
            // dims-aware: a batch of dims<=4 jobs rides the d4 artifact,
            // halving the in-kernel RNG cost (§Perf L1).
            let want_dims = jobs.iter().map(|j| j.dims()).max().unwrap_or(1);
            reg.pick(ExeKind::VmMulti, cfg.samples_per_fn, want_dims)?
        }
    };
    let n_chunks = cfg.samples_per_fn.div_ceil(exe.samples).max(1);

    // Pack jobs into function blocks of the artifact's width.
    let fns: Vec<VmFn> = jobs
        .iter()
        .enumerate()
        .map(|(i, j)| VmFn {
            program: j.program.clone(),
            theta: j.theta.clone(),
            bounds: j.bounds.clone(),
            stream: cfg.stream_base + i as u32,
        })
        .collect();

    let mut tasks = Vec::new();
    for (b, block) in fns.chunks(exe.n_fns).enumerate() {
        for c in 0..n_chunks {
            let rng = RngCtr {
                seed: split_seed(cfg.seed),
                base: (c * exe.samples) as u32,
                trial: cfg.trial,
            };
            tasks.push(LaunchTask {
                exe: exe.name.clone(),
                tag: b as u64,
                inputs: vm_multi_inputs(exe, rng, block)?,
            });
        }
    }
    Ok((tasks, exe))
}

/// Submit a heterogeneous job set to an engine or cluster; returns
/// immediately.
pub fn submit<X: LaunchExec + ?Sized>(
    exec: &X,
    jobs: &[IntegralJob],
    cfg: &MultiConfig,
) -> Result<MultiHandle> {
    if jobs.is_empty() {
        return Ok(MultiHandle {
            inner: None,
            n_fns: 1,
            samples: 0,
            volumes: vec![],
        });
    }
    let (tasks, exe) = build_tasks(exec.registry(), jobs, cfg)?;
    let (n_fns, samples) = (exe.n_fns, exe.samples);
    let inner = exec.submit_launches(tasks, cfg.max_retries)?;
    Ok(MultiHandle {
        inner: Some(inner),
        n_fns,
        samples,
        volumes: jobs.iter().map(|j| j.volume()).collect(),
    })
}

/// Integrate a heterogeneous job set; returns one estimate per job, in
/// order. See [`MultiConfig`] for sampling/addressing options.
///
/// With an error target set (`target_rel_err` / `target_abs_err`) this
/// runs the adaptive pilot-then-refine loop of [`crate::adaptive`]
/// instead of one-shot uniform sampling: the batch budget flows to the
/// functions that still dominate the error, and each function stops as
/// soon as its target is met.
pub fn integrate<X: LaunchExec + ?Sized>(
    exec: &X,
    jobs: &[IntegralJob],
    cfg: &MultiConfig,
) -> Result<Vec<Estimate>> {
    if cfg.is_adaptive() {
        return crate::adaptive::integrate(exec, jobs, cfg);
    }
    submit(exec, jobs, cfg)?.wait()
}

/// Convenience: single integrand.
pub fn integrate_one<X: LaunchExec + ?Sized>(
    exec: &X,
    job: &IntegralJob,
    samples: usize,
    seed: u64,
) -> Result<Estimate> {
    let cfg = MultiConfig {
        samples_per_fn: samples,
        seed,
        ..Default::default()
    };
    Ok(integrate(exec, std::slice::from_ref(job), &cfg)?[0])
}

/// Independent repeats (the paper's "10 independent evaluations"):
/// returns `trials` estimate vectors, each from a disjoint trial stream.
///
/// All trials are submitted up front and then awaited in order, so they
/// interleave across the engine's workers instead of running strictly
/// one after another.
pub fn integrate_trials<X: LaunchExec + ?Sized>(
    exec: &X,
    jobs: &[IntegralJob],
    cfg: &MultiConfig,
    trials: u32,
) -> Result<Vec<Vec<Estimate>>> {
    if cfg.is_adaptive() {
        // adaptive rounds need per-round feedback, so trials run
        // sequentially; each trial's rounds still interleave with any
        // other engine traffic
        return (0..trials)
            .map(|t| {
                let c = MultiConfig { trial: cfg.trial + t, ..cfg.clone() };
                integrate(exec, jobs, &c)
            })
            .collect();
    }
    let handles: Vec<MultiHandle> = (0..trials)
        .map(|t| {
            let c = MultiConfig { trial: cfg.trial + t, ..cfg.clone() };
            submit(exec, jobs, &c)
        })
        .collect::<Result<_>>()?;
    handles.into_iter().map(MultiHandle::wait).collect()
}

pub(crate) fn split_seed(seed: u64) -> [u32; 2] {
    [(seed & 0xFFFF_FFFF) as u32, (seed >> 32) as u32]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_math() {
        // pure logic test (device tests live in tests/integrator_integration.rs)
        assert_eq!(10usize.div_ceil(4), 3);
        assert_eq!(split_seed(0x1122334455667788),
                   [0x55667788, 0x11223344]);
    }

    #[test]
    fn empty_handle_resolves_to_nothing() {
        let h = MultiHandle {
            inner: None,
            n_fns: 1,
            samples: 0,
            volumes: vec![],
        };
        assert!(h.is_done());
        assert_eq!(h.n_launches(), 0);
        assert!(h.wait().unwrap().is_empty());
    }
}
