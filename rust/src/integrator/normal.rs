//! `ZMCintegral_normal` — stratified sampling + heuristic tree search
//! (the algorithm of the original ZMCintegral paper, CPC 248:106962).
//!
//! 1. Partition the domain into `k^D` hypercubes.
//! 2. Evaluate every cube `n_trials` times with independent Philox trial
//!    streams (all cubes of a level batched into `stratified` artifact
//!    launches).
//! 3. Compute each cube's std across trials; flag cubes with
//!    `std > mean(stds) + sigma_mult * std(stds)` as *fluctuating* —
//!    the paper's heuristic for "this region needs a closer look".
//! 4. Recursively subdivide flagged cubes (2 per dimension, capped) up
//!    to `max_depth`; unflagged cubes keep their trial statistics.
//! 5. Total = Σ cube means; error = √(Σ cube var/n_trials) — stratified
//!    variance combination.

use anyhow::{bail, Result};

use crate::cluster::LaunchExec;
use crate::engine::LaunchTask;
use crate::integrator::multifunctions::split_seed;
use crate::integrator::spec::{Estimate, IntegralJob};
use crate::runtime::launch::{stratified_inputs, RngCtr};
use crate::runtime::registry::ExeKind;
use crate::stats::Welford;

/// Tree-search configuration (defaults follow the ZMCintegral package).
#[derive(Debug, Clone)]
pub struct NormalConfig {
    /// Initial divisions per dimension (k^D starting cubes).
    pub initial_divisions: usize,
    /// Independent evaluations per cube per level.
    pub n_trials: u32,
    /// Flag threshold: mean(std) + sigma_mult·std(std).
    pub sigma_mult: f64,
    /// Maximum refinement depth (0 = no refinement).
    pub max_depth: usize,
    /// Subdivide at most this many dimensions per split (2^d children).
    pub max_split_dims: usize,
    pub seed: u64,
    pub max_retries: u32,
    /// Force a specific stratified executable.
    pub exe: Option<String>,
}

impl Default for NormalConfig {
    fn default() -> Self {
        NormalConfig {
            initial_divisions: 4,
            n_trials: 5,
            sigma_mult: 1.0,
            max_depth: 2,
            max_split_dims: 4,
            seed: 2021,
            max_retries: 3,
            exe: None,
        }
    }
}

/// Result including tree diagnostics.
#[derive(Debug, Clone)]
pub struct NormalResult {
    pub estimate: Estimate,
    /// Cubes evaluated at each depth.
    pub cubes_per_level: Vec<usize>,
    /// Cubes flagged (and refined) at each depth.
    pub flagged_per_level: Vec<usize>,
    /// Total device launches issued.
    pub launches: usize,
}

#[derive(Debug, Clone)]
struct Cube {
    lo: Vec<f64>,
    hi: Vec<f64>,
}

impl Cube {
    fn volume(&self) -> f64 {
        self.lo
            .iter()
            .zip(&self.hi)
            .map(|(l, h)| h - l)
            .product()
    }

    /// Split into 2^d children along the `d` widest dimensions.
    fn split(&self, max_dims: usize) -> Vec<Cube> {
        let dims = self.lo.len();
        // order dimensions by width, split the widest `max_dims`
        let mut order: Vec<usize> = (0..dims).collect();
        order.sort_by(|&a, &b| {
            (self.hi[b] - self.lo[b]).total_cmp(&(self.hi[a] - self.lo[a]))
        });
        let split_dims = &order[..max_dims.min(dims)];
        let mut out = vec![self.clone()];
        for &d in split_dims {
            let mid = 0.5 * (self.lo[d] + self.hi[d]);
            let mut next = Vec::with_capacity(out.len() * 2);
            for c in out {
                let mut a = c.clone();
                a.hi[d] = mid;
                let mut b = c;
                b.lo[d] = mid;
                next.push(a);
                next.push(b);
            }
            out = next;
        }
        out
    }
}

/// Integrate with stratified sampling + tree search.
///
/// Each refinement level is one engine job: the level's cube batch is
/// submitted as a set of launches and awaited before flagging. Under
/// the persistent engine the stratified executable compiles once per
/// worker on the first level and every later level (and every later
/// `integrate` call) reuses it.
///
/// Generic over [`LaunchExec`]: pass a
/// [`crate::engine::DeviceEngine`] for the single-device path or a
/// [`crate::cluster::DeviceCluster`] to shard each level's cube batch
/// across engines. Every launch carries its own Philox
/// `(stream, trial)` addressing and results come back in task order,
/// so the tree (and the estimate) is bit-identical at any engine
/// count.
pub fn integrate<X: LaunchExec + ?Sized>(
    exec: &X,
    job: &IntegralJob,
    cfg: &NormalConfig,
) -> Result<NormalResult> {
    if cfg.n_trials < 2 {
        bail!("n_trials must be >= 2 for the variance heuristic");
    }
    let reg = exec.registry();
    let exe = match &cfg.exe {
        Some(name) => reg.get(name)?,
        None => reg.pick(ExeKind::Stratified, 0, job.dims())?,
    };
    let dims = job.dims();
    let k = cfg.initial_divisions.max(1);
    if (k as f64).powi(dims as i32) > 65536.0 {
        bail!(
            "initial grid {k}^{dims} too large; lower initial_divisions"
        );
    }

    // Build the initial uniform grid.
    let mut cubes = vec![Cube {
        lo: job.bounds.iter().map(|b| b.0).collect(),
        hi: job.bounds.iter().map(|b| b.1).collect(),
    }];
    for d in 0..dims {
        let mut next = Vec::with_capacity(cubes.len() * k);
        for c in cubes {
            let w = (c.hi[d] - c.lo[d]) / k as f64;
            for i in 0..k {
                let mut child = c.clone();
                child.lo[d] = c.lo[d] + w * i as f64;
                child.hi[d] = c.lo[d] + w * (i + 1) as f64;
                next.push(child);
            }
        }
        cubes = next;
    }

    let mut total = Welford::new(); // not used for value; kept for API
    let _ = &mut total;
    let mut value = 0.0f64;
    let mut variance = 0.0f64;
    let mut cubes_per_level = Vec::new();
    let mut flagged_per_level = Vec::new();
    let mut launches = 0usize;
    let mut next_stream: u32 = 0;

    for depth in 0..=cfg.max_depth {
        if cubes.is_empty() {
            break;
        }
        cubes_per_level.push(cubes.len());
        // per-cube per-trial integral estimates
        let stats = eval_level(
            exec, exe, job, &cubes, cfg, &mut next_stream, &mut launches,
        )?;

        // Welford over trials per cube → (mean, std)
        let cube_stats: Vec<Welford> = stats;
        if depth == cfg.max_depth {
            // accept everything at the depth limit
            for (c, w) in cubes.iter().zip(&cube_stats) {
                let _ = c;
                value += w.mean();
                variance += w.sem().powi(2);
            }
            flagged_per_level.push(0);
            break;
        }

        // the flagging heuristic
        let stds: Vec<f64> = cube_stats.iter().map(|w| w.std()).collect();
        let mean_std = stds.iter().sum::<f64>() / stds.len() as f64;
        let std_std = (stds
            .iter()
            .map(|s| (s - mean_std).powi(2))
            .sum::<f64>()
            / stds.len() as f64)
            .sqrt();
        let threshold = mean_std + cfg.sigma_mult * std_std;

        let mut next_cubes = Vec::new();
        let mut flagged = 0usize;
        for (c, w) in cubes.iter().zip(&cube_stats) {
            if w.std() > threshold && w.std() > 0.0 {
                flagged += 1;
                next_cubes.extend(c.split(cfg.max_split_dims));
            } else {
                value += w.mean();
                variance += w.sem().powi(2);
            }
        }
        flagged_per_level.push(flagged);
        cubes = next_cubes;
    }

    let samples_per_cube = exe.samples as u64;
    let n_samples: u64 = cubes_per_level
        .iter()
        .map(|&c| c as u64 * samples_per_cube * cfg.n_trials as u64)
        .sum();
    Ok(NormalResult {
        estimate: Estimate {
            value,
            std_err: variance.sqrt(),
            n_samples,
            rounds: cubes_per_level.len() as u32,
        },
        cubes_per_level,
        flagged_per_level,
        launches,
    })
}

/// Evaluate all cubes × all trials at one level; returns per-cube
/// Welford stats of the per-trial integral estimates.
fn eval_level<X: LaunchExec + ?Sized>(
    exec: &X,
    exe: &crate::runtime::registry::ExeSpec,
    job: &IntegralJob,
    cubes: &[Cube],
    cfg: &NormalConfig,
    next_stream: &mut u32,
    launches: &mut usize,
) -> Result<Vec<Welford>> {
    // assign one stream per cube (refined cubes get fresh streams)
    let streams: Vec<u32> =
        (0..cubes.len()).map(|i| *next_stream + i as u32).collect();
    *next_stream += cubes.len() as u32;

    let mut tasks = Vec::new();
    for (g, group) in cubes.chunks(exe.n_cubes).enumerate() {
        let cube_vecs: Vec<(Vec<f64>, Vec<f64>)> = group
            .iter()
            .map(|c| (c.lo.clone(), c.hi.clone()))
            .collect();
        let group_streams =
            &streams[g * exe.n_cubes..g * exe.n_cubes + group.len()];
        for t in 0..cfg.n_trials {
            let rng = RngCtr {
                seed: split_seed(cfg.seed),
                base: 0,
                trial: t,
            };
            tasks.push(LaunchTask {
                exe: exe.name.clone(),
                tag: g as u64,
                inputs: stratified_inputs(
                    exe,
                    rng,
                    &job.program,
                    &job.theta,
                    &cube_vecs,
                    group_streams,
                )?,
            });
        }
    }
    *launches += tasks.len();

    let outs = exec.submit_launches(tasks, cfg.max_retries)?.wait()?;

    let mut stats = vec![Welford::new(); cubes.len()];
    for out in outs {
        let g = out.tag as usize;
        for ci in 0..exe.n_cubes {
            let idx = g * exe.n_cubes + ci;
            if idx >= cubes.len() {
                break;
            }
            let mean = out.data[ci * 2] as f64 / exe.samples as f64;
            let est = cubes[idx].volume() * mean;
            stats[idx].push(est);
        }
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cube_split_widest_dims() {
        let c = Cube { lo: vec![0.0, 0.0], hi: vec![4.0, 1.0] };
        let kids = c.split(1);
        assert_eq!(kids.len(), 2);
        // splits x (wider), not y
        assert_eq!(kids[0].hi[0], 2.0);
        assert_eq!(kids[0].hi[1], 1.0);
        let all = c.split(2);
        assert_eq!(all.len(), 4);
        let vol: f64 = all.iter().map(|c| c.volume()).sum();
        assert!((vol - 4.0).abs() < 1e-12);
    }

    #[test]
    fn cube_volume() {
        let c = Cube { lo: vec![0.0, -1.0], hi: vec![0.5, 1.0] };
        assert!((c.volume() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn config_validation() {
        let cfg = NormalConfig { n_trials: 1, ..Default::default() };
        assert_eq!(cfg.n_trials, 1); // integrate() rejects this at run time
    }
}
