//! Specialized fast path for harmonic families (the Fig. 1 workload):
//! `f_n(x) = a_n cos(k_n·x) + b_n sin(k_n·x)` over a shared box.
//!
//! Uses the MXU-shaped `harmonic` artifact: one launch evaluates up to
//! 128 harmonics over a shared sample tile, with the phase computation
//! done as one (S,D)×(D,N) matmul — an order of magnitude fewer
//! launches than routing each harmonic through the generic VM. Batches
//! are submitted to the persistent [`DeviceEngine`]; [`submit`] gives
//! the asynchronous handle form, [`integrate`] the synchronous one.

use anyhow::{bail, Result};

use crate::engine::{DeviceEngine, DeviceHandle, LaunchTask};
use crate::integrator::multifunctions::{split_seed, MultiConfig};
use crate::integrator::spec::Estimate;
use crate::runtime::launch::{harmonic_inputs, RngCtr};
use crate::runtime::registry::ExeKind;
use crate::sampler::volume;
use crate::stats::MomentSum;

/// A batch of harmonic integrands over one shared box.
#[derive(Debug, Clone)]
pub struct HarmonicBatch {
    /// Wave vectors, one row per function (row length = dims).
    pub k: Vec<Vec<f64>>,
    pub a: Vec<f64>,
    pub b: Vec<f64>,
    pub bounds: Vec<(f64, f64)>,
}

impl HarmonicBatch {
    /// The Fig. 1 series: n = 1..=n_max, k_n = ((n+50)/2π)·𝟙₄, a=b=1,
    /// over [0,1]⁴.
    pub fn fig1(n_max: u32) -> Self {
        let kmag =
            |n: u32| (n as f64 + 50.0) / (2.0 * std::f64::consts::PI);
        HarmonicBatch {
            k: (1..=n_max).map(|n| vec![kmag(n); 4]).collect(),
            a: vec![1.0; n_max as usize],
            b: vec![1.0; n_max as usize],
            bounds: vec![(0.0, 1.0); 4],
        }
    }

    pub fn len(&self) -> usize {
        self.k.len()
    }

    pub fn is_empty(&self) -> bool {
        self.k.is_empty()
    }

    /// Closed-form value of function `i` (for validation).
    pub fn truth(&self, i: usize) -> f64 {
        crate::analytic::harmonic_box(
            &self.k[i],
            self.a[i],
            self.b[i],
            &self.bounds,
        )
    }
}

/// In-flight harmonic batch; wait to get one estimate per harmonic.
pub struct HarmonicHandle {
    inner: Option<DeviceHandle>,
    n: usize,
    n_fns: usize,
    samples: usize,
    volume: f64,
}

impl HarmonicHandle {
    pub fn wait(self) -> Result<Vec<Estimate>> {
        // Output layout per launch: f32[2, n_fns] — row 0 Σf, row 1 Σf².
        let mut moments = vec![MomentSum::new(); self.n];
        if let Some(handle) = self.inner {
            for out in handle.wait()? {
                let block = out.tag as usize;
                for f in 0..self.n_fns {
                    let j = block * self.n_fns + f;
                    if j >= self.n {
                        break;
                    }
                    moments[j].merge(&MomentSum::from_device(
                        self.samples as u64,
                        out.data[f],
                        out.data[self.n_fns + f],
                    ));
                }
            }
        }
        Ok(moments
            .iter()
            .map(|m| {
                let (value, std_err) = m.estimate(self.volume);
                Estimate { value, std_err, n_samples: m.n, rounds: 1 }
            })
            .collect())
    }

    pub fn is_done(&self) -> bool {
        match &self.inner {
            Some(h) => h.is_done(),
            None => true,
        }
    }
}

/// Submit the batch; returns immediately with its handle.
pub fn submit(
    engine: &DeviceEngine,
    batch: &HarmonicBatch,
    cfg: &MultiConfig,
) -> Result<HarmonicHandle> {
    let n = batch.len();
    if n == 0 {
        return Ok(HarmonicHandle {
            inner: None,
            n: 0,
            n_fns: 1,
            samples: 0,
            volume: 0.0,
        });
    }
    if batch.a.len() != n || batch.b.len() != n {
        bail!("harmonic batch: a/b length mismatch");
    }
    let reg = engine.registry();
    let exe = match &cfg.exe {
        Some(name) => reg.get(name)?,
        None => reg.pick(
            ExeKind::Harmonic,
            cfg.samples_per_fn,
            batch.bounds.len(),
        )?,
    };
    let n_chunks = cfg.samples_per_fn.div_ceil(exe.samples).max(1);
    let lo: Vec<f64> = batch.bounds.iter().map(|b| b.0).collect();
    let hi: Vec<f64> = batch.bounds.iter().map(|b| b.1).collect();

    let mut tasks = Vec::new();
    let n_blocks = n.div_ceil(exe.n_fns);
    for b in 0..n_blocks {
        let r = b * exe.n_fns..(b * exe.n_fns + exe.n_fns).min(n);
        for c in 0..n_chunks {
            let rng = RngCtr {
                seed: split_seed(cfg.seed),
                base: (c * exe.samples) as u32,
                trial: cfg.trial,
            };
            tasks.push(LaunchTask {
                exe: exe.name.clone(),
                tag: b as u64,
                inputs: harmonic_inputs(
                    exe,
                    rng,
                    cfg.stream_base + b as u32,
                    &batch.k[r.clone()],
                    &batch.a[r.clone()],
                    &batch.b[r.clone()],
                    &lo,
                    &hi,
                )?,
            });
        }
    }

    let inner = engine.submit_with_retries(tasks, cfg.max_retries)?;
    Ok(HarmonicHandle {
        inner: Some(inner),
        n,
        n_fns: exe.n_fns,
        samples: exe.samples,
        volume: volume(&batch.bounds),
    })
}

/// Integrate the batch; one estimate per harmonic, in order.
pub fn integrate(
    engine: &DeviceEngine,
    batch: &HarmonicBatch,
    cfg: &MultiConfig,
) -> Result<Vec<Estimate>> {
    submit(engine, batch, cfg)?.wait()
}

/// Independent repeats, one estimate vector per trial — all submitted
/// up front so trials interleave across the engine's workers.
pub fn integrate_trials(
    engine: &DeviceEngine,
    batch: &HarmonicBatch,
    cfg: &MultiConfig,
    trials: u32,
) -> Result<Vec<Vec<Estimate>>> {
    let handles: Vec<HarmonicHandle> = (0..trials)
        .map(|t| {
            let c = MultiConfig { trial: cfg.trial + t, ..cfg.clone() };
            submit(engine, batch, &c)
        })
        .collect::<Result<_>>()?;
    handles.into_iter().map(HarmonicHandle::wait).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_batch_shape() {
        let b = HarmonicBatch::fig1(100);
        assert_eq!(b.len(), 100);
        assert_eq!(b.k[0].len(), 4);
        assert!((b.k[0][0] - 51.0 / (2.0 * std::f64::consts::PI)).abs()
            < 1e-12);
        assert_eq!(b.bounds.len(), 4);
        // truth matches the analytic helper
        assert_eq!(b.truth(0), crate::analytic::fig1_truth(1));
    }
}
