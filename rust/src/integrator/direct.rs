//! Single-core CPU baseline: identical bytecode, identical Philox
//! streams, no device. This is the comparator for the backend benches
//! (experiment A3) and the ground-truth cross-check in integration
//! tests — with the same `(seed, stream, trial)` it reproduces the
//! device path's estimates up to f32 accumulation order.

use crate::integrator::spec::{Estimate, IntegralJob};
use crate::sampler::StreamKey;
use crate::stats::MomentSum;
use crate::vm::interp::BatchInterp;

/// Evaluation chunk size (samples per VM batch) — mirrors the device
/// tile so per-instruction dispatch amortizes identically.
pub const CHUNK: usize = 2048;

/// Integrate one job with `samples` draws on stream
/// `(seed, stream, trial)`.
pub fn integrate_one(
    job: &IntegralJob,
    samples: usize,
    seed: u64,
    stream: u32,
    trial: u32,
) -> Estimate {
    let dims = job.bounds.len();
    let key = StreamKey::new(seed, stream, trial);
    let theta: Vec<f32> = job.theta.iter().map(|&t| t as f32).collect();
    let mut interp = BatchInterp::new(CHUNK);
    let mut xt: Vec<Vec<f32>> = vec![vec![0f32; CHUNK]; dims];
    let mut out = vec![0f32; CHUNK];
    let mut m = MomentSum::new();
    let mut idx = 0u32;
    let mut left = samples;
    while left > 0 {
        let n = left.min(CHUNK);
        for i in 0..n {
            let u = key.point(idx.wrapping_add(i as u32), dims);
            for d in 0..dims {
                let (lo, hi) = job.bounds[d];
                xt[d][i] = lo as f32 + (hi - lo) as f32 * u[d];
            }
        }
        interp.eval(&job.program, &xt, &theta, n, &mut out);
        // accumulate in f64 (absorbs f32 partial error over big S)
        let mut s = 0f64;
        let mut q = 0f64;
        for &v in &out[..n] {
            s += v as f64;
            q += (v as f64) * (v as f64);
        }
        m.merge(&MomentSum { n: n as u64, sum: s, sumsq: q });
        idx = idx.wrapping_add(n as u32);
        left -= n;
    }
    let (value, std_err) = m.estimate(job.volume());
    Estimate { value, std_err, n_samples: m.n, rounds: 1 }
}

/// Integrate many jobs serially (stream = job index + `stream_base`).
pub fn integrate_many(
    jobs: &[IntegralJob],
    samples: usize,
    seed: u64,
    stream_base: u32,
    trial: u32,
) -> Vec<Estimate> {
    jobs.iter()
        .enumerate()
        .map(|(i, j)| {
            integrate_one(j, samples, seed, stream_base + i as u32, trial)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic;

    #[test]
    fn constant_is_exact() {
        let j = IntegralJob::parse("2", &[(0.0, 3.0)]).unwrap();
        let e = integrate_one(&j, 1000, 1, 0, 0);
        assert!((e.value - 6.0).abs() < 1e-5);
        assert_eq!(e.std_err, 0.0);
        assert_eq!(e.n_samples, 1000);
    }

    #[test]
    fn monomial_within_6_sigma() {
        let j = IntegralJob::parse("x1^2", &[(0.0, 1.0)]).unwrap();
        let e = integrate_one(&j, 1 << 16, 7, 0, 0);
        assert!(e.consistent_with(analytic::monomial(2.0), 6.0),
                "{e:?}");
        assert!(e.std_err < 0.01);
    }

    #[test]
    fn eq2_families() {
        let j2 = IntegralJob::with_params(
            "p0*abs(x1+x2)",
            &[(0.0, 1.0), (0.0, 1.0)],
            &[1.5],
        )
        .unwrap();
        let e2 = integrate_one(&j2, 1 << 16, 11, 0, 0);
        assert!(e2.consistent_with(analytic::eq2_abs2(1.5), 6.0), "{e2:?}");

        let j3 = IntegralJob::with_params(
            "p0*abs(x1+x2-x3)",
            &[(0.0, 1.0), (0.0, 1.0), (0.0, 1.0)],
            &[2.0],
        )
        .unwrap();
        let e3 = integrate_one(&j3, 1 << 16, 11, 1, 0);
        assert!(e3.consistent_with(analytic::eq2_abs3(2.0), 6.0), "{e3:?}");
    }

    #[test]
    fn trials_are_independent() {
        let j = IntegralJob::parse("sin(8*x1)", &[(0.0, 1.0)]).unwrap();
        let a = integrate_one(&j, 4096, 3, 0, 0);
        let b = integrate_one(&j, 4096, 3, 0, 1);
        let c = integrate_one(&j, 4096, 3, 0, 0);
        assert_ne!(a.value, b.value);
        assert_eq!(a.value, c.value); // reproducible
    }

    #[test]
    fn error_scales_inverse_sqrt() {
        let j = IntegralJob::parse("cos(20*x1)", &[(0.0, 1.0)]).unwrap();
        let small = integrate_one(&j, 1 << 10, 5, 0, 0);
        let large = integrate_one(&j, 1 << 14, 5, 0, 0);
        let ratio = small.std_err / large.std_err;
        assert!((ratio - 4.0).abs() < 1.0, "ratio={ratio}");
    }

    #[test]
    fn many_uses_distinct_streams() {
        let jobs = vec![
            IntegralJob::parse("x1", &[(0.0, 1.0)]).unwrap(),
            IntegralJob::parse("x1", &[(0.0, 1.0)]).unwrap(),
        ];
        let es = integrate_many(&jobs, 2048, 9, 0, 0);
        assert_ne!(es[0].value, es[1].value);
    }

    #[test]
    fn partial_chunk_tail() {
        let j = IntegralJob::parse("x1", &[(0.0, 1.0)]).unwrap();
        let e = integrate_one(&j, CHUNK + 7, 1, 0, 0);
        assert_eq!(e.n_samples as usize, CHUNK + 7);
    }
}
