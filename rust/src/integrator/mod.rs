//! The paper's three integration classes, plus the CPU baseline.
//!
//! | paper (python class)         | here |
//! |------------------------------|------|
//! | `ZMCintegral_normal`         | [`normal`] — stratified sampling + heuristic tree search |
//! | `ZMCintegral_functional`     | [`functional`] — one integrand, large parameter grid |
//! | `ZMCintegral_multifunctions` | [`multifunctions`] — heterogeneous integrand batches |
//!
//! All three decompose work into *chunk tasks* (one AOT-artifact launch
//! each, addressed by Philox `(seed, stream, trial, counter_base)`) and
//! submit them to the persistent [`crate::engine::DeviceEngine`]: the
//! synchronous `integrate*` entry points are submit-then-wait sugar over
//! the `submit*` handle forms, so independent batches share one warm
//! engine. [`direct`] is the single-core CPU comparator running
//! identical bytecode on the same sample streams.
//!
//! The [`crate::session::Session`] builders are the preferred front
//! door; the free functions here remain as the compatibility layer
//! they delegate to (bit-identical, per `tests/session_test.rs`).

pub mod direct;
pub mod functional;
pub mod harmonic;
pub mod multifunctions;
pub mod normal;
pub mod spec;
