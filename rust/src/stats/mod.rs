//! Accumulators and statistics for Monte-Carlo estimates.
//!
//! Device launches return raw `(Σf, Σf²)` pairs; the coordinator folds
//! them into [`MomentSum`]s (exact mergeable moments), converts to
//! integral estimates with volume scaling, and combines independent
//! repeats with [`Welford`] (numerically stable running mean/variance).
//! Merge operations are associative and commutative — the scheduler
//! property tests rely on this to prove worker-count invariance.

/// Mergeable first/second moment accumulator for one integrand:
/// `n` samples, `Σf`, `Σf²` (f64 to absorb many f32 partials safely).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MomentSum {
    pub n: u64,
    pub sum: f64,
    pub sumsq: f64,
}

impl MomentSum {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_device(n: u64, sum: f32, sumsq: f32) -> Self {
        MomentSum { n, sum: sum as f64, sumsq: sumsq as f64 }
    }

    pub fn push(&mut self, v: f64) {
        self.n += 1;
        self.sum += v;
        self.sumsq += v * v;
    }

    /// The one merge primitive of the whole pipeline: chunk outputs
    /// fold into function moments, stratum launches fold into strata
    /// ([`crate::adaptive`]), and the cluster reducer
    /// ([`crate::cluster::reduce_tagged`]) folds shard outputs — all
    /// through this pure accumulation. It is commutative bit-exactly
    /// (f64 `+` is); associativity holds only up to rounding, which is
    /// why every caller merges in task order rather than completion
    /// order.
    pub fn merge(&mut self, other: &MomentSum) {
        self.n += other.n;
        self.sum += other.sum;
        self.sumsq += other.sumsq;
    }

    pub fn mean(&self) -> f64 {
        self.sum / self.n as f64
    }

    /// Population variance of f (clamped at 0 against f32 cancellation).
    pub fn variance(&self) -> f64 {
        let m = self.mean();
        (self.sumsq / self.n as f64 - m * m).max(0.0)
    }

    /// MC integral estimate over a domain of volume `vol`:
    /// `I ≈ V·mean(f)`, `σ_I = V·sqrt(var(f)/n)`.
    pub fn estimate(&self, vol: f64) -> (f64, f64) {
        let value = vol * self.mean();
        let std_err = vol * (self.variance() / self.n as f64).sqrt();
        (value, std_err)
    }
}

/// Combine per-stratum `(volume, moments)` accumulators over a domain
/// partition into one integral estimate:
/// `I = Σ V_s·mean_s`, `σ_I = √(Σ V_s²·var_s/n_s)` — the stratified
/// variance combination the adaptive allocator refines round by round.
/// An unsampled stratum (n = 0) contributes nothing to the value but
/// forces an infinite error, so callers can never mistake a partially
/// sampled partition for a converged one.
pub fn stratified_estimate(parts: &[(f64, MomentSum)]) -> (f64, f64) {
    let mut value = 0.0f64;
    let mut var = 0.0f64;
    let mut unsampled = false;
    for (vol, m) in parts {
        if m.n == 0 {
            unsampled = true;
            continue;
        }
        let (v, e) = m.estimate(*vol);
        value += v;
        var += e * e;
    }
    let std_err = if unsampled { f64::INFINITY } else { var.sqrt() };
    (value, std_err)
}

/// Welford running mean/variance over a stream of values (used for the
/// paper's "10 independent evaluations" repeat statistics).
#[derive(Debug, Clone, Copy, Default)]
pub struct Welford {
    pub n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, v: f64) {
        self.n += 1;
        let d = v - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (v - self.mean);
    }

    /// Chan et al. parallel merge — associative up to fp rounding.
    pub fn merge(&mut self, o: &Welford) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *o;
            return;
        }
        let n = (self.n + o.n) as f64;
        let d = o.mean - self.mean;
        self.mean += d * o.n as f64 / n;
        self.m2 += o.m2 + d * d * (self.n as f64 * o.n as f64) / n;
        self.n += o.n;
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample (n-1) variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).max(0.0)
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            f64::INFINITY
        } else {
            (self.variance() / self.n as f64).sqrt()
        }
    }
}

/// Two-sided z test helper: does `value` lie within `z`·σ of `truth`?
pub fn within_sigma(value: f64, truth: f64, sigma: f64, z: f64) -> bool {
    // an exactly-zero sigma (constant integrand) requires exact match
    if sigma == 0.0 {
        return (value - truth).abs() < 1e-12;
    }
    (value - truth).abs() <= z * sigma
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_basic() {
        let mut m = MomentSum::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            m.push(v);
        }
        assert_eq!(m.n, 4);
        assert_eq!(m.mean(), 2.5);
        assert!((m.variance() - 1.25).abs() < 1e-12);
        let (val, err) = m.estimate(2.0);
        assert_eq!(val, 5.0);
        assert!((err - 2.0 * (1.25f64 / 4.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn moment_merge_equals_concat() {
        let vals: Vec<f64> = (0..100).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut whole = MomentSum::new();
        vals.iter().for_each(|&v| whole.push(v));
        let mut a = MomentSum::new();
        let mut b = MomentSum::new();
        vals[..33].iter().for_each(|&v| a.push(v));
        vals[33..].iter().for_each(|&v| b.push(v));
        a.merge(&b);
        assert_eq!(a.n, whole.n);
        assert!((a.sum - whole.sum).abs() < 1e-9);
        assert!((a.sumsq - whole.sumsq).abs() < 1e-9);
    }

    #[test]
    fn moment_merge_commutes_bitwise() {
        // the cluster reducer's correctness leans on this: a ⊕ b and
        // b ⊕ a are the same f64s exactly, so shard placement cannot
        // perturb a merged moment (order of the *sequence* still
        // matters — associativity is only up to rounding — which is
        // why reduction walks outputs in task order)
        let a = MomentSum { n: 3, sum: 0.1 + 0.2, sumsq: 0.30000301 };
        let b = MomentSum { n: 7, sum: -1.7, sumsq: 2.89 };
        let (mut ab, mut ba) = (a, b);
        ab.merge(&b);
        ba.merge(&a);
        assert_eq!(ab, ba);
    }

    #[test]
    fn welford_matches_two_pass() {
        let vals: Vec<f64> =
            (0..1000).map(|i| ((i * 2654435761u64) % 1000) as f64).collect();
        let mut w = Welford::new();
        vals.iter().for_each(|&v| w.push(v));
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        let var = vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>()
            / (vals.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-9);
        assert!((w.variance() - var).abs() / var < 1e-12);
    }

    #[test]
    fn welford_merge_associative() {
        let vals: Vec<f64> = (0..300).map(|i| (i as f64).sqrt()).collect();
        let mut whole = Welford::new();
        vals.iter().for_each(|&v| whole.push(v));
        // ((a+b)+c) vs (a+(b+c))
        let parts: Vec<Welford> = vals
            .chunks(100)
            .map(|c| {
                let mut w = Welford::new();
                c.iter().for_each(|&v| w.push(v));
                w
            })
            .collect();
        let mut left = parts[0];
        left.merge(&parts[1]);
        left.merge(&parts[2]);
        let mut bc = parts[1];
        bc.merge(&parts[2]);
        let mut right = parts[0];
        right.merge(&bc);
        assert!((left.mean() - right.mean()).abs() < 1e-10);
        assert!((left.variance() - right.variance()).abs() < 1e-9);
        assert!((left.mean() - whole.mean()).abs() < 1e-10);
    }

    #[test]
    fn welford_edge_cases() {
        let w = Welford::new();
        assert_eq!(w.variance(), 0.0);
        assert!(w.sem().is_infinite());
        let mut one = Welford::new();
        one.push(5.0);
        assert_eq!(one.mean(), 5.0);
        assert_eq!(one.variance(), 0.0);
        let mut empty_merge = Welford::new();
        empty_merge.merge(&one);
        assert_eq!(empty_merge.mean(), 5.0);
    }

    #[test]
    fn stratified_combination_matches_whole_domain() {
        // f(x) = x over [0,2]: exact I = 2. Two strata [0,1], [1,2]
        // sampled separately must combine to the same estimate family.
        let mk = |vals: &[f64]| {
            let mut m = MomentSum::new();
            vals.iter().for_each(|&v| m.push(v));
            m
        };
        let lo = mk(&[0.25, 0.5, 0.75]); // samples of f on [0,1]
        let hi = mk(&[1.25, 1.5, 1.75]); // samples of f on [1,2]
        let (value, err) = stratified_estimate(&[(1.0, lo), (1.0, hi)]);
        assert!((value - 2.0).abs() < 1e-12, "{value}");
        // per-stratum errors combine in quadrature
        let (_, e_lo) = lo.estimate(1.0);
        let (_, e_hi) = hi.estimate(1.0);
        let want = (e_lo * e_lo + e_hi * e_hi).sqrt();
        assert!((err - want).abs() < 1e-12);
    }

    #[test]
    fn stratified_unsampled_stratum_is_infinite_error() {
        let mut m = MomentSum::new();
        m.push(1.0);
        m.push(2.0);
        let (value, err) =
            stratified_estimate(&[(1.0, m), (1.0, MomentSum::new())]);
        assert!((value - 1.5).abs() < 1e-12);
        assert!(err.is_infinite());
        let (v0, e0) = stratified_estimate(&[]);
        assert_eq!(v0, 0.0);
        assert_eq!(e0, 0.0);
    }

    #[test]
    fn sigma_test() {
        assert!(within_sigma(1.05, 1.0, 0.01, 6.0));
        assert!(!within_sigma(1.2, 1.0, 0.01, 6.0));
        assert!(within_sigma(1.0, 1.0, 0.0, 6.0));
    }
}
