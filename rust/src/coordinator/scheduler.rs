//! Work-queue scheduler: N device workers pulling chunk tasks from a
//! shared FIFO, with bounded retries and deterministic fault injection.
//!
//! Generic over the task and worker-context types so the same machinery
//! runs (a) real PJRT launches in production, (b) pure-CPU mock tasks in
//! the property tests, and (c) virtual-time tasks in the cluster
//! scaling simulation.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::fault::{FaultPlan, Verdict};
use crate::coordinator::progress::Metrics;

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct Scheduler {
    pub n_workers: usize,
    /// Per-task retry budget (attempts = 1 + retries).
    pub max_retries: u32,
}

impl Default for Scheduler {
    fn default() -> Self {
        Scheduler { n_workers: 1, max_retries: 3 }
    }
}

impl Scheduler {
    pub fn new(n_workers: usize) -> Self {
        Scheduler { n_workers, ..Default::default() }
    }

    /// Execute every task exactly once (semantically) and return results
    /// in task order.
    ///
    /// * `make_ctx(worker_idx)` builds the per-thread context (a
    ///   `DeviceRuntime` in production); called on the worker thread.
    /// * `run(ctx, task)` executes one task.
    /// * `fault` injects deterministic failures (including on context
    ///   construction, counted as attempt 0 faults).
    ///
    /// Fails if any task exhausts its retry budget or all workers die.
    pub fn run<T, R, C>(
        &self,
        tasks: Vec<T>,
        fault: &FaultPlan,
        metrics: &Metrics,
        make_ctx: impl Fn(usize) -> Result<C> + Sync,
        run: impl Fn(&C, &T) -> Result<R> + Sync,
    ) -> Result<Vec<R>>
    where
        T: Send + Sync,
        R: Send,
    {
        if self.n_workers == 0 {
            return Err(anyhow!("scheduler needs >= 1 worker"));
        }
        let n_tasks = tasks.len();
        let queue: Mutex<VecDeque<usize>> =
            Mutex::new((0..n_tasks).collect());
        let attempts: Mutex<Vec<u32>> = Mutex::new(vec![0; n_tasks]);
        let results: Mutex<Vec<Option<R>>> =
            Mutex::new((0..n_tasks).map(|_| None).collect());
        let remaining = Mutex::new(n_tasks);
        let done_cv = Condvar::new();
        let fatal: Mutex<Option<String>> = Mutex::new(None);
        let live_workers = Mutex::new(self.n_workers);
        let tasks = Arc::new(tasks);

        std::thread::scope(|scope| {
            for w in 0..self.n_workers {
                let queue = &queue;
                let attempts = &attempts;
                let results = &results;
                let remaining = &remaining;
                let done_cv = &done_cv;
                let fatal = &fatal;
                let live_workers = &live_workers;
                let tasks = Arc::clone(&tasks);
                let make_ctx = &make_ctx;
                let run = &run;
                scope.spawn(move || {
                    let t_start = Instant::now();
                    let mut busy = std::time::Duration::ZERO;
                    let mut my_attempts: u64 = 0;
                    let ctx = match make_ctx(w) {
                        Ok(c) => c,
                        Err(e) => {
                            worker_exit(live_workers, fatal, done_cv, Some(
                                format!("worker {w}: context: {e}"),
                            ));
                            return;
                        }
                    };
                    loop {
                        // stop if the job is finished or failed
                        if fatal.lock().unwrap().is_some()
                            || *remaining.lock().unwrap() == 0
                        {
                            break;
                        }
                        let idx = { queue.lock().unwrap().pop_front() };
                        let Some(idx) = idx else {
                            // queue drained but tasks may still be
                            // in-flight on other workers (and might be
                            // requeued); spin-wait briefly.
                            std::thread::yield_now();
                            continue;
                        };
                        match fault.judge(w, my_attempts) {
                            Verdict::WorkerDead => {
                                // put the task back and die
                                queue.lock().unwrap().push_front(idx);
                                break;
                            }
                            Verdict::FailAttempt => {
                                my_attempts += 1;
                                metrics.failure();
                                requeue_or_abort(
                                    idx,
                                    "injected fault",
                                    self.max_retries,
                                    queue,
                                    attempts,
                                    fatal,
                                    metrics,
                                );
                                continue;
                            }
                            Verdict::Proceed => {}
                        }
                        my_attempts += 1;
                        let t0 = Instant::now();
                        match run(&ctx, &tasks[idx]) {
                            Ok(r) => {
                                busy += t0.elapsed();
                                results.lock().unwrap()[idx] = Some(r);
                                metrics.task_done();
                                let mut rem = remaining.lock().unwrap();
                                *rem -= 1;
                                if *rem == 0 {
                                    done_cv.notify_all();
                                }
                            }
                            Err(e) => {
                                busy += t0.elapsed();
                                metrics.failure();
                                requeue_or_abort(
                                    idx,
                                    &e.to_string(),
                                    self.max_retries,
                                    queue,
                                    attempts,
                                    fatal,
                                    metrics,
                                );
                            }
                        }
                    }
                    metrics.record_worker(busy, t_start.elapsed());
                    worker_exit(live_workers, fatal, done_cv, None);
                });
            }
        });

        if let Some(msg) = fatal.lock().unwrap().take() {
            return Err(anyhow!(msg));
        }
        if *remaining.lock().unwrap() != 0 {
            return Err(anyhow!(
                "all workers exited with {} tasks unfinished",
                remaining.lock().unwrap()
            ));
        }
        let results = results.into_inner().unwrap();
        Ok(results.into_iter().map(|r| r.unwrap()).collect())
    }
}

fn requeue_or_abort(
    idx: usize,
    err: &str,
    max_retries: u32,
    queue: &Mutex<VecDeque<usize>>,
    attempts: &Mutex<Vec<u32>>,
    fatal: &Mutex<Option<String>>,
    metrics: &Metrics,
) {
    let mut att = attempts.lock().unwrap();
    att[idx] += 1;
    if att[idx] > max_retries {
        *fatal.lock().unwrap() = Some(format!(
            "task {idx} failed after {} attempts: {err}",
            att[idx]
        ));
    } else {
        metrics.retry();
        queue.lock().unwrap().push_back(idx);
    }
}

fn worker_exit(
    live: &Mutex<usize>,
    fatal: &Mutex<Option<String>>,
    cv: &Condvar,
    err: Option<String>,
) {
    let mut l = live.lock().unwrap();
    *l -= 1;
    if let Some(e) = err {
        // a worker that failed to even build its context is fatal only
        // if it was the last one alive
        if *l == 0 {
            *fatal.lock().unwrap() = Some(e);
        }
    }
    cv.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_tasks_in_order() {
        let s = Scheduler::new(4);
        let m = Metrics::new();
        let out = s
            .run(
                (0..100).collect::<Vec<i32>>(),
                &FaultPlan::none(),
                &m,
                |_| Ok(()),
                |_, &t| Ok(t * 2),
            )
            .unwrap();
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(m.done(), 100);
        assert_eq!(m.retried(), 0);
    }

    #[test]
    fn transient_faults_are_retried() {
        let s = Scheduler::new(3);
        let m = Metrics::new();
        let out = s
            .run(
                (0..50).collect::<Vec<i32>>(),
                &FaultPlan::transient(5),
                &m,
                |_| Ok(()),
                |_, &t| Ok(t),
            )
            .unwrap();
        assert_eq!(out, (0..50).collect::<Vec<_>>());
        assert!(m.retried() > 0);
    }

    #[test]
    fn worker_death_is_survived() {
        let s = Scheduler::new(3);
        let m = Metrics::new();
        let out = s
            .run(
                (0..40).collect::<Vec<i32>>(),
                &FaultPlan::kill(1, 3),
                &m,
                |_| Ok(()),
                |_, &t| Ok(t + 1),
            )
            .unwrap();
        assert_eq!(out.len(), 40);
        assert_eq!(out[0], 1);
    }

    #[test]
    fn retry_budget_exhaustion_fails() {
        let s = Scheduler { n_workers: 2, max_retries: 2 };
        let m = Metrics::new();
        let err = s
            .run(
                vec![7i32],
                &FaultPlan::none(),
                &m,
                |_| Ok(()),
                |_, _| -> Result<i32> { Err(anyhow!("boom")) },
            )
            .unwrap_err();
        assert!(err.to_string().contains("boom"));
    }

    #[test]
    fn single_worker_context_failure_is_fatal() {
        let s = Scheduler::new(1);
        let m = Metrics::new();
        let err = s
            .run(
                vec![1i32],
                &FaultPlan::none(),
                &m,
                |_| -> Result<()> { Err(anyhow!("no device")) },
                |_, &t| Ok(t),
            )
            .unwrap_err();
        assert!(err.to_string().contains("no device"));
    }

    #[test]
    fn empty_task_list() {
        let s = Scheduler::new(2);
        let m = Metrics::new();
        let out: Vec<i32> = s
            .run(
                Vec::<i32>::new(),
                &FaultPlan::none(),
                &m,
                |_| Ok(()),
                |_, &t: &i32| Ok(t),
            )
            .unwrap();
        assert!(out.is_empty());
    }
}
