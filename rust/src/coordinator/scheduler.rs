//! One-shot work-queue scheduler — the legacy synchronous API, now a
//! thin scoped-thread wrapper over the persistent engine's worker loop
//! ([`crate::engine::core`]).
//!
//! `Scheduler::run` executes one task list to completion on N ephemeral
//! workers and returns. Production integrators no longer use it (they
//! submit to a long-lived [`crate::engine::Engine`] whose device
//! contexts and executable caches persist across calls); it remains the
//! entry point for the property tests, the cluster-scaling measurements,
//! and any caller that genuinely wants borrowed, non-`'static` closures.
//!
//! Because both paths share one worker loop, the retry/fault semantics
//! are identical by construction: bounded retries per task, transient
//! faults requeue, a dead worker's task is handed to its peers, and a
//! worker whose context construction fails is recorded in [`Metrics`]
//! and surfaced in the final error if the job later fails (previously
//! such errors were silently dropped unless the worker was the last one
//! alive). The old empty-queue `yield_now` spin-wait is gone: workers
//! block on the engine's condvar.

use std::marker::PhantomData;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::coordinator::fault::FaultPlan;
use crate::coordinator::progress::Metrics;
use crate::engine::core::{worker_loop, Backend, JobState, Shared};

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct Scheduler {
    pub n_workers: usize,
    /// Per-task retry budget (attempts = 1 + retries).
    pub max_retries: u32,
}

impl Default for Scheduler {
    fn default() -> Self {
        Scheduler { n_workers: 1, max_retries: 3 }
    }
}

/// Adapts a pair of borrowed closures to the engine's [`Backend`].
struct ClosureBackend<F, G, C, T, R> {
    make_ctx: F,
    run: G,
    _marker: PhantomData<fn() -> (C, T, R)>,
}

impl<F, G, C, T, R> Backend for ClosureBackend<F, G, C, T, R>
where
    F: Fn(usize) -> Result<C>,
    G: Fn(&C, &T) -> Result<R>,
{
    type Ctx = C;
    type Task = T;
    type Out = R;

    fn make_ctx(&self, worker: usize) -> Result<C> {
        (self.make_ctx)(worker)
    }

    fn run(&self, ctx: &C, task: &T) -> Result<R> {
        (self.run)(ctx, task)
    }
}

impl Scheduler {
    pub fn new(n_workers: usize) -> Self {
        Scheduler { n_workers, ..Default::default() }
    }

    /// Execute every task exactly once (semantically) and return results
    /// in task order.
    ///
    /// * `make_ctx(worker_idx)` builds the per-thread context (a
    ///   `DeviceRuntime` in production); called on the worker thread.
    /// * `run(ctx, task)` executes one task.
    /// * `fault` injects deterministic failures (including on context
    ///   construction, counted as attempt 0 faults).
    ///
    /// Fails if any task exhausts its retry budget or all workers die.
    pub fn run<T, R, C>(
        &self,
        tasks: Vec<T>,
        fault: &FaultPlan,
        metrics: &Metrics,
        make_ctx: impl Fn(usize) -> Result<C> + Sync,
        run: impl Fn(&C, &T) -> Result<R> + Sync,
    ) -> Result<Vec<R>>
    where
        T: Send + Sync,
        R: Send,
    {
        if self.n_workers == 0 {
            return Err(anyhow!("scheduler needs >= 1 worker"));
        }
        let backend = ClosureBackend {
            make_ctx,
            run,
            _marker: PhantomData,
        };
        let shared = Shared::new(self.n_workers);
        let job = Arc::new(JobState::new(tasks, self.max_retries));
        shared.enqueue(&job).expect("fresh queue accepts work");

        std::thread::scope(|scope| {
            for w in 0..self.n_workers {
                let shared = &shared;
                let backend = &backend;
                scope.spawn(move || {
                    worker_loop(w, shared, backend, fault, metrics)
                });
            }
            // Wait for this one job, then release the workers so the
            // scope can join them.
            let out = job.wait();
            shared.begin_shutdown();
            out
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_tasks_in_order() {
        let s = Scheduler::new(4);
        let m = Metrics::new();
        let out = s
            .run(
                (0..100).collect::<Vec<i32>>(),
                &FaultPlan::none(),
                &m,
                |_| Ok(()),
                |_, &t| Ok(t * 2),
            )
            .unwrap();
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(m.done(), 100);
        assert_eq!(m.retried(), 0);
    }

    #[test]
    fn transient_faults_are_retried() {
        let s = Scheduler::new(3);
        let m = Metrics::new();
        let out = s
            .run(
                (0..50).collect::<Vec<i32>>(),
                &FaultPlan::transient(5),
                &m,
                |_| Ok(()),
                |_, &t| Ok(t),
            )
            .unwrap();
        assert_eq!(out, (0..50).collect::<Vec<_>>());
        assert!(m.retried() > 0);
    }

    #[test]
    fn worker_death_is_survived() {
        let s = Scheduler::new(3);
        let m = Metrics::new();
        let out = s
            .run(
                (0..40).collect::<Vec<i32>>(),
                &FaultPlan::kill(1, 3),
                &m,
                |_| Ok(()),
                |_, &t| Ok(t + 1),
            )
            .unwrap();
        assert_eq!(out.len(), 40);
        assert_eq!(out[0], 1);
    }

    #[test]
    fn retry_budget_exhaustion_fails() {
        let s = Scheduler { n_workers: 2, max_retries: 2 };
        let m = Metrics::new();
        let err = s
            .run(
                vec![7i32],
                &FaultPlan::none(),
                &m,
                |_| Ok(()),
                |_, _| -> Result<i32> { Err(anyhow!("boom")) },
            )
            .unwrap_err();
        assert!(err.to_string().contains("boom"));
    }

    #[test]
    fn single_worker_context_failure_is_fatal() {
        let s = Scheduler::new(1);
        let m = Metrics::new();
        let err = s
            .run(
                vec![1i32],
                &FaultPlan::none(),
                &m,
                |_| -> Result<()> { Err(anyhow!("no device")) },
                |_, &t| Ok(t),
            )
            .unwrap_err();
        assert!(err.to_string().contains("no device"));
    }

    #[test]
    fn nonfinal_context_failure_is_recorded_not_fatal() {
        // Worker 0 can never build a context; worker 1 carries the job.
        // The error must land in Metrics instead of being dropped.
        let s = Scheduler::new(2);
        let m = Metrics::new();
        let out = s
            .run(
                (0..20).collect::<Vec<i32>>(),
                &FaultPlan::none(),
                &m,
                |w| {
                    if w == 0 {
                        Err(anyhow!("flaky node"))
                    } else {
                        Ok(())
                    }
                },
                |_, &t| Ok(t),
            )
            .unwrap();
        assert_eq!(out.len(), 20);
        let errs = m.worker_errors();
        assert_eq!(errs.len(), 1);
        assert!(errs[0].contains("flaky node"), "{errs:?}");
    }

    #[test]
    fn context_failure_surfaces_when_job_fails_later() {
        // Worker 0's context error is not fatal by itself, but when the
        // job dies on retries the root cause must mention it.
        let s = Scheduler { n_workers: 2, max_retries: 1 };
        let m = Metrics::new();
        let err = s
            .run(
                vec![1i32],
                &FaultPlan::none(),
                &m,
                |w| {
                    if w == 0 {
                        Err(anyhow!("bad PJRT plugin"))
                    } else {
                        // don't start until worker 0's error is recorded,
                        // so the failure message deterministically sees it
                        while m.worker_errors().is_empty() {
                            std::thread::sleep(
                                std::time::Duration::from_millis(1),
                            );
                        }
                        Ok(())
                    }
                },
                |_, _| -> Result<i32> { Err(anyhow!("launch failed")) },
            )
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("launch failed"), "{msg}");
        assert!(msg.contains("bad PJRT plugin"), "{msg}");
    }

    #[test]
    fn empty_task_list() {
        let s = Scheduler::new(2);
        let m = Metrics::new();
        let out: Vec<i32> = s
            .run(
                Vec::<i32>::new(),
                &FaultPlan::none(),
                &m,
                |_| Ok(()),
                |_, &t: &i32| Ok(t),
            )
            .unwrap();
        assert!(out.is_empty());
    }
}
