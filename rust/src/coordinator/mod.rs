//! The coordination layer — what Ray provided in the paper, rebuilt as a
//! deterministic work-queue over the simulated device pool.
//!
//! Production traffic runs on the persistent [`crate::engine`] (workers
//! and their executable caches live for the process lifetime; jobs are
//! submitted concurrently and awaited per-handle). This module holds
//! the policy pieces the engine enforces, plus the legacy one-shot
//! entry point:
//!
//! * [`scheduler`] — one-shot synchronous scheduler: runs a single task
//!   list on N ephemeral workers via the engine's worker loop; kept for
//!   the property tests and borrowed-closure callers.
//! * [`fault`] — deterministic failure injection (every k-th launch
//!   fails / a worker dies after m tasks), used to prove the retry path
//!   preserves results exactly (Philox counters make task execution
//!   idempotent, so at-least-once == exactly-once for the integrals).
//! * [`progress`] — counters + per-worker utilization for the benches.
//!
//! Correctness argument (tested in `tests/scheduler_prop.rs`): a task is
//! fully described by `(exe, inputs)` where inputs embed the Philox
//! `(seed, stream, trial, counter_base)`; re-running it on any worker
//! yields bit-identical sums, and the accumulator merge is commutative —
//! so results are invariant to worker count, scheduling order, and
//! injected failures.

pub mod fault;
pub mod progress;
pub mod scheduler;
