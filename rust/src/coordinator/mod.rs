//! The coordination layer — what Ray provided in the paper, rebuilt as a
//! deterministic work-queue scheduler over the simulated device pool.
//!
//! Responsibilities:
//! * [`scheduler`] — generic chunk scheduler: a shared FIFO of tasks,
//!   N worker threads (one [`DeviceRuntime`](crate::runtime::device)
//!   each), at-least-once execution with bounded retries.
//! * [`fault`] — deterministic failure injection (every k-th launch
//!   fails / a worker dies after m tasks), used to prove the retry path
//!   preserves results exactly (Philox counters make task execution
//!   idempotent, so at-least-once == exactly-once for the integrals).
//! * [`progress`] — counters + per-worker utilization for the benches.
//!
//! Correctness argument (tested in `tests/scheduler_prop.rs`): a task is
//! fully described by `(exe, inputs)` where inputs embed the Philox
//! `(seed, stream, trial, counter_base)`; re-running it on any worker
//! yields bit-identical sums, and the accumulator merge is commutative —
//! so results are invariant to worker count, scheduling order, and
//! injected failures.

pub mod fault;
pub mod progress;
pub mod scheduler;
