//! Run metrics: task counters, retries, per-worker utilization.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Metrics collected across one scheduler run or engine lifetime.
#[derive(Debug, Default)]
pub struct Metrics {
    pub tasks_done: AtomicU64,
    pub retries: AtomicU64,
    pub failures: AtomicU64,
    /// Tasks purged from the queue because their job's handle was
    /// dropped (or cancelled) before being awaited.
    pub cancellations: AtomicU64,
    /// Program-plan cache hits across this engine's workers (one per
    /// program row served from a worker's `ExecPlan` LRU).
    pub plan_hits: AtomicU64,
    /// Program-plan cache misses (row decoded + lowered on a worker).
    pub plan_misses: AtomicU64,
    /// Fused-plan cache hits (row served from a worker's `FusedPlan`
    /// LRU — the fused-tier twin of `plan_hits`).
    pub fused_hits: AtomicU64,
    /// Fused-plan cache misses (row decoded + lowered fused).
    pub fused_misses: AtomicU64,
    /// Canonical program classes executed by the batch subsystem's
    /// dedup path (one per structural equivalence class per run).
    pub dedup_unique: AtomicU64,
    /// Functions the batch dedup folded into an already-counted class
    /// (batch size minus classes, summed across runs) — each is one
    /// program the plan/fused caches and the registry never saw.
    pub dedup_folded: AtomicU64,
    /// Remote engines re-established after their host died (each is
    /// one successful reconnect + re-handshake by the supervisor).
    pub reconnects: AtomicU64,
    /// Failed reconnect attempts (the supervisor's backoff loop keeps
    /// counting until it succeeds or drains its retry budget).
    pub reconnect_failures: AtomicU64,
    /// (busy, total) wall time per worker, filled at worker exit.
    worker_times: Mutex<Vec<(Duration, Duration)>>,
    /// Context-construction failures (worker never joined the pool).
    /// Recorded even when peers keep the job alive, and appended to the
    /// final error of any job that later fails.
    worker_errors: Mutex<Vec<String>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn task_done(&self) {
        self.tasks_done.fetch_add(1, Ordering::Relaxed);
    }

    pub fn retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    pub fn failure(&self) {
        self.failures.fetch_add(1, Ordering::Relaxed);
    }

    /// Count `n` queued tasks purged by a job cancellation.
    pub fn record_cancelled(&self, n: u64) {
        self.cancellations.fetch_add(n, Ordering::Relaxed);
    }

    /// Fold one task's plan-cache events in (reported by the device
    /// backend after each launch).
    pub fn record_plan_events(&self, hits: u64, misses: u64) {
        if hits > 0 {
            self.plan_hits.fetch_add(hits, Ordering::Relaxed);
        }
        if misses > 0 {
            self.plan_misses.fetch_add(misses, Ordering::Relaxed);
        }
    }

    /// Plan-cache hits across this engine's workers.
    pub fn plan_hits(&self) -> u64 {
        self.plan_hits.load(Ordering::Relaxed)
    }

    /// Plan-cache misses (decode + lower) across this engine's workers.
    pub fn plan_misses(&self) -> u64 {
        self.plan_misses.load(Ordering::Relaxed)
    }

    /// Fold one task's fused-plan cache events in (reported by the
    /// device backend after each launch, like `record_plan_events`).
    pub fn record_fused_events(&self, hits: u64, misses: u64) {
        if hits > 0 {
            self.fused_hits.fetch_add(hits, Ordering::Relaxed);
        }
        if misses > 0 {
            self.fused_misses.fetch_add(misses, Ordering::Relaxed);
        }
    }

    /// Fused-plan cache hits across this engine's workers.
    pub fn fused_hits(&self) -> u64 {
        self.fused_hits.load(Ordering::Relaxed)
    }

    /// Fused-plan cache misses across this engine's workers.
    pub fn fused_misses(&self) -> u64 {
        self.fused_misses.load(Ordering::Relaxed)
    }

    /// Fold one batch run's dedup outcome in: `unique` canonical
    /// classes actually executed, `folded` functions that shared one
    /// of them (recorded by the batch subsystem per run).
    pub fn record_dedup_events(&self, unique: u64, folded: u64) {
        if unique > 0 {
            self.dedup_unique.fetch_add(unique, Ordering::Relaxed);
        }
        if folded > 0 {
            self.dedup_folded.fetch_add(folded, Ordering::Relaxed);
        }
    }

    /// Canonical program classes executed via the batch dedup path.
    pub fn dedup_unique(&self) -> u64 {
        self.dedup_unique.load(Ordering::Relaxed)
    }

    /// Functions folded away by batch dedup (never compiled/cached).
    pub fn dedup_folded(&self) -> u64 {
        self.dedup_folded.load(Ordering::Relaxed)
    }

    /// Count one successful remote-engine reconnect.
    pub fn reconnect(&self) {
        self.reconnects.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one failed reconnect attempt.
    pub fn reconnect_failure(&self) {
        self.reconnect_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// Successful remote-engine reconnects.
    pub fn reconnects(&self) -> u64 {
        self.reconnects.load(Ordering::Relaxed)
    }

    /// Failed reconnect attempts.
    pub fn reconnect_failures(&self) -> u64 {
        self.reconnect_failures.load(Ordering::Relaxed)
    }

    pub fn record_worker(&self, busy: Duration, total: Duration) {
        self.worker_times.lock().unwrap().push((busy, total));
    }

    /// Record a worker that died before serving any task (context
    /// construction failed).
    pub fn record_worker_error(&self, msg: String) {
        self.worker_errors.lock().unwrap().push(msg);
    }

    /// All recorded context-construction failures, in arrival order.
    pub fn worker_errors(&self) -> Vec<String> {
        self.worker_errors.lock().unwrap().clone()
    }

    pub fn done(&self) -> u64 {
        self.tasks_done.load(Ordering::Relaxed)
    }

    pub fn retried(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    pub fn failed(&self) -> u64 {
        self.failures.load(Ordering::Relaxed)
    }

    /// Tasks purged by job cancellations.
    pub fn cancelled(&self) -> u64 {
        self.cancellations.load(Ordering::Relaxed)
    }

    /// Mean fraction of wall time workers spent executing launches.
    pub fn utilization(&self) -> f64 {
        let w = self.worker_times.lock().unwrap();
        if w.is_empty() {
            return 0.0;
        }
        let fracs: f64 = w
            .iter()
            .map(|(busy, total)| {
                if total.as_secs_f64() > 0.0 {
                    busy.as_secs_f64() / total.as_secs_f64()
                } else {
                    0.0
                }
            })
            .sum();
        fracs / w.len() as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "tasks={} retries={} failures={} cancelled={} \
             plan_hits={} plan_misses={} fused_hits={} fused_misses={} \
             dedup_unique={} dedup_folded={} \
             reconnects={} reconnect_failures={} utilization={:.0}%",
            self.done(),
            self.retried(),
            self.failed(),
            self.cancelled(),
            self.plan_hits(),
            self.plan_misses(),
            self.fused_hits(),
            self.fused_misses(),
            self.dedup_unique(),
            self.dedup_folded(),
            self.reconnects(),
            self.reconnect_failures(),
            self.utilization() * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters() {
        let m = Metrics::new();
        m.task_done();
        m.task_done();
        m.retry();
        assert_eq!(m.done(), 2);
        assert_eq!(m.retried(), 1);
        assert_eq!(m.failed(), 0);
        assert_eq!(m.cancelled(), 0);
        m.record_cancelled(42);
        assert_eq!(m.cancelled(), 42);
        assert!(m.summary().contains("cancelled=42"));
        m.record_plan_events(5, 2);
        m.record_plan_events(1, 0);
        assert_eq!(m.plan_hits(), 6);
        assert_eq!(m.plan_misses(), 2);
        assert!(m.summary().contains("plan_hits=6"));
        m.record_fused_events(4, 1);
        m.record_fused_events(0, 1);
        assert_eq!(m.fused_hits(), 4);
        assert_eq!(m.fused_misses(), 2);
        assert!(m.summary().contains("fused_hits=4 fused_misses=2"));
        m.record_dedup_events(2, 98);
        m.record_dedup_events(1, 0);
        assert_eq!(m.dedup_unique(), 3);
        assert_eq!(m.dedup_folded(), 98);
        assert!(m.summary().contains("dedup_unique=3 dedup_folded=98"));
        m.reconnect();
        m.reconnect_failure();
        m.reconnect_failure();
        assert_eq!(m.reconnects(), 1);
        assert_eq!(m.reconnect_failures(), 2);
        assert!(m
            .summary()
            .contains("reconnects=1 reconnect_failures=2"));
    }

    #[test]
    fn worker_errors_accumulate() {
        let m = Metrics::new();
        assert!(m.worker_errors().is_empty());
        m.record_worker_error("worker 3: context: no device".into());
        m.record_worker_error("worker 5: context: oom".into());
        let errs = m.worker_errors();
        assert_eq!(errs.len(), 2);
        assert!(errs[0].contains("worker 3"));
    }

    #[test]
    fn utilization_mean() {
        let m = Metrics::new();
        m.record_worker(Duration::from_secs(1), Duration::from_secs(2));
        m.record_worker(Duration::from_secs(2), Duration::from_secs(2));
        assert!((m.utilization() - 0.75).abs() < 1e-12);
        assert!(m.summary().contains("utilization=75%"));
    }
}
