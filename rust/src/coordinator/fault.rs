//! Deterministic failure injection for the scheduler.
//!
//! Ray tolerates worker loss by rescheduling; we reproduce (and test)
//! that behaviour with two deterministic fault shapes instead of real
//! process kills:
//!
//! * **transient** — globally, every k-th task *attempt* returns an
//!   error (models a failed kernel launch / OOM / flaky node);
//! * **worker death** — worker w stops accepting tasks after its m-th
//!   attempt (models losing a node mid-job).

use std::sync::atomic::{AtomicU64, Ordering};

/// Shared, thread-safe fault plan consulted by every worker.
#[derive(Debug, Default)]
pub struct FaultPlan {
    /// Every k-th attempt (1-based, globally counted) fails.
    pub fail_every: Option<u64>,
    /// (worker index, attempts before it dies).
    pub kill_worker: Option<(usize, u64)>,
    attempts: AtomicU64,
}

/// What the plan says about one attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Proceed,
    /// This attempt must return an error (transient).
    FailAttempt,
    /// This worker is dead: it must stop pulling tasks.
    WorkerDead,
}

impl FaultPlan {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn transient(k: u64) -> Self {
        FaultPlan { fail_every: Some(k), ..Default::default() }
    }

    pub fn kill(worker: usize, after: u64) -> Self {
        FaultPlan { kill_worker: Some((worker, after)), ..Default::default() }
    }

    /// Called by a worker before each attempt.
    pub fn judge(&self, worker: usize, worker_attempts: u64) -> Verdict {
        if let Some((w, after)) = self.kill_worker {
            if w == worker && worker_attempts >= after {
                return Verdict::WorkerDead;
            }
        }
        let n = self.attempts.fetch_add(1, Ordering::Relaxed) + 1;
        if let Some(k) = self.fail_every {
            if n % k == 0 {
                return Verdict::FailAttempt;
            }
        }
        Verdict::Proceed
    }

    pub fn total_attempts(&self) -> u64 {
        self.attempts.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transient_every_third() {
        let p = FaultPlan::transient(3);
        let vs: Vec<Verdict> = (0..6).map(|_| p.judge(0, 0)).collect();
        assert_eq!(
            vs,
            vec![
                Verdict::Proceed,
                Verdict::Proceed,
                Verdict::FailAttempt,
                Verdict::Proceed,
                Verdict::Proceed,
                Verdict::FailAttempt,
            ]
        );
    }

    #[test]
    fn worker_death() {
        let p = FaultPlan::kill(1, 2);
        assert_eq!(p.judge(0, 100), Verdict::Proceed);
        assert_eq!(p.judge(1, 0), Verdict::Proceed);
        assert_eq!(p.judge(1, 1), Verdict::Proceed);
        assert_eq!(p.judge(1, 2), Verdict::WorkerDead);
        assert_eq!(p.judge(1, 3), Verdict::WorkerDead);
    }

    #[test]
    fn none_never_fails() {
        let p = FaultPlan::none();
        for _ in 0..100 {
            assert_eq!(p.judge(0, 0), Verdict::Proceed);
        }
        assert_eq!(p.total_attempts(), 100);
    }
}
