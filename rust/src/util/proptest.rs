//! Tiny property-based testing harness (offline stand-in for `proptest`).
//!
//! Deterministic: case `i` of a run derives all randomness from
//! `SplitMix64(seed + i)`, so failures reproduce by re-running the test.
//! On failure the harness reports the failing case index and seed; there
//! is no shrinking — generators are kept small-biased instead.

/// SplitMix64 — tiny, well-distributed PRNG for test-case generation.
/// (The *product* RNG is Philox in `sampler`; this one is test-only.)
#[derive(Clone, Debug)]
pub struct Gen {
    state: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n) — n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Inclusive integer range.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo) as u64 + 1)) as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.unit_f64()
    }

    /// Uniform f32 in [lo, hi), rounded through f32.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.range_f64(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

/// Run `f` for `cases` deterministic cases; panics with the case index on
/// the first failure (assert inside `f`).
pub fn check<F: FnMut(&mut Gen)>(seed: u64, cases: usize, mut f: F) {
    for i in 0..cases {
        let mut g = Gen::new(seed.wrapping_add(i as u64));
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut g)
        }));
        if let Err(e) = r {
            eprintln!(
                "property failed at case {i} (seed {seed}); rerun is \
                 deterministic"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_is_deterministic() {
        let mut a = Gen::new(7);
        let mut b = Gen::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_hold() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let x = g.range_i64(-3, 9);
            assert!((-3..=9).contains(&x));
            let u = g.unit_f64();
            assert!((0.0..1.0).contains(&u));
            let f = g.range_f64(2.0, 5.0);
            assert!((2.0..5.0).contains(&f));
            assert!(g.below(17) < 17);
        }
    }

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check(0, 25, |_| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failure() {
        check(0, 10, |g| assert!(g.below(10) < 5));
    }
}
